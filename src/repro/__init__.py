"""repro — GPTPU/GPETPU (Hsu & Tseng, SC'21) reproduced as a production JAX/TPU framework.

The package layers, bottom-up:

  kernels/      Pallas TPU kernels (int8 MXU matmul, stencil) with jnp oracles
  core/         the paper's contribution: Tensorizer (range-calibrated int8
                quantization, Eqs. 4-8), the GPETPU instruction set, instruction
                selection, the OPQ/IQ task-queue runtime, tpuGemm
  models/       the 10 assigned LM architectures (dense / MoE / SSM / hybrid /
                enc-dec / VLM backbones) with train_step / serve_step
  data/ optim/ checkpoint/ ft/ distributed/   substrate
  configs/      one config per assigned architecture + paper apps
  launch/       production mesh, multi-pod dry-run, train / serve drivers
"""

__version__ = "1.0.0"
