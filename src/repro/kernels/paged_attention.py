"""Pallas TPU kernel: block-native paged decode attention.

The paged serving cache (serving/store.py ``PagedKVStore``) keeps K/V in a
pool of fixed-size blocks — leaves ``(n_blocks, block_size, KV, hd)`` — and
maps each decode slot's sequence positions through a per-slot block table:
position ``p`` of slot ``b`` lives in pool cell
``(tables[b, p // block_size], p % block_size)``. PR 3's decode bridged this
layout by gathering every slot's blocks into a transient contiguous
``(B, S, KV, hd)`` view per step — correct, but the view is exactly the
working set paging exists to avoid. This kernel attends over the pool
*in place*:

Block-table addressing scheme
  * grid ``(B, MB)`` — one program per (slot, table entry). The block table
    and per-slot write indices ride in scalar-prefetch memory
    (``PrefetchScalarGridSpec``), so the input ``BlockSpec`` index map can
    address HBM *through the table*: program ``(b, j)`` DMAs pool block
    ``tables[b, j]`` into VMEM — never a gathered copy of the whole row, and
    blocks the table doesn't name are never touched.
  * table entries past a slot's lease point at the reserved null block 0;
    their positions ``j*bs + t`` exceed the slot's causal horizon
    ``index[b]``, so the kernel masks them before the softmax and their
    weight is exactly 0 — null-block contents can never leak into a slot.
  * GQA: query heads are folded as ``(KV, rep, hd)`` against the pool's KV
    heads inside VMEM — the pool is never expanded to ``n_heads``.
  * softmax is the online (flash-style) rescaling accumulated across the MB
    grid steps in VMEM scratch: running max ``m``, normalizer ``l``, and the
    unnormalized output ``acc``, finalized at ``j == MB - 1``.

Peak per-step working set: one ``(block_size, KV, hd)`` K and V tile plus
``(H, hd)`` accumulators per program — the pool stays the only HBM-resident
cache object (``memory_stats()["decode_view_bytes"] == 0``).

Numerics: the online softmax is mathematically the row softmax but not
bitwise identical to the jnp full-row reduction, so the engine's
bit-identity oracle (native == gather-bridge == contiguous,
tests/test_serving.py) runs on the jnp block-native path in
``models/attention.py paged_decode_attention``; this kernel is the TPU fast
path behind ``EngineConfig.paged_kernel`` and is validated against the
gather reference to float tolerance (interpret mode on CPU CI).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_kernel(tables_ref, index_ref, q_ref, k_ref, v_ref, o_ref,
                  m_ref, l_ref, acc_ref, *, bs: int, n_tbl: int,
                  sm_scale: float):
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    idx = index_ref[b]
    kpos = j * bs + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)[0]
    valid = kpos <= idx                                   # causal horizon
    q = q_ref[0].astype(jnp.float32)                      # (H, hd)
    k = k_ref[0].astype(jnp.float32)                      # (bs, KV, hd)
    v = v_ref[0].astype(jnp.float32)
    H, hd = q.shape
    KV = k.shape[1]
    rep = H // KV
    q3 = q.reshape(KV, rep, hd)                           # GQA fold, no expand
    s = jnp.einsum("grd,tgd->grt", q3, k) * sm_scale      # (KV, rep, bs)
    s = jnp.where(valid[None, None, :], s, NEG_INF).reshape(H, bs)
    m_prev = m_ref[...][:, :1]                            # (H, 1)
    l_prev = l_ref[...][:, :1]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    # explicit zero at masked positions: a fully-masked block (past the
    # lease) must contribute nothing even while m is still at NEG_INF
    p = jnp.where(valid[None, :], jnp.exp(s - m_new), 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + jnp.sum(p, axis=-1, keepdims=True)
    pv = jnp.einsum("grt,tgd->grd", p.reshape(KV, rep, bs), v).reshape(H, hd)
    acc_ref[...] = acc_ref[...] * corr + pv
    m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(j == n_tbl - 1)
    def _done():
        o_ref[0] = (acc_ref[...] / l_ref[...][:, :1]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_decode_attention(
    q: jax.Array,          # (B, H, hd) current-token queries
    k_pool: jax.Array,     # (n_blocks, block_size, KV, hd)
    v_pool: jax.Array,
    tables: jax.Array,     # (B, MB) int32 per-slot block tables
    index: jax.Array,      # (B,) int32 causal horizons (current positions)
    *,
    interpret: bool = False,
) -> jax.Array:
    """Single-token attention over the block pool through the tables.
    Returns (B, H, hd) f32. ``interpret=True`` runs the kernel on CPU (the
    fast-tier CI path)."""
    B, H, hd = q.shape
    _, bs, KV, _ = k_pool.shape
    MB = tables.shape[1]
    assert H % KV == 0, (H, KV)
    kernel = functools.partial(_paged_kernel, bs=bs, n_tbl=MB,
                               sm_scale=hd ** -0.5)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(B, MB),
            in_specs=[
                pl.BlockSpec((1, H, hd), lambda b, j, tbl, idx: (b, 0, 0)),
                pl.BlockSpec((1, bs, KV, hd),
                             lambda b, j, tbl, idx: (tbl[b, j], 0, 0, 0)),
                pl.BlockSpec((1, bs, KV, hd),
                             lambda b, j, tbl, idx: (tbl[b, j], 0, 0, 0)),
            ],
            out_specs=pl.BlockSpec((1, H, hd), lambda b, j, tbl, idx: (b, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((H, 128), jnp.float32),    # running max m
                pltpu.VMEM((H, 128), jnp.float32),    # normalizer l
                pltpu.VMEM((H, hd), jnp.float32),     # unnormalized output
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, H, hd), jnp.float32),
        interpret=interpret,
    )(tables, index, q, k_pool, v_pool)
