"""Pure-jnp oracles for every Pallas kernel (the correctness contracts).

Each oracle implements the kernel's exact mathematical semantics with no
tiling, so tests can ``assert_allclose(kernel(interpret=True), ref)`` across
shape/dtype sweeps.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def qgemm_ref(a_q: jax.Array, b_q: jax.Array, sb: jax.Array) -> jax.Array:
    """int8 x int8 -> int32 accumulate, per-output-channel dequant."""
    acc = jax.lax.dot_general(
        a_q, b_q, dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )
    return acc.astype(jnp.float32) * sb.reshape(1, -1)


def qgemm_tile_scales_ref(
    a_q: jax.Array, b_q: jax.Array, sa: jax.Array, sb: jax.Array, t: int = 128
) -> jax.Array:
    """Blocked dequant: partial(i,k,j) * sa[i,k] * sb[k,j], summed over k."""
    M, K = a_q.shape
    _, N = b_q.shape
    at = a_q.reshape(M // t, t, K // t, t).swapaxes(1, 2).astype(jnp.int32)
    bt = b_q.reshape(K // t, t, N // t, t).swapaxes(1, 2).astype(jnp.int32)
    partial = jnp.einsum("ikab,kjbc->ikjac", at, bt).astype(jnp.float32)
    scaled = partial * sa[:, :, None, None, None] * sb[None, :, :, None, None]
    out_tiles = scaled.sum(axis=1)                      # (Mb, Nb, t, t)
    return out_tiles.swapaxes(1, 2).reshape(M, N)


def stencil3x3_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """Zero-padded 3x3 cross-correlation (NN convention, stride 1)."""
    xp = jnp.pad(x, 1).astype(jnp.float32)
    H, W = x.shape
    out = jnp.zeros((H, W), jnp.float32)
    for p in range(3):
        for q in range(3):
            out = out + w[p, q] * xp[p:p + H, q:q + W]
    return out


def qgemv_ref(x: jax.Array, w_q: jax.Array, scale: jax.Array) -> jax.Array:
    return (x.astype(jnp.float32) @ w_q.astype(jnp.float32)) * scale.reshape(1, -1)
