"""Pallas TPU kernels for the paper's compute hot-spots.

  qgemm.py       W8A8 int8 MXU matmul (128-tile BlockSpecs, int32 accum, fused dequant)
  stencil3x3.py  HotSpot3D 3x3 weighted stencil (row-blocked VPU kernel)
  qdot_serve.py  int8-weight GEMV for the memory-bound decode path
  ops.py         jit'd public wrappers (auto interpret=True off-TPU)
  ref.py         pure-jnp oracles — the correctness contracts for tests
"""
