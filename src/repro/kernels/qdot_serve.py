"""Pallas TPU kernel: int8-weight x f32-activation GEMV for the decode path.

Decode (one token per step) is *memory-roofline bound*: the whole weight matrix
streams HBM->VMEM per step while compute is a single row of MACs. The paper's
int8 tensorization therefore pays exactly 2x here (half the bytes of bf16
weights), which is the dominant-term optimization recorded in EXPERIMENTS.md
§Perf for the decode cells.

Layout: weights (K, N) int8 with per-output-channel scales; activations
(B, K) f32 (B = decode batch, small). Blocks stream N in bn-wide stripes with
the full K resident — for LM d_model up to ~6k, a (K x 256) int8 stripe is
~1.5 MiB, well within VMEM, and B x K activations are reused across stripes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams

BN = 256


def _qgemv_kernel(x_ref, w_ref, s_ref, o_ref):
    x = x_ref[...]                                    # (B, K) f32
    w = w_ref[...].astype(jnp.float32)                # (K, bn) int8 -> f32 on VREGs
    o_ref[...] = (x @ w) * s_ref[...]                 # dequant epilogue


@functools.partial(jax.jit, static_argnames=("bn", "interpret"))
def qgemv(
    x: jax.Array,         # (B, K) f32 activations
    w_q: jax.Array,       # (K, N) int8 weights
    scale: jax.Array,     # (N,) f32 per-channel dequant scales
    *,
    bn: int = BN,
    interpret: bool = False,
) -> jax.Array:
    B, K = x.shape
    K2, N = w_q.shape
    assert K == K2 and N % bn == 0, (x.shape, w_q.shape, bn)
    grid = (N // bn,)
    return pl.pallas_call(
        _qgemv_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((B, K), lambda j: (0, 0)),
            pl.BlockSpec((K, bn), lambda j: (0, j)),
            pl.BlockSpec((1, bn), lambda j: (0, j)),
        ],
        out_specs=pl.BlockSpec((B, bn), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((B, N), jnp.float32),
        interpret=interpret,
        compiler_params=_CompilerParams(dimension_semantics=("arbitrary",)),
    )(x, w_q, scale.reshape(1, N))
