"""Pallas TPU kernel: 3x3 weighted stencil (HotSpot3D inner loop, paper §7.2.2).

The paper maps HotSpot3D onto the Edge TPU's ``conv2D`` instruction with a 3x3
kernel and no striding. On TPU we implement the stencil as a row-blocked Pallas
kernel: the wrapper materializes three row-shifted views (top/mid/bot) of the
zero-padded field so every grid step reads non-overlapping (bm, W+2) VMEM
blocks; the 3 column taps are static slices inside the block. This keeps the
working set in VMEM and turns the 9-tap stencil into fused VPU FMAs — the
memory-bound-optimal formulation (arithmetic intensity ~9 FLOP / 4 bytes).

The z-coupling of HotSpot3D (layer above/below + power density) is applied by
the caller as pairwise adds, exactly as the paper composes it from ``add``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams

BM = 256  # rows per block; W is kept whole (stencils are row-contiguous)


def _stencil_kernel(top_ref, mid_ref, bot_ref, w_ref, o_ref):
    w = w_ref[...]                          # (3, 3) in SMEM-like small block
    top, mid, bot = top_ref[...], mid_ref[...], bot_ref[...]
    Wp = mid.shape[1]
    acc = (
        top[:, 0:Wp - 2] * w[0, 0] + top[:, 1:Wp - 1] * w[0, 1] + top[:, 2:Wp] * w[0, 2]
        + mid[:, 0:Wp - 2] * w[1, 0] + mid[:, 1:Wp - 1] * w[1, 1] + mid[:, 2:Wp] * w[1, 2]
        + bot[:, 0:Wp - 2] * w[2, 0] + bot[:, 1:Wp - 1] * w[2, 1] + bot[:, 2:Wp] * w[2, 2]
    )
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("bm", "interpret"))
def stencil3x3(
    x: jax.Array,        # (H, W) f32 field
    w: jax.Array,        # (3, 3) f32 stencil weights
    *,
    bm: int = BM,
    interpret: bool = False,
) -> jax.Array:
    H, W = x.shape
    Hp = ((H + bm - 1) // bm) * bm
    xp = jnp.pad(x, [(1, 1 + (Hp - H)), (1, 1)])       # halo + row-block padding
    top = xp[0:Hp, :]
    mid = xp[1:Hp + 1, :]
    bot = xp[2:Hp + 2, :]
    grid = (Hp // bm,)
    out = pl.pallas_call(
        _stencil_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, W + 2), lambda i: (i, 0)),
            pl.BlockSpec((bm, W + 2), lambda i: (i, 0)),
            pl.BlockSpec((bm, W + 2), lambda i: (i, 0)),
            pl.BlockSpec((3, 3), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, W), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Hp, W), jnp.float32),
        interpret=interpret,
        compiler_params=_CompilerParams(dimension_semantics=("parallel",)),
    )(top, mid, bot, w.astype(jnp.float32))
    return out[:H]
