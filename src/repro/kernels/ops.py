"""Public jit'd wrappers over the Pallas kernels.

On the CPU container kernels execute in ``interpret=True`` mode (the kernel
body runs as traced jnp on CPU — bit-accurate semantics, no Mosaic); on a TPU
backend they compile to MXU/VPU code. ``_interpret()`` picks automatically;
callers can force either via the ``interpret`` kwarg.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import qdot_serve, qgemm, stencil3x3
from repro.kernels import ref  # noqa: F401  (re-exported for tests/benchmarks)


def _interpret(override: Optional[bool]) -> bool:
    if override is not None:
        return override
    return jax.default_backend() != "tpu"


def qgemm_f32(a_q, b_q, sb, *, interpret: Optional[bool] = None, **kw):
    """(M,K)i8 @ (K,N)i8 -> (M,N)f32 with per-channel dequant."""
    return qgemm.qgemm(a_q, b_q, sb, interpret=_interpret(interpret), **kw)


def qgemm_tiles(a_q, sa, b_q, sb, *, interpret: Optional[bool] = None):
    """Tile-grid layout entry used by core.gemm: (Mb,Kb,t,t) grids + per-tile scales."""
    t = a_q.shape[-1]
    Mb, Kb = a_q.shape[0], a_q.shape[1]
    Nb = b_q.shape[1]
    a2 = a_q.swapaxes(1, 2).reshape(Mb * t, Kb * t)
    b2 = b_q.swapaxes(1, 2).reshape(Kb * t, Nb * t)
    out = qgemm.qgemm_tile_scales(
        a2, b2, sa.reshape(Mb, Kb), sb.reshape(Kb, Nb),
        interpret=_interpret(interpret),
    )
    return out.reshape(Mb, t, Nb, t).swapaxes(1, 2)     # (Mb, Nb, t, t)


def qgemm_i32(a_q, b_q, *, interpret: Optional[bool] = None):
    """Raw int32 accumulation (scale=1), used by tensorizer.qdot(use_kernel=True)."""
    ones = jnp.ones((b_q.shape[1],), jnp.float32)
    return qgemm.qgemm(a_q, b_q, ones, interpret=_interpret(interpret))


def stencil(x, w, *, interpret: Optional[bool] = None, **kw):
    return stencil3x3.stencil3x3(x, w, interpret=_interpret(interpret), **kw)


def qgemv(x, w_q, scale, *, interpret: Optional[bool] = None, **kw):
    return qdot_serve.qgemv(x, w_q, scale, interpret=_interpret(interpret), **kw)
