"""Version shims for the pallas TPU API surface used by the kernels.

jax 0.4.x names the compiler-params dataclass ``TPUCompilerParams``;
jax >= 0.6 renamed it ``CompilerParams``. Import from here so the next
rename is a one-file fix.
"""

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
