"""Pallas TPU kernel: W8A8 int8 GEMM with int32 MXU accumulation + fused dequant.

This is the compute hot-spot of the paper's technique mapped to TPU v5e: the
Edge TPU's 128x128x8-bit systolic array (paper §3.3 — "the Edge TPU's matrix
unit is designed for computing on 128x128x8-bit matrices") corresponds exactly
to the v5e MXU, which runs int8 at 394 TOPS (2x bf16). BlockSpec tiling keeps
an (bm x bk) activation tile, a (bk x bn) weight tile and an (bm x bn) int32
accumulator resident in VMEM; the K-loop is the innermost ("arbitrary") grid
dimension so the accumulator never round-trips to HBM; dequantization happens
once per output tile in the epilogue (the paper's "aggregate in wider
registers", §6.2.1, fused on-chip).

Two variants:
  * ``qgemm``              — per-output-channel weight scales (production W8A8)
  * ``qgemm_tile_scales``  — per-128x128-tile scales for both operands (the
                             Tensorizer's blocked calibration, paper §6.2.1)

Validated against ``ref.py`` oracles in interpret mode (CPU container); on a
real TPU the same code lowers to MXU ops. Block shapes are hardware-aligned:
multiples of 128 in both MXU dims; int8 minor tiling (32, 128) divides them.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams

# MXU-aligned defaults. bk=512 amortizes the accumulator epilogue; VMEM use:
# bm*bk + bk*bn (int8) + bm*bn*4 (int32 acc) = 128*512*2 + 128*128*4 ≈ 196 KiB.
BM, BN, BK = 128, 128, 512


def _qgemm_kernel(a_ref, b_ref, sb_ref, o_ref, acc_ref, *, nk: int):
    """Grid = (M/bm, N/bn, K/bk); K is the sequential (arbitrary) axis."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        a_ref[...], b_ref[...],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )

    @pl.when(k == nk - 1)
    def _epilogue():
        # fused dequant: int32 accumulator -> f32, scaled per output channel
        o_ref[...] = acc_ref[...].astype(jnp.float32) * sb_ref[...]


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def qgemm(
    a_q: jax.Array,          # (M, K) int8 activations
    b_q: jax.Array,          # (K, N) int8 weights
    sb: jax.Array,           # (N,) f32 combined scale (sa * per-channel sb)
    *,
    bm: int = BM,
    bn: int = BN,
    bk: int = BK,
    interpret: bool = False,
) -> jax.Array:
    M, K = a_q.shape
    K2, N = b_q.shape
    assert K == K2 and M % bm == 0 and N % bn == 0 and K % bk == 0, (
        f"shapes must be block-aligned: {a_q.shape} @ {b_q.shape} vs ({bm},{bn},{bk})"
    )
    nk = K // bk
    grid = (M // bm, N // bn, nk)
    return pl.pallas_call(
        functools.partial(_qgemm_kernel, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],  # int32 accumulator tile
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
    )(a_q, b_q, sb.reshape(1, N))


def _qgemm_tile_scales_kernel(a_ref, b_ref, sa_ref, sb_ref, o_ref, acc_ref, *, nk: int):
    """Per-tile dequant: partial products are scaled by sa[i,k]*sb[k,j] *before*
    accumulation (scales differ along K), accumulator is f32."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    partial_i32 = jax.lax.dot_general(
        a_ref[...], b_ref[...],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )
    acc_ref[...] += partial_i32.astype(jnp.float32) * (sa_ref[0, 0] * sb_ref[0, 0])

    @pl.when(k == nk - 1)
    def _epilogue():
        o_ref[...] = acc_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def qgemm_tile_scales(
    a_q: jax.Array,          # (M, K) int8, tile-quantized
    b_q: jax.Array,          # (K, N) int8, tile-quantized
    sa: jax.Array,           # (M/128, K/128) f32 per-tile scales of a
    sb: jax.Array,           # (K/128, N/128) f32 per-tile scales of b
    *,
    interpret: bool = False,
) -> jax.Array:
    t = 128
    M, K = a_q.shape
    _, N = b_q.shape
    assert M % t == 0 and N % t == 0 and K % t == 0
    nk = K // t
    grid = (M // t, N // t, nk)
    return pl.pallas_call(
        functools.partial(_qgemm_tile_scales_kernel, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((t, t), lambda i, j, k: (i, k)),
            pl.BlockSpec((t, t), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, 1), lambda i, j, k: (i, k)),
            pl.BlockSpec((1, 1), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((t, t), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        scratch_shapes=[pltpu.VMEM((t, t), jnp.float32)],  # f32 accumulator tile
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
    )(a_q, b_q, sa, sb)
