"""Checkpointing: atomic, async, integrity-tagged, resharding-capable.

Format: one directory per step —
    step_000123/
      manifest.json     tree structure + shapes + dtypes + crc32 per leaf
      arr_00000.npy ... one file per leaf (host-gathered)
      _COMPLETE         commit marker (written last -> atomic)

Fault-tolerance contract (exercised in tests/test_ft.py):
  * a crash mid-save leaves no _COMPLETE marker; ``latest_step`` skips it;
  * ``load_checkpoint`` verifies crc32 per leaf (detects torn/corrupt files);
  * arrays are saved as full (host-replicated) values and re-sharded on load
    against whatever mesh the *restarted* job has — elastic re-mesh after a
    node failure loads the same checkpoint on a smaller/larger mesh.

Async: ``AsyncCheckpointer`` snapshots to host (device_get, blocking only on
transfer) then writes on a worker thread — training continues during the write
(compute/IO overlap, the checkpoint analogue of the paper's transfer overlap).
"""

from __future__ import annotations

import json
import shutil
import threading
import zlib
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten(tree) -> tuple[list, Any]:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save_checkpoint(ckpt_dir: str | Path, step: int, tree) -> Path:
    ckpt_dir = Path(ckpt_dir)
    final = ckpt_dir / f"step_{step:09d}"
    tmp = ckpt_dir / f".tmp_step_{step:09d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    leaves, treedef = _flatten(tree)
    manifest = {"step": step, "treedef": str(treedef), "leaves": []}
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        fname = f"arr_{i:05d}.npy"
        np.save(tmp / fname, arr)
        manifest["leaves"].append({
            "file": fname,
            "shape": list(arr.shape),
            "dtype": str(arr.dtype),
            "crc32": zlib.crc32(arr.tobytes()),
        })
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    (tmp / "_COMPLETE").write_text("ok")
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)
    return final


def latest_step(ckpt_dir: str | Path) -> Optional[int]:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = []
    for d in ckpt_dir.iterdir():
        if d.name.startswith("step_") and (d / "_COMPLETE").exists():
            steps.append(int(d.name.split("_")[1]))
    return max(steps) if steps else None


def load_checkpoint(ckpt_dir: str | Path, step: int, like_tree, *, shardings=None):
    """Load into the structure of ``like_tree``; reshard onto ``shardings``
    (a matching pytree of NamedSharding) when given — the elastic-restart path."""
    d = Path(ckpt_dir) / f"step_{step:09d}"
    manifest = json.loads((d / "manifest.json").read_text())
    leaves, treedef = _flatten(like_tree)
    assert len(leaves) == len(manifest["leaves"]), (
        f"checkpoint has {len(manifest['leaves'])} leaves, tree expects {len(leaves)}")
    out = []
    shard_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                    if shardings is not None else [None] * len(leaves))
    for meta, ref, shard in zip(manifest["leaves"], leaves, shard_leaves):
        arr = np.load(d / meta["file"])
        if zlib.crc32(arr.tobytes()) != meta["crc32"]:
            raise IOError(f"checkpoint corruption in {meta['file']} (crc mismatch)")
        if shard is not None:
            out.append(jax.device_put(arr, shard))
        else:
            out.append(jax.device_put(arr))
    return jax.tree_util.tree_unflatten(treedef, out)


class AsyncCheckpointer:
    """Snapshot-to-host synchronously, write-to-disk on a background thread."""

    def __init__(self, ckpt_dir: str | Path, keep_last: int = 3):
        self.ckpt_dir = Path(ckpt_dir)
        self.keep_last = keep_last
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, step: int, tree) -> None:
        self.wait()  # one outstanding write at a time
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            try:
                save_checkpoint(self.ckpt_dir, step, host_tree)
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self) -> None:
        steps = sorted(
            int(d.name.split("_")[1]) for d in self.ckpt_dir.iterdir()
            if d.name.startswith("step_") and (d / "_COMPLETE").exists())
        for s in steps[:-self.keep_last]:
            shutil.rmtree(self.ckpt_dir / f"step_{s:09d}", ignore_errors=True)
