"""Tensorizer — the paper's core contribution (GPETPU §6.2), adapted to TPU v5e.

The Edge TPU forces *all* computation through an int8 128x128 systolic array, so
GPETPU's Tensorizer does three jobs:

  1. derive a *range-calibrated* scaling factor per operator (paper Eqs. 4-8) so
     that quantized computation never overflows and stays within ~1% MAPE;
  2. partition arbitrary-shape operations into instructions at the hardware's
     optimal tile shape (128x128 int8);
  3. accumulate partial results in *wider* precision than the accelerator's 8-bit
     datapath (on the Edge TPU: host CPU registers; here: int32 inside the MXU /
     fp32 in VMEM).

On TPU v5e the same machinery is a 2x-throughput / 2x-bandwidth *optimization*
(int8 MXU = 394 TOPS vs 197 TFLOP/s bf16; int8 weights = half the HBM bytes),
selectable per-op, rather than a functional requirement. See DESIGN.md §2.

Conventions
-----------
``QTensor.scale`` is the *dequantization* multiplier: ``x_hat = q * scale``.
The paper's scaling factor ``S`` (Eqs. 4-8) is a *quantization* multiplier with
values mapped into [-1, 1] (``q = round(x * S * 127)``), i.e. ``scale = 1/(S*127)``.
``paper_scale_for`` returns S verbatim so the reproduction is auditable;
``scale_from_paper_S`` converts.
"""

from __future__ import annotations

import dataclasses
import enum
from functools import partial
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

QMAX = 127.0          # symmetric int8; -128 is excluded (paper uses +-127 range)
MXU_TILE = 128        # Edge TPU *and* TPU v5e MXU are 128x128 systolic arrays
MATRIXWISE_TILE = 64  # paper: mean/max favor 64x64 sub-matrices


class OpKind(enum.Enum):
    """Operator classes with distinct scaling rules (paper §6.2.2)."""

    MATMUL = "matmul"          # conv2D / FullyConnected       (Eq. 5)
    ADD_SUB = "add_sub"        # pair-wise add / sub           (Eq. 6)
    MUL = "mul"                # pair-wise mul                 (Eq. 7)
    ELEMENTWISE = "elementwise"  # tanh / relu / crop / ext / ...  (Eq. 8)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QTensor:
    """A symmetric-int8 quantized tensor: ``x_hat = q.astype(f32) * scale``.

    ``scale`` is a scalar (per-tensor) or broadcastable array (per-channel /
    per-tile). ``meta_shape`` records the pre-padding logical shape so that the
    Tensorizer's ``ext`` padding (paper §3.3) can be undone by ``crop``.
    """

    q: jax.Array
    scale: jax.Array
    meta_shape: Tuple[int, ...] = ()

    def tree_flatten(self):
        return (self.q, self.scale), (self.meta_shape,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        q, scale = children
        return cls(q=q, scale=scale, meta_shape=aux[0])

    @property
    def shape(self):
        return self.q.shape

    def dequantize(self) -> jax.Array:
        return self.q.astype(jnp.float32) * self.scale


# ---------------------------------------------------------------------------
# Paper scaling rules (Eqs. 4-8), verbatim.
# ---------------------------------------------------------------------------

def paper_scale_for(
    op: OpKind,
    lo: jax.Array,
    hi: jax.Array,
    n: Optional[int] = None,
) -> jax.Array:
    """Return the paper's scaling factor S for an operator given input range.

    ``lo``/``hi`` are the (sampled) min / max of the input dataset; ``n`` is the
    contraction dimension for MATMUL (paper Eq. 5 uses NxN inputs; we use the
    actual contraction length, which is the quantity that bounds the output).

    The rules guarantee ``|output| * S <= 1`` so the scaled output cannot
    overflow the accelerator's representable range (paper: "GPETPU prevents the
    case of overflow").
    """
    r = jnp.abs(hi - lo)
    r = jnp.maximum(r, 1e-12)  # guard degenerate all-equal datasets
    if op == OpKind.MATMUL:
        if n is None:
            raise ValueError("MATMUL scaling (Eq. 5) requires the contraction length n")
        return 1.0 / (r * r * n)                      # Eq. 5
    if op == OpKind.ADD_SUB:
        return 1.0 / (2.0 * r)                        # Eq. 6
    if op == OpKind.MUL:
        return 1.0 / (r * r)                          # Eq. 7
    return 1.0 / r                                    # Eq. 8 (elementwise & others)


def scale_from_paper_S(S: jax.Array) -> jax.Array:
    """Convert the paper's quantization multiplier S into a QTensor dequant scale."""
    return 1.0 / (S * QMAX)


# ---------------------------------------------------------------------------
# Calibration + quantize / dequantize
# ---------------------------------------------------------------------------

def amax_calibrate(
    x: jax.Array,
    axis: Optional[Sequence[int]] = None,
    keepdims: bool = True,
) -> jax.Array:
    """Absolute-max range calibration (the runtime part of Tensorizer §6.2.2).

    Per-tensor when ``axis is None``; per-channel / per-tile otherwise. This is
    a single O(bytes) reduction — the TPU analogue of the paper's 1.8 ms model
    writer: cheap enough to run per-buffer at dispatch time.
    """
    amax = jnp.max(jnp.abs(x)) if axis is None else jnp.max(jnp.abs(x), axis=axis, keepdims=keepdims)
    return jnp.maximum(amax, 1e-12) / QMAX


def quantize(
    x: jax.Array,
    scale: Optional[jax.Array] = None,
    axis: Optional[Sequence[int]] = None,
    snap_integer: bool = False,
) -> QTensor:
    """Symmetric int8 quantization. ``scale`` defaults to amax calibration.

    ``snap_integer``: when the data is already integer-valued with amax <= 127,
    snap the scale to 1 so quantization is EXACT — this mirrors the Edge TPU
    compiler's behavior on integer datasets and is how the paper's Gaussian /
    LUD rows measure 0.00% error (Table 4).
    """
    x = x.astype(jnp.float32)
    if scale is None:
        scale = amax_calibrate(x, axis=axis)
        if snap_integer:
            is_int = jnp.all(jnp.round(x) == x) & (jnp.max(jnp.abs(x)) <= QMAX)
            scale = jnp.where(is_int, jnp.ones_like(scale), scale)
    q = jnp.clip(jnp.round(x / scale), -QMAX, QMAX).astype(jnp.int8)
    return QTensor(q=q, scale=scale, meta_shape=tuple(x.shape))


def dequantize(qt: QTensor) -> jax.Array:
    return qt.dequantize()


def fake_quantize(x: jax.Array, axis: Optional[Sequence[int]] = None,
                  snap_integer: bool = False) -> jax.Array:
    """quantize->dequantize roundtrip; the QAT / error-model building block."""
    return dequantize(quantize(x, axis=axis, snap_integer=snap_integer))


# ---------------------------------------------------------------------------
# Wide-accumulation quantized contractions (the production path)
# ---------------------------------------------------------------------------

def qdot(
    a: jax.Array,
    b: jax.Array,
    *,
    per_channel: bool = True,
    use_kernel: Optional[bool] = None,
) -> jax.Array:
    """W8A8 matmul with int32 accumulation and fused dequant: ``a @ b`` in int8.

    ``a``: (..., M, K) activations, quantized per-tensor (amax).
    ``b``: (K, N) weights, quantized per-output-channel when ``per_channel``.

    int32 accumulation cannot overflow for K <= 2^31 / 127^2 ~= 133k — checked.
    This mirrors the paper's "aggregate on wider CPU registers" (§6.2.1) with
    the aggregation kept *inside* the MXU (DESIGN.md §2).

    ``use_kernel=True`` routes through the Pallas qgemm kernel (TPU target);
    default (None) uses the XLA int8 dot, which maps to the same MXU path.
    """
    K = a.shape[-1]
    if K > (2**31) // (127 * 127):
        raise ValueError(f"contraction dim {K} would overflow int32 accumulation")
    qa = quantize(a)
    qb = quantize(b, axis=(0,)) if per_channel else quantize(b)
    if use_kernel:
        from repro.kernels import ops as kernel_ops  # local import: kernels layer optional

        acc = kernel_ops.qgemm_i32(qa.q, qb.q)
    else:
        acc = jax.lax.dot_general(
            qa.q, qb.q,
            dimension_numbers=(((a.ndim - 1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32,
        )
    sb = qb.scale.reshape(-1) if per_channel else qb.scale  # (N,): rank-safe
    return acc.astype(jnp.float32) * qa.scale * sb


def qdot_paper(
    a: jax.Array,
    b: jax.Array,
    *,
    requantize_output: bool = False,
) -> jax.Array:
    """Paper-faithful GEMM quantization (Eq. 5 + §6.2.1 wide aggregation).

    Inputs are quantized against their sampled range (amax); accumulation is
    wide (int32 — the Edge TPU's host-CPU aggregation analogue), and Eq. 5's
    output-range factor ``S`` *bounds* the accumulated magnitude, guaranteeing
    the pipeline can never overflow — the property benchmarked against FBGEMM
    in paper Fig. 7 (see benchmarks/fig7_overflow.py). Output requantization
    to int8 against ``S`` happens only when the result feeds another on-device
    instruction (``requantize_output=True``), which is where chained-op error
    comes from.
    """
    lo = jnp.minimum(jnp.min(a), jnp.min(b))
    hi = jnp.maximum(jnp.max(a), jnp.max(b))
    K = a.shape[-1]
    S = paper_scale_for(OpKind.MATMUL, lo, hi, n=K)
    qa, qb = quantize(a), quantize(b, axis=(0,))
    acc = jax.lax.dot_general(
        qa.q, qb.q,
        dimension_numbers=(((a.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )
    out = acc.astype(jnp.float32) * (qa.scale * qb.scale)
    if requantize_output:
        q_out = jnp.clip(jnp.round(out * S * QMAX), -QMAX, QMAX)
        return q_out / (S * QMAX)
    return out


def qdot_naive_int8(a: jax.Array, b: jax.Array, input_range: float = 127.0) -> jax.Array:
    """The FBGEMM-style strawman of paper Fig. 7: dtype-range int8, no output
    calibration — saturates/overflows as value magnitudes grow. Used only by
    benchmarks to reproduce the paper's RMSE blow-up."""
    qa = jnp.clip(jnp.round(a), -QMAX, QMAX).astype(jnp.int8)
    qb = jnp.clip(jnp.round(b), -QMAX, QMAX).astype(jnp.int8)
    acc = jax.lax.dot_general(
        qa, qb,
        dimension_numbers=(((a.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )
    # emulate a 16-bit requantized output pipeline (no range awareness)
    return jnp.clip(acc, -(2**15), 2**15 - 1).astype(jnp.float32)


# ---------------------------------------------------------------------------
# Tile partitioning (paper §6.2.1 "mapping operators into instructions")
# ---------------------------------------------------------------------------

def round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def ext(x: jax.Array, row_mult: int = MXU_TILE, col_mult: int = MXU_TILE) -> jax.Array:
    """Pad a matrix to tile-aligned dimensionality (the paper's ``ext`` instruction)."""
    r, c = x.shape[-2], x.shape[-1]
    pad = [(0, 0)] * (x.ndim - 2) + [(0, round_up(r, row_mult) - r), (0, round_up(c, col_mult) - c)]
    return jnp.pad(x, pad)


def crop(x: jax.Array, rows: int, cols: int) -> jax.Array:
    """Remove padding, returning the logical sub-matrix (the paper's ``crop``)."""
    return x[..., :rows, :cols]


def partition(x: jax.Array, tile: int = MXU_TILE) -> jax.Array:
    """(R, C) -> (R/t, C/t, t, t) grid of MXU tiles (pads first)."""
    xp = ext(x, tile, tile)
    R, C = xp.shape[-2], xp.shape[-1]
    g = xp.reshape(*xp.shape[:-2], R // tile, tile, C // tile, tile)
    return jnp.swapaxes(g, -3, -2)

def reassemble(tiles: jax.Array, rows: int, cols: int) -> jax.Array:
    """Inverse of :func:`partition` followed by :func:`crop`."""
    g = jnp.swapaxes(tiles, -3, -2)
    t = g.shape[-1]
    x = g.reshape(*g.shape[:-4], g.shape[-4] * t, g.shape[-2] * t)
    return crop(x, rows, cols)


# ---------------------------------------------------------------------------
# Serving-time weight quantization (first-class framework integration)
# ---------------------------------------------------------------------------

def quantize_params(params, predicate=None):
    """Quantize every >=2D floating-point leaf of a param pytree to QTensor.

    This is the W8A8 serving path: weights live in HBM as int8 (half the
    memory-roofline bytes of bf16 — the measured §Perf win), activations are
    quantized per-dispatch by qdot. ``predicate(path, leaf) -> bool`` can
    exclude sensitive leaves (norm scales, SSM recurrence params...).
    """
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    out = []
    for path, leaf in flat:
        quantizable = (
            hasattr(leaf, "ndim") and leaf.ndim >= 2
            and jnp.issubdtype(leaf.dtype, jnp.floating)
            and (predicate is None or predicate(path, leaf))
        )
        # per-output-channel scales: reduce over the contraction dim (-2),
        # keeping any leading stacked-layer / expert axes (scan-compatible)
        out.append(quantize(leaf, axis=(-2,)) if quantizable else leaf)
    return jax.tree_util.tree_unflatten(treedef, out)
