"""Pod-scale tpuGemm: the paper's multi-accelerator GEMM (Fig. 8) on a
production mesh.

GPETPU scaled GEMM across 8 Edge TPUs by queueing independent tile tasks
(OPQ). On a TPU pod the same decomposition is expressed as GSPMD sharding:
M-rows over ``data``, N-columns over ``model`` — every chip owns an
(M/16 x N/16) output tile and the K-contraction streams fully local operand
panels (A row-panel replicated along model, B column-panel replicated along
data), i.e. the classic 2D SUMMA layout with *zero* inner-loop collectives;
only the operand broadcast appears as all-gathers at the edges.

The quantized variant runs the Tensorizer W8A8 path per shard — the paper's
technique at 256-chip scale. ``dryrun_distributed_gemm`` lowers + compiles it
on the production mesh and reports roofline terms (used by benchmarks).
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import tensorizer as tz
from repro.distributed import sharding as shd


def distributed_gemm(a: jax.Array, b: jax.Array, *, quantized: bool = True) -> jax.Array:
    """C = A @ B with A:(M,K) rows->data, B:(K,N) cols->model, C 2D-sharded."""
    a = shd.with_sharding(a, P("data", None))
    b = shd.with_sharding(b, P(None, "model"))
    if quantized:
        out = tz.qdot(a, b)
    else:
        out = a @ b
    return shd.with_sharding(out, P("data", "model"))


def dryrun_distributed_gemm(M: int = 32768, K: int = 32768, N: int = 32768,
                            quantized: bool = True) -> dict:
    """Lower + compile the pod-scale GEMM; return cost/collective stats."""
    from repro.launch.dryrun import collective_bytes

    mesh = shd.current_mesh()
    a = jax.ShapeDtypeStruct((M, K), jnp.float32,
                             sharding=NamedSharding(mesh, P("data", None)))
    b = jax.ShapeDtypeStruct((K, N), jnp.float32,
                             sharding=NamedSharding(mesh, P(None, "model")))
    fn = lambda x, y: distributed_gemm(x, y, quantized=quantized)
    compiled = jax.jit(fn).lower(a, b).compile()
    cost = compiled.cost_analysis() or {}
    coll = collective_bytes(compiled.as_text())
    flops_ideal = 2.0 * M * K * N / mesh.devices.size
    return {
        "flops_dev": cost.get("flops"),
        "bytes_dev": cost.get("bytes accessed"),
        "collective_bytes_dev": coll["total_bytes"],
        "ideal_flops_dev": flops_ideal,
        "n_devices": int(mesh.devices.size),
    }
