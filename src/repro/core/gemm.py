"""tpuGemm — the paper's flagship library routine (GPETPU §7.1).

Two complete lowerings of C = A @ B are provided, mirroring the paper:

  * ``fully_connected`` — iterate mat-vec products / tiled matmul (paper §7.1.1);
    on the Edge TPU this was the *slow* path (FullyConnected has 1/25 the RPS of
    conv2D); on a real TPU the MXU matmul is the native fast path.
  * ``conv2d`` — the paper's key algorithmic contribution (§7.1.2): reshape each
    row of A into a ceil(sqrt(K))^2 patch, each column of B into a kernel of the
    same shape, and run a *strided* convolution whose stride equals the patch
    size, producing exactly the same multiply-accumulate set as GEMM.

``instr_select`` chooses the lowering from the measured instruction cost table
(benchmarks/table1_ops.py), reproducing the paper's measure-then-rewrite
methodology; on TPU the ordering inverts (DESIGN.md §2) and matmul wins.
"""

from __future__ import annotations

import math
from functools import partial
from typing import Literal

import jax
import jax.numpy as jnp

from repro.core import tensorizer as tz

Lowering = Literal["fully_connected", "conv2d", "fp32"]


# ---------------------------------------------------------------------------
# FullyConnected lowering: 128-tile blocked int8 matmul, int32 accumulation
# ---------------------------------------------------------------------------

def gemm_fully_connected(a: jax.Array, b: jax.Array, *, use_kernel: bool = False) -> jax.Array:
    """Blocked W8A8 GEMM (the paper's §7.1.1 path, with the blocking algorithm
    of §6.2.1 'similar to [Dongarra & Sorensen]'): tiles are quantized with
    per-tile scales, partials accumulate in wide precision."""
    M, K = a.shape
    K2, N = b.shape
    assert K == K2, (a.shape, b.shape)
    t = tz.MXU_TILE
    at = tz.partition(a, t)                       # (Mb, Kb, t, t)
    bt = tz.partition(b, t)                       # (Kb, Nb, t, t)
    # per-tile symmetric scales — the Tensorizer's blocked calibration
    sa = tz.amax_calibrate(at, axis=(-1, -2))     # (Mb, Kb, 1, 1)
    sb = tz.amax_calibrate(bt, axis=(-1, -2))     # (Kb, Nb, 1, 1)
    qa = jnp.clip(jnp.round(at / sa), -tz.QMAX, tz.QMAX).astype(jnp.int8)
    qb = jnp.clip(jnp.round(bt / sb), -tz.QMAX, tz.QMAX).astype(jnp.int8)

    if use_kernel:
        from repro.kernels import ops as kernel_ops

        out_tiles = kernel_ops.qgemm_tiles(qa, sa, qb, sb)   # (Mb, Nb, t, t) f32
    else:
        # Per-(i,k,j) tile partial sums in int32 (wide accumulation), dequantized
        # with the pair of per-tile scales, then reduced over k — exactly the
        # paper's blocked algorithm with host-side wide aggregation.
        partial_ikj = jnp.einsum(
            "ikab,kjbc->ikjac", qa.astype(jnp.int32), qb.astype(jnp.int32),
        )  # (Mb, Kb, Nb, t, t)
        scaled = partial_ikj.astype(jnp.float32) * (
            sa[:, :, None, :, :] * jnp.swapaxes(sb, 0, 1)[None, :, :, :, :]
        )
        out_tiles = jnp.sum(scaled, axis=1)       # (Mb, Nb, t, t) f32
    return tz.reassemble(out_tiles, M, N)


# ---------------------------------------------------------------------------
# conv2D lowering (paper §7.1.2, Figure 4)
# ---------------------------------------------------------------------------

def _patch_layout(a: jax.Array) -> tuple[jax.Array, int, int]:
    """Reshape each row of A (M,K) into an s x s patch, stacked vertically:
    returns (M*s, s) 'image', with K zero-padded to s*s (paper: the kernel
    matrix 'contains exactly the same or similar amount of elements')."""
    M, K = a.shape
    s = math.isqrt(K - 1) + 1 if K > 0 else 1     # ceil(sqrt(K))
    ap = jnp.pad(a, [(0, 0), (0, s * s - K)])
    return ap.reshape(M * s, s), s, s


def gemm_conv2d(a: jax.Array, b: jax.Array, *, quantized: bool = True) -> jax.Array:
    """GEMM lowered onto strided conv2D: stride (s, s) walks the patch grid so
    each output element is exactly the GEMM dot product (Eq. 9 with stride)."""
    M, K = a.shape
    K2, N = b.shape
    assert K == K2
    img, sx, sy = _patch_layout(a)                              # (M*sx, sy)
    # each column of B becomes one kernel, padded to the same patch shape
    kern = jnp.pad(b, [(0, sx * sy - K), (0, 0)]).reshape(sx, sy, 1, N)
    if quantized:
        qi, qk = tz.quantize(img), tz.quantize(kern)
        x4 = qi.q[None, :, :, None]
        out = jax.lax.conv_general_dilated(
            x4, qk.q, window_strides=(sx, sy), padding="VALID",
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
            preferred_element_type=jnp.int32,
        )[0, :, 0, :].astype(jnp.float32) * (qi.scale * qk.scale)
    else:
        x4 = img[None, :, :, None].astype(jnp.float32)
        out = jax.lax.conv_general_dilated(
            x4, kern.astype(jnp.float32), window_strides=(sx, sy), padding="VALID",
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
        )[0, :, 0, :]
    return out                                                   # (M, N)


# ---------------------------------------------------------------------------
# public entry
# ---------------------------------------------------------------------------

def tpu_gemm(
    a: jax.Array,
    b: jax.Array,
    lowering: Lowering | None = None,
    *,
    use_kernel: bool = False,
) -> jax.Array:
    """The library GEMM (paper's ``tpuGemm``). ``lowering=None`` consults
    :mod:`repro.core.instr_select` (measured cost table)."""
    if lowering is None:
        from repro.core import instr_select

        lowering = instr_select.best_gemm_lowering()
    if lowering == "fp32":
        return a.astype(jnp.float32) @ b.astype(jnp.float32)
    if lowering == "conv2d":
        return gemm_conv2d(a, b)
    return gemm_fully_connected(a, b, use_kernel=use_kernel)
