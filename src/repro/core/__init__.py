"""GPETPU core: Tensorizer, instruction set, instruction selection, OPQ runtime, tpuGemm."""

from repro.core import gemm, instr, instr_select, opq, tensorizer  # noqa: F401
from repro.core.gemm import tpu_gemm  # noqa: F401
from repro.core.opq import OPQ, Buffer  # noqa: F401
from repro.core.tensorizer import QTensor, dequantize, qdot, quantize  # noqa: F401
