"""Instruction selection — the paper's Table-1 methodology, made a live component.

GPETPU measured OPS (ops/sec) and RPS (results/sec) per Edge TPU instruction
(paper §3.2, Eqs. 1-3) and rewrote algorithms to use the highest-RPS
instruction: on that hardware conv2D beat FullyConnected by 25x in RPS, so GEMM
was lowered onto strided conv2D (§7.1.2).

Here the same table is (re-)measured on the actual backend by
``benchmarks/table1_ops.py`` and cached; ``best_gemm_lowering`` consumes it.
On TPU v5e the ordering *inverts* (matmul is the MXU-native op; conv lowers to
matmul with layout overhead) — the framework discovers that from data rather
than assuming it, exactly as the paper did.
"""

from __future__ import annotations

import json
import os
import time
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp

_CACHE_ENV = "REPRO_INSTR_TABLE"
_DEFAULT_CACHE = os.path.join(os.path.dirname(__file__), "_instr_table.json")
_table: Optional[Dict[str, Dict[str, float]]] = None


def measure_op(fn: Callable, *args, iters: int = 30) -> Dict[str, float]:
    """OPS / RPS via the paper's two-run differencing (Eqs. 1-2): run the op
    ``iters`` and ``2*iters`` times; the difference cancels transfer/setup time."""
    f = jax.jit(fn)
    out = f(*args)
    jax.block_until_ready(out)  # compile + warm

    def run(n: int) -> float:
        t0 = time.perf_counter()
        for _ in range(n):
            out = f(*args)
        jax.block_until_ready(out)
        return time.perf_counter() - t0

    t1, t2 = run(iters), run(2 * iters)
    dt = max(t2 - t1, 1e-9)
    n_results = int(jnp.size(out))
    return {
        "ops_per_s": iters / dt,                    # Eq. 1
        "results_per_s": iters * n_results / dt,    # Eq. 2
    }


def build_table(size: int = 256, iters: int = 20) -> Dict[str, Dict[str, float]]:
    """Measure every GPETPU instruction (paper Table 1) on this backend."""
    from repro.core import instr as I

    key = jax.random.PRNGKey(0)
    a = jax.random.normal(key, (size, size), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(1), (size, size), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (size,), jnp.float32)
    k3 = jax.random.normal(jax.random.PRNGKey(3), (3, 3), jnp.float32)

    cases = {
        "conv2D": (I.conv2d_quant, (a, k3)),
        "FullyConnected": (I.fully_connected_quant, (v, b)),
        "sub": (I.sub_quant, (a, b)),
        "add": (I.add_quant, (a, b)),
        "mul": (I.mul_quant, (a, b)),
        "crop": (lambda x: I.crop_fp(x, size // 2, size // 2), (a,)),
        "ext": (lambda x: I.ext_fp(x), (a,)),
        "mean": (I.mean_quant, (a,)),
        "max": (I.max_quant, (a,)),
        "tanh": (I.tanh_quant, (a,)),
        "ReLu": (I.relu_quant, (a,)),
        # GEMM lowerings measured head-to-head for best_gemm_lowering
        "gemm_fully_connected": (lambda x, y: _gemm_fc(x, y), (a, b)),
        "gemm_conv2d": (lambda x, y: _gemm_conv(x, y), (a, b)),
    }
    table = {}
    for name, (fn, args) in cases.items():
        table[name] = measure_op(fn, *args, iters=iters)
    return table


def _gemm_fc(a, b):
    from repro.core import gemm

    return gemm.gemm_fully_connected(a, b)


def _gemm_conv(a, b):
    from repro.core import gemm

    return gemm.gemm_conv2d(a, b)


def get_table(refresh: bool = False) -> Dict[str, Dict[str, float]]:
    global _table
    path = os.environ.get(_CACHE_ENV, _DEFAULT_CACHE)
    if _table is not None and not refresh:
        return _table
    if not refresh and os.path.exists(path):
        with open(path) as f:
            _table = json.load(f)
        return _table
    _table = build_table()
    try:
        with open(path, "w") as f:
            json.dump(_table, f, indent=1)
    except OSError:
        pass
    return _table


def best_gemm_lowering() -> str:
    """Pick the GEMM lowering with the highest measured RPS (paper §7.1.3)."""
    t = get_table()
    fc = t.get("gemm_fully_connected", {}).get("results_per_s", 0.0)
    cv = t.get("gemm_conv2d", {}).get("results_per_s", 0.0)
    return "fully_connected" if fc >= cv else "conv2d"
