"""The GPETPU instruction set (paper Table 1) as JAX operations.

Each instruction exists in two lowerings:

  * ``fp``      — reference/bf16 semantics (what the host would compute);
  * ``quant``   — Tensorizer-calibrated int8 semantics (what the Edge TPU
                  executes; on v5e this is the int8-MXU fast path).

The OPQ runtime dispatches these; ``instr_select`` picks lowerings; the paper's
applications (§7.2) are written against this set exactly as OpenCtpu programs
call ``openctpu_invoke_operator``.
"""

from __future__ import annotations

import enum
from typing import Callable, Dict

import jax
import jax.numpy as jnp

from repro.core import tensorizer as tz


class Instr(enum.Enum):
    CONV2D = "conv2D"
    FULLY_CONNECTED = "FullyConnected"
    SUB = "sub"
    ADD = "add"
    MUL = "mul"
    CROP = "crop"
    EXT = "ext"
    MEAN = "mean"
    MAX = "max"
    TANH = "tanh"
    RELU = "ReLu"


# --------------------------------------------------------------------------
# fp lowerings (the semantics; Table 1 "Description" column)
# --------------------------------------------------------------------------

def conv2d_fp(x: jax.Array, kernel: jax.Array, stride=(1, 1), padding="SAME") -> jax.Array:
    """2D convolution (cross-correlation, NN convention) of a matrix by a kernel."""
    x4 = x[None, :, :, None].astype(jnp.float32)           # NHWC
    k4 = kernel[:, :, None, None].astype(jnp.float32)      # HWIO
    out = jax.lax.conv_general_dilated(
        x4, k4, window_strides=stride, padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return out[0, :, :, 0]


def fully_connected_fp(v: jax.Array, w: jax.Array) -> jax.Array:
    """Input vector (or batch of vectors) multiplies a weight matrix."""
    return v.astype(jnp.float32) @ w.astype(jnp.float32)


def add_fp(a, b):
    return a + b

def sub_fp(a, b):
    return a - b

def mul_fp(a, b):
    return a * b

def mean_fp(a):
    return jnp.mean(a)

def max_fp(a):
    return jnp.max(a)

def tanh_fp(a):
    return jnp.tanh(a)

def relu_fp(a):
    return jnp.maximum(a, 0.0)

crop_fp = tz.crop
ext_fp = tz.ext


# --------------------------------------------------------------------------
# Quantized lowerings (Tensorizer semantics)
# --------------------------------------------------------------------------

def _pairwise_quant(op: Callable, kind: tz.OpKind):
    """Pairwise int8 op with *sampled* output-range scaling (paper Eq. 4).

    Eqs. 6-7 are the worst-case default bounds; §6.2.2 says the Tensorizer
    "estimates the range of output values" from sampled input ranges — the
    tight bounds below are exactly that estimate and remain overflow-proof:
        add/sub:  |out| <= amax_a + amax_b
        mul:      |out| <= amax_a * amax_b
    """
    def f(a: jax.Array, b: jax.Array) -> jax.Array:
        amax_a = jnp.maximum(jnp.max(jnp.abs(a)), 1e-12)
        amax_b = jnp.maximum(jnp.max(jnp.abs(b)), 1e-12)
        bound = amax_a * amax_b if kind == tz.OpKind.MUL else amax_a + amax_b
        S = 1.0 / bound                                       # Eq. 4
        out = op(tz.fake_quantize(a, snap_integer=True),
                 tz.fake_quantize(b, snap_integer=True))
        # integer fast path: integer inputs with an in-range output bound stay
        # exact end-to-end (scale snapped to 1 — paper Table 4's 0.00% rows)
        both_int = (jnp.all(jnp.round(a) == a) & jnp.all(jnp.round(b) == b)
                    & (bound <= tz.QMAX))
        q = jnp.clip(jnp.round(out * S * tz.QMAX), -tz.QMAX, tz.QMAX)
        return jnp.where(both_int, out, q / (S * tz.QMAX))
    return f


add_quant = _pairwise_quant(add_fp, tz.OpKind.ADD_SUB)
sub_quant = _pairwise_quant(sub_fp, tz.OpKind.ADD_SUB)
mul_quant = _pairwise_quant(mul_fp, tz.OpKind.MUL)


def fully_connected_quant(v: jax.Array, w: jax.Array) -> jax.Array:
    return tz.qdot(v, w)


def conv2d_quant(x: jax.Array, kernel: jax.Array, stride=(1, 1), padding="SAME") -> jax.Array:
    qx, qk = tz.quantize(x), tz.quantize(kernel)
    x4 = qx.q[None, :, :, None].astype(jnp.int8)
    k4 = qk.q[:, :, None, None].astype(jnp.int8)
    out = jax.lax.conv_general_dilated(
        x4, k4, window_strides=stride, padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        preferred_element_type=jnp.int32,
    )
    return out[0, :, :, 0].astype(jnp.float32) * (qx.scale * qk.scale)


def _elementwise_quant(op: Callable):
    def f(a: jax.Array) -> jax.Array:
        return op(tz.fake_quantize(a))
    return f


tanh_quant = _elementwise_quant(tanh_fp)
relu_quant = _elementwise_quant(relu_fp)


def mean_quant(a: jax.Array) -> jax.Array:
    """Matrix-wise op: 64x64 sub-matrix instructions + host-side aggregation
    (paper §6.2.1: CPU aggregates because a second accelerator round-trip costs
    more than the 4096x-reduced data)."""
    t = tz.MATRIXWISE_TILE
    tiles = tz.partition(a, t)  # zero-padding is accounted for by true-count
    per_tile = jnp.sum(tz.fake_quantize(tiles), axis=(-1, -2))
    return jnp.sum(per_tile) / a.size


def max_quant(a: jax.Array) -> jax.Array:
    t = tz.MATRIXWISE_TILE
    neg = jnp.min(a) - 1.0
    ap = jnp.pad(a, [(0, tz.round_up(a.shape[0], t) - a.shape[0]),
                     (0, tz.round_up(a.shape[1], t) - a.shape[1])],
                 constant_values=neg)
    tiles = tz.partition(ap, t)
    per_tile = jnp.max(tz.fake_quantize(tiles), axis=(-1, -2))
    return jnp.max(per_tile)


# --------------------------------------------------------------------------
# Dispatch tables
# --------------------------------------------------------------------------

FP: Dict[Instr, Callable] = {
    Instr.CONV2D: conv2d_fp,
    Instr.FULLY_CONNECTED: fully_connected_fp,
    Instr.ADD: add_fp,
    Instr.SUB: sub_fp,
    Instr.MUL: mul_fp,
    Instr.CROP: crop_fp,
    Instr.EXT: ext_fp,
    Instr.MEAN: mean_fp,
    Instr.MAX: max_fp,
    Instr.TANH: tanh_fp,
    Instr.RELU: relu_fp,
}

QUANT: Dict[Instr, Callable] = {
    Instr.CONV2D: conv2d_quant,
    Instr.FULLY_CONNECTED: fully_connected_quant,
    Instr.ADD: add_quant,
    Instr.SUB: sub_quant,
    Instr.MUL: mul_quant,
    Instr.CROP: crop_fp,   # shape ops are exact in either lowering
    Instr.EXT: ext_fp,
    Instr.MEAN: mean_quant,
    Instr.MAX: max_quant,
    Instr.TANH: tanh_quant,
    Instr.RELU: relu_quant,
}


def invoke(instr: Instr, *args, quantized: bool = True, **kw):
    """``openctpu_invoke_operator`` — execute one accelerator instruction."""
    table = QUANT if quantized else FP
    return table[instr](*args, **kw)
