"""The GPETPU runtime: OPQ / IQ dataflow task scheduler (paper §6.1, Fig. 3).

OpenCtpu semantics reproduced:

  * ``enqueue(kernel, *buffers)``     -> task id   (``openctpu_enqueue``)
  * tasks execute out-of-order, operators within a task serialize;
  * ``sync()`` / ``wait(task_id)``                  (``openctpu_sync/_wait``)

Scheduling policy (paper §6.1): after the Tensorizer rewrites a task's operator
into tile-granularity *instructions* (IQ entries), instructions that share the
same input buffer, quantization flags, and task id are pinned to the device
already holding that data (affinity — avoids re-transfer and re-quantization);
everything else is first-come-first-served onto the least-loaded device.

Production posture: the scheduler also implements *straggler mitigation* by
backup re-issue — if an instruction sits in a device lane longer than
``straggler_factor`` x the lane's moving-average service time, a backup copy is
issued to the fastest lane and whichever finishes first wins (results are
idempotent pure functions, so duplicated execution is safe). This is exercised
in tests with an injected slow executor.
"""

from __future__ import annotations

import dataclasses
import itertools
import threading
import time
from collections import defaultdict
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np


@dataclasses.dataclass
class Buffer:
    """``openctpu_buffer``: host data + dimensionality + device placement map."""

    data: Any                                  # host array (np/jnp)
    name: str = ""
    _on_device: Dict[int, Any] = dataclasses.field(default_factory=dict)

    def to_device(self, device) -> Any:
        did = device.id
        if did not in self._on_device:
            self._on_device[did] = jax.device_put(self.data, device)
        return self._on_device[did]

    @property
    def resident_devices(self) -> List[int]:
        return list(self._on_device)

    @classmethod
    def resident(cls, data: Any, device, name: str = "") -> "Buffer":
        """Wrap a pytree already living on ``device`` (no transfer): the buffer
        is born resident, so the affinity policy can pin follow-up work to the
        device that holds it. The serving engine uses this for params and the
        in-flight KV cache (serving/engine.py)."""
        buf = cls(data, name)
        buf._on_device[device.id] = data
        return buf


@dataclasses.dataclass
class Instruction:
    """One IQ entry: a pure function applied to buffers (a tile-level op)."""

    task_id: int
    fn: Callable
    buffers: Tuple[Buffer, ...]
    flags: str = ""                            # quantization method etc.
    seq: int = 0                               # order within the task (serialized)


@dataclasses.dataclass
class _Lane:
    """Per-device execution lane with service-time stats for straggler detection."""

    device: Any
    pending: int = 0
    ema_service_s: float = 1e-3

    def observe(self, dt: float) -> None:
        self.ema_service_s = 0.9 * self.ema_service_s + 0.1 * dt


class OPQ:
    """The operation-queue runtime over a set of JAX devices.

    Device-parallelism note: on the CPU container there is a single device, so
    lanes share one executor; on a real machine ``jax.devices()`` exposes all
    accelerators and lanes dispatch concurrently (JAX dispatch is async).
    """

    def __init__(
        self,
        devices: Optional[Sequence[Any]] = None,
        *,
        straggler_factor: float = 8.0,
        enable_backup_tasks: bool = True,
        executor: Optional[Callable[[Instruction, Any], Any]] = None,
    ):
        self.devices = list(devices if devices is not None else jax.devices())
        self.lanes = [_Lane(d) for d in self.devices]
        self.straggler_factor = straggler_factor
        self.enable_backup_tasks = enable_backup_tasks
        self._executor = executor or self._default_executor
        self._task_counter = itertools.count()
        self._task_futures: Dict[int, List[Future]] = defaultdict(list)
        self._task_prev: Dict[int, Future] = {}   # chains in-task serialization
        self._pool = ThreadPoolExecutor(max_workers=max(2, len(self.devices)))
        self._lock = threading.Lock()
        self.stats = {"issued": 0, "backups_issued": 0, "affinity_hits": 0}
        # per-flag instruction counts ("prefill/32", "decode", ...): the
        # audit trail callers use to assert dispatch shape — e.g. the serving
        # engine's fused admission proves zero replay decodes ever ran
        self.flag_counts: Dict[str, int] = defaultdict(int)

    # ------------------------------------------------------------------ API

    def enqueue(self, kernel: Callable, *buffers: Buffer, flags: str = "") -> int:
        """``openctpu_enqueue``: run ``kernel`` which may call :meth:`invoke`.

        The kernel executes immediately on the host (mirroring the paper: the
        kernel function body runs until it reaches ``openctpu_invoke_operator``)
        and its operator invocations are scheduled asynchronously.
        """
        task_id = next(self._task_counter)
        seq = itertools.count()

        def invoke(fn: Callable, *bufs: Buffer, flags: str = flags) -> Future:
            ins = Instruction(task_id, fn, tuple(bufs), flags, next(seq))
            return self._schedule(ins, chain=True)

        kernel(invoke, *buffers)
        # the kernel body has enqueued every instruction — drop the chain tail
        self._task_prev.pop(task_id, None)
        return task_id

    def invoke_operator(self, fn: Callable, *buffers: Buffer, flags: str = "",
                        track: bool = True) -> Future:
        """Single-operator task (``openctpu_invoke_operator`` outside a kernel).

        ``track=False`` skips the task-futures registry: the caller owns the
        returned Future and the result is not retained for ``sync()``. Long-
        running callers (the serving engine: one instruction per decode step,
        forever) must use this or the registry grows without bound."""
        task_id = next(self._task_counter)
        return self._schedule(Instruction(task_id, fn, tuple(buffers), flags),
                              track=track)

    def wait(self, task_id: int):
        """``openctpu_wait``: block until every instruction of a task finished."""
        futs = self._task_futures.get(task_id, [])
        return [f.result() for f in futs]

    def sync(self):
        """``openctpu_sync``: block until *all* tasks finished; returns results
        grouped by task id."""
        out = {}
        for tid in sorted(self._task_futures):
            out[tid] = self.wait(tid)
        return out

    # ------------------------------------------------------------ scheduling

    def _pick_lane(self, ins: Instruction) -> Tuple[_Lane, bool]:
        # Affinity (paper §6.1): same input already resident on a device ->
        # schedule there, avoiding the transfer + re-transformation.
        for b in ins.buffers:
            for did in b.resident_devices:
                for lane in self.lanes:
                    if lane.device.id == did:
                        return lane, True
        # FCFS onto the least-loaded lane otherwise.
        return min(self.lanes, key=lambda l: l.pending), False

    def _schedule(self, ins: Instruction, track: bool = True,
                  chain: bool = False) -> Future:
        lane, affinity = self._pick_lane(ins)
        with self._lock:
            self.stats["issued"] += 1
            self.flag_counts[ins.flags] += 1
            if affinity:
                self.stats["affinity_hits"] += 1
            lane.pending += 1
        # Operators within a task serialize (paper §5): kernel-enqueued
        # instructions (``chain=True``) wait on their task's previous one.
        # Safe with a FIFO pool: a waiter's dependency is always earlier in
        # the queue, so it can never starve. invoke_operator tasks are
        # single-instruction and skip the chain registry entirely (no growth).
        prev = self._task_prev.get(ins.task_id) if chain else None
        if prev is None:
            fut: Future = self._pool.submit(self._run_with_backup, ins, lane)
        else:
            def chained(prev=prev, ins=ins, lane=lane):
                prev.exception()   # wait for predecessor; its failure doesn't
                                   # cancel successors (futures stay per-op)
                return self._run_with_backup(ins, lane)
            fut = self._pool.submit(chained)
        if chain:
            self._task_prev[ins.task_id] = fut
        if track:
            self._task_futures[ins.task_id].append(fut)
        return fut

    def _run_with_backup(self, ins: Instruction, lane: _Lane):
        t0 = time.perf_counter()
        deadline = lane.ema_service_s * self.straggler_factor
        try:
            result = self._executor(ins, lane.device)
        except _StragglerTimeout:
            # Backup-task policy: re-issue on the currently fastest lane.
            with self._lock:
                self.stats["backups_issued"] += 1
            backup = min(self.lanes, key=lambda l: l.ema_service_s)
            result = self._executor(ins, backup.device)
        finally:
            with self._lock:
                lane.pending -= 1
        dt = time.perf_counter() - t0
        lane.observe(dt)
        if self.enable_backup_tasks and dt > deadline and len(self.lanes) > 1:
            # Late detection (post-hoc): record for telemetry; result stands.
            with self._lock:
                self.stats.setdefault("stragglers_detected", 0)
                self.stats["stragglers_detected"] += 1
        return result

    # ------------------------------------------------------------- executors

    @staticmethod
    def _default_executor(ins: Instruction, device):
        args = [b.to_device(device) for b in ins.buffers]
        out = ins.fn(*args)
        return jax.block_until_ready(out)

    def shutdown(self):
        self._pool.shutdown(wait=True)


class _StragglerTimeout(Exception):
    """Raised by injectable executors (tests) to trigger the backup path."""
