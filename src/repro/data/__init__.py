from repro.data.pipeline import SyntheticLM, TokenFileDataset, make_dataset  # noqa: F401
