"""Token data pipeline: deterministic, shardable, and checkpoint-resumable.

Production posture for 1000+ nodes:
  * each data-parallel host reads only its shard (``shard_id/num_shards``);
  * the iterator is a pure function of (seed, step) — no hidden state — so a
    restart from step N reproduces exactly the batches a failed run would have
    seen (``state()``/``restore()`` are just the step counter);
  * double-buffered host->device transfer (the CPU analogue of the paper's
    "overlap Tensorizer with data movement", §6.2.3).

Two sources:
  * SyntheticLM      — seeded LCG token streams (tests / dry-runs / examples)
  * TokenFileDataset — memory-mapped uint16/uint32 token files (real corpora)
"""

from __future__ import annotations

import dataclasses
from pathlib import Path
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass
class SyntheticLM:
    """Deterministic synthetic LM batches: batch of (tokens, labels)."""

    vocab: int
    seq_len: int
    global_batch: int
    shard_id: int = 0
    num_shards: int = 1
    seed: int = 0
    step: int = 0

    def __post_init__(self):
        assert self.global_batch % self.num_shards == 0
        self.local_batch = self.global_batch // self.num_shards

    def _batch_at(self, step: int) -> Dict[str, np.ndarray]:
        # Philox-like independence: seed per (step, shard)
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.shard_id]))
        tokens = rng.integers(
            0, self.vocab, (self.local_batch, self.seq_len), dtype=np.int32)
        # labels are the same stream (next-token objective shifts internally)
        return {"tokens": tokens, "labels": tokens}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        b = self._batch_at(self.step)
        self.step += 1
        return b

    # ---- checkpoint interface ----
    def state(self) -> Dict:
        return {"step": self.step, "seed": self.seed,
                "shard_id": self.shard_id, "num_shards": self.num_shards}

    def restore(self, state: Dict) -> None:
        assert state["seed"] == self.seed, "seed mismatch on restore"
        self.step = int(state["step"])


@dataclasses.dataclass
class TokenFileDataset:
    """Memory-mapped token file, sliced into (batch, seq) windows.

    File layout: flat little-endian token ids (uint16 when vocab < 65536).
    Window w of shard s at step t is deterministic: contiguous strided reads —
    restart-safe like SyntheticLM.
    """

    path: str
    vocab: int
    seq_len: int
    global_batch: int
    shard_id: int = 0
    num_shards: int = 1
    step: int = 0
    dtype: str = "uint16"

    def __post_init__(self):
        assert self.global_batch % self.num_shards == 0
        self.local_batch = self.global_batch // self.num_shards
        self._mm = np.memmap(self.path, dtype=self.dtype, mode="r")
        self.n_windows = len(self._mm) // self.seq_len

    def _batch_at(self, step: int) -> Dict[str, np.ndarray]:
        idx = (step * self.global_batch
               + self.shard_id * self.local_batch
               + np.arange(self.local_batch)) % max(1, self.n_windows - 1)
        tokens = np.stack([
            self._mm[i * self.seq_len:(i + 1) * self.seq_len] for i in idx
        ]).astype(np.int32)
        return {"tokens": tokens, "labels": tokens}

    def __iter__(self):
        return self

    def __next__(self):
        b = self._batch_at(self.step)
        self.step += 1
        return b

    def state(self) -> Dict:
        return {"step": self.step, "path": str(self.path)}

    def restore(self, state: Dict) -> None:
        self.step = int(state["step"])


def make_dataset(cfg, shape, *, path: Optional[str] = None,
                 shard_id: int = 0, num_shards: int = 1, seed: int = 0):
    if path:
        return TokenFileDataset(path=path, vocab=cfg.vocab, seq_len=shape.seq_len,
                                global_batch=shape.global_batch,
                                shard_id=shard_id, num_shards=num_shards)
    return SyntheticLM(vocab=cfg.vocab, seq_len=shape.seq_len,
                       global_batch=shape.global_batch,
                       shard_id=shard_id, num_shards=num_shards, seed=seed)
