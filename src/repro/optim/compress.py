"""Gradient compression with error feedback (distributed-optimization trick).

int8 range-calibrated gradient quantization — the Tensorizer applied to the
DP gradient all-reduce (4x fewer wire bytes than f32, 2x fewer than bf16) —
with per-leaf error feedback (residual carried to the next step) so the
compression bias vanishes in expectation (Karimireddy et al., 2019).

Usage in a train step:
    g_q, ef = compress_grads(grads, ef)      # before the (simulated) reduce
    ... all-reduce g_q.q (int8 payload) ...
    grads = decompress_grads(g_q)

The dry-run measures the effect as a collective-bytes reduction when enabled
(cfg flag threaded by the launcher); tests verify the error-feedback
convergence property.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import tensorizer as tz


def init_error_feedback(grads) -> Any:
    return jax.tree.map(lambda g: jnp.zeros_like(g, dtype=jnp.float32), grads)


def compress_grads(grads, error_feedback=None) -> Tuple[Any, Any]:
    """Quantize each gradient leaf to int8 (per-tensor amax scale), carrying
    the quantization residual into ``error_feedback`` for the next step."""
    if error_feedback is None:
        error_feedback = init_error_feedback(grads)

    def one(g, ef):
        corrected = g.astype(jnp.float32) + ef
        qt = tz.quantize(corrected)
        new_ef = corrected - qt.dequantize()
        return qt, new_ef

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(error_feedback)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    q_tree = treedef.unflatten([o[0] for o in out])
    ef_tree = treedef.unflatten([o[1] for o in out])
    return q_tree, ef_tree


def decompress_grads(q_tree):
    return jax.tree.map(
        lambda q: q.dequantize(),
        q_tree, is_leaf=lambda x: isinstance(x, tz.QTensor))
