"""LR schedules (pure functions of the step counter, scan/jit friendly)."""

from __future__ import annotations

import jax.numpy as jnp


def cosine_schedule(step, *, peak: float, warmup: int = 100, total: int = 10000,
                    floor_ratio: float = 0.1):
    t = step.astype(jnp.float32)
    warm = peak * t / jnp.maximum(1.0, float(warmup))
    prog = jnp.clip((t - warmup) / jnp.maximum(1.0, float(total - warmup)), 0.0, 1.0)
    cos = peak * (floor_ratio + (1 - floor_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
    return jnp.where(t < warmup, warm, cos)
