"""Decoupled AdamW with global-norm clipping — sharded-state friendly.

Optimizer states inherit the parameter sharding (ZeRO-0); with ``cfg.zero1``
the launch layer additionally shards them over the data axis (ZeRO-1), which
the dry-run exposes as reduce-scatter + all-gather instead of all-reduce.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    mu: dict
    nu: dict


def adamw_init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
    )


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), gn


def adamw_update(
    params,
    grads,
    state: AdamWState,
    lr,
    *,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
):
    """Returns (new_params, new_state). All math in f32."""
    step = state.step + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m_new = b1 * m + (1 - b1) * gf
        v_new = b2 * v + (1 - b2) * gf * gf
        mhat = m_new / bc1
        vhat = v_new / bc2
        delta = mhat / (jnp.sqrt(vhat) + eps)
        # decoupled weight decay only on >=2D weights (skip norms/biases/gates)
        wd = weight_decay if p.ndim >= 2 else 0.0
        p_new = p.astype(jnp.float32) - lr * (delta + wd * p.astype(jnp.float32))
        return p_new.astype(p.dtype), m_new, v_new

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state.mu)
    flat_v = tdef.flatten_up_to(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step=step, mu=new_m, nu=new_v)
