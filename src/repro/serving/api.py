"""Streaming HTTP serve API over an Engine or Router (stdlib-only).

The first externally-consumable interface to the stack: a small HTTP server
fronting either a single :class:`~repro.serving.engine.Engine` or the
multi-host :class:`~repro.serving.router.Router`, with

  * ``POST /v1/completions`` — token generation, optionally streamed as
    Server-Sent Events (``"stream": true``): one ``data: {"token": t,
    "index": i}`` event per generated token AS IT LANDS (incremental
    delivery is asserted in CI), then a final ``data: {"done": true, ...}``
    and ``data: [DONE]``. Per-request sampling params (temperature, top_k,
    top_p, repetition_penalty, seed, stop) map straight onto
    :class:`~repro.serving.sampling.SamplingParams`. ``"logprobs": true``
    adds each emitted token's log-probability (from the very logits row the
    token choice used — no second forward, no second executable), and
    ``"top_logprobs": k`` (k <= sampling.TOP_LOGPROBS) its k most likely
    alternatives; both ride token events and the non-streamed response, and
    are strictly opt-in — responses without them are byte-identical to
    before.
  * ``POST /v1/embeddings`` / ``POST /v1/classify`` — the non-generative
    endpoints: one fused bucketed forward (``Engine.embed``) returning the
    prompt's last-position hidden state, or a softmax over candidate token
    ids' logits. No slot is leased; classification is zero-shot over the
    LM head.
  * ``GET /v1/stats`` — the engine/fleet telemetry, JSON-sanitized.
  * ``GET /healthz`` — liveness.

Threading model: the Engine/Router are NOT thread-safe (host-side slot
state, OPQ dispatch), so ONE driver thread owns the backend and runs the
serve loop (step + harvest); HTTP handler threads (ThreadingHTTPServer)
talk to it exclusively through a command queue and receive tokens through
per-request stream queues. The driver thread enters the jax mesh context
itself (``mesh=`` argument) because jax's active-mesh state is
thread-local — the creating thread's ``with mesh:`` does not reach here.

Requests and responses carry token IDS, not text: tokenization is the
client's business (the repo has no tokenizer dependency), which also keeps
the bit-identity story auditable end to end.
"""

from __future__ import annotations

import contextlib
import json
import queue
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Dict, List, Optional

import numpy as np

from repro.distributed import sharding as shd
from repro.serving.engine import Engine
from repro.serving.router import Router
from repro.serving.sampling import SamplingParams

__all__ = ["ApiServer", "serve_api"]

_IDLE_WAIT_S = 0.02          # command-queue poll while the backend is empty
_STREAM_TIMEOUT_S = 120.0    # handler-side wait for the next token event


def _jsonable(obj):
    """Stats trees mix numpy scalars, inf, and tuples — make them JSON."""
    if isinstance(obj, dict):
        return {str(k): _jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonable(v) for v in obj]
    if isinstance(obj, (np.integer,)):
        return int(obj)
    if isinstance(obj, (np.floating, float)):
        f = float(obj)
        return f if np.isfinite(f) else str(f)
    if isinstance(obj, np.ndarray):
        return obj.tolist()
    return obj


def _params_from(body: Dict) -> Optional[SamplingParams]:
    """Request-body sampling fields -> SamplingParams (None == plain greedy,
    the engine's zero-cost default). Raises ValueError on bad values — the
    handler turns that into a 400."""
    stop = body.get("stop") or ()
    if isinstance(stop, (int, float)):
        stop = [int(stop)]
    sp = SamplingParams(
        temperature=float(body.get("temperature", 0.0)),
        top_k=int(body.get("top_k", 0)),
        top_p=float(body.get("top_p", 1.0)),
        repetition_penalty=float(body.get("repetition_penalty", 1.0)),
        seed=int(body.get("seed", 0)),
        stop=tuple(tuple(s) if isinstance(s, (list, tuple)) else (int(s),)
                   for s in stop))
    return None if sp == SamplingParams() else sp


class _Backend:
    """Uniform driver-thread view over Engine | Router: submit/step/harvest
    with live per-request token access (Router.progress covers mid-segment
    tokens so a drain mid-stream never stalls the SSE feed)."""

    def __init__(self, target):
        self.target = target
        self.is_router = isinstance(target, Router)

    def submit(self, prompt, max_new_tokens, sampling, want_logprobs=None):
        return self.target.submit(prompt, max_new_tokens, sampling=sampling,
                                  want_logprobs=want_logprobs, strict=True)

    def tokens(self, handle) -> List[int]:
        if self.is_router:
            return self.target.progress(handle)
        return list(handle.tokens)

    @staticmethod
    def logprob_rows(handle):
        """(logprobs, top_logprobs) mirrors — Request and RouterRequest both
        carry them, appended atomically with each token, so slicing by the
        token cursor stays aligned."""
        return handle.logprobs, handle.top_logprobs

    @staticmethod
    def done(handle) -> bool:
        return bool(handle.done)

    @staticmethod
    def finish_reason(handle) -> Optional[str]:
        return getattr(handle, "finish_reason", None)

    def embed(self, prompt):
        return self.target.embed(prompt)

    def step(self):
        self.target.step()

    def has_work(self) -> bool:
        return self.target.has_work()

    def stats(self) -> Dict:
        return self.target.stats()


class _ServeLoop(threading.Thread):
    """The single thread that owns the backend. Commands arrive as
    ``(kind, payload, reply_q)``; generation streams leave through the
    per-request queues as ``("token", (id, logprob_fields|None))`` /
    ``("done", finish_reason)`` / ``("error", message)`` events. A server
    shutdown flushes ``("done", "shutdown")`` to every live stream so no
    SSE consumer is left hanging without a terminal frame."""

    def __init__(self, backend: _Backend, mesh=None):
        super().__init__(daemon=True, name="serve-loop")
        self.backend = backend
        self.mesh = mesh
        self.cmds: "queue.Queue" = queue.Queue()
        # not named _stop: threading.Thread defines an internal _stop()
        # method that join() calls, and shadowing it breaks teardown
        self._halt = threading.Event()
        # live streams: key -> [handle, stream_q, n_tokens_sent]
        self._streams: Dict[int, list] = {}
        self._keys = iter(range(1 << 62))

    # ------------------------------------------------- handler-thread side

    def call(self, kind: str, payload):
        """Execute one command on the driver thread, propagating errors."""
        reply: "queue.Queue" = queue.Queue()
        self.cmds.put((kind, payload, reply))
        ok, val = reply.get(timeout=_STREAM_TIMEOUT_S)
        if not ok:
            raise val
        return val

    def stop(self):
        self._halt.set()
        self.cmds.put(None)          # wake the idle wait

    # -------------------------------------------------- driver-thread side

    def _handle(self, cmd) -> None:
        kind, payload, reply = cmd
        try:
            if kind == "submit":
                prompt, gen, sampling, want = payload
                handle = self.backend.submit(prompt, gen, sampling, want)
                q: "queue.Queue" = queue.Queue()
                self._streams[next(self._keys)] = [handle, q, 0, want]
                reply.put((True, q))
            elif kind == "embed":
                reply.put((True, self.backend.embed(payload)))
            elif kind == "stats":
                reply.put((True, self.backend.stats()))
            else:
                reply.put((False, ValueError(f"unknown command {kind!r}")))
        except Exception as exc:            # surfaced as the caller's error
            reply.put((False, exc))

    def _harvest(self) -> None:
        for key in list(self._streams):
            handle, q, sent, want = self._streams[key]
            toks = self.backend.tokens(handle)
            lps = tls = ()
            if want is not None:
                lps, tls = self.backend.logprob_rows(handle)
            for j in range(sent, len(toks)):
                extra = None
                if want is not None and j < len(lps):
                    extra = {"logprob": float(lps[j])}
                    if want > 0:
                        extra["top_logprobs"] = [
                            [int(t), float(v)] for t, v in tls[j][:want]]
                q.put(("token", (int(toks[j]), extra)))
            self._streams[key][2] = len(toks)
            if self.backend.done(handle):
                q.put(("done", self.backend.finish_reason(handle)))
                del self._streams[key]

    def run(self) -> None:
        ctx = (shd.use_mesh(self.mesh) if self.mesh is not None
               else contextlib.nullcontext())
        with ctx:
            while not self._halt.is_set():
                try:
                    cmd = self.cmds.get(
                        block=not self.backend.has_work(),
                        timeout=_IDLE_WAIT_S)
                except queue.Empty:
                    cmd = None
                if self._halt.is_set():
                    break
                if cmd is not None:
                    self._handle(cmd)
                    continue             # drain commands before stepping
                if self.backend.has_work():
                    try:
                        self.backend.step()
                    except Exception as exc:
                        # a failed step poisons every live stream, not the
                        # server: report and keep serving new requests
                        for _, q, _, _ in self._streams.values():
                            q.put(("error", f"{type(exc).__name__}: {exc}"))
                        self._streams.clear()
                    self._harvest()
        # graceful shutdown: every stream still live gets a terminal frame
        # (an SSE consumer must never hang waiting on a dead server)
        for _, q, _, _ in self._streams.values():
            q.put(("done", "shutdown"))
        self._streams.clear()


def _make_handler(loop: _ServeLoop):
    class Handler(BaseHTTPRequestHandler):
        # HTTP/1.0 + Connection: close — SSE needs no chunked framing, the
        # stream ends when the socket does
        protocol_version = "HTTP/1.0"

        def log_message(self, *args):    # quiet: the engine has its own logs
            pass

        # ------------------------------------------------------ plumbing

        def _json(self, code: int, obj) -> None:
            body = json.dumps(_jsonable(obj)).encode()
            self.send_response(code)
            self.send_header("Content-Type", "application/json")
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)

        def _body(self) -> Dict:
            n = int(self.headers.get("Content-Length", 0))
            if n <= 0:
                return {}
            return json.loads(self.rfile.read(n).decode())

        def _sse_event(self, obj) -> None:
            data = obj if isinstance(obj, str) else json.dumps(obj)
            self.wfile.write(f"data: {data}\n\n".encode())
            self.wfile.flush()

        # ----------------------------------------------------- endpoints

        def do_GET(self):
            if self.path == "/healthz":
                self._json(200, {"ok": True})
            elif self.path == "/v1/stats":
                self._json(200, loop.call("stats", None))
            else:
                self._json(404, {"error": f"no route {self.path}"})

        def do_POST(self):
            try:
                body = self._body()
            except (ValueError, json.JSONDecodeError) as exc:
                return self._json(400, {"error": f"bad JSON body: {exc}"})
            try:
                if self.path == "/v1/completions":
                    return self._completions(body)
                if self.path == "/v1/embeddings":
                    return self._embeddings(body)
                if self.path == "/v1/classify":
                    return self._classify(body)
            except Exception as exc:     # engine-door rejections -> 400
                return self._json(400, {"error": f"{type(exc).__name__}: "
                                                 f"{exc}"})
            self._json(404, {"error": f"no route {self.path}"})

        def _completions(self, body: Dict) -> None:
            prompt = body.get("prompt")
            if not prompt:
                return self._json(400, {"error": "prompt (a list of token "
                                                 "ids) is required"})
            gen = int(body.get("max_new_tokens", 16))
            sampling = _params_from(body)
            # logprobs are opt-in: "logprobs": true records each token's
            # log-probability; "top_logprobs": k adds its k alternatives
            # (k bounded by the device-side capture width — the engine's
            # door rejects more with a 400 here)
            want = (int(body.get("top_logprobs", 0))
                    if body.get("logprobs") else None)
            stream_q = loop.call("submit", (prompt, gen, sampling, want))
            if not body.get("stream"):
                toks, lps, tls, reason = [], [], [], None
                while True:
                    kind, val = stream_q.get(timeout=_STREAM_TIMEOUT_S)
                    if kind == "token":
                        tok, extra = val
                        toks.append(tok)
                        if extra is not None:
                            lps.append(extra["logprob"])
                            tls.append(extra.get("top_logprobs", []))
                    elif kind == "done":
                        reason = val
                        break
                    else:
                        return self._json(500, {"error": val})
                out = {"tokens": toks, "finish_reason": reason}
                if want is not None:
                    out["logprobs"] = lps
                    if want > 0:
                        out["top_logprobs"] = tls
                return self._json(200, out)
            self.send_response(200)
            self.send_header("Content-Type", "text/event-stream")
            self.send_header("Cache-Control", "no-cache")
            self.send_header("Connection", "close")
            self.end_headers()
            i = 0
            while True:
                kind, val = stream_q.get(timeout=_STREAM_TIMEOUT_S)
                if kind == "token":
                    tok, extra = val
                    event = {"token": tok, "index": i}
                    if extra is not None:
                        event.update(extra)
                    self._sse_event(event)
                    i += 1
                elif kind == "done":
                    self._sse_event({"done": True, "finish_reason": val,
                                     "n_tokens": i})
                    self._sse_event("[DONE]")
                    return
                else:
                    self._sse_event({"error": val})
                    self._sse_event("[DONE]")
                    return

        def _embeddings(self, body: Dict) -> None:
            prompt = body.get("prompt")
            if not prompt:
                return self._json(400, {"error": "prompt (a list of token "
                                                 "ids) is required"})
            out = loop.call("embed", prompt)
            emb = out["embedding"]
            self._json(200, {"embedding": [float(x) for x in emb],
                             "dim": len(emb)})

        def _classify(self, body: Dict) -> None:
            prompt = body.get("prompt")
            classes = body.get("classes")
            if not prompt or not classes:
                return self._json(400, {"error": "prompt and classes (lists "
                                                 "of token ids) are required"})
            out = loop.call("embed", prompt)
            logits = np.asarray(out["logits"], np.float64)
            sel = logits[np.asarray(classes, np.int64)]
            sel -= sel.max()
            probs = np.exp(sel) / np.exp(sel).sum()
            self._json(200, {"classes": [int(c) for c in classes],
                             "probs": [float(p) for p in probs],
                             "top": int(classes[int(probs.argmax())])})

    return Handler


class ApiServer:
    """Handle for a running serve API: ``.port`` (bound port — pass
    ``port=0`` to let the OS pick, tests do), ``.close()`` (stop loop +
    server), ``.wait()`` (block until closed — the CLI's foreground mode)."""

    def __init__(self, loop: _ServeLoop, httpd: ThreadingHTTPServer):
        self._loop = loop
        self._httpd = httpd
        self._thread = threading.Thread(target=httpd.serve_forever,
                                        daemon=True, name="serve-http")
        self._thread.start()

    @property
    def port(self) -> int:
        return self._httpd.server_address[1]

    @property
    def url(self) -> str:
        host = self._httpd.server_address[0]
        return f"http://{host}:{self.port}"

    def close(self) -> None:
        self._loop.stop()
        self._httpd.shutdown()
        self._httpd.server_close()
        self._thread.join(timeout=5)
        self._loop.join(timeout=5)

    def wait(self) -> None:
        try:
            self._thread.join()
        except KeyboardInterrupt:
            self.close()


def serve_api(target, *, port: int = 0, host: str = "127.0.0.1",
              mesh=None) -> ApiServer:
    """Boot the HTTP serve API over an Engine or Router. Returns the
    running :class:`ApiServer`; pass the jax mesh the backend's programs
    were built under — the driver thread must enter it itself (jax's
    active-mesh context is thread-local)."""
    backend = _Backend(target)
    loop = _ServeLoop(backend, mesh=mesh)
    loop.start()
    httpd = ThreadingHTTPServer((host, port), _make_handler(loop))
    return ApiServer(loop, httpd)
