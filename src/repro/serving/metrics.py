"""Serving telemetry: per-request latency records + engine-level counters.

Per request we track the two numbers a serving SLO is written against —
TTFT (arrival -> first generated token, queue wait included) and the decode
rate after the first token. TTFT decomposes as queue_wait_s (arrival ->
admission) + prefill_s (admission -> first token: the fused prefill forward
plus the batched cache-seed write); the engine aggregates the device-side
halves as prefill_wait_s / seed_write_s. Engine counters are designed to *reconcile*:
``tokens_generated`` must equal the sum of every completed/active request's
``n_generated`` (asserted in tests/test_serving.py).

Cache-memory telemetry comes from ``SlotStore.memory_stats()`` (bytes per
backend, block occupancy for the paged store) — surfaced through
``Engine.stats()["cache"]`` and rendered by :func:`format_memory_stats` in
the launch/serve.py end-of-run report. ``admissions_deferred`` counts store
lease refusals (paged block-pool backpressure).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional


def now() -> float:
    return time.monotonic()


@dataclasses.dataclass
class RequestMetrics:
    arrival_s: float
    prompt_len: int = 0
    admitted_s: Optional[float] = None         # slot leased, prefill dispatched
    first_token_s: Optional[float] = None      # set when prefill emits token 1
    finish_s: Optional[float] = None
    n_generated: int = 0

    @property
    def ttft_s(self) -> Optional[float]:
        if self.first_token_s is None:
            return None
        return self.first_token_s - self.arrival_s

    @property
    def queue_wait_s(self) -> Optional[float]:
        """TTFT share spent waiting for a slot (arrival -> admission)."""
        if self.admitted_s is None:
            return None
        return self.admitted_s - self.arrival_s

    @property
    def prefill_s(self) -> Optional[float]:
        """TTFT share spent in the fused prefill + cache seeding (admission ->
        first token). With fused admission this is one forward + one batched
        slot write, flat in prompt length — the replay era's O(prompt_len)
        decode chain lived here."""
        if self.admitted_s is None or self.first_token_s is None:
            return None
        return self.first_token_s - self.admitted_s

    @property
    def decode_tok_s(self) -> Optional[float]:
        """Post-first-token generation rate for this request."""
        if self.finish_s is None or self.first_token_s is None:
            return None
        dt = self.finish_s - self.first_token_s
        return (self.n_generated - 1) / dt if dt > 0 else float("inf")


@dataclasses.dataclass
class EngineMetrics:
    submitted: int = 0
    rejected: int = 0
    admissions_deferred: int = 0               # store lease refusals (paged
                                               # block-pool backpressure)
    evicted: int = 0                           # queued requests pulled by a
                                               # router drain (never admitted
                                               # here; re-placed elsewhere)
    preempted: int = 0                         # in-flight requests handed off
                                               # by a router drain (slot
                                               # retired, tokens stand)
    completed: int = 0
    tokens_generated: int = 0                  # prefill first-tokens + ALL
                                               # tokens decode rounds emitted
                                               # (accepted counts, NOT steps:
                                               # a speculative round emits
                                               # 1..k+1 per slot, so tok/s is
                                               # token-based by construction)
    decode_steps: int = 0                      # dispatched TARGET decode-path
                                               # forwards (plain steps +
                                               # verify rounds) — spec decode
                                               # drives steps/token below 1
    spec_rounds: int = 0                       # draft-verify rounds dispatched
    draft_steps: int = 0                       # narrow draft decode dispatches
    proposed_tokens: int = 0                   # draft proposals verified
                                               # (spec_k per active slot-round)
    accepted_tokens: int = 0                   # proposals the target confirmed
                                               # (emitted - 1 per slot-round:
                                               # the window's position-0 token
                                               # comes free, draft or no draft)
    accept_hist: Dict[int, int] = dataclasses.field(default_factory=dict)
                                               # tokens-emitted-per-slot-round
                                               # histogram {length: rounds}
    sampled_tokens: int = 0                    # tokens emitted by non-greedy
                                               # (sampled) requests — greedy
                                               # traffic keeps this at 0
    stop_hits: int = 0                         # requests finished by a stop-
                                               # sequence suffix match
    embed_requests: int = 0                    # non-generative forwards
                                               # (serve API embeddings/
                                               # classification)
    prefill_batches: int = 0
    prefill_tokens: int = 0                    # unpadded prompt tokens prefilled
    prefill_chunks: int = 0                    # block-size prefill chunks
                                               # actually computed (prefix-
                                               # cache engines only: cached
                                               # chunks are skipped, so this
                                               # is the dispatched-work unit
                                               # BENCH_prefix.json tracks)
    prefix_hits: int = 0                       # leases that matched cached
                                               # prefix blocks (or a COW fork)
    prefix_blocks_reused: int = 0              # whole cached blocks leased by
                                               # refcount instead of prefilled
    prefix_tokens_reused: int = 0              # prompt positions whose prefill
                                               # was skipped outright
    exported_slots: int = 0                    # in-flight requests extracted
                                               # WITH their cache blocks for
                                               # cross-host shipping (disagg)
    exported_blocks: int = 0                   # pool blocks serialized out
    imported_slots: int = 0                    # requests admitted from a
                                               # shipped block payload — zero
                                               # prefill dispatches each
    imported_blocks: int = 0                   # pool blocks adopted verbatim
    prefill_wait_s: float = 0.0                # wall time blocked on prefill forwards
    seed_write_s: float = 0.0                  # wall time in batched slot writes
    steps: int = 0                             # engine iterations observed
    queue_depth_sum: int = 0                   # for mean queue depth
    occupancy_sum: int = 0                     # active slots summed per step
    started_s: float = dataclasses.field(default_factory=now)
    first_token_s: Optional[float] = None      # first token the engine produced
    last_token_s: Optional[float] = None

    def observe_step(self, queue_depth: int, n_active: int) -> None:
        self.steps += 1
        self.queue_depth_sum += queue_depth
        self.occupancy_sum += n_active

    def observe_tokens(self, n: int) -> None:
        t = now()
        if self.first_token_s is None:
            self.first_token_s = t
        self.last_token_s = t
        self.tokens_generated += n

    def sustained_tok_s(self) -> float:
        """Generated tokens over the first->last token wall span (the number
        the throughput benchmark sweeps offered load against)."""
        if self.first_token_s is None or self.last_token_s is None:
            return 0.0
        dt = self.last_token_s - self.first_token_s
        return self.tokens_generated / dt if dt > 0 else float("inf")

    def summary(self) -> Dict[str, float]:
        return {
            "submitted": self.submitted,
            "rejected": self.rejected,
            "admissions_deferred": self.admissions_deferred,
            "evicted": self.evicted,
            "preempted": self.preempted,
            "completed": self.completed,
            "tokens_generated": self.tokens_generated,
            "decode_steps": self.decode_steps,
            "spec_rounds": self.spec_rounds,
            "draft_steps": self.draft_steps,
            "proposed_tokens": self.proposed_tokens,
            "accepted_tokens": self.accepted_tokens,
            "acceptance_rate": (self.accepted_tokens
                                / max(self.proposed_tokens, 1)),
            "accept_hist": dict(sorted(self.accept_hist.items())),
            "sampled_tokens": self.sampled_tokens,
            "stop_hits": self.stop_hits,
            "embed_requests": self.embed_requests,
            "prefill_batches": self.prefill_batches,
            "prefill_tokens": self.prefill_tokens,
            "prefill_chunks": self.prefill_chunks,
            "prefix_hits": self.prefix_hits,
            "prefix_blocks_reused": self.prefix_blocks_reused,
            "prefix_tokens_reused": self.prefix_tokens_reused,
            "exported_slots": self.exported_slots,
            "exported_blocks": self.exported_blocks,
            "imported_slots": self.imported_slots,
            "imported_blocks": self.imported_blocks,
            "prefill_wait_s": self.prefill_wait_s,
            "seed_write_s": self.seed_write_s,
            "sustained_tok_s": self.sustained_tok_s(),
            "mean_queue_depth": self.queue_depth_sum / max(self.steps, 1),
            "mean_occupancy": self.occupancy_sum / max(self.steps, 1),
        }


@dataclasses.dataclass
class TransportMetrics:
    """Per-transport RPC telemetry (serving/transport.py): every Router->host
    call is one RPC, whether it crosses a process boundary (SubprocessTransport
    frames over a local socket) or not (InProcessTransport method calls — timed
    the same way so the subprocess overhead is measured against a real
    baseline, reports/BENCH_transport.json)."""

    rpcs: int = 0
    retries: int = 0                           # idempotent calls re-sent after
                                               # a timeout/drop (fresh seq; the
                                               # stale reply is discarded)
    errors: int = 0                            # calls that raised
                                               # TransportError (timeouts,
                                               # EOF/connection loss)
    rpc_wait_s: float = 0.0                    # wall time inside RPCs

    def observe(self, dt: float) -> None:
        self.rpcs += 1
        self.rpc_wait_s += dt

    def summary(self) -> Dict[str, float]:
        return {
            "rpcs": self.rpcs,
            "retries": self.retries,
            "errors": self.errors,
            "rpc_wait_s": self.rpc_wait_s,
            "mean_rpc_us": 1e6 * self.rpc_wait_s / max(self.rpcs, 1),
        }


def format_transport_stats(stats: Dict) -> str:
    """One-line fleet-transport summary from ``Router.stats()`` — per-host
    RPC volume/latency plus loss/recovery counters, the launch/serve.py
    report line when hosts run as real processes."""
    r = stats["router"]
    per_host = r.get("transport", [])
    kinds = {t["kind"] for t in per_host}
    rpcs = sum(t["rpcs"] for t in per_host)
    retries = sum(t["retries"] for t in per_host)
    errors = sum(t["errors"] for t in per_host)
    mean_us = (1e6 * sum(t["rpc_wait_s"] for t in per_host) / rpcs
               if rpcs else 0.0)
    lost = f" | lost={r['lost']}" if r.get("lost") else ""
    return (f"transport[{'/'.join(sorted(kinds))}]: {rpcs} rpcs "
            f"({mean_us:.0f} us mean) | {retries} retries, {errors} errors | "
            f"{r.get('hosts_lost', 0)} hosts lost -> "
            f"{r.get('recovered', 0)} streams recovered{lost}")


def format_router_stats(stats: Dict) -> str:
    """One-line fleet summary from ``Router.stats()`` — placement counters in
    the same shape OPQ reports per-lane scheduling (placed/affinity_hits, the
    cross-host analog of issued/affinity_hits) plus drain/handoff activity —
    the launch/serve.py multi-host report line."""
    r = stats["router"]
    f = stats["fleet"]
    drained = f" | draining={r['draining']}" if r.get("draining") else ""
    ships = ""
    if r.get("roles"):
        ships = (f" | disagg: {r.get('ships', 0)} ships "
                 f"({r.get('shipped_blocks', 0)} blocks, "
                 f"{r.get('ship_fallbacks', 0)} fallbacks)")
    return (f"{r['hosts']} hosts | {r['placed']} placed "
            f"({r['affinity_hits']} affinity hits, {r['spills']} spills) | "
            f"{r['drains']} drains -> {r['handoffs']} handoffs + "
            f"{r['requeued']} requeued{ships} | fleet: {f['completed']} done, "
            f"{f['tokens_generated']} tok, {f['sustained_tok_s']:.1f} tok/s"
            f"{drained}")


def format_spec_stats(s: Dict) -> str:
    """One-line speculative-decode summary from ``EngineMetrics.summary()``
    — the launch/serve.py report line when ``--speculative`` is on. Shows
    the lever (target decode-path dispatches vs tokens they bought) and the
    accepted-length histogram {tokens-emitted-in-a-round: rounds}."""
    hist = " ".join(f"{length}:{count}"
                    for length, count in s["accept_hist"].items())
    spt = s["decode_steps"] / max(s["tokens_generated"] - s["completed"], 1)
    return (f"speculative: {s['spec_rounds']} rounds + {s['draft_steps']} "
            f"draft steps | {s['accepted_tokens']}/{s['proposed_tokens']} "
            f"proposals accepted ({s['acceptance_rate']:.2f}) | "
            f"{spt:.2f} target steps/decode-token | "
            f"accepted-length hist {{{hist}}}")


def format_sampling_stats(s: Dict) -> str:
    """One-line sampling summary from ``EngineMetrics.summary()`` — the
    launch/serve.py report line when the traffic mix includes non-greedy
    requests or stop sequences."""
    return (f"sampling: {s['sampled_tokens']}/{s['tokens_generated']} tokens "
            f"sampled | {s['stop_hits']} stop-sequence finishes | "
            f"{s['embed_requests']} embed requests")


def format_memory_stats(ms: Dict) -> str:
    """One-line cache-memory summary from ``SlotStore.memory_stats()`` —
    the end-of-run report line (launch/serve.py) and log decoration."""
    kib = ms.get("bytes", 0) / 1024.0
    if ms.get("backend") == "paged":
        if ms.get("native"):
            tail = "block-native decode (no transient view)"
        else:
            view_kib = ms.get("decode_view_bytes", 0) / 1024.0
            tail = f"+{view_kib:.1f} KiB transient decode view"
        if "prefix_cached_blocks" in ms:
            tail += (f" | prefix: {ms['prefix_hits']} hits, "
                     f"{ms['prefix_blocks_reused']} blocks reused, "
                     f"{ms['prefix_cached_blocks']} cached, "
                     f"{ms['prefix_evictions']} evicted, "
                     f"{ms['cow_forks']} COW forks")
        return (f"paged: {kib:.1f} KiB pool | block={ms['block_size']} tok | "
                f"{ms['blocks_used']}/{ms['blocks_total']} blocks used "
                f"({ms['blocks_free']} free) | {tail}")
    per_slot = ms.get("bytes_per_slot", 0) / 1024.0
    return (f"{ms.get('backend', '?')}: {kib:.1f} KiB "
            f"({per_slot:.1f} KiB/slot x {ms.get('slots', 0)} slots)")
