"""Continuous-batching inference engine over the OPQ runtime.

The production-shaped layer the GPTPU runtime was missing: requests enter a
bounded FIFO (admission control), a slot-based scheduler joins them into a
fixed-width in-flight decode batch and retires them as they finish — no
full-batch barrier, so a long generation never stalls short ones — and a
SlotStore backend leases per-slot cache capacity (allocate once, reset on
retire, int8-KV aware). All device work is dispatched as OPQ instructions, so the
paper's buffer-affinity scheduling and backup-task straggler mitigation apply
to serving traffic, not just the Rodinia apps.

Admission is *fused prefill-with-cache*: one bucketed forward per admission
batch returns the first token AND the per-layer K/V in cache layout
(models/serve.py ``prefill_with_cache``), which one batched donated scatter
writes into all leased slot rows (serving/kv.py ``write_slots``). Seeding a
prompt of length L therefore costs exactly one dispatched forward + one slot
write per bucket — O(1) instructions instead of the old O(L) B=1 replay-decode
chain — keeping admission on the matmul-bound side of the roofline (the GPTPU
whole-kernel-offload argument applied to TTFT). Multi-bucket admission rounds
dispatch their prefills concurrently and wait once, so buckets overlap on the
OPQ lanes.

Decode semantics are *greedy and batch-invariant*: every slot computes exactly
the math of a single-request decode at its own position (per-slot cache index,
see models/attention.py), so staggered-arrival outputs are bit-identical to
one-at-a-time sequential decoding — asserted in tests/test_serving.py, which
also keeps a reference replay seeder proving fused admission is bit-identical
to the replay era. MoE routing is per-request isolated: idle slots are masked
out of the expert-capacity cumsum at decode, prefill routes row-isolated, and
serving capacity is dropless (models/moe.py), so a token's expert assignment
never depends on its batchmates.

The cache itself lives behind the SlotStore protocol (serving/store.py): the
engine only leases, seeds, resets, and exchanges an opaque pytree with the
decode step — it never touches cache leaves. Backends: ``contiguous``
(per-slot rows sized to max_seq_len), ``paged`` (vLLM-style block pool +
per-slot block tables; ``lease`` returning False is admission backpressure
when the pool runs dry), and ``recurrent`` (per-slot mamba/xlstm state rows —
ssm and hybrid families serve through the same engine, admitted by a
masked-scan prefill that is one dispatch per bucket like the dense path).
The paged store additionally runs block-native (``paged_native=True``): the
decode step receives the pool + tables directly and attends in place — no
gather-bridge view, peak decode working set = the pool — bit-identical to
the bridge, with an optional Pallas kernel path (``paged_kernel=True``).
Long prompts admit via chunked prefill (``prefill_chunk=W``): buckets wider
than W scan the prompt W tokens at a time, peak score memory (B, H, W, S)
instead of (B, H, S, S), bit-identical to single-shot fused prefill — so the
admissible prompt length is no longer capped by the quadratic score matrix.
A request deferred by the store lease while zero slots are active can never
make progress; ``step`` raises a diagnostic immediately instead of spinning
``max_steps`` no-ops (the fits-vs-lease drift guard).

Scope: token-input dense/moe/ssm/hybrid families. encdec/vlm (embeds input)
serving is a ROADMAP item.
"""

from __future__ import annotations

import dataclasses
import enum
import functools
import itertools
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.opq import OPQ, Buffer
from repro.models import steps as ST
from repro.serving.metrics import EngineMetrics, RequestMetrics, now
from repro.serving.sampling import (
    GREEDY, TOP_LOGPROBS, SamplingParams, stack_params, stop_match,
)
from repro.serving.scheduler import Scheduler, bucket_for, default_buckets
from repro.serving.store import RECURRENT_FAMILIES, SlotStore, make_store


class RequestState(enum.Enum):
    QUEUED = "queued"
    RUNNING = "running"
    DONE = "done"
    PREEMPTED = "preempted"    # pulled mid-flight by a drain handoff: the
                               # slot was retired, the tokens generated so
                               # far stand, and a continuation request on
                               # another engine carries the remainder


@dataclasses.dataclass
class Request:
    id: int
    prompt: np.ndarray                     # (L,) int32
    max_new_tokens: int
    state: RequestState = RequestState.QUEUED
    tokens: List[int] = dataclasses.field(default_factory=list)
    # emission time (metrics.now epoch) of each entry in ``tokens``, stamped
    # where the engine appends — the only honest inter-token-latency source
    # for a free-running worker, whose poll deltas arrive in bursts
    token_ts: List[float] = dataclasses.field(default_factory=list)
    metrics: RequestMetrics = None         # set at submit
    sampling: Optional[SamplingParams] = None   # None == greedy
    # tokens generated in earlier segments of this logical stream (router
    # drain/handoff continuations): stop sequences match against
    # stop_history + tokens, so a handoff never re-arms or misses a stop
    stop_history: Tuple[int, ...] = ()
    finish_reason: Optional[str] = None    # "length" | "eos" | "stop"
    # logprob capture (serve API): None == off; an int N asks for the
    # chosen token's logprob plus its top-N alternatives per emitted token
    # (N == 0 records the chosen logprob only; N <= sampling.TOP_LOGPROBS)
    want_logprobs: Optional[int] = None
    logprobs: List[float] = dataclasses.field(default_factory=list)
    top_logprobs: List[List[Tuple[int, float]]] = dataclasses.field(
        default_factory=list)

    @property
    def last_token(self) -> int:
        return self.tokens[-1]

    @property
    def done(self) -> bool:
        return self.state == RequestState.DONE

    def to_wire(self) -> Dict:
        """The request's transport wire form: plain JSON/msgpack-able data,
        sufficient to re-admit the stream as a continuation elsewhere
        (serving/transport.py). The prompt travels as a token list; sampling
        params via their own wire form; metrics stay host-local."""
        from repro.serving.sampling import sampling_to_wire
        return {
            "id": self.id,
            "prompt": [int(t) for t in self.prompt],
            "max_new_tokens": self.max_new_tokens,
            "state": self.state.value,
            "tokens": [int(t) for t in self.tokens],
            "sampling": sampling_to_wire(self.sampling),
            "stop_history": [int(t) for t in self.stop_history],
            "finish_reason": self.finish_reason,
            "want_logprobs": self.want_logprobs,
            "logprobs": [float(v) for v in self.logprobs],
            "top_logprobs": [[[int(t), float(v)] for t, v in row]
                             for row in self.top_logprobs],
        }


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Per-engine serving knobs. Operator-facing documentation (including the
    CLI flag each field maps to) lives in ``docs/serving.md``.

    max_slots
        Width of the in-flight decode batch — the number of requests that
        decode concurrently. Each slot leases one cache row (or block set)
        from the SlotStore for its whole residency.
    max_queue
        Admission control: the bound on the waiting FIFO. ``submit`` rejects
        (returns None, or raises :class:`QueueFull` with ``strict=True``)
        once this many requests are queued — backpressure at the door rather
        than unbounded buffering.
    max_seq_len
        Per-slot sequence budget: a request's ``prompt + max_new_tokens``
        must fit in it. Sizes the contiguous rows / the paged slot tables /
        the recurrent prefill scan length.
    buckets
        Prefill pad lengths. Prompts are right-padded up to the smallest
        bucket that holds them so same-bucket arrivals share one prefill
        forward and the number of compiled prefill shapes is bounded by
        ``len(buckets)``, not by traffic. ``None`` = powers of two from 16
        capped at ``max_seq_len`` (scheduler.default_buckets). A bucket wider
        than ``max_seq_len`` is rejected at construction.
    eos_id
        Early-finish token id: a request retires when it emits this token
        (or at ``max_new_tokens``, whichever first). ``None`` = length-only.
    use_opq
        Dispatch every device step through the OPQ runtime (buffer affinity +
        backup-task straggler mitigation). ``False`` runs steps eagerly —
        tests/microbenchmarks only; the OPQ instruction-flag audit trail
        (``stats()["opq"]``) disappears with it.
    cache_backend
        SlotStore backend: ``auto`` | ``contiguous`` | ``paged`` |
        ``recurrent`` (serving/store.py). ``auto`` picks contiguous for
        dense/moe archs and recurrent for ssm/hybrid.
    block_size
        Paged backend only: tokens per KV block. Must divide
        ``max_seq_len`` (the gathered view must be exactly ``max_seq_len``
        long — the bit-identity contract with the contiguous decode program).
    n_blocks
        Paged backend only: block-pool size INCLUDING the reserved null
        block 0. ``None`` sizes the pool to full capacity
        (``max_slots * max_seq_len / block_size`` + the null block); smaller
        pools trade admission backpressure for resident bytes
        (reports/BENCH_paged.json).
    paged_native
        Paged backend only (added PR 4): block-native decode. The decode
        step receives the pool + tables and writes/attends through them in
        place — no transient gather-bridge view
        (``memory_stats()["decode_view_bytes"] == 0``), tokens bit-identical
        to the bridge, which remains the reference oracle.
    paged_kernel
        With ``paged_native`` (added PR 4): route the attention contraction
        through the Pallas paged-attention kernel
        (kernels/paged_attention.py — scalar-prefetch block-table addressing
        + online softmax, block-sized VMEM working set). Float-KV only; runs
        in interpret mode off-TPU, which is how CPU CI exercises it.
    prefill_chunk
        Dense families only (added PR 4): chunked prefill width W. Buckets
        wider than W admit through a ``lax.scan`` of W-token chunks — peak
        prefill score memory (B, H, W, S) instead of (B, H, S, S) — and the
        bucket set extends past the fused buckets by multiples of W up to
        ``max_seq_len``, lifting the long-prompt admission cap. Bit-identical
        to single-shot fused prefill. Rejected for recurrent families (their
        masked-scan prefill is already linear) and mrope position encoding.
    prefix_cache
        Paged backend only (added PR 6): shared-prefix radix cache. The
        store keeps a refcounted trie of full prompt blocks
        (serving/store.py); a lease whose prompt walks onto cached blocks
        leases them by refcount and admission dispatches the SUFFIX prefill
        step only over the unmatched chunks (block-size-wide, traced start —
        TTFT for a hot prefix is O(suffix)). Copy-on-write forks the
        divergence block before any slot write; retire scrubs only blocks
        whose refcount hits zero; unreferenced cached prefixes LRU-evict
        under pool pressure, so caching never refuses an admission the bare
        pool could serve. Tokens and cache bits stay bit-identical to cold
        admission (tests/test_prefix_cache.py). Requires ``block_size`` to
        divide every prefill bucket; rejected for mrope (the suffix scan is
        the chunked scan).
    speculative
        Draft-verify decode (added PR 7): every round a small DRAFT model
        proposes ``spec_k`` tokens per active slot in narrow decode steps,
        then the target scores all ``spec_k + 1`` window positions for the
        whole batch in ONE wide verify forward — one dispatched target step
        buys up to k+1 tokens per slot. Greedy acceptance keeps the emitted
        stream bit-identical to plain decode (each window position's greedy
        token is exactly what sequential decode would emit there); rejected
        draft K/V is scrubbed from both caches before the round ends. Slots
        advance 1..k+1 tokens per round independently — EOS/length stops
        land mid-window and retire at the stop position. Requires ``draft``
        + ``draft_params``; target must be a dense-family arch (the draft
        may be recurrent — its rollback is snapshot selection); rejected
        with ``paged_kernel`` (a single-query decode kernel) and mrope.
    spec_k
        Draft proposals per speculative round (window = ``spec_k + 1``).
    draft
        The draft model's ArchConfig (vocab must match the target's); its
        params go to ``Engine(..., draft_params=...)``. The draft runs as a
        second OPQ program with its own slot-synced store, kept in lockstep
        through admission, rollback, preemption, and retire.
    """

    max_slots: int = 4
    max_queue: int = 64
    max_seq_len: int = 64
    buckets: Optional[Tuple[int, ...]] = None
    eos_id: Optional[int] = None
    use_opq: bool = True
    cache_backend: str = "auto"
    block_size: int = 16
    n_blocks: Optional[int] = None
    paged_native: bool = False
    paged_kernel: bool = False
    prefill_chunk: Optional[int] = None
    prefix_cache: bool = False
    speculative: bool = False
    spec_k: int = 4
    draft: Optional[ArchConfig] = None


def _spec_round_donate() -> bool:
    """Whether the speculative-round steps (verify, dense draft decode) may
    donate their cache argument. Not on CPU: jax 0.4.37's XLA:CPU runtime
    can deserialize an executable from the persistent compilation cache
    whose completion events fire BEFORE its donated in-place writes land —
    ``block_until_ready`` on its outputs returns early, so the rollback
    scrub dispatched right after a verify races the verify's own tail
    writes and intermittently loses the rejected window cells (stale draft
    K/V where pristine was written; reproducible only with a warm
    ``.jax_cache``, never with freshly compiled executables). Whether an
    entry was deserialized is not observable here, so CPU skips donation
    for exactly the two steps whose freshly written cells the round
    overwrites microseconds later. Plain decode/prefill keep donation on
    every backend: nothing ever overwrites a cell they just wrote before
    the next data-dependent executable, so a late write is unobservable.
    TPU keeps the in-place verify — donation is what holds peak cache
    memory to one pool during the wide forward."""
    return jax.default_backend() != "cpu"


@functools.lru_cache(maxsize=None)
def _jitted_steps(cfg: ArchConfig, kind: str, max_seq_len: int = 0,
                  native: bool = False, kernel: bool = False, chunk: int = 0,
                  prefix_chunk: int = 0, spec_k: int = 0):
    """Compiled step fns shared across Engine instances of the same
    (config, store kind, decode/prefill mode) — rebuilding an engine (tests,
    benchmark sweeps) reuses XLA executables. ``max_seq_len`` keys the cache
    ONLY for the recurrent backend (its prefill scan allocates the state
    cache at that length); dense/moe callers pass 0 so engines with
    different seq budgets keep sharing one set of compiled executables.
    Dense-family prefill is the fused prefill-with-cache step: right-padded
    bucket batch in, (first_tokens, per-layer K/V in cache layout) out —
    causal attention means pad tokens after a row's prompt never reach its
    logits or its K/V rows, so a small fixed bucket set is exact for any pad
    content. ``chunk`` additionally builds the chunked prefill step (same
    contract, (B, H, chunk, S) peak score memory) for the long-prompt
    buckets. Recurrent-family prefill is the masked scan of the decode body
    (same contract, state rows out). The decode step is shared across
    contiguous/paged-bridge backends — paged layout translation happens
    inside the store's decode_cache/swap bridge, which is what makes paged
    decode bit-identical to contiguous; ``native`` compiles the block-native
    decode instead (pool in, pool out — models/serve.py decode_paged), which
    is bit-identical to the bridge by construction."""
    if kind == "recurrent":
        prefill = jax.jit(ST.make_recurrent_prefill_step(cfg, max_seq_len))
    else:
        prefill = jax.jit(ST.make_prefill_with_cache_step(cfg))
    prefill_chunked = (jax.jit(ST.make_chunked_prefill_step(cfg, chunk))
                       if chunk else None)
    # ``prefix_chunk`` (== the paged block size) builds the suffix prefill
    # for prefix-cache hits: the chunked scan with a TRACED start chunk and
    # cache-seeded accumulators, so one executable per (B, bucket) serves
    # every matched-prefix length
    prefill_suffix = (
        jax.jit(ST.make_suffix_prefill_step(cfg, prefix_chunk))
        if prefix_chunk else None)
    decode_fn = (ST.make_paged_decode_step(cfg, use_kernel=kernel)
                 if native else ST.make_decode_step(cfg))
    decode = jax.jit(decode_fn, donate_argnums=(1,))
    # ``spec_k`` builds the speculative verify step: the W = spec_k + 1 wide
    # target forward that scores a whole draft window in one dispatch
    # (models/steps.py make_verify_step). Block-native engines verify through
    # the pool + tables; the paged gather bridge and the contiguous backend
    # share the contiguous verify program, exactly like plain decode.
    verify = None
    if spec_k:
        verify_fn = (ST.make_paged_verify_step(cfg, spec_k + 1) if native
                     else ST.make_verify_step(cfg, spec_k + 1))
        verify = (jax.jit(verify_fn, donate_argnums=(1,))
                  if _spec_round_donate() else jax.jit(verify_fn))
    return prefill, prefill_chunked, prefill_suffix, decode, verify


@functools.lru_cache(maxsize=None)
def _jitted_draft_steps(cfg: ArchConfig, kind: str, max_seq_len: int = 0,
                        donate: bool = True):
    """Compiled DRAFT-model steps for speculative decode: the bucketed
    admission prefill (the draft cache must be seeded with the prompt through
    the draft's own weights) and the narrow proposal decode. Recurrent drafts
    pass ``donate=False``: the engine keeps one state snapshot per draft step
    of a round so rollback can per-slot select the post-acceptance state —
    donating would overwrite snapshot i while producing i+1."""
    if kind == "recurrent":
        prefill = jax.jit(ST.make_recurrent_prefill_step(cfg, max_seq_len))
    else:
        prefill = jax.jit(ST.make_prefill_with_cache_step(cfg))
    decode_fn = ST.make_decode_step(cfg)
    decode = (jax.jit(decode_fn, donate_argnums=(1,))
              if donate and _spec_round_donate() else jax.jit(decode_fn))
    return prefill, decode


@functools.lru_cache(maxsize=None)
def _jitted_embed(cfg: ArchConfig):
    """Compiled non-generative forward (serve API embeddings/classification):
    bucketed tokens in, (last-position hidden, last-position logits) out —
    shared across Engine instances like the serving steps above."""
    return jax.jit(ST.make_embed_step(cfg))


class _Ready:
    """Completed-future shim for the OPQ-disabled direct-dispatch path."""

    def __init__(self, value):
        self._value = value

    def result(self):
        return self._value


class QueueFull(Exception):
    """Raised by submit(strict=True) when admission control rejects."""


class Engine:
    """See module docstring. Typical use::

        engine = Engine(cfg, params, EngineConfig(max_slots=4, max_seq_len=64))
        engine.submit(prompt_ids, max_new_tokens=16)
        done = engine.run_until_complete()
    """

    def __init__(self, cfg: ArchConfig, params, engine_cfg: EngineConfig = None,
                 *, opq: Optional[OPQ] = None, draft_params=None):
        if (cfg.family not in ("dense", "moe") + RECURRENT_FAMILIES
                or cfg.input_mode != "tokens"):
            raise ValueError(
                f"serving engine supports token-input dense/moe/ssm/hybrid "
                f"archs, got family={cfg.family} input_mode={cfg.input_mode}")
        self.cfg = cfg
        self.params = params
        self.ecfg = engine_cfg or EngineConfig()
        if self.ecfg.paged_kernel and not self.ecfg.paged_native:
            raise ValueError("paged_kernel requires paged_native=True")
        if self.ecfg.paged_native and self.ecfg.cache_backend != "paged":
            raise ValueError(
                f"paged_native requires cache_backend='paged', got "
                f"{self.ecfg.cache_backend!r}")
        if self.ecfg.prefix_cache:
            if self.ecfg.cache_backend != "paged":
                raise ValueError(
                    f"prefix_cache (shared-prefix radix cache) requires "
                    f"cache_backend='paged', got {self.ecfg.cache_backend!r}")
            if cfg.rope_kind == "mrope":
                raise ValueError(
                    "prefix_cache does not support mrope position encoding "
                    "(the suffix prefill is the chunked scan, which does not "
                    "thread positions3)")
        if self.ecfg.speculative:
            if self.ecfg.draft is None or draft_params is None:
                raise ValueError(
                    "speculative decode needs a draft model: set "
                    "EngineConfig.draft (the draft ArchConfig) and pass "
                    "Engine(..., draft_params=...)")
            if self.ecfg.spec_k < 1:
                raise ValueError(
                    f"spec_k must be >= 1, got {self.ecfg.spec_k}")
            if cfg.family not in ("dense", "moe"):
                raise ValueError(
                    f"speculative decode verifies through the K/V-window "
                    f"path, so the TARGET must be a dense-family arch, got "
                    f"{cfg.family} (a recurrent model can be the draft, "
                    f"not the target)")
            if cfg.rope_kind == "mrope":
                raise ValueError(
                    "speculative verify does not support mrope position "
                    "encoding (the window forward does not thread positions3)")
            if self.ecfg.paged_kernel:
                raise ValueError(
                    "speculative decode does not route through the Pallas "
                    "paged-attention kernel (a single-query decode shape; "
                    "the verify window is multi-query) — drop paged_kernel")
            d = self.ecfg.draft
            if d.vocab != cfg.vocab:
                raise ValueError(
                    f"draft vocab {d.vocab} != target vocab {cfg.vocab}: "
                    f"draft proposals must be target token ids")
            if (d.input_mode != "tokens"
                    or d.family not in ("dense", "moe") + RECURRENT_FAMILIES):
                raise ValueError(
                    f"draft must be a token-input dense/moe/ssm/hybrid arch, "
                    f"got family={d.family} input_mode={d.input_mode}")
        elif self.ecfg.draft is not None:
            raise ValueError(
                "EngineConfig.draft is set but speculative=False — enable "
                "speculative or drop the draft config")
        buckets = self.ecfg.buckets or default_buckets(self.ecfg.max_seq_len)
        chunk = self.ecfg.prefill_chunk
        if chunk:
            if cfg.family in RECURRENT_FAMILIES:
                raise ValueError(
                    "prefill_chunk applies to the dense-family score-matrix "
                    f"prefill, not the recurrent scan ({cfg.family})")
            if cfg.rope_kind == "mrope":
                raise ValueError(
                    "prefill_chunk does not support mrope position encoding "
                    "(the chunked scan does not thread positions3)")
            if not 1 <= chunk <= self.ecfg.max_seq_len:
                raise ValueError(
                    f"prefill_chunk {chunk} must be in [1, max_seq_len "
                    f"{self.ecfg.max_seq_len}]")
            # buckets at most one chunk wide keep the single-shot fused step;
            # beyond that, admission goes through chunk-multiple buckets and
            # the chunked scan — which is what lifts the admissible prompt
            # length past the widest fused bucket
            fused = tuple(b for b in buckets if b <= chunk)
            chunked = tuple(
                k * chunk for k in range(1, self.ecfg.max_seq_len // chunk + 1)
                if k * chunk > max(fused, default=0))
            self._chunked_buckets = frozenset(chunked)
            buckets = tuple(sorted(set(fused) | set(chunked)))
        else:
            self._chunked_buckets = frozenset()
        if max(buckets) > self.ecfg.max_seq_len:
            # a bucket wider than the slot rows could admit prompts whose
            # fused K/V block cannot be scattered into the cache
            raise ValueError(
                f"largest prefill bucket {max(buckets)} exceeds "
                f"max_seq_len {self.ecfg.max_seq_len} (the slot-row length)")
        if self.ecfg.prefix_cache:
            bad = [b for b in buckets if b % self.ecfg.block_size]
            if bad:
                # the suffix prefill scans block-size-wide chunks, so a
                # bucket must be a whole number of them to resume mid-prompt
                raise ValueError(
                    f"prefix_cache requires block_size "
                    f"{self.ecfg.block_size} to divide every prefill bucket "
                    f"(got {bad})")
        self.scheduler = Scheduler(self.ecfg.max_slots, buckets)
        self.store: SlotStore = make_store(
            cfg, self.ecfg.max_slots, self.ecfg.max_seq_len,
            backend=self.ecfg.cache_backend,
            block_size=self.ecfg.block_size, n_blocks=self.ecfg.n_blocks,
            native=self.ecfg.paged_native,
            prefix_cache=self.ecfg.prefix_cache)
        (self._prefill, self._prefill_chunked, self._prefill_suffix,
         self._decode, self._verify) = _jitted_steps(
            cfg, self.store.kind,
            self.ecfg.max_seq_len if self.store.kind == "recurrent" else 0,
            native=self.ecfg.paged_native, kernel=self.ecfg.paged_kernel,
            chunk=chunk or 0,
            prefix_chunk=self.ecfg.block_size if self.ecfg.prefix_cache else 0,
            spec_k=self.ecfg.spec_k if self.ecfg.speculative else 0)
        self._owns_opq = opq is None and self.ecfg.use_opq
        self.opq = (OPQ() if self._owns_opq else opq) if self.ecfg.use_opq else None
        self._params_buf = Buffer(params, name="params")
        # the draft model is a SECOND program over the same slot geometry:
        # its own params buffer, its own slot-synced store (contiguous for
        # dense drafts, per-slot state rows for recurrent ones), admitted /
        # rolled back / reset in lockstep with the target's slots
        self.draft_store: Optional[SlotStore] = None
        if self.ecfg.speculative:
            dcfg = self.ecfg.draft
            self.draft_store = make_store(dcfg, self.ecfg.max_slots,
                                          self.ecfg.max_seq_len)
            self._draft_recurrent = self.draft_store.kind == "recurrent"
            self._draft_prefill, self._draft_decode = _jitted_draft_steps(
                dcfg, self.draft_store.kind,
                self.ecfg.max_seq_len if self._draft_recurrent else 0,
                donate=not self._draft_recurrent)
            self._draft_params_buf = Buffer(draft_params, name="draft-params")
        self._req_ids = itertools.count()
        # host-side token-presence bitmap per slot (prompt + generated): the
        # repetition penalty's input, maintained through admit/emit/retire so
        # it rides the slot lease like the cache does
        self._presence = np.zeros((self.ecfg.max_slots, cfg.vocab_padded),
                                  bool)
        self.metrics = EngineMetrics()
        # fleet counter reconciliation: which queued request ids this engine
        # has already counted as deferred, and each slot's prefix-cache hit
        # contribution — evict_queued/preempt unwind exactly what admission
        # counted, so a request drained and re-admitted on another host shows
        # up once (not once per host) in fleet-summed stats()
        self._deferred_ids: set = set()
        self._prefix_contrib: Dict[int, Tuple[int, int, int]] = {}
        self.completed: List[Request] = []

    @property
    def kv(self) -> SlotStore:
        """Back-compat alias from the KVSlotManager era — the slot store."""
        return self.store

    # ------------------------------------------------------------ OPQ bridge

    def _resident(self, tree, name: str) -> Buffer:
        leaves = jax.tree.leaves(tree)
        try:
            dev = next(iter(leaves[0].devices()))
            return Buffer.resident(tree, dev, name=name)
        except (AttributeError, IndexError, StopIteration):
            return Buffer(tree, name=name)

    def _dispatch(self, fn, *bufs: Buffer, flags: str = ""):
        """Run one instruction to completion (decode path)."""
        return self._dispatch_async(fn, *bufs, flags=flags).result()

    def _dispatch_async(self, fn, *bufs: Buffer, flags: str = ""):
        """Issue one instruction and return its future: through the OPQ
        scheduler (affinity + backup tasks), or eagerly when the runtime is
        disabled. Admission uses this to overlap the per-bucket prefills of
        one round on the lanes before a single wait. Untracked: the engine
        consumes each result itself, so nothing is retained for sync() and
        the task registry stays empty over an unbounded serving run."""
        if self.opq is None:
            return _Ready(fn(*(b.data for b in bufs)))
        return self.opq.invoke_operator(fn, *bufs, flags=flags, track=False)

    # ------------------------------------------------------------- admission

    def would_accept(self, prompt_len: int, max_new_tokens: int) -> bool:
        """The submit-time admission predicate, side-effect free: whether a
        request of this shape would pass the door right now (queue bound, seq
        budget, bucket cap, store total-capacity ``fits``). The multi-host
        router asks this before placing or handing off a request so a
        rejection never costs a preemption (serving/router.py)."""
        return not (self.scheduler.queue_depth >= self.ecfg.max_queue
                    or prompt_len < 1
                    or max_new_tokens < 1
                    or prompt_len + max_new_tokens > self.ecfg.max_seq_len
                    # custom buckets may cap below max_seq_len: reject at the
                    # door, not mid-admission after a slot was leased
                    or prompt_len > max(self.scheduler.buckets)
                    # a request exceeding the store's TOTAL capacity (e.g.
                    # more paged blocks than the pool holds) could never be
                    # leased: deferring it would livelock the queue head
                    or not self.store.fits(prompt_len, max_new_tokens))

    def lease_headroom(self, prompt_len: int, max_new_tokens: int) -> bool:
        """Whether the store could lease this request RIGHT NOW (free paged
        blocks vs. ``fits``'s total-capacity check). False means admission
        would defer on backpressure — the router's cue to spill the request
        to another host instead of head-of-line blocking behind a dry pool."""
        return self.store.available_now(prompt_len, max_new_tokens)

    def submit(self, prompt: Sequence[int], max_new_tokens: int,
               *, sampling: Optional[SamplingParams] = None,
               stop_history: Sequence[int] = (),
               want_logprobs: Optional[int] = None,
               strict: bool = False) -> Optional[Request]:
        """Admission control at the door: a bounded queue and a hard per-slot
        sequence budget. Returns the Request, or None when rejected
        (QueueFull when ``strict``).

        ``sampling`` (None == greedy) rides the request through its whole
        slot residency; ``stop_history`` is the generated prefix of an
        earlier segment (router drain handoff) that stop sequences must see.
        ``want_logprobs`` (None == off) records each emitted token's logprob
        plus its top-N alternatives from the very logits row the token
        choice used. Non-greedy params on a speculative engine are a
        configuration error (greedy acceptance is what makes draft-verify
        exact; rejection sampling is a ROADMAP item), diagnosed here rather
        than emitting a silently-greedy stream."""
        if (sampling is not None and not sampling.greedy
                and self.ecfg.speculative):
            raise ValueError(
                f"speculative decode is greedy-only: temperature="
                f"{sampling.temperature} requires sampled acceptance "
                f"(rejection sampling — a ROADMAP follow-up). Drop "
                f"--speculative or the sampling params.")
        if want_logprobs is not None and not 0 <= want_logprobs <= TOP_LOGPROBS:
            raise ValueError(
                f"want_logprobs must be in [0, {TOP_LOGPROBS}] (the device-"
                f"side top-K capture width), got {want_logprobs}")
        prompt = np.asarray(prompt, dtype=np.int32).reshape(-1)
        if not self.would_accept(len(prompt), max_new_tokens):
            self.metrics.rejected += 1
            if strict:
                raise QueueFull(
                    f"rejected: queue_depth={self.scheduler.queue_depth}, "
                    f"prompt={len(prompt)} + gen={max_new_tokens} vs "
                    f"max_seq_len={self.ecfg.max_seq_len}")
            return None
        req = Request(id=next(self._req_ids), prompt=prompt,
                      max_new_tokens=max_new_tokens,
                      sampling=sampling, stop_history=tuple(stop_history),
                      want_logprobs=want_logprobs,
                      metrics=RequestMetrics(arrival_s=now(),
                                             prompt_len=len(prompt)))
        self.scheduler.enqueue(req)
        self.metrics.submitted += 1
        return req

    # ----------------------------------------------------------- engine step

    def _try_lease(self, slot: int, req: Request) -> bool:
        """Reserve store capacity for a request before the scheduler commits
        the slot. A False return (paged block-pool dry) leaves the request at
        the queue head — admission backpressure, never mid-flight corruption.
        With the prefix cache on, the lease also walks the radix trie with
        the prompt tokens; matched cached blocks are leased by refcount and
        their prefill skipped (``_admit`` reads ``prefix_lease_info``)."""
        ok = self.store.lease(
            slot, len(req.prompt), req.max_new_tokens,
            tokens=req.prompt if self.ecfg.prefix_cache else None)
        if not ok:
            # counted once per request, not per attempt: the deferred queue
            # head is re-tried every step, and a fleet drain + re-admission
            # elsewhere reconciles this host's count back out (evict_queued)
            # — so admissions_deferred means "requests that experienced
            # deferral", summable across hosts without double-counting
            if req.id not in self._deferred_ids:
                self._deferred_ids.add(req.id)
                self.metrics.admissions_deferred += 1
            return ok
        self._deferred_ids.discard(req.id)
        if self.ecfg.prefix_cache:
            info = self.store.prefix_lease_info(slot)
            if info["hit"]:
                self.metrics.prefix_hits += 1
                self.metrics.prefix_blocks_reused += info["shared_blocks"]
                self.metrics.prefix_tokens_reused += info["prefill_start"]
                self._prefix_contrib[slot] = (
                    1, info["shared_blocks"], info["prefill_start"])
        return ok

    def _prefix_group_key(self, slot: int, req: Request) -> int:
        """Admission group key under the prefix cache: the slot's suffix
        start CHUNK. A batched suffix prefill can only skip what every row
        skips, so rows with different cached-prefix depths dispatch
        separately — a cold arrival never forces a hot one to recompute its
        cached prefix (scheduler.plan_admissions ``group_key``)."""
        info = self.store.prefix_lease_info(slot)
        return info["prefill_start"] // self.ecfg.block_size

    def _admit(self) -> int:
        """Fused admission: ONE dispatched prefill forward per bucket batch
        (first token + cache payload out — per-layer K/V for dense families,
        post-prompt state rows for recurrent ones) and ONE batched donated
        scatter into the leased slot rows — zero B=1 replay decodes, seeding
        cost O(1) instructions in prompt length. All buckets of the round are
        dispatched before the first wait, so they overlap on the OPQ lanes.
        Buckets wider than ``prefill_chunk`` dispatch the chunked prefill
        step instead (long prompts — linear-in-S peak score memory, same
        contract and bit-identical output). Returns the number of requests
        admitted this round (step() uses 0 to detect a zero-progress
        deferral with an idle engine)."""
        pending = []
        admitted = 0
        group_key = self._prefix_group_key if self.ecfg.prefix_cache else None
        for bucket, pairs in self.scheduler.plan_admissions(self._try_lease,
                                                            group_key):
            admitted += len(pairs)
            toks = np.zeros((len(pairs), bucket), np.int32)
            last = np.zeros((len(pairs),), np.int32)
            presence = np.zeros((len(pairs), self.cfg.vocab_padded), bool)
            for i, (slot, req) in enumerate(pairs):
                toks[i, :len(req.prompt)] = req.prompt
                last[i] = len(req.prompt) - 1
                presence[i, req.prompt] = True
                # the slot inherits the row's presence for decode steps
                self._presence[slot, :] = presence[i]
                req.metrics.admitted_s = now()
            smp = stack_params([req.sampling for _, req in pairs], presence)
            # prefix-cache hit groups resume the chunked scan mid-prompt:
            # every row in the group shares this start chunk (the scheduler
            # grouped by it), so no row recomputes a cached position and no
            # row skips one it needs
            start_chunk = (self._prefix_group_key(*pairs[0])
                           if self.ecfg.prefix_cache else 0)
            chunked = bucket in self._chunked_buckets
            if start_chunk > 0:
                kv0 = self.store.gather_prefix_rows(
                    [slot for slot, _ in pairs], bucket)
                fut = self._dispatch_async(
                    lambda p, t, li, k0, s, fn=self._prefill_suffix,
                    sc=start_chunk: fn(p, t, li, k0, sc, s),
                    self._params_buf, Buffer(toks, name=f"prefill{bucket}"),
                    Buffer(last), self._resident(kv0, "prefix-kv0"),
                    Buffer(smp, name="sampling"),
                    flags=f"prefill_prefix/{bucket}")
                self.metrics.prefill_chunks += (
                    bucket // self.ecfg.block_size - start_chunk)
            else:
                step_fn = self._prefill_chunked if chunked else self._prefill
                flag = (f"prefill_chunked/{bucket}" if chunked
                        else f"prefill/{bucket}")
                # sampling params always ride the dispatch — ONE prefill
                # executable per bucket regardless of the greedy/sampled mix
                fut = self._dispatch_async(
                    lambda p, t, li, s, fn=step_fn: fn(p, t, li, s),
                    self._params_buf, Buffer(toks, name=f"prefill{bucket}"),
                    Buffer(last), Buffer(smp, name="sampling"), flags=flag)
                if self.ecfg.prefix_cache:
                    # cold groups compute every block-size chunk — the unit
                    # the prefix benchmark counts dispatched prefill work in
                    self.metrics.prefill_chunks += (
                        bucket // self.ecfg.block_size)
            # speculative: the draft cache must hold the prompt through the
            # DRAFT's own weights, so every admission group also dispatches a
            # draft prefill (always the full prompt — the draft store has no
            # prefix cache, and a suffix-group target skip never applies to it)
            dfut = None
            if self.draft_store is not None:
                dfut = self._dispatch_async(
                    lambda p, t, li, fn=self._draft_prefill: fn(p, t, li),
                    self._draft_params_buf,
                    Buffer(toks, name=f"draft-prefill{bucket}"),
                    Buffer(last), flags=f"draft_prefill/{bucket}")
            pending.append((pairs, last, fut, dfut))
        for pairs, last, fut, dfut in pending:
            t0 = now()
            first, kv, lp = fut.result()
            first = np.asarray(first)
            self.metrics.prefill_wait_s += now() - t0
            self.metrics.prefill_batches += 1
            self.metrics.prefill_tokens += int(last.sum()) + len(pairs)
            t0 = now()
            self._seed_admitted(pairs, kv)
            if dfut is not None:
                # draft first token discarded — the TARGET's prefill token is
                # the emitted one; the draft only needed its cache seeded
                _, dkv = dfut.result()
                self.draft_store.write_slots(
                    [slot for slot, _ in pairs], dkv,
                    [len(req.prompt) for _, req in pairs])
            self.metrics.seed_write_s += now() - t0
            for i, (slot, req) in enumerate(pairs):
                req.state = RequestState.RUNNING
                tok = int(first[i])
                req.tokens.append(tok)
                req.token_ts.append(now())
                self._record_logprob(req, lp, i)
                self._presence[slot, tok] = True
                if req.sampling is not None and not req.sampling.greedy:
                    self.metrics.sampled_tokens += 1
                req.metrics.first_token_s = now()
                req.metrics.n_generated = 1
                self.metrics.observe_tokens(1)
                if self._finished(req):       # done at the prefill token:
                    self._retire(slot)        # reset scrubs the seeded row
        return admitted

    def _record_logprob(self, req: Request, lp, idx) -> None:
        """Append one emitted token's logprob record from a step's
        ``logprob_info`` payload (idx selects the request's row — an int for
        prefill/decode, a (slot, window_pos) pair for verify). Free for
        requests that didn't opt in: the payload was computed inside the
        already-dispatched step (one executable), only the host-side copy
        is skipped."""
        if req.want_logprobs is None:
            return
        req.logprobs.append(float(np.asarray(lp["lp"])[idx]))
        ids = np.asarray(lp["top_ids"])[idx]
        lps = np.asarray(lp["top_lps"])[idx]
        req.top_logprobs.append(
            [(int(t), float(v)) for t, v in zip(ids, lps)])

    def _sampling_batch(self) -> Dict:
        """The decode batch's stacked per-slot sampling params + presence
        rows (serving/sampling.py). Always attached to the dispatch, so the
        decode program is ONE executable across every greedy/sampled mix —
        idle and paramless slots stack as GREEDY."""
        return stack_params(self.scheduler.sampling_by_slot(GREEDY),
                            self._presence.copy())

    def _seed_admitted(self, pairs, kv) -> None:
        """Seed every leased row of one admission bucket from the fused
        prefill's payload — one batched donated scatter through the store.
        Overridable seam: tests substitute the PR-1 B=1 replay seeder here to
        prove fused admission is bit-identical to prompt replay."""
        self.store.write_slots([slot for slot, _ in pairs], kv,
                               [len(req.prompt) for _, req in pairs])

    def _decode_once(self) -> None:
        toks, active = self.scheduler.decode_batch()
        next_tok, cache, lp = self._dispatch(
            lambda p, c, b: self._decode(p, c, b),
            self._params_buf,
            self._resident(self.store.decode_cache(), "kv-cache"),
            Buffer({"tokens": toks, "active": active,
                    "sampling": self._sampling_batch()},
                   name="decode-tokens"),
            flags="decode")
        self.store.swap(cache)
        self.metrics.decode_steps += 1
        next_np = np.asarray(next_tok)
        produced = 0
        t_emit = now()
        for slot, req in list(self.scheduler.active.items()):
            tok = int(next_np[slot])
            req.tokens.append(tok)
            req.token_ts.append(t_emit)
            self._record_logprob(req, lp, slot)
            self._presence[slot, tok] = True
            if req.sampling is not None and not req.sampling.greedy:
                self.metrics.sampled_tokens += 1
            req.metrics.n_generated += 1
            produced += 1
            if self._finished(req):
                self._retire(slot)
        self.metrics.observe_tokens(produced)

    def _spec_decode_once(self) -> None:
        """One speculative draft-verify round. k+1 NARROW draft decode steps
        propose k tokens per active slot (the last proposal is discarded —
        the extra step keeps the draft cache in lockstep through a fully
        accepted window), then ONE W = k+1 wide target verify forward scores
        every window position for the whole batch, and each slot advances by
        its own acceptance length: 1..k+1 tokens per round, EOS/length stops
        landing mid-window. Greedy acceptance makes the stream provably
        bit-identical to plain decode — window position j's greedy token is
        exactly what sequential decode would emit after j accepted tokens,
        so a bad draft costs speed, never correctness. Rejected window
        positions are scrubbed from BOTH caches before any retire: future
        verify horizons reach them, and the retire-time row bits must equal
        plain decode's (the cache-bit half of the invariant)."""
        k = self.ecfg.spec_k
        W = k + 1
        n = self.ecfg.max_slots
        toks, active = self.scheduler.decode_batch()
        # ---- draft: propose. Window column 0 is each slot's last emitted
        # token; columns 1..k the draft's chained proposals.
        window = np.zeros((n, W), np.int32)
        window[:, 0] = toks[:, 0]
        snapshots = ([self.draft_store.decode_cache()]
                     if self._draft_recurrent else None)
        cur = toks
        for i in range(W):
            nxt, dcache = self._dispatch(
                lambda p, c, b: self._draft_decode(p, c, b),
                self._draft_params_buf,
                self._resident(self.draft_store.decode_cache(), "draft-cache"),
                Buffer({"tokens": cur, "active": active}, name="draft-tokens"),
                flags="draft_decode")
            self.draft_store.swap(dcache)
            if snapshots is not None:
                snapshots.append(dcache)
            self.metrics.draft_steps += 1
            nxt_np = np.asarray(nxt).reshape(n).astype(np.int32)
            if i < k:
                window[:, i + 1] = nxt_np
            cur = nxt_np.reshape(n, 1)
        # ---- verify: one wide target forward for the whole batch
        greedy, cache, lp = self._dispatch(
            lambda p, c, b: self._verify(p, c, b),
            self._params_buf,
            self._resident(self.store.decode_cache(), "kv-cache"),
            Buffer({"tokens": window, "active": active}, name="verify-window"),
            flags="verify")
        self.store.swap_window(cache, W)
        self.metrics.decode_steps += 1
        self.metrics.spec_rounds += 1
        greedy_np = np.asarray(greedy)                     # (B, W)
        # ---- per-slot acceptance (host) + fixed-shape rollback plan
        slot_ids = np.full((n,), n, np.int64)              # pad: dropped
        new_index = np.zeros((n,), np.int64)
        scrub = np.full((n, k), self.ecfg.max_seq_len, np.int64)
        sel = np.zeros((n,), np.int64)                     # recurrent draft
        produced = 0
        to_retire = []
        for slot, req in list(self.scheduler.active.items()):
            # pre-round write position: prompt + generated - 1, the last
            # emitted token's (unwritten) slot — pure host arithmetic, no
            # device sync in the hot loop
            p = len(req.prompt) + req.metrics.n_generated - 1
            g = greedy_np[slot]
            a = 0             # leading draft proposals the target confirms
            while a < k and window[slot, a + 1] == g[a]:
                a += 1
            emit = min(a + 1, req.max_new_tokens - req.metrics.n_generated)
            if self.ecfg.eos_id is not None:
                hits = np.flatnonzero(g[:emit] == self.ecfg.eos_id)
                if hits.size:     # stop lands mid-window: nothing past it
                    emit = int(hits[0]) + 1
            stop = req.sampling.stop if req.sampling is not None else ()
            if stop:
                # a stop sequence can complete mid-window too: truncate the
                # emission at the first window position whose suffix matches
                hist = tuple(req.stop_history) + tuple(req.tokens)
                for j in range(emit):
                    if stop_match(hist + tuple(int(t) for t in g[:j + 1]),
                                  stop):
                        emit = j + 1
                        break
            req.tokens.extend(int(t) for t in g[:emit])
            req.token_ts.extend([now()] * emit)
            for j in range(emit):
                self._record_logprob(req, lp, (slot, j))
            self._presence[slot, [int(t) for t in g[:emit]]] = True
            req.metrics.n_generated += emit
            produced += emit
            self.metrics.proposed_tokens += k
            self.metrics.accepted_tokens += emit - 1
            self.metrics.accept_hist[emit] = (
                self.metrics.accept_hist.get(emit, 0) + 1)
            slot_ids[slot] = slot
            new_index[slot] = p + emit
            sel[slot] = emit
            scrub[slot, :W - emit] = p + emit + np.arange(W - emit)
            if self._finished(req):
                to_retire.append(slot)
        self.store.rollback(slot_ids, new_index, scrub)
        if self._draft_recurrent:
            # recurrent state has no positions to scrub: each slot adopts
            # the snapshot taken right after its last accepted token
            self.draft_store.adopt_selected(snapshots, sel)
        else:
            # the draft wrote K/V at exactly the target's window positions
            # (feed i writes position p+i), so the same rollback plan applies
            self.draft_store.rollback(slot_ids, new_index, scrub)
        for slot in to_retire:
            self._retire(slot)
        self.metrics.observe_tokens(produced)

    def _finished(self, req: Request) -> bool:
        """Finish check after every emitted token, setting
        ``req.finish_reason`` (priority: length, eos, stop). Stop sequences
        suffix-match the generated stream only — ``stop_history + tokens``,
        so a drain-handoff continuation still sees a match spanning the
        handoff point, and a match spanning a decode-step boundary fires at
        its last token."""
        if req.metrics.n_generated >= req.max_new_tokens:
            req.finish_reason = "length"
            return True
        if (self.ecfg.eos_id is not None
                and req.last_token == self.ecfg.eos_id):
            req.finish_reason = "eos"
            return True
        stop = req.sampling.stop if req.sampling is not None else ()
        if stop and stop_match(tuple(req.stop_history) + tuple(req.tokens),
                               stop):
            req.finish_reason = "stop"
            self.metrics.stop_hits += 1
            return True
        return False

    def _unwind_prefix(self, slot: int) -> None:
        """Take back a departing slot's prefix-cache hit counters: a
        preempted/exported request is re-admitted elsewhere, where its prefix
        walk is counted afresh — keeping this host's contribution would make
        the fleet-summed hit/reuse totals count one logical admission twice
        (the ISSUE-10 counter-reconciliation fix; regression in
        tests/test_disagg.py). Requests that COMPLETE here keep their counts
        (_retire drops the record without decrementing)."""
        contrib = self._prefix_contrib.pop(slot, None)
        if contrib is not None:
            hits, blocks, toks = contrib
            self.metrics.prefix_hits -= hits
            self.metrics.prefix_blocks_reused -= blocks
            self.metrics.prefix_tokens_reused -= toks

    def _retire(self, slot: int) -> None:
        req = self.scheduler.retire(slot)
        self._prefix_contrib.pop(slot, None)
        self.store.reset(slot)
        self._presence[slot, :] = False
        if self.draft_store is not None:
            self.draft_store.reset(slot)
        req.state = RequestState.DONE
        req.metrics.finish_s = now()
        self.metrics.completed += 1
        self.completed.append(req)

    # ------------------------------------------------------------ drain hooks
    # The multi-host router (serving/router.py) drains an engine by (1) no
    # longer placing traffic on it, (2) pulling its not-yet-admitted queue
    # with evict_queued, and (3) preempting long in-flight generations for
    # re-admission elsewhere. Both hooks operate at step boundaries only —
    # nothing is ever interrupted mid-dispatch.

    def evict_queued(self) -> List[Request]:
        """Pull every not-yet-admitted request out of the waiting FIFO, in
        order, leaving in-flight slots untouched. The requests hold no cache
        state yet (admission is what leases and seeds a slot), so the caller
        can re-submit them anywhere verbatim."""
        out = list(self.scheduler.waiting)
        self.scheduler.waiting.clear()
        for req in out:
            req.state = RequestState.PREEMPTED
            if req.id in self._deferred_ids:
                # the deferral leaves with the request: whichever host
                # re-admits it counts (or not) on its own, so the fleet sum
                # sees one deferral per logical request, not one per host it
                # ever waited on
                self._deferred_ids.discard(req.id)
                self.metrics.admissions_deferred -= 1
        self.metrics.evicted += len(out)
        return out

    def preempt(self, req_id: int) -> Request:
        """Remove an in-flight request at a step boundary: retire its slot,
        scrub its cache rows, and return it with the tokens it generated so
        far (>= 1 — admission produced the first). Greedy decode is
        deterministic, so a continuation submitted elsewhere with
        ``prompt + tokens`` as its prompt regenerates the EXACT remaining
        stream — the fused prefill-with-cache seeding path is bit-identical
        to decode replay, which is what makes drain handoff lossless
        (asserted in tests/test_router.py)."""
        for slot, req in self.scheduler.active.items():
            if req.id == req_id:
                self.scheduler.retire(slot)
                self.store.reset(slot)
                self._presence[slot, :] = False
                if self.draft_store is not None:
                    self.draft_store.reset(slot)
                self._unwind_prefix(slot)
                req.state = RequestState.PREEMPTED
                self.metrics.preempted += 1
                return req
        raise KeyError(f"request {req_id} is not in flight on this engine")

    # -------------------------------------------------- disaggregated handoff
    # Prefill/decode disaggregation (serving/router.py --disaggregate): a
    # prefill host admits and prefills a request, then its finished cache
    # blocks are SHIPPED to a decode host instead of recomputed there.
    # extract_seeded is the export side (a preempt whose KV leaves as a wire
    # payload); submit_seeded the import side (admission from a payload —
    # zero prefill dispatches, which is what keeps decode hosts' OPQ flag
    # audit free of prefill instructions). Shipped blocks carry exact cache
    # bits, so the continued stream is bit-identical to never having moved —
    # unlike re-prefill continuation, which remains the fallback oracle.

    def extract_seeded(self, req_id: int) -> Tuple[Request, Dict]:
        """Preempt an in-flight request AND export its slot's cache blocks
        as a serialized payload (store.export_blocks): the request's wire
        state plus exactly the bits a decode host needs to continue it
        without re-prefill. The payload id is cursor-named
        (``r<id>c<n_tokens>``) so a retried ship of the same cut is
        recognisable and never double-imports. The exported blocks stay on
        this host's export ledger — still counted as referenced — until
        ``release_exported`` acks the ship, so a failed ship falls back to
        re-prefill without having freed blocks a retry might still frame."""
        if self.ecfg.speculative:
            raise ValueError(
                "extract_seeded does not support speculative engines (the "
                "draft store's state cannot ship with the target's blocks)")
        if not hasattr(self.store, "export_blocks"):
            raise ValueError(
                f"extract_seeded requires the paged cache backend "
                f"(cross-host block shipping), got {self.store.kind!r} — "
                f"use preempt + re-prefill continuation instead")
        for slot, req in self.scheduler.active.items():
            if req.id == req_id:
                payload = self.store.export_blocks(
                    slot, payload_id=f"r{req.id}c{len(req.tokens)}")
                self.scheduler.retire(slot)
                self._presence[slot, :] = False
                self._unwind_prefix(slot)
                req.state = RequestState.PREEMPTED
                self.metrics.exported_slots += 1
                self.metrics.exported_blocks += payload["n_blocks"]
                return req, payload
        raise KeyError(f"request {req_id} is not in flight on this engine")

    def release_exported(self, payload_id: str) -> bool:
        """Ack a shipped payload: release the export ledger's hold on its
        blocks (refcount-correct — trie-cached blocks stay cached, private
        ones scrub free). Idempotent; False when the id is unknown or
        already acked."""
        return self.store.release_exported(payload_id)

    def submit_seeded(self, prompt: Sequence[int], max_new_tokens: int,
                      tokens: Sequence[int], payload: Dict,
                      *, sampling: Optional[SamplingParams] = None,
                      stop_history: Sequence[int] = (),
                      want_logprobs: Optional[int] = None,
                      logprobs: Sequence[float] = (),
                      top_logprobs: Sequence = ()) -> Optional[Request]:
        """Admit a mid-flight stream straight into the in-flight batch from
        a shipped block payload: lease a slot, import the payload's cache
        bits into it (validated in full BEFORE any device write — a corrupt
        payload raises ValueError with the slot left clean), and join the
        next decode step. No prefill is dispatched at all.

        ``tokens`` is the stream's generated-so-far suffix (>= 1 — the last
        token is what the next decode step feeds); ``max_new_tokens`` the
        ORIGINAL budget, which ``len(tokens)`` already counts against.
        Returns None when no slot is free or the lease is refused — the
        router's cue to fall back to re-prefill continuation."""
        if self.ecfg.speculative:
            raise ValueError(
                "submit_seeded does not support speculative engines (no "
                "draft-store payload ships with the target's blocks)")
        if not hasattr(self.store, "import_blocks"):
            raise ValueError(
                f"submit_seeded requires the paged cache backend "
                f"(cross-host block shipping), got {self.store.kind!r}")
        prompt = np.asarray(prompt, dtype=np.int32).reshape(-1)
        tokens = [int(t) for t in tokens]
        if not tokens:
            raise ValueError(
                "submit_seeded needs >= 1 generated token (the decode step "
                "feeds the stream's last emitted token)")
        if len(tokens) >= max_new_tokens:
            raise ValueError(
                f"stream already finished: {len(tokens)} generated tokens "
                f">= max_new_tokens {max_new_tokens} — nothing to decode")
        if len(prompt) + max_new_tokens > self.ecfg.max_seq_len:
            raise ValueError(
                f"prompt={len(prompt)} + gen={max_new_tokens} exceeds "
                f"max_seq_len {self.ecfg.max_seq_len}")
        if not self.scheduler.free:
            return None
        slot = self.scheduler.free[-1]
        # a plain lease (no prompt-token trie walk): imported blocks stay
        # PRIVATE to this slot — they never register in the radix trie,
        # because their content hash belongs to the shipping host's cache
        if not self.store.lease(slot, len(prompt), max_new_tokens):
            return None
        try:
            self.store.import_blocks(slot, payload)
        except Exception:
            self.store.reset(slot)
            raise
        req = Request(id=next(self._req_ids), prompt=prompt,
                      max_new_tokens=max_new_tokens,
                      state=RequestState.RUNNING, tokens=tokens,
                      sampling=sampling, stop_history=tuple(stop_history),
                      want_logprobs=want_logprobs,
                      metrics=RequestMetrics(arrival_s=now(),
                                             prompt_len=len(prompt)))
        req.logprobs = [float(v) for v in logprobs]
        req.top_logprobs = [[(int(t), float(v)) for t, v in row]
                            for row in top_logprobs]
        # keep token_ts index-aligned with tokens: the seeded prefix was
        # emitted (and harvested) on the shipping host, so its entries are
        # placeholders behind every caller's cursor
        req.token_ts = [now()] * len(tokens)
        self.scheduler.admit_seeded(req)
        t = now()
        req.metrics.admitted_s = t
        req.metrics.first_token_s = t
        req.metrics.n_generated = len(tokens)
        self._presence[slot, :] = False
        self._presence[slot, prompt] = True
        self._presence[slot, tokens] = True
        self.metrics.submitted += 1
        self.metrics.imported_slots += 1
        self.metrics.imported_blocks += payload["n_blocks"]
        return req

    def step(self) -> None:
        """One engine iteration: join waiting requests into free slots, then
        one batched decode step for whatever is in flight."""
        admitted = self._admit()
        if (admitted == 0 and not self.scheduler.active
                and self.scheduler.waiting):
            # zero-progress state: the queue head was deferred by the store
            # lease while NOTHING is in flight — no retire can ever free
            # capacity, so every further step would be an identical no-op.
            # (fits() should have bounced such a request at submit; this
            # guards the submit-time-reject vs lease-time-defer line against
            # drift, which previously burned max_steps idle iterations.)
            head = self.scheduler.waiting[0]
            raise RuntimeError(
                f"admission livelock: request {head.id} "
                f"(prompt={len(head.prompt)} tok, "
                f"max_new_tokens={head.max_new_tokens}) was deferred by the "
                f"{self.store.kind} store's lease with zero active slots — "
                f"no retire can free capacity for it; "
                f"store: {self.store.memory_stats()}")
        # occupancy sampled before the decode's retires, so slots busy this
        # step count even when their request finishes in it
        n_active = self.scheduler.n_active
        if n_active:
            if self.ecfg.speculative:
                self._spec_decode_once()
            else:
                self._decode_once()
        self.metrics.observe_step(self.scheduler.queue_depth, n_active)

    def has_work(self) -> bool:
        return self.scheduler.has_work()

    def run_until_complete(self, max_steps: int = 100_000) -> List[Request]:
        steps = 0
        while self.has_work():
            self.step()
            steps += 1
            if steps > max_steps:
                raise RuntimeError(f"engine did not drain in {max_steps} steps")
        return self.completed

    # ------------------------------------------------------- non-generative

    def embed(self, prompt: Sequence[int]) -> Dict[str, np.ndarray]:
        """Non-generative forward for the serve API (embeddings /
        classification): one bucketed dispatch returning the prompt's
        last-position final-norm hidden state and its last-position logits
        row (padded vocab columns trimmed). Reuses the prefill bucketing so
        the number of compiled embed shapes is bounded like admission's, and
        goes through the same OPQ dispatch (flag ``embed/<bucket>``) —
        no slot is leased, nothing touches the cache."""
        prompt = np.asarray(prompt, dtype=np.int32).reshape(-1)
        if len(prompt) < 1:
            raise ValueError("embed needs a non-empty prompt")
        bucket = bucket_for(len(prompt), self.scheduler.buckets)
        toks = np.zeros((1, bucket), np.int32)
        toks[0, :len(prompt)] = prompt
        last = np.asarray([len(prompt) - 1], np.int32)
        hid, row = self._dispatch(
            lambda p, t, li, fn=_jitted_embed(self.cfg): fn(p, t, li),
            self._params_buf, Buffer(toks, name=f"embed{bucket}"),
            Buffer(last), flags=f"embed/{bucket}")
        self.metrics.embed_requests += 1
        return {"embedding": np.asarray(hid)[0],
                "logits": np.asarray(row)[0, :self.cfg.vocab]}

    # --------------------------------------------------------------- summary

    def stats(self) -> Dict:
        out = dict(self.metrics.summary())
        out["cache"] = self.store.memory_stats()
        if self.opq is not None:
            out["opq"] = dict(self.opq.stats)
            # per-flag instruction counts: the dispatch-shape audit trail
            # (tests assert admission issues one prefill/<bucket> instruction
            # per bucket batch and zero replay decodes)
            out["opq"]["flags"] = dict(self.opq.flag_counts)
        return out

    def close(self) -> None:
        if self._owns_opq and self.opq is not None:
            self.opq.shutdown()
