"""Continuous-batching inference engine over the OPQ runtime.

The production-shaped layer the GPTPU runtime was missing: requests enter a
bounded FIFO (admission control), a slot-based scheduler joins them into a
fixed-width in-flight decode batch and retires them as they finish — no
full-batch barrier, so a long generation never stalls short ones — and a
KVSlotManager leases per-slot cache rows (allocate once, reset on retire,
int8-KV aware). All device work (bucketed prefill, replay seeding, the batched
decode step) is dispatched as OPQ instructions, so the paper's buffer-affinity
scheduling and backup-task straggler mitigation apply to serving traffic, not
just the Rodinia apps.

Decode semantics are *greedy and batch-invariant* for dense archs: every slot
computes exactly the math of a single-request decode at its own position
(per-slot cache index, see models/attention.py), so staggered-arrival outputs
are bit-identical to one-at-a-time sequential decoding — asserted in
tests/test_serving.py. MoE archs serve correctly but without the bit-identity
guarantee: expert capacity is shared across the decode batch (moe.py), so
under capacity pressure a token's expert slot can depend on its batchmates —
the standard batched-MoE-serving tradeoff.

Scope: token-input dense/moe families (tinyllama, qwen3, granite, starcoder2,
deepseek/moonshot MoE). Hybrid/ssm/encdec recurrent state slots, paged KV,
and per-request-isolated MoE routing are ROADMAP items.
"""

from __future__ import annotations

import dataclasses
import enum
import functools
import itertools
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.opq import OPQ, Buffer
from repro.models import model as M
from repro.models import serve as SV
from repro.models import steps as ST
from repro.serving.kv import KVSlotManager
from repro.serving.metrics import EngineMetrics, RequestMetrics, now
from repro.serving.scheduler import Scheduler, default_buckets


class RequestState(enum.Enum):
    QUEUED = "queued"
    RUNNING = "running"
    DONE = "done"


@dataclasses.dataclass
class Request:
    id: int
    prompt: np.ndarray                     # (L,) int32
    max_new_tokens: int
    state: RequestState = RequestState.QUEUED
    tokens: List[int] = dataclasses.field(default_factory=list)
    metrics: RequestMetrics = None         # set at submit

    @property
    def last_token(self) -> int:
        return self.tokens[-1]

    @property
    def done(self) -> bool:
        return self.state == RequestState.DONE


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    max_slots: int = 4                     # in-flight decode batch width
    max_queue: int = 64                    # admission control: FIFO bound
    max_seq_len: int = 64                  # per-slot cache rows (prompt + gen)
    buckets: Optional[Tuple[int, ...]] = None   # prefill pad lengths
    eos_id: Optional[int] = None           # early finish token (None = length-only)
    use_opq: bool = True                   # dispatch through the OPQ runtime


def _make_bucket_prefill(cfg: ArchConfig):
    """Batched prefill over right-padded prompts. Causal attention means pad
    tokens after a row's prompt never reach its logits, so gathering at
    ``last_index`` (= prompt_len - 1) is exact for any pad content on dense
    archs — that is what makes a small fixed bucket set safe. MoE archs carry
    the same caveat as decode (module docstring): pad tokens are routed and
    consume shared expert capacity, so under capacity pressure the gathered
    logits can depend on the bucket/batch composition."""
    def prefill(params, tokens, last_index):
        logits, _ = M.forward(params, cfg, {"tokens": tokens})
        B, V = tokens.shape[0], logits.shape[-1]
        idx = jnp.broadcast_to(last_index[:, None, None], (B, 1, V))
        row = jnp.take_along_axis(logits, idx, axis=1)[:, 0, :]
        return jnp.argmax(row, axis=-1)
    return prefill


@functools.lru_cache(maxsize=None)
def _jitted_steps(cfg: ArchConfig):
    """Compiled step fns shared across Engine instances of the same config —
    rebuilding an engine (tests, benchmark sweeps) reuses XLA executables."""
    prefill = jax.jit(_make_bucket_prefill(cfg))
    decode = jax.jit(ST.make_decode_step(cfg), donate_argnums=(1,))
    replay = jax.jit(ST.make_decode_step(cfg))   # B=1 seeding, no donation:
    # the pristine replay template cache is reused for every admission
    return prefill, decode, replay


class QueueFull(Exception):
    """Raised by submit(strict=True) when admission control rejects."""


class Engine:
    """See module docstring. Typical use::

        engine = Engine(cfg, params, EngineConfig(max_slots=4, max_seq_len=64))
        engine.submit(prompt_ids, max_new_tokens=16)
        done = engine.run_until_complete()
    """

    def __init__(self, cfg: ArchConfig, params, engine_cfg: EngineConfig = None,
                 *, opq: Optional[OPQ] = None):
        if cfg.family not in ("dense", "moe") or cfg.input_mode != "tokens":
            raise ValueError(
                f"serving engine supports token-input dense/moe archs, got "
                f"family={cfg.family} input_mode={cfg.input_mode}")
        self.cfg = cfg
        self.params = params
        self.ecfg = engine_cfg or EngineConfig()
        buckets = self.ecfg.buckets or default_buckets(self.ecfg.max_seq_len)
        self.scheduler = Scheduler(self.ecfg.max_slots, buckets)
        self.kv = KVSlotManager(cfg, self.ecfg.max_slots, self.ecfg.max_seq_len)
        self._prefill, self._decode, self._replay = _jitted_steps(cfg)
        self._replay_template = SV.init_cache(cfg, 1, self.ecfg.max_seq_len)
        self._owns_opq = opq is None and self.ecfg.use_opq
        self.opq = (OPQ() if self._owns_opq else opq) if self.ecfg.use_opq else None
        self._params_buf = Buffer(params, name="params")
        self._req_ids = itertools.count()
        self.metrics = EngineMetrics()
        self.completed: List[Request] = []

    # ------------------------------------------------------------ OPQ bridge

    def _resident(self, tree, name: str) -> Buffer:
        leaves = jax.tree.leaves(tree)
        try:
            dev = next(iter(leaves[0].devices()))
            return Buffer.resident(tree, dev, name=name)
        except (AttributeError, IndexError, StopIteration):
            return Buffer(tree, name=name)

    def _dispatch(self, fn, *bufs: Buffer, flags: str = ""):
        """Run one instruction: through the OPQ scheduler (affinity + backup
        tasks), or directly when the runtime is disabled. Untracked: the
        engine consumes each result here, so nothing is retained for sync()
        and the task registry stays empty over an unbounded serving run."""
        if self.opq is None:
            return fn(*(b.data for b in bufs))
        return self.opq.invoke_operator(fn, *bufs, flags=flags,
                                        track=False).result()

    # ------------------------------------------------------------- admission

    def submit(self, prompt: Sequence[int], max_new_tokens: int,
               *, strict: bool = False) -> Optional[Request]:
        """Admission control at the door: a bounded queue and a hard per-slot
        sequence budget. Returns the Request, or None when rejected
        (QueueFull when ``strict``)."""
        prompt = np.asarray(prompt, dtype=np.int32).reshape(-1)
        reject = (self.scheduler.queue_depth >= self.ecfg.max_queue
                  or len(prompt) == 0
                  or max_new_tokens < 1
                  or len(prompt) + max_new_tokens > self.ecfg.max_seq_len
                  # custom buckets may cap below max_seq_len: reject at the
                  # door, not mid-admission after a slot was leased
                  or len(prompt) > max(self.scheduler.buckets))
        if reject:
            self.metrics.rejected += 1
            if strict:
                raise QueueFull(
                    f"rejected: queue_depth={self.scheduler.queue_depth}, "
                    f"prompt={len(prompt)} + gen={max_new_tokens} vs "
                    f"max_seq_len={self.ecfg.max_seq_len}")
            return None
        req = Request(id=next(self._req_ids), prompt=prompt,
                      max_new_tokens=max_new_tokens,
                      metrics=RequestMetrics(arrival_s=now(),
                                             prompt_len=len(prompt)))
        self.scheduler.enqueue(req)
        self.metrics.submitted += 1
        return req

    # ----------------------------------------------------------- engine step

    def _admit(self) -> None:
        for bucket, pairs in self.scheduler.plan_admissions():
            toks = np.zeros((len(pairs), bucket), np.int32)
            last = np.zeros((len(pairs),), np.int32)
            for i, (_, req) in enumerate(pairs):
                toks[i, :len(req.prompt)] = req.prompt
                last[i] = len(req.prompt) - 1
            first = self._dispatch(
                lambda p, t, li: self._prefill(p, t, li),
                self._params_buf, Buffer(toks, name=f"prefill{bucket}"),
                Buffer(last), flags=f"prefill/{bucket}")
            first = np.asarray(first)
            self.metrics.prefill_batches += 1
            self.metrics.prefill_tokens += int(last.sum()) + len(pairs)
            for i, (slot, req) in enumerate(pairs):
                req.state = RequestState.RUNNING
                req.tokens.append(int(first[i]))
                req.metrics.first_token_s = now()
                req.metrics.n_generated = 1
                self.metrics.observe_tokens(1)
                if self._finished(req):       # done at the prefill token:
                    self._retire(slot)        # skip the O(prompt) seeding
                else:
                    self._seed_slot(slot, req)

    def _seed_slot(self, slot: int, req: Request) -> None:
        """Fill the slot's cache row with the prompt's K/V by replaying it
        through the B=1 decode step (every replay step is the same (1,1)
        shape — zero length-dependent recompilation), then copy the region
        into the leased row."""
        rc = self._replay_template
        for i in range(len(req.prompt)):
            tok = np.asarray([[req.prompt[i]]], np.int32)
            _, rc = self._dispatch(
                lambda p, c, t: self._replay(p, c, {"tokens": t}),
                self._params_buf, self._resident(rc, "replay-cache"),
                Buffer(tok), flags="replay")
        self.kv.write_slot(slot, rc, n_valid=len(req.prompt))

    def _decode_once(self) -> None:
        toks = np.zeros((self.ecfg.max_slots, 1), np.int32)
        for slot, req in self.scheduler.active.items():
            toks[slot, 0] = req.last_token
        next_tok, cache = self._dispatch(
            lambda p, c, t: self._decode(p, c, {"tokens": t}),
            self._params_buf, self._resident(self.kv.cache, "kv-cache"),
            Buffer(toks, name="decode-tokens"), flags="decode")
        self.kv.swap(cache)
        self.metrics.decode_steps += 1
        next_np = np.asarray(next_tok)
        produced = 0
        for slot, req in list(self.scheduler.active.items()):
            req.tokens.append(int(next_np[slot]))
            req.metrics.n_generated += 1
            produced += 1
            if self._finished(req):
                self._retire(slot)
        self.metrics.observe_tokens(produced)

    def _finished(self, req: Request) -> bool:
        return (req.metrics.n_generated >= req.max_new_tokens
                or (self.ecfg.eos_id is not None
                    and req.last_token == self.ecfg.eos_id))

    def _retire(self, slot: int) -> None:
        req = self.scheduler.retire(slot)
        self.kv.reset_slot(slot)
        req.state = RequestState.DONE
        req.metrics.finish_s = now()
        self.metrics.completed += 1
        self.completed.append(req)

    def step(self) -> None:
        """One engine iteration: join waiting requests into free slots, then
        one batched decode step for whatever is in flight."""
        self._admit()
        # occupancy sampled before the decode's retires, so slots busy this
        # step count even when their request finishes in it
        n_active = self.scheduler.n_active
        if n_active:
            self._decode_once()
        self.metrics.observe_step(self.scheduler.queue_depth, n_active)

    def has_work(self) -> bool:
        return self.scheduler.has_work()

    def run_until_complete(self, max_steps: int = 100_000) -> List[Request]:
        steps = 0
        while self.has_work():
            self.step()
            steps += 1
            if steps > max_steps:
                raise RuntimeError(f"engine did not drain in {max_steps} steps")
        return self.completed

    # --------------------------------------------------------------- summary

    def stats(self) -> Dict:
        out = dict(self.metrics.summary())
        if self.opq is not None:
            out["opq"] = dict(self.opq.stats)
        return out

    def close(self) -> None:
        if self._owns_opq and self.opq is not None:
            self.opq.shutdown()
