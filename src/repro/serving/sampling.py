"""Batch-invariant sampling: per-request stochastic decoding on the engine.

The engine was greedy-only; this module adds the full per-request sampling
surface (temperature, top-k, top-p nucleus, repetition penalty, stop
sequences, seeds) as ONE batched step that the decode/prefill executables
share across every parameter mix — param application is masked and
vectorized, so a batch mixing greedy and sampled rows still dispatches a
single OPQ program per step (the flag-audit invariant holds).

The load-bearing property is **batch invariance**: randomness is derived
counter-style from ``(request_seed, absolute_position)`` via
``jax.random.fold_in``, never from batch-level state, so a seeded request
emits the *same* token stream no matter which batchmates share its decode
step, which slot it lands in, which cache backend holds its K/V, or whether
a router drain hands it off mid-stream. This extends the repo's bit-identity
invariant family from greedy to stochastic decoding.

Stop sequences are matched host-side over the *generated* tokens only (the
prompt never triggers a stop); matching is suffix-based each step so a stop
spanning a decode-step boundary (or a speculative window) still fires.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "SamplingParams", "GREEDY", "stack_params", "sample_tokens",
    "choose_tokens", "stop_match", "logprob_info", "TOP_LOGPROBS",
    "sampling_to_wire", "sampling_from_wire",
]

# Device-side top-K width for per-token logprob capture. Fixed so the
# decode/prefill executables stay ONE program regardless of what any
# request asked for (the serve API trims to the requested top_logprobs
# host-side; requests asking for more than this are rejected at the door).
TOP_LOGPROBS = 5


def _norm_stop(stop) -> Tuple[Tuple[int, ...], ...]:
    """Normalize stop sequences to a tuple of non-empty int tuples."""
    if stop is None:
        return ()
    if isinstance(stop, (int, np.integer)):
        stop = ((int(stop),),)
    out = []
    for seq in stop:
        if isinstance(seq, (int, np.integer)):
            seq = (int(seq),)
        seq = tuple(int(t) for t in seq)
        if not seq:
            raise ValueError("empty stop sequence")
        out.append(seq)
    return tuple(out)


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request decoding parameters.

    ``temperature <= 0`` means greedy (argmax) — the default — so a plain
    ``SamplingParams()`` is exactly the engine's historical behaviour.
    ``top_k <= 0`` disables top-k; ``top_p >= 1.0`` disables nucleus
    filtering; ``repetition_penalty == 1.0`` is a no-op. ``stop`` is a
    sequence of token-id sequences matched against generated tokens.
    """

    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    repetition_penalty: float = 1.0
    seed: int = 0
    stop: Tuple[Tuple[int, ...], ...] = ()

    def __post_init__(self):
        if self.temperature < 0:
            raise ValueError(f"temperature must be >= 0, got {self.temperature}")
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0, got {self.top_k}")
        if not (0.0 < self.top_p <= 1.0):
            raise ValueError(f"top_p must be in (0, 1], got {self.top_p}")
        if self.repetition_penalty <= 0:
            raise ValueError(
                f"repetition_penalty must be > 0, got {self.repetition_penalty}")
        object.__setattr__(self, "stop", _norm_stop(self.stop))

    @property
    def greedy(self) -> bool:
        return self.temperature <= 0.0


GREEDY = SamplingParams()


def stack_params(sps: Sequence[Optional[SamplingParams]],
                 presence: np.ndarray) -> Dict[str, jnp.ndarray]:
    """Stack per-slot SamplingParams into the batched arrays sample_tokens
    consumes. ``presence`` is the host-side (B, vocab_padded) bool array of
    token ids already seen by each slot (prompt + generated), used by the
    repetition penalty. ``None`` entries mean greedy (empty slots / legacy
    callers)."""
    sps = [sp if sp is not None else GREEDY for sp in sps]
    return {
        "temperature": jnp.asarray([sp.temperature for sp in sps], jnp.float32),
        "top_k": jnp.asarray([sp.top_k for sp in sps], jnp.int32),
        "top_p": jnp.asarray([sp.top_p for sp in sps], jnp.float32),
        "rep_penalty": jnp.asarray(
            [sp.repetition_penalty for sp in sps], jnp.float32),
        "seed": jnp.asarray([sp.seed for sp in sps], jnp.uint32),
        "greedy": jnp.asarray([sp.greedy for sp in sps], bool),
        "presence": jnp.asarray(presence, bool),
    }


def _gumbel_rows(seed: jnp.ndarray, position: jnp.ndarray,
                 vocab: int) -> jnp.ndarray:
    """(B,) seed x (B,) position -> (B, vocab) Gumbel noise, a pure function
    of each row's (seed, position) — the batch-invariance keystone."""

    def one(s, p):
        key = jax.random.fold_in(jax.random.PRNGKey(s), p)
        return jax.random.gumbel(key, (vocab,), jnp.float32)

    return jax.vmap(one)(seed, position)


def sample_tokens(logits: jnp.ndarray, sp: Dict[str, jnp.ndarray],
                  positions: jnp.ndarray) -> jnp.ndarray:
    """One batched, batch-invariant sampling step.

    logits: (B, vocab_padded) last-position logits (any float dtype).
    sp: stacked params from stack_params.
    positions: (B,) int32 absolute position of the token being emitted —
    the randomness counter.

    Greedy rows take a plain argmax on the raw (cast) logits — bit-identical
    to the historical greedy path. Sampled rows apply repetition penalty,
    temperature, top-k, top-p, then Gumbel-max with counter-derived noise.
    All rows run through one executable; the mix is masked, not branched
    per-row.
    """
    logits = logits.astype(jnp.float32)
    B, V = logits.shape
    greedy_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)

    def sampled(_):
        # Repetition penalty (CTRL-style) over the presence mask.
        rep = sp["rep_penalty"][:, None]
        seen = sp["presence"]
        pen = jnp.where(logits > 0, logits / rep, logits * rep)
        l = jnp.where(seen, pen, logits)
        # Temperature.
        l = l / jnp.maximum(sp["temperature"], 1e-6)[:, None]
        # Sort once, apply top-k and top-p in sorted space.
        srt = jnp.sort(l, axis=-1)[:, ::-1]
        col = jnp.arange(V)[None, :]
        k = jnp.clip(sp["top_k"], 0, V)[:, None]
        in_k = (k <= 0) | (col < k)
        probs = jax.nn.softmax(jnp.where(in_k, srt, -jnp.inf), axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        top_p = sp["top_p"][:, None]
        keep = in_k & (((cum - probs) < top_p) | (top_p >= 1.0))
        keep = keep.at[:, 0].set(True)
        # Threshold back to unsorted space: allowed = logit >= smallest kept.
        thresh = jnp.min(jnp.where(keep, srt, jnp.inf), axis=-1)[:, None]
        allowed = l >= thresh
        g = _gumbel_rows(sp["seed"], positions.astype(jnp.int32), V)
        return jnp.argmax(jnp.where(allowed, l + g, -jnp.inf),
                          axis=-1).astype(jnp.int32)

    # Skip the whole sampled pipeline when every row is greedy (the common
    # serving default pays nothing).
    tok = jax.lax.cond(jnp.any(~sp["greedy"]), sampled,
                       lambda _: greedy_tok, operand=None)
    return jnp.where(sp["greedy"], greedy_tok, tok)


def choose_tokens(row: jnp.ndarray, sampling: Optional[Dict[str, jnp.ndarray]],
                  positions) -> jnp.ndarray:
    """Logits row -> token, for the step builders: greedy argmax when no
    sampling state is threaded (legacy/test callers), the batched sampler
    otherwise. ``positions`` may be scalar (broadcast over the batch)."""
    if sampling is None:
        return jnp.argmax(row, axis=-1).astype(jnp.int32)
    positions = jnp.asarray(positions, jnp.int32)
    if positions.ndim == 0:
        positions = jnp.broadcast_to(positions, (row.shape[0],))
    return sample_tokens(row, sampling, positions)


def logprob_info(row: jnp.ndarray, chosen: jnp.ndarray,
                 vocab: int) -> Dict[str, jnp.ndarray]:
    """Per-token logprob capture for the serve API: the log-softmax
    probability of the CHOSEN token (sampled or greedy) plus the top
    ``TOP_LOGPROBS`` alternatives, computed on the same logits row the
    token choice used — no second forward, no second executable.

    row: (..., vocab_padded) logits; chosen: (...,) int token ids.
    Padded vocab columns are masked to -inf BEFORE the softmax so the
    distribution is over the real vocabulary (pad logits are unspecified).
    Returns {"lp": (...,) f32, "top_ids": (..., K) i32, "top_lps":
    (..., K) f32}.
    """
    row = row.astype(jnp.float32)
    V = row.shape[-1]
    real = jnp.arange(V) < vocab
    lp = jax.nn.log_softmax(jnp.where(real, row, -jnp.inf), axis=-1)
    chosen_lp = jnp.take_along_axis(
        lp, chosen[..., None].astype(jnp.int32), axis=-1)[..., 0]
    top_lps, top_ids = jax.lax.top_k(lp, TOP_LOGPROBS)
    return {"lp": chosen_lp, "top_ids": top_ids.astype(jnp.int32),
            "top_lps": top_lps}


def sampling_to_wire(sp: Optional[SamplingParams]) -> Optional[Dict]:
    """SamplingParams -> plain JSON/msgpack-able dict (transport frames)."""
    if sp is None:
        return None
    return {
        "temperature": sp.temperature, "top_k": sp.top_k, "top_p": sp.top_p,
        "repetition_penalty": sp.repetition_penalty, "seed": sp.seed,
        "stop": [list(seq) for seq in sp.stop],
    }


def sampling_from_wire(d: Optional[Dict]) -> Optional[SamplingParams]:
    """Inverse of :func:`sampling_to_wire` (worker side of the transport)."""
    if d is None:
        return None
    return SamplingParams(
        temperature=float(d.get("temperature", 0.0)),
        top_k=int(d.get("top_k", 0)), top_p=float(d.get("top_p", 1.0)),
        repetition_penalty=float(d.get("repetition_penalty", 1.0)),
        seed=int(d.get("seed", 0)),
        stop=tuple(tuple(int(t) for t in seq) for seq in d.get("stop", ())))


def stop_match(tokens: Sequence[int],
               stop: Tuple[Tuple[int, ...], ...]) -> Optional[Tuple[int, ...]]:
    """Suffix-match generated tokens against stop sequences; returns the
    matched sequence (or None). Called host-side each harvest, so a stop
    spanning a step boundary fires as soon as its last token lands."""
    if not stop:
        return None
    toks = tuple(tokens)
    for seq in stop:
        n = len(seq)
        if n <= len(toks) and toks[-n:] == seq:
            return seq
    return None
