"""Continuous-batching serving engine on the OPQ runtime (see engine.py)."""

from repro.serving.engine import (          # noqa: F401
    Engine, EngineConfig, QueueFull, Request, RequestState,
)
from repro.serving.kv import KVSlotManager              # noqa: F401
from repro.serving.metrics import EngineMetrics, RequestMetrics  # noqa: F401
from repro.serving.scheduler import Scheduler, bucket_for, default_buckets  # noqa: F401
