"""Continuous-batching serving engine on the OPQ runtime (see engine.py).

Public cache surface: the :class:`SlotStore` protocol (store.py) with
``ContiguousKVStore`` / ``PagedKVStore`` / ``RecurrentStateStore`` backends
and the ``make_store(cfg, n_slots, max_seq_len, backend=...)`` factory.
``KVSlotManager`` survives as a deprecated shim over ContiguousKVStore.

Multi-host: :class:`Router` (router.py) fronts one Engine per simulated host
with cache-affinity placement, load-aware spill, and drain/handoff — the OPQ
affinity policy extended across hosts. See docs/architecture.md for the
layer map.
"""

from repro.serving.engine import (          # noqa: F401
    Engine, EngineConfig, QueueFull, Request, RequestState,
)
from repro.serving.kv import KVSlotManager              # noqa: F401  (deprecated)
from repro.serving.metrics import (          # noqa: F401
    EngineMetrics, RequestMetrics, format_memory_stats, format_router_stats,
    format_sampling_stats,
)
from repro.serving.router import (           # noqa: F401
    Router, RouterConfig, RouterRequest,
)
from repro.serving.sampling import (         # noqa: F401
    GREEDY, SamplingParams, sample_tokens, stop_match,
)
from repro.serving.api import ApiServer, serve_api      # noqa: F401
from repro.serving.scheduler import Scheduler, bucket_for, default_buckets  # noqa: F401
from repro.serving.store import (            # noqa: F401
    ContiguousKVStore, PagedKVStore, RecurrentStateStore, SlotStore,
    make_store, pristine_value,
)
