"""Continuous-batching serving engine on the OPQ runtime (see engine.py).

Public cache surface: the :class:`SlotStore` protocol (store.py) with
``ContiguousKVStore`` / ``PagedKVStore`` / ``RecurrentStateStore`` backends
and the ``make_store(cfg, n_slots, max_seq_len, backend=...)`` factory.
``KVSlotManager`` survives as a deprecated shim over ContiguousKVStore.

Multi-host: :class:`Router` (router.py) fronts one host per
:class:`HostTransport` (transport.py) with cache-affinity placement,
load-aware spill, drain/handoff, and host-loss recovery. Hosts are
in-process engines (``build_inproc_fleet``, the default) or real OS
processes (``SubprocessTransport`` + host_main.py workers speaking framed
RPC over a local socket). See docs/architecture.md for the layer map.
"""

from repro.serving.engine import (          # noqa: F401
    Engine, EngineConfig, QueueFull, Request, RequestState,
)
from repro.serving.kv import KVSlotManager              # noqa: F401  (deprecated)
from repro.serving.metrics import (          # noqa: F401
    EngineMetrics, RequestMetrics, TransportMetrics, format_memory_stats,
    format_router_stats, format_sampling_stats, format_transport_stats,
)
from repro.serving.transport import (        # noqa: F401
    EngineHost, HostTransport, InProcessTransport, SubprocessTransport,
    TransportError, build_inproc_fleet, build_model_spec,
    realize_model_spec,
)
from repro.serving.router import (           # noqa: F401
    Router, RouterConfig, RouterRequest,
)
from repro.serving.sampling import (         # noqa: F401
    GREEDY, SamplingParams, sample_tokens, stop_match,
)
from repro.serving.api import ApiServer, serve_api      # noqa: F401
from repro.serving.scheduler import Scheduler, bucket_for, default_buckets  # noqa: F401
from repro.serving.store import (            # noqa: F401
    ContiguousKVStore, PagedKVStore, RecurrentStateStore, SlotStore,
    make_store, pristine_value,
)
