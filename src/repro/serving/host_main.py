"""Worker entry point for SubprocessTransport: one engine per OS process.

``python -m repro.serving.host_main --socket PATH`` connects back to the
parent's AF_UNIX listener, receives an init frame ({model_spec,
engine_cfg}), rebuilds the model deterministically from the spec
(bit-identical weights to the parent — see transport.realize_model_spec),
and enters the serve loop.

The loop FREE-RUNS the engine: between frames it calls ``pump()`` (one
engine step when there is work), polling the socket with a zero timeout
while busy and a short sleep-poll when idle. This is the "step loop driven
by the worker" half of the transport refactor — the Router never drives
remote engines step-by-step, it only submits and harvests. Batch
invariance is what makes that safe: the tokens a free-running engine emits
are a pure function of each request's prompt + seed, independent of how
far the worker ran ahead of the Router's polls.

Errors split two ways: application errors (ValueError/KeyError from a
healthy engine, e.g. strict-submit QueueFull or a bad preempt id) reply as
``{"ok": False, "etype", "err"}`` and the loop continues; anything that
breaks the socket ends the process — the parent's TransportError handling
takes over from there.
"""

from __future__ import annotations

import argparse
import os
import select
import socket
import sys


def serve(sock_path: str) -> int:
    sock = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
    sock.connect(sock_path)

    from repro.serving.transport import Channel, TransportError
    chan = Channel(sock)

    init = chan.recv(timeout=None)
    if init.get("op") != "init":
        chan.send({"seq": init.get("seq"), "ok": False, "etype": "RuntimeError",
                   "err": f"expected init frame, got {init.get('op')!r}"})
        return 2
    spec = init["args"]["model_spec"]

    # heavy imports AFTER the socket handshake so a connect failure is fast
    from repro.distributed import sharding as shd
    from repro.launch.mesh import make_smoke_mesh
    from repro.serving.engine import Engine
    from repro.serving.sampling import sampling_from_wire
    from repro.serving.transport import (
        EngineHost, engine_cfg_from_wire, realize_model_spec,
    )

    mesh = make_smoke_mesh(int(spec.get("model_parallel", 1)))
    with shd.use_mesh(mesh):
        cfg, params, draft_cfg, draft_params = realize_model_spec(spec)
        ecfg = engine_cfg_from_wire(init["args"]["engine_cfg"],
                                    draft_cfg=draft_cfg)
        host = EngineHost(Engine(cfg, params, ecfg,
                                 draft_params=draft_params))
        chan.send({"seq": init.get("seq"), "ok": True,
                   "val": {"pid": os.getpid()}})
        try:
            _loop(chan, host)
        finally:
            host.close()
    return 0


def _loop(chan, host) -> None:
    from repro.serving.transport import TransportError
    while True:
        # busy => zero-timeout poll (frames handled between engine steps);
        # idle => short block so an idle worker doesn't spin a core
        timeout = 0.0 if host.has_work() else 0.05
        ready, _, _ = select.select([chan.sock], [], [], timeout)
        if not ready:
            host.pump()
            continue
        try:
            frame = chan.recv(timeout=None)
        except TransportError:
            return                      # parent went away: exit, engine closes
        seq, op = frame.get("seq"), frame.get("op")
        if op == "shutdown":
            try:
                chan.send({"seq": seq, "ok": True, "val": None})
            except TransportError:
                pass                    # parent may already be gone
            return
        try:
            val = _dispatch(host, op, frame.get("args") or {})
            chan.send({"seq": seq, "ok": True, "val": val})
        except TransportError:
            return
        except Exception as e:          # application error: reply, keep serving
            try:
                chan.send({"seq": seq, "ok": False,
                           "etype": type(e).__name__, "err": str(e)})
            except TransportError:
                return


def _dispatch(host, op: str, args: dict):
    from repro.serving.sampling import sampling_from_wire
    if op == "would_accept":
        return host.would_accept(int(args["plen"]), int(args["gen"]))
    if op == "lease_headroom":
        return host.lease_headroom(int(args["plen"]), int(args["gen"]))
    if op == "load":
        return host.load()
    if op == "submit":
        return host.submit(
            args["prompt"], int(args["gen"]),
            sampling=sampling_from_wire(args.get("sampling")),
            stop_history=tuple(int(t) for t in args.get("stop_history", ())),
            want_logprobs=args.get("want_logprobs"))
    if op == "poll":
        cursors = {int(k): int(v)
                   for k, v in (args.get("cursors") or {}).items()}
        return host.poll(cursors, drop=args.get("drop") or ())
    if op == "has_work":
        return host.has_work()
    if op == "evict_queued":
        return host.evict_queued(args.get("ids") or ())
    if op == "inflight":
        return host.inflight()
    if op == "preempt":
        return host.preempt(int(args["id"]))
    if op == "ship_blocks":
        return host.ship_blocks(int(args["id"]))
    if op == "recv_blocks":
        return host.recv_blocks(args["entry"])
    if op == "ack_ship":
        return host.ack_ship(args["payload_id"])
    if op == "embed":
        return host.embed(args["prompt"])
    if op == "stats":
        return host.stats()
    if op == "probe":
        return host.probe()
    raise ValueError(f"unknown op {op!r}")


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--socket", required=True,
                        help="AF_UNIX socket path of the parent's listener")
    args = parser.parse_args(argv)
    return serve(args.socket)


if __name__ == "__main__":
    sys.exit(main())
