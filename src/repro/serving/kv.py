"""KVSlotManager: slot-granular ownership of the decode batch's cache.

The engine decodes a fixed ``n_slots``-row batch; each row ("slot") is leased
to one in-flight request. This manager owns the backing cache pytree
(``models/serve.py:init_cache`` with a per-slot index vector) and implements
the slot lifecycle:

  * allocate once — the arrays are created a single time (``alloc_count`` stays
    1); admit/retire never reallocates, they rewrite one batch row in place
    (a jitted ``dynamic_update_slice`` with the cache donated, so XLA aliases
    the buffers instead of copying the whole cache per admission)
  * ``write_slots(slots, kv, n_valid)`` on admit — scatter a fused-prefill
    K/V block (leaves (L, B, S_bucket, ...), models/serve.py
    ``prefill_with_cache``) into all leased rows with ONE jitted donated
    scatter per admission bucket; each row's pad tail is scrubbed back to the
    pristine pattern so the result is bit-equal to a replay-seeded row
  * ``write_slot(slot, cache)`` — single-row variant taking a full-length B=1
    cache (the replay-seeding reference path, now exercised only by tests)
  * ``reset_slot(slot)`` on retire — restore the row to its pristine init
    state (zero k/v, 1e-12 scales, index 0) so the next lease starts clean

Leaf layout (dense/moe/vlm): k/v (L, B, S, KV, hd) and scales (L, B, S, KV)
carry the slot on axis 1; the index vector (B,) carries it on axis 0.
"""

from __future__ import annotations

import functools
from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import serve as SV


@functools.partial(jax.jit, donate_argnums=(0,))
def _write_row(cache: Dict, row: Dict, slot, n_valid) -> Dict:
    """Write one slot's row (B=1 leaves) + its index into the cache. The cache
    is donated: XLA updates the buffers in place, O(row) not O(cache)."""
    out = {}
    for name, leaf in cache.items():
        if name == "index":
            out[name] = jax.lax.dynamic_update_slice(
                leaf, jnp.asarray([n_valid], jnp.int32), (slot,))
        else:
            out[name] = jax.lax.dynamic_update_slice(
                leaf, row[name].astype(leaf.dtype),
                (0, slot) + (0,) * (leaf.ndim - 2))
    return out


@functools.partial(jax.jit, donate_argnums=(0,))
def _scatter_rows(cache: Dict, kv: Dict, slots, n_valid) -> Dict:
    """Batched admission write: scatter per-layer K/V blocks (L, B, Sb, ...)
    into rows ``slots`` (B,) of the cache, set each row's index to its prompt
    length, and scrub everything at/after position n_valid[i] back to the
    pristine pattern (k/v -> 0, scales -> 1e-12) so an admitted row is
    bit-equal to a replay-seeded one. One donated scatter for the whole
    bucket batch — O(B rows), never O(cache)."""
    Sb = kv["k"].shape[2]
    out = {}
    for name, leaf in cache.items():
        if name == "index":
            out[name] = leaf.at[slots].set(n_valid)
            continue
        S = leaf.shape[2]
        src = kv[name].astype(leaf.dtype)
        if S > Sb:  # pad the bucket block out to the row length
            src = jnp.pad(src, [(0, 0), (0, 0), (0, S - Sb)]
                          + [(0, 0)] * (src.ndim - 3))
        valid = jnp.arange(S)[None, :] < n_valid[:, None]          # (B, S)
        valid = valid.reshape(valid.shape + (1,) * (src.ndim - 3))
        pristine = 1e-12 if name.endswith("_scale") else 0
        src = jnp.where(valid, src, jnp.asarray(pristine, leaf.dtype))
        out[name] = leaf.at[:, slots].set(src)
    return out


class KVSlotManager:
    def __init__(self, cfg: ArchConfig, n_slots: int, max_seq_len: int):
        if cfg.family not in ("dense", "moe", "vlm"):
            raise ValueError(
                f"KVSlotManager supports dense-family caches, not {cfg.family}")
        self.cfg = cfg
        self.n_slots = n_slots
        self.max_seq_len = max_seq_len
        self.cache: Dict = SV.init_cache(cfg, n_slots, max_seq_len,
                                         per_slot_index=True)
        self.alloc_count = 1
        # Pristine single-slot row, captured before any write (functional
        # updates never mutate it): reset_slot copies it back into a retired
        # row. Kept with a size-1 batch axis, the _write_row layout. The
        # explicit copy matters: with n_slots == 1 the slice is full-extent
        # and JAX would alias the cache buffer, which donation then deletes.
        self._empty_row = {name: jnp.array(leaf[:, :1], copy=True)
                           for name, leaf in self.cache.items()
                           if name != "index"}

    # ------------------------------------------------------------- lifecycle

    def write_slots(self, slots, kv: Dict, n_valid) -> None:
        """Lease ``slots`` (B,) to the requests of one admission bucket: one
        batched donated scatter of the fused-prefill K/V block (leaves
        (L, B, S_bucket, ...)) into the leased rows + their index entries.
        Pad positions (>= each row's prompt length) are scrubbed to pristine,
        so the written rows are bit-equal to replay-seeded ones."""
        slots = jnp.asarray(slots, jnp.int32)
        n_valid = jnp.asarray(n_valid, jnp.int32)
        assert slots.shape == n_valid.shape and slots.ndim == 1
        self.cache = _scatter_rows(self.cache, kv, slots, n_valid)

    def write_slot(self, slot: int, src_cache: Dict, n_valid: int) -> None:
        """Lease ``slot`` to a request: copy a single-request (B=1) cache —
        same seq length, scalar index — into the slot's row."""
        assert 0 <= slot < self.n_slots
        row = {name: src_cache[name] for name in self.cache if name != "index"}
        self.cache = _write_row(self.cache, row, jnp.int32(slot),
                                jnp.int32(n_valid))

    def reset_slot(self, slot: int) -> None:
        """Retire a request: scrub the row so tokens can never leak into the
        slot's next tenant, and park the index at 0."""
        assert 0 <= slot < self.n_slots
        self.cache = _write_row(self.cache, self._empty_row, jnp.int32(slot),
                                jnp.int32(0))

    def swap(self, new_cache: Dict) -> None:
        """Adopt the cache pytree returned by a decode step (the old buffers
        were donated to it)."""
        self.cache = new_cache

    # ------------------------------------------------------------------ info

    def slot_index(self, slot: int) -> int:
        return int(self.cache["index"][slot])

    def nbytes(self) -> int:
        return sum(leaf.size * leaf.dtype.itemsize
                   for leaf in jax.tree.leaves(self.cache))
