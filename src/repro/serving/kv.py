"""Deprecated shim: ``KVSlotManager`` moved behind the SlotStore protocol.

The slot-granular cache layer now lives in ``repro/serving/store.py`` —
:class:`~repro.serving.store.SlotStore` with three backends
(``ContiguousKVStore``, ``PagedKVStore``, ``RecurrentStateStore``) built via
``make_store(cfg, n_slots, max_seq_len, backend=...)``. ``KVSlotManager``
was exactly today's ``ContiguousKVStore``; this subclass keeps old imports
working (same constructor, same lifecycle methods incl. the ``reset_slot``
alias) and warns once per instantiation.
"""

from __future__ import annotations

import warnings

from repro.serving.store import ContiguousKVStore
from repro.serving.store import pristine_value  # noqa: F401  (old import site)


class KVSlotManager(ContiguousKVStore):
    """Deprecated alias of :class:`repro.serving.store.ContiguousKVStore`."""

    def __init__(self, cfg, n_slots: int, max_seq_len: int):
        warnings.warn(
            "KVSlotManager is deprecated: use repro.serving.store.make_store("
            "cfg, n_slots, max_seq_len, backend='contiguous') or "
            "ContiguousKVStore directly",
            DeprecationWarning, stacklevel=2)
        super().__init__(cfg, n_slots, max_seq_len)
