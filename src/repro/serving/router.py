"""Multi-host serving router: the OPQ placement policy, one level up.

GPTPU's runtime places tile instructions on the accelerator already holding
their input buffer (affinity) and falls back to the least-loaded lane
(core/opq.py ``_pick_lane``); Jouppi et al. make the same argument at rack
scale — serving utilization comes from scheduling work onto the accelerator
that already holds the data. This module applies that policy across
*simulated hosts*: a :class:`Router` fronts N :class:`~repro.serving.engine.
Engine` instances (one per host, each with its own OPQ runtime and SlotStore),
and places whole requests the way OPQ places instructions:

  * **cache-affinity placement** — requests carry an affinity key (an
    explicit ``session``, or a hash of the prompt ids); a key's requests pin
    to the host whose SlotStore served it last — the host holding its leased
    blocks — and the hit is counted exactly the way OPQ counts per-lane
    affinity (``stats()["router"]["placed"/"affinity_hits"]`` mirrors
    ``opq.stats["issued"/"affinity_hits"]``).
  * **load-aware spill** — when the pinned host cannot take the request NOW
    (paged block pool dry — ``Engine.lease_headroom`` — or its queue/door
    rejects), the router places it on the least-loaded accepting host
    instead of head-of-line blocking the fleet behind one dry pool, counts a
    ``spill``, and re-pins the key to where the blocks actually leased.
    First-seen keys go least-loaded, the OPQ FCFS fallback.
  * **drain/handoff** — ``drain(host)`` stops placing traffic on an engine
    and empties it without losing or changing a single token: queued
    requests are pulled (``Engine.evict_queued``) and re-placed verbatim;
    in-flight requests with more than ``handoff_threshold`` tokens left are
    preempted (``Engine.preempt``) and re-admitted on another host as a
    continuation — ``prompt + tokens generated so far`` through the normal
    fused prefill-with-cache seeding path, which is bit-identical to decode
    replay, so the stitched stream equals an undrained run bit-for-bit
    (asserted in tests/test_router.py). Short remainders just finish in
    place on the draining engine. Once ``is_drained``, the host can restart
    elastically and return via ``undrain``.

Determinism: every engine is batch-invariant (staggered == sequential,
engine.py) and greedy decode is a pure function of the token prefix, so ANY
placement — spills, handoffs, mid-run drains included — yields bit-identical
tokens to serving the same requests one at a time on a single engine. The
router can therefore never trade correctness for load balance.
"""

from __future__ import annotations

import dataclasses
import itertools
import zlib
from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.configs.base import ArchConfig
from repro.serving.engine import (
    Engine, EngineConfig, QueueFull, Request, RequestState,
)
from repro.serving.metrics import now
from repro.serving.sampling import SamplingParams


@dataclasses.dataclass(frozen=True)
class RouterConfig:
    """Fleet-level knobs (per-engine knobs stay in EngineConfig).

    n_hosts
        Engines the router fronts — one per simulated host, each with its
        own OPQ runtime and SlotStore.
    handoff_threshold
        ``drain(host)``: in-flight requests with MORE than this many tokens
        still to generate are preempted and re-admitted on another host;
        at/below it they finish on the draining engine (a handoff costs one
        continuation prefill — not worth it for a tail of a few tokens).
    """

    n_hosts: int = 2
    handoff_threshold: int = 4


@dataclasses.dataclass
class RouterRequest:
    """The fleet-level request: engine requests are per-segment internals
    (a handoff retires one and opens another); ``tokens`` is the stitched
    stream and ``hosts`` the placement trail (len > 1 == handed off)."""

    id: int
    prompt: np.ndarray
    max_new_tokens: int
    session: Optional[str]
    arrival_s: float
    tokens: List[int] = dataclasses.field(default_factory=list)
    hosts: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    finish_s: Optional[float] = None
    sampling: Optional[SamplingParams] = None   # rides every segment
    finish_reason: Optional[str] = None         # from the final segment

    @property
    def n_generated(self) -> int:
        return len(self.tokens)


class Router:
    """See module docstring. Typical use::

        router = Router(cfg, params, EngineConfig(max_slots=4),
                        RouterConfig(n_hosts=2))
        req = router.submit(prompt_ids, max_new_tokens=16, session="user-7")
        router.drain(0)                       # elastic restart of host 0
        router.run_until_complete()
        print(req.tokens, router.stats()["router"])
    """

    def __init__(self, cfg: ArchConfig, params,
                 engine_cfg: EngineConfig = None,
                 router_cfg: RouterConfig = None, *, draft_params=None):
        self.rcfg = router_cfg or RouterConfig()
        if self.rcfg.n_hosts < 1:
            raise ValueError(f"n_hosts must be >= 1, got {self.rcfg.n_hosts}")
        if self.rcfg.handoff_threshold < 0:
            raise ValueError("handoff_threshold must be >= 0")
        # one engine per host; compiled steps are shared across them via the
        # _jitted_steps cache, so N hosts costs N caches, not N XLA compiles.
        # ``draft_params`` (speculative decode) is shared the same way: every
        # host runs the same draft program over its own slot-synced store, so
        # a drain handoff lands on a host whose draft re-prefills the
        # continuation prompt like any other admission — lockstep by
        # construction, nothing draft-specific to hand off.
        self.engines: List[Engine] = [
            Engine(cfg, params, engine_cfg, draft_params=draft_params)
            for _ in range(self.rcfg.n_hosts)]
        self._draining: Set[int] = set()
        self._affinity: Dict[str, int] = {}        # key -> host of last lease
        self._live: Dict[Tuple[int, int], RouterRequest] = {}
        # rreq.id -> the engine Request of its CURRENT segment, so the serve
        # API can stream mid-segment tokens live (``progress``)
        self._segments: Dict[int, Request] = {}
        self._harvested: List[int] = [0] * self.rcfg.n_hosts
        self._req_ids = itertools.count()
        self.completed: List[RouterRequest] = []
        # the OPQ-shaped placement ledger: placed/affinity_hits is the
        # cross-host analog of opq.stats issued/affinity_hits
        self.counters: Dict[str, int] = {
            "placed": 0, "affinity_hits": 0, "spills": 0, "rejected": 0,
            "drains": 0, "handoffs": 0, "requeued": 0,
        }

    # ------------------------------------------------------------- placement

    def _key(self, prompt: np.ndarray, session: Optional[str]) -> str:
        """The affinity key: an explicit session pins a user's requests
        together; otherwise identical prompts hash together (prefix-cache
        affinity in spirit — the host already holds those K/V blocks)."""
        if session is not None:
            return f"s:{session}"
        return f"p:{zlib.crc32(np.ascontiguousarray(prompt).tobytes()):#x}"

    def _load(self, host: int) -> int:
        e = self.engines[host]
        return e.scheduler.queue_depth + e.scheduler.n_active

    def _place(self, key: str, prompt_len: int, max_new_tokens: int,
               exclude: Set[int] = frozenset()
               ) -> Optional[Tuple[int, bool, bool]]:
        """Pick a host for a request: pinned host first (affinity), else
        least-loaded accepting host (FCFS fallback; a bypassed pin counts as
        a spill). Returns (host, affinity_hit, spilled), or None when no
        host can ever take it. Mirrors opq.OPQ._pick_lane one level up."""
        alive = [h for h in range(self.rcfg.n_hosts)
                 if h not in self._draining and h not in exclude]
        if not alive:
            return None
        pinned = self._affinity.get(key)
        spilled = False
        if pinned is not None and pinned in alive:
            e = self.engines[pinned]
            if (e.would_accept(prompt_len, max_new_tokens)
                    and e.lease_headroom(prompt_len, max_new_tokens)):
                return pinned, True, False
            # the pinned host's pool is dry (or its door rejects): shed the
            # request rather than queue the fleet behind one host
            spilled = True
        accepting = [h for h in sorted(alive, key=self._load)
                     if self.engines[h].would_accept(prompt_len,
                                                     max_new_tokens)]
        if not accepting:
            return None
        # prefer a host that can lease immediately; fall back to queueing on
        # the least-loaded door if every pool is dry right now
        ready = [h for h in accepting
                 if self.engines[h].lease_headroom(prompt_len,
                                                   max_new_tokens)]
        pick = (ready or accepting)[0]
        if pick == pinned:
            # every pool is dry and the least-loaded door is the pin itself:
            # the request lands where its pin points, so the ledger records a
            # (queued) affinity hit, not a spill
            return pinned, True, False
        return pick, False, spilled

    def submit(self, prompt: Sequence[int], max_new_tokens: int, *,
               session: Optional[str] = None,
               sampling: Optional[SamplingParams] = None,
               strict: bool = False) -> Optional[RouterRequest]:
        """Place one request on the fleet. Returns the RouterRequest, or
        None when every host rejects it (QueueFull when ``strict``) — the
        same door contract as Engine.submit. ``sampling`` rides the request
        through every segment a drain/handoff opens, so a seeded stream
        stitches bit-identically to an undrained run."""
        prompt = np.asarray(prompt, dtype=np.int32).reshape(-1)
        key = self._key(prompt, session)
        placed = self._place(key, len(prompt), max_new_tokens)
        ereq = None
        if placed is not None:
            host, hit, spilled = placed
            ereq = self.engines[host].submit(prompt, max_new_tokens,
                                             sampling=sampling)
        if ereq is None:
            self.counters["rejected"] += 1
            if strict:
                raise QueueFull(
                    f"no host accepts prompt={len(prompt)} + "
                    f"gen={max_new_tokens} "
                    f"(draining={sorted(self._draining)})")
            return None
        self.counters["placed"] += 1
        self.counters["affinity_hits"] += int(hit)
        self.counters["spills"] += int(spilled)
        self._affinity[key] = host                 # pin to where the lease is
        rreq = RouterRequest(id=next(self._req_ids), prompt=prompt,
                             max_new_tokens=max_new_tokens, session=session,
                             arrival_s=now(), hosts=[host], sampling=sampling)
        self._live[(host, ereq.id)] = rreq
        self._segments[rreq.id] = ereq
        return rreq

    # ------------------------------------------------------------ drain/handoff

    def drain(self, host: int) -> None:
        """Stop admitting to ``host`` and empty it without losing a token:
        re-place its queued requests, hand off in-flight generations longer
        than ``handoff_threshold`` as continuations (``prompt + tokens so
        far`` re-admitted through the normal seeding path — bit-identical to
        not draining), and let short tails finish in place. The engine keeps
        stepping until its slots empty (``is_drained``); ``undrain`` returns
        it to the placement pool after an elastic restart."""
        if not 0 <= host < self.rcfg.n_hosts:
            raise ValueError(f"no host {host} (n_hosts={self.rcfg.n_hosts})")
        if host in self._draining:
            return
        self._draining.add(host)
        self.counters["drains"] += 1
        eng = self.engines[host]
        # queued requests hold no cache state: re-place them verbatim. A
        # request no other host can take goes back to the draining engine's
        # queue — drain blocks NEW traffic, not work already accepted.
        for ereq in eng.evict_queued():
            rreq = self._live.pop((host, ereq.id), None)
            if rreq is None:
                # submitted to the engine directly, not router-placed: put it
                # back in the engine's own queue untouched (same Request
                # object, so the direct caller's handle still completes)
                ereq.state = RequestState.QUEUED
                eng.scheduler.enqueue(ereq)
                continue
            self._reroute(rreq, np.asarray(ereq.prompt),
                          ereq.max_new_tokens, fallback=eng)
        # in-flight: hand off the long generations, finish the short tails
        for slot in sorted(eng.scheduler.active):
            ereq = eng.scheduler.active[slot]
            rreq = self._live.get((host, ereq.id))
            if rreq is None:
                continue                           # direct submit: finish here
            remaining = ereq.max_new_tokens - len(ereq.tokens)
            if remaining <= self.rcfg.handoff_threshold:
                continue
            done_tokens = rreq.tokens + ereq.tokens
            cont_prompt = np.concatenate(
                [rreq.prompt, np.asarray(done_tokens, np.int32)])
            target = self._place(self._key(rreq.prompt, rreq.session),
                                 len(cont_prompt), remaining,
                                 exclude={host})
            if target is None:
                continue                           # nowhere to go: finish here
            eng.preempt(ereq.id)
            del self._live[(host, ereq.id)]
            rreq.tokens.extend(ereq.tokens)
            self._submit_segment(rreq, target[0], cont_prompt, remaining)
            self.counters["handoffs"] += 1

    def _reroute(self, rreq: RouterRequest, prompt: np.ndarray,
                 max_new_tokens: int, fallback: Engine) -> None:
        placed = self._place(self._key(rreq.prompt, rreq.session),
                             len(prompt), max_new_tokens)
        host = (self.engines.index(fallback) if placed is None
                else placed[0])
        self._submit_segment(rreq, host, prompt, max_new_tokens)
        self.counters["requeued"] += 1

    def _submit_segment(self, rreq: RouterRequest, host: int,
                        prompt: np.ndarray, max_new_tokens: int) -> None:
        # sampling params survive the handoff, and the new segment's stop
        # matcher sees the tokens earlier segments generated (stop_history)
        # — position-counter randomness makes the stitched seeded stream
        # bit-identical to the undrained one (tests/test_sampling.py)
        ereq = self.engines[host].submit(
            prompt, max_new_tokens, sampling=rreq.sampling,
            stop_history=tuple(rreq.tokens), strict=True)
        self._live[(host, ereq.id)] = rreq
        self._segments[rreq.id] = ereq
        rreq.hosts.append(host)
        self._affinity[self._key(rreq.prompt, rreq.session)] = host

    def is_drained(self, host: int) -> bool:
        """Draining AND empty — safe to restart the host process."""
        return host in self._draining and not self.engines[host].has_work()

    def undrain(self, host: int) -> None:
        """Return a (restarted) host to the placement pool."""
        self._draining.discard(host)

    # --------------------------------------------------------------- stepping

    def step(self) -> None:
        """One fleet iteration: step every engine that has work (draining
        engines included — they must finish what they hold), then harvest
        completions into the fleet-level requests."""
        for host, eng in enumerate(self.engines):
            if eng.has_work():
                eng.step()
            self._harvest(host)

    def _harvest(self, host: int) -> None:
        eng = self.engines[host]
        while self._harvested[host] < len(eng.completed):
            ereq = eng.completed[self._harvested[host]]
            self._harvested[host] += 1
            rreq = self._live.pop((host, ereq.id), None)
            if rreq is None:
                continue                   # not router-placed (direct submit)
            rreq.tokens.extend(ereq.tokens)
            rreq.done = True
            rreq.finish_s = now()
            rreq.finish_reason = ereq.finish_reason
            self._segments.pop(rreq.id, None)
            self.completed.append(rreq)

    def progress(self, rreq: RouterRequest) -> List[int]:
        """The stitched token stream INCLUDING the live segment's tokens —
        what an SSE streamer polls between fleet steps. ``rreq.tokens``
        alone only advances at segment boundaries (handoff/finish)."""
        seg = self._segments.get(rreq.id)
        if seg is None or rreq.done:
            return list(rreq.tokens)
        return list(rreq.tokens) + list(seg.tokens)

    def embed(self, prompt: Sequence[int]) -> Dict[str, np.ndarray]:
        """Non-generative forward on the least-loaded non-draining host —
        embeddings/classification never lease a slot, so placement is pure
        load balancing (no affinity to honour)."""
        alive = [h for h in range((self.rcfg.n_hosts))
                 if h not in self._draining]
        if not alive:
            raise RuntimeError("every host is draining — no embed capacity")
        return self.engines[min(alive, key=self._load)].embed(prompt)

    def has_work(self) -> bool:
        return any(e.has_work() for e in self.engines)

    def run_until_complete(self, max_steps: int = 100_000
                           ) -> List[RouterRequest]:
        steps = 0
        while self.has_work():
            self.step()
            steps += 1
            if steps > max_steps:
                raise RuntimeError(
                    f"fleet did not drain in {max_steps} steps")
        return self.completed

    # ---------------------------------------------------------------- summary

    def stats(self) -> Dict:
        """Fleet telemetry, three levels down: ``router`` (the placement
        ledger — placed/affinity_hits/spills in the OPQ per-lane shape, plus
        drain/handoff counts), ``fleet`` (engine counters summed across
        hosts), and ``per_host`` (each engine's full ``stats()``, its own
        OPQ affinity/backup counters included)."""
        per_host = [e.stats() for e in self.engines]
        fleet_keys = ("submitted", "rejected", "admissions_deferred",
                      "evicted", "preempted", "completed", "tokens_generated",
                      "decode_steps", "prefill_batches", "prefill_tokens",
                      "spec_rounds", "draft_steps", "proposed_tokens",
                      "accepted_tokens", "sampled_tokens", "stop_hits",
                      "embed_requests")
        fleet = {k: sum(h[k] for h in per_host) for k in fleet_keys}
        # fleet rate over the FLEET's first->last token span — summing
        # per-host rates would overstate it whenever host spans differ
        # (e.g. a host drained early has a short span and a high rate)
        firsts = [e.metrics.first_token_s for e in self.engines
                  if e.metrics.first_token_s is not None]
        lasts = [e.metrics.last_token_s for e in self.engines
                 if e.metrics.last_token_s is not None]
        span = (max(lasts) - min(firsts)) if firsts else 0.0
        fleet["sustained_tok_s"] = (
            fleet["tokens_generated"] / span if span > 0
            else float("inf") if fleet["tokens_generated"] else 0.0)
        return {
            "router": dict(self.counters, hosts=self.rcfg.n_hosts,
                           draining=sorted(self._draining),
                           completed=len(self.completed)),
            "fleet": fleet,
            "per_host": per_host,
        }

    def close(self) -> None:
        for e in self.engines:
            e.close()
