"""Multi-host serving router: the OPQ placement policy over real transports.

GPTPU's runtime places tile instructions on the accelerator already holding
their input buffer (affinity) and falls back to the least-loaded lane
(core/opq.py ``_pick_lane``); Jouppi et al. make the same argument at rack
scale — serving utilization comes from scheduling work onto the accelerator
that already holds the data. This module applies that policy across hosts:
a :class:`Router` fronts N hosts behind the
:class:`~repro.serving.transport.HostTransport` protocol — in-process
engines (the default, ``build_inproc_fleet``) or one OS process per host
(``SubprocessTransport``) — and places whole requests the way OPQ places
instructions:

  * **cache-affinity placement** — requests carry an affinity key (an
    explicit ``session``, or a hash of the prompt ids); a key's requests pin
    to the host whose slot pool served it last — the host holding its leased
    blocks — and the hit is counted exactly the way OPQ counts per-lane
    affinity (``stats()["router"]["placed"/"affinity_hits"]``).
  * **load-aware spill** — when the pinned host cannot take the request NOW
    (paged block pool dry — ``lease_headroom`` — or its queue/door rejects),
    the router places it on the least-loaded accepting host instead of
    head-of-line blocking the fleet behind one dry pool, counts a ``spill``,
    and re-pins the key to where the blocks actually leased. First-seen keys
    go least-loaded, the OPQ FCFS fallback. The door predicates are
    advisory: admission races with other traffic (and, on subprocess hosts,
    with the worker's own free-running loop), so a candidate whose door
    closed between ``would_accept`` and ``submit`` is simply skipped and the
    next candidate tried — the ledger records the host that actually took
    the request.
  * **drain/handoff** — ``drain(host)`` stops placing traffic on a host and
    empties it without losing or changing a single token: queued requests
    are pulled (``evict_queued``) and re-placed verbatim; in-flight requests
    with more than ``handoff_threshold`` tokens left are preempted
    (``preempt`` returns the authoritative segment state) and re-admitted on
    another host as a continuation — ``prompt + tokens generated so far``
    through the normal fused prefill-with-cache seeding path, which is
    bit-identical to decode replay, so the stitched stream equals an
    undrained run bit-for-bit (asserted in tests/test_router.py). Short
    remainders just finish in place on the draining host. Once
    ``is_drained``, the host can restart elastically and return via
    ``undrain``.
  * **loss recovery** — a transport failure (timeout, dead worker process)
    marks the host LOST: it leaves the placement pool, its queued and
    in-flight requests are re-admitted elsewhere as continuations from the
    tokens already *harvested* (a token only counts as emitted once a
    ``poll`` returned it), and requests no surviving host can take yet wait
    as orphans retried every step. Because decode is deterministic, the
    replacement segment regenerates exactly the tokens that died un-polled
    in the lost process — the stream stays bit-identical and never
    double-emits (tests/test_transport.py kills a worker with SIGKILL
    mid-decode and asserts exactly this).

  * **prefill/decode disaggregation** — ``RouterConfig.roles`` (built by
    :func:`parse_disaggregate` from a ``prefill:N,decode:M`` spec) splits
    the fleet: admissions (and every re-prefill fallback) place only on
    prefill-role hosts, and once a stream's prefill has finished its exact
    cache blocks are SHIPPED to a decode-role host over the transport
    (``ship_blocks``/``recv_blocks``/``ack_ship``) — decode hosts never
    dispatch a prefill instruction, so long-prompt admission work stops
    head-of-line-blocking the decode batch (the GPTPU role-matching thesis
    at fleet scale). Shipped blocks carry exact cache bits, so the handed-
    off stream is bit-identical to never having moved; a failed ship falls
    back to the re-prefill continuation path, which stays the oracle.

Determinism: every host is batch-invariant (staggered == sequential) and
greedy/seeded decode is a pure function of the token prefix, so ANY
placement — spills, handoffs, mid-run drains, even crash re-admissions —
yields bit-identical tokens to serving the same requests one at a time on a
single host. The router can therefore never trade correctness for load
balance or availability.
"""

from __future__ import annotations

import dataclasses
import itertools
import zlib
from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.configs.base import ArchConfig
from repro.serving.metrics import now
from repro.serving.sampling import SamplingParams
from repro.serving.transport import (
    EngineConfig, HostTransport, QueueFull, TransportError,
    build_inproc_fleet,
)


@dataclasses.dataclass(frozen=True)
class RouterConfig:
    """Fleet-level knobs (per-host knobs stay in EngineConfig).

    n_hosts
        Hosts the router fronts — one transport per host, each fronting an
        engine with its own OPQ runtime and slot pool. Ignored when an
        explicit ``transports`` fleet is handed to the Router.
    handoff_threshold
        ``drain(host)``: in-flight requests with MORE than this many tokens
        still to generate are preempted and re-admitted on another host;
        at/below it they finish on the draining host (a handoff costs one
        continuation prefill — not worth it for a tail of a few tokens).
        Under disaggregation the same threshold gates block shipping: a
        remainder at/below it finishes on its prefill host.
    roles
        Prefill/decode disaggregation (``parse_disaggregate`` builds this
        from a ``--disaggregate prefill:N,decode:M`` spec): one role per
        host. ``prefill`` hosts take every admission (fused/chunked prefill
        AND the re-prefill fallback); ``decode`` hosts ONLY ever receive
        shipped cache blocks and run the decode step — their OPQ flag audit
        stays free of prefill instructions by construction. None (default)
        disables role splitting: every host does both, exactly the pre-10
        fleet.
    ships_per_step
        Ship pacing: at most this many block-ship import attempts per fleet
        step. A ship is a synchronous export->wire->import leg inside the
        step loop, so an unpaced burst (every stream of a fresh mix turning
        eligible at once) would stall harvesting — and therefore every
        OTHER stream's observed inter-token latency — for the whole burst.
        Streams past the budget simply keep decoding on their prefill host
        until a later step ships them.
    """

    n_hosts: int = 2
    handoff_threshold: int = 4
    roles: Optional[Tuple[str, ...]] = None
    ships_per_step: int = 1


# refused imports (decode-side slot/lease backpressure) tolerated before a
# parked ship gives up and falls back to re-prefill. Refusals are capacity
# signals, not errors — a decode host refusing now admits once its streams
# drain (tens of steps for a full slot set), so this is a wedged-host
# safety valve, sized far above any healthy drain, not a fast-fail knob:
# the fallback recomputes the prefill, which costs the bit-identity the
# ship existed to preserve.
_MAX_SHIP_TRIES = 256


def parse_disaggregate(spec: str, n_hosts: int) -> Tuple[str, ...]:
    """``--disaggregate`` spec -> per-host role tuple, prefill hosts first.
    Accepts ``prefill:N,decode:M`` or the shorthand ``N:M``; N + M must
    equal the fleet size and each role needs at least one host."""
    counts = {"prefill": 0, "decode": 0}
    parts = [p.strip() for p in spec.split(",") if p.strip()]
    try:
        if (len(parts) == 1 and ":" in parts[0]
                and parts[0].split(":")[0].strip().isdigit()):
            n, m = parts[0].split(":")
            counts["prefill"], counts["decode"] = int(n), int(m)
        else:
            for part in parts:
                role, n = part.split(":")
                counts[role.strip()] += int(n)
    except (KeyError, ValueError) as e:
        raise ValueError(
            f"--disaggregate expects 'prefill:N,decode:M' (or 'N:M'), "
            f"got {spec!r}") from e
    if counts["prefill"] < 1 or counts["decode"] < 1:
        raise ValueError(
            f"--disaggregate needs at least one host per role, got "
            f"prefill:{counts['prefill']},decode:{counts['decode']}")
    total = counts["prefill"] + counts["decode"]
    if total != n_hosts:
        raise ValueError(
            f"--disaggregate assigns {total} hosts but the fleet has "
            f"{n_hosts}")
    return (("prefill",) * counts["prefill"]
            + ("decode",) * counts["decode"])


@dataclasses.dataclass
class RouterRequest:
    """The fleet-level request: per-host requests are per-segment internals
    (a handoff retires one and opens another); ``tokens`` is the stitched
    stream and ``hosts`` the placement trail (len > 1 == handed off).
    ``tokens`` advances as the router harvests (``poll``) — it is the
    caller-visible truth; un-harvested tokens on a host are provisional and
    regenerated exactly if that host dies."""

    id: int
    prompt: np.ndarray
    max_new_tokens: int
    session: Optional[str]
    arrival_s: float
    tokens: List[int] = dataclasses.field(default_factory=list)
    # worker-side emission time of each token (engine-stamped, monotonic
    # epoch — see transport poll's "ts"): honest inter-token gaps even when
    # a free-running worker's tokens reach the router in one burst
    token_ts: List[float] = dataclasses.field(default_factory=list)
    hosts: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    finish_s: Optional[float] = None
    sampling: Optional[SamplingParams] = None   # rides every segment
    finish_reason: Optional[str] = None         # from the final segment
    want_logprobs: Optional[int] = None         # rides every segment
    logprobs: List[float] = dataclasses.field(default_factory=list)
    top_logprobs: List[List[Tuple[int, float]]] = dataclasses.field(
        default_factory=list)

    @property
    def n_generated(self) -> int:
        return len(self.tokens)


# per-host stats substitute once a host is lost: zeros for everything the
# fleet sums, so aggregation degrades instead of crashing
_FLEET_KEYS = ("submitted", "rejected", "admissions_deferred",
               "evicted", "preempted", "completed", "tokens_generated",
               "decode_steps", "prefill_batches", "prefill_tokens",
               "spec_rounds", "draft_steps", "proposed_tokens",
               "accepted_tokens", "sampled_tokens", "stop_hits",
               "embed_requests")


class Router:
    """See module docstring. Typical use::

        router = Router(cfg, params, EngineConfig(max_slots=4),
                        RouterConfig(n_hosts=2))
        req = router.submit(prompt_ids, max_new_tokens=16, session="user-7")
        router.drain(0)                       # elastic restart of host 0
        router.run_until_complete()
        print(req.tokens, router.stats()["router"])

    or, with real host processes::

        fleet = [SubprocessTransport(model_spec, engine_cfg)
                 for _ in range(2)]
        router = Router(transports=fleet)
    """

    def __init__(self, cfg: ArchConfig = None, params=None,
                 engine_cfg: EngineConfig = None,
                 router_cfg: RouterConfig = None, *, draft_params=None,
                 transports: Optional[Sequence[HostTransport]] = None):
        self.rcfg = router_cfg or RouterConfig()
        if transports is not None:
            # an explicit fleet sets its own size
            self.rcfg = dataclasses.replace(self.rcfg,
                                            n_hosts=len(transports))
        if self.rcfg.n_hosts < 1:
            raise ValueError(f"n_hosts must be >= 1, got {self.rcfg.n_hosts}")
        if self.rcfg.handoff_threshold < 0:
            raise ValueError("handoff_threshold must be >= 0")
        if self.rcfg.ships_per_step < 1:
            raise ValueError("ships_per_step must be >= 1")
        if self.rcfg.roles is not None:
            roles = tuple(self.rcfg.roles)
            if len(roles) != self.rcfg.n_hosts:
                raise ValueError(
                    f"roles assigns {len(roles)} hosts but the fleet has "
                    f"{self.rcfg.n_hosts}")
            bad = [r for r in roles if r not in ("prefill", "decode")]
            if bad:
                raise ValueError(f"unknown host roles {bad!r} (want "
                                 f"'prefill' or 'decode')")
            if "prefill" not in roles or "decode" not in roles:
                raise ValueError(
                    "disaggregation needs at least one prefill host and "
                    "one decode host")
            self.rcfg = dataclasses.replace(self.rcfg, roles=roles)
        if transports is None:
            transports = build_inproc_fleet(cfg, params, engine_cfg,
                                            self.rcfg.n_hosts,
                                            draft_params=draft_params)
        self.transports: List[HostTransport] = list(transports)
        self._draining: Set[int] = set()
        self._lost: Set[int] = set()
        self._affinity: Dict[str, int] = {}        # key -> host of last lease
        # (host, per-host request id) -> fleet request, with a harvest cursor
        # (tokens already polled off that segment) per live placement
        self._live: Dict[Tuple[int, int], RouterRequest] = {}
        self._cursor: Dict[Tuple[int, int], int] = {}
        # finished ids each host should forget, shipped with the next poll
        self._drop: List[List[int]] = [[] for _ in range(self.rcfg.n_hosts)]
        # requests from a lost (or mid-drain-rejected) host awaiting a
        # surviving host with capacity; retried every step
        self._orphans: List[RouterRequest] = []
        # shipped-but-unimported block payloads awaiting decode-host
        # capacity; retried every step (the recv is idempotent, the source's
        # export-ledger hold stays open until the outcome settles)
        self._ship_parked: List[Dict] = []
        self._req_ids = itertools.count()
        self.completed: List[RouterRequest] = []
        # the OPQ-shaped placement ledger: placed/affinity_hits is the
        # cross-host analog of opq issued/affinity_hits
        self.counters: Dict[str, int] = {
            "placed": 0, "affinity_hits": 0, "spills": 0, "rejected": 0,
            "drains": 0, "handoffs": 0, "requeued": 0,
            "hosts_lost": 0, "recovered": 0,
            "ships": 0, "shipped_blocks": 0, "ship_fallbacks": 0,
        }

    @property
    def engines(self):
        """The underlying engines of an in-process fleet — test/debug access
        only (raises AttributeError on transports without one, e.g. a real
        host process, where there is no same-address-space engine to hand
        out)."""
        return [t.engine for t in self.transports]

    # ------------------------------------------------------------- transport

    def _guard(self, host: int, fn, *args, default=None, **kwargs):
        """Run one transport call; a transport-level failure marks the host
        LOST (re-placing its work) and returns ``default`` so fleet-level
        control flow degrades instead of unwinding."""
        try:
            return fn(*args, **kwargs)
        except TransportError:
            self._mark_lost(host)
            return default

    def _mark_lost(self, host: int) -> None:
        """Host-loss recovery: pull the host from the placement pool, close
        its transport (reaping a dead worker — no orphan processes), and
        re-admit every request it owned as a continuation from the tokens
        already harvested. Determinism regenerates the un-harvested tail
        exactly, so the recovered stream is bit-identical and nothing
        double-emits."""
        if host in self._lost:
            return
        self._lost.add(host)
        self.counters["hosts_lost"] += 1
        try:
            self.transports[host].close()
        except Exception:
            pass
        self._drop[host] = []
        for key in [k for k in self._live if k[0] == host]:
            rreq = self._live.pop(key)
            self._cursor.pop(key, None)
            if rreq.max_new_tokens - len(rreq.tokens) <= 0:
                # every token was already harvested; only the final done
                # frame died with the host
                self._finalize(rreq, rreq.finish_reason or "length")
                continue
            if not self._readmit(rreq):
                self._orphans.append(rreq)

    def _readmit(self, rreq: RouterRequest) -> bool:
        """Re-admit a disrupted request as a continuation on any surviving
        host; False leaves it an orphan for the next step's retry."""
        remaining = rreq.max_new_tokens - len(rreq.tokens)
        cont_prompt = np.concatenate(
            [rreq.prompt, np.asarray(rreq.tokens, np.int32)]
        ) if rreq.tokens else rreq.prompt
        placed = self._place(self._key(rreq.prompt, rreq.session),
                             len(cont_prompt), remaining)
        if placed is None:
            return False
        if not self._submit_segment(rreq, placed[0], cont_prompt, remaining):
            return False
        self.counters["recovered"] += 1
        return True

    def _finalize(self, rreq: RouterRequest, reason: Optional[str]) -> None:
        rreq.done = True
        rreq.finish_s = now()
        rreq.finish_reason = reason
        self.completed.append(rreq)

    # ------------------------------------------------------------- placement

    def _key(self, prompt: np.ndarray, session: Optional[str]) -> str:
        """The affinity key: an explicit session pins a user's requests
        together; otherwise identical prompts hash together (prefix-cache
        affinity in spirit — the host already holds those K/V blocks)."""
        if session is not None:
            return f"s:{session}"
        return f"p:{zlib.crc32(np.ascontiguousarray(prompt).tobytes()):#x}"

    def _load(self, host: int) -> int:
        return self._guard(host, self.transports[host].load, default=1 << 30)

    def _alive(self, exclude: Set[int] = frozenset()) -> List[int]:
        return [h for h in range(self.rcfg.n_hosts)
                if h not in self._draining and h not in self._lost
                and h not in exclude]

    def _admitting(self, exclude: Set[int] = frozenset()) -> List[int]:
        """Hosts eligible for ADMISSION placement: alive, and under
        disaggregation never a decode-role host. Admission dispatches
        prefill — and so does every fallback (re-prefill continuation,
        orphan re-admission), so routing them all through this filter is
        what keeps a decode host's OPQ flag audit prefill-free no matter
        which failure path ran."""
        alive = self._alive(exclude)
        if self.rcfg.roles is None:
            return alive
        return [h for h in alive if self.rcfg.roles[h] == "prefill"]

    def _place(self, key: str, prompt_len: int, max_new_tokens: int,
               exclude: Set[int] = frozenset()
               ) -> Optional[Tuple[int, bool, bool]]:
        """Pick a host for a request: pinned host first (affinity), else
        least-loaded accepting host (FCFS fallback; a bypassed pin counts as
        a spill). Returns (host, affinity_hit, spilled), or None when no
        host can ever take it. Mirrors opq lane-picking one level up."""
        alive = self._admitting(exclude)
        if not alive:
            return None
        pinned = self._affinity.get(key)
        spilled = False
        if pinned is not None and pinned in alive:
            t = self.transports[pinned]
            if (self._guard(pinned, t.would_accept, prompt_len,
                            max_new_tokens, default=False)
                    and self._guard(pinned, t.lease_headroom, prompt_len,
                                    max_new_tokens, default=False)):
                return pinned, True, False
            # the pinned host's pool is dry (or its door rejects): shed the
            # request rather than queue the fleet behind one host
            spilled = pinned not in self._lost
        alive = self._admitting(exclude)       # a probe may have lost a host
        accepting = [h for h in sorted(alive, key=self._load)
                     if self._guard(h, self.transports[h].would_accept,
                                    prompt_len, max_new_tokens,
                                    default=False)]
        if not accepting:
            return None
        # prefer a host that can lease immediately; fall back to queueing on
        # the least-loaded door if every pool is dry right now
        ready = [h for h in accepting
                 if self._guard(h, self.transports[h].lease_headroom,
                                prompt_len, max_new_tokens, default=False)]
        pick = (ready or accepting)[0]
        if pick == pinned:
            # every pool is dry and the least-loaded door is the pin itself:
            # the request lands where its pin points, so the ledger records a
            # (queued) affinity hit, not a spill
            return pinned, True, False
        return pick, False, spilled

    def submit(self, prompt: Sequence[int], max_new_tokens: int, *,
               session: Optional[str] = None,
               sampling: Optional[SamplingParams] = None,
               want_logprobs: Optional[int] = None,
               strict: bool = False) -> Optional[RouterRequest]:
        """Place one request on the fleet. Returns the RouterRequest, or
        None when every host rejects it (QueueFull when ``strict``) — the
        same door contract as the engine's own submit. ``sampling`` and
        ``want_logprobs`` ride the request through every segment a
        drain/handoff opens, so a seeded stream stitches bit-identically to
        an undrained run.

        The door predicates in ``_place`` are a snapshot, not a lease:
        another submit (or, on subprocess hosts, the worker's own loop) can
        consume the capacity between ``would_accept`` and ``submit``. A
        candidate whose door closed in that window returns None from submit
        and the NEXT candidate is re-validated and tried — never a
        spurious fleet-level rejection while some host still accepts."""
        prompt = np.asarray(prompt, dtype=np.int32).reshape(-1)
        key = self._key(prompt, session)
        tried: Set[int] = set()
        host = eid = None
        hit = spilled = False
        while True:
            placed = self._place(key, len(prompt), max_new_tokens,
                                 exclude=tried)
            if placed is None:
                break
            host, hit, spilled = placed
            eid = self._guard(host, self.transports[host].submit,
                              prompt, max_new_tokens, sampling=sampling,
                              want_logprobs=want_logprobs)
            if eid is not None:
                break
            tried.add(host)                # door closed since the probe —
            host = None                    # re-validate the next candidate
        if eid is None or host is None:
            self.counters["rejected"] += 1
            if strict:
                raise QueueFull(
                    f"no host accepts prompt={len(prompt)} + "
                    f"gen={max_new_tokens} "
                    f"(draining={sorted(self._draining)})")
            return None
        self.counters["placed"] += 1
        self.counters["affinity_hits"] += int(hit)
        self.counters["spills"] += int(spilled)
        self._affinity[key] = host                 # pin to where the lease is
        rreq = RouterRequest(id=next(self._req_ids), prompt=prompt,
                             max_new_tokens=max_new_tokens, session=session,
                             arrival_s=now(), hosts=[host], sampling=sampling,
                             want_logprobs=want_logprobs)
        self._live[(host, eid)] = rreq
        self._cursor[(host, eid)] = 0
        return rreq

    # ------------------------------------------------------------ drain/handoff

    def drain(self, host: int) -> None:
        """Stop admitting to ``host`` and empty it without losing a token:
        re-place its queued requests, hand off in-flight generations longer
        than ``handoff_threshold`` as continuations (``prompt + tokens so
        far`` re-admitted through the normal seeding path — bit-identical to
        not draining), and let short tails finish in place. The host keeps
        stepping until its slots empty (``is_drained``); ``undrain`` returns
        it to the placement pool after an elastic restart."""
        if not 0 <= host < self.rcfg.n_hosts:
            raise ValueError(f"no host {host} (n_hosts={self.rcfg.n_hosts})")
        if host in self._draining:
            return
        self._draining.add(host)
        self.counters["drains"] += 1
        if host in self._lost:
            return                         # nothing left to empty
        t = self.transports[host]
        # sync the harvest mirror first so continuation prompts and the
        # handoff-threshold decision see every token the host emitted
        self._harvest(host)
        if host in self._lost:
            return
        # queued requests hold no cache state: re-place them verbatim. A
        # request no other host can take goes back to the draining host's
        # queue — drain blocks NEW traffic, not work already accepted.
        # Requests the router does not own (submitted to the engine
        # directly) re-enqueue on the host untouched — the host side of
        # evict_queued handles them (transport.EngineHost).
        owned = [eid for (h, eid) in self._live if h == host]
        for eid in self._guard(host, t.evict_queued, owned, default=[]):
            key = (host, eid)
            rreq = self._live.pop(key, None)
            if rreq is None:
                continue
            self._cursor.pop(key, None)
            self._reroute(rreq, fallback=host)
        if host in self._lost:
            return
        # in-flight: hand off the long generations, finish the short tails
        for entry in self._guard(host, t.inflight, default=[]):
            eid = int(entry["id"])
            key = (host, eid)
            rreq = self._live.get(key)
            if rreq is None:
                continue                   # not router-placed: finish here
            remaining = rreq.max_new_tokens - len(rreq.tokens)
            if remaining <= self.rcfg.handoff_threshold:
                continue
            target = self._place(self._key(rreq.prompt, rreq.session),
                                 len(rreq.prompt) + len(rreq.tokens),
                                 remaining, exclude={host})
            if target is None:
                continue                   # nowhere to go: finish here
            wire = self._guard(host, t.preempt, eid)
            if host in self._lost:
                return                     # loss recovery took over
            if wire is None:
                continue                   # finished meanwhile: next poll
            del self._live[key]
            cur = self._cursor.pop(key, 0)
            self._absorb_segment(rreq, wire, cur)
            remaining = rreq.max_new_tokens - len(rreq.tokens)
            if remaining <= 0:
                self._finalize(rreq, wire.get("finish_reason") or "length")
                continue
            cont_prompt = np.concatenate(
                [rreq.prompt, np.asarray(rreq.tokens, np.int32)])
            if self._submit_segment(rreq, target[0], cont_prompt, remaining):
                self.counters["handoffs"] += 1
            else:
                self._orphans.append(rreq)

    def _absorb_segment(self, rreq: RouterRequest, wire: Dict,
                        cursor: int) -> None:
        """Fold a preempted segment's authoritative wire state into the
        fleet request: everything past the harvest cursor (a free-running
        worker may be ahead of the last poll)."""
        absorbed = wire["tokens"][cursor:]
        rreq.tokens.extend(int(t) for t in absorbed)
        # the wire form carries no emission times; absorb time is the best
        # stand-in (preemption already interrupts the stream's cadence)
        rreq.token_ts.extend([now()] * len(absorbed))
        if rreq.want_logprobs is not None:
            rreq.logprobs.extend(float(v)
                                 for v in wire.get("logprobs", [])[cursor:])
            rreq.top_logprobs.extend(
                [(int(t), float(v)) for t, v in row]
                for row in wire.get("top_logprobs", [])[cursor:])

    def _reroute(self, rreq: RouterRequest, fallback: int) -> None:
        remaining = rreq.max_new_tokens - len(rreq.tokens)
        cont_prompt = np.concatenate(
            [rreq.prompt, np.asarray(rreq.tokens, np.int32)]
        ) if rreq.tokens else rreq.prompt
        placed = self._place(self._key(rreq.prompt, rreq.session),
                             len(cont_prompt), remaining)
        host = fallback if placed is None else placed[0]
        if not self._submit_segment(rreq, host, cont_prompt, remaining):
            self._orphans.append(rreq)
        self.counters["requeued"] += 1

    def _submit_segment(self, rreq: RouterRequest, host: int,
                        prompt: np.ndarray, max_new_tokens: int) -> bool:
        # sampling params survive the handoff, and the new segment's stop
        # matcher sees the tokens earlier segments generated (stop_history)
        # — position-counter randomness makes the stitched seeded stream
        # bit-identical to the undrained one (tests/test_sampling.py)
        eid = self._guard(host, self.transports[host].submit,
                          prompt, max_new_tokens, sampling=rreq.sampling,
                          stop_history=tuple(rreq.tokens),
                          want_logprobs=rreq.want_logprobs)
        if eid is None:
            return False
        self._live[(host, eid)] = rreq
        self._cursor[(host, eid)] = 0
        rreq.hosts.append(host)
        self._affinity[self._key(rreq.prompt, rreq.session)] = host
        return True

    def is_drained(self, host: int) -> bool:
        """Draining AND empty — safe to restart the host process. A lost
        host is vacuously drained (its work was re-placed)."""
        if host not in self._draining:
            return False
        if host in self._lost:
            return True
        return not self._guard(host, self.transports[host].has_work,
                               default=False)

    def undrain(self, host: int) -> None:
        """Return a (restarted) host to the placement pool."""
        self._draining.discard(host)

    # --------------------------------------------------------------- stepping

    def step(self) -> None:
        """One fleet iteration: pump every live host (one engine step for
        in-process hosts; a no-op for subprocess hosts, whose workers
        free-run), harvest new tokens and completions, and retry orphaned
        requests against recovered capacity. Draining hosts are pumped too —
        they must finish what they hold."""
        if self._orphans:
            pending, self._orphans = self._orphans, []
            for rreq in pending:
                if not self._readmit(rreq):
                    self._orphans.append(rreq)
        for host in range(self.rcfg.n_hosts):
            if host in self._lost:
                continue
            self._guard(host, self.transports[host].pump)
            if host in self._lost:
                continue
            self._harvest(host)
        if self.rcfg.roles is not None:
            self._disagg_handoff()

    def _disagg_handoff(self) -> None:
        """Move prefilled streams from prefill-role hosts onto decode-role
        hosts by SHIPPING their exact cache blocks over the transport — no
        recompute, so the continued stream is bit-identical to never having
        moved. A stream becomes eligible once its first token was harvested
        (its prefill is finished) and its remainder is worth the move
        (handoff_threshold); with no decode host holding lease headroom it
        simply keeps decoding where it is and is retried next step. A
        REFUSED import (a free-running decode worker won the slot/lease
        race between the headroom probe and the recv) is transient: the
        extracted payload parks and the recv retries next step — it is
        idempotent, so a retry never double-imports. Only a corrupt frame
        or ``_MAX_SHIP_TRIES`` consecutive refusals fall back to the PR 5
        re-prefill continuation path on a PREFILL host — the degenerate
        oracle — so decode hosts stay prefill-free no matter which leg
        fails; the source's export-ledger hold is released (``ack_ship``)
        once the outcome settles, on every path."""
        budget = self.rcfg.ships_per_step
        if self._ship_parked:
            parked, self._ship_parked = self._ship_parked, []
            for item in parked:
                if budget <= 0:
                    self._ship_parked.append(item)
                    continue
                status = self._recv_install(item["entry"], item["rreq"],
                                            item["src"])
                if status == "shipped":
                    budget -= 1
                    continue
                if status == "refused":
                    budget -= 1            # a recv attempt was spent;
                    item["tries"] += 1     # no-dst waits without burning
                                           # retries: capacity WILL free
                if (status in ("corrupt", "dead")
                        or item["tries"] > _MAX_SHIP_TRIES):
                    self._ship_fallback(item["rreq"], item["src"],
                                        item["entry"]["payload_id"])
                else:
                    self._ship_parked.append(item)
        src_keys = [k for k in self._live
                    if self.rcfg.roles[k[0]] == "prefill"]
        for key in src_keys:
            if budget <= 0:
                break                      # paced: the rest ship next steps
            host, eid = key
            rreq = self._live.get(key)
            if rreq is None or host in self._lost:
                continue
            if not rreq.tokens:
                continue                   # prefill not harvested yet
            remaining = rreq.max_new_tokens - len(rreq.tokens)
            if remaining <= self.rcfg.handoff_threshold:
                continue                   # short tail: finish in place
            if not self._ship_dsts(rreq):
                continue                   # no decode capacity right now
            t_src = self.transports[host]
            entry = self._guard(host, t_src.ship_blocks, eid)
            if host in self._lost:
                continue                   # loss recovery re-placed it
            if entry is None:
                continue                   # finished meanwhile: next poll
            # the stream is off the source engine now: fold its
            # authoritative segment state in before deciding where it lands
            del self._live[key]
            cur = self._cursor.pop(key, 0)
            wire = entry["wire"]
            pid = entry["payload_id"]
            self._absorb_segment(rreq, wire, cur)
            if rreq.max_new_tokens - len(rreq.tokens) <= 0:
                self._guard(host, t_src.ack_ship, pid)
                self._finalize(rreq, wire.get("finish_reason") or "length")
                continue
            status = self._recv_install(entry, rreq, host)
            budget -= 1
            if status == "corrupt":
                self._ship_fallback(rreq, host, pid)
            elif status != "shipped":
                self._ship_parked.append(
                    {"entry": entry, "rreq": rreq, "src": host, "tries": 1})

    def _ship_dsts(self, rreq: RouterRequest) -> List[int]:
        """Alive decode-role hosts with lease headroom for this stream."""
        return [h for h in self._alive()
                if self.rcfg.roles[h] == "decode"
                and self._guard(h, self.transports[h].lease_headroom,
                                len(rreq.prompt), rreq.max_new_tokens,
                                default=False)]

    def _recv_install(self, entry: Dict, rreq: RouterRequest,
                      src: int) -> str:
        """Offer a shipped payload to the least-loaded eligible decode host.
        Returns ``"shipped"`` (imported + installed, source hold acked),
        ``"refused"``/``"no-dst"`` (transient: park and retry), ``"dead"``
        (no decode host left alive: fall back now), or ``"corrupt"`` (the
        importer rejected the frame: fall back)."""
        alive = [h for h in self._alive()
                 if self.rcfg.roles[h] == "decode"]
        if not alive:
            return "dead"
        dsts = self._ship_dsts(rreq)
        if not dsts:
            return "no-dst"
        dst = min(dsts, key=self._load)
        try:
            new_id = self._guard(dst, self.transports[dst].recv_blocks,
                                 entry)
        except ValueError:
            return "corrupt"               # importer refused: bad frame
        if new_id is None:
            return "refused"               # slot/lease race: retry
        self._guard(src, self.transports[src].ack_ship,
                    entry["payload_id"])
        wire = entry["wire"]
        self._live[(dst, new_id)] = rreq
        self._cursor[(dst, new_id)] = len(wire["tokens"])
        rreq.hosts.append(dst)
        self.counters["ships"] += 1
        self.counters["shipped_blocks"] += int(entry["payload"]["n_blocks"])
        return "shipped"

    def _ship_fallback(self, rreq: RouterRequest, src: int,
                       payload_id: str) -> None:
        """A ship that cannot complete: release the source's export-ledger
        hold and continue by re-prefill on a PREFILL host (decode hosts
        never prefill, even on the failure path)."""
        self._guard(src, self.transports[src].ack_ship, payload_id)
        self.counters["ship_fallbacks"] += 1
        cont = np.concatenate(
            [rreq.prompt, np.asarray(rreq.tokens, np.int32)])
        rem = rreq.max_new_tokens - len(rreq.tokens)
        placed = self._place(self._key(rreq.prompt, rreq.session),
                             len(cont), rem)
        if placed is None or not self._submit_segment(
                rreq, placed[0], cont, rem):
            self._orphans.append(rreq)

    def _harvest(self, host: int) -> None:
        """Poll one host for token deltas past each live request's cursor.
        Polling is idempotent — a duplicated or retried poll re-reads, never
        re-emits — and a request's done flag travels with its final tokens,
        so completion is atomic with the tokens that caused it."""
        cursors = {eid: self._cursor[(h, eid)]
                   for (h, eid) in self._live if h == host}
        drop, self._drop[host] = self._drop[host], []
        if not cursors and not drop:
            return
        deltas = self._guard(host, self.transports[host].poll, cursors,
                             drop, default=None)
        if deltas is None:
            self._drop[host] = drop        # poll failed: host marked lost
            return
        for eid, delta in deltas.items():
            key = (host, int(eid))
            rreq = self._live.get(key)
            if rreq is None:
                continue
            new = [int(t) for t in delta.get("t", ())]
            rreq.tokens.extend(new)
            ts = [float(v) for v in delta.get("ts", ())]
            # tolerate older workers without timestamps: harvest time is
            # the (burst-quantized) fallback
            rreq.token_ts.extend(ts if len(ts) == len(new)
                                 else [now()] * len(new))
            self._cursor[key] += len(new)
            if rreq.want_logprobs is not None:
                rreq.logprobs.extend(float(v) for v in delta.get("lp", ()))
                rreq.top_logprobs.extend(
                    [(int(t), float(v)) for t, v in row]
                    for row in delta.get("tl", ()))
            if delta.get("done"):
                del self._live[key]
                del self._cursor[key]
                self._drop[host].append(int(eid))
                self._finalize(rreq, delta.get("reason"))

    def progress(self, rreq: RouterRequest) -> List[int]:
        """The stitched token stream as of the last harvest — what an SSE
        streamer polls between fleet steps. Harvest is continuous (every
        ``step`` polls deltas), so this is simply the mirror."""
        return list(rreq.tokens)

    def embed(self, prompt: Sequence[int]) -> Dict[str, np.ndarray]:
        """Non-generative forward on the least-loaded live host —
        embeddings/classification never lease a slot, so placement is pure
        load balancing (no affinity to honour)."""
        alive = self._alive()
        if not alive:
            raise RuntimeError("every host is draining — no embed capacity")
        host = min(alive, key=self._load)
        out = self._guard(host, self.transports[host].embed, prompt)
        if out is None:
            return self.embed(prompt)      # host died mid-call: next host
        return out

    def has_work(self) -> bool:
        # un-finalized placements count as work even when every host is idle:
        # a free-running worker can finish (and go idle) between fleet steps,
        # and the completion still has to be harvested by a poll
        if self._orphans or self._live or self._ship_parked:
            return True
        return any(self._guard(h, self.transports[h].has_work, default=False)
                   for h in range(self.rcfg.n_hosts) if h not in self._lost)

    def run_until_complete(self, max_steps: int = 100_000
                           ) -> List[RouterRequest]:
        steps = 0
        while self.has_work():
            self.step()
            steps += 1
            if steps > max_steps:
                raise RuntimeError(
                    f"fleet did not drain in {max_steps} steps")
        return self.completed

    # ---------------------------------------------------------------- summary

    def stats(self) -> Dict:
        """Fleet telemetry, three levels down: ``router`` (the placement
        ledger — placed/affinity_hits/spills in the OPQ per-lane shape, plus
        drain/handoff/loss counts and per-transport RPC telemetry),
        ``fleet`` (host counters summed across the fleet), and ``per_host``
        (each host's full stats, its own per-lane OPQ counters included;
        zeros for a lost host, which can no longer report)."""
        per_host = []
        for host in range(self.rcfg.n_hosts):
            s = (None if host in self._lost
                 else self._guard(host, self.transports[host].stats))
            per_host.append(s if s is not None else dict(
                {k: 0 for k in _FLEET_KEYS},
                first_token_s=None, last_token_s=None, lost=True))
        fleet = {k: sum(h[k] for h in per_host) for k in _FLEET_KEYS}
        # fleet rate over the FLEET's first->last token span — summing
        # per-host rates would overstate it whenever host spans differ
        # (e.g. a host drained early has a short span and a high rate)
        firsts = [h["first_token_s"] for h in per_host
                  if h.get("first_token_s") is not None]
        lasts = [h["last_token_s"] for h in per_host
                 if h.get("last_token_s") is not None]
        span = (max(lasts) - min(firsts)) if firsts else 0.0
        fleet["sustained_tok_s"] = (
            fleet["tokens_generated"] / span if span > 0
            else float("inf") if fleet["tokens_generated"] else 0.0)
        return {
            "router": dict(self.counters, hosts=self.rcfg.n_hosts,
                           roles=(list(self.rcfg.roles)
                                  if self.rcfg.roles else None),
                           draining=sorted(self._draining),
                           lost=sorted(self._lost),
                           orphans=len(self._orphans),
                           completed=len(self.completed),
                           transport=[dict(t.metrics.summary(), kind=t.kind)
                                      for t in self.transports]),
            "fleet": fleet,
            "per_host": per_host,
        }

    def close(self) -> None:
        for host, t in enumerate(self.transports):
            if host in self._lost:
                continue                   # already closed at loss time
            try:
                t.close()
            except TransportError:
                pass
