"""Slot-based continuous-batching scheduler (rtp-llm FIFOScheduler shape).

Requests wait in a FIFO; every engine step the scheduler joins as many waiting
requests as there are free slots into the in-flight decode batch and retires
finished ones — there is no full-batch barrier, a long request never blocks
short ones from entering and leaving around it.

Admissions are grouped by *prefill bucket* (prompt padded up to a small fixed
set of lengths) so same-bucket arrivals share one prefill forward and the
number of distinct compiled prefill shapes is bounded by ``len(buckets)``
instead of the number of distinct prompt lengths seen in traffic.
"""

from __future__ import annotations

from collections import deque
from typing import Deque, Dict, List, Sequence, Tuple

import numpy as np

MIN_BUCKET = 16


def default_buckets(max_len: int) -> Tuple[int, ...]:
    """Powers of two from MIN_BUCKET up, capped at ``max_len``."""
    buckets: List[int] = []
    b = MIN_BUCKET
    while b < max_len:
        buckets.append(b)
        b *= 2
    buckets.append(max_len)
    return tuple(buckets)


def bucket_for(n: int, buckets: Sequence[int]) -> int:
    for b in buckets:
        if n <= b:
            return b
    raise ValueError(f"prompt length {n} exceeds largest bucket {buckets[-1]}")


class Scheduler:
    def __init__(self, n_slots: int, buckets: Sequence[int]):
        self.n_slots = n_slots
        self.buckets = tuple(sorted(buckets))
        # pop() from the tail — reversed so slot 0 is leased first
        self.free: List[int] = list(range(n_slots))[::-1]
        self.active: Dict[int, object] = {}        # slot -> Request
        self.waiting: Deque[object] = deque()

    # ------------------------------------------------------------------ FIFO

    def enqueue(self, request) -> None:
        self.waiting.append(request)

    @property
    def queue_depth(self) -> int:
        return len(self.waiting)

    @property
    def n_active(self) -> int:
        return len(self.active)

    def has_work(self) -> bool:
        return bool(self.waiting or self.active)

    # ------------------------------------------------------------ join/retire

    def plan_admissions(self, try_lease=None, group_key=None
                        ) -> List[Tuple[int, List[Tuple[int, object]]]]:
        """Lease free slots to waiting requests (FIFO), grouped by prefill
        bucket: [(bucket_len, [(slot, request), ...]), ...]. Mutates the free
        list and active map — the engine must prefill every planned request.

        ``try_lease(slot, request) -> bool`` lets the cache backend reserve
        capacity before the slot is committed (serving/store.py). A False
        return stops planning with the request still at the queue head —
        FIFO-order admission backpressure (e.g. paged block-pool exhaustion),
        resolved when a retire frees capacity.

        ``group_key(slot, request)`` further partitions a bucket's admissions
        (evaluated AFTER the lease, so the key can read what the lease
        reserved). The prefix-cache engine keys by suffix start chunk: a
        batched prefill can only skip chunks every row in it skips, so mixing
        a hot-prefix row with a cold one would silently recompute the hot
        row's cached prefix — separate groups keep each dispatch's skip at
        its own rows' minimum. A bucket may therefore appear more than once
        in the result, once per distinct key."""
        groups: Dict[Tuple[int, int], List[Tuple[int, object]]] = {}
        while self.waiting and self.free:
            req = self.waiting[0]
            slot = self.free[-1]
            if try_lease is not None and not try_lease(slot, req):
                break
            self.waiting.popleft()
            self.free.pop()
            self.active[slot] = req
            b = bucket_for(len(req.prompt), self.buckets)
            key = (b, group_key(slot, req) if group_key is not None else 0)
            groups.setdefault(key, []).append((slot, req))
        return [(b, pairs) for (b, _), pairs in sorted(groups.items())]

    def admit_seeded(self, request) -> "int | None":
        """Place an externally-seeded request straight into the in-flight
        batch, bypassing the waiting queue and prefill planning entirely.
        The caller has already materialised the slot's KV (e.g. from an
        imported cross-host block payload), so there is nothing to prefill —
        the request joins the next decode step as-is. Returns the slot, or
        None when no slot is free (the caller keeps the payload and retries
        or falls back to re-prefill)."""
        if not self.free:
            return None
        slot = self.free.pop()
        self.active[slot] = request
        return slot

    def decode_batch(self) -> Tuple[np.ndarray, np.ndarray]:
        """The in-flight batch as fixed-shape host arrays: ``tokens``
        (n_slots, 1) int32 — each active slot's last emitted token, the
        input every decode variant feeds next — and the ``active`` mask
        (n_slots,). Shared by the plain decode step and the speculative
        draft/verify round (serving/engine.py), so the two decode paths can
        never disagree about what a slot feeds."""
        tokens = np.zeros((self.n_slots, 1), np.int32)
        active = np.zeros((self.n_slots,), bool)
        for slot, req in self.active.items():
            tokens[slot, 0] = req.last_token
            active[slot] = True
        return tokens, active

    def sampling_by_slot(self, default) -> List[object]:
        """Each slot's SamplingParams as a fixed-width list aligned with
        ``decode_batch``'s rows: the active request's params (``default``
        when it has none) or ``default`` for idle slots. The engine stacks
        this into the decode batch every step, so params ride the slot state
        through join/preempt/handoff exactly like the cache lease does."""
        out = [default] * self.n_slots
        for slot, req in self.active.items():
            out[slot] = getattr(req, "sampling", None) or default
        return out

    def retire(self, slot: int):
        req = self.active.pop(slot)
        self.free.append(slot)
        return req
