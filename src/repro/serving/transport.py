"""HostTransport: the Router<->host boundary as a real protocol.

PR 5's Router fronted "hosts" that were in-process Engine objects — every
placement call was a Python attribute access, so the fleet could never
survive a host process dying, and fleet throughput never measured real
process parallelism. This module extracts the complete Router->host call
surface into the :class:`HostTransport` protocol and provides two backends:

  * :class:`InProcessTransport` — today's behavior, now just one
    implementation: an :class:`EngineHost` wrapping an Engine in the same
    address space. The Router drives the engine one step per fleet
    iteration through ``pump()``.
  * :class:`SubprocessTransport` — one OS process per host running the
    ``serving/host_main.py`` worker loop, speaking length-prefixed
    msgpack-or-JSON frames over an AF_UNIX socket. The worker FREE-RUNS
    its engine between requests (the step loop is driven by the worker,
    not the caller), which is only correct because the engine is
    batch-invariant and greedy/seeded decode is a pure function of the
    token prefix — the async fleet emits streams bit-identical to a
    synchronous single engine (tests/test_transport.py).

Failure semantics: every RPC carries a ``seq`` number; replies with a
stale seq (duplicated or late frames) are discarded. Idempotent calls
(door predicates, polls, stats, probes) retry a bounded number of times
with a FRESH seq on timeout; non-idempotent calls (submit, evict,
preempt) never retry — a failure raises :class:`TransportError` and the
Router marks the host LOST, re-places its queued work, and re-admits its
in-flight streams as continuations from the tokens already harvested.
Tokens only count as emitted once the Router has polled them, so a
SIGKILLed worker loses only un-harvested tokens — which determinism
regenerates exactly, never double-emitting (the crash-tolerance half of
the bit-identity invariant).

Workers rebuild their model deterministically from a *model spec*
(arch name + smoke/quantize/overrides + init seed) instead of shipping
parameter pytrees over the wire: ``init_model(cfg, PRNGKey(seed))`` is
bit-reproducible on a given backend, so parent and worker hold identical
weights by construction.
"""

from __future__ import annotations

import base64
import dataclasses
import itertools
import json
import os
import socket
import struct
import subprocess
import sys
import tempfile
from pathlib import Path
from typing import Dict, List, Optional, Protocol, Sequence

import numpy as np

from repro.serving.engine import (
    Engine, EngineConfig, QueueFull, Request, RequestState,
)
from repro.serving.metrics import TransportMetrics, now
from repro.serving.sampling import (
    SamplingParams, sampling_from_wire, sampling_to_wire,
)

try:                                   # optional: CI installs jax/numpy/pytest
    import msgpack                     # only — frames fall back to JSON
except ImportError:                    # pragma: no cover - environment-dependent
    msgpack = None

__all__ = [
    "TransportError", "HostTransport", "EngineHost", "InProcessTransport",
    "SubprocessTransport", "Channel", "build_inproc_fleet",
    "build_model_spec", "realize_model_spec",
    "engine_cfg_to_wire", "engine_cfg_from_wire", "QueueFull",
]

MAX_FRAME_BYTES = 64 * 1024 * 1024     # sanity bound on one frame


class TransportError(Exception):
    """Host-level transport failure: timeout, dropped connection, dead
    worker. Distinct from application errors a healthy host returns (those
    re-raise as their original exception type) — the Router's cue to mark
    the host LOST and re-place its work."""


# --------------------------------------------------------------------- codec

def _sanitize(x):
    """Python/numpy tree -> plain JSON/msgpack-able tree (ndarrays as
    dtype/shape/b64 triples, numpy scalars as Python scalars)."""
    if isinstance(x, np.ndarray):
        return {"__nd__": True, "dtype": str(x.dtype),
                "shape": list(x.shape),
                "b64": base64.b64encode(
                    np.ascontiguousarray(x).tobytes()).decode("ascii")}
    if isinstance(x, np.integer):
        return int(x)
    if isinstance(x, np.floating):
        return float(x)
    if isinstance(x, np.bool_):
        return bool(x)
    if isinstance(x, dict):
        return {k: _sanitize(v) for k, v in x.items()}
    if isinstance(x, (list, tuple)):
        return [_sanitize(v) for v in x]
    return x


def _restore(x):
    if isinstance(x, dict):
        if x.get("__nd__"):
            arr = np.frombuffer(base64.b64decode(x["b64"]),
                                dtype=np.dtype(x["dtype"]))
            return arr.reshape([int(s) for s in x["shape"]]).copy()
        return {k: _restore(v) for k, v in x.items()}
    if isinstance(x, list):
        return [_restore(v) for v in x]
    return x


def default_codec() -> str:
    return "msgpack" if msgpack is not None else "json"


def encode_frame(obj, codec: Optional[str] = None) -> bytes:
    """Object -> one frame body: 1 codec byte + payload."""
    tree = _sanitize(obj)
    codec = codec or default_codec()
    if codec == "msgpack":
        return b"M" + msgpack.packb(tree, use_bin_type=True)
    return b"J" + json.dumps(tree).encode()


def decode_frame(body: bytes):
    """Inverse of :func:`encode_frame` — dispatches on the codec byte, so a
    JSON peer can decode a msgpack sender's frames only when msgpack is
    importable locally (both ends of an AF_UNIX socket share the env)."""
    if body[:1] == b"M":
        if msgpack is None:
            raise TransportError("received a msgpack frame but msgpack is "
                                 "not importable here")
        return _restore(msgpack.unpackb(body[1:], raw=False,
                                        strict_map_key=False))
    return _restore(json.loads(body[1:].decode()))


class Channel:
    """Length-prefixed frames over a stream socket. The seam the transport
    fault-injection tests wrap (a flaky channel drops/duplicates/delays
    frames here without touching the protocol logic above it)."""

    def __init__(self, sock: socket.socket, codec: Optional[str] = None):
        self.sock = sock
        self.codec = codec or default_codec()

    def send(self, obj) -> None:
        body = encode_frame(obj, self.codec)
        try:
            self.sock.sendall(struct.pack(">I", len(body)) + body)
        except OSError as e:
            raise TransportError(f"frame send failed: {e}") from e

    def recv(self, timeout: Optional[float] = None):
        try:
            self.sock.settimeout(timeout)
            head = self._read_exact(4)
            (n,) = struct.unpack(">I", head)
            if n > MAX_FRAME_BYTES:
                raise TransportError(f"frame of {n} bytes exceeds the "
                                     f"{MAX_FRAME_BYTES} bound")
            return decode_frame(self._read_exact(n))
        except socket.timeout as e:
            raise TransportError(
                f"frame recv timed out after {timeout}s") from e
        except OSError as e:
            raise TransportError(f"frame recv failed: {e}") from e

    def _read_exact(self, n: int) -> bytes:
        buf = b""
        while len(buf) < n:
            chunk = self.sock.recv(n - len(buf))
            if not chunk:
                raise TransportError("connection closed (EOF)")
            buf += chunk
        return buf

    def close(self) -> None:
        try:
            self.sock.close()
        except OSError:
            pass


# ---------------------------------------------------------------- wire forms

def engine_cfg_to_wire(ecfg: Optional[EngineConfig]) -> Dict:
    """EngineConfig -> plain dict. The ``draft`` ArchConfig is dropped —
    the worker rebuilds it from the model spec's ``draft`` entry (configs
    are named registry entries, not wire payloads)."""
    d = dataclasses.asdict(ecfg or EngineConfig())
    d.pop("draft", None)
    if d.get("buckets") is not None:
        d["buckets"] = [int(b) for b in d["buckets"]]
    return d


def engine_cfg_from_wire(d: Dict, draft_cfg=None) -> EngineConfig:
    d = dict(d)
    if d.get("buckets") is not None:
        d["buckets"] = tuple(int(b) for b in d["buckets"])
    return EngineConfig(**d, draft=draft_cfg)


def build_model_spec(arch: str, *, smoke: bool = True, quantize: str = "off",
                     overrides: Optional[Dict] = None, seed: int = 0,
                     draft_arch: Optional[str] = None,
                     model_parallel: int = 1) -> Dict:
    """The deterministic model recipe a worker rebuilds its params from:
    registry arch name, smoke scaling, ArchConfig field overrides, the
    Tensorizer quantize mode, and the init PRNG seed. Same spec + same
    backend => bit-identical weights in every process."""
    spec = {"arch": arch, "smoke": bool(smoke), "quantize": quantize,
            "overrides": dict(overrides or {}), "seed": int(seed),
            "model_parallel": int(model_parallel)}
    if draft_arch:
        spec["draft"] = {"arch": draft_arch, "smoke": bool(smoke),
                         "seed": int(seed)}
    return spec


def _build_cfg(entry: Dict):
    from repro.configs import get_config
    cfg = get_config(entry["arch"])
    if entry.get("smoke", True):
        cfg = cfg.smoke()
    overrides = entry.get("overrides") or {}
    if overrides:
        cfg = cfg.replace(**overrides)
    return cfg


def realize_model_spec(spec: Dict):
    """Model spec -> (cfg, params, draft_cfg, draft_params), exactly the
    objects the CLI path builds (launch/serve.py): smoke-scaled registry
    config + overrides, ``init_model(cfg, PRNGKey(seed))``, and — with
    ``quantize='serve'`` — the same Tensorizer W8A8 pass over the same
    predicate. Must run inside a mesh context."""
    import jax
    from repro.models import init_model
    cfg = _build_cfg(spec)
    quantize = spec.get("quantize", "off")
    if quantize != "off":
        cfg = cfg.replace(quantize=quantize)
    params = init_model(cfg, jax.random.PRNGKey(int(spec.get("seed", 0))))
    if quantize == "serve":
        from repro import tensorizer as tz
        from repro.launch.serve import _quant_predicate
        params = tz.quantize_params(params, predicate=_quant_predicate)
    draft_cfg = draft_params = None
    if spec.get("draft"):
        draft_cfg = _build_cfg(spec["draft"])
        draft_params = init_model(
            draft_cfg, jax.random.PRNGKey(int(spec["draft"].get("seed", 0))))
    return cfg, params, draft_cfg, draft_params


# ----------------------------------------------------------------- protocol

class HostTransport(Protocol):
    """The complete Router->host call surface. ``poll`` is the harvest
    primitive: cursor-based (tokens already received per request), so it is
    idempotent and a duplicated/retried poll can never double-deliver a
    token. ``submit``/``evict_queued``/``preempt`` mutate and are never
    retried."""

    kind: str
    metrics: TransportMetrics

    def would_accept(self, prompt_len: int, max_new_tokens: int) -> bool: ...
    def lease_headroom(self, prompt_len: int, max_new_tokens: int) -> bool: ...
    def load(self) -> int: ...
    def submit(self, prompt: Sequence[int], max_new_tokens: int,
               sampling: Optional[SamplingParams] = None,
               stop_history: Sequence[int] = (),
               want_logprobs: Optional[int] = None) -> Optional[int]: ...
    def pump(self) -> None: ...
    def poll(self, cursors: Dict[int, int],
             drop: Sequence[int] = ()) -> Dict[int, Dict]: ...
    def has_work(self) -> bool: ...
    def evict_queued(self, ids: Sequence[int]) -> List[int]: ...
    def inflight(self) -> List[Dict]: ...
    def preempt(self, req_id: int) -> Optional[Dict]: ...
    def ship_blocks(self, req_id: int) -> Optional[Dict]: ...
    def recv_blocks(self, entry: Dict) -> Optional[int]: ...
    def ack_ship(self, payload_id: str) -> bool: ...
    def embed(self, prompt: Sequence[int]) -> Dict: ...
    def stats(self) -> Dict: ...
    def probe(self) -> bool: ...
    def close(self) -> None: ...


class EngineHost:
    """Server-side host logic shared by BOTH backends: an Engine plus the
    ownership map of caller-submitted requests. InProcessTransport calls it
    directly; host_main.py calls it behind the RPC loop — identical
    behavior on either side of the process boundary by construction."""

    def __init__(self, engine: Engine):
        self.engine = engine
        self._by_id: Dict[int, Request] = {}
        # cross-host block shipping state (prefill/decode disaggregation):
        # outbound entries keyed by payload id (and by request id, so a
        # retried ship_blocks returns the SAME cursor-named entry), plus the
        # inbound dedup map a retried recv_blocks resolves against — a
        # duplicated frame can therefore never double-import a payload
        self._shipped: Dict[str, Dict] = {}
        self._ship_pid: Dict[int, str] = {}
        self._imported: Dict[str, int] = {}

    def would_accept(self, prompt_len: int, max_new_tokens: int) -> bool:
        return bool(self.engine.would_accept(prompt_len, max_new_tokens))

    def lease_headroom(self, prompt_len: int, max_new_tokens: int) -> bool:
        return bool(self.engine.lease_headroom(prompt_len, max_new_tokens))

    def load(self) -> int:
        sched = self.engine.scheduler
        return sched.queue_depth + sched.n_active

    def submit(self, prompt, max_new_tokens, sampling=None, stop_history=(),
               want_logprobs=None) -> Optional[int]:
        req = self.engine.submit(
            np.asarray(prompt, np.int32), int(max_new_tokens),
            sampling=sampling, stop_history=tuple(stop_history),
            want_logprobs=want_logprobs)
        if req is None:
            return None
        self._by_id[req.id] = req
        return req.id

    def pump(self) -> None:
        if self.engine.has_work():
            self.engine.step()

    def poll(self, cursors: Dict[int, int],
             drop: Sequence[int] = ()) -> Dict[int, Dict]:
        """Token deltas for the caller's live requests: everything past each
        request's cursor, plus done/finish_reason once finished. A request's
        final tokens and its done flag always travel in the SAME delta (the
        engine appends and finishes synchronously), so a crash can only lose
        them together — which re-decoding regenerates exactly. ``drop`` lets
        the caller forget fully-harvested requests."""
        for rid in drop:
            self._by_id.pop(int(rid), None)
        out: Dict[int, Dict] = {}
        for rid, n in cursors.items():
            req = self._by_id.get(int(rid))
            if req is None:
                continue
            n = int(n)
            d: Dict = {"t": [int(t) for t in req.tokens[n:]],
                       # emission timestamps (monotonic epoch, shared across
                       # processes on Linux): a free-running worker's tokens
                       # arrive in bursts, so harvest times measure the
                       # caller's poll cadence — these measure the engine's
                       "ts": [float(v) for v in req.token_ts[n:]]}
            if req.want_logprobs is not None:
                d["lp"] = [float(v) for v in req.logprobs[n:]]
                d["tl"] = [[[int(t), float(v)] for t, v in row]
                           for row in req.top_logprobs[n:]]
            if req.done:
                d["done"] = True
                d["reason"] = req.finish_reason
            out[int(rid)] = d
        return out

    def has_work(self) -> bool:
        return self.engine.has_work()

    def evict_queued(self, ids: Sequence[int]) -> List[int]:
        """Pull the queue; caller-owned requests (``ids``) come back as ids
        for re-placement elsewhere, anything else (direct engine submits)
        re-enqueues untouched — the same Request object, so a direct
        caller's handle still completes here."""
        own = {int(i) for i in ids}
        evicted: List[int] = []
        for req in self.engine.evict_queued():
            if req.id in own:
                self._by_id.pop(req.id, None)
                evicted.append(req.id)
            else:
                req.state = RequestState.QUEUED
                self.engine.scheduler.enqueue(req)
        return evicted

    def inflight(self) -> List[Dict]:
        return [{"id": req.id, "generated": len(req.tokens)}
                for _, req in sorted(self.engine.scheduler.active.items())
                if req.id in self._by_id]

    def preempt(self, req_id: int) -> Optional[Dict]:
        """Preempt one in-flight request and return its authoritative wire
        form (full segment tokens — a free-running worker may be ahead of
        the caller's last poll). None when the request already finished
        between the caller's snapshot and now (the next poll reports it)."""
        try:
            req = self.engine.preempt(int(req_id))
        except KeyError:
            return None
        self._by_id.pop(int(req_id), None)
        return req.to_wire()

    def ship_blocks(self, req_id: int) -> Optional[Dict]:
        """Export one in-flight request's stream state AND its exact cache
        blocks as a ship entry (``{"payload_id", "wire", "payload"}``) for a
        decode host to adopt. Idempotent by construction: the entry is cached
        under the request id, so a retried ship returns the same cursor-named
        payload — combined with ``recv_blocks``'s dedup, a duplicated frame
        can never double-import. The blocks stay on the engine's export
        ledger (unreusable, unfreed) until ``ack_ship``. None when the
        request already finished here (the next poll reports it)."""
        pid = self._ship_pid.get(int(req_id))
        if pid is not None:
            return self._shipped[pid]
        try:
            req, payload = self.engine.extract_seeded(int(req_id))
        except KeyError:
            return None
        self._by_id.pop(int(req_id), None)
        entry = {"payload_id": payload["payload_id"],
                 "wire": req.to_wire(), "payload": payload}
        self._shipped[entry["payload_id"]] = entry
        self._ship_pid[int(req_id)] = entry["payload_id"]
        return entry

    def recv_blocks(self, entry: Dict) -> Optional[int]:
        """Adopt a shipped entry: lease a slot, import the payload's cache
        bits (validated before any device write), and continue the stream
        with zero prefill dispatches. Dedup on the cursor-named payload id —
        a retried recv of an already-imported payload returns the SAME local
        request id instead of importing twice. Returns None when refused
        (no free slot / lease backpressure: the shipper falls back to
        re-prefill continuation); raises ValueError on a corrupt payload."""
        pid = str(entry["payload_id"])
        if pid in self._imported:
            return self._imported[pid]
        wire = entry["wire"]
        req = self.engine.submit_seeded(
            wire["prompt"], int(wire["max_new_tokens"]), wire["tokens"],
            entry["payload"],
            sampling=sampling_from_wire(wire.get("sampling")),
            stop_history=tuple(int(t) for t in wire.get("stop_history") or ()),
            want_logprobs=wire.get("want_logprobs"),
            logprobs=wire.get("logprobs") or (),
            top_logprobs=wire.get("top_logprobs") or ())
        if req is None:
            return None
        self._imported[pid] = req.id
        self._by_id[req.id] = req
        return req.id

    def ack_ship(self, payload_id: str) -> bool:
        """Settle one outbound ship: release the export ledger's block hold
        and drop the cached entry. Called on BOTH outcomes — successful
        import (the blocks live on the decode host now) and fallback (the
        re-prefill continuation owns the stream). Idempotent."""
        pid = str(payload_id)
        entry = self._shipped.pop(pid, None)
        if entry is not None:
            self._ship_pid.pop(int(entry["wire"]["id"]), None)
        return bool(self.engine.release_exported(pid))

    def embed(self, prompt) -> Dict:
        return self.engine.embed(np.asarray(prompt, np.int32))

    def stats(self) -> Dict:
        out = dict(self.engine.stats())
        # the fleet sustained-rate span needs the raw first/last token
        # timestamps, which EngineMetrics.summary() does not carry — ship
        # them in the wire stats (time.monotonic shares an epoch across
        # processes on Linux, so cross-process spans are comparable)
        out["first_token_s"] = self.engine.metrics.first_token_s
        out["last_token_s"] = self.engine.metrics.last_token_s
        return out

    def probe(self) -> bool:
        return True

    def close(self) -> None:
        self.engine.close()


# ------------------------------------------------------------- in-process

class InProcessTransport:
    """Today's fleet, behind the protocol: host calls are Python calls,
    timed through the same TransportMetrics so the subprocess backend's RPC
    overhead is measured against a real baseline. ``pump`` drives one
    engine step — with no worker process, the caller is the step loop."""

    kind = "in-process"

    def __init__(self, host: EngineHost):
        self.host = host
        self.metrics = TransportMetrics()

    @property
    def engine(self) -> Engine:
        return self.host.engine

    def _timed(self, fn, *args, **kwargs):
        t0 = now()
        try:
            return fn(*args, **kwargs)
        finally:
            self.metrics.observe(now() - t0)

    def would_accept(self, prompt_len, max_new_tokens):
        return self._timed(self.host.would_accept, prompt_len, max_new_tokens)

    def lease_headroom(self, prompt_len, max_new_tokens):
        return self._timed(self.host.lease_headroom, prompt_len,
                           max_new_tokens)

    def load(self):
        return self._timed(self.host.load)

    def submit(self, prompt, max_new_tokens, sampling=None, stop_history=(),
               want_logprobs=None):
        return self._timed(self.host.submit, prompt, max_new_tokens,
                           sampling=sampling, stop_history=stop_history,
                           want_logprobs=want_logprobs)

    def pump(self):
        self.host.pump()

    def poll(self, cursors, drop=()):
        return self._timed(self.host.poll, cursors, drop)

    def has_work(self):
        return self.host.has_work()

    def evict_queued(self, ids):
        return self._timed(self.host.evict_queued, ids)

    def inflight(self):
        return self._timed(self.host.inflight)

    def preempt(self, req_id):
        return self._timed(self.host.preempt, req_id)

    def ship_blocks(self, req_id):
        return self._timed(self.host.ship_blocks, req_id)

    def recv_blocks(self, entry):
        return self._timed(self.host.recv_blocks, entry)

    def ack_ship(self, payload_id):
        return self._timed(self.host.ack_ship, payload_id)

    def embed(self, prompt):
        return self._timed(self.host.embed, prompt)

    def stats(self):
        return self._timed(self.host.stats)

    def probe(self):
        return True

    def close(self):
        self.host.close()


def build_inproc_fleet(cfg, params, engine_cfg: Optional[EngineConfig] = None,
                       n_hosts: int = 1, *,
                       draft_params=None) -> List[InProcessTransport]:
    """N in-process hosts over shared params — compiled steps are shared
    across them via the engine's _jitted_steps cache, so N hosts costs N
    caches, not N XLA compiles. The default Router fleet."""
    return [
        InProcessTransport(EngineHost(
            Engine(cfg, params, engine_cfg, draft_params=draft_params)))
        for _ in range(n_hosts)]


# ------------------------------------------------------------- subprocess

# ops safe to retry after a timeout: read-only predicates and cursor-based
# reads. submit/evict/preempt mutate — a lost reply leaves the mutation's
# fate unknown, so they surface TransportError instead of retrying (the
# Router treats that as a lost host and re-places from harvested state).
# The block-shipping trio mutates but is retry-safe by protocol design:
# ship_blocks caches its cursor-named entry per request, recv_blocks dedups
# on the payload id, and ack_ship releases idempotently — a retried frame
# replays to the same state it left.
_IDEMPOTENT_OPS = frozenset({
    "would_accept", "lease_headroom", "load", "has_work", "poll",
    "inflight", "stats", "probe", "embed",
    "ship_blocks", "recv_blocks", "ack_ship",
})


class SubprocessTransport:
    """One OS process per host: spawns ``python -m repro.serving.host_main``
    connected over an AF_UNIX socket, ships the model spec + engine config
    in an init frame, then speaks the framed RPC protocol. The worker
    free-runs its engine between requests; ``pump`` is therefore a no-op.

    ``connect_timeout_s`` bounds worker boot (imports + init_model);
    ``request_timeout_s`` bounds each RPC — generous by default because a
    worker mid-XLA-compile blocks its loop for seconds on first traffic.
    """

    kind = "subprocess"

    def __init__(self, model_spec: Dict,
                 engine_cfg: Optional[EngineConfig] = None, *,
                 connect_timeout_s: float = 300.0,
                 request_timeout_s: float = 300.0,
                 retries: int = 2):
        self.model_spec = dict(model_spec)
        self.ecfg = engine_cfg or EngineConfig()
        self.request_timeout_s = request_timeout_s
        self.retries = retries
        self.metrics = TransportMetrics()
        self._seq = itertools.count(1)
        self._closed = False
        self._tmpdir = tempfile.mkdtemp(prefix="rhost")
        path = os.path.join(self._tmpdir, "s")
        listener = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
        listener.bind(path)
        listener.listen(1)
        listener.settimeout(connect_timeout_s)
        self.proc = subprocess.Popen(
            [sys.executable, "-m", "repro.serving.host_main",
             "--socket", path],
            env=self._worker_env())
        try:
            conn, _ = listener.accept()
        except socket.timeout:
            self._reap()
            raise TransportError(
                f"worker (pid {self.proc.pid}) did not connect within "
                f"{connect_timeout_s}s")
        finally:
            listener.close()
        self.chan = Channel(conn)
        # init is a regular seq'd request so the reply path is uniform, but
        # with the boot timeout: the worker only answers after building the
        # model (imports + init_model + optional quantize)
        ready = self._call("init",
                           {"model_spec": self.model_spec,
                            "engine_cfg": engine_cfg_to_wire(engine_cfg)},
                           timeout=connect_timeout_s)
        self.pid = int(ready["pid"])

    @staticmethod
    def _worker_env() -> Dict[str, str]:
        import jax
        import repro
        env = dict(os.environ)
        src_dir = str(Path(repro.__file__).resolve().parents[1])
        existing = env.get("PYTHONPATH")
        env["PYTHONPATH"] = (src_dir + os.pathsep + existing
                             if existing else src_dir)
        # share the parent's persistent compilation cache so sibling workers
        # load executables the first one compiled
        cache_dir = getattr(jax.config, "jax_compilation_cache_dir", None)
        if cache_dir:
            env.setdefault("JAX_COMPILATION_CACHE_DIR", cache_dir)
            env.setdefault("JAX_PERSISTENT_CACHE_MIN_COMPILE_TIME_SECS",
                           "0.5")
        return env

    # --------------------------------------------------------------- rpc

    def _call(self, op: str, args: Optional[Dict] = None,
              timeout: Optional[float] = None):
        if self._closed:
            raise TransportError(f"transport to pid {self.pid} is closed")
        timeout = self.request_timeout_s if timeout is None else timeout
        attempts = 1 + (self.retries if op in _IDEMPOTENT_OPS else 0)
        last: Optional[TransportError] = None
        for attempt in range(attempts):
            seq = next(self._seq)
            t0 = now()
            try:
                self.chan.send({"seq": seq, "op": op, "args": args or {}})
                deadline = t0 + timeout
                while True:
                    reply = self.chan.recv(timeout=max(deadline - now(),
                                                       0.001))
                    # a retried call's earlier reply (or a duplicated
                    # frame) carries a stale seq: discard, keep reading
                    if reply.get("seq") == seq:
                        break
            except TransportError as e:
                self.metrics.errors += 1
                last = e
                if attempt + 1 < attempts:
                    self.metrics.retries += 1
                    continue
                raise TransportError(
                    f"rpc {op!r} to worker pid {self.pid} failed after "
                    f"{attempts} attempt(s): {e}") from e
            self.metrics.observe(now() - t0)
            if reply.get("ok"):
                return reply.get("val")
            # application error from a healthy host: re-raise in kind
            etype, msg = reply.get("etype"), reply.get("err", "")
            if etype == "ValueError":
                raise ValueError(msg)
            if etype == "KeyError":
                raise KeyError(msg)
            raise RuntimeError(f"remote {etype or 'error'}: {msg}")
        raise last  # pragma: no cover - loop always raises/returns

    # ---------------------------------------------------------- protocol

    def would_accept(self, prompt_len, max_new_tokens):
        return bool(self._call("would_accept", {"plen": int(prompt_len),
                                                "gen": int(max_new_tokens)}))

    def lease_headroom(self, prompt_len, max_new_tokens):
        return bool(self._call("lease_headroom",
                               {"plen": int(prompt_len),
                                "gen": int(max_new_tokens)}))

    def load(self):
        return int(self._call("load"))

    def submit(self, prompt, max_new_tokens, sampling=None, stop_history=(),
               want_logprobs=None):
        val = self._call("submit", {
            "prompt": [int(t) for t in prompt],
            "gen": int(max_new_tokens),
            "sampling": sampling_to_wire(sampling),
            "stop_history": [int(t) for t in stop_history],
            "want_logprobs": want_logprobs,
        })
        return None if val is None else int(val)

    def pump(self):
        pass                           # the worker's loop steps the engine

    def poll(self, cursors, drop=()):
        val = self._call("poll", {
            "cursors": {int(k): int(v) for k, v in cursors.items()},
            "drop": [int(i) for i in drop],
        }) or {}
        # JSON frames stringify int dict keys; normalize either way
        return {int(k): v for k, v in val.items()}

    def has_work(self):
        return bool(self._call("has_work"))

    def evict_queued(self, ids):
        return [int(i) for i in
                (self._call("evict_queued",
                            {"ids": [int(i) for i in ids]}) or [])]

    def inflight(self):
        return list(self._call("inflight") or [])

    def preempt(self, req_id):
        return self._call("preempt", {"id": int(req_id)})

    def ship_blocks(self, req_id):
        return self._call("ship_blocks", {"id": int(req_id)})

    def recv_blocks(self, entry):
        val = self._call("recv_blocks", {"entry": entry})
        return None if val is None else int(val)

    def ack_ship(self, payload_id):
        return bool(self._call("ack_ship", {"payload_id": str(payload_id)}))

    def embed(self, prompt):
        val = self._call("embed", {"prompt": [int(t) for t in prompt]})
        return {"embedding": np.asarray(val["embedding"]),
                "logits": np.asarray(val["logits"])}

    def stats(self):
        return self._call("stats")

    def probe(self) -> bool:
        """Liveness: False for a dead/unreachable worker, never raises."""
        if self._closed or self.proc.poll() is not None:
            return False
        try:
            return bool(self._call("probe", timeout=5.0))
        except TransportError:
            return False

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        try:
            if self.proc.poll() is None:
                self.chan.send({"seq": next(self._seq), "op": "shutdown",
                                "args": {}})
                self.chan.recv(timeout=5.0)   # let the worker ack + exit
        except TransportError:
            pass
        self.chan.close()
        self._reap()
        try:
            os.unlink(os.path.join(self._tmpdir, "s"))
            os.rmdir(self._tmpdir)
        except OSError:
            pass

    def _reap(self, grace_s: float = 5.0) -> None:
        """No orphans: wait briefly, then terminate, then kill."""
        try:
            self.proc.wait(timeout=grace_s)
            return
        except subprocess.TimeoutExpired:
            pass
        self.proc.terminate()
        try:
            self.proc.wait(timeout=2.0)
        except subprocess.TimeoutExpired:
            self.proc.kill()
            self.proc.wait()
