"""SlotStore: the pluggable serving-cache layer.

GPTPU's thesis is a general-purpose runtime interface that hides
accelerator-specific memory layout behind a clean API; this module applies the
same posture to the serving cache. The engine decodes a fixed ``n_slots``-row
batch; each row ("slot") is leased to one in-flight request. Everything the
engine knows about the cache goes through the :class:`SlotStore` protocol —
no code outside this module touches cache leaves directly:

  * ``alloc()``            — build the backing pytree ONCE (``alloc_count``
                             stays 1; admit/retire rewrite rows in place via
                             jitted donated updates, never reallocating)
  * ``fits``/``lease``     — capacity checks + reservation: ``fits`` gates
                             submit() against TOTAL capacity (an unservable
                             request bounces at the door), ``lease`` reserves
                             at admission time (paged: block accounting →
                             admission backpressure instead of mid-flight
                             corruption)
  * ``write_slots``        — batched admission write: scatter one fused-prefill
                             payload (K/V block or recurrent state rows) into
                             all leased slot rows with ONE donated dispatch
  * ``write_slot``         — single-row variant taking a full-length B=1 cache
                             (the replay-seeding reference path, tests only)
  * ``reset``              — retire: restore the row/blocks to the pristine
                             pattern so the next lease can never see a prior
                             tenant's tokens or state
  * ``decode_cache``/``swap`` — the pytree handed to (and adopted back from)
                             the jitted decode step; backends translate layout
                             here (paged: gather blocks → contiguous view →
                             scatter the written entries back)
  * ``gather_view``        — contiguous-layout view for inspection and tests
  * ``memory_stats``       — bytes / block occupancy per backend

Backends
  ContiguousKVStore   dense/moe/vlm K/V rows sized to ``max_seq_len`` — the
                      original ``KVSlotManager`` layout, ported.
  PagedKVStore        vLLM-style block-paged K/V: a fixed pool of
                      ``block_size``-token blocks plus per-slot block tables.
                      Slots lease exactly ``ceil((prompt+gen)/block_size)``
                      blocks, so the pool can be far smaller than
                      ``n_slots * max_seq_len`` rows — more concurrent short
                      requests per byte, with admission backpressure when the
                      pool runs dry. Two decode bridges: the GATHER bridge
                      (default) gathers each slot's blocks into a contiguous
                      view (``attention.gather_block_kv``, a jnp.take over
                      the block axis), runs the SAME compiled decode step as
                      the contiguous backend, and scatters the one written
                      entry per row back into block layout — which is what
                      makes paged decode bit-identical to contiguous; NATIVE
                      mode (``native=True``) skips the view entirely and
                      hands the pool itself to the block-native decode step
                      (models/serve.py ``decode_paged``), which writes and
                      attends through the tables in place — same tokens, and
                      the peak decode working set is the pool alone
                      (``decode_view_bytes: 0``). Block-table uploads are
                      batched: leases mutate a host mirror, synced to device
                      once per admission round (``table_uploads``).
  RecurrentStateStore per-slot recurrent state rows (mamba conv/ssm, xlstm
                      mLSTM/sLSTM hidden states, plus the hybrid family's attn
                      K/V) with pristine reset — makes ssm/hybrid families
                      servable through the same engine.

Shared-prefix radix cache (paged backend, ``prefix_cache=True``): most
production traffic shares system prompts and few-shot preambles, and
re-prefilling a hot prefix for every request wastes exactly the tensor
throughput the accelerator should be spending on new tokens. The paged store
already has block granularity, so prefix reuse is one refcount + trie layer:
every FULL block of an admitted prompt is registered in a radix trie keyed on
chained token-id block hashes (SGLang-style); a later ``lease`` walks the trie
with the new prompt's tokens and LEASES every matched block by bumping its
refcount instead of drawing a fresh one — those positions skip prefill
entirely, and the engine runs the chunked scan only over the suffix
(models/serve.py ``prefill_with_cache_suffix``). Shared blocks are immutable:
admission writes redirect shared positions to the null block, and a prompt
that diverges MID-block copy-on-write forks the divergence block into a fresh
private block before the slot ever writes into it. ``reset`` decrements
refcounts and scrubs/frees ONLY blocks that hit zero — blocks still referenced
by other slots, and trie-cached blocks awaiting their next hit, survive
retire untouched. Under pool pressure, unreferenced cached prefixes are
evicted leaf-first in LRU order, so caching never steals capacity from live
admissions. Block lifecycle invariant (property-tested): every non-null block
is in exactly one of {free, referenced (refcount > 0), cached-unreferenced};
``debug_block_census`` exposes the partition.

Leaf convention (all backends): the ``index`` leaf carries the slot on axis 0
(shape ``(B,)``); every other leaf carries it on axis 1 (``(L, B, ...)``).
``pristine_value`` is the single definition of each leaf's "empty" fill —
shared by reset, pad-scrub, and block-scrub so the pattern cannot drift
between backends.
"""

from __future__ import annotations

import abc
import functools
import math
import zlib
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import attention as A
from repro.models import serve as SV
from repro.models.xlstm import M_INIT

DENSE_FAMILIES = ("dense", "moe", "vlm")
RECURRENT_FAMILIES = ("ssm", "hybrid")

# Non-zero pristine fills, by leaf name. Everything else resets to 0. These
# mirror models/serve.py:init_cache exactly — a reset row must be bit-equal to
# a freshly allocated one (asserted in tests/test_serving.py).
_PRISTINE = {
    "mlstm_m": M_INIT,      # xlstm stabilizer "no history" sentinel
    "slstm_n": 1e-6,        # sLSTM normalizer floor
    "slstm_m": -1e30,       # sLSTM stabilizer init
}


def pristine_value(name: str) -> float:
    """The single source of truth for a cache leaf's empty-state fill value,
    shared by every backend's reset/scrub path (int8-KV dequant scales park at
    1e-12 so a pristine entry dequantizes to exactly 0 without dividing by 0)."""
    if name.endswith("_scale"):
        return 1e-12
    return _PRISTINE.get(name, 0.0)


def _leaf_name(path) -> str:
    for p in reversed(path):
        key = getattr(p, "key", getattr(p, "name", ""))
        if key:
            return str(key)
    return ""


# ===========================================================================
# jitted row/block primitives (donated: XLA updates buffers in place)
# ===========================================================================

@functools.partial(jax.jit, donate_argnums=(0,))
def _write_row(cache, row, slot, n_valid):
    """Write one slot's row (B=1 leaves on axis 1) + its index entry. Works on
    any nested cache pytree following the axis-0/axis-1 slot convention."""
    def f(path, leaf, src):
        if _leaf_name(path) == "index":
            return jax.lax.dynamic_update_slice(
                leaf, jnp.asarray([n_valid], jnp.int32), (slot,))
        return jax.lax.dynamic_update_slice(
            leaf, src.astype(leaf.dtype), (0, slot) + (0,) * (leaf.ndim - 2))
    return jax.tree_util.tree_map_with_path(f, cache, row)


@functools.partial(jax.jit, donate_argnums=(0,))
def _reset_row(cache, slot):
    """Restore one slot's row across every leaf to the pristine pattern (the
    ``pristine_value`` fills) and park its index at 0."""
    def f(path, leaf):
        name = _leaf_name(path)
        if name == "index":
            return jax.lax.dynamic_update_slice(
                leaf, jnp.zeros((1,), jnp.int32), (slot,))
        row = jnp.full((leaf.shape[0], 1) + leaf.shape[2:],
                       pristine_value(name), leaf.dtype)
        return jax.lax.dynamic_update_slice(
            leaf, row, (0, slot) + (0,) * (leaf.ndim - 2))
    return jax.tree_util.tree_map_with_path(f, cache)


@functools.partial(jax.jit, donate_argnums=(0,))
def _scatter_kv_rows(cache, kv, slots, n_valid):
    """Contiguous admission write: scatter per-layer K/V blocks (L, B, Sb, ...)
    into rows ``slots`` (B,), set each row's index to its prompt length, and
    scrub everything at/after position n_valid[i] back to pristine so an
    admitted row is bit-equal to a replay-seeded one. One donated scatter for
    the whole bucket batch — O(B rows), never O(cache)."""
    Sb = kv["k"].shape[2]
    out = {}
    for name, leaf in cache.items():
        if name == "index":
            out[name] = leaf.at[slots].set(n_valid)
            continue
        S = leaf.shape[2]
        src = kv[name].astype(leaf.dtype)
        if S > Sb:  # pad the bucket block out to the row length
            src = jnp.pad(src, [(0, 0), (0, 0), (0, S - Sb)]
                          + [(0, 0)] * (src.ndim - 3))
        valid = jnp.arange(S)[None, :] < n_valid[:, None]          # (B, S)
        valid = valid.reshape(valid.shape + (1,) * (src.ndim - 3))
        src = jnp.where(valid, src,
                        jnp.asarray(pristine_value(name), leaf.dtype))
        out[name] = leaf.at[:, slots].set(src)
    return out


@functools.partial(jax.jit, donate_argnums=(0,))
def _scatter_state_rows(cache, states, slots, n_valid):
    """Recurrent admission write: copy whole state rows (leaves (nl, B, ...))
    from a prefill's B-row cache into rows ``slots`` — one donated scatter."""
    def f(path, leaf, src):
        if _leaf_name(path) == "index":
            return leaf.at[slots].set(n_valid)
        return leaf.at[:, slots].set(src.astype(leaf.dtype))
    return jax.tree_util.tree_map_with_path(f, cache, states)


@functools.partial(jax.jit, donate_argnums=(0,))
def _paged_scatter(cache, kv, phys, off, slots, n_valid):
    """Paged admission write: scatter K/V blocks (L, B, Sb, ...) through each
    row's block table — position p of admitted row i lands in the pool at
    (phys[i, p], off[i, p]). Pad positions are scrubbed to pristine; pad
    positions past a row's leased blocks resolve to the reserved null block 0,
    which no request ever reads."""
    out = {}
    for name, leaf in cache.items():
        if name == "index":
            out[name] = leaf.at[slots].set(n_valid)
            continue
        if name == "tables":
            out[name] = leaf
            continue
        Sb = kv[name].shape[2]
        src = kv[name].astype(leaf.dtype)
        valid = jnp.arange(Sb)[None, :] < n_valid[:, None]          # (B, Sb)
        valid = valid.reshape(valid.shape + (1,) * (src.ndim - 3))
        src = jnp.where(valid, src,
                        jnp.asarray(pristine_value(name), leaf.dtype))
        out[name] = leaf.at[:, phys, off].set(src)
    return out


@functools.partial(jax.jit, donate_argnums=(0,))
def _paged_reset(cache, blocks, slot):
    """Retire a slot: scrub its (freed) blocks back to pristine, zero its
    table row, park its index. ``blocks`` is padded with 0 (the null block) to
    a fixed length so every retire shares one compiled shape."""
    out = {}
    for name, leaf in cache.items():
        if name == "index":
            out[name] = jax.lax.dynamic_update_slice(
                leaf, jnp.zeros((1,), jnp.int32), (slot,))
        elif name == "tables":
            out[name] = jax.lax.dynamic_update_slice(
                leaf, jnp.zeros((1, leaf.shape[1]), jnp.int32), (slot, 0))
        else:
            fill = jnp.full((leaf.shape[0], blocks.shape[0]) + leaf.shape[2:],
                            pristine_value(name), leaf.dtype)
            out[name] = leaf.at[:, blocks].set(fill)
    return out


@functools.partial(jax.jit, donate_argnums=(0,))
def _scrub_blocks(cache, blocks):
    """Scrub a batch of freed pool blocks back to the pristine pattern —
    the block-granular half of :func:`_paged_reset`, used when blocks free
    OUTSIDE a slot retire (LRU eviction of cached prefixes). ``blocks`` is
    padded with 0 (the null block) to a fixed length so evictions share a
    bounded set of compiled shapes."""
    out = {}
    for name, leaf in cache.items():
        if name in ("index", "tables"):
            out[name] = leaf
        else:
            fill = jnp.full((leaf.shape[0], blocks.shape[0]) + leaf.shape[2:],
                            pristine_value(name), leaf.dtype)
            out[name] = leaf.at[:, blocks].set(fill)
    return out


@functools.partial(jax.jit, donate_argnums=(0,))
def _copy_block(cache, src, dst):
    """Copy one pool block's contents (every K/V leaf, scales included) from
    ``src`` to ``dst`` — the copy-on-write fork: a prompt diverging mid-block
    gets a private copy of the shared divergence block before its slot ever
    writes into it, so the cached original stays immutable."""
    out = {}
    for name, leaf in cache.items():
        if name in ("index", "tables"):
            out[name] = leaf
        else:
            out[name] = leaf.at[:, dst].set(leaf[:, src])
    return out


@functools.partial(jax.jit, donate_argnums=(0,))
def _import_blocks_write(cache, blocks, slot, n_valid, payload):
    """Cross-host block import: write whole shipped pool blocks
    (``payload`` leaves (L, nb, bs, ...)) into the destination pool cells
    ``blocks`` and set the slot's index to the shipped valid length. The
    slot's table row was already populated by its lease; the shipped bits
    land verbatim, so the imported cache is bit-equal to the exporter's —
    which is what lets a disaggregated decode host skip prefill entirely.
    ``blocks`` is padded with 0 (the null block) and ``payload`` with
    pristine fill to a fixed width, so every import shares one compiled
    shape per pool geometry."""
    out = {}
    for name, leaf in cache.items():
        if name == "index":
            out[name] = leaf.at[slot].set(n_valid)
        elif name == "tables":
            out[name] = leaf
        else:
            out[name] = leaf.at[:, blocks].set(
                payload[name].astype(leaf.dtype))
    return out


@jax.jit
def _gather_prefix_rows(cache, tables):
    """Gather a (B, nb) block-table excerpt into contiguous K/V rows
    (L, B, nb*bs, ...) — the suffix-prefill accumulator seed: matched prefix
    blocks' entries land at their sequence positions, so the chunked scan can
    resume mid-prompt (models/serve.py ``prefill_with_cache_suffix``)."""
    pool = {name: leaf for name, leaf in cache.items()
            if name not in ("index", "tables")}
    return A.gather_block_kv(pool, tables)


@jax.jit
def _paged_gather(cache):
    """Pool → contiguous-layout view {k, v, (scales), index}: every slot's
    blocks concatenated in table order. Table entries past a slot's lease are
    0 (the null block), so those view positions hold null-block contents —
    always at positions > the slot's index, where decode masks scores to -inf
    and the softmax weight is exactly 0, keeping the view's decode bit-equal
    to the contiguous backend's."""
    pool = {name: leaf for name, leaf in cache.items()
            if name not in ("index", "tables")}
    view = A.gather_block_kv(pool, cache["tables"])
    view["index"] = cache["index"]
    return view


@functools.partial(jax.jit, donate_argnums=(0,))
def _paged_writeback(cache, view):
    """Adopt a decode-updated contiguous view back into the pool: decode wrote
    exactly one entry per row at its pre-step index, so only O(B) pool cells
    change. Rows whose table is zeroed (retired slots) write into the null
    block — harmless, it is never read unmasked."""
    index = cache["index"]                       # pre-step write positions
    tables = cache["tables"]
    B = tables.shape[0]
    bs = cache["k"].shape[2]
    S = view["k"].shape[2]
    rows = jnp.arange(B)
    pos = jnp.minimum(index, S - 1)              # idle rows: index can run on
    phys = tables[rows, pos // bs]
    off = pos % bs
    out = {}
    for name, leaf in cache.items():
        if name == "index":
            out[name] = view["index"]
        elif name == "tables":
            out[name] = leaf
        else:
            out[name] = leaf.at[:, phys, off].set(view[name][:, rows, pos])
    return out


@functools.partial(jax.jit, static_argnums=(2,), donate_argnums=(0,))
def _paged_writeback_window(cache, view, W):
    """Adopt a verify-updated contiguous view back into the pool: the verify
    step wrote W entries per row at its pre-step index..index+W-1, so O(B*W)
    pool cells change. Positions past the row length — idle rows whose index
    ran on, or verify overshoot near the end of a lease — redirect to the
    null block, exactly like the block-native verify's own writes."""
    index = cache["index"]                       # pre-step write positions
    tables = cache["tables"]
    B = tables.shape[0]
    bs = cache["k"].shape[2]
    S = view["k"].shape[2]
    rows = jnp.arange(B)
    positions = index[:, None] + jnp.arange(W, dtype=jnp.int32)[None, :]
    pos_c = jnp.minimum(positions, S - 1)
    in_range = positions < S
    phys = jnp.where(in_range, tables[rows[:, None], pos_c // bs], 0)
    off = jnp.where(in_range, pos_c % bs, 0)
    out = {}
    for name, leaf in cache.items():
        if name == "index":
            out[name] = view["index"]
        elif name == "tables":
            out[name] = leaf
        else:
            out[name] = leaf.at[:, phys, off].set(
                view[name][:, rows[:, None], pos_c])
    return out


@functools.partial(jax.jit, donate_argnums=(0,))
def _scrub_positions(cache, slots, new_index, pos):
    """Speculative rollback, contiguous layout: restore the REJECTED draft
    positions ``pos[i, :]`` of each row ``slots[i]`` to the pristine pattern
    and set the row's index to its post-acceptance value. Fixed shapes —
    ``slots`` pads with n_slots and ``pos`` with max_seq_len, both
    out-of-bounds so ``mode="drop"`` discards them — one compiled executable
    per spec-k. Correctness-critical, not hygiene: future verify horizons
    reach these positions, so a stale rejected entry would perturb scores."""
    out = {}
    for name, leaf in cache.items():
        if name == "index":
            out[name] = leaf.at[slots].set(new_index, mode="drop")
            continue
        fill = jnp.full((leaf.shape[0],) + pos.shape + leaf.shape[3:],
                        pristine_value(name), leaf.dtype)
        out[name] = leaf.at[:, slots[:, None], pos].set(fill, mode="drop")
    return out


@functools.partial(jax.jit, donate_argnums=(0,))
def _paged_scrub_positions(cache, phys, off, slots, new_index):
    """Speculative rollback, paged layout: same contract as
    :func:`_scrub_positions` with the (slot, position) -> (phys, off)
    translation done host-side through the table mirror. Pad entries and
    positions past a row's length arrive pre-redirected to the null block 0 —
    scrubbing the null block to pristine is harmless (it is never read
    unmasked) and keeps the scatter shape fixed."""
    out = {}
    for name, leaf in cache.items():
        if name == "index":
            out[name] = leaf.at[slots].set(new_index, mode="drop")
        elif name == "tables":
            out[name] = leaf
        else:
            fill = jnp.full((leaf.shape[0],) + phys.shape + leaf.shape[3:],
                            pristine_value(name), leaf.dtype)
            out[name] = leaf.at[:, phys, off].set(fill)
    return out


@jax.jit
def _select_snapshot_rows(stacked, sel):
    """Per-slot select over stacked recurrent-state snapshots: leaf
    (N, L, B, ...) + sel (B,) -> (L, B, ...) where row b comes from snapshot
    sel[b]. The recurrent-draft rollback: a draft that consumed m accepted
    tokens adopts snapshot m wholesale — recurrent state has no per-position
    axis to scrub, so rollback is selection, not un-writing."""
    def f(path, leaf):
        if _leaf_name(path) == "index":
            return leaf[sel, jnp.arange(leaf.shape[1])]
        picked = leaf[sel, :, jnp.arange(sel.shape[0])]   # (B, L, ...)
        return jnp.moveaxis(picked, 0, 1)
    return jax.tree_util.tree_map_with_path(f, stacked)


# ===========================================================================
# the protocol + backends
# ===========================================================================

class SlotStore(abc.ABC):
    """Slot-granular ownership of the decode batch's cache (see module doc).
    Subclasses implement ``alloc`` and ``write_slots``; the row-generic
    lifecycle (write_slot / reset / decode bridge) is shared."""

    #: Backend identifier ("contiguous" | "paged" | "recurrent") — keys the
    #: engine's compiled-step cache and the ``memory_stats()["backend"]``
    #: telemetry field.
    kind: str = "abstract"

    def __init__(self, cfg: ArchConfig, n_slots: int, max_seq_len: int):
        self.cfg = cfg
        self.n_slots = n_slots
        self.max_seq_len = max_seq_len
        self.cache: Dict = self.alloc()
        self.alloc_count = 1

    # ------------------------------------------------------------ allocation

    @abc.abstractmethod
    def alloc(self) -> Dict:
        """Build the backing cache pytree. Called exactly once."""

    # ----------------------------------------------------------- reservation

    def fits(self, prompt_len: int, max_new_tokens: int) -> bool:
        """Whether a request of this size could EVER be leased (checked
        against total capacity, not current occupancy). The engine rejects
        at submit() when False — a request that can never fit must bounce at
        the door, not park at the queue head deferring forever and
        head-of-line-blocking everything behind it."""
        return True

    def lease(self, slot: int, prompt_len: int, max_new_tokens: int,
              tokens: Optional[np.ndarray] = None) -> bool:
        """Reserve capacity for a request on ``slot``. Returns False when the
        backend cannot hold it right now (admission backpressure) — the
        scheduler then leaves the request queued, FIFO order intact.
        ``tokens`` (the prompt ids) lets a prefix-aware backend match the
        prompt against cached content at reservation time; backends without
        a prefix cache ignore it."""
        return True

    def available_now(self, prompt_len: int, max_new_tokens: int) -> bool:
        """Whether a ``lease`` for this request would succeed RIGHT NOW,
        without reserving anything — ``fits`` asks about total capacity,
        this asks about current occupancy. The multi-host router uses it as
        the spill signal: a pinned host whose pool is dry should shed the
        request to the least-loaded host instead of queueing behind the
        backpressure (serving/router.py). Default True: the contiguous and
        recurrent backends bound admission by free slots, which the
        scheduler owns, not by store occupancy."""
        return self.fits(prompt_len, max_new_tokens)

    # ------------------------------------------------------------- lifecycle

    @abc.abstractmethod
    def write_slots(self, slots: Sequence[int], payload: Dict,
                    n_valid: Sequence[int]) -> None:
        """Seed all leased rows of one admission bucket from the fused
        prefill's payload (K/V block or recurrent state rows) — one batched
        donated scatter."""

    def write_slot(self, slot: int, src_cache: Dict, n_valid: int) -> None:
        """Seed ``slot`` from a single-request (B=1, full-length) cache — the
        replay-seeding reference path, exercised only by tests."""
        assert 0 <= slot < self.n_slots
        self.cache = _write_row(self.cache, src_cache, jnp.int32(slot),
                                jnp.int32(n_valid))

    def reset(self, slot: int) -> None:
        """Retire a request: scrub the row so state can never leak into the
        slot's next tenant, and park the index at 0."""
        assert 0 <= slot < self.n_slots
        self.cache = _reset_row(self.cache, jnp.int32(slot))

    def reset_slot(self, slot: int) -> None:
        """Back-compat alias for :meth:`reset` from the KVSlotManager era."""
        self.reset(slot)

    # ---------------------------------------------------------- decode bridge

    def decode_cache(self) -> Dict:
        """The pytree handed to the jitted decode step (donated)."""
        return self.cache

    def swap(self, new_cache: Dict) -> None:
        """Adopt the cache pytree returned by a decode step (the old buffers
        were donated to it)."""
        self.cache = new_cache

    def swap_window(self, new_cache: Dict, window: int) -> None:
        """Adopt the cache returned by a W-position verify step (speculative
        decode). Backends whose decode bridge is the cache itself just swap;
        the paged gather bridge overrides this to scatter all W written
        entries per row back into block layout."""
        self.swap(new_cache)

    def rollback(self, slots, new_index, positions) -> None:
        """Speculative rollback: scrub the rejected draft positions
        ``positions[i, :]`` (pad: max_seq_len) of each row ``slots[i]``
        (pad: n_slots) back to pristine and set the surviving rows' index to
        ``new_index[i]``. Fixed-shape host arrays — one compiled scrub per
        spec-k, regardless of the per-slot acceptance pattern."""
        raise NotImplementedError(
            f"{self.kind} store does not support speculative rollback")

    def gather_view(self) -> Dict:
        """Contiguous-layout view of the cache (inspection / tests)."""
        return self.cache

    # ------------------------------------------------------------------ info

    def slot_index(self, slot: int) -> int:
        """The slot's current write position (== valid sequence length for
        K/V backends): 0 for a pristine slot, the prompt length right after
        admission, advancing by one per decode step. Device sync per call —
        inspection/tests, not the decode hot path."""
        return int(self.cache["index"][slot])

    def nbytes(self) -> int:
        """Total RESIDENT bytes of the backing cache pytree (every leaf,
        block tables and index planes included). Transient decode-time
        allocations — e.g. the paged gather-bridge view — are NOT in here;
        see ``memory_stats()["decode_view_bytes"]``."""
        return sum(leaf.size * leaf.dtype.itemsize
                   for leaf in jax.tree.leaves(self.cache))

    def memory_stats(self) -> Dict:
        """Occupancy/byte telemetry dict for this backend — always carries
        ``backend`` and ``bytes`` (resident allocation); backends add their
        own keys (paged: block occupancy, ``decode_view_bytes``,
        ``table_uploads``). Surfaced as ``Engine.stats()["cache"]`` and
        rendered one-line by ``metrics.format_memory_stats``; field-by-field
        documentation lives in docs/serving.md."""
        b = self.nbytes()
        return {"backend": self.kind, "bytes": b,
                "bytes_per_slot": b // max(self.n_slots, 1),
                "slots": self.n_slots}


class ContiguousKVStore(SlotStore):
    """Dense-family K/V rows sized to ``max_seq_len`` — the original
    ``KVSlotManager`` layout. Leaf layout: k/v (L, B, S, KV, hd) and scales
    (L, B, S, KV) carry the slot on axis 1; index (B,) on axis 0."""

    kind = "contiguous"

    def __init__(self, cfg: ArchConfig, n_slots: int, max_seq_len: int):
        if cfg.family not in DENSE_FAMILIES:
            raise ValueError(
                f"ContiguousKVStore supports dense-family caches, not "
                f"{cfg.family}")
        super().__init__(cfg, n_slots, max_seq_len)

    def alloc(self) -> Dict:
        return SV.init_cache(self.cfg, self.n_slots, self.max_seq_len,
                             per_slot_index=True)

    def write_slots(self, slots, kv: Dict, n_valid) -> None:
        slots = jnp.asarray(slots, jnp.int32)
        n_valid = jnp.asarray(n_valid, jnp.int32)
        assert slots.shape == n_valid.shape and slots.ndim == 1
        self.cache = _scatter_kv_rows(self.cache, kv, slots, n_valid)

    def rollback(self, slots, new_index, positions) -> None:
        self.cache = _scrub_positions(self.cache,
                                      jnp.asarray(slots, jnp.int32),
                                      jnp.asarray(new_index, jnp.int32),
                                      jnp.asarray(positions, jnp.int32))


class PagedKVStore(SlotStore):
    """vLLM-style block-paged K/V. Pool leaves: k/v (L, NB, bs, KV, hd) and
    scales (L, NB, bs, KV); per-slot block tables (B, MB) map sequence
    position p to pool cell (tables[slot, p // bs], p % bs). Block 0 is the
    reserved null block: never leased, absorbs idle-slot writes, and backs
    table entries past a slot's lease so gathers stay in-bounds.

    A request leases exactly ceil((prompt + gen) / bs) blocks at admission —
    the whole-generation reservation means decode can never run out of blocks
    mid-flight, and ``lease`` returning False is clean backpressure. The pool
    (``n_blocks``) can therefore be sized well below the contiguous
    n_slots x max_seq_len footprint for short-request mixes.

    With ``prefix_cache=True`` the store additionally keeps a shared-prefix
    radix cache over the pool (module docstring): per-block refcounts, a trie
    of full prompt blocks keyed on chained token-id hashes, copy-on-write
    forks at mid-block divergence, and LRU eviction of unreferenced cached
    prefixes under pool pressure. ``lease`` then takes the prompt ``tokens``
    and leases matched blocks by refcount instead of drawing fresh ones —
    ``prefix_lease_info`` tells the engine how much prefill to skip.

    Cross-host shipping (prefill/decode disaggregation):
    ``export_blocks`` serializes a slot's written blocks into a
    layout-tagged, checksummed payload and parks the lease in an export
    ledger (blocks stay referenced until ``release_exported`` — no re-lease
    of an unacked block); ``import_blocks`` validates and adopts such a
    payload into a freshly leased slot on another host's pool, bit-exactly,
    with zero prefill dispatches on the importing engine.
    """

    kind = "paged"

    def __init__(self, cfg: ArchConfig, n_slots: int, max_seq_len: int,
                 *, block_size: int = 16, n_blocks: Optional[int] = None,
                 native: bool = False, prefix_cache: bool = False):
        if cfg.family not in DENSE_FAMILIES:
            raise ValueError(
                f"PagedKVStore supports dense-family caches, not {cfg.family}")
        if max_seq_len % block_size:
            # the gathered view must be exactly max_seq_len long so the decode
            # step compiles to the same program as the contiguous backend —
            # the bit-identity contract
            raise ValueError(
                f"block_size {block_size} must divide max_seq_len {max_seq_len}")
        self.block_size = block_size
        self.blocks_per_slot = max_seq_len // block_size
        full = n_slots * self.blocks_per_slot + 1          # +1: null block
        self.n_blocks = full if n_blocks is None else n_blocks
        if not 2 <= self.n_blocks:
            raise ValueError(f"n_blocks must be >= 2, got {self.n_blocks}")
        # native: decode_cache/swap hand the pool straight to/from the
        # block-native decode step (models/serve.py decode_paged) — no
        # gather-bridge view, decode_view_bytes == 0
        self.native = native
        super().__init__(cfg, n_slots, max_seq_len)
        # block 0 reserved as the null block; free blocks hand out low ids first
        self._free: List[int] = list(range(1, self.n_blocks))[::-1]
        self._leased: Dict[int, List[int]] = {}
        self._tables = np.zeros((n_slots, self.blocks_per_slot), np.int32)
        # ---- shared-prefix radix cache state (maintained even when the
        # feature is off: refcounts make reset's scrub decision uniform) ----
        self.prefix_cache = prefix_cache
        # per-block lease refcount: 0 = free or cached-unreferenced,
        # n>0 = leased by n slots (shared prefix blocks can exceed 1)
        self._ref = np.zeros(self.n_blocks, np.int64)
        # radix trie over FULL prompt blocks. Node 0 is the root (no block);
        # each other node owns exactly one pool block holding one full block
        # of some previously admitted prompt. Children are keyed by the
        # child block's token hash; stored token ids disambiguate collisions.
        self._nodes: Dict[int, Dict] = {
            0: {"parent": -1, "hash": 0, "block": 0, "tokens": None,
                "kids": {}, "children": 0, "tick": 0}}
        self._block_node: Dict[int, int] = {}     # pool block -> trie node
        self._node_ids = 1
        self._lru_tick = 0
        # per-slot prefix-lease metadata (prefix mode only): what matched,
        # where suffix prefill starts, whether a COW fork happened
        self._slot_meta: Dict[int, Dict] = {}
        # cross-host export ledger: payload_id -> the blocks an exported
        # slot's lease transferred to (rtp-llm RequestBlockBuffer shape).
        # The ledger HOLDS the lease refcount until release_exported(), so
        # an exported-but-unacked block can never be re-leased as fresh —
        # it stays "referenced" in the census until the importer acks.
        self._exported: Dict[str, List[int]] = {}
        self.blocks_exported = 0
        self.blocks_imported = 0
        self.prefix_hits = 0
        self.prefix_blocks_reused = 0
        self.prefix_tokens_reused = 0
        self.prefix_evictions = 0
        self.cow_forks = 0
        # table uploads are batched: leases mutate only the host mirror and
        # mark it dirty; _sync_tables uploads ONCE when the device next needs
        # the tables (decode/gather) — one upload per admission round instead
        # of one per lease. table_uploads is the regression counter.
        self._tables_dirty = False
        self.table_uploads = 0

    def alloc(self) -> Dict:
        return SV.init_paged_cache(self.cfg, self.n_slots, self.n_blocks,
                                   self.block_size, self.blocks_per_slot)

    # ----------------------------------------------------------- reservation

    def _blocks_needed(self, prompt_len: int, max_new_tokens: int) -> int:
        return math.ceil((prompt_len + max_new_tokens) / self.block_size)

    def fits(self, prompt_len: int, max_new_tokens: int) -> bool:
        # against the WHOLE pool and the table width: a request needing more
        # blocks than exist is unservable and must be rejected at submit,
        # never deferred (lease would refuse it forever — livelock)
        return (self._blocks_needed(prompt_len, max_new_tokens)
                <= min(self.n_blocks - 1, self.blocks_per_slot))

    def _n_evictable(self) -> int:
        """Blocks reclaimable from the prefix cache: cached blocks no live
        lease references. Counts the whole unreferenced set, not just current
        leaves — evicting leaf-first exposes parents, so the full set IS
        reachable by the eviction loop whenever nothing holds a reference
        into it (the zero-active livelock case the engine guards)."""
        if not self.prefix_cache:
            return 0
        return sum(1 for b in self._block_node if self._ref[b] == 0)

    def available_now(self, prompt_len: int, max_new_tokens: int) -> bool:
        # the router's spill signal: lease would refuse (pool dry) even
        # though fits() says the request is servable in principle. Cached
        # but unreferenced prefix blocks count as available — lease evicts
        # them before refusing, so caching never manufactures backpressure.
        need = self._blocks_needed(prompt_len, max_new_tokens)
        return (need <= len(self._free) + self._n_evictable()
                and need <= self.blocks_per_slot)

    # ----------------------------------------------------- prefix radix trie

    def _tick(self) -> int:
        self._lru_tick += 1
        return self._lru_tick

    def _block_hash(self, blk: np.ndarray) -> int:
        return zlib.crc32(np.ascontiguousarray(blk, np.int32).tobytes())

    def _match_prefix(self, tokens: np.ndarray, prompt_len: int):
        """Walk the trie with the prompt's full blocks. Returns
        ``(matched_node_ids, fork_src_block)``: the chain of cached nodes
        whose blocks hold the prompt's leading full blocks verbatim, plus —
        when every full block matched AND the prompt's partial tail (r =
        prompt_len mod bs tokens) matches the first r tokens of some cached
        child — that child's block as the copy-on-write fork source."""
        node, matched = 0, []
        full = prompt_len // self.block_size
        for i in range(full):
            blk = tokens[i * self.block_size:(i + 1) * self.block_size]
            kid = self._nodes[node]["kids"].get(self._block_hash(blk))
            if kid is None or not np.array_equal(self._nodes[kid]["tokens"], blk):
                return matched, None          # divergence at a block boundary
            matched.append(kid)
            node = kid
        r = prompt_len - full * self.block_size
        if r:
            # mid-block divergence: any cached child whose first r tokens
            # equal the prompt's tail is a fork source — its block already
            # holds the tail's K/V entries bit-exactly (freshest tick wins)
            best = None
            for kid in self._nodes[node]["kids"].values():
                nd = self._nodes[kid]
                if np.array_equal(nd["tokens"][:r], tokens[full * self.block_size:
                                                           prompt_len]):
                    if best is None or nd["tick"] > self._nodes[best]["tick"]:
                        best = kid
            if best is not None:
                return matched, self._nodes[best]["block"]
        return matched, None

    def _evict_cached(self, n: int, pinned: frozenset) -> None:
        """Free up to ``n`` pool blocks by evicting unreferenced cached
        prefixes, least-recently-used LEAF first (an interior node only
        becomes evictable once its children are gone — evicting it earlier
        would orphan them). ``pinned`` protects blocks the in-progress lease
        is about to reference. Evicted blocks are scrubbed to pristine before
        rejoining the free list — a cached block re-leased as fresh must be
        bit-equal to a never-used one."""
        freed: List[int] = []
        while len(freed) < n:
            best = None
            for nid, nd in self._nodes.items():
                if (nid == 0 or nd["children"] or nd["block"] in pinned
                        or self._ref[nd["block"]] > 0):
                    continue
                if best is None or nd["tick"] < self._nodes[best]["tick"]:
                    best = nid
            if best is None:
                break
            nd = self._nodes.pop(best)
            parent = self._nodes[nd["parent"]]
            del parent["kids"][nd["hash"]]
            parent["children"] -= 1
            del self._block_node[nd["block"]]
            freed.append(nd["block"])
            self.prefix_evictions += 1
        if freed:
            self._scrub_free(freed)

    def _scrub_free(self, blocks: List[int]) -> None:
        """Scrub freed blocks to pristine and return them to the free list —
        chunked to ``blocks_per_slot``-sized shapes (null-padded) so scrubs
        share the retire path's compiled executables."""
        w = self.blocks_per_slot
        for i in range(0, len(blocks), w):
            chunk = blocks[i:i + w]
            padded = chunk + [0] * (w - len(chunk))
            self.cache = _scrub_blocks(self.cache,
                                       jnp.asarray(padded, jnp.int32))
        self._free.extend(blocks)

    # ----------------------------------------------------------------- lease

    def lease(self, slot: int, prompt_len: int, max_new_tokens: int,
              tokens: Optional[np.ndarray] = None) -> bool:
        need = self._blocks_needed(prompt_len, max_new_tokens)
        if need > self.blocks_per_slot:
            return False
        shared_nodes: List[int] = []
        fork_src: Optional[int] = None
        if self.prefix_cache and tokens is not None:
            tokens = np.asarray(tokens, np.int32).reshape(-1)
            assert len(tokens) == prompt_len
            shared_nodes, fork_src = self._match_prefix(tokens, prompt_len)
        shared = [self._nodes[n]["block"] for n in shared_nodes]
        need_fresh = need - len(shared)
        if need_fresh > len(self._free):
            pinned = frozenset(shared if fork_src is None
                               else shared + [fork_src])
            self._evict_cached(need_fresh - len(self._free), pinned)
        if need_fresh > len(self._free):
            return False
        tick = self._tick()
        for nid in shared_nodes:
            self._nodes[nid]["tick"] = tick
            self._ref[self._nodes[nid]["block"]] += 1
        fresh: List[int] = []
        for _ in range(need_fresh):
            b = self._free.pop()
            # teeth: a block handed out as fresh must be wholly unowned —
            # leasing a still-referenced or still-cached block as private
            # would let one slot scribble over another's (or the cache's) bits
            assert self._ref[b] == 0 and b not in self._block_node, (
                f"block {b} leased as fresh while referenced/cached "
                f"(ref={self._ref[b]})")
            self._ref[b] = 1
            fresh.append(b)
        blocks = shared + fresh
        self._leased[slot] = blocks
        self._tables[slot, :] = 0
        self._tables[slot, :need] = blocks
        # host mirror only — the device copy syncs lazily (one upload per
        # admission round, not one per lease; admission writes themselves
        # address blocks through the host mirror)
        self._tables_dirty = True
        shared_tok = len(shared) * self.block_size
        matched_tok = shared_tok
        if fork_src is not None:
            # COW fork: the divergence block's leading tokens are the
            # prompt's tail — copy it into the slot's first private block so
            # those entries exist without recomputation AND the cached
            # original stays immutable when decode writes mid-block
            self.cache = _copy_block(self.cache, jnp.int32(fork_src),
                                     jnp.int32(fresh[0]))
            self.cow_forks += 1
            matched_tok = prompt_len
        if self.prefix_cache and tokens is not None:
            # always recompute at least the last prompt position: admission
            # must produce the first token's logits from this dispatch
            start = min(matched_tok, prompt_len - 1)
            self._slot_meta[slot] = {
                "tokens": tokens.copy(), "prompt_len": prompt_len,
                "shared_tokens": shared_tok, "prefill_start": start,
                "forked": fork_src is not None, "committed": False}
            if shared or fork_src is not None:
                self.prefix_hits += 1
                self.prefix_blocks_reused += len(shared)
                self.prefix_tokens_reused += start
        return True

    def prefix_lease_info(self, slot: int) -> Dict:
        """What the prefix cache did for this slot's lease: ``hit``,
        ``shared_blocks``/``shared_tokens`` (whole cached blocks leased by
        refcount — immutable, never written by this slot), ``forked``
        (a COW fork supplied the mid-block tail), and ``prefill_start`` —
        the first sequence position admission must still compute. The engine
        floors its suffix dispatch at ``prefill_start // block_size`` chunks."""
        meta = self._slot_meta.get(slot)
        if meta is None:
            return {"hit": False, "shared_blocks": 0, "shared_tokens": 0,
                    "forked": False, "prefill_start": 0}
        return {"hit": meta["shared_tokens"] > 0 or meta["forked"],
                "shared_blocks": meta["shared_tokens"] // self.block_size,
                "shared_tokens": meta["shared_tokens"],
                "forked": meta["forked"],
                "prefill_start": meta["prefill_start"]}

    def _sync_tables(self) -> None:
        if self._tables_dirty:
            self.cache = dict(self.cache, tables=jnp.asarray(self._tables))
            self.table_uploads += 1
            self._tables_dirty = False

    # ------------------------------------------------------------- lifecycle

    def _phys_off(self, slots: np.ndarray, length: int):
        """(B, length) physical block + offset (host arrays) for sequence
        positions 0..length-1 of each slot, through the block tables."""
        pos = np.arange(length)
        blk, off = pos // self.block_size, pos % self.block_size
        phys = self._tables[slots][:, blk].copy()           # (B, length)
        return phys, np.broadcast_to(off, phys.shape)

    def _redirect_shared(self, slots_np: np.ndarray,
                         phys: np.ndarray, length: int) -> np.ndarray:
        """Shared prefix blocks are immutable: point each slot's shared
        positions at the null block so the admission scatter's writes there
        land harmlessly (the cached entries already hold those positions'
        K/V bit-exactly — that is what the lease matched)."""
        for i, s in enumerate(slots_np):
            meta = self._slot_meta.get(int(s))
            if meta and meta["shared_tokens"]:
                phys[i, :min(meta["shared_tokens"], length)] = 0
        return phys

    def write_slots(self, slots, kv: Dict, n_valid) -> None:
        slots_np = np.asarray(slots, np.int32)
        Sb = kv["k"].shape[2]
        phys, off = self._phys_off(slots_np, Sb)
        phys = self._redirect_shared(slots_np, phys, Sb)
        self.cache = _paged_scatter(self.cache, kv,
                                    jnp.asarray(phys, jnp.int32),
                                    jnp.asarray(off, jnp.int32),
                                    jnp.asarray(slots_np),
                                    jnp.asarray(n_valid, jnp.int32))
        for s in slots_np:
            self._commit_prefix(int(s))

    def write_slot(self, slot: int, src_cache: Dict, n_valid: int) -> None:
        assert 0 <= slot < self.n_slots
        kv = {name: src_cache[name] for name in self.cache
              if name not in ("index", "tables")}
        slots_np = np.asarray([slot], np.int32)
        phys, off = self._phys_off(slots_np, kv["k"].shape[2])
        phys = self._redirect_shared(slots_np, phys, kv["k"].shape[2])
        self.cache = _paged_scatter(self.cache, kv,
                                    jnp.asarray(phys, jnp.int32),
                                    jnp.asarray(off, jnp.int32),
                                    jnp.asarray([slot], jnp.int32),
                                    jnp.asarray([n_valid], jnp.int32))
        self._commit_prefix(slot)

    def _commit_prefix(self, slot: int) -> None:
        """After a slot's prompt K/V is fully written, register its FULL
        prompt blocks in the radix trie so later prompts can lease them.
        Blocks already cached along the chain are skipped (the slot shares
        them — its table points at the very same blocks); the slot's private
        full blocks become new trie nodes. Partial-tail and generation
        blocks never enter the trie: only positions covered by the prompt
        are immutable-by-construction."""
        meta = self._slot_meta.get(slot)
        if meta is None or meta["committed"]:
            return
        meta["committed"] = True
        tokens, L = meta["tokens"], meta["prompt_len"]
        node = 0
        for i in range(L // self.block_size):
            blk = tokens[i * self.block_size:(i + 1) * self.block_size]
            h = self._block_hash(blk)
            kid = self._nodes[node]["kids"].get(h)
            if kid is not None:
                if not np.array_equal(self._nodes[kid]["tokens"], blk):
                    break          # hash collision: stop caching this chain
                node = kid
                continue
            b = int(self._tables[slot, i])
            if b == 0 or b in self._block_node:
                break              # defensive: never alias a cached block
            nid = self._node_ids
            self._node_ids += 1
            self._nodes[nid] = {"parent": node, "hash": h, "block": b,
                                "tokens": blk.copy(), "kids": {},
                                "children": 0, "tick": self._tick()}
            self._nodes[node]["kids"][h] = nid
            self._nodes[node]["children"] += 1
            self._block_node[b] = nid
            node = nid

    def commit_prefix(self, slot: int) -> None:
        """Public trie registration hook. ``write_slots``/``write_slot`` call
        it automatically once a slot's prompt K/V is written; the property
        test drives it directly to exercise the trie bookkeeping without a
        device prefill."""
        self._commit_prefix(slot)

    def reset(self, slot: int) -> None:
        """Retire a slot: decrement every leased block's refcount, then
        scrub + free ONLY blocks that hit zero AND are not held by the
        prefix trie. A block another slot still references, or a cached
        prefix awaiting its next hit, must survive the retire bit-intact —
        scrubbing by lease list alone would corrupt shared state (the teeth
        test in tests/test_prefix_cache.py proves that failure is caught)."""
        assert 0 <= slot < self.n_slots
        blocks = self._leased.pop(slot, [])
        self._slot_meta.pop(slot, None)
        scrub: List[int] = []
        for b in blocks:
            assert self._ref[b] > 0, f"double-free of block {b}"
            self._ref[b] -= 1
            if self._ref[b] == 0:
                if b in self._block_node:
                    # cached: stays resident (LRU-evictable from now on)
                    self._nodes[self._block_node[b]]["tick"] = self._tick()
                else:
                    self._free.append(b)
                    scrub.append(b)
        self._tables[slot, :] = 0
        # pad with the null block to a fixed length: one compiled reset shape
        padded = scrub + [0] * (self.blocks_per_slot - len(scrub))
        # _paged_reset zeroes the slot's device-side table row itself — only
        # the host mirror needed updating above
        self.cache = _paged_reset(self.cache, jnp.asarray(padded, jnp.int32),
                                  jnp.int32(slot))

    # ------------------------------------------------- cross-host shipping

    def _payload_crc(self, header: str, leaves: Dict[str, np.ndarray]) -> int:
        """Checksum over the payload header + every leaf's raw bytes (name
        order fixed). Import recomputes and refuses on mismatch — a frame
        corrupted in flight must surface as an error, never as silently
        wrong cache bits."""
        crc = zlib.crc32(header.encode())
        for name in sorted(leaves):
            crc = zlib.crc32(np.ascontiguousarray(leaves[name]).tobytes(),
                             crc)
        return crc

    def export_blocks(self, slot: int, *, payload_id: str) -> Dict:
        """Serialize ``slot``'s written cache blocks for shipping to another
        host's pool and move the slot's lease into the export ledger. The
        payload carries a layout tag (block size + per-leaf dtype/shape, so
        int8-KV scales travel with their blocks), the valid length, the raw
        block contents for every position written so far, and a checksum.

        Refcount correctness: the slot's reference on each leased block
        TRANSFERS to the ledger entry — nothing is decremented, scrubbed, or
        freed here, so shared prefix blocks stay intact and no exported
        block can be re-leased while the ship is in flight. The slot itself
        is cleared (table row zeroed, index parked) and is immediately
        reusable. ``release_exported`` settles the ledger once the importer
        acked (or the router gave up and fell back to re-prefill)."""
        if payload_id in self._exported:
            raise ValueError(f"payload id {payload_id!r} already exported")
        blocks = self._leased.pop(slot, None)
        if blocks is None:
            raise KeyError(f"slot {slot} holds no lease to export")
        self._slot_meta.pop(slot, None)
        n_valid = int(np.asarray(self.cache["index"])[slot])
        nb = math.ceil(n_valid / self.block_size)
        # gather at a FIXED index width (null-block pad), then slice on the
        # host: every export shares one compiled gather per pool geometry
        # instead of compiling per block count — ships stay O(copy), not
        # O(XLA compile)
        idx = np.zeros((self.blocks_per_slot,), np.int32)
        idx[:nb] = blocks[:nb]
        idx_dev = jnp.asarray(idx)
        leaves = {
            name: np.asarray(leaf[:, idx_dev])[:, :nb] for name, leaf in
            self.cache.items() if name not in ("index", "tables")}
        header = f"{payload_id}:{n_valid}:{nb}:{self.block_size}"
        payload = {
            "payload_id": payload_id,
            "n_valid": n_valid,
            "n_blocks": nb,
            "layout": {
                "block_size": self.block_size,
                "leaves": {name: {"dtype": str(arr.dtype),
                                  "shape": [int(s) for s in arr.shape]}
                           for name, arr in leaves.items()},
            },
            "leaves": leaves,
            "crc": self._payload_crc(header, leaves),
        }
        self._exported[payload_id] = blocks
        self.blocks_exported += nb
        # clear the slot WITHOUT scrubbing its blocks (the ledger owns them
        # now): the all-null pad means _paged_reset scrubs only block 0
        self._tables[slot, :] = 0
        self.cache = _paged_reset(
            self.cache, jnp.zeros((self.blocks_per_slot,), jnp.int32),
            jnp.int32(slot))
        return payload

    def release_exported(self, payload_id: str) -> bool:
        """Settle one export-ledger entry: drop the ledger's reference on
        every block it held, scrubbing + freeing the ones that hit zero —
        exactly ``reset``'s decision per block, so trie-cached and
        still-shared blocks survive. Idempotent: releasing an unknown (or
        already-released) payload id is a no-op returning False, which is
        what makes a retried ack safe."""
        blocks = self._exported.pop(payload_id, None)
        if blocks is None:
            return False
        scrub: List[int] = []
        for b in blocks:
            assert self._ref[b] > 0, f"double-free of exported block {b}"
            self._ref[b] -= 1
            if self._ref[b] == 0:
                if b in self._block_node:
                    self._nodes[self._block_node[b]]["tick"] = self._tick()
                else:
                    scrub.append(b)
        if scrub:
            self._scrub_free(scrub)
        return True

    def import_blocks(self, slot: int, payload: Dict) -> None:
        """Adopt a shipped block payload into ``slot``'s freshly leased
        blocks: validate the layout tag and checksum against this pool,
        then write the shipped bits verbatim and set the slot's index to
        the shipped valid length — the imported cache is bit-equal to the
        exporter's, so decode continues with zero prefill dispatches.
        Raises ValueError on any mismatch (geometry, dtype, truncation,
        checksum) BEFORE touching device state; the caller unwinds the
        lease with ``reset``. Imported blocks are private to the slot and
        are never registered in the prefix trie (their token identity is
        the exporter's concern, not this pool's)."""
        blocks = self._leased.get(slot)
        if blocks is None:
            raise KeyError(f"slot {slot} holds no lease to import into")
        layout = payload.get("layout") or {}
        if int(layout.get("block_size", -1)) != self.block_size:
            raise ValueError(
                f"shipped block_size {layout.get('block_size')} != pool "
                f"block_size {self.block_size}")
        n_valid = int(payload["n_valid"])
        nb = int(payload["n_blocks"])
        if nb != math.ceil(n_valid / self.block_size):
            raise ValueError(
                f"shipped payload claims {nb} blocks for n_valid {n_valid} "
                f"(block_size {self.block_size})")
        if nb > len(blocks):
            raise ValueError(
                f"shipped payload needs {nb} blocks but the lease holds "
                f"{len(blocks)}")
        leaves = payload.get("leaves") or {}
        names = {n for n in self.cache if n not in ("index", "tables")}
        if set(leaves) != names or set(layout.get("leaves") or {}) != names:
            raise ValueError(
                f"shipped leaves {sorted(leaves)} != pool leaves "
                f"{sorted(names)} (kv dtype/scale layout mismatch)")
        for name in sorted(names):
            arr = np.asarray(leaves[name])
            pool_leaf = self.cache[name]
            want = ((pool_leaf.shape[0], nb) + tuple(pool_leaf.shape[2:]))
            tag = layout["leaves"][name]
            if (str(arr.dtype) != str(tag["dtype"])
                    or list(arr.shape) != [int(s) for s in tag["shape"]]):
                raise ValueError(
                    f"shipped leaf {name!r} does not match its layout tag "
                    f"(payload truncated or corrupted)")
            if (tuple(arr.shape) != want
                    or str(arr.dtype) != str(pool_leaf.dtype)):
                raise ValueError(
                    f"shipped leaf {name!r} {arr.dtype}{list(arr.shape)} "
                    f"does not fit pool leaf {pool_leaf.dtype}"
                    f"{[want[0], nb] + list(want[2:])}")
            leaves[name] = arr
        header = (f"{payload['payload_id']}:{n_valid}:{nb}:"
                  f"{self.block_size}")
        if self._payload_crc(header, leaves) != int(payload["crc"]):
            raise ValueError(
                f"shipped payload {payload['payload_id']!r} failed its "
                f"checksum — refusing to import corrupt blocks")
        dst = blocks[:nb] + [0] * (self.blocks_per_slot - nb)
        padded = {}
        for name in names:
            pool_leaf = self.cache[name]
            full = np.full(
                (pool_leaf.shape[0], self.blocks_per_slot)
                + tuple(pool_leaf.shape[2:]),
                pristine_value(name), np.asarray(leaves[name]).dtype)
            full[:, :nb] = leaves[name]
            padded[name] = jnp.asarray(full)
        self.cache = _import_blocks_write(
            self.cache, jnp.asarray(dst, jnp.int32), jnp.int32(slot),
            jnp.int32(n_valid), padded)
        self.blocks_imported += nb

    # ---------------------------------------------------------- decode bridge

    def decode_cache(self) -> Dict:
        """Native mode: the pool pytree itself (blocks + tables + index) —
        the block-native decode step attends through the tables in place.
        Bridge mode: gather every slot's blocks into the contiguous view the
        shared decode step consumes — layout translation lives HERE, the
        decode math (and its compiled program) is byte-for-byte the
        contiguous backend's."""
        self._sync_tables()
        if self.native:
            return self.cache
        return _paged_gather(self.cache)

    def swap(self, new_cache: Dict) -> None:
        if self.native:
            self.cache = new_cache                # pool in, pool out
        else:
            self.cache = _paged_writeback(self.cache, new_cache)

    def swap_window(self, new_cache: Dict, window: int) -> None:
        if self.native:
            self.cache = new_cache                # pool in, pool out
        else:
            self.cache = _paged_writeback_window(self.cache, new_cache,
                                                 int(window))

    def rollback(self, slots, new_index, positions) -> None:
        """Un-write rejected draft positions through the block tables. A
        rejected position always lands in a PRIVATE cell: generation
        positions start at prompt_len, past every shared prefix block, and
        within the whole-generation lease — so scrubbing can never touch a
        shared or foreign block. Positions past the lease (verify overshoot
        near max_seq_len) and pad entries redirect to the null block, the
        same machinery admission uses for shared-position writes."""
        slots_np = np.asarray(slots, np.int64)
        pos_np = np.asarray(positions, np.int64)
        valid = (slots_np < self.n_slots)[:, None] & (pos_np < self.max_seq_len)
        safe_slots = np.where(slots_np < self.n_slots, slots_np, 0)
        pos_c = np.where(valid, pos_np, 0)
        phys = np.where(valid,
                        self._tables[safe_slots[:, None],
                                     pos_c // self.block_size], 0)
        off = np.where(valid, pos_c % self.block_size, 0)
        self.cache = _paged_scrub_positions(
            self.cache,
            jnp.asarray(phys, jnp.int32), jnp.asarray(off, jnp.int32),
            jnp.asarray(slots_np, jnp.int32),
            jnp.asarray(new_index, jnp.int32))

    def gather_view(self) -> Dict:
        self._sync_tables()
        return _paged_gather(self.cache)

    def gather_prefix_rows(self, slots: Sequence[int], length: int) -> Dict:
        """Contiguous K/V rows (L, B, length, ...) for positions 0..length-1
        of the given slots, gathered through the block tables — the suffix
        prefill's accumulator seed. Positions past a slot's lease resolve to
        the null block, exactly like the decode gather bridge: the chunked
        scan only READS positions below its start chunk, all of which the
        lease matched (valid cached entries), so the junk never reaches an
        unmasked score."""
        assert length % self.block_size == 0
        tb = self._tables[np.asarray(slots, np.int32)][:, :length // self.block_size]
        return _gather_prefix_rows(self.cache, jnp.asarray(tb, jnp.int32))

    # ------------------------------------------------------------------ info

    def debug_block_census(self) -> Dict[str, List[int]]:
        """The block-lifecycle partition, for invariant tests: every non-null
        block must be in EXACTLY ONE of ``free`` (on the free list, pristine),
        ``referenced`` (refcount > 0: leased, possibly by several slots —
        export-ledger holds count here, so an exported-but-unacked block is
        referenced, never free), or ``cached_unreferenced`` (held only by
        the prefix trie, evictable). Conservation — the three sets disjoint
        and their union == all blocks — is the no-leak/no-double-own
        invariant the property test drives, on both ends of a ship."""
        return {
            "free": sorted(self._free),
            "referenced": [b for b in range(1, self.n_blocks)
                           if self._ref[b] > 0],
            "cached_unreferenced": sorted(
                b for b in self._block_node if self._ref[b] == 0),
        }

    def memory_stats(self) -> Dict:
        # unique blocks with a live lease — shared prefix blocks count once
        # no matter how many slots reference them (which is the point)
        used = int((self._ref > 0).sum())
        total = self.n_blocks - 1                           # null block excluded
        # the persistent allocation is the pool ("bytes"). In bridge mode
        # each decode step additionally materializes a TRANSIENT contiguous
        # view of n_slots x max_seq_len rows (the gather bridge that buys
        # exact bit-identity with the contiguous decode program) — reported
        # separately so operators size devices for pool + view, not pool
        # alone. In native mode no STORE-level view exists — the decode step
        # attends over the pool in place (models/serve.py decode_paged) and
        # decode_view_bytes is 0; the jnp native path still gathers one
        # layer's rows transiently inside the layer scan (view/n_layers),
        # and the Pallas kernel path works from block-sized VMEM tiles alone
        # (per-step peaks recorded in reports/BENCH_paged_native.json).
        view_bytes = 0 if self.native else sum(
            leaf.dtype.itemsize
            * leaf.shape[0] * self.n_slots * self.max_seq_len
            * int(np.prod(leaf.shape[3:], dtype=np.int64))
            for name, leaf in self.cache.items()
            if name not in ("index", "tables"))
        out = {
            "backend": self.kind,
            "native": self.native,
            "bytes": self.nbytes(),
            "decode_view_bytes": view_bytes,
            "block_size": self.block_size,
            "blocks_total": total,
            "blocks_free": len(self._free),
            "blocks_used": used,
            "table_uploads": self.table_uploads,
            "slots": self.n_slots,
            "blocks_exported": self.blocks_exported,
            "blocks_imported": self.blocks_imported,
            "blocks_export_pending": sum(
                len(bs) for bs in self._exported.values()),
        }
        if self.prefix_cache:
            out["prefix_cached_blocks"] = self._n_evictable()
            out["prefix_hits"] = self.prefix_hits
            out["prefix_blocks_reused"] = self.prefix_blocks_reused
            out["prefix_tokens_reused"] = self.prefix_tokens_reused
            out["prefix_evictions"] = self.prefix_evictions
            out["cow_forks"] = self.cow_forks
        return out


class RecurrentStateStore(SlotStore):
    """Per-slot recurrent state rows for the ssm (xlstm mLSTM/sLSTM) and
    hybrid (zamba2 mamba conv/ssm + shared-attention K/V) families. Leaves
    follow the same axis-1 slot convention, so the row-generic lifecycle
    applies unchanged; admission payloads are whole state rows from the
    masked-scan recurrent prefill (models/serve.py ``prefill_recurrent``)."""

    kind = "recurrent"

    def __init__(self, cfg: ArchConfig, n_slots: int, max_seq_len: int):
        if cfg.family not in RECURRENT_FAMILIES:
            raise ValueError(
                f"RecurrentStateStore supports ssm/hybrid state caches, not "
                f"{cfg.family}")
        super().__init__(cfg, n_slots, max_seq_len)

    def alloc(self) -> Dict:
        return SV.init_cache(self.cfg, self.n_slots, self.max_seq_len,
                             per_slot_index=True)

    def write_slots(self, slots, states: Dict, n_valid) -> None:
        slots = jnp.asarray(slots, jnp.int32)
        n_valid = jnp.asarray(n_valid, jnp.int32)
        assert slots.shape == n_valid.shape and slots.ndim == 1
        self.cache = _scatter_state_rows(self.cache, states, slots, n_valid)

    def adopt_selected(self, snapshots: Sequence[Dict], sel) -> None:
        """Speculative rollback for a recurrent DRAFT model: recurrent state
        has no per-position axis to scrub, so the engine keeps one state
        snapshot per draft step of the round and each slot adopts the
        snapshot taken right after it consumed its last ACCEPTED token —
        snapshot m for a slot that advanced m tokens (snapshot 0 is the
        pre-round state). The snapshot's index leaf already carries the
        post-acceptance position, so no separate index fix-up is needed."""
        stacked = jax.tree.map(lambda *ls: jnp.stack(ls), *snapshots)
        self.cache = _select_snapshot_rows(stacked,
                                           jnp.asarray(sel, jnp.int32))


def make_store(cfg: ArchConfig, n_slots: int, max_seq_len: int,
               backend: str = "auto", *, block_size: int = 16,
               n_blocks: Optional[int] = None,
               native: bool = False, prefix_cache: bool = False) -> SlotStore:
    """Factory: build the SlotStore backend for a config. ``backend="auto"``
    picks contiguous for dense-family archs and recurrent for ssm/hybrid.
    ``native`` (paged only) selects the block-native decode bridge: the pool
    is handed to the decode step in block layout, no gather view.
    ``prefix_cache`` (paged only) enables the shared-prefix radix cache."""
    if backend == "auto":
        backend = ("recurrent" if cfg.family in RECURRENT_FAMILIES
                   else "contiguous")
    if native and backend != "paged":
        raise ValueError(
            f"native (block-native decode) requires the paged backend, "
            f"got {backend!r}")
    if prefix_cache and backend != "paged":
        raise ValueError(
            f"prefix_cache (shared-prefix radix cache) requires the paged "
            f"backend, got {backend!r}")
    if backend == "contiguous":
        return ContiguousKVStore(cfg, n_slots, max_seq_len)
    if backend == "paged":
        return PagedKVStore(cfg, n_slots, max_seq_len,
                            block_size=block_size, n_blocks=n_blocks,
                            native=native, prefix_cache=prefix_cache)
    if backend == "recurrent":
        return RecurrentStateStore(cfg, n_slots, max_seq_len)
    raise ValueError(
        f"unknown cache backend {backend!r} "
        f"(expected auto | contiguous | paged | recurrent)")
