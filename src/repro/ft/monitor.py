"""Fault tolerance: failure detection, elastic re-mesh planning, restart policy.

At 1000+ nodes the failure model is: a host stops heartbeating (hardware,
preemption) or straggles (thermal, network). The control loop is:

  1. ``HeartbeatMonitor`` detects missing/late heartbeats (tests inject them);
  2. ``plan_elastic_mesh`` computes the largest valid (data, model) mesh from
     the surviving hosts — model-parallel degree is preserved (params must
     still fit), the data axis shrinks to the surviving multiple;
  3. the driver (launch/train.py) rebuilds the mesh, re-shards from the last
     checkpoint (checkpoint/store.py loads onto any mesh), restores the data
     iterator state, and resumes; the global batch is kept constant by raising
     per-host accumulation (``grad_accum``) when the data axis shrank.

Straggler mitigation for *collective* training (distinct from the OPQ
backup-task policy, which covers independent tasks): the monitor tracks
per-host step latencies and flags hosts whose EMA exceeds
``straggler_factor`` x median, so the driver can evict them at the next
checkpoint boundary rather than letting one slow host gate every all-reduce.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple


@dataclasses.dataclass
class HostState:
    last_beat: float
    step_ema_s: float = 0.0
    beats: int = 0


class HeartbeatMonitor:
    def __init__(self, hosts: List[str], *, timeout_s: float = 60.0,
                 straggler_factor: float = 3.0, clock=time.monotonic):
        self._clock = clock
        self.timeout_s = timeout_s
        self.straggler_factor = straggler_factor
        now = self._clock()
        self.hosts: Dict[str, HostState] = {h: HostState(last_beat=now) for h in hosts}

    def beat(self, host: str, step_latency_s: Optional[float] = None) -> None:
        st = self.hosts[host]
        st.last_beat = self._clock()
        st.beats += 1
        if step_latency_s is not None:
            st.step_ema_s = (0.8 * st.step_ema_s + 0.2 * step_latency_s
                             if st.beats > 1 else step_latency_s)

    def dead_hosts(self) -> List[str]:
        now = self._clock()
        return [h for h, st in self.hosts.items()
                if now - st.last_beat > self.timeout_s]

    def stragglers(self) -> List[str]:
        lat = sorted(st.step_ema_s for st in self.hosts.values() if st.step_ema_s > 0)
        if not lat:
            return []
        median = lat[len(lat) // 2]
        return [h for h, st in self.hosts.items()
                if st.step_ema_s > self.straggler_factor * max(median, 1e-9)]

    def healthy_hosts(self) -> List[str]:
        bad = set(self.dead_hosts()) | set(self.stragglers())
        return [h for h in self.hosts if h not in bad]


def plan_elastic_mesh(
    n_surviving_hosts: int,
    chips_per_host: int,
    model_parallel: int,
    *,
    old_data_parallel: int,
    global_batch: int,
) -> Dict:
    """Largest valid mesh from the survivors + the accumulation factor that
    keeps the global batch constant.

    Model-parallel degree is fixed (a model shard must fit in HBM exactly as
    before); the data axis becomes the largest divisor-friendly size.
    """
    chips = n_surviving_hosts * chips_per_host
    if chips < model_parallel:
        raise RuntimeError(
            f"not enough chips ({chips}) for model_parallel={model_parallel}")
    new_dp = chips // model_parallel
    # keep global batch: per-replica microbatch must divide it
    while new_dp > 0 and global_batch % new_dp != 0:
        new_dp -= 1
    if new_dp == 0:
        raise RuntimeError("no valid data-parallel size for the global batch")
    grad_accum = max(1, old_data_parallel // new_dp)
    return {
        "mesh_shape": (new_dp, model_parallel),
        "axis_names": ("data", "model"),
        "chips_used": new_dp * model_parallel,
        "chips_idle": chips - new_dp * model_parallel,
        "grad_accum": grad_accum,
        "note": "reload latest checkpoint with checkpoint.load_checkpoint("
                "shardings=<new mesh specs>); restore data iterator state",
    }
