from repro.ft.monitor import HeartbeatMonitor, plan_elastic_mesh  # noqa: F401
