"""Attention: GQA/MQA/MHA with RoPE / M-RoPE / qk_norm, three execution paths.

  * plain      — materialized scores; used below ``cfg.attn_chunk`` seq len
  * chunked    — online-softmax scan over KV chunks (flash-style, O(S·C) memory
                 instead of O(S^2)); the train_4k / prefill_32k path
  * decode     — single-query attention against a (possibly sequence-sharded)
                 KV cache; softmax reductions over the sharded seq dim are
                 GSPMD-partitioned (SP for the 32k/500k decode cells)

plus the serving-admission and paged-serving variants:
  * ``prefill_attention_with_kv`` — the fused admission path: decode-mirrored
    full-sequence attention that also emits the cache-layout K/V entries
    (float or int8+scales) so one prefill forward can seed a serving slot
  * ``chunked_prefill_attention_with_kv`` — the long-prompt admission path:
    one fixed-width chunk attending over the accumulated rows, (B,H,W,S)
    scores instead of (B,H,S,S), bit-identical to the single-shot path
  * ``paged_decode_attention`` — block-native decode over the paged block
    pool through per-slot tables (no gather-bridge view), bit-identical to
    ``decode_attention`` on the gathered view; optional Pallas kernel path
    (kernels/paged_attention.py)

Sharding: q/k/v heads constrained to the ``model`` axis when
``cfg.shard_heads`` (TP); KV caches shard (batch->data, heads->model) and for
long-context cells additionally sequence->data.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.distributed import sharding as shd
from repro.models import layers as L

NEG_INF = -1e30


def init_attn(key, cfg: ArchConfig, d: int) -> Dict:
    ks = jax.random.split(key, 5)
    H, KV, hd = cfg.n_heads, cfg.n_kv, cfg.hd
    p = {
        "wq": L.dense_init(ks[0], (d, H * hd)),
        "wk": L.dense_init(ks[1], (d, KV * hd)),
        "wv": L.dense_init(ks[2], (d, KV * hd)),
        "wo": L.dense_init(ks[3], (H * hd, d)),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((cfg.hd,), jnp.float32)
        p["k_norm"] = jnp.ones((cfg.hd,), jnp.float32)
    return p


def attn_specs(cfg: ArchConfig) -> Dict:
    # Param sharding is decoupled from activation head-sharding: the flat
    # projection columns (H*hd) divide the model axis even when the head
    # count doesn't (qwen3: 40 heads but 5120 columns), so weights always
    # shard; only the activation layout (_heads_spec / attn_sp) is gated.
    m = "model"
    p = {"wq": P(None, m), "wk": P(None, m), "wv": P(None, m), "wo": P(m, None)}
    if cfg.qk_norm:
        p["q_norm"] = P(None)
        p["k_norm"] = P(None)
    return p


def _divisible_model(n: int) -> bool:
    try:
        return n % shd.model_parallel_size() == 0
    except RuntimeError:
        return True


def _heads_spec(cfg: ArchConfig, n_heads: Optional[int] = None) -> P:
    """Head-axis sharding, only when the head count divides the model axis —
    uneven head sharding triggers GSPMD involuntary full rematerialization
    (replicate-then-reshard), observed in the dry-run. Non-divisible archs
    (qwen3 40H, qwen2-vl 12H, xlstm 4H) replicate heads (see §Perf)."""
    n = cfg.n_heads if n_heads is None else n_heads
    m = "model" if (cfg.shard_heads and _divisible_model(n)) else None
    return shd.batch_spec(None, m, None)


def _project_qkv(
    p: Dict, x: jax.Array, cfg: ArchConfig,
    positions: Optional[jax.Array], positions3: Optional[jax.Array],
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    B, S, _ = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv, cfg.hd
    q = L.pdot(x, p["wq"], cfg).reshape(B, S, H, hd)
    k = L.pdot(x, p["wk"], cfg).reshape(B, S, KV, hd)
    v = L.pdot(x, p["wv"], cfg).reshape(B, S, KV, hd)
    if cfg.qk_norm:
        q = L.rms_head_norm(q, p["q_norm"])
        k = L.rms_head_norm(k, p["k_norm"])
    if cfg.rope_kind == "mrope":
        assert positions3 is not None, "mrope requires (3,B,S) positions"
        q = L.apply_mrope(q, positions3, cfg.rope_theta, cfg.mrope_sections)
        k = L.apply_mrope(k, positions3, cfg.rope_theta, cfg.mrope_sections)
    elif cfg.rope_kind == "rope":
        assert positions is not None
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)
    q = shd.with_sharding(q, _heads_spec(cfg))
    k = shd.with_sharding(k, _heads_spec(cfg, cfg.n_kv))
    v = shd.with_sharding(v, _heads_spec(cfg, cfg.n_kv))
    return q, k, v


def _expand_kv(k: jax.Array, n_heads: int) -> jax.Array:
    """GQA: repeat kv heads to match q heads (B, S, KV, hd) -> (B, S, H, hd)."""
    B, S, KV, hd = k.shape
    rep = n_heads // KV
    return jnp.repeat(k, rep, axis=2) if rep > 1 else k


def _quantize_kv(k_new: jax.Array, v_new: jax.Array):
    """Tensorizer int8 KV-cache quantization: per-token / per-head amax scales
    (exact per-position calibration — no cross-step rescaling). The SINGLE
    definition shared by decode_attention and prefill_attention_with_kv: the
    fused-admission bit-identity contract (tests/test_serving.py) requires the
    two paths to quantize identically, epsilon and all.

    Returns (k_q, v_q, k_scale, v_scale): int8 entries (..., KV, hd) and f32
    dequant scales (..., KV)."""
    k_sc = jnp.max(jnp.abs(k_new.astype(jnp.float32)), axis=-1) / 127.0 + 1e-12
    v_sc = jnp.max(jnp.abs(v_new.astype(jnp.float32)), axis=-1) / 127.0 + 1e-12
    k_q = jnp.clip(jnp.round(k_new.astype(jnp.float32) / k_sc[..., None]), -127, 127).astype(jnp.int8)
    v_q = jnp.clip(jnp.round(v_new.astype(jnp.float32) / v_sc[..., None]), -127, 127).astype(jnp.int8)
    return k_q, v_q, k_sc, v_sc


def _plain_attention(q, k, v, causal: bool, q_offset: int = 0) -> jax.Array:
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * (hd ** -0.5)
    if causal:
        qpos = jnp.arange(Sq)[:, None] + q_offset
        kpos = jnp.arange(Sk)[None, :]
        scores = jnp.where(kpos <= qpos, scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", w.astype(v.dtype), v)


def _chunked_attention(q, k, v, causal: bool, chunk: int, unroll: bool = False,
                       impl: str = "f32") -> jax.Array:
    """Online-softmax scan over KV chunks (flash-style).

    impl="f32": all internals f32 (the conservative baseline).
    impl="bf16acc": q/k/v and the probability matrix stay bf16; only the
    softmax statistics (m, l) and the output accumulator are f32 — the TPU
    flash-attention recipe. Halves the bytes of the two big streams (scores
    inputs and p), measured in §Perf.
    """
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    bf16 = impl == "bf16acc"
    n_chunks = -(-Sk // chunk)
    pad = n_chunks * chunk - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(B, n_chunks, chunk, H, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, n_chunks, chunk, H, hd).transpose(1, 0, 2, 3, 4)
    if bf16:
        qf = (q.astype(jnp.float32) * (hd ** -0.5)).astype(jnp.bfloat16)
    else:
        qf = q.astype(jnp.float32) * (hd ** -0.5)
    qpos = jnp.arange(Sq)[:, None]

    def step(carry, inputs):
        m, l, o = carry                       # (B,H,Sq,1), (B,H,Sq,1), (B,Sq,H,hd)
        ci, (kb, vb) = inputs
        kb_c = kb if bf16 else kb.astype(jnp.float32)
        # bf16 inputs with f32 accumulation (MXU-native mixed precision)
        s = jnp.einsum("bqhd,bkhd->bhqk", qf, kb_c,
                       preferred_element_type=jnp.float32)
        kpos = ci * chunk + jnp.arange(chunk)[None, :]
        mask = (kpos < Sk) if not causal else ((kpos <= qpos) & (kpos < Sk))
        s = jnp.where(mask[None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1, keepdims=True)
        pv = (p.astype(jnp.bfloat16) if bf16 else p)
        vb_c = vb if bf16 else vb.astype(jnp.float32)
        o_new = o * corr.squeeze(-1).transpose(0, 2, 1)[..., None] + jnp.einsum(
            "bhqk,bkhd->bqhd", pv, vb_c,
            preferred_element_type=jnp.float32,
        )
        return (m_new, l_new, o_new), None

    m0 = jnp.full((B, H, Sq, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, H, Sq, 1), jnp.float32)
    o0 = jnp.zeros((B, Sq, H, hd), jnp.float32)
    (m, l, o), _ = jax.lax.scan(
        step, (m0, l0, o0), (jnp.arange(n_chunks), (kc, vc)),
        unroll=True if unroll else 1,   # exact-cost mode for the dry-run
    )
    o = o / jnp.maximum(l.squeeze(-1).transpose(0, 2, 1)[..., None], 1e-30)
    return o.astype(q.dtype)


def attention(
    p: Dict,
    x: jax.Array,
    cfg: ArchConfig,
    *,
    positions: Optional[jax.Array] = None,
    positions3: Optional[jax.Array] = None,
    causal: bool = True,
    kv: Optional[Tuple[jax.Array, jax.Array]] = None,   # cross-attention K/V source
) -> jax.Array:
    """Full-sequence attention (train / prefill). ``kv`` overrides K/V for
    cross-attention (enc-dec); cross-attention is non-causal."""
    B, S, _ = x.shape
    q, k, v = _project_qkv(p, x, cfg, positions, positions3)
    if kv is not None:
        k, v = kv
        causal = False
    k = _expand_kv(k, cfg.n_heads)
    v = _expand_kv(v, cfg.n_heads)
    if cfg.attn_sp and S > cfg.attn_chunk:
        # SP attention: shard *queries* over 'model' (for archs whose head
        # count doesn't divide the axis — qwen2-vl 12H, qwen3 40H); K/V stay
        # replicated over model, every device computes all heads for S/16
        # query rows. Even work split where head sharding can't be.
        q = shd.with_sharding(q, shd.batch_spec("model", None, None))
    if max(S, k.shape[1]) > cfg.attn_chunk:
        o = _chunked_attention(q, k, v, causal, cfg.attn_chunk,
                               unroll=cfg.scan_unroll, impl=cfg.attn_impl)
    else:
        o = _plain_attention(q, k, v, causal)
    if cfg.attn_sp and S > cfg.attn_chunk:
        o = shd.with_sharding(o, shd.batch_spec("model", None, None))
    o = shd.with_sharding(o, _heads_spec(cfg))
    out = L.pdot(o.reshape(B, S, cfg.n_heads * cfg.hd), p["wo"], cfg)
    return out


def prefill_attention_with_kv(
    p: Dict,
    x: jax.Array,                 # (B, S, D) prompt activations
    cfg: ArchConfig,
    *,
    positions: jax.Array,
    positions3: Optional[jax.Array] = None,
    int8_kv: bool = False,
) -> Tuple[jax.Array, ...]:
    """Full-sequence causal attention that also returns this layer's K/V rows
    exactly as the decode cache stores them (fused prefill-with-cache).

    Returns ``(out, k_entry, v_entry)`` with entries in the cache dtype, or
    ``(out, k_q, v_q, k_scale, v_scale)`` on the int8-KV path — shapes
    (B, S, KV, hd) and (B, S, KV), ready to stack into the (L, B, S, KV, hd)
    cache layout and scatter into serving slot rows.

    The math deliberately mirrors :func:`decode_attention` bit-for-bit rather
    than reusing :func:`attention`'s plain/chunked paths: scores and the value
    contraction run in f32 against the *cache-dtype* K/V (int8 entries are
    quantized with the same per-token/per-head amax scales and dequantized
    before use, exactly as decode reads them back). That makes a cache seeded
    from these entries continue decoding with the identical token stream the
    B=1 prompt-replay seeding produced — the fused-admission bit-identity
    guarantee asserted in tests/test_serving.py.

    Memory: materializes the (B, H, S, S) f32 score matrix (the rounding
    anchor is decode's full-row softmax, which the chunked/online-softmax
    kernel does not reproduce bitwise). S here is an admission bucket — the
    engine bounds it by ``max_seq_len`` (slot-row length) at construction —
    not the 32k-class training/prefill sequence lengths, which keep using
    :func:`attention`'s chunked path. Paged long-prompt admission is the
    ROADMAP item.
    """
    B, S, _ = x.shape
    q, k_new, v_new = _project_qkv(p, x, cfg, positions, positions3)
    if int8_kv:
        k_q, v_q, k_sc, v_sc = _quantize_kv(k_new, v_new)
        k_full = k_q.astype(jnp.float32) * k_sc[..., None]
        v_full = v_q.astype(jnp.float32) * v_sc[..., None]
        k = _expand_kv(k_full.astype(x.dtype), cfg.n_heads)
        v = _expand_kv(v_full.astype(x.dtype), cfg.n_heads)
        entries: Tuple[jax.Array, ...] = (k_q, v_q, k_sc, v_sc)
    else:
        cache_dt = L.cdtype(cfg)
        k_c = k_new.astype(cache_dt)
        v_c = v_new.astype(cache_dt)
        k = _expand_kv(k_c, cfg.n_heads)
        v = _expand_kv(v_c, cfg.n_heads)
        entries = (k_c, v_c)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s * (cfg.hd ** -0.5)
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(S)[None, :]
    s = jnp.where((kpos <= qpos)[None, None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", w, v.astype(jnp.float32)).astype(x.dtype)
    out = L.pdot(o.reshape(B, S, cfg.n_heads * cfg.hd), p["wo"], cfg)
    return (out,) + entries


def chunked_prefill_attention_with_kv(
    p: Dict,
    x: jax.Array,                 # (B, W, D) one prompt chunk's activations
    cfg: ArchConfig,
    *,
    positions: jax.Array,         # (B, W) absolute positions of this chunk
    chunk_start,                  # () int32 — first absolute position (traced)
    k_acc: jax.Array,             # (B, S, KV, hd) cache-layout accumulator
    v_acc: jax.Array,
    k_sc_acc: Optional[jax.Array] = None,   # (B, S, KV) int8-KV scales
    v_sc_acc: Optional[jax.Array] = None,
    int8_kv: bool = False,
) -> Tuple[jax.Array, ...]:
    """One chunk of the chunked prefill-with-cache: project this chunk's
    K/V into the accumulated cache rows at ``chunk_start`` and attend the
    chunk's queries over everything written so far — already-written chunks
    plus the chunk itself, under the absolute causal mask.

    Returns ``(out, k_acc, v_acc)`` (+ scale accumulators on the int8 path)
    with the accumulators updated in place (``dynamic_update_slice``).

    Bit-identity with :func:`prefill_attention_with_kv` (the single-shot
    fused path) is the contract, and it is structural: the accumulator rows
    carry exactly the single-shot path's cache-dtype entries at written
    positions and zeros beyond the writing frontier; scores against the
    unwritten tail are masked to NEG_INF by the same absolute causal mask
    (``kpos <= qpos``: every unwritten position is in some future chunk,
    hence past every current query), so each query's softmax row is the
    single-shot row — same length S, same values, exact-zero tail — and the
    value contraction adds exact-zero terms for the tail. The score matrix
    is (B, H, W, S) per chunk instead of (B, H, S, S): peak prefill memory
    drops from quadratic to linear in S, which is what lets 32k-class
    prompts admit (models/serve.py ``prefill_with_cache_chunked``)."""
    B, W, _ = x.shape
    S = k_acc.shape[1]
    q, k_new, v_new = _project_qkv(p, x, cfg, positions, None)
    if int8_kv:
        k_q, v_q, k_sc, v_sc = _quantize_kv(k_new, v_new)
        k_acc = jax.lax.dynamic_update_slice(k_acc, k_q, (0, chunk_start, 0, 0))
        v_acc = jax.lax.dynamic_update_slice(v_acc, v_q, (0, chunk_start, 0, 0))
        k_sc_acc = jax.lax.dynamic_update_slice(k_sc_acc, k_sc, (0, chunk_start, 0))
        v_sc_acc = jax.lax.dynamic_update_slice(v_sc_acc, v_sc, (0, chunk_start, 0))
        k_full = k_acc.astype(jnp.float32) * k_sc_acc[..., None]
        v_full = v_acc.astype(jnp.float32) * v_sc_acc[..., None]
        k = _expand_kv(k_full.astype(x.dtype), cfg.n_heads)
        v = _expand_kv(v_full.astype(x.dtype), cfg.n_heads)
    else:
        cache_dt = L.cdtype(cfg)
        k_acc = jax.lax.dynamic_update_slice(
            k_acc, k_new.astype(cache_dt), (0, chunk_start, 0, 0))
        v_acc = jax.lax.dynamic_update_slice(
            v_acc, v_new.astype(cache_dt), (0, chunk_start, 0, 0))
        k = _expand_kv(k_acc, cfg.n_heads)
        v = _expand_kv(v_acc, cfg.n_heads)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s * (cfg.hd ** -0.5)
    qpos = chunk_start + jnp.arange(W)[:, None]
    kpos = jnp.arange(S)[None, :]
    s = jnp.where((kpos <= qpos)[None, None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", w, v.astype(jnp.float32)).astype(x.dtype)
    out = L.pdot(o.reshape(B, W, cfg.n_heads * cfg.hd), p["wo"], cfg)
    if int8_kv:
        return out, k_acc, v_acc, k_sc_acc, v_sc_acc
    return out, k_acc, v_acc


def project_kv_for_cross(p: Dict, enc_out: jax.Array, cfg: ArchConfig):
    """Pre-compute cross-attention K/V from encoder output (cached at prefill)."""
    B, S, _ = enc_out.shape
    k = L.pdot(enc_out, p["wk"], cfg).reshape(B, S, cfg.n_kv, cfg.hd)
    v = L.pdot(enc_out, p["wv"], cfg).reshape(B, S, cfg.n_kv, cfg.hd)
    return k, v


# ---------------------------------------------------------------------------
# KV cache + decode
# ---------------------------------------------------------------------------

def gather_block_kv(pool: Dict, tables: jax.Array) -> Dict:
    """Block-paged K/V gather (serving/store.py PagedKVStore): pool leaves
    (L, n_blocks, block_size, ...) + per-slot block tables (B, MB) -> the
    contiguous view (L, B, MB*block_size, ...) that :func:`decode_attention`
    consumes — every slot's blocks concatenated in table order, one
    ``jnp.take`` over the block axis per leaf (XLA lowers it to a single
    dynamic-gather; rows stay block-aligned so the copy is contiguous per
    block). Table entries past a slot's lease point at the reserved null
    block 0; those view positions sit beyond the slot's causal horizon, where
    decode masks scores to -inf and the softmax weight is exactly 0."""
    B, MB = tables.shape
    flat = tables.reshape(-1)
    out = {}
    for name, leaf in pool.items():
        bs = leaf.shape[2]
        g = jnp.take(leaf, flat, axis=1)                   # (L, B*MB, bs, ...)
        out[name] = g.reshape(leaf.shape[0], B, MB * bs, *leaf.shape[3:])
    return out


def cache_spec(cfg: ArchConfig, seq_shard: bool) -> P:
    """Cache (B, S, KV, hd): batch->data axes, seq->data when SP (long ctx,
    batch too small to shard), heads->model when divisible."""
    names = ()
    try:
        names = shd.axis_names()
    except RuntimeError:
        pass
    model = "model" if ("model" in names and cfg.shard_heads and _divisible_model(cfg.n_kv)) else None
    if seq_shard:
        return P(None, "data", model, None)
    b = shd.batch_axes()
    lead = b if len(b) > 1 else (b[0] if b else None)
    return P(lead, None, model, None)


def init_cache(cfg: ArchConfig, n_layers: int, batch: int, seq: int, dtype) -> Dict:
    return {
        "k": jnp.zeros((n_layers, batch, seq, cfg.n_kv, cfg.hd), dtype),
        "v": jnp.zeros((n_layers, batch, seq, cfg.n_kv, cfg.hd), dtype),
        "index": jnp.zeros((), jnp.int32),
    }


def decode_attention(
    p: Dict,
    x: jax.Array,                 # (B, 1, D) current token
    cache_k: jax.Array,           # (B, S, KV, hd)
    cache_v: jax.Array,
    index: jax.Array,             # () int32 — number of valid cache entries;
                                  # or (B,) int32 — per-row (slot) positions,
                                  # the continuous-batching serving layout
    cfg: ArchConfig,
    *,
    positions: Optional[jax.Array] = None,
    positions3: Optional[jax.Array] = None,
    update_cache: bool = True,
    cache_scales: Optional[Tuple[jax.Array, jax.Array]] = None,  # (B,S,KV) x2
) -> Tuple[jax.Array, ...]:
    """One-token attention against the cache. Returns (out, new_k, new_v
    [, new_k_scale, new_v_scale]).

    The softmax reduction runs over the cache's (possibly sharded) seq dim —
    GSPMD partitions the max/sum (the SP decode path for 32k/500k cells).

    A vector ``index`` gives every batch row its own write position and causal
    horizon (requests admitted at different times share one decode batch —
    serving/engine.py); writes then go through a per-row scatter
    (``dynamic_update_slice`` needs a batch-uniform start), touching O(B)
    cache rows per step. mode="drop" skips rows whose index is out of range
    (idle serving slots whose position ran past the cache).

    ``cache_scales`` enables the Tensorizer int8 KV cache: entries are stored
    int8 with a *per-token, per-head* scale (exact per-position calibration —
    no cross-step rescaling), halving the dominant decode-bandwidth stream.
    """
    B, _, _ = x.shape
    S = cache_k.shape[1]
    index = jnp.asarray(index)
    per_row = index.ndim == 1
    if per_row:
        rows = jnp.arange(B)
    q, k_new, v_new = _project_qkv(p, x, cfg, positions, positions3)
    int8_cache = cache_scales is not None
    if int8_cache:
        ks, vs = cache_scales
        k_q, v_q, k_sc, v_sc = _quantize_kv(k_new, v_new)
        if update_cache and per_row:
            cache_k = cache_k.at[rows, index].set(k_q[:, 0], mode="drop")
            cache_v = cache_v.at[rows, index].set(v_q[:, 0], mode="drop")
            ks = ks.at[rows, index].set(k_sc[:, 0], mode="drop")
            vs = vs.at[rows, index].set(v_sc[:, 0], mode="drop")
        elif update_cache:
            cache_k = jax.lax.dynamic_update_slice(cache_k, k_q, (0, index, 0, 0))
            cache_v = jax.lax.dynamic_update_slice(cache_v, v_q, (0, index, 0, 0))
            ks = jax.lax.dynamic_update_slice(ks, k_sc, (0, index, 0))
            vs = jax.lax.dynamic_update_slice(vs, v_sc, (0, index, 0))
        k_full = cache_k.astype(jnp.float32) * ks[..., None]
        v_full = cache_v.astype(jnp.float32) * vs[..., None]
        k = _expand_kv(k_full.astype(x.dtype), cfg.n_heads)
        v = _expand_kv(v_full.astype(x.dtype), cfg.n_heads)
    else:
        if update_cache and per_row:
            cache_k = cache_k.at[rows, index].set(
                k_new[:, 0].astype(cache_k.dtype), mode="drop")
            cache_v = cache_v.at[rows, index].set(
                v_new[:, 0].astype(cache_v.dtype), mode="drop")
        elif update_cache:
            cache_k = jax.lax.dynamic_update_slice(cache_k, k_new.astype(cache_k.dtype), (0, index, 0, 0))
            cache_v = jax.lax.dynamic_update_slice(cache_v, v_new.astype(cache_v.dtype), (0, index, 0, 0))
        k = _expand_kv(cache_k, cfg.n_heads)
        v = _expand_kv(cache_v, cfg.n_heads)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s * (cfg.hd ** -0.5)
    horizon = index[:, None, None, None] if per_row else index
    valid = jnp.arange(S)[None, None, None, :] <= horizon     # causal: <= current
    s = jnp.where(valid, s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", w, v.astype(jnp.float32)).astype(x.dtype)
    out = L.pdot(o.reshape(B, 1, cfg.n_heads * cfg.hd), p["wo"], cfg)
    if int8_cache:
        return out, cache_k, cache_v, ks, vs
    return out, cache_k, cache_v


def verify_decode_attention(
    p: Dict,
    x: jax.Array,                 # (B, W, D) window: last token + k draft tokens
    cache_k: jax.Array,           # (B, S, KV, hd)
    cache_v: jax.Array,
    index: jax.Array,             # (B,) int32 per-slot window start positions
    cfg: ArchConfig,
    *,
    positions: jax.Array,         # (B, W) absolute positions = index + arange(W)
    cache_scales: Optional[Tuple[jax.Array, jax.Array]] = None,  # (B,S,KV) x2
) -> Tuple[jax.Array, ...]:
    """Speculative-verify attention: W tokens per row scored in ONE forward,
    each against the same cache row sequential decode would have seen.

    All W new K/V entries are scattered into the cache rows *before* the
    contraction (positions ``index[b]+j``, mode="drop" for rows past the
    slot extent), and the causal horizon is per-query: query j attends
    ``kpos <= index + j``, so entries written for later window positions are
    masked to NEG_INF (exact-zero softmax weight) exactly as if they had not
    been written yet. The visible entries are the same bits sequential
    :func:`decode_attention` steps would have produced (same `_project_qkv`
    / `_quantize_kv` math per position), so the (B, H, W, S) score rows are
    the (B, H, 1, S) decode rows stacked — the speculative==plain
    bit-identity contract (tests/test_speculative.py).

    Rejected-window entries are real writes; the engine scrubs them back to
    pristine via the store rollback after acceptance (serving/store.py).

    Returns (out, new_k, new_v[, new_k_scale, new_v_scale])."""
    B, W, _ = x.shape
    S = cache_k.shape[1]
    rows = jnp.arange(B)
    index = jnp.asarray(index)
    q, k_new, v_new = _project_qkv(p, x, cfg, positions, None)
    int8_cache = cache_scales is not None
    if int8_cache:
        ks, vs = cache_scales
        k_q, v_q, k_sc, v_sc = _quantize_kv(k_new, v_new)
        cache_k = cache_k.at[rows[:, None], positions].set(k_q, mode="drop")
        cache_v = cache_v.at[rows[:, None], positions].set(v_q, mode="drop")
        ks = ks.at[rows[:, None], positions].set(k_sc, mode="drop")
        vs = vs.at[rows[:, None], positions].set(v_sc, mode="drop")
        k_full = cache_k.astype(jnp.float32) * ks[..., None]
        v_full = cache_v.astype(jnp.float32) * vs[..., None]
        k = _expand_kv(k_full.astype(x.dtype), cfg.n_heads)
        v = _expand_kv(v_full.astype(x.dtype), cfg.n_heads)
    else:
        cache_k = cache_k.at[rows[:, None], positions].set(
            k_new.astype(cache_k.dtype), mode="drop")
        cache_v = cache_v.at[rows[:, None], positions].set(
            v_new.astype(cache_v.dtype), mode="drop")
        k = _expand_kv(cache_k, cfg.n_heads)
        v = _expand_kv(cache_v, cfg.n_heads)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s * (cfg.hd ** -0.5)
    # per-query causal horizon: query j sees kpos <= index + j
    valid = jnp.arange(S)[None, None, None, :] <= positions[:, None, :, None]
    s = jnp.where(valid, s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", w, v.astype(jnp.float32)).astype(x.dtype)
    out = L.pdot(o.reshape(B, W, cfg.n_heads * cfg.hd), p["wo"], cfg)
    if int8_cache:
        return out, cache_k, cache_v, ks, vs
    return out, cache_k, cache_v


def paged_verify_attention(
    p: Dict,
    x: jax.Array,                 # (B, W, D) window: last token + k draft tokens
    pool_k: jax.Array,            # (n_blocks, block_size, KV, hd) — ONE layer's pool
    pool_v: jax.Array,
    tables: jax.Array,            # (B, MB) int32 per-slot block tables
    index: jax.Array,             # (B,) int32 per-slot window start positions
    cfg: ArchConfig,
    *,
    positions: jax.Array,         # (B, W) absolute positions = index + arange(W)
    cache_scales: Optional[Tuple[jax.Array, jax.Array]] = None,  # (NB,bs,KV) x2
) -> Tuple[jax.Array, ...]:
    """Block-native speculative verify: :func:`verify_decode_attention`'s
    windowed write-then-attend, addressed through the block tables. Window
    positions past the slot extent redirect to the reserved null block 0 (the
    same null-block machinery the bridge writeback clamps into) instead of
    landing in a live cell, so an end-of-budget window can never corrupt a
    leased position; the engine rollback un-writes rejected cells back to
    pristine. Per-layer transient gather + the exact contraction of
    :func:`paged_decode_attention`, W queries wide."""
    B, W, _ = x.shape
    bs = pool_k.shape[1]
    MB = tables.shape[1]
    S = MB * bs
    rows = jnp.arange(B)
    index = jnp.asarray(index)
    q, k_new, v_new = _project_qkv(p, x, cfg, positions, None)
    pos_c = jnp.minimum(positions, S - 1)
    in_range = positions < S
    phys = jnp.where(in_range, tables[rows[:, None], pos_c // bs], 0)
    off = jnp.where(in_range, pos_c % bs, 0)
    int8_cache = cache_scales is not None
    if int8_cache:
        pks, pvs = cache_scales
        k_q, v_q, k_sc, v_sc = _quantize_kv(k_new, v_new)
        pool_k = pool_k.at[phys, off].set(k_q)
        pool_v = pool_v.at[phys, off].set(v_q)
        pks = pks.at[phys, off].set(k_sc)
        pvs = pvs.at[phys, off].set(v_sc)
    else:
        pool_k = pool_k.at[phys, off].set(k_new.astype(pool_k.dtype))
        pool_v = pool_v.at[phys, off].set(v_new.astype(pool_v.dtype))
    flat = tables.reshape(-1)
    k_rows = jnp.take(pool_k, flat, axis=0).reshape(B, S, *pool_k.shape[2:])
    v_rows = jnp.take(pool_v, flat, axis=0).reshape(B, S, *pool_v.shape[2:])
    if int8_cache:
        ks = jnp.take(pks, flat, axis=0).reshape(B, S, *pks.shape[2:])
        vs = jnp.take(pvs, flat, axis=0).reshape(B, S, *pvs.shape[2:])
        k_full = k_rows.astype(jnp.float32) * ks[..., None]
        v_full = v_rows.astype(jnp.float32) * vs[..., None]
        k = _expand_kv(k_full.astype(x.dtype), cfg.n_heads)
        v = _expand_kv(v_full.astype(x.dtype), cfg.n_heads)
    else:
        k = _expand_kv(k_rows, cfg.n_heads)
        v = _expand_kv(v_rows, cfg.n_heads)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s * (cfg.hd ** -0.5)
    valid = jnp.arange(S)[None, None, None, :] <= positions[:, None, :, None]
    s = jnp.where(valid, s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", w, v.astype(jnp.float32)).astype(x.dtype)
    out = L.pdot(o.reshape(B, W, cfg.n_heads * cfg.hd), p["wo"], cfg)
    if int8_cache:
        return out, pool_k, pool_v, pks, pvs
    return out, pool_k, pool_v


def paged_decode_attention(
    p: Dict,
    x: jax.Array,                 # (B, 1, D) current token
    pool_k: jax.Array,            # (n_blocks, block_size, KV, hd) — ONE layer's pool
    pool_v: jax.Array,
    tables: jax.Array,            # (B, MB) int32 per-slot block tables
    index: jax.Array,             # (B,) int32 per-slot positions
    cfg: ArchConfig,
    *,
    positions: Optional[jax.Array] = None,
    positions3: Optional[jax.Array] = None,
    cache_scales: Optional[Tuple[jax.Array, jax.Array]] = None,  # (NB,bs,KV) x2
    use_kernel: bool = False,
) -> Tuple[jax.Array, ...]:
    """Block-native single-token attention: the paged pool stays in block
    layout end to end. The new token's K/V is scattered straight into its
    slot's current pool cell ``(tables[b, index[b] // bs], index[b] % bs)``
    and attention runs against the table-addressed blocks — no store-level
    ``gather_block_kv`` view of all layers is ever materialized
    (``PagedKVStore`` native mode passes the pool through unchanged and
    reports ``decode_view_bytes: 0``).

    Bit-identity with the gather-bridge decode is the contract: this path
    gathers exactly one layer's table-addressed rows transiently inside the
    layer body and then computes byte-for-byte the math of
    :func:`decode_attention` on them (same einsum shapes, same length-S
    softmax rows, same masks), so native tokens equal bridge tokens equal
    contiguous tokens (tests/test_serving.py). Rows whose index ran past the
    slot extent (idle/retired slots) clamp into their zeroed table — the
    reserved null block 0 — mirroring the bridge writeback's clamped null
    write; the null block is never read unmasked.

    ``use_kernel`` routes the attention contraction through the Pallas
    kernel (kernels/paged_attention.py) — truly block-granular HBM traffic,
    online softmax (float-equivalent, not bit-exact; float-KV only, the
    int8 path keeps the jnp contraction). Off-TPU the kernel runs in
    interpret mode, which is how CPU CI exercises it.

    Returns ``(out, pool_k, pool_v)`` (+ scale pools on the int8 path)."""
    B = x.shape[0]
    bs = pool_k.shape[1]
    MB = tables.shape[1]
    S = MB * bs
    rows = jnp.arange(B)
    index = jnp.asarray(index)
    q, k_new, v_new = _project_qkv(p, x, cfg, positions, positions3)
    pos = jnp.minimum(index, S - 1)          # idle rows: index can run on
    phys = tables[rows, pos // bs]           # zeroed table -> null block 0
    off = pos % bs
    int8_cache = cache_scales is not None
    if int8_cache:
        pks, pvs = cache_scales
        k_q, v_q, k_sc, v_sc = _quantize_kv(k_new, v_new)
        pool_k = pool_k.at[phys, off].set(k_q[:, 0])
        pool_v = pool_v.at[phys, off].set(v_q[:, 0])
        pks = pks.at[phys, off].set(k_sc[:, 0])
        pvs = pvs.at[phys, off].set(v_sc[:, 0])
    else:
        pool_k = pool_k.at[phys, off].set(k_new[:, 0].astype(pool_k.dtype))
        pool_v = pool_v.at[phys, off].set(v_new[:, 0].astype(pool_v.dtype))

    if use_kernel and not int8_cache:
        from repro.kernels.paged_attention import (
            paged_decode_attention as _pallas_paged)
        o = _pallas_paged(
            q[:, 0].astype(jnp.float32), pool_k, pool_v,
            tables.astype(jnp.int32), index.astype(jnp.int32),
            interpret=jax.default_backend() != "tpu")
        o = o[:, None].astype(x.dtype)        # (B, 1, H, hd)
    else:
        # per-layer transient gather of this layer's table-addressed rows,
        # then exactly decode_attention's math — the bit-identity oracle
        flat = tables.reshape(-1)
        k_rows = jnp.take(pool_k, flat, axis=0).reshape(B, S, *pool_k.shape[2:])
        v_rows = jnp.take(pool_v, flat, axis=0).reshape(B, S, *pool_v.shape[2:])
        if int8_cache:
            ks = jnp.take(pks, flat, axis=0).reshape(B, S, *pks.shape[2:])
            vs = jnp.take(pvs, flat, axis=0).reshape(B, S, *pvs.shape[2:])
            k_full = k_rows.astype(jnp.float32) * ks[..., None]
            v_full = v_rows.astype(jnp.float32) * vs[..., None]
            k = _expand_kv(k_full.astype(x.dtype), cfg.n_heads)
            v = _expand_kv(v_full.astype(x.dtype), cfg.n_heads)
        else:
            k = _expand_kv(k_rows, cfg.n_heads)
            v = _expand_kv(v_rows, cfg.n_heads)
        s = jnp.einsum("bqhd,bkhd->bhqk",
                       q.astype(jnp.float32), k.astype(jnp.float32))
        s = s * (cfg.hd ** -0.5)
        valid = jnp.arange(S)[None, None, None, :] <= index[:, None, None, None]
        s = jnp.where(valid, s, NEG_INF)
        w = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhqk,bkhd->bqhd", w, v.astype(jnp.float32)).astype(x.dtype)
    out = L.pdot(o.reshape(B, 1, cfg.n_heads * cfg.hd), p["wo"], cfg)
    if int8_cache:
        return out, pool_k, pool_v, pks, pvs
    return out, pool_k, pool_v
