"""Serving path: cache construction, prefill, and single-token decode for all
families. Decode is the memory-roofline-bound cell set (32k/500k); caches are
sharded per attention.cache_spec — (batch->data, kv-heads->model), plus
sequence->data (SP) for the 500k single-batch cell.

Cache pytrees by family:
  dense/moe/vlm  {"k": (L,B,S,KV,hd), "v": ..., "index": ()}
  encdec         self cache + precomputed cross K/V (Ld,B,Se,KV,hd)
  hybrid         mamba (conv,ssm) states per layer + attn cache per application
  ssm            mLSTM (C,n,m) + sLSTM (c,n,h,m) states per pair
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.distributed import sharding as shd
from repro.models import attention as A
from repro.models import layers as L
from repro.models import model as M
from repro.models import moe as MOE
from repro.models import ssm as SSM
from repro.models import xlstm as XL


def _seq_shard(cfg: ArchConfig, batch: int) -> bool:
    """Shard the cache seq dim over 'data' when the batch can't cover it
    (the long_500k single-request cell)."""
    try:
        return batch < shd.data_parallel_size()
    except RuntimeError:
        return False


def init_cache(cfg: ArchConfig, batch: int, seq: int, *,
               per_slot_index: bool = False) -> Dict:
    """``per_slot_index`` builds the continuous-batching cache layout: a (B,)
    index vector so every batch row (serving slot) tracks its own position —
    supported for the families the serving engine batches (dense/moe/vlm K/V
    rows and ssm/hybrid recurrent state rows; encdec's scalar-index cross
    cache is not slot-batched)."""
    int8_kv = cfg.kv_cache_dtype == "int8" and cfg.family in ("dense", "moe", "vlm")
    dt = jnp.int8 if int8_kv else L.cdtype(cfg)
    seq_shard = _seq_shard(cfg, batch)
    spec = A.cache_spec(cfg, seq_shard)
    if per_slot_index and cfg.family == "encdec":
        raise ValueError(f"per-slot cache indices unsupported for {cfg.family}")
    idx0 = (jnp.zeros((batch,), jnp.int32) if per_slot_index
            else jnp.zeros((), jnp.int32))

    def kv(n_layers, s):
        k = shd.with_sharding(jnp.zeros((n_layers, batch, s, cfg.n_kv, cfg.hd), dt), P(None, *spec))
        v = shd.with_sharding(jnp.zeros((n_layers, batch, s, cfg.n_kv, cfg.hd), dt), P(None, *spec))
        return k, v

    if cfg.family in ("dense", "moe", "vlm"):
        k, v = kv(cfg.n_layers, seq)
        cache = {"k": k, "v": v, "index": idx0}
        if int8_kv:
            # Tensorizer int8 KV cache: per-token / per-head dequant scales.
            # Two distinct allocations — aliasing one buffer into both leaves
            # breaks buffer donation of the cache pytree (double-donate).
            sspec = P(None, *list(spec)[:-1])
            ones = lambda: jnp.full((cfg.n_layers, batch, seq, cfg.n_kv), 1e-12, jnp.float32)
            cache["k_scale"] = shd.with_sharding(ones(), sspec)
            cache["v_scale"] = shd.with_sharding(ones(), sspec)
        return cache
    if cfg.family == "encdec":
        k, v = kv(cfg.n_layers, seq)
        se = max(1, seq // cfg.enc_len_ratio)
        ck, cv = kv(cfg.n_layers, se)
        return {"k": k, "v": v, "cross_k": ck, "cross_v": cv,
                "index": jnp.zeros((), jnp.int32)}
    if cfg.family == "hybrid":
        n_groups = cfg.n_layers // cfg.attn_every
        rem = cfg.n_layers - n_groups * cfg.attn_every
        di = SSM.d_inner(cfg)
        H, Pd, N = SSM.n_ssm_heads(cfg), cfg.ssm_headdim, cfg.ssm_state
        k, v = kv(n_groups, seq)        # one attn cache per shared-block application
        mk = lambda nl: {
            "conv": jnp.zeros((nl, batch, SSM.CONV_W - 1, di + 2 * N), dt),
            "ssm": jnp.zeros((nl, batch, H, Pd, N), jnp.float32),
        }
        return {"k": k, "v": v, "groups": mk(n_groups * cfg.attn_every),
                "tail": mk(rem) if rem else None,
                "index": idx0}
    if cfg.family == "ssm":
        n_pairs = cfg.n_layers // 2
        H, hd = XL._heads(cfg)
        D = cfg.d_model
        return {
            "mlstm_C": jnp.zeros((n_pairs, batch, H, hd, hd), jnp.float32),
            "mlstm_n": jnp.zeros((n_pairs, batch, H, hd), jnp.float32),
            "mlstm_m": jnp.full((n_pairs, batch, H), XL.M_INIT, jnp.float32),
            "slstm_c": jnp.zeros((n_pairs, batch, D), jnp.float32),
            "slstm_n": jnp.full((n_pairs, batch, D), 1e-6, jnp.float32),
            "slstm_h": jnp.zeros((n_pairs, batch, D), jnp.float32),
            "slstm_m": jnp.full((n_pairs, batch, D), -1e30, jnp.float32),
            "index": idx0,
        }
    raise ValueError(cfg.family)


def init_paged_cache(cfg: ArchConfig, n_slots: int, n_blocks: int,
                     block_size: int, blocks_per_slot: int) -> Dict:
    """Block-paged serving cache (dense/moe/vlm): K/V entries live in a pool
    of ``n_blocks`` blocks of ``block_size`` tokens — k/v (L, NB, bs, KV, hd),
    int8 scales (L, NB, bs, KV) — addressed through per-slot block tables
    (B, MB). Block 0 is the reserved null block (serving/store.py
    PagedKVStore). Scales park at 1e-12 like the contiguous layout so a
    pristine entry dequantizes to exactly 0."""
    if cfg.family not in ("dense", "moe", "vlm"):
        raise ValueError(f"paged KV cache is a dense-family layout, not {cfg.family}")
    int8_kv = cfg.kv_cache_dtype == "int8"
    dt = jnp.int8 if int8_kv else L.cdtype(cfg)
    shape = (cfg.n_layers, n_blocks, block_size, cfg.n_kv, cfg.hd)
    cache = {
        "k": jnp.zeros(shape, dt),
        "v": jnp.zeros(shape, dt),
        "index": jnp.zeros((n_slots,), jnp.int32),
        "tables": jnp.zeros((n_slots, blocks_per_slot), jnp.int32),
    }
    if int8_kv:
        ones = lambda: jnp.full(shape[:-1], 1e-12, jnp.float32)
        cache["k_scale"] = ones()
        cache["v_scale"] = ones()
    return cache


# ===========================================================================
# prefill-with-cache: one forward that seeds a serving slot
# ===========================================================================

def prefill_with_cache(params: Dict, cfg: ArchConfig, batch: Dict) -> Tuple[jax.Array, Dict]:
    """Fused admission prefill (dense/moe/vlm): one full-sequence forward over
    right-padded prompts that returns (logits, kv) with kv the per-layer K/V
    already in cache layout — {"k","v": (L, B, S, KV, hd)} (+ per-token int8
    scales when ``cfg.kv_cache_dtype == "int8"``), ready to scatter into
    leased engine slot rows (serving/kv.py ``write_slots``). Replaces the
    O(prompt_len) B=1 replay-decode seeding with O(1) forwards per admission
    bucket; bit-identity with the replay path is asserted in
    tests/test_serving.py."""
    logits, _, kv = M.forward(params, cfg, batch, return_kv=True)
    return logits, kv


def prefill_with_cache_chunked(params: Dict, cfg: ArchConfig,
                               tokens: jax.Array, last_index: jax.Array,
                               chunk: int) -> Tuple[jax.Array, Dict]:
    """Chunked admission prefill (dense/moe/vlm): run the right-padded prompt
    bucket through the stack ``chunk`` tokens at a time — a ``lax.scan`` over
    chunks, each attending over everything already written plus itself
    (models/attention.py ``chunked_prefill_attention_with_kv``) — and return
    ``(first_tokens, kv)`` with kv in cache layout, the same contract as the
    single-shot :func:`prefill_with_cache` step — except ``first_tokens`` is
    the (B, vocab_padded) f32 last-position logits row (the step builders in
    models/steps.py turn it into tokens, greedy or sampled).

    The point is the score matrix: single-shot fused prefill materializes
    (B, H, S, S) f32 scores, which caps the admissible prompt length at
    whatever S^2 fits; here the peak is (B, H, chunk, S) — linear in S — so
    32k-class prompts admit through the same engine (serving/engine.py
    ``prefill_chunk``). Emitted K/V entries, first tokens, and every token
    decoded from a cache seeded with them are BIT-IDENTICAL to the
    single-shot path (structurally: identical per-position projections,
    length-S softmax rows with exact-zero masked tails, and exact-zero
    value-contraction terms beyond the writing frontier — asserted in
    tests/test_serving.py). MoE layers route row-isolated and dropless, so a
    token's expert assignment is independent of which chunk carried it.

    The vocab projection runs ONCE, after the scan, on each row's carried
    ``last_index`` hidden state ((B, 1, V)) — never per chunk and never
    (B, S, V), so admission pays exactly one row of logits per request.
    mrope configs are rejected upstream (Engine construction): the chunked
    scan does not thread positions3."""
    first, kv = _chunked_prefill(params, cfg, tokens, last_index, chunk,
                                 kv0=None, start_chunk=0)
    return first, kv


def prefill_with_cache_suffix(params: Dict, cfg: ArchConfig,
                              tokens: jax.Array, last_index: jax.Array,
                              chunk: int, kv0: Dict,
                              start_chunk: jax.Array) -> Tuple[jax.Array, Dict]:
    """Suffix admission prefill (shared-prefix cache hits): resume the
    chunked scan mid-prompt. ``kv0`` seeds the K/V accumulators with cached
    prefix entries gathered from the leased blocks (serving/store.py
    ``gather_prefix_rows``) and the scan runs only chunks
    ``start_chunk..n_chunks-1`` — TTFT for a hot prefix is O(suffix), the
    skipped chunks having been PAID FOR by whichever cold admission cached
    them.

    Bit-identity with a cold admission holds structurally: the seeded
    accumulator entries are the very bits the cold chunked scan would have
    written (the cache stores the scan's own output, and the lease matched
    the token ids that produced them); every recomputed chunk attends over
    length-S rows with exact-zero masked tails, identical math to the cold
    scan's corresponding chunk. ``start_chunk`` is a traced scalar — one
    compiled executable per (B, bucket) serves every prefix length — and is
    floored by the engine at the batch minimum so no row skips a chunk it
    actually needs. The vocab projection still runs once, on the carried
    ``last_index`` hidden state, which the engine guarantees lives at or
    after the start chunk (``prefill_start <= prompt_len - 1``)."""
    return _chunked_prefill(params, cfg, tokens, last_index, chunk,
                            kv0=kv0, start_chunk=start_chunk)


def _chunked_prefill(params: Dict, cfg: ArchConfig, tokens: jax.Array,
                     last_index: jax.Array, chunk: int,
                     kv0, start_chunk) -> Tuple[jax.Array, Dict]:
    """Shared body of the chunked and suffix prefill steps: one chunk-body,
    scanned from chunk 0 with zeroed accumulators (cold) or fori_loop'd from
    ``start_chunk`` with cache-seeded accumulators (prefix hit) — the per-
    chunk math is the same trace either way, which is what keeps the two
    paths bit-identical chunk for chunk."""
    B, S = tokens.shape
    if S % chunk:
        raise ValueError(f"chunk {chunk} must divide the bucket length {S}")
    n_chunks = S // chunk
    int8_kv = cfg.kv_cache_dtype == "int8" and cfg.family in ("dense", "moe", "vlm")
    cdt = jnp.int8 if int8_kv else L.cdtype(cfg)
    nl = cfg.n_layers
    names = ("k", "v", "k_scale", "v_scale") if int8_kv else ("k", "v")
    if kv0 is None:
        kv = {"k": jnp.zeros((nl, B, S, cfg.n_kv, cfg.hd), cdt),
              "v": jnp.zeros((nl, B, S, cfg.n_kv, cfg.hd), cdt)}
        if int8_kv:
            kv["k_scale"] = jnp.full((nl, B, S, cfg.n_kv), 1e-12, jnp.float32)
            kv["v_scale"] = jnp.full((nl, B, S, cfg.n_kv), 1e-12, jnp.float32)
    else:
        kv = {n: kv0[n].astype(
            jnp.int8 if n in ("k", "v") and int8_kv else
            (jnp.float32 if n.endswith("_scale") else cdt))
            for n in names}
    last_x0 = jnp.zeros((B, cfg.d_model), L.cdtype(cfg))

    def chunk_body(carry, c):
        kv, last_x = carry
        start = c * chunk
        tok_c = jax.lax.dynamic_slice_in_dim(tokens, start, chunk, axis=1)
        positions = start + jnp.broadcast_to(
            jnp.arange(chunk, dtype=jnp.int32), (B, chunk))
        # mirror _embed_in: cast the table before the gather
        x = params["embed"].astype(L.cdtype(cfg))[tok_c]
        x = shd.with_sharding(x, shd.batch_spec(None, None))

        def layer_body(xc, inp):
            if int8_kv:
                lp, kl, vl, ksl, vsl = inp
            else:
                lp, kl, vl = inp
                ksl = vsl = None
            h = L.apply_norm(lp["ln1"], xc, cfg)
            res = A.chunked_prefill_attention_with_kv(
                lp["attn"], h, cfg, positions=positions, chunk_start=start,
                k_acc=kl, v_acc=vl, k_sc_acc=ksl, v_sc_acc=vsl,
                int8_kv=int8_kv)
            xc = xc + res[0]
            h = L.apply_norm(lp["ln2"], xc, cfg)
            if cfg.family == "moe":
                y, _ = MOE.apply_moe(lp["moe"], h, cfg, row_isolated=True)
            else:
                y = L.apply_mlp(lp["mlp"], h, cfg)
            return xc + y, res[1:]

        xs = tuple([params["layers"]] + [kv[n] for n in names])
        xc, new = jax.lax.scan(layer_body, x, xs,
                               unroll=True if cfg.scan_unroll else 1)
        kv = dict(zip(names, new))
        # carry each row's last-prompt-position hidden state; the vocab
        # projection happens once, after the scan
        rel = last_index - start
        in_chunk = (rel >= 0) & (rel < chunk)
        idx = jnp.clip(rel, 0, chunk - 1)
        row = jnp.take_along_axis(
            xc, jnp.broadcast_to(idx[:, None, None],
                                 (B, 1, xc.shape[-1])), axis=1)[:, 0]
        last_x = jnp.where(in_chunk[:, None], row, last_x)
        return kv, last_x

    if kv0 is None:
        (kv, last_x), _ = jax.lax.scan(
            lambda carry, c: (chunk_body(carry, c), None),
            (kv, last_x0), jnp.arange(n_chunks))
    else:
        # traced start bound: fori_loop runs chunks start_chunk..n_chunks-1,
        # one compiled program for every prefix length of this (B, S) shape
        kv, last_x = jax.lax.fori_loop(
            start_chunk, n_chunks,
            lambda c, carry: chunk_body(carry, c), (kv, last_x0))
    logits = M._logits(params, cfg, last_x[:, None, :])     # (B, 1, V)
    # return the f32 logits row, not a token: the step builders (models/
    # steps.py) own the logits->token choice so greedy and sampled requests
    # share this one prefill executable
    return logits[:, 0, :].astype(jnp.float32), kv


def prefill_recurrent(params: Dict, cfg: ArchConfig, tokens: jax.Array,
                      last_index: jax.Array, max_seq_len: int
                      ) -> Tuple[jax.Array, Dict]:
    """Fused admission prefill for the recurrent families (ssm/hybrid): run
    the right-padded prompt batch through the single-token decode body with a
    ``lax.scan`` over time — ONE dispatched instruction per admission bucket —
    and return (last_logits (B, vocab_padded) f32, cache) where cache holds each row's
    post-prompt state (mamba conv/ssm, xlstm mLSTM/sLSTM, hybrid attn K/V),
    ready to scatter into leased slot rows.

    Rows whose prompt ended (t > last_index[i]) keep their state frozen via a
    per-leaf ``where`` mask, so pad tokens never touch it. Each scan step
    computes exactly the math of a B-row decode step, and every recurrent
    decode body is row-independent, so the emitted states and first tokens
    are bit-identical to replaying each prompt alone through the B=1 decode
    step — the recurrent analogue of the dense fused==replay guarantee."""
    B, Sb = tokens.shape
    cache0 = init_cache(cfg, B, max_seq_len, per_slot_index=True)
    row0 = jnp.zeros((B, cfg.vocab_padded), jnp.float32)

    def body(carry, inp):
        cache, row = carry
        t, tok = inp                                        # (), (B,)
        logits, new_cache = decode(params, cfg, cache, {"tokens": tok[:, None]})
        keep = t <= last_index                              # (B,) still in prompt

        def sel(path, new, old):
            if new is old:
                return new
            name_is_index = any(
                getattr(p, "key", None) == "index" for p in path[-1:])
            if name_is_index:
                return jnp.where(keep, new, old)
            mask = keep.reshape((1, B) + (1,) * (new.ndim - 2))
            return jnp.where(mask, new, old)

        cache = jax.tree_util.tree_map_with_path(sel, new_cache, cache)
        row = jnp.where((t == last_index)[:, None],
                        logits[:, -1, :].astype(jnp.float32), row)
        return (cache, row), None

    (cache, row), _ = jax.lax.scan(
        body, (cache0, row0),
        (jnp.arange(Sb), jnp.moveaxis(tokens.astype(jnp.int32), 1, 0)))
    return row, cache


# ===========================================================================
# decode: one token against the cache
# ===========================================================================

def decode(params: Dict, cfg: ArchConfig, cache: Dict, batch: Dict) -> Tuple[jax.Array, Dict]:
    """batch: {"tokens": (B,1)} (+ positions3 for mrope; + "active" (B,) bool
    for MoE serving — masks idle engine slots out of the expert-capacity
    cumsum). Returns (logits, cache)."""
    tokens = batch["tokens"]
    B = tokens.shape[0]
    index = cache["index"]
    if getattr(index, "ndim", 0) == 1:
        # Per-slot indices (continuous-batching serving): each batch row sits
        # at its own sequence position — see serving/kv.py.
        positions = index[:, None].astype(jnp.int32)
    else:
        positions = jnp.broadcast_to(index[None, None], (B, 1)).astype(jnp.int32)
    positions3 = batch.get("positions3")
    if cfg.rope_kind == "mrope" and positions3 is None:
        p3 = index[None, :, None] if getattr(index, "ndim", 0) == 1 else index[None, None, None]
        positions3 = jnp.broadcast_to(p3, (3, B, 1)).astype(jnp.int32)

    x = params["embed"][tokens].astype(L.cdtype(cfg))
    x = shd.with_sharding(x, shd.batch_spec(None, None))

    if cfg.family in ("dense", "moe", "vlm"):
        int8_kv = "k_scale" in cache

        def body(carry, inp):
            x = carry
            if int8_kv:
                lp, ck, cv, cks, cvs = inp
                h = L.apply_norm(lp["ln1"], x, cfg)
                o, ck, cv, cks, cvs = A.decode_attention(
                    lp["attn"], h, ck, cv, index, cfg,
                    positions=positions, positions3=positions3,
                    cache_scales=(cks, cvs))
            else:
                lp, ck, cv = inp
                h = L.apply_norm(lp["ln1"], x, cfg)
                o, ck, cv = A.decode_attention(
                    lp["attn"], h, ck, cv, index, cfg,
                    positions=positions, positions3=positions3)
            x = x + o
            h = L.apply_norm(lp["ln2"], x, cfg)
            if cfg.family == "moe":
                y, _ = MOE.apply_moe(lp["moe"], h, cfg,
                                     active=batch.get("active"))
            else:
                y = L.apply_mlp(lp["mlp"], h, cfg)
            out_caches = (ck, cv, cks, cvs) if int8_kv else (ck, cv)
            return x + y, out_caches

        xs = ((params["layers"], cache["k"], cache["v"], cache["k_scale"], cache["v_scale"])
              if int8_kv else (params["layers"], cache["k"], cache["v"]))
        x, new_caches = jax.lax.scan(body, x, xs,
                                     unroll=True if cfg.scan_unroll else 1)
        if int8_kv:
            k_new, v_new, ks_new, vs_new = new_caches
            cache = dict(cache, k=k_new, v=v_new, k_scale=ks_new,
                         v_scale=vs_new, index=index + 1)
        else:
            k_new, v_new = new_caches
            cache = dict(cache, k=k_new, v=v_new, index=index + 1)
        return M._logits(params, cfg, x), cache

    if cfg.family == "encdec":
        def body(carry, inp):
            x = carry
            lp, ck, cv, xk, xv = inp
            h = L.apply_norm(lp["ln1"], x, cfg)
            o, ck, cv = A.decode_attention(lp["self_attn"], h, ck, cv, index, cfg,
                                           positions=positions)
            x = x + o
            h = L.apply_norm(lp["ln_x"], x, cfg)
            o, _, _ = A.decode_attention(
                lp["cross_attn"], h, xk, xv, xk.shape[1] - 1, cfg,
                positions=positions, update_cache=False)
            x = x + o
            h = L.apply_norm(lp["ln2"], x, cfg)
            return x + L.apply_mlp(lp["mlp"], h, cfg), (ck, cv)
        x, (k_new, v_new) = jax.lax.scan(
            body, x, (params["decoder"], cache["k"], cache["v"],
                      cache["cross_k"], cache["cross_v"]),
            unroll=True if cfg.scan_unroll else 1)
        cache = dict(cache, k=k_new, v=v_new, index=index + 1)
        return M._logits(params, cfg, x), cache

    if cfg.family == "hybrid":
        hp = params["hybrid"]

        def mamba_body(x, inp):
            lp, conv_s, ssm_s = inp
            h = L.apply_norm(lp["ln"], x, cfg)
            y, (conv_s, ssm_s) = SSM.apply_mamba2(
                lp["mamba"], h, cfg, conv_state=conv_s, ssm_state=ssm_s, decode=True)
            return x + y, (conv_s, ssm_s)

        n_groups = cfg.n_layers // cfg.attn_every
        g_conv = cache["groups"]["conv"].reshape(
            n_groups, cfg.attn_every, *cache["groups"]["conv"].shape[1:])
        g_ssm = cache["groups"]["ssm"].reshape(
            n_groups, cfg.attn_every, *cache["groups"]["ssm"].shape[1:])

        def group_body(x, inp):
            gp, lora, ck, cv, conv_s, ssm_s = inp
            h = L.apply_norm(hp["shared"]["ln1"], x, cfg)
            attn_p = dict(hp["shared"]["attn"])
            wq = attn_p["wq"]
            if hasattr(wq, "dequantize"):      # Tensorizer-quantized shared block
                wq = wq.dequantize()
            attn_p["wq"] = wq + (lora["qA"] @ lora["qB"])
            o, ck, cv = A.decode_attention(attn_p, h, ck, cv, index, cfg,
                                           positions=positions)
            x = x + o
            h = L.apply_norm(hp["shared"]["ln2"], x, cfg)
            x = x + L.apply_mlp(hp["shared"]["mlp"], h, cfg)
            x, (conv_s, ssm_s) = jax.lax.scan(mamba_body, x, (gp, conv_s, ssm_s),
                                              unroll=True if cfg.scan_unroll else 1)
            return x, (ck, cv, conv_s, ssm_s)

        x, (k_new, v_new, gc, gs) = jax.lax.scan(
            group_body, x,
            (hp["groups"], hp["lora"], cache["k"], cache["v"], g_conv, g_ssm),
            unroll=True if cfg.scan_unroll else 1)
        new_cache = dict(cache, k=k_new, v=v_new, index=index + 1)
        new_cache["groups"] = {
            "conv": gc.reshape(-1, *gc.shape[2:]),
            "ssm": gs.reshape(-1, *gs.shape[2:]),
        }
        if cache.get("tail") is not None:
            x, (tc, ts) = jax.lax.scan(
                mamba_body, x, (hp["tail"], cache["tail"]["conv"], cache["tail"]["ssm"]),
                unroll=True if cfg.scan_unroll else 1)
            new_cache["tail"] = {"conv": tc, "ssm": ts}
        return M._logits(params, cfg, x), new_cache

    if cfg.family == "ssm":
        xp = params["xlstm"]["pairs"]

        def body(carry, inp):
            x = carry
            lp, C, n, m, sc, sn, sh, sm = inp
            h = L.apply_norm(lp["ln_m"], x, cfg)
            y, (C, n, m) = XL.apply_mlstm(lp["mlstm"], h, cfg, state=(C, n, m), decode=True)
            x = x + y
            h = L.apply_norm(lp["ln_s"], x, cfg)
            y, (sc, sn, sh, sm) = XL.apply_slstm(lp["slstm"], h, cfg,
                                                 state=(sc, sn, sh, sm), decode=True)
            return x + y, (C, n, m, sc, sn, sh, sm)

        x, states = jax.lax.scan(
            body, x, (xp, cache["mlstm_C"], cache["mlstm_n"], cache["mlstm_m"],
                      cache["slstm_c"], cache["slstm_n"], cache["slstm_h"],
                      cache["slstm_m"]),
            unroll=True if cfg.scan_unroll else 1)
        C, n, m, sc, sn, sh, sm = states
        cache = dict(cache, mlstm_C=C, mlstm_n=n, mlstm_m=m,
                     slstm_c=sc, slstm_n=sn, slstm_h=sh, slstm_m=sm,
                     index=index + 1)
        return M._logits(params, cfg, x), cache

    raise ValueError(cfg.family)


def decode_paged(params: Dict, cfg: ArchConfig, cache: Dict, batch: Dict,
                 *, use_kernel: bool = False) -> Tuple[jax.Array, Dict]:
    """Block-native single-token decode over the paged pool (dense/moe/vlm):
    ``cache`` is the ``init_paged_cache`` pytree — k/v pools
    (L, n_blocks, bs, KV, hd), per-slot tables (B, MB), per-slot index (B,) —
    and is returned in the same layout: no store-level gather view exists in
    this path (serving/store.py ``PagedKVStore`` native mode passes the pool
    straight through). Each layer writes the new token's K/V into its pool
    cell through the tables and attends block-natively
    (models/attention.py ``paged_decode_attention``); tokens are
    bit-identical to the gather-bridge decode, which remains the reference
    oracle. ``use_kernel`` selects the Pallas kernel for the attention
    contraction (float-KV; interpret mode off-TPU)."""
    if cfg.family not in ("dense", "moe", "vlm"):
        raise ValueError(
            f"paged decode is a dense-family path, not {cfg.family}")
    tokens = batch["tokens"]
    B = tokens.shape[0]
    index = cache["index"]                        # (B,) per-slot positions
    tables = cache["tables"]
    positions = index[:, None].astype(jnp.int32)
    positions3 = batch.get("positions3")
    if cfg.rope_kind == "mrope" and positions3 is None:
        positions3 = jnp.broadcast_to(
            index[None, :, None], (3, B, 1)).astype(jnp.int32)

    x = params["embed"][tokens].astype(L.cdtype(cfg))
    x = shd.with_sharding(x, shd.batch_spec(None, None))
    int8_kv = "k_scale" in cache

    def body(carry, inp):
        x = carry
        if int8_kv:
            lp, pk, pv, pks, pvs = inp
            h = L.apply_norm(lp["ln1"], x, cfg)
            o, pk, pv, pks, pvs = A.paged_decode_attention(
                lp["attn"], h, pk, pv, tables, index, cfg,
                positions=positions, positions3=positions3,
                cache_scales=(pks, pvs), use_kernel=use_kernel)
        else:
            lp, pk, pv = inp
            h = L.apply_norm(lp["ln1"], x, cfg)
            o, pk, pv = A.paged_decode_attention(
                lp["attn"], h, pk, pv, tables, index, cfg,
                positions=positions, positions3=positions3,
                use_kernel=use_kernel)
        x = x + o
        h = L.apply_norm(lp["ln2"], x, cfg)
        if cfg.family == "moe":
            y, _ = MOE.apply_moe(lp["moe"], h, cfg, active=batch.get("active"))
        else:
            y = L.apply_mlp(lp["mlp"], h, cfg)
        out_pools = (pk, pv, pks, pvs) if int8_kv else (pk, pv)
        return x + y, out_pools

    xs = ((params["layers"], cache["k"], cache["v"],
           cache["k_scale"], cache["v_scale"])
          if int8_kv else (params["layers"], cache["k"], cache["v"]))
    x, new_pools = jax.lax.scan(body, x, xs,
                                unroll=True if cfg.scan_unroll else 1)
    if int8_kv:
        k_new, v_new, ks_new, vs_new = new_pools
        cache = dict(cache, k=k_new, v=v_new, k_scale=ks_new,
                     v_scale=vs_new, index=index + 1)
    else:
        k_new, v_new = new_pools
        cache = dict(cache, k=k_new, v=v_new, index=index + 1)
    return M._logits(params, cfg, x), cache


# ===========================================================================
# speculative verify: score a k+1-token window in one forward
# ===========================================================================

def verify_window(params: Dict, cfg: ArchConfig, cache: Dict,
                  batch: Dict, window: int) -> Tuple[jax.Array, Dict]:
    """Speculative-verify forward (dense/moe): ``batch["tokens"]`` is (B, W)
    — each row's last emitted token followed by ``W-1`` draft proposals —
    and the target model scores ALL W positions in one dispatch, the wide
    chunked-scoring shape of the admission prefill applied to the decode
    loop. Per layer the W new K/V entries scatter into the slot rows before
    a (B, H, W, S) contraction whose per-query causal horizon hides the
    not-yet-accepted entries (models/attention.py
    ``verify_decode_attention``), so position j's logits are bit-identical
    to the logits sequential :func:`decode` would produce after accepting
    j tokens — greedy acceptance therefore reproduces plain decode's token
    stream exactly, whatever the draft proposed. MoE layers route
    row-isolated and dropless, the same per-token-independent routing the
    chunked prefill uses.

    Advances ``index`` by W for active rows (the engine rolls back each
    slot to its true accepted position via the store rollback). Returns
    (logits (B, W, V), cache)."""
    if cfg.family not in ("dense", "moe"):
        raise ValueError(
            f"speculative verify is a dense-family path, not {cfg.family}")
    tokens = batch["tokens"]
    B, W = tokens.shape
    assert W == window, (W, window)
    index = cache["index"]                        # (B,) per-slot positions
    active = batch.get("active")
    positions = (index[:, None]
                 + jnp.arange(W, dtype=jnp.int32)[None, :]).astype(jnp.int32)

    x = params["embed"][tokens].astype(L.cdtype(cfg))
    x = shd.with_sharding(x, shd.batch_spec(None, None))
    int8_kv = "k_scale" in cache

    def body(carry, inp):
        x = carry
        if int8_kv:
            lp, ck, cv, cks, cvs = inp
            h = L.apply_norm(lp["ln1"], x, cfg)
            o, ck, cv, cks, cvs = A.verify_decode_attention(
                lp["attn"], h, ck, cv, index, cfg,
                positions=positions, cache_scales=(cks, cvs))
        else:
            lp, ck, cv = inp
            h = L.apply_norm(lp["ln1"], x, cfg)
            o, ck, cv = A.verify_decode_attention(
                lp["attn"], h, ck, cv, index, cfg, positions=positions)
        x = x + o
        h = L.apply_norm(lp["ln2"], x, cfg)
        if cfg.family == "moe":
            y, _ = MOE.apply_moe(lp["moe"], h, cfg, row_isolated=True)
        else:
            y = L.apply_mlp(lp["mlp"], h, cfg)
        out_caches = (ck, cv, cks, cvs) if int8_kv else (ck, cv)
        return x + y, out_caches

    xs = ((params["layers"], cache["k"], cache["v"],
           cache["k_scale"], cache["v_scale"])
          if int8_kv else (params["layers"], cache["k"], cache["v"]))
    x, new_caches = jax.lax.scan(body, x, xs,
                                 unroll=True if cfg.scan_unroll else 1)
    new_index = index + W if active is None else jnp.where(active, index + W, index)
    if int8_kv:
        k_new, v_new, ks_new, vs_new = new_caches
        cache = dict(cache, k=k_new, v=v_new, k_scale=ks_new,
                     v_scale=vs_new, index=new_index)
    else:
        k_new, v_new = new_caches
        cache = dict(cache, k=k_new, v=v_new, index=new_index)
    return M._logits(params, cfg, x), cache


def verify_window_paged(params: Dict, cfg: ArchConfig, cache: Dict,
                        batch: Dict, window: int) -> Tuple[jax.Array, Dict]:
    """Block-native speculative verify: :func:`verify_window` addressed
    through the paged pool + per-slot block tables (models/attention.py
    ``paged_verify_attention``). Window cells past a slot's extent redirect
    to the reserved null block, so an end-of-budget window never touches a
    live cell. Same logits, same greedy acceptance, same rollback contract."""
    if cfg.family not in ("dense", "moe"):
        raise ValueError(
            f"speculative verify is a dense-family path, not {cfg.family}")
    tokens = batch["tokens"]
    B, W = tokens.shape
    assert W == window, (W, window)
    index = cache["index"]
    tables = cache["tables"]
    active = batch.get("active")
    positions = (index[:, None]
                 + jnp.arange(W, dtype=jnp.int32)[None, :]).astype(jnp.int32)

    x = params["embed"][tokens].astype(L.cdtype(cfg))
    x = shd.with_sharding(x, shd.batch_spec(None, None))
    int8_kv = "k_scale" in cache

    def body(carry, inp):
        x = carry
        if int8_kv:
            lp, pk, pv, pks, pvs = inp
            h = L.apply_norm(lp["ln1"], x, cfg)
            o, pk, pv, pks, pvs = A.paged_verify_attention(
                lp["attn"], h, pk, pv, tables, index, cfg,
                positions=positions, cache_scales=(pks, pvs))
        else:
            lp, pk, pv = inp
            h = L.apply_norm(lp["ln1"], x, cfg)
            o, pk, pv = A.paged_verify_attention(
                lp["attn"], h, pk, pv, tables, index, cfg,
                positions=positions)
        x = x + o
        h = L.apply_norm(lp["ln2"], x, cfg)
        if cfg.family == "moe":
            y, _ = MOE.apply_moe(lp["moe"], h, cfg, row_isolated=True)
        else:
            y = L.apply_mlp(lp["mlp"], h, cfg)
        out_pools = (pk, pv, pks, pvs) if int8_kv else (pk, pv)
        return x + y, out_pools

    xs = ((params["layers"], cache["k"], cache["v"],
           cache["k_scale"], cache["v_scale"])
          if int8_kv else (params["layers"], cache["k"], cache["v"]))
    x, new_pools = jax.lax.scan(body, x, xs,
                                unroll=True if cfg.scan_unroll else 1)
    new_index = index + W if active is None else jnp.where(active, index + W, index)
    if int8_kv:
        k_new, v_new, ks_new, vs_new = new_pools
        cache = dict(cache, k=k_new, v=v_new, k_scale=ks_new,
                     v_scale=vs_new, index=new_index)
    else:
        k_new, v_new = new_pools
        cache = dict(cache, k=k_new, v=v_new, index=new_index)
    return M._logits(params, cfg, x), cache
