"""Mixture-of-Experts with expert parallelism (moonshot / deepseek-moe archs).

Design (DESIGN.md §7): activations are batch-sharded over (pod, data) and
*replicated* over ``model``; expert weights are sharded over ``model`` (EP:
64 experts / 16 = 4 per device). Each device therefore holds every local token
and a slice of experts — dispatch is purely local (sort-free cumsum binning
into fixed-capacity buffers, MXU-friendly batched matmuls), and the only
collective is one ``psum`` over ``model`` to combine expert outputs, the same
pattern (and cost) as Megatron-style TP. No all-to-all, no global sort.

Token dropping: fixed capacity C = ceil(T·topk/E · capacity_factor) per expert
(Switch-style); dropped slots scatter out-of-bounds (mode="drop").

Serving (per-request-isolated routing): ``apply_moe(active=...)`` masks idle
engine slots out of the capacity cumsum so a decode token's expert slot never
depends on idle batchmates, and ``row_isolated=True`` bins each batch row
against its own capacity so requests sharing one fused-prefill forward route
exactly as they would alone — the engine's MoE batch-invariance guarantees
(tests/test_serving.py).

The router aux (load-balance) loss is returned alongside; it is identical
across model shards (computed pre-dispatch from replicated scores).
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.distributed import sharding as shd
from repro.models import layers as L

if hasattr(jax, "shard_map"):                       # jax >= 0.6
    _shard_map, _CHECK_KW = jax.shard_map, "check_vma"
else:                                               # jax 0.4.x container
    from jax.experimental.shard_map import shard_map as _shard_map
    _CHECK_KW = "check_rep"


def init_moe(key, cfg: ArchConfig, d: int) -> Dict:
    ks = jax.random.split(key, 5)
    E, F = cfg.n_experts, cfg.d_ff
    p = {
        "router": L.dense_init(ks[0], (d, E)),
        "wi": L.dense_init(ks[1], (E, d, F), in_axis=1),
        "wg": L.dense_init(ks[2], (E, d, F), in_axis=1),
        "wo": L.dense_init(ks[3], (E, F, d), in_axis=1),
    }
    if cfg.n_shared_experts:
        p["shared"] = L.init_mlp(ks[4], cfg, d, cfg.n_shared_experts * F)
    return p


def moe_specs(cfg: ArchConfig) -> Dict:
    p = {
        "router": P(None, None),
        "wi": P("model", None, None),
        "wg": P("model", None, None),
        "wo": P("model", None, None),
    }
    if cfg.n_shared_experts:
        p["shared"] = L.mlp_specs(cfg)
    return p


def _dispatch_local(x_flat, scores, E: int, E_loc: int, e_offset, topk: int, capacity: int, cfg,
                    token_valid=None):
    """Bin local tokens into (E_loc, C, D) buffers; return combine metadata.

    ``token_valid`` (T,) bool masks tokens out of the capacity cumsum entirely
    (they neither occupy expert slots nor shift other tokens' queue positions)
    — the serving engine passes the active-slot mask here so a request's expert
    assignment never depends on idle batchmates (per-request-isolated routing).
    """
    T, D = x_flat.shape
    gate, ids = jax.lax.top_k(scores, topk)                   # (T, k)
    gate = jax.nn.softmax(gate.astype(jnp.float32), axis=-1)  # normalize over selected
    flat_ids = ids.reshape(-1)                                # (T*k,)
    flat_gate = gate.reshape(-1)
    slot_token = jnp.repeat(jnp.arange(T), topk)              # (T*k,)

    local = flat_ids - e_offset                               # target local expert
    valid = (local >= 0) & (local < E_loc)
    if token_valid is not None:
        valid = valid & token_valid[slot_token]
    local_c = jnp.where(valid, local, 0)
    # position of each slot within its expert queue (sort-free: cumsum of onehots)
    oh = jax.nn.one_hot(jnp.where(valid, local, E_loc), E_loc + 1, dtype=jnp.int32)[:, :E_loc]
    pos = (jnp.cumsum(oh, axis=0) * oh).sum(axis=1) - 1       # (T*k,), -1 if invalid
    keep = valid & (pos >= 0) & (pos < capacity)

    scatter_e = jnp.where(keep, local_c, E_loc)               # OOB row drops
    scatter_c = jnp.where(keep, pos, 0)
    x_buf = jnp.zeros((E_loc + 1, capacity, D), x_flat.dtype)
    x_buf = x_buf.at[scatter_e, scatter_c].add(x_flat[slot_token])
    return x_buf[:E_loc], (slot_token, local_c, pos, keep, flat_gate)


def _combine_local(y_buf, meta, T: int, D: int):
    slot_token, local_c, pos, keep, flat_gate = meta
    pos_c = jnp.clip(pos, 0, y_buf.shape[1] - 1)
    y_slot = y_buf[local_c, pos_c] * (keep * flat_gate)[:, None].astype(y_buf.dtype)
    y = jnp.zeros((T, D), y_buf.dtype)
    return y.at[slot_token].add(y_slot)


def apply_moe(p: Dict, x: jax.Array, cfg: ArchConfig, *,
              active: Optional[jax.Array] = None,
              row_isolated: bool = False) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (y, aux_loss). EP over 'model' via shard_map.

    Serving isolation knobs (training uses neither — shared batch capacity
    with Switch-style dropping). Both serving modes are *dropless*: capacity
    is raised to the worst-case per-expert load (each token contributes at
    most one entry per expert), because an expert buffer slot's value depends
    only on the token occupying it — so with dropping impossible, a token's
    MoE output is bitwise independent of its batchmates. That is what makes
    the engine's staggered==sequential bit-identity hold for MoE.

    ``active`` (B,) bool — decode: mask whole batch rows out of the capacity
    cumsum (idle slots' garbage tokens never consume capacity or shift queue
    positions) and use capacity = T so no active token can ever be dropped.

    ``row_isolated`` — fused prefill: bin each batch row against its own
    dropless capacity (= S), so a token only competes with tokens of the same
    row/request — requests sharing one bucketed admission forward route
    exactly as they would alone, and exactly as the B=1 replay decode would
    have routed them (right-padding keeps pad tokens *after* the prompt in
    the cumsum, so they never shift real tokens either).
    """
    mesh = shd.current_mesh()
    names = mesh.axis_names
    has_model = "model" in names
    b_axes = shd.batch_axes()
    E, topk = cfg.n_experts, cfg.topk
    mp = mesh.shape["model"] if has_model else 1
    assert E % mp == 0, (E, mp)
    E_loc = E // mp
    B, S, D = x.shape
    T_loc = (B // max(1, shd.data_parallel_size())) * S
    if row_isolated:
        capacity = max(topk, S)        # dropless within a row (see docstring)
    elif active is not None:
        capacity = max(topk, T_loc)    # dropless decode batch
    else:
        capacity = max(topk, math.ceil(T_loc * topk / E * cfg.capacity_factor))

    x = shd.with_sharding(x, shd.batch_spec(None, None))      # replicate over model
    if active is None:
        active = jnp.ones((B,), bool)
    active = shd.with_sharding(active.astype(bool), shd.batch_spec())

    batch_entry = b_axes if len(b_axes) > 1 else (b_axes[0] if b_axes else None)

    # Tensorizer-quantized expert weights: dequantize before shard_map (the
    # in_specs tree expects plain arrays; the W8A8 fast path covers the dense
    # projections — expert matmuls stay bf16 in serve mode)
    from repro.core.tensorizer import QTensor
    wi, wg, wo, router = (w.dequantize() if isinstance(w, QTensor) else w
                          for w in (p["wi"], p["wg"], p["wo"], p["router"]))

    def local_fn(xb, act, router, wi, wg, wo):
        Bl, Sl, _ = xb.shape
        xf = xb.reshape(Bl * Sl, D)
        scores = (xf.astype(jnp.float32) @ router).astype(jnp.float32)   # (T, E)
        e_offset = (jax.lax.axis_index("model") * E_loc) if has_model else 0
        if row_isolated:
            # per-row dispatch: buffers (Bl, E_loc, C, D), cumsum within a row
            x_buf, meta = jax.vmap(
                lambda xr, sr: _dispatch_local(
                    xr, sr, E, E_loc, e_offset, topk, capacity, cfg)
            )(xb, scores.reshape(Bl, Sl, E))
            h = jnp.einsum("becd,edf->becf", x_buf, wi.astype(xb.dtype))
            h = jax.nn.silu(h) * jnp.einsum("becd,edf->becf", x_buf, wg.astype(xb.dtype))
            y_buf = jnp.einsum("becf,efd->becd", h, wo.astype(xb.dtype))
            y = jax.vmap(lambda yb, m: _combine_local(yb, m, Sl, D))(y_buf, meta)
            y = y.reshape(Bl * Sl, D)
        else:
            token_valid = jnp.broadcast_to(act[:, None], (Bl, Sl)).reshape(-1)
            x_buf, meta = _dispatch_local(xf, scores, E, E_loc, e_offset, topk,
                                          capacity, cfg, token_valid=token_valid)
            h = jnp.einsum("ecd,edf->ecf", x_buf, wi.astype(xb.dtype))
            h = jax.nn.silu(h) * jnp.einsum("ecd,edf->ecf", x_buf, wg.astype(xb.dtype))
            y_buf = jnp.einsum("ecf,efd->ecd", h, wo.astype(xb.dtype))
            y = _combine_local(y_buf, meta, Bl * Sl, D)
        if has_model:
            y = jax.lax.psum(y, "model")
        # Switch-style load-balance aux: E * sum_e f_e * p_e  (replicated over model)
        probs = jax.nn.softmax(scores, axis=-1)
        _, ids = jax.lax.top_k(scores, topk)
        f = jnp.mean(jax.nn.one_hot(ids, E, dtype=jnp.float32).sum(axis=1), axis=0)
        pmean = jnp.mean(probs, axis=0)
        aux = E * jnp.sum(f * pmean)
        return y.reshape(Bl, Sl, D), aux[None]

    in_specs = (
        P(batch_entry, None, None),
        P(batch_entry),
        P(None, None),
        P("model" if has_model else None, None, None),
        P("model" if has_model else None, None, None),
        P("model" if has_model else None, None, None),
    )
    out_specs = (P(batch_entry, None, None), P(batch_entry))
    y, aux = _shard_map(
        local_fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        **{_CHECK_KW: False},
    )(x, active, router, wi, wg, wo)

    if cfg.n_shared_experts:
        y = y + L.apply_mlp(p["shared"], x, cfg)
    return y, jnp.mean(aux)
