"""Model zoo: the 10 assigned architectures as one composable LM stack."""

from repro.models import attention, layers, model, moe, serve, ssm, steps, xlstm  # noqa: F401
from repro.models.model import forward, init_model, param_specs  # noqa: F401
from repro.models.steps import make_decode_step, make_prefill_step, make_train_step  # noqa: F401
