"""LMModel: init / forward / prefill / decode for all 10 assigned families.

Families
  dense   — [pre-norm attn] + [pre-norm MLP], scan over stacked layers
  moe     — dense with the MLP replaced by expert-parallel MoE (models/moe.py)
  vlm     — dense backbone consuming precomputed patch embeddings + M-RoPE
  encdec  — bidirectional encoder (frame-embedding stub input) + causal
            decoder with cross-attention (seamless-m4t)
  hybrid  — zamba2: groups of [shared-attn-block (+LoRA per application);
            attn_every x mamba2], remainder mamba2 layers at the end
  ssm     — xlstm: alternating (mLSTM, sLSTM) pairs

All stacks run under ``lax.scan`` with per-layer ``jax.checkpoint`` (constant
HLO size in depth — the 1000-node compile-time posture, DESIGN.md §7).
Params are nested dicts with a leading stacked-layer axis; ``param_specs``
mirrors the tree with PartitionSpecs (layer axis never sharded).
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.distributed import sharding as shd
from repro.models import attention as A
from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import ssm as SSM
from repro.models import xlstm as XL

LORA_RANK = 16  # zamba2 per-application adapter rank


# ===========================================================================
# init
# ===========================================================================

def _stack(fn, key, n: int):
    return jax.vmap(fn)(jax.random.split(key, n))


def _init_dense_layer(cfg: ArchConfig):
    def f(key):
        k1, k2, k3, k4 = jax.random.split(key, 4)
        p = {
            "ln1": L.init_norm(cfg, cfg.d_model),
            "attn": A.init_attn(k1, cfg, cfg.d_model),
            "ln2": L.init_norm(cfg, cfg.d_model),
        }
        if cfg.family == "moe":
            p["moe"] = MOE.init_moe(k2, cfg, cfg.d_model)
        else:
            p["mlp"] = L.init_mlp(k2, cfg, cfg.d_model, cfg.d_ff)
        return p
    return f


def _init_encdec(cfg: ArchConfig, key):
    ke, kd = jax.random.split(key)

    def enc_layer(k):
        k1, k2 = jax.random.split(k)
        return {
            "ln1": L.init_norm(cfg, cfg.d_model),
            "attn": A.init_attn(k1, cfg, cfg.d_model),
            "ln2": L.init_norm(cfg, cfg.d_model),
            "mlp": L.init_mlp(k2, cfg, cfg.d_model, cfg.d_ff),
        }

    def dec_layer(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {
            "ln1": L.init_norm(cfg, cfg.d_model),
            "self_attn": A.init_attn(k1, cfg, cfg.d_model),
            "ln_x": L.init_norm(cfg, cfg.d_model),
            "cross_attn": A.init_attn(k2, cfg, cfg.d_model),
            "ln2": L.init_norm(cfg, cfg.d_model),
            "mlp": L.init_mlp(k3, cfg, cfg.d_model, cfg.d_ff),
        }

    return {
        "encoder": _stack(enc_layer, ke, cfg.n_enc_layers),
        "decoder": _stack(dec_layer, kd, cfg.n_layers),
    }


def _init_hybrid(cfg: ArchConfig, key):
    """zamba2: n_groups x [shared attn ; attn_every x mamba] + remainder mamba."""
    n_groups = cfg.n_layers // cfg.attn_every
    rem = cfg.n_layers - n_groups * cfg.attn_every
    k1, k2, k3, k4 = jax.random.split(key, 4)

    def mamba_layer(k):
        return {"ln": L.init_norm(cfg, cfg.d_model), "mamba": SSM.init_mamba2(k, cfg)}

    def group(k):
        return _stack(mamba_layer, k, cfg.attn_every)

    shared = {
        "ln1": L.init_norm(cfg, cfg.d_model),
        "attn": A.init_attn(k1, cfg, cfg.d_model),
        "ln2": L.init_norm(cfg, cfg.d_model),
        "mlp": L.init_mlp(k2, cfg, cfg.d_model, cfg.d_ff),
    }

    def lora(k):
        ka, kb = jax.random.split(k)
        return {
            "qA": jax.random.normal(ka, (cfg.d_model, LORA_RANK), jnp.float32) * 0.02,
            "qB": jnp.zeros((LORA_RANK, cfg.n_heads * cfg.hd), jnp.float32),
        }

    return {
        "groups": _stack(group, k3, n_groups),          # (G, attn_every, ...)
        "shared": shared,
        "lora": _stack(lora, k4, n_groups),             # per-application adapters
        "tail": _stack(mamba_layer, jax.random.fold_in(k3, 7), rem) if rem else None,
    }


def _init_xlstm(cfg: ArchConfig, key):
    n_pairs = cfg.n_layers // 2
    k1, k2 = jax.random.split(key)

    def pair(k):
        ka, kb = jax.random.split(k)
        return {
            "ln_m": L.init_norm(cfg, cfg.d_model),
            "mlstm": XL.init_mlstm(ka, cfg),
            "ln_s": L.init_norm(cfg, cfg.d_model),
            "slstm": XL.init_slstm(kb, cfg),
        }

    return {"pairs": _stack(pair, k1, n_pairs)}


def init_model(cfg: ArchConfig, key) -> Dict:
    k_emb, k_layers, k_head = jax.random.split(key, 3)
    params: Dict[str, Any] = {
        "embed": jax.random.normal(k_emb, (cfg.vocab_padded, cfg.d_model), jnp.float32) * 0.02,
        "final_ln": L.init_norm(cfg, cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.dense_init(k_head, (cfg.d_model, cfg.vocab_padded))
    if cfg.family in ("dense", "moe", "vlm"):
        params["layers"] = _stack(_init_dense_layer(cfg), k_layers, cfg.n_layers)
    elif cfg.family == "encdec":
        params.update(_init_encdec(cfg, k_layers))
        params["enc_final_ln"] = L.init_norm(cfg, cfg.d_model)
    elif cfg.family == "hybrid":
        params["hybrid"] = _init_hybrid(cfg, k_layers)
    elif cfg.family == "ssm":
        params["xlstm"] = _init_xlstm(cfg, k_layers)
    else:
        raise ValueError(cfg.family)
    if cfg.param_dtype != "float32":
        # serving stores weights at compute precision (half the HBM bytes of
        # the f32 training master copy) — the decode-cell §Perf baseline fix
        dt = jnp.dtype(cfg.param_dtype)
        params = jax.tree.map(
            lambda x: x.astype(dt) if jnp.issubdtype(x.dtype, jnp.floating) else x,
            params)
    return params


# ===========================================================================
# param sharding specs
# ===========================================================================

def _norm_specs(cfg: ArchConfig) -> Dict:
    p = {"scale": P(None)}
    if cfg.norm == "layernorm":
        p["bias"] = P(None)
    return p


def _prepend(spec_tree, axis_entry=None):
    """Add a leading (stacked-layer) axis to every spec in a tree."""
    return jax.tree.map(
        lambda s: P(axis_entry, *s), spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def param_specs(cfg: ArchConfig) -> Dict:
    specs: Dict[str, Any] = {
        "embed": P("model", None),
        "final_ln": _norm_specs(cfg),
    }
    if not cfg.tie_embeddings:
        specs["lm_head"] = P(None, "model")
    if cfg.family in ("dense", "moe", "vlm"):
        layer = {
            "ln1": _norm_specs(cfg),
            "attn": A.attn_specs(cfg),
            "ln2": _norm_specs(cfg),
        }
        if cfg.family == "moe":
            layer["moe"] = MOE.moe_specs(cfg)
        else:
            layer["mlp"] = L.mlp_specs(cfg)
        specs["layers"] = _prepend(layer)
    elif cfg.family == "encdec":
        enc = {"ln1": _norm_specs(cfg), "attn": A.attn_specs(cfg),
               "ln2": _norm_specs(cfg), "mlp": L.mlp_specs(cfg)}
        dec = {"ln1": _norm_specs(cfg), "self_attn": A.attn_specs(cfg),
               "ln_x": _norm_specs(cfg), "cross_attn": A.attn_specs(cfg),
               "ln2": _norm_specs(cfg), "mlp": L.mlp_specs(cfg)}
        specs["encoder"] = _prepend(enc)
        specs["decoder"] = _prepend(dec)
        specs["enc_final_ln"] = _norm_specs(cfg)
    elif cfg.family == "hybrid":
        mamba = {"ln": _norm_specs(cfg), "mamba": SSM.mamba2_specs(cfg)}
        specs["hybrid"] = {
            "groups": _prepend(_prepend(mamba)),        # (G, attn_every, ...)
            "shared": {"ln1": _norm_specs(cfg), "attn": A.attn_specs(cfg),
                       "ln2": _norm_specs(cfg), "mlp": L.mlp_specs(cfg)},
            "lora": _prepend({"qA": P(None, None), "qB": P(None, "model")}),
            "tail": _prepend(mamba) if cfg.n_layers % cfg.attn_every else None,
        }
    elif cfg.family == "ssm":
        pair = {"ln_m": _norm_specs(cfg), "mlstm": XL.mlstm_specs(cfg),
                "ln_s": _norm_specs(cfg), "slstm": XL.slstm_specs(cfg)}
        specs["xlstm"] = {"pairs": _prepend(pair)}
    return specs


# ===========================================================================
# forward (train / prefill)
# ===========================================================================

def _maybe_remat(f, cfg: ArchConfig):
    return jax.checkpoint(f) if cfg.remat else f


def _scan(body, init, xs, cfg: ArchConfig):
    """lax.scan over stacked layers; fully unrolled when cfg.scan_unroll (the
    dry-run's exact-cost mode — while bodies are cost-counted once by XLA)."""
    return jax.lax.scan(body, init, xs, unroll=True if cfg.scan_unroll else 1)


def _embed_in(params, cfg: ArchConfig, batch: Dict) -> jax.Array:
    if "embeds" in batch:
        x = batch["embeds"].astype(L.cdtype(cfg))
    else:
        # cast the (vocab-sharded) table BEFORE the gather: the combine
        # all-reduce then moves bf16, not the f32 master rows (§Perf cell A)
        x = params["embed"].astype(L.cdtype(cfg))[batch["tokens"]]
    return shd.with_sharding(x, shd.batch_spec(None, None))


def _logits(params, cfg: ArchConfig, x: jax.Array) -> jax.Array:
    x = L.apply_norm(params["final_ln"], x, cfg)
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = L.pdot(x, w, cfg)
    if cfg.vocab_padded != cfg.vocab:
        # padded vocab columns (model-axis divisibility) masked to -inf:
        # exp(-1e30) == 0 in the CE logsumexp, argmax never selects them
        mask = jnp.where(jnp.arange(cfg.vocab_padded) < cfg.vocab, 0.0, -1e30)
        logits = logits + mask.astype(logits.dtype)
    return shd.with_sharding(logits, shd.batch_spec(None, "model"))


def _dense_layer_fwd(lp, x, cfg: ArchConfig, positions, positions3):
    h = L.apply_norm(lp["ln1"], x, cfg)
    x = x + A.attention(lp["attn"], h, cfg, positions=positions, positions3=positions3)
    h = L.apply_norm(lp["ln2"], x, cfg)
    if cfg.family == "moe":
        y, aux = MOE.apply_moe(lp["moe"], h, cfg)
    else:
        y, aux = L.apply_mlp(lp["mlp"], h, cfg), jnp.zeros((), jnp.float32)
    return x + y, aux


def _with_hidden(params, cfg: ArchConfig, x, aux, return_hidden: bool):
    """Tail of forward: logits (+ final-norm hidden states when asked).
    The hidden row at the last prompt position is the embedding surface the
    serve API's non-generative endpoints read."""
    if return_hidden:
        return _logits(params, cfg, x), aux, L.apply_norm(
            params["final_ln"], x, cfg)
    return _logits(params, cfg, x), aux


def forward(params: Dict, cfg: ArchConfig, batch: Dict, *,
            return_kv: bool = False, return_hidden: bool = False):
    """Full-sequence forward. Returns (logits, aux_loss), or with
    ``return_kv=True`` (dense/moe/vlm only) (logits, aux_loss, kv) where kv is
    the per-layer K/V in decode-cache layout — {"k","v": (L, B, S, KV, hd)}
    plus {"k_scale","v_scale": (L, B, S, KV)} on the int8-KV path.

    The return_kv path is the fused serving admission (prefill-with-cache): it
    swaps the plain/chunked attention for the decode-mirrored
    ``prefill_attention_with_kv`` so the emitted entries (and hence every token
    decoded from a cache seeded with them) are bit-identical to replaying the
    prompt through the B=1 decode step, and routes MoE layers row-isolated so
    requests sharing one bucketed forward never perturb each other's experts.
    """
    B = (batch.get("tokens") if "tokens" in batch else batch["embeds"]).shape[0]
    S = (batch.get("tokens") if "tokens" in batch else batch["embeds"]).shape[1]
    positions = batch.get("positions")
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    positions3 = batch.get("positions3")

    x = _embed_in(params, cfg, batch)

    if return_hidden and return_kv:
        raise ValueError("return_hidden and return_kv are exclusive paths")

    if cfg.family in ("dense", "moe", "vlm"):
        if return_kv:
            int8_kv = cfg.kv_cache_dtype == "int8"

            def body_kv(carry, lp):
                x, aux = carry
                h = L.apply_norm(lp["ln1"], x, cfg)
                o, *kv = A.prefill_attention_with_kv(
                    lp["attn"], h, cfg, positions=positions,
                    positions3=positions3, int8_kv=int8_kv)
                x = x + o
                h = L.apply_norm(lp["ln2"], x, cfg)
                if cfg.family == "moe":
                    y, a = MOE.apply_moe(lp["moe"], h, cfg, row_isolated=True)
                else:
                    y, a = L.apply_mlp(lp["mlp"], h, cfg), jnp.zeros((), jnp.float32)
                return (x + y, aux + a), tuple(kv)

            (x, aux), kv = _scan(_maybe_remat(body_kv, cfg), (x, 0.0),
                                 params["layers"], cfg)
            names = ("k", "v", "k_scale", "v_scale") if int8_kv else ("k", "v")
            return _logits(params, cfg, x), aux, dict(zip(names, kv))

        def body(carry, lp):
            x, aux = carry
            x, a = _dense_layer_fwd(lp, x, cfg, positions, positions3)
            return (x, aux + a), None
        (x, aux), _ = _scan(_maybe_remat(body, cfg), (x, 0.0), params["layers"], cfg)
        return _with_hidden(params, cfg, x, aux, return_hidden)

    if return_kv:
        raise ValueError(f"return_kv is a dense/moe/vlm cache path, not {cfg.family}")

    if cfg.family == "encdec":
        if return_hidden:
            raise ValueError("return_hidden is a decoder-only path, not encdec")
        return _encdec_forward(params, cfg, batch, positions)

    if cfg.family == "hybrid":
        x, _ = _hybrid_forward(params["hybrid"], cfg, x, positions)
        return _with_hidden(params, cfg, x, jnp.zeros((), jnp.float32),
                            return_hidden)

    if cfg.family == "ssm":
        def body(carry, lp):
            x = carry
            h = L.apply_norm(lp["ln_m"], x, cfg)
            y, _ = XL.apply_mlstm(lp["mlstm"], h, cfg)
            x = x + y
            h = L.apply_norm(lp["ln_s"], x, cfg)
            y, _ = XL.apply_slstm(lp["slstm"], h, cfg)
            return x + y, None
        x, _ = _scan(_maybe_remat(body, cfg), x, params["xlstm"]["pairs"], cfg)
        return _with_hidden(params, cfg, x, jnp.zeros((), jnp.float32),
                            return_hidden)

    raise ValueError(cfg.family)


def _hybrid_forward(hp, cfg: ArchConfig, x, positions):
    """Training/prefill pass for zamba2. Returns (x, per-application attn K/V
    is not cached here — see decode path)."""
    def mamba_body(x, lp):
        h = L.apply_norm(lp["ln"], x, cfg)
        y, _ = SSM.apply_mamba2(lp["mamba"], h, cfg)
        return x + y, None

    def group_body(x, inp):
        gp, lora = inp
        # shared attention block with per-application LoRA on W_q
        h = L.apply_norm(hp["shared"]["ln1"], x, cfg)
        attn_p = dict(hp["shared"]["attn"])
        wq = attn_p["wq"]
        if hasattr(wq, "dequantize"):      # Tensorizer-quantized shared block
            wq = wq.dequantize()
        attn_p["wq"] = wq + (lora["qA"] @ lora["qB"])
        x = x + A.attention(attn_p, h, cfg, positions=positions)
        h = L.apply_norm(hp["shared"]["ln2"], x, cfg)
        x = x + L.apply_mlp(hp["shared"]["mlp"], h, cfg)
        # attn_every mamba layers
        x, _ = _scan(mamba_body, x, gp, cfg)
        return x, None

    x, _ = _scan(_maybe_remat(group_body, cfg), x, (hp["groups"], hp["lora"]), cfg)
    if hp.get("tail") is not None:
        x, _ = _scan(_maybe_remat(lambda c, lp: mamba_body(c, lp), cfg),
                     x, hp["tail"], cfg)
    return x, None


def _encdec_forward(params, cfg: ArchConfig, batch, positions):
    enc_x = batch["embeds"].astype(L.cdtype(cfg))          # frame stub (B, Se, D)
    enc_x = shd.with_sharding(enc_x, shd.batch_spec(None, None))
    Se = enc_x.shape[1]
    enc_pos = jnp.broadcast_to(jnp.arange(Se, dtype=jnp.int32), enc_x.shape[:2])

    def enc_body(x, lp):
        h = L.apply_norm(lp["ln1"], x, cfg)
        x = x + A.attention(lp["attn"], h, cfg, positions=enc_pos, causal=False)
        h = L.apply_norm(lp["ln2"], x, cfg)
        return x + L.apply_mlp(lp["mlp"], h, cfg), None

    enc_x, _ = _scan(_maybe_remat(enc_body, cfg), enc_x, params["encoder"], cfg)
    enc_out = L.apply_norm(params["enc_final_ln"], enc_x, cfg)

    x = params["embed"][batch["tokens"]].astype(L.cdtype(cfg))
    x = shd.with_sharding(x, shd.batch_spec(None, None))

    def dec_body(x, lp):
        h = L.apply_norm(lp["ln1"], x, cfg)
        x = x + A.attention(lp["self_attn"], h, cfg, positions=positions)
        h = L.apply_norm(lp["ln_x"], x, cfg)
        ck, cv = A.project_kv_for_cross(lp["cross_attn"], enc_out, cfg)
        x = x + A.attention(lp["cross_attn"], h, cfg, positions=positions, kv=(ck, cv))
        h = L.apply_norm(lp["ln2"], x, cfg)
        return x + L.apply_mlp(lp["mlp"], h, cfg), None

    x, _ = _scan(_maybe_remat(dec_body, cfg), x, params["decoder"], cfg)
    return _logits(params, cfg, x), jnp.zeros((), jnp.float32)
