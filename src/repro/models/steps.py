"""Step functions lowered by the dry-run / executed by train.py & serve.py.

  make_train_step(cfg)   — fwd + CE loss + bwd + grad-clip + AdamW update
                           (the full production step incl. optimizer collectives)
  make_prefill_step(cfg) — full-sequence forward returning last-token logits
  make_prefill_with_cache_step(cfg) — bucketed serving prefill returning
                           (first_tokens, per-layer K/V in cache layout)
  make_chunked_prefill_step(cfg, chunk) — same contract, scanning the bucket
                           chunk tokens at a time (long-prompt admission:
                           linear-in-S peak score memory)
  make_recurrent_prefill_step(cfg, max_seq_len) — masked-scan admission
                           prefill for ssm/hybrid recurrent-state slots
  make_decode_step(cfg)  — one-token decode against the KV/state cache
  make_paged_decode_step(cfg) — block-native one-token decode over the paged
                           block pool through per-slot tables (no gather view)
  input_specs(cfg,shape) — ShapeDtypeStruct stand-ins + shardings per cell
                           (the assignment's no-allocation dry-run inputs)

Distributed-optimization tricks wired in here (recorded in §Perf):
  * gradient all-reduce in bf16 (cfg.grad_allreduce_dtype)
  * ZeRO-1 optimizer-state sharding over data (cfg.zero1)
  * donated params/opt-state buffers (see launch/dryrun.py, train.py)
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeCfg
from repro.distributed import sharding as shd
from repro.models import layers as L
from repro.models import model as M
from repro.models import serve as SV
from repro.optim import adamw_init, adamw_update, clip_by_global_norm, cosine_schedule

AUX_WEIGHT = 0.01


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Token-mean CE; logsumexp in f32 over the (model-sharded) vocab dim."""
    lf = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - gold)


def loss_fn(params, cfg: ArchConfig, batch: Dict) -> Tuple[jax.Array, Dict]:
    logits, aux = M.forward(params, cfg, batch)
    ce = cross_entropy(logits[:, :-1], batch["labels"][:, 1:])
    loss = ce + AUX_WEIGHT * aux
    return loss, {"ce": ce, "aux": aux}


def _cast_grads(grads, dtype_str: str):
    dt = jnp.dtype(dtype_str)
    # DP gradient reduction in bf16 halves the collective bytes (§Perf);
    # master math stays f32 inside AdamW.
    return jax.tree.map(lambda g: g.astype(dt), grads)


def make_train_step(cfg: ArchConfig) -> Callable:
    def train_step(params, opt_state, batch, step):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(p, cfg, batch), has_aux=True)(params)
        grads = _cast_grads(grads, cfg.grad_allreduce_dtype)
        grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
        lr = cosine_schedule(step, peak=cfg.learning_rate)
        params, opt_state = adamw_update(
            params, grads, opt_state, lr, weight_decay=cfg.weight_decay)
        metrics = dict(metrics, loss=loss, gnorm=gnorm, lr=lr)
        return params, opt_state, metrics
    return train_step


def make_prefill_step(cfg: ArchConfig) -> Callable:
    def prefill_step(params, batch):
        logits, _ = M.forward(params, cfg, batch)
        return logits[:, -1, :]            # next-token distribution
    return prefill_step


def make_prefill_with_cache_step(cfg: ArchConfig) -> Callable:
    """Fused admission step (serving): one bucketed forward over right-padded
    prompts returning (first_tokens, kv) — the token at each row's
    ``last_index`` plus the per-layer K/V in cache layout, so the engine seeds
    a leased slot with a single dispatch instead of O(prompt_len) replay
    decodes (serving/engine.py).

    ``sampling`` (optional trailing arg, stacked serving/sampling.py params)
    turns the greedy argmax into the batched batch-invariant sampler — ONE
    executable per bucket regardless of the batch's greedy/sampled mix
    (param application is masked, not branched) — and extends the return to
    (first_tokens, kv, logprob_info): the chosen token's logprob + top-K
    alternatives from the same logits row (serving/sampling.py
    ``logprob_info``), which is how the serve API reports logprobs without
    a second executable. Legacy/test callers that pass three args trace the
    plain greedy two-tuple program, unchanged."""
    from repro.serving import sampling as SMP

    def prefill_step(params, tokens, last_index, sampling=None):
        logits, kv = SV.prefill_with_cache(params, cfg, {"tokens": tokens})
        B, V = tokens.shape[0], logits.shape[-1]
        idx = jnp.broadcast_to(last_index[:, None, None], (B, 1, V))
        row = jnp.take_along_axis(logits, idx, axis=1)[:, 0, :]
        # the emitted token's absolute position (randomness counter)
        tok = SMP.choose_tokens(row, sampling, last_index + 1)
        if sampling is None:
            return tok, kv
        return tok, kv, SMP.logprob_info(row, tok, cfg.vocab)
    return prefill_step


def make_recurrent_prefill_step(cfg: ArchConfig, max_seq_len: int) -> Callable:
    """Fused admission step for the recurrent families (ssm/hybrid): a masked
    scan of the decode body over the right-padded prompt bucket — one
    dispatch per bucket, same (params, tokens, last_index) ->
    (first_tokens, cache-payload) contract as the dense
    ``make_prefill_with_cache_step`` so the engine's admission path is
    backend-agnostic (serving/store.py RecurrentStateStore). Optional
    ``sampling`` as in ``make_prefill_with_cache_step``."""
    from repro.serving import sampling as SMP

    def prefill_step(params, tokens, last_index, sampling=None):
        row, cache = SV.prefill_recurrent(params, cfg, tokens, last_index,
                                          max_seq_len)
        tok = SMP.choose_tokens(row, sampling, last_index + 1)
        if sampling is None:
            return tok, cache
        return tok, cache, SMP.logprob_info(row, tok, cfg.vocab)
    return prefill_step


def make_chunked_prefill_step(cfg: ArchConfig, chunk: int) -> Callable:
    """Chunked admission step (serving, long prompts): same
    (params, tokens, last_index) -> (first_tokens, kv) contract as
    ``make_prefill_with_cache_step``, but scanning the bucket ``chunk``
    tokens at a time so peak prefill memory is (B, H, chunk, S) instead of
    the single-shot (B, H, S, S) score matrix — bit-identical output
    (models/serve.py ``prefill_with_cache_chunked``). Optional ``sampling``
    as in ``make_prefill_with_cache_step``."""
    from repro.serving import sampling as SMP

    def prefill_step(params, tokens, last_index, sampling=None):
        row, kv = SV.prefill_with_cache_chunked(params, cfg, tokens,
                                                last_index, chunk)
        tok = SMP.choose_tokens(row, sampling, last_index + 1)
        if sampling is None:
            return tok, kv
        return tok, kv, SMP.logprob_info(row, tok, cfg.vocab)
    return prefill_step


def make_suffix_prefill_step(cfg: ArchConfig, chunk: int) -> Callable:
    """Suffix admission step (serving, shared-prefix cache hits): the chunked
    prefill resumed mid-prompt. Takes the usual (params, tokens, last_index)
    plus ``kv0`` (cache-layout accumulators pre-seeded with the leased prefix
    blocks' entries, serving/store.py ``gather_prefix_rows``) and a traced
    ``start_chunk`` — chunks before it are skipped outright, so a hot-prefix
    admission pays O(suffix) prefill while emitting tokens and K/V
    bit-identical to a cold one (models/serve.py
    ``prefill_with_cache_suffix``). Optional ``sampling`` as in
    ``make_prefill_with_cache_step``."""
    from repro.serving import sampling as SMP

    def prefill_step(params, tokens, last_index, kv0, start_chunk,
                     sampling=None):
        row, kv = SV.prefill_with_cache_suffix(params, cfg, tokens,
                                               last_index, chunk, kv0,
                                               start_chunk)
        tok = SMP.choose_tokens(row, sampling, last_index + 1)
        if sampling is None:
            return tok, kv
        return tok, kv, SMP.logprob_info(row, tok, cfg.vocab)
    return prefill_step


def make_decode_step(cfg: ArchConfig) -> Callable:
    """One-token decode step. When the batch dict carries a ``"sampling"``
    entry (stacked serving/sampling.py params) the logits->token choice runs
    the batched batch-invariant sampler at each slot's post-step cache index
    (= the emitted token's absolute position, the randomness counter);
    without it the step is the historical greedy argmax, bit for bit."""
    from repro.serving import sampling as SMP

    def decode_step(params, cache, batch):
        logits, cache = SV.decode(params, cfg, cache, batch)
        sampling = batch.get("sampling")
        if sampling is None:
            return jnp.argmax(logits[:, -1, :], axis=-1), cache
        row = logits[:, -1, :]
        next_tok = SMP.choose_tokens(row, sampling, cache["index"])
        return next_tok, cache, SMP.logprob_info(row, next_tok, cfg.vocab)
    return decode_step


def make_paged_decode_step(cfg: ArchConfig, use_kernel: bool = False) -> Callable:
    """Block-native decode step (serving, paged store in native mode): the
    cache argument is the block pool + tables + per-slot index, returned in
    the same layout — no gather-bridge view (models/serve.py
    ``decode_paged``). Sampling contract as ``make_decode_step``."""
    from repro.serving import sampling as SMP

    def decode_step(params, cache, batch):
        logits, cache = SV.decode_paged(params, cfg, cache, batch,
                                        use_kernel=use_kernel)
        sampling = batch.get("sampling")
        if sampling is None:
            return jnp.argmax(logits[:, -1, :], axis=-1), cache
        row = logits[:, -1, :]
        next_tok = SMP.choose_tokens(row, sampling, cache["index"])
        return next_tok, cache, SMP.logprob_info(row, next_tok, cfg.vocab)
    return decode_step


def make_verify_step(cfg: ArchConfig, window: int) -> Callable:
    """Speculative-verify step (serving): ``batch["tokens"]`` is the (B, W)
    window — each slot's last emitted token + W-1 draft proposals — scored
    by the target model in ONE dispatch (models/serve.py ``verify_window``).
    Returns (greedy (B, W) int32, cache, logprob_info over every window
    position): position j's greedy token is bit-identical to what sequential
    decode would emit after accepting j window tokens, which is what makes
    greedy acceptance == plain decode; the logprob payload lets the engine
    report per-token logprobs for the accepted positions without a second
    forward (serving/sampling.py ``logprob_info``)."""
    from repro.serving import sampling as SMP

    def verify_step(params, cache, batch):
        logits, cache = SV.verify_window(params, cfg, cache, batch, window)
        greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return greedy, cache, SMP.logprob_info(logits, greedy, cfg.vocab)
    return verify_step


def make_paged_verify_step(cfg: ArchConfig, window: int) -> Callable:
    """Block-native speculative-verify step: same contract as
    ``make_verify_step`` over the paged pool + block tables (models/serve.py
    ``verify_window_paged``)."""
    from repro.serving import sampling as SMP

    def verify_step(params, cache, batch):
        logits, cache = SV.verify_window_paged(params, cfg, cache, batch,
                                               window)
        greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return greedy, cache, SMP.logprob_info(logits, greedy, cfg.vocab)
    return verify_step


def make_embed_step(cfg: ArchConfig) -> Callable:
    """Non-generative forward (serve API embeddings/classification): the same
    right-padded bucketed full-sequence forward the fused prefill runs, but
    returning each row's last-position final-norm hidden state (the
    embedding) plus its last-position logits row (classification over
    candidate token ids / scoring), no cache emitted."""
    def embed_step(params, tokens, last_index):
        logits, _, hidden = M.forward(params, cfg, {"tokens": tokens},
                                      return_hidden=True)
        B = tokens.shape[0]
        hid = jnp.take_along_axis(
            hidden, jnp.broadcast_to(last_index[:, None, None],
                                     (B, 1, hidden.shape[-1])), axis=1)[:, 0]
        row = jnp.take_along_axis(
            logits, jnp.broadcast_to(last_index[:, None, None],
                                     (B, 1, logits.shape[-1])), axis=1)[:, 0]
        return hid.astype(jnp.float32), row.astype(jnp.float32)
    return embed_step


# ===========================================================================
# dry-run input specs (ShapeDtypeStruct — never allocated)
# ===========================================================================

def _fit(shape, spec: P) -> P:
    """Drop sharding on dims the axis sizes don't divide (e.g. batch=1 cells)."""
    mesh = shd.current_mesh()
    entries = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for dim, e in zip(shape, entries):
        axes = e if isinstance(e, (tuple, list)) else (e,) if e else ()
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        out.append(e if (size and dim % size == 0) else None)
    return P(*out)


def _sds(shape, dtype, spec: P):
    mesh = shd.current_mesh()
    return jax.ShapeDtypeStruct(shape, dtype, sharding=NamedSharding(mesh, _fit(shape, spec)))


def _scrub(spec: P) -> P:
    names = set(shd.axis_names())

    def f(entry):
        if entry is None:
            return None
        if isinstance(entry, (tuple, list)):
            kept = tuple(e for e in entry if e in names)
            return kept if kept else None
        return entry if entry in names else None

    return P(*(f(e) for e in spec))


def batch_specs(cfg: ArchConfig, shape: ShapeCfg) -> Dict[str, jax.ShapeDtypeStruct]:
    """Model inputs for a cell, sharded: batch->(pod,data)."""
    B, S = shape.global_batch, shape.seq_len
    b = shd.batch_axes()
    lead = b if len(b) > 1 else (b[0] if b else None)
    tok = lambda shp: _sds(shp, jnp.int32, _scrub(P(lead, *([None] * (len(shp) - 1)))))
    out: Dict[str, Any] = {}
    if shape.kind == "decode":
        out["tokens"] = tok((B, 1))
        if cfg.rope_kind == "mrope":
            out["positions3"] = _sds((3, B, 1), jnp.int32, _scrub(P(None, lead, None)))
        return out
    # train / prefill
    if cfg.input_mode == "embeds":          # vlm / audio-frontend stubs
        emb_spec = _scrub(P(lead, None, None))
        out["embeds"] = _sds((B, S, cfg.d_model), jnp.dtype(cfg.dtype), emb_spec)
        if cfg.is_encdec:
            # encoder frames + decoder tokens (seamless)
            se = max(1, S // cfg.enc_len_ratio)
            out["embeds"] = _sds((B, se, cfg.d_model), jnp.dtype(cfg.dtype), emb_spec)
            out["tokens"] = tok((B, S))
        if cfg.rope_kind == "mrope":
            out["positions3"] = _sds((3, B, S), jnp.int32, _scrub(P(None, lead, None)))
    else:
        out["tokens"] = tok((B, S))
    if shape.kind == "train":
        out["labels"] = tok((B, S))
    return out


def cache_specs(cfg: ArchConfig, shape: ShapeCfg) -> Dict:
    """ShapeDtypeStructs matching serve.init_cache's shapes + shardings."""
    cache = jax.eval_shape(
        lambda: SV.init_cache(cfg, shape.global_batch, shape.seq_len))
    # re-attach shardings (eval_shape drops them): rebuild via init_cache spec logic
    mesh = shd.current_mesh()
    seq_shard = shape.global_batch < shd.data_parallel_size()
    from repro.models.attention import cache_spec
    kv_spec = P(None, *cache_spec(cfg, seq_shard))

    def attach(path, leaf):
        name = path[-1].key if hasattr(path[-1], "key") else ""
        if name in ("k", "v", "cross_k", "cross_v"):
            return jax.ShapeDtypeStruct(
                leaf.shape, leaf.dtype,
                sharding=NamedSharding(mesh, _fit(leaf.shape, _scrub(kv_spec))))
        b = shd.batch_axes()
        lead = b if len(b) > 1 else (b[0] if b else None)
        if leaf.ndim >= 2:
            spec = _fit(leaf.shape, _scrub(P(None, lead, *([None] * (leaf.ndim - 2)))))
        else:
            spec = P()
        return jax.ShapeDtypeStruct(leaf.shape, leaf.dtype,
                                    sharding=NamedSharding(mesh, spec))

    return jax.tree_util.tree_map_with_path(attach, cache)


def param_sds(cfg: ArchConfig) -> Dict:
    """ShapeDtypeStructs for params with their production shardings.

    With ``cfg.quantize == "serve"`` the tree mirrors
    ``tensorizer.quantize_params``: projection weights become QTensor stand-ins
    (int8 q + per-channel scale) so the dry-run lowers the true W8A8 program —
    half the weight bytes on the memory roofline term (§Perf cell B).
    """
    mesh = shd.current_mesh()
    specs = M.param_specs(cfg)
    if cfg.quantize == "serve":
        from repro.core import tensorizer as tz
        from repro.launch.serve import _quant_predicate

        shapes = jax.eval_shape(
            lambda k: tz.quantize_params(M.init_model(cfg, k), predicate=_quant_predicate),
            jax.random.PRNGKey(0))

        def attach_q(leaf, spec):
            if isinstance(leaf, tz.QTensor):
                sspec = P(*[e if d > 1 else None
                            for d, e in zip(leaf.scale.shape,
                                            list(spec) + [None] * (len(leaf.scale.shape) - len(spec)))])
                return tz.QTensor(
                    q=jax.ShapeDtypeStruct(leaf.q.shape, leaf.q.dtype,
                                           sharding=NamedSharding(mesh, _fit(leaf.q.shape, _scrub(spec)))),
                    scale=jax.ShapeDtypeStruct(leaf.scale.shape, leaf.scale.dtype,
                                               sharding=NamedSharding(mesh, _fit(leaf.scale.shape, _scrub(sspec)))),
                    meta_shape=leaf.meta_shape,
                )
            return jax.ShapeDtypeStruct(leaf.shape, leaf.dtype,
                                        sharding=NamedSharding(mesh, _fit(leaf.shape, _scrub(spec))))

        return jax.tree.map(
            attach_q, shapes, specs,
            is_leaf=lambda x: isinstance(x, (jax.ShapeDtypeStruct, tz.QTensor)))

    shapes = jax.eval_shape(lambda k: M.init_model(cfg, k), jax.random.PRNGKey(0))

    def attach(leaf, spec):
        return jax.ShapeDtypeStruct(leaf.shape, leaf.dtype,
                                    sharding=NamedSharding(mesh, _fit(leaf.shape, _scrub(spec))))

    return jax.tree.map(attach, shapes, specs,
                        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def opt_sds(cfg: ArchConfig, params_sds) -> Any:
    """Optimizer-state stand-ins; ZeRO-1 shards them over data when cfg.zero1."""
    mesh = shd.current_mesh()
    state = jax.eval_shape(adamw_init, params_sds)

    def attach(leaf, ref):
        if leaf.ndim == 0:
            return jax.ShapeDtypeStruct(leaf.shape, leaf.dtype,
                                        sharding=NamedSharding(mesh, P()))
        spec = ref.sharding.spec if hasattr(ref, "sharding") and ref.sharding else P()
        if cfg.zero1:
            # shard the largest unsharded dim over data
            entries = list(spec) + [None] * (leaf.ndim - len(spec))
            if "data" not in jax.tree.leaves(entries):
                for i, e in enumerate(entries):
                    if e is None and leaf.shape[i] % mesh.shape["data"] == 0 and leaf.shape[i] > 1:
                        entries[i] = "data"
                        break
            spec = P(*entries)
        return jax.ShapeDtypeStruct(leaf.shape, leaf.dtype,
                                    sharding=NamedSharding(mesh, spec))

    mu = jax.tree.map(attach, state.mu, params_sds,
                      is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    nu = jax.tree.map(attach, state.nu, params_sds,
                      is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    step = jax.ShapeDtypeStruct((), jnp.int32, sharding=NamedSharding(mesh, P()))
    from repro.optim import AdamWState
    return AdamWState(step=step, mu=mu, nu=nu)
