"""Mamba2 (SSD) blocks + the generic chunked linear recurrence.

TPU adaptation (DESIGN.md §2): the recurrence
    h_t = a_t * h_{t-1} + X_t (x) B_t           (scalar decay per head)
    y_t = C_t . h_t
is computed in *chunked* form — intra-chunk terms become masked matmuls on the
MXU (a (Q x Q) decay-masked Gram matrix per head), inter-chunk state is a
short ``lax.scan`` over T/Q chunks. This is the memory-feasible training form
(O(T·P + T/Q·P·N) residuals instead of O(T·P·N)) and is reused verbatim by the
chunked mLSTM (models/xlstm.py), which is the *same* algebra with decay
f-gates and (k, q, i·v) as (B, C, X).

Numerics: decays enter as log-space cumulative sums; all exponents are
differences bounded above by 0, so ``exp`` never overflows.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.distributed import sharding as shd
from repro.models import layers as L

CONV_W = 4  # mamba2 causal depthwise conv width


def _segsum(l: jax.Array) -> jax.Array:
    """l: (..., Q) log-decays -> (..., Q, Q) with out[t,s] = sum_{r=s+1..t} l_r
    for s <= t, -inf otherwise (the decay matrix exponent)."""
    Q = l.shape[-1]
    cs = jnp.cumsum(l, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]            # L_t - L_s
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    return jnp.where(mask, diff, -jnp.inf)


def chunked_linear_recurrence(
    log_a: jax.Array,     # (B, T, H)      per-step log decay (<= 0 for stability)
    Bm: jax.Array,        # (B, T, H, N)   input-side vectors
    Cm: jax.Array,        # (B, T, H, N)   output-side vectors
    X: jax.Array,         # (B, T, H, P)   values
    chunk: int,
    h0: jax.Array | None = None,          # (B, H, P, N) initial state
) -> Tuple[jax.Array, jax.Array]:
    """Return (Y, h_final): Y[t] = C_t . h_t with h_t = a_t h_{t-1} + X_t (x) B_t."""
    Bsz, T, H = log_a.shape
    N, Pd = Bm.shape[-1], X.shape[-1]
    assert T % chunk == 0, (T, chunk)
    nc, Q = T // chunk, chunk
    la = log_a.reshape(Bsz, nc, Q, H).astype(jnp.float32)
    Bc = Bm.reshape(Bsz, nc, Q, H, N).astype(jnp.float32)
    Cc = Cm.reshape(Bsz, nc, Q, H, N).astype(jnp.float32)
    Xc = X.reshape(Bsz, nc, Q, H, Pd).astype(jnp.float32)

    lah = jnp.moveaxis(la, -1, 2)                          # (B, nc, H, Q)
    seg = _segsum(lah)                                     # (B, nc, H, Q, Q)
    decay_M = jnp.exp(seg)                                 # masked decay matrix
    # intra-chunk: Y_inner[t] = sum_s M[t,s] (C_t.B_s) X_s
    G = jnp.einsum("bnqhi,bnshi->bnhqs", Cc, Bc)           # Gram (C_t . B_s)
    Y_inner = jnp.einsum("bnhqs,bnhqs,bnshp->bnqhp", G, decay_M, Xc)

    # chunk-final states: S_n = sum_s exp(L_end - L_s) X_s (x) B_s
    Lend = jnp.sum(lah, axis=-1, keepdims=True)            # (B, nc, H, 1)
    Lcum = jnp.cumsum(lah, axis=-1)                        # L_s (inclusive)
    decay_out = jnp.exp(Lend - Lcum)                       # (B, nc, H, Q)
    S_chunk = jnp.einsum("bnhq,bnqhp,bnqhi->bnhpi", decay_out, Xc, Bc)

    # inter-chunk scan: h_{n} = exp(Lend_n) h_{n-1} + S_n
    a_chunk = jnp.exp(Lend.squeeze(-1))                    # (B, nc, H)

    def step(h, inp):
        a_n, S_n = inp                                     # (B,H), (B,H,P,N)
        h_new = a_n[..., None, None] * h + S_n
        return h_new, h                                    # emit state *entering* chunk n

    h_init = jnp.zeros((Bsz, H, Pd, N), jnp.float32) if h0 is None else h0.astype(jnp.float32)
    h_last, h_in = jax.lax.scan(
        step, h_init,
        (jnp.moveaxis(a_chunk, 1, 0), jnp.moveaxis(S_chunk, 1, 0)),
    )
    h_in = jnp.moveaxis(h_in, 0, 1)                        # (B, nc, H, P, N)

    # inter-chunk contribution: C_t . (exp(L_t) h_in)
    decay_in = jnp.exp(Lcum)                               # (B, nc, H, Q)
    Y_inter = jnp.einsum("bnqhi,bnhq,bnhpi->bnqhp", Cc, decay_in, h_in)

    Y = (Y_inner + Y_inter).reshape(Bsz, T, H, Pd)
    return Y, h_last


# ---------------------------------------------------------------------------
# Mamba2 block
# ---------------------------------------------------------------------------

def d_inner(cfg: ArchConfig) -> int:
    return cfg.ssm_expand * cfg.d_model


def n_ssm_heads(cfg: ArchConfig) -> int:
    return d_inner(cfg) // cfg.ssm_headdim


def init_mamba2(key, cfg: ArchConfig) -> Dict:
    D, N = cfg.d_model, cfg.ssm_state
    di, H = d_inner(cfg), n_ssm_heads(cfg)
    conv_ch = di + 2 * N                       # x, B, C go through the conv
    ks = jax.random.split(key, 8)
    return {
        "wz": L.dense_init(ks[0], (D, di)),
        "wxbc": L.dense_init(ks[1], (D, conv_ch)),
        "wdt": L.dense_init(ks[2], (D, H)),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "conv_w": jax.random.normal(ks[3], (CONV_W, conv_ch), jnp.float32) * 0.2,
        "conv_b": jnp.zeros((conv_ch,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, float(H), H).astype(jnp.float32)),
        "Dskip": jnp.ones((H,), jnp.float32),
        "norm": jnp.ones((di,), jnp.float32),
        "wo": L.dense_init(ks[4], (di, D)),
    }


def mamba2_specs(cfg: ArchConfig) -> Dict:
    return {
        "wz": P(None, "model"), "wxbc": P(None, None), "wdt": P(None, "model"),
        "dt_bias": P("model"), "conv_w": P(None, None), "conv_b": P(None),
        "A_log": P("model"), "Dskip": P("model"), "norm": P("model"),
        "wo": P("model", None),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 state: jax.Array | None = None) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv width CONV_W as shifted adds (channel-sharded
    friendly). x: (B, T, C). Returns (y, new_state) with state = last W-1 x's."""
    B, T, C = x.shape
    if state is None:
        state = jnp.zeros((B, CONV_W - 1, C), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)               # (B, T+W-1, C)
    y = sum(xp[:, i:i + T, :] * w[i] for i in range(CONV_W)) + b
    return y.astype(x.dtype), xp[:, -(CONV_W - 1):, :]


def apply_mamba2(
    p: Dict, x: jax.Array, cfg: ArchConfig,
    conv_state=None, ssm_state=None, decode: bool = False,
):
    """x: (B, T, D). Train/prefill: decode=False (chunked SSD). Decode: T == 1,
    states threaded. Returns (y, (conv_state, ssm_state))."""
    B, T, D = x.shape
    di, H, N, Pd = d_inner(cfg), n_ssm_heads(cfg), cfg.ssm_state, cfg.ssm_headdim
    z = L.pdot(x, p["wz"], cfg)
    xbc = L.pdot(x, p["wxbc"], cfg)
    dt = jax.nn.softplus(
        L.pdot(x, p["wdt"], cfg).astype(jnp.float32) + p["dt_bias"]
    )                                                       # (B, T, H)
    xbc, conv_state = _causal_conv(xbc, p["conv_w"], p["conv_b"], conv_state)
    xbc = jax.nn.silu(xbc)
    xs = xbc[..., :di].reshape(B, T, H, Pd)
    Bm = xbc[..., di:di + N][:, :, None, :] * jnp.ones((1, 1, H, 1), xbc.dtype)
    Cm = xbc[..., di + N:][:, :, None, :] * jnp.ones((1, 1, H, 1), xbc.dtype)

    A = -jnp.exp(p["A_log"])                                # (H,) negative
    log_a = dt * A                                          # (B, T, H)
    X = xs.astype(jnp.float32) * dt[..., None]

    if decode:
        assert T == 1
        h0 = ssm_state if ssm_state is not None else jnp.zeros((B, H, Pd, N), jnp.float32)
        a = jnp.exp(log_a[:, 0])                            # (B, H)
        h = a[..., None, None] * h0 + jnp.einsum(
            "bhp,bhn->bhpn", X[:, 0], Bm[:, 0].astype(jnp.float32))
        y = jnp.einsum("bhn,bhpn->bhp", Cm[:, 0].astype(jnp.float32), h)[:, None]
        ssm_state = h
    else:
        chunk = min(cfg.ssm_chunk, T)
        y, ssm_state = chunked_linear_recurrence(log_a, Bm, Cm, X, chunk, h0=ssm_state)

    y = y + xs.astype(jnp.float32) * p["Dskip"][None, None, :, None]
    y = y.reshape(B, T, di).astype(x.dtype)
    # gated RMSNorm (mamba2's norm before out-proj)
    y = y * jax.nn.silu(z)
    yf = y.astype(jnp.float32)
    y = (yf * jax.lax.rsqrt(jnp.mean(yf * yf, -1, keepdims=True) + 1e-6) * p["norm"]).astype(x.dtype)
    out = L.pdot(y, p["wo"], cfg)
    return out, (conv_state, ssm_state)
