"""Shared model layers: norms, activations, MLPs, RoPE / M-RoPE, init helpers.

Everything is functional: params are nested dicts of jnp arrays; init_* builds
them, apply functions consume them. Compute dtype is cfg.dtype (bf16 on TPU);
master params and norm math stay f32.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.core import tensorizer as tz
from repro.distributed import sharding as shd


def cdtype(cfg: ArchConfig):
    return jnp.dtype(cfg.dtype)


def dense_init(key, shape, in_axis: int = 0) -> jax.Array:
    """LeCun-normal init in f32 (master precision)."""
    fan_in = shape[in_axis]
    return jax.random.normal(key, shape, jnp.float32) * (fan_in ** -0.5)


# ---------------------------------------------------------------------------
# Quantizable matmul: the Tensorizer integration point (DESIGN.md §4)
# ---------------------------------------------------------------------------

def pdot(x: jax.Array, w, cfg: ArchConfig) -> jax.Array:
    """Activation @ weight with the framework's precision policy.

    ``w`` is a plain array (training / quantize=off) or a ``QTensor`` produced
    by ``tensorizer.quantize_params`` (serving, quantize="serve") — in which
    case the contraction runs int8 x int8 with wide accumulation and fused
    dequant (the paper's technique as the serving fast path).

    Activations are calibrated per-ROW (amax over the contraction dim only),
    not per-tensor: a row's quantization scale must depend only on that row,
    or one slot's numerics shift with whatever else shares the decode batch —
    an idle slot's stale cache row changing another stream's sampled token.
    Per-row scales make serving batch-invariant (same stream, same tokens,
    regardless of co-residents or admission order), which is what lets a
    disaggregated continuation on another host stay bit-identical. The
    paper-faithful per-tensor calibration lives in ``tensorizer.qdot`` /
    ``qdot_paper`` for the accuracy benchmarks.
    """
    if isinstance(w, tz.QTensor):
        qx = tz.quantize(x.astype(jnp.float32), axis=(x.ndim - 1,))
        acc = jax.lax.dot_general(
            qx.q, w.q,
            dimension_numbers=(((x.ndim - 1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32,
        )
        return (acc.astype(jnp.float32) * (qx.scale * w.scale)).astype(cdtype(cfg))
    # preferred_element_type pins the output dtype even when XLA folds an
    # upstream f32->bf16 convert into the dot — otherwise the TP partial-sum
    # all-reduce after row-parallel matmuls silently runs at f32 (2x bytes;
    # found via HLO metadata in §Perf cell A)
    return jnp.dot(x, w.astype(cdtype(cfg)),
                   preferred_element_type=cdtype(cfg))


# ---------------------------------------------------------------------------
# bf16 gradient barrier (comm-dtype discipline)
# ---------------------------------------------------------------------------

@jax.custom_vjp
def bf16_grad(x):
    """Identity forward; backward casts the cotangent to bf16 *before* it
    flows into the TP dgrad matmuls — keeping the big activation-gradient
    all-reduces in bf16 instead of f32 (halves §Perf cell A's collective
    bytes). Standard Megatron communication-precision discipline."""
    return x


def _bf16_grad_fwd(x):
    return x, x.dtype


def _bf16_grad_bwd(x_dtype, g):
    # truncate cotangent mantissa to bf16, keep the primal's dtype contract
    return (g.astype(jnp.bfloat16).astype(x_dtype),)


bf16_grad.defvjp(_bf16_grad_fwd, _bf16_grad_bwd)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_norm(cfg: ArchConfig, d: int) -> Dict:
    p = {"scale": jnp.ones((d,), jnp.float32)}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((d,), jnp.float32)
    return p


def apply_norm(p: Dict, x: jax.Array, cfg: ArchConfig, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.dtype(cfg.norm_dtype))
    if cfg.norm == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    else:
        ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
        out = xf * jax.lax.rsqrt(ms + eps) * p["scale"]
    return out.astype(x.dtype)


def rms_head_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    """qk_norm (qwen3): RMS-normalize the last (head) dim of q/k."""
    xf = x.astype(jnp.float32)
    ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * scale).astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (dense FFN)
# ---------------------------------------------------------------------------

def init_mlp(key, cfg: ArchConfig, d: int, f: int) -> Dict:
    ks = jax.random.split(key, 3)
    if cfg.act == "swiglu":
        return {
            "wi": dense_init(ks[0], (d, f)),
            "wg": dense_init(ks[1], (d, f)),
            "wo": dense_init(ks[2], (f, d)),
        }
    return {"wi": dense_init(ks[0], (d, f)), "wo": dense_init(ks[2], (f, d))}


def mlp_specs(cfg: ArchConfig) -> Dict:
    if cfg.act == "swiglu":
        return {"wi": P(None, "model"), "wg": P(None, "model"), "wo": P("model", None)}
    return {"wi": P(None, "model"), "wo": P("model", None)}


def apply_mlp(p: Dict, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    h = pdot(x, p["wi"], cfg)
    if cfg.act == "swiglu":
        h = jax.nn.silu(h) * pdot(x, p["wg"], cfg)
    else:
        h = jax.nn.gelu(h)
    h = shd.with_sharding(h, shd.batch_spec(*([None] * (h.ndim - 2)), "model"))
    return pdot(h, p["wo"], cfg)


# ---------------------------------------------------------------------------
# RoPE and M-RoPE
# ---------------------------------------------------------------------------

def rope_freqs(hd: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd); positions: (..., S) int32."""
    hd = x.shape[-1]
    inv = rope_freqs(hd, theta)                                   # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * inv          # (..., S, hd/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(
    x: jax.Array, positions3: jax.Array, theta: float, sections: Tuple[int, int, int]
) -> jax.Array:
    """Qwen2-VL multimodal RoPE: the rotary half-dims are split into
    (temporal, height, width) sections, each rotated by its own position id.

    x: (B, S, H, hd); positions3: (3, B, S).
    """
    hd = x.shape[-1]
    half = hd // 2
    assert sum(sections) == half, (sections, hd)
    inv = rope_freqs(hd, theta)                                   # (half,)
    # build per-frequency position: section s of the freq axis uses positions3[s]
    sec_id = jnp.repeat(
        jnp.arange(3), jnp.array(sections), total_repeat_length=half
    )                                                             # (half,)
    pos = positions3.astype(jnp.float32)[sec_id]                  # (half, B, S): section gather
    ang = jnp.moveaxis(pos, 0, -1) * inv                          # (B, S, half)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)
