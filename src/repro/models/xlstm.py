"""xLSTM blocks: mLSTM (matrix memory) and sLSTM (scalar memory with
recurrent gating), per Beck et al. 2024 (arXiv:2405.04517).

TPU adaptation (DESIGN.md §2, §Arch-applicability):

  * mLSTM — the matrix-memory recurrence C_t = f~_t C_{t-1} + i~_t v_t k_t^T
    is the *same algebra* as Mamba2's SSD (scalar decay per head, outer-product
    increment), so training reuses ``ssm.chunked_linear_recurrence`` with
    (B, C, X) := (k, q, i~ * v) — MXU matmuls instead of a T-step scan. The
    exponential-gating stabilizer m_t has the closed form
        m_t = F_t + cummax_s(log i_s - F_s),   F_t = cumsum(log f)
    (max-plus scan), so no sequential pass is needed for it either.
  * sLSTM — genuinely sequential (h_{t-1} feeds the gates through recurrent
    block-diagonal R); implemented as a ``lax.scan`` over time with per-head
    block recurrence. Carries are (B, D)-sized scalars — cheap residuals.
    This matches the xLSTM paper's own characterization (sLSTM is not
    parallelizable; it trades throughput for its memory-mixing ability).

Block layout for xlstm-125m: even layers mLSTM, odd layers sLSTM (1:1), both
pre-norm residual with internal up/down projections (d_ff = 0 in the config —
there is no separate FFN).
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.distributed import sharding as shd
from repro.models import layers as L
from repro.models.ssm import chunked_linear_recurrence


# Stabilizer "no history" sentinel. NOT -inf/-1e30: the chunked form runs the
# decays through cumsum, and -1e30 + x == -1e30 in f32 (absorption) would
# destroy every subsequent decay term. exp(-60) ~ 1e-26 is exactly zero
# relative to any real term, while -60 + x stays fully precise.
M_INIT = -60.0


def _heads(cfg: ArchConfig) -> Tuple[int, int]:
    H = cfg.n_heads
    return H, cfg.d_model // H          # (heads, head dim) — e.g. 4 x 192


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def init_mlstm(key, cfg: ArchConfig) -> Dict:
    D = cfg.d_model
    H, hd = _heads(cfg)
    ks = jax.random.split(key, 8)
    return {
        "wq": L.dense_init(ks[0], (D, H * hd)),
        "wk": L.dense_init(ks[1], (D, H * hd)),
        "wv": L.dense_init(ks[2], (D, H * hd)),
        "wi": L.dense_init(ks[3], (D, H)),     # input gate (per head)
        "wf": L.dense_init(ks[4], (D, H)),     # forget gate (per head)
        "b_i": jnp.zeros((H,), jnp.float32),
        "b_f": jnp.full((H,), 3.0, jnp.float32),   # bias toward remembering
        "wo_gate": L.dense_init(ks[5], (D, H * hd)),
        "wo": L.dense_init(ks[6], (H * hd, D)),
    }


def mlstm_specs(cfg: ArchConfig) -> Dict:
    m = "model" if cfg.shard_heads else None
    return {
        "wq": P(None, m), "wk": P(None, m), "wv": P(None, m),
        "wi": P(None, m), "wf": P(None, m), "b_i": P(m), "b_f": P(m),
        "wo_gate": P(None, m), "wo": P(m, None),
    }


def _stabilizer(log_f: jax.Array, log_i: jax.Array) -> jax.Array:
    """m_t = max(log f_t + m_{t-1}, log i_t), m_0 = M_INIT, via the max-plus
    closed form m_t = F_t + max(cummax_s(li_s - F_s), M_INIT - F_0 + lf_0...).
    The M_INIT branch can only win at t=0 (decays are negative), where it
    equals max(li_0, lf_0 + M_INIT) — folded in via the initial cummax term."""
    F = jnp.cumsum(log_f, axis=1)                       # (B, T, H)
    base = jax.lax.cummax(log_i - F, axis=1)
    init = (M_INIT + log_f[:, :1] - F[:, :1])           # lf_0 + M_INIT - F_0
    return F + jnp.maximum(base, init)


def apply_mlstm(p: Dict, x: jax.Array, cfg: ArchConfig,
                state=None, decode: bool = False):
    """x: (B, T, D). state = (C, n, m) for decode. Returns (y, state)."""
    B, T, D = x.shape
    H, hd = _heads(cfg)
    q = L.pdot(x, p["wq"], cfg).reshape(B, T, H, hd)
    k = L.pdot(x, p["wk"], cfg).reshape(B, T, H, hd) * (hd ** -0.5)
    v = L.pdot(x, p["wv"], cfg).reshape(B, T, H, hd)
    log_i = (L.pdot(x, p["wi"], cfg).astype(jnp.float32) + p["b_i"])      # (B,T,H)
    log_f = jax.nn.log_sigmoid(
        L.pdot(x, p["wf"], cfg).astype(jnp.float32) + p["b_f"])

    if decode:
        assert T == 1
        C0, n0, m0 = state if state is not None else (
            jnp.zeros((B, H, hd, hd), jnp.float32),
            jnp.zeros((B, H, hd), jnp.float32),
            jnp.full((B, H), M_INIT, jnp.float32),
        )
        li, lf = log_i[:, 0], log_f[:, 0]                                  # (B,H)
        m = jnp.maximum(lf + m0, li)
        f_t = jnp.exp(lf + m0 - m)
        i_t = jnp.exp(li - m)
        kf, vf, qf = (t[:, 0].astype(jnp.float32) for t in (k, v, q))
        C = f_t[..., None, None] * C0 + i_t[..., None, None] * jnp.einsum(
            "bhp,bhn->bhpn", vf, kf)
        n = f_t[..., None] * n0 + i_t[..., None] * kf
        num = jnp.einsum("bhpn,bhn->bhp", C, qf)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhn,bhn->bh", n, qf)), jnp.exp(-m))
        h = (num / den[..., None])[:, None]                                # (B,1,H,hd)
        new_state = (C, n, m)
    else:
        m = _stabilizer(log_f, log_i)                                      # (B,T,H)
        m_prev = jnp.concatenate([jnp.full((B, 1, H), M_INIT, jnp.float32),
                                  m[:, :-1]], axis=1)
        log_fs = log_f + m_prev - m                  # stabilized decay (<= 0)
        i_s = jnp.exp(log_i - m)                     # stabilized input gate
        kf = k.astype(jnp.float32)
        qf = q.astype(jnp.float32)
        Xv = v.astype(jnp.float32) * i_s[..., None]
        chunk = min(cfg.ssm_chunk, T)
        if T % chunk:
            chunk = T                                # smoke shapes: single chunk
        num, C_last = chunked_linear_recurrence(log_fs, kf, qf, Xv, chunk)
        ones = jnp.ones((B, T, H, 1), jnp.float32)
        den_raw, n_last_pn = chunked_linear_recurrence(
            log_fs, kf, qf, i_s[..., None] * ones, chunk)
        den = jnp.maximum(jnp.abs(den_raw.squeeze(-1)), jnp.exp(-m))       # (B,T,H)
        h = num / den[..., None]
        new_state = (C_last, n_last_pn.squeeze(-2), m[:, -1])
    h = h * jax.nn.sigmoid(L.pdot(x, p["wo_gate"], cfg)
                           .reshape(B, T, H, hd).astype(jnp.float32))
    out = L.pdot(h.reshape(B, T, H * hd).astype(x.dtype), p["wo"], cfg)
    return out, new_state


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def init_slstm(key, cfg: ArchConfig) -> Dict:
    D = cfg.d_model
    H, hd = _heads(cfg)
    ks = jax.random.split(key, 10)
    gates = {}
    for gi, g in enumerate(("z", "i", "f", "o")):
        gates[f"w{g}"] = L.dense_init(ks[gi], (D, D))
        gates[f"r{g}"] = jax.random.normal(ks[4 + gi], (H, hd, hd), jnp.float32) * (hd ** -0.5)
        gates[f"b{g}"] = (jnp.full((D,), 1.0, jnp.float32) if g == "f"
                          else jnp.zeros((D,), jnp.float32))
    gates["wup"] = L.dense_init(ks[8], (D, 2 * D))
    gates["wdown"] = L.dense_init(ks[9], (D, D))
    return gates


def slstm_specs(cfg: ArchConfig) -> Dict:
    p = {}
    for g in ("z", "i", "f", "o"):
        p[f"w{g}"] = P(None, None)
        p[f"r{g}"] = P(None, None, None)
        p[f"b{g}"] = P(None)
    p["wup"] = P(None, "model")
    p["wdown"] = P(None, None)
    return p


def apply_slstm(p: Dict, x: jax.Array, cfg: ArchConfig,
                state=None, decode: bool = False):
    """Sequential scan over T. state = (c, n, h, m), each (B, D)."""
    B, T, D = x.shape
    H, hd = _heads(cfg)
    xz = L.pdot(x, p["wz"], cfg).astype(jnp.float32) + p["bz"]
    xi = L.pdot(x, p["wi"], cfg).astype(jnp.float32) + p["bi"]
    xf = L.pdot(x, p["wf"], cfg).astype(jnp.float32) + p["bf"]
    xo = L.pdot(x, p["wo"], cfg).astype(jnp.float32) + p["bo"]

    if state is None:
        zeros = jnp.zeros((B, D), jnp.float32)
        state = (zeros, zeros + 1e-6, zeros, jnp.full((B, D), -1e30, jnp.float32))

    def rmul(r, h):                                      # block-diag recurrence
        hh = h.reshape(B, H, hd)
        return jnp.einsum("bhp,hpn->bhn", hh, r).reshape(B, D)

    def step(carry, inp):
        c, n, h, m = carry
        xz_t, xi_t, xf_t, xo_t = inp
        z = jnp.tanh(xz_t + rmul(p["rz"], h))
        li = xi_t + rmul(p["ri"], h)
        lf = jax.nn.log_sigmoid(xf_t + rmul(p["rf"], h))
        o = jax.nn.sigmoid(xo_t + rmul(p["ro"], h))
        m_new = jnp.maximum(lf + m, li)
        i_s = jnp.exp(li - m_new)
        f_s = jnp.exp(lf + m - m_new)
        c_new = f_s * c + i_s * z
        n_new = f_s * n + i_s
        h_new = o * c_new / jnp.maximum(n_new, 1e-6)
        return (c_new, n_new, h_new, m_new), h_new

    seq = (jnp.moveaxis(xz, 1, 0), jnp.moveaxis(xi, 1, 0),
           jnp.moveaxis(xf, 1, 0), jnp.moveaxis(xo, 1, 0))
    new_state, hs = jax.lax.scan(step, state, seq)
    h_seq = jnp.moveaxis(hs, 0, 1).astype(x.dtype)       # (B, T, D)
    # GeGLU-ish up/down projection (the sLSTM block's internal FFN)
    up = L.pdot(h_seq, p["wup"], cfg)
    a, b = jnp.split(up, 2, axis=-1)
    out = L.pdot(jax.nn.gelu(a) * b, p["wdown"], cfg)
    return out, new_state
