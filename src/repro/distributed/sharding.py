"""Sharding rules: logical roles -> mesh axes, for any of the three meshes.

Meshes (launch/mesh.py):
  smoke       (1,)            ("data",)                     CPU tests
  single-pod  (16, 16)        ("data", "model")             256 chips
  multi-pod   (2, 16, 16)     ("pod", "data", "model")      512 chips

Roles:
  batch      -> ("pod","data")  hierarchical DP (intra-pod ICI reduce-scatter,
                                inter-pod DCI all-reduce — GSPMD derives it)
  model-dim  -> "model"         TP: attention heads / d_ff / vocab / experts (EP)
  sequence   -> "data"          SP for long-context KV caches (decode cells)

The mesh is carried in a module-level context so model code never takes a mesh
parameter; tests and launchers call ``set_mesh``/``use_mesh``.
"""

from __future__ import annotations

import contextlib
from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_MESH: Optional[Mesh] = None


def set_mesh(mesh: Optional[Mesh]) -> None:
    global _MESH
    _MESH = mesh


def current_mesh() -> Mesh:
    if _MESH is None:
        raise RuntimeError("no mesh set — call distributed.set_mesh(...) or use_mesh(...)")
    return _MESH


@contextlib.contextmanager
def use_mesh(mesh: Mesh):
    global _MESH
    prev = _MESH
    _MESH = mesh
    try:
        with mesh:
            yield mesh
    finally:
        _MESH = prev


def axis_names() -> Tuple[str, ...]:
    return tuple(current_mesh().axis_names)


def batch_axes() -> Tuple[str, ...]:
    """Axes the batch dimension shards over (pod+data when present)."""
    return tuple(a for a in ("pod", "data") if a in axis_names())


def model_axis() -> Optional[str]:
    return "model" if "model" in axis_names() else None


def seq_axis() -> Optional[str]:
    """Axis used for sequence sharding of long KV caches (SP)."""
    return "data" if "data" in axis_names() else None


def data_parallel_size() -> int:
    m = current_mesh()
    n = 1
    for a in batch_axes():
        n *= m.shape[a]
    return n


def model_parallel_size() -> int:
    m = current_mesh()
    a = model_axis()
    return m.shape[a] if a else 1


def batch_spec(*trailing) -> P:
    """P((pod,data), *trailing) — the activation batch sharding."""
    ax = batch_axes()
    lead = ax if len(ax) > 1 else (ax[0] if ax else None)
    return P(lead, *trailing)


def with_sharding(x, spec: P):
    """``lax.with_sharding_constraint`` against the current mesh (no-op when
    the spec refers to axes the mesh doesn't have)."""
    mesh = current_mesh()
    names = set(mesh.axis_names)

    def scrub(entry):
        if entry is None:
            return None
        if isinstance(entry, (tuple, list)):
            kept = tuple(e for e in entry if e in names)
            return kept if kept else None
        return entry if entry in names else None

    spec = P(*(scrub(e) for e in spec))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def shard(x, spec: P):
    """device_put with a NamedSharding on the current mesh."""
    return jax.device_put(x, NamedSharding(current_mesh(), spec))
