"""Distribution layer: mesh context, sharding rules, collective helpers."""

from repro.distributed.sharding import (  # noqa: F401
    batch_axes,
    batch_spec,
    current_mesh,
    data_parallel_size,
    model_axis,
    set_mesh,
    with_sharding,
)
