"""Backprop (paper §7.2.5): plain-vanilla feedforward NN training step —
FullyConnected layers + activation + tpuGemm for the weight-delta outer
products + ``add`` for the update, per the paper's instruction mapping."""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.apps.common import register
from repro.core import instr as I
from repro.core.gemm import tpu_gemm

HIDDEN = 64
LR = 0.1


def _sigmoid(x):
    return 1.0 / (1.0 + np.exp(-x))


@register("backprop")
def run(n: int, quantized: bool = True):
    rng = np.random.default_rng(0)
    X = rng.normal(size=(n, 16)).astype(np.float32)
    y = (X.sum(axis=1, keepdims=True) > 0).astype(np.float32)
    W1 = rng.normal(size=(16, HIDDEN)).astype(np.float32) * 0.5
    W2 = rng.normal(size=(HIDDEN, 1)).astype(np.float32) * 0.5

    def train_step_gptpu(W1, W2):
        fc = I.fully_connected_quant if quantized else I.fully_connected_fp
        gemm = (lambda a, b: tpu_gemm(a, b)) if quantized else (lambda a, b: a @ b)
        Xj = jnp.asarray(X)
        h = 1.0 / (1.0 + jnp.exp(-fc(Xj, jnp.asarray(W1))))
        o = 1.0 / (1.0 + jnp.exp(-fc(h, jnp.asarray(W2))))
        d_o = (o - y) * o * (1 - o)
        d_h = fc(d_o, jnp.asarray(W2).T) * h * (1 - h)
        gW2 = gemm(jnp.asarray(h).T, d_o) / n
        gW1 = gemm(Xj.T, d_h) / n
        W2n = I.sub_fp(jnp.asarray(W2), LR * gW2)      # update via add/sub
        W1n = I.sub_fp(jnp.asarray(W1), LR * gW1)
        return np.asarray(W1n), np.asarray(W2n)

    W1g, W2g = train_step_gptpu(W1, W2)
    out = np.concatenate([W1g.ravel(), W2g.ravel()]).astype(np.float64)

    def ref():
        h = _sigmoid(X @ W1)
        o = _sigmoid(h @ W2)
        d_o = (o - y) * o * (1 - o)
        d_h = (d_o @ W2.T) * h * (1 - h)
        gW2 = h.T @ d_o / n
        gW1 = X.T @ d_h / n
        return np.concatenate([(W1 - LR * gW1).ravel(),
                               (W2 - LR * gW2).ravel()]).astype(np.float64)

    return out, ref
