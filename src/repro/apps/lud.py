"""LU decomposition (paper §7.2.3): recursive block algorithm via crop /
FullyConnected / conv2D — the O(n^3) Schur-complement update runs on tpuGemm,
triangular solves stay on the host (exactly the paper's CPU/TPU split).

Input: diagonally-dominant small-integer matrices (quantization-lossless for
the dominant range, matching the paper's measured 0.00% LUD error)."""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.apps.common import register
from repro.core import tensorizer as tz
from repro.core.gemm import tpu_gemm

BLOCK = 32


def _lu_base(A: np.ndarray):
    """Doolittle LU (no pivoting) for the base block."""
    n = A.shape[0]
    L = np.eye(n, dtype=np.float64)
    U = A.astype(np.float64).copy()
    for k in range(n - 1):
        L[k + 1:, k] = U[k + 1:, k] / U[k, k]
        U[k + 1:, k:] -= np.outer(L[k + 1:, k], U[k, k:])
        U[k + 1:, k] = 0.0
    return L, U


def _lu_block(A: np.ndarray, quantized: bool):
    n = A.shape[0]
    if n <= BLOCK:
        return _lu_base(A)
    h = n // 2
    A11, A12 = A[:h, :h], A[:h, h:]        # the paper's `crop`
    A21, A22 = A[h:, :h], A[h:, h:]
    L11, U11 = _lu_block(A11, quantized)
    U12 = np.linalg.solve(L11, A12)                        # host triangular solve
    L21 = np.linalg.solve(U11.T, A21.T).T
    if quantized:
        prod = np.asarray(tpu_gemm(jnp.asarray(L21.astype(np.float32)),
                                   jnp.asarray(U12.astype(np.float32))),
                          dtype=np.float64)
    else:
        prod = L21 @ U12
    S = A22 - prod                                          # Schur complement
    L22, U22 = _lu_block(S, quantized)
    L = np.block([[L11, np.zeros((h, n - h))], [L21, L22]])
    U = np.block([[U11, U12], [np.zeros((n - h, h)), U22]])
    return L, U


@register("lud")
def run(n: int, quantized: bool = True):
    rng = np.random.default_rng(0)
    A = rng.integers(-8, 9, (n, n)).astype(np.float64)
    A += np.eye(n) * 8.0 * n               # diagonal dominance (no pivoting)
    L, U = _lu_block(A, quantized)
    out = L @ U                            # validate the factorization

    def ref():
        return A

    return out, ref
