"""GEMM (paper §7.1): the tpuGemm library call vs fp reference."""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.apps.common import register
from repro.core.gemm import tpu_gemm


@register("gemm")
def run(n: int, quantized: bool = True):
    # positive-range data per the paper's GEMM evaluation (Fig. 7: "1024x1024
    # matrices with positive integers"); zero-mean data makes MAPE a
    # cancellation metric rather than an accuracy one (RMSE covers that case)
    rng = np.random.default_rng(0)
    a = rng.uniform(0.0, 16.0, (n, n)).astype(np.float32)
    b = rng.uniform(0.0, 16.0, (n, n)).astype(np.float32)
    lowering = None if quantized else "fp32"
    out = tpu_gemm(jnp.asarray(a), jnp.asarray(b), lowering=lowering)
    return np.asarray(out), lambda: a.astype(np.float64) @ b.astype(np.float64)
