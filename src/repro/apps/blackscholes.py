"""Black-Scholes (paper §7.2.6): option pricing where the cumulative normal
distribution is a ninth-degree polynomial evaluated as one FullyConnected
(powers-of-x matrix x coefficient vector) — the paper's mapping of a scalar
special function onto the matrix unit."""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.apps.common import register
from repro.core import instr as I

import math

_DEG = 9
# Fit Phi on the NORMALIZED basis t = x/4 in [-1, 1]: every power t^i stays
# in [-1, 1], so the Tensorizer's int8 quantization keeps full resolution on
# all basis columns (quantizing raw x^9 ~ 2.6e5 would destroy the low-order
# terms — the same range-awareness the paper's §6.2.2 rules encode).
_xs = np.linspace(-1, 1, 4001)
_phi = 0.5 * (1.0 + np.array([math.erf(4 * t / math.sqrt(2)) for t in _xs]))
_COEF = np.polyfit(_xs, _phi, _DEG)[::-1].astype(np.float32)   # ascending


def _cnd_gptpu(x: jnp.ndarray, quantized: bool) -> jnp.ndarray:
    t = jnp.clip(x / 4.0, -1.0, 1.0)
    powers = jnp.stack([t ** i for i in range(_DEG + 1)], axis=-1)  # (N, 10)
    if quantized:
        # per-column Tensorizer calibration (blocked §6.2.1) + two-pass
        # residual refinement: quantize, then quantize the residual — two int8
        # passes ~ 14-bit effective precision. This is the paper's §10 claim
        # "GPETPU can achieve the desired level of precision by iteratively
        # computing on different portions of raw input numbers", implemented.
        from repro.core import tensorizer as tz
        pq = tz.fake_quantize(powers, axis=(0,))
        resid = tz.fake_quantize(powers - pq, axis=(0,))
        out = (pq + resid) @ jnp.asarray(_COEF)[:, None]
    else:
        out = I.fully_connected_fp(powers, jnp.asarray(_COEF)[:, None])
    return jnp.clip(out[..., 0], 0.0, 1.0)


def _cnd_ref(x: np.ndarray) -> np.ndarray:
    return np.array([0.5 * (1.0 + math.erf(t / math.sqrt(2))) for t in x])


def _bs_call(S, K, T, r, sigma, cnd):
    d1 = (np.log(S / K) + (r + 0.5 * sigma ** 2) * T) / (sigma * np.sqrt(T))
    d2 = d1 - sigma * np.sqrt(T)
    return S * cnd(d1) - K * np.exp(-r * T) * cnd(d2)


@register("blackscholes")
def run(n: int, quantized: bool = True):
    rng = np.random.default_rng(0)
    N = n * n                                  # n is a side length elsewhere
    S = rng.uniform(10, 100, N)
    K = S * rng.uniform(0.7, 1.3, N)           # bounded moneyness (AxBench-like
    T = rng.uniform(0.2, 2.0, N)               # option params, not deep-OTM dust)
    r, sigma = 0.05, 0.3

    out = _bs_call(S, K, T, r, sigma,
                   lambda d: np.asarray(_cnd_gptpu(jnp.asarray(d, jnp.float32), quantized),
                                        dtype=np.float64))

    def ref():
        return _bs_call(S, K, T, r, sigma, _cnd_ref)

    return out, ref
