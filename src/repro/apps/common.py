"""Shared app scaffolding + the paper's error metrics."""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict

import numpy as np

ALL: Dict[str, Callable] = {}


@dataclasses.dataclass
class AppResult:
    name: str
    n: int
    mape_pct: float
    rmse_pct: float
    t_gptpu_s: float
    t_ref_s: float

    @property
    def speedup_proxy(self) -> float:
        """Host wall-time ratio — NOT the paper's CPU-vs-EdgeTPU speedup (we
        have no accelerator); Fig. 6 reproduction derives v5e-time from the
        roofline instead (benchmarks/fig6_apps.py)."""
        return self.t_ref_s / max(self.t_gptpu_s, 1e-12)


def mape(out: np.ndarray, ref: np.ndarray, rel_floor: float = 1e-3) -> float:
    """Mean absolute percentage error (paper Table 4a), in percent.

    Near-zero reference entries are excluded (|ref| < rel_floor x range):
    percentage error against a ~0 denominator is unbounded noise — the metric
    pathology, not computation error (LUD/GEMM have exact zeros in ref)."""
    thresh = rel_floor * max(float(np.max(np.abs(ref))), 1e-12)
    mask = np.abs(ref) >= thresh
    if not mask.any():
        return 0.0
    return float(np.mean(np.abs(out[mask] - ref[mask]) / np.abs(ref[mask])) * 100.0)


def rmse_pct(out: np.ndarray, ref: np.ndarray) -> float:
    """Range-normalized RMSE (paper Table 4b), in percent."""
    rng = max(float(ref.max() - ref.min()), 1e-9)
    return float(np.sqrt(np.mean((out - ref) ** 2)) / rng * 100.0)


def register(name: str):
    def deco(fn):
        ALL[name] = fn
        return fn
    return deco


def run_app(name: str, n: int = 256, quantized: bool = True) -> AppResult:
    fn = ALL[name]
    t0 = time.perf_counter()
    out, ref_fn = fn(n, quantized=quantized)
    t_g = time.perf_counter() - t0
    t0 = time.perf_counter()
    ref = ref_fn()
    t_r = time.perf_counter() - t0
    out = np.asarray(out, dtype=np.float64)
    ref = np.asarray(ref, dtype=np.float64)
    return AppResult(name=name, n=n, mape_pct=mape(out, ref),
                     rmse_pct=rmse_pct(out, ref), t_gptpu_s=t_g, t_ref_s=t_r)
