"""The paper's seven applications (GPETPU §7), each with a GPETPU
(Tensorizer-quantized) implementation and an fp reference, reporting the
paper's accuracy metrics (MAPE / RMSE, Table 4).

Registry:   apps.ALL  — name -> run(n, quantized=...) -> AppResult
"""

from repro.apps.common import ALL, AppResult, mape, rmse_pct, run_app  # noqa: F401
from repro.apps import backprop, blackscholes, gaussian, gemm_app, hotspot3d, lud, pagerank  # noqa: F401
