"""PageRank (paper §7.2.1): power method, one FullyConnected (mat-vec) per
iteration on the quantized adjacency matrix."""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.apps.common import register
from repro.core import instr as I

DAMPING = 0.85
ITERS = 20


def _graph(n: int, rng) -> np.ndarray:
    """Column-stochastic adjacency of a random sparse-ish graph."""
    deg = 8
    M = np.zeros((n, n), np.float32)
    for j in range(n):
        targets = rng.choice(n, size=min(deg, n), replace=False)
        M[targets, j] = 1.0
    M /= np.maximum(M.sum(axis=0, keepdims=True), 1.0)
    return M


@register("pagerank")
def run(n: int, quantized: bool = True):
    rng = np.random.default_rng(0)
    M = _graph(n, rng)
    r = np.full((n,), 1.0 / n, np.float32)
    fc = I.fully_connected_quant if quantized else I.fully_connected_fp
    Mj = jnp.asarray(M.T)                 # FullyConnected computes v @ W
    rv = jnp.asarray(r)
    for _ in range(ITERS):
        rv = DAMPING * fc(rv, Mj) + (1 - DAMPING) / n
        rv = rv / jnp.sum(rv)

    def ref():
        rr = np.full((n,), 1.0 / n, np.float64)
        Md = M.astype(np.float64)
        for _ in range(ITERS):
            rr = DAMPING * (Md @ rr) + (1 - DAMPING) / n
            rr = rr / rr.sum()
        return rr

    return np.asarray(rv), ref
