"""Gaussian elimination (paper §7.2.4): row reduction per pivot where the
rank-1 update (factor column x pivot row) runs on the pairwise ``mul``
instruction, then ``sub`` — the paper's exact instruction mapping."""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.apps.common import register
from repro.core import instr as I


def _eliminate(Ab: jnp.ndarray, quantized: bool) -> jnp.ndarray:
    n = Ab.shape[0]
    mul = I.mul_quant if quantized else I.mul_fp
    sub = I.sub_quant if quantized else I.sub_fp

    A = Ab
    for k in range(n - 1):
        pivot_row = A[k]                               # (n+1,)
        factors = A[:, k] / A[k, k]                    # (n,)
        mask = (jnp.arange(n) > k).astype(A.dtype)
        factors = factors * mask
        # rank-1 update as pair-wise `mul` of broadcast matrices, then `sub`
        update = mul(jnp.broadcast_to(factors[:, None], A.shape),
                     jnp.broadcast_to(pivot_row[None, :], A.shape))
        A = sub(A, update)
    return A


def _banded_integer_system(n: int, rng, band: int = 4):
    """A = L @ U with banded unit-lower L (multipliers in {-1,0,1}) and small
    integer U: every elimination multiplier is an exact small integer and all
    intermediates stay integer within +-127, so the int8 pipeline with
    integer-snapped scales runs EXACTLY (the paper's 0.00% Gaussian row)."""
    L = np.eye(n, dtype=np.float64)
    U = np.zeros((n, n), np.float64)
    for i in range(n):
        lo = max(0, i - band)
        L[i, lo:i] = rng.integers(-1, 2, i - lo)
        U[i, i] = rng.integers(3, 7)
        hi = min(n, i + band)
        U[i, i + 1:hi] = rng.integers(-2, 3, hi - i - 1)
    return L @ U


def _eliminate_np(Ab: np.ndarray) -> np.ndarray:
    A = Ab.astype(np.float64).copy()
    n = A.shape[0]
    for k in range(n - 1):
        factors = A[:, k] / A[k, k]
        factors[:k + 1] = 0.0
        A -= np.outer(factors, A[k])
    return A


@register("gaussian")
def run(n: int, quantized: bool = True):
    n = min(n, 96)                                     # python-loop pivots
    rng = np.random.default_rng(0)
    A = _banded_integer_system(n, rng).astype(np.float32)
    # b = A @ x with x in {-1,0,1}: the transformed RHS is U @ x — bounded and
    # integer all the way through (an arbitrary b would grow like L^{-1} b and
    # leave the int8-exact range)
    x_true = rng.integers(-1, 2, (n,)).astype(np.float32)
    b = (A @ x_true).astype(np.float32)
    Ab = np.concatenate([A, b[:, None]], axis=1)

    # the application output is the eliminated (upper-triangularized) system,
    # compared against the same elimination in fp64 (the CPU baseline)
    out = np.asarray(_eliminate(jnp.asarray(Ab), quantized), dtype=np.float64)

    def ref():
        return _eliminate_np(Ab)

    return out, ref
