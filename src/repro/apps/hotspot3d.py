"""HotSpot3D (paper §7.2.2): thermal simulation, 3x3 stencil per layer (the
paper's conv2D mapping) + z-coupling and power terms as pairwise adds.

The stencil runs through the Pallas kernel (interpret mode on CPU) in the
quantized variant the paper's way: conv2D on a Tensorizer-quantized field."""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.apps.common import register
from repro.core import instr as I
from repro.kernels import ops as K

ITERS = 8
NZ = 4

W = np.array([[0.05, 0.10, 0.05],
              [0.10, 0.30, 0.10],
              [0.05, 0.10, 0.05]], np.float32)
CZ = 0.05          # coupling to layers above/below
AMB = 0.05         # ambient leak


def _step_fp(T, P):
    out = np.empty_like(T)
    for z in range(T.shape[0]):
        field = T[z]
        pad = np.pad(field, 1)
        acc = np.zeros_like(field)
        for p in range(3):
            for q in range(3):
                acc += W[p, q] * pad[p:p + field.shape[0], q:q + field.shape[1]]
        up = T[z - 1] if z > 0 else field
        dn = T[z + 1] if z < T.shape[0] - 1 else field
        out[z] = acc * (1 - 2 * CZ - AMB) + CZ * up + CZ * dn + P[z]
    return out


@register("hotspot3d")
def run(n: int, quantized: bool = True):
    rng = np.random.default_rng(0)
    T0 = (rng.uniform(40, 80, (NZ, n, n))).astype(np.float32)
    P = (rng.uniform(0, 1.0, (NZ, n, n))).astype(np.float32)

    T = jnp.asarray(T0)
    Pj = jnp.asarray(P)
    w = jnp.asarray(W)
    # Residual-form stencil: conv(T, W) = mean + conv(T - mean, W). The conv2D
    # instruction then quantizes the *residual field* (range ~ +-20) instead of
    # the absolute temperatures (~40-80): 2x finer int8 resolution, and the
    # error stays relative to the residual, not the field — the Tensorizer
    # "transform data to minimize loss of accuracy" rule (§6.2.2) applied.
    # position-dependent stencil mass (boundary cells see fewer taps)
    mass = I.conv2d_fp(jnp.ones((n, n), jnp.float32), w)
    for _ in range(ITERS):
        new = []
        for z in range(NZ):
            if quantized:
                mu = jnp.mean(T[z])
                acc = I.conv2d_quant(T[z] - mu, w) + mu * mass
            else:
                acc = K.stencil(T[z], w)                # Pallas stencil kernel
            up = T[z - 1] if z > 0 else T[z]
            dn = T[z + 1] if z < NZ - 1 else T[z]
            new.append(acc * (1 - 2 * CZ - AMB) + CZ * up + CZ * dn + Pj[z])
        T = jnp.stack(new)

    def ref():
        Td = T0.astype(np.float64)
        for _ in range(ITERS):
            Td = _step_fp(Td, P.astype(np.float64))
        return Td

    return np.asarray(T), ref
