"""Serving CLI: a thin driver over the continuous-batching engine
(serving/engine.py) with the Tensorizer W8A8 fast path.

    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b --smoke \
        --quantize serve --requests 4 --prompt-len 32 --gen 16

The paper's technique is the serving fast path: with ``--quantize serve``,
every >=2D weight is Tensorizer-quantized to int8 (per-output-channel scales,
int32 accumulation, fused dequant) — half the HBM bytes per decode step, which
is exactly the dominant roofline term of the decode cells (§Perf).

Batching model: requests flow through the Engine's bounded queue into a
slot-based in-flight decode batch (continuous batching — joins and retires per
step, no full-batch barrier); admission is fused prefill-with-cache — one
bucketed forward returns the first token plus per-layer K/V that a single
batched scatter writes into the leased slot rows (O(1) dispatches per bucket,
zero replay decodes); all device work is dispatched through the OPQ runtime.
``--stagger-steps N`` offsets arrivals by N engine steps to exercise
mid-flight joins.

The cache sits behind the SlotStore protocol (serving/store.py):
``--cache-backend contiguous`` leases per-slot rows sized to the seq budget,
``--cache-backend paged`` leases fixed-size blocks from a pool
(``--block-size``, ``--n-blocks``) with admission backpressure when the pool
runs dry, and ``auto`` picks contiguous for dense/moe and the recurrent-state
backend for ssm/hybrid archs (xlstm/zamba2 serve end-to-end now). With
``--paged-native`` decode attends over the block pool through the per-slot
tables — no transient gather view, ``decode_view_bytes == 0`` — and
``--paged-kernel`` routes the contraction through the Pallas paged-attention
kernel. ``--prefill-chunk W`` admits prompts wider than the fused buckets
through the chunked prefill scan (peak score memory W*S, not S^2). The
end-of-run report prints ``memory_stats()`` for the selected backend.

``--hosts N`` serves the same traffic through the multi-host Router
(serving/router.py): N hosts, cache-affinity placement (requests cycle
through N sessions here, so repeat sessions pin to the host holding their
blocks), load-aware spill, and — with ``--drain-at K`` — a drain of host 0
after K fleet steps, handing its in-flight generations off to the other
hosts mid-run (tokens provably unchanged; see docs/serving.md). By default
hosts are in-process engines; ``--host-procs`` runs each host as its own OS
process (serving/host_main.py workers over SubprocessTransport) — real
process parallelism, spawned and supervised here, reaped on exit. Workers
rebuild the model deterministically from the arch/smoke/quantize/seed spec,
so fleet tokens stay bit-identical to the in-process fleet.

In ``--api-port`` server mode, SIGINT/SIGTERM trigger a graceful shutdown:
admissions stop, live SSE streams are flushed with a terminal frame, hosts
drain, and worker processes (with ``--host-procs``) are reaped — no
orphans.

Every flag is documented operator-style in docs/serving.md, which
tests/test_docs.py keeps in lockstep with this parser.
"""

from __future__ import annotations

import argparse
import signal
import sys

import jax
import numpy as np

from repro.configs import get_config
from repro.core import tensorizer as tz
from repro.distributed import sharding as shd
from repro.launch.mesh import make_smoke_mesh
from repro.models import init_model
from repro.serving.api import serve_api
from repro.serving.engine import Engine, EngineConfig
from repro.serving.metrics import (format_memory_stats, format_router_stats,
                                   format_sampling_stats, format_spec_stats,
                                   format_transport_stats)
from repro.serving.router import Router, RouterConfig, parse_disaggregate
from repro.serving.sampling import SamplingParams
from repro.serving.transport import SubprocessTransport, build_model_spec


def _quant_predicate(path, leaf):
    """Quantize projection weights only (allowlist: names starting with "w",
    plus lm_head) — norms, biases, conv taps, LoRA adapters, and the SSM/xLSTM
    recurrence weights stay f32 (DESIGN.md §Arch-applicability)."""
    name = ""
    for p in reversed(path):
        name = getattr(p, "key", getattr(p, "name", ""))
        if name:
            break
    skip = {"conv_w",                      # depthwise taps (tiny, shape-critical)
            "wup", "wdown",                # sLSTM block FFN adjacent to recurrence
            "rz", "ri", "rf", "ro"}        # sLSTM recurrence
    return (name == "lm_head" or name.startswith("w")) and name not in skip


def build_parser() -> argparse.ArgumentParser:
    """The serve CLI surface — kept at module level so tests/test_docs.py can
    assert every flag here is documented in docs/serving.md and vice versa."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--quantize", default="off", choices=["off", "serve"])
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4,
                    help="in-flight decode batch width (engine slots)")
    ap.add_argument("--max-queue", type=int, default=64)
    ap.add_argument("--stagger-steps", type=int, default=0,
                    help="engine steps between request arrivals (0 = all at once)")
    ap.add_argument("--cache-backend", default="auto",
                    choices=["auto", "contiguous", "paged", "recurrent"],
                    help="SlotStore backend (auto: contiguous for dense/moe, "
                         "recurrent for ssm/hybrid)")
    ap.add_argument("--block-size", type=int, default=16,
                    help="paged backend: tokens per KV block")
    ap.add_argument("--n-blocks", type=int, default=0,
                    help="paged backend: pool size in blocks (0 = full "
                         "slots x max-seq capacity)")
    ap.add_argument("--paged-native", action="store_true",
                    help="paged backend: block-native decode — attend over "
                         "the block pool through the tables, no transient "
                         "gather view (decode_view_bytes == 0)")
    ap.add_argument("--paged-kernel", action="store_true",
                    help="with --paged-native: route the attention "
                         "contraction through the Pallas paged-attention "
                         "kernel (interpret mode off-TPU)")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="chunked prefill width for long prompts: buckets "
                         "wider than this admit via the chunked scan "
                         "(peak score memory chunk*S instead of S^2; "
                         "0 = single-shot fused prefill only)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="paged backend: shared-prefix radix cache — "
                         "admission leases matched immutable prefix blocks "
                         "by refcount and prefills only the suffix (COW "
                         "fork at mid-block divergence; LRU eviction of "
                         "unreferenced cached prefixes under pool "
                         "pressure). Requests here share a half-prompt "
                         "preamble to exercise hits")
    ap.add_argument("--speculative", action="store_true",
                    help="draft-verify decode: a small draft model proposes "
                         "--spec-k tokens per active slot each round and the "
                         "target verifies the whole window in ONE wide "
                         "forward — slots advance 1..k+1 tokens per target "
                         "dispatch, tokens bit-identical to plain greedy "
                         "decode (greedy acceptance)")
    ap.add_argument("--draft-config", default="tinyllama-1.1b",
                    help="with --speculative: the draft model's arch config "
                         "(must share the target's vocab; smoke-reduced "
                         "under --smoke)")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="with --speculative: draft proposals per round "
                         "(verify window = spec-k + 1 positions)")
    ap.add_argument("--hosts", type=int, default=1,
                    help="simulated hosts: 1 = a single engine; >1 serves "
                         "through the multi-host Router (one engine per "
                         "host, cache-affinity placement + load-aware "
                         "spill; serving/router.py)")
    ap.add_argument("--host-procs", action="store_true",
                    help="with --hosts: run each host as its own OS process "
                         "(a serving/host_main.py worker speaking framed RPC "
                         "over a local socket) instead of an in-process "
                         "engine — real process parallelism; workers are "
                         "spawned, supervised, and reaped here, and a dead "
                         "worker's streams recover on the surviving hosts")
    ap.add_argument("--drain-at", type=int, default=0,
                    help="with --hosts > 1: drain host 0 after this many "
                         "fleet steps — queued requests re-place, long "
                         "in-flight generations hand off to other hosts "
                         "(0 = never drain)")
    ap.add_argument("--disaggregate", default="",
                    help="with --hosts > 1: split the fleet into prefill and "
                         "decode roles (\"prefill:N,decode:M\", or the \"N:M\" "
                         "shorthand; N+M must equal --hosts). Admissions go "
                         "to prefill hosts only; once a stream's remaining "
                         "budget clears the handoff threshold its KV blocks "
                         "ship to the least-loaded decode host and decode "
                         "continues there — tokens bit-identical, decode "
                         "hosts dispatch zero prefill instructions. Requires "
                         "--cache-backend paged --paged-native (block "
                         "shipping exports pool blocks)")
    ap.add_argument("--disagg-report", default="",
                    help="write the prefill/decode disaggregation JSON here "
                         "and exit (runs benchmarks/serve_throughput.py's "
                         "disagg cell): decode p99 inter-token gap for a "
                         "bimodal interactive+batch mix with and without the "
                         "role split, tokens hard-asserted bit-identical to "
                         "a single engine for dense AND int8-KV, zero "
                         "prefill instructions on decode hosts")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="sampling temperature for the sampled half of the "
                         "synthetic traffic mix (0 = all-greedy). Even-"
                         "indexed requests sample at this temperature with "
                         "per-request seeds, odd ones stay greedy, so decode "
                         "batches mix both through ONE executable")
    ap.add_argument("--top-k", type=int, default=0,
                    help="sampled requests: keep only the k highest-logit "
                         "tokens (0 = off)")
    ap.add_argument("--top-p", type=float, default=1.0,
                    help="sampled requests: nucleus filtering — smallest "
                         "probability mass >= p survives (1.0 = off)")
    ap.add_argument("--seed", type=int, default=0,
                    help="base sampling seed: request i samples with "
                         "seed + i; randomness is counter-style per (seed, "
                         "position), so a seeded stream is batch-invariant")
    ap.add_argument("--stop", action="append", metavar="IDS",
                    help="stop sequence as comma-separated token ids "
                         "(repeatable; applies to every request) — a request "
                         "retires when its generated stream ends with one")
    ap.add_argument("--api-port", type=int, default=-1,
                    help="boot the streaming HTTP serve API (SSE "
                         "completions + embeddings/classify; serving/api.py) "
                         "on this port instead of running the synthetic "
                         "traffic loop (0 = OS-assigned, -1 = off). Fronts "
                         "the single engine, or the Router with --hosts > 1")
    ap.add_argument("--model-parallel", type=int, default=1)
    return ap


def _roles_for(args):
    """--disaggregate spec -> per-host role tuple (None when off). Validated
    once in main() via ap.error; recomputed here so both Router construction
    sites (synthetic fleet loop, --api-port server) share one source."""
    if not args.disaggregate:
        return None
    return parse_disaggregate(args.disaggregate, args.hosts)


def _sampling_for(args, i: int):
    """The synthetic traffic generator's per-request sampling mix: with
    --temperature > 0, EVEN-indexed requests sample (per-request seed =
    --seed + i) while odd ones stay greedy — every decode batch then mixes
    greedy and sampled rows through the one masked executable, which is the
    heterogeneous-batch case worth smoking. --stop applies to all."""
    stops = tuple(tuple(int(t) for t in s.split(","))
                  for s in (args.stop or []))
    if args.temperature > 0 and i % 2 == 0:
        return SamplingParams(
            temperature=args.temperature, top_k=args.top_k,
            top_p=args.top_p, seed=args.seed + i, stop=stops)
    if stops:
        return SamplingParams(stop=stops)
    return None


def _spawn_fleet(args, ecfg):
    """--host-procs: one worker process per host, each rebuilding the model
    deterministically from the same spec (bit-identical weights to the
    in-process path). A boot failure reaps the partial fleet — no orphans."""
    spec = build_model_spec(
        args.arch, smoke=args.smoke, quantize=args.quantize, seed=0,
        draft_arch=args.draft_config if args.speculative else None,
        model_parallel=args.model_parallel)
    fleet = []
    try:
        for _ in range(args.hosts):
            fleet.append(SubprocessTransport(spec, ecfg))
    except Exception:
        for t in fleet:
            t.close()
        raise
    print(f"[serve] spawned {len(fleet)} host processes "
          f"(pids {[t.pid for t in fleet]})", flush=True)
    return fleet


def _serve_fleet(cfg, params, ecfg, prompts, args, *, draft_params=None,
                 transports=None) -> int:
    """The --hosts > 1 path: the same traffic through the multi-host Router.
    Requests cycle over ``hosts`` session keys so the second lap of arrivals
    pins to the hosts already holding those sessions' blocks (affinity
    hits); ``--drain-at K`` drains host 0 after K fleet steps, exercising
    queued-requeue + in-flight handoff mid-run. ``transports`` (the
    --host-procs fleet) swaps the in-process engines for worker
    processes."""
    router = Router(cfg, params, ecfg,
                    RouterConfig(n_hosts=args.hosts, roles=_roles_for(args)),
                    draft_params=draft_params, transports=transports)
    requests = []
    fleet_steps = 0

    def tick(n):
        nonlocal fleet_steps
        for _ in range(n):
            router.step()
            fleet_steps += 1
            if args.drain_at and fleet_steps == args.drain_at:
                router.drain(0)
                print(f"[serve] draining host 0 at fleet step {fleet_steps}",
                      flush=True)

    for i in range(args.requests):
        requests.append(router.submit(prompts[i], args.gen,
                                      session=str(i % args.hosts),
                                      sampling=_sampling_for(args, i),
                                      strict=True))
        tick(args.stagger_steps)
    while router.has_work():
        tick(1)

    for r in requests:
        trail = "->".join(str(h) for h in r.hosts)
        handed = " (handoff)" if len(r.hosts) > 1 else ""
        print(f"[serve] req {r.id}: prompt {len(r.prompt)} tok | "
              f"host {trail}{handed} | {r.n_generated} tok", flush=True)
    s = router.stats()
    print(f"[serve] router: {format_router_stats(s)}", flush=True)
    if any(t["kind"] != "in-process" for t in s["router"]["transport"]):
        print(f"[serve] {format_transport_stats(s)}", flush=True)
    if args.temperature > 0 or args.stop:
        print(f"[serve] fleet {format_sampling_stats(s['fleet'])}",
              flush=True)
    if args.speculative:
        f = s["fleet"]
        rate = f["accepted_tokens"] / max(f["proposed_tokens"], 1)
        print(f"[serve] fleet speculative: {f['spec_rounds']} rounds + "
              f"{f['draft_steps']} draft steps | "
              f"{f['accepted_tokens']}/{f['proposed_tokens']} proposals "
              f"accepted ({rate:.2f})", flush=True)
    for h, hs in enumerate(s["per_host"]):
        o = hs.get("opq", {})
        drained = " [drained]" if router.is_drained(h) else ""
        print(f"[serve] host {h}{drained}: {hs['completed']} done | "
              f"{hs['decode_steps']} decode steps | "
              f"{hs['preempted']} preempted, {hs['evicted']} evicted | "
              f"cache {format_memory_stats(hs['cache'])} | "
              f"opq {o.get('issued', 0)} instr, "
              f"{o.get('affinity_hits', 0)} affinity hits", flush=True)
    print(f"[serve] sample generation (req 0): {requests[0].tokens}",
          flush=True)
    router.close()
    return 0


def main(argv=None) -> int:
    ap = build_parser()
    args = ap.parse_args(argv)
    for name in ("requests", "prompt_len", "gen", "slots", "max_queue"):
        if getattr(args, name) < 1:
            ap.error(f"--{name.replace('_', '-')} must be >= 1")
    if (args.paged_native or args.paged_kernel) and args.cache_backend != "paged":
        ap.error("--paged-native/--paged-kernel require --cache-backend paged")
    if args.paged_kernel and not args.paged_native:
        ap.error("--paged-kernel requires --paged-native")
    if args.prefix_cache and args.cache_backend != "paged":
        ap.error("--prefix-cache requires --cache-backend paged")
    if args.hosts < 1:
        ap.error("--hosts must be >= 1")
    if args.drain_at and args.hosts < 2:
        ap.error("--drain-at needs --hosts >= 2 (handoff requires another "
                 "host to admit the drained work)")
    if args.disaggregate:
        if args.hosts < 2:
            ap.error("--disaggregate needs --hosts >= 2 (at least one "
                     "prefill host and one decode host)")
        if args.cache_backend != "paged" or not args.paged_native:
            ap.error("--disaggregate requires --cache-backend paged "
                     "--paged-native (KV block shipping exports and imports "
                     "pool blocks)")
        if args.speculative:
            ap.error("--disaggregate does not support --speculative (the "
                     "draft model's KV does not ship; drop one)")
        try:
            parse_disaggregate(args.disaggregate, args.hosts)
        except ValueError as e:
            ap.error(str(e))
    if args.disagg_report and args.quantize == "serve":
        ap.error("--disagg-report runs the dense AND int8-KV cells itself "
                 "(it quantizes a copy of the params for the second cell); "
                 "leave --quantize off")
    if args.spec_k < 1:
        ap.error("--spec-k must be >= 1")
    if args.speculative and args.paged_kernel:
        ap.error("--speculative does not support --paged-kernel (the Pallas "
                 "kernel is a single-query decode shape)")
    if args.temperature < 0:
        ap.error("--temperature must be >= 0 (0 = greedy)")
    if not 0.0 < args.top_p <= 1.0:
        ap.error("--top-p must be in (0, 1]")
    if args.top_k < 0:
        ap.error("--top-k must be >= 0 (0 = off)")
    if args.temperature > 0 and args.speculative:
        ap.error("--speculative is greedy-only: non-greedy sampling needs "
                 "rejection-sampling acceptance (a ROADMAP follow-up) — "
                 "drop --speculative or --temperature")
    for s in args.stop or []:
        if not all(t.strip().lstrip("-").isdigit() for t in s.split(",")):
            ap.error(f"--stop takes comma-separated token ids, got {s!r}")

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    cfg = cfg.replace(quantize=args.quantize)
    if args.speculative and cfg.family not in ("dense", "moe"):
        ap.error(f"--speculative needs a dense-family TARGET arch, got "
                 f"{args.arch} (family={cfg.family}); recurrent models can "
                 "be the draft, not the target")
    if (cfg.family not in ("dense", "moe", "ssm", "hybrid")
            or cfg.input_mode != "tokens"):
        ap.error(f"--arch {args.arch} (family={cfg.family}, "
                 f"input_mode={cfg.input_mode}) is not servable yet: the "
                 "engine handles token-input dense/moe (contiguous or paged "
                 "KV) and ssm/hybrid (recurrent-state) archs; encdec/vlm "
                 "serving is a ROADMAP item")
    mesh = make_smoke_mesh(args.model_parallel)

    with shd.use_mesh(mesh):
        params = init_model(cfg, jax.random.PRNGKey(0))
        if args.quantize == "serve":
            params = tz.quantize_params(params, predicate=_quant_predicate)
            n_q = sum(isinstance(l, tz.QTensor)
                      for l in jax.tree.leaves(params, is_leaf=lambda x: isinstance(x, tz.QTensor)))
            print(f"[serve] Tensorizer W8A8: {n_q} weight tensors quantized", flush=True)

        if args.disagg_report:
            # the bench module owns the disagg measurement cell; load it by
            # path (benchmarks/ is not a package) and hand over the already-
            # built params so its reference engine matches the worker spec
            import importlib.util
            from pathlib import Path
            bench_py = (Path(__file__).resolve().parents[3] / "benchmarks"
                        / "serve_throughput.py")
            bspec = importlib.util.spec_from_file_location(
                "serve_throughput_bench", bench_py)
            bench = importlib.util.module_from_spec(bspec)
            bspec.loader.exec_module(bench)
            bench.disagg_report(
                cfg, params, arch=args.arch, smoke=args.smoke,
                prompt_len=args.prompt_len, gen=args.gen,
                requests=args.requests, out_path=args.disagg_report)
            return 0

        rng = np.random.default_rng(0)
        prompts = rng.integers(0, cfg.vocab, (args.requests, args.prompt_len),
                               dtype=np.int32)
        if args.prefix_cache:
            # hot-prefix traffic shape: every request opens with the same
            # half-prompt preamble (a shared system prompt), so all but the
            # first admission walk onto cached blocks
            prompts[:, :args.prompt_len // 2] = prompts[0, :args.prompt_len // 2]

        draft_cfg = None
        draft_params = None
        if args.speculative:
            draft_cfg = get_config(args.draft_config)
            if args.smoke:
                draft_cfg = draft_cfg.smoke()
            # seed 0, like the target: with --draft-config == --arch the
            # draft IS the target and acceptance is total — the cheap way to
            # smoke the full accept path; a real deployment points this at a
            # genuinely smaller config
            draft_params = init_model(draft_cfg, jax.random.PRNGKey(0))
            print(f"[serve] speculative: draft {args.draft_config}, "
                  f"k={args.spec_k} (verify window {args.spec_k + 1})",
                  flush=True)

        ecfg = EngineConfig(
            max_slots=args.slots, max_queue=args.max_queue,
            max_seq_len=args.prompt_len + args.gen,
            cache_backend=args.cache_backend, block_size=args.block_size,
            n_blocks=args.n_blocks or None,
            paged_native=args.paged_native,
            paged_kernel=args.paged_kernel,
            prefill_chunk=args.prefill_chunk or None,
            prefix_cache=args.prefix_cache,
            speculative=args.speculative, spec_k=args.spec_k,
            draft=draft_cfg)

        transports = _spawn_fleet(args, ecfg) if args.host_procs else None

        if args.api_port >= 0:
            # server mode: no synthetic traffic — expose the engine (or the
            # fleet) over HTTP and block until interrupted
            if args.hosts > 1 or transports is not None:
                target = Router(cfg, params, ecfg,
                                RouterConfig(n_hosts=args.hosts,
                                             roles=_roles_for(args)),
                                draft_params=draft_params,
                                transports=transports)
                front = (f"router, {args.hosts} host "
                         f"{'processes' if transports else 'engines'}")
            else:
                target = Engine(cfg, params, ecfg,
                                draft_params=draft_params)
                front = "single engine"
            srv = serve_api(target, port=args.api_port, mesh=mesh)
            print(f"[serve] HTTP API on {srv.url} ({front}) — "
                  f"POST /v1/completions (SSE with \"stream\": true), "
                  f"/v1/embeddings, /v1/classify; GET /v1/stats /healthz",
                  flush=True)

            # graceful shutdown on SIGINT and SIGTERM: wait() turns the
            # KeyboardInterrupt into close(), which stops admissions,
            # flushes a terminal frame to every live SSE stream, and —
            # through target.close() — drains the hosts and reaps worker
            # processes. No orphans, exit 0.
            def _graceful(signum, frame):
                raise KeyboardInterrupt
            signal.signal(signal.SIGTERM, _graceful)
            srv.wait()
            print("[serve] shutdown: streams flushed, closing fleet",
                  flush=True)
            target.close()
            print("[serve] shutdown complete (workers reaped)", flush=True)
            return 0

        if args.hosts > 1 or transports is not None:
            return _serve_fleet(cfg, params, ecfg, prompts, args,
                                draft_params=draft_params,
                                transports=transports)

        engine = Engine(cfg, params, ecfg, draft_params=draft_params)
        requests = []
        for i in range(args.requests):
            requests.append(engine.submit(prompts[i], args.gen,
                                          sampling=_sampling_for(args, i),
                                          strict=True))
            for _ in range(args.stagger_steps):
                engine.step()
        engine.run_until_complete()

        for r in requests:
            print(f"[serve] req {r.id}: prompt {r.metrics.prompt_len} tok | "
                  f"TTFT {r.metrics.ttft_s*1e3:.1f} ms "
                  f"(queue {r.metrics.queue_wait_s*1e3:.1f} + "
                  f"prefill+seed {r.metrics.prefill_s*1e3:.1f}) | "
                  f"{r.metrics.n_generated} tok @ {r.metrics.decode_tok_s:.1f} tok/s",
                  flush=True)
        s = engine.stats()
        print(f"[serve] engine: {s['completed']} requests | "
              f"{s['prefill_batches']} prefill batches | "
              f"{s['decode_steps']} decode steps | "
              f"sustained {s['sustained_tok_s']:.1f} tok/s | "
              f"mean queue depth {s['mean_queue_depth']:.2f} | "
              f"mean occupancy {s['mean_occupancy']:.2f}/{args.slots}", flush=True)
        print(f"[serve] admission: fused prefill-with-cache | "
              f"prefill wait {s['prefill_wait_s']*1e3:.1f} ms | "
              f"batched seed writes {s['seed_write_s']*1e3:.1f} ms | "
              f"0 replay decodes | "
              f"{s['admissions_deferred']} deferred (backpressure)", flush=True)
        if args.speculative:
            print(f"[serve] {format_spec_stats(s)}", flush=True)
        if args.temperature > 0 or args.stop:
            print(f"[serve] {format_sampling_stats(s)}", flush=True)
        if args.prefix_cache:
            print(f"[serve] prefix cache: {s['prefix_hits']} hits | "
                  f"{s['prefix_blocks_reused']} blocks reused | "
                  f"{s['prefix_tokens_reused']} prompt positions skipped | "
                  f"{s['prefill_chunks']} prefill chunks computed", flush=True)
        print(f"[serve] cache: {format_memory_stats(s['cache'])}", flush=True)
        if "opq" in s:
            o = s["opq"]
            print(f"[serve] opq: {o['issued']} instructions | "
                  f"{o['affinity_hits']} affinity hits | "
                  f"{o['backups_issued']} backups", flush=True)
        print(f"[serve] sample generation (req 0): {requests[0].tokens}", flush=True)
        engine.close()
    return 0


if __name__ == "__main__":
    sys.exit(main())
