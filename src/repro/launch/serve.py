"""Serving driver: batched prefill + decode with the Tensorizer W8A8 path.

    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b --smoke \
        --quantize serve --requests 4 --prompt-len 32 --gen 16

The paper's technique is the serving fast path: with ``--quantize serve``,
every >=2D weight is Tensorizer-quantized to int8 (per-output-channel scales,
int32 accumulation, fused dequant) — half the HBM bytes per decode step, which
is exactly the dominant roofline term of the decode cells (§Perf).

Batching model: requests accumulate into a fixed decode batch (continuous
batching lite); prefill runs per padded-length bucket; decode is one jit'd
step for the whole batch.
"""

from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import tensorizer as tz
from repro.distributed import sharding as shd
from repro.launch.mesh import make_smoke_mesh
from repro.models import init_model, steps as ST
from repro.models import serve as SV
from repro.models import model as M


def _quant_predicate(path, leaf):
    """Quantize projection weights only (allowlist: names starting with "w",
    plus lm_head) — norms, biases, conv taps, LoRA adapters, and the SSM/xLSTM
    recurrence weights stay f32 (DESIGN.md §Arch-applicability)."""
    name = ""
    for p in reversed(path):
        name = getattr(p, "key", getattr(p, "name", ""))
        if name:
            break
    skip = {"conv_w",                      # depthwise taps (tiny, shape-critical)
            "wup", "wdown",                # sLSTM block FFN adjacent to recurrence
            "rz", "ri", "rf", "ro"}        # sLSTM recurrence
    return (name == "lm_head" or name.startswith("w")) and name not in skip


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--quantize", default="off", choices=["off", "serve"])
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--model-parallel", type=int, default=1)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    cfg = cfg.replace(quantize=args.quantize)
    mesh = make_smoke_mesh(args.model_parallel)

    with shd.use_mesh(mesh):
        params = init_model(cfg, jax.random.PRNGKey(0))
        if args.quantize == "serve":
            params = tz.quantize_params(params, predicate=_quant_predicate)
            n_q = sum(isinstance(l, tz.QTensor)
                      for l in jax.tree.leaves(params, is_leaf=lambda x: isinstance(x, tz.QTensor)))
            print(f"[serve] Tensorizer W8A8: {n_q} weight tensors quantized", flush=True)

        B = args.requests
        total = args.prompt_len + args.gen
        rng = np.random.default_rng(0)
        prompts = rng.integers(0, cfg.vocab, (B, args.prompt_len), dtype=np.int32)

        # ---- prefill: batch forward, then seed the cache token by token ----
        prefill = jax.jit(ST.make_prefill_step(cfg))
        decode = jax.jit(ST.make_decode_step(cfg), donate_argnums=(1,))

        t0 = time.time()
        batch = {"tokens": jnp.asarray(prompts)}
        if cfg.input_mode == "embeds" and not cfg.is_encdec:
            batch = {"embeds": params_embed_stub(params, cfg, prompts)}
        if cfg.is_encdec:
            se = max(1, args.prompt_len // cfg.enc_len_ratio)
            batch["embeds"] = jnp.zeros((B, se, cfg.d_model), jnp.bfloat16)
        if cfg.rope_kind == "mrope":
            batch["positions3"] = jnp.broadcast_to(
                jnp.arange(args.prompt_len, dtype=jnp.int32), (3, B, args.prompt_len))
        next_logits = prefill(params, batch)
        next_tok = jnp.argmax(next_logits, axis=-1)[:, None]
        t_prefill = time.time() - t0

        # cache replay: feed prompt tokens through decode to fill the cache
        # (production would fuse prefill-with-cache; decode-seeding keeps the
        # smoke driver simple and exercises the decode path heavily)
        cache = SV.init_cache(cfg, B, total)
        for i in range(args.prompt_len):
            _, cache = decode(params, cache, {"tokens": jnp.asarray(prompts[:, i:i + 1])})

        t1 = time.time()
        out_tokens = []
        tok = next_tok
        for i in range(args.gen):
            tok, cache = decode(params, cache, {"tokens": tok})
            tok = tok[:, None]
            out_tokens.append(np.asarray(tok))
        jax.block_until_ready(tok)
        t_decode = time.time() - t1

        gen = np.concatenate(out_tokens, axis=1)
        print(f"[serve] {B} requests | prefill {args.prompt_len} tok in "
              f"{t_prefill*1e3:.1f} ms | {args.gen} decode steps in "
              f"{t_decode*1e3:.1f} ms ({B*args.gen/max(t_decode,1e-9):.1f} tok/s)", flush=True)
        print(f"[serve] sample generation (req 0): {gen[0].tolist()}", flush=True)
    return 0


def params_embed_stub(params, cfg, prompts):
    """VLM stub: pretend patch embeddings = token embeddings of the prompt."""
    emb = params["embed"]
    if isinstance(emb, tz.QTensor):
        emb = emb.dequantize()
    return emb[prompts].astype(jnp.bfloat16)


if __name__ == "__main__":
    sys.exit(main())
