"""Training driver: end-to-end, fault-tolerant, resumable.

    PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
        --steps 200 --batch 8 --seq 128 --smoke --ckpt-dir /tmp/ckpt

Production behaviors wired in (all exercised by tests / examples on CPU):
  * jit'd train step with donated params/opt-state (no double-buffering of
    the 12-bytes/param optimizer + master state);
  * async checkpointing every ``--ckpt-every`` steps (params, opt state,
    data-iterator state), atomic commit, crc-verified restore;
  * automatic resume from the latest complete checkpoint;
  * simulated failure injection (``--fail-at-step``) to exercise the
    crash->restart->resume path end to end;
  * grad accumulation (``--grad-accum``) — the elastic re-mesh lever that
    keeps the global batch constant when the data axis shrinks (ft/monitor).
"""

from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import AsyncCheckpointer, latest_step, load_checkpoint
from repro.configs import get_config
from repro.configs.base import ShapeCfg
from repro.data import make_dataset
from repro.distributed import sharding as shd
from repro.launch.mesh import make_smoke_mesh
from repro.models import init_model, steps as ST
from repro.optim import adamw_init


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config (CPU-sized)")
    ap.add_argument("--quantize", default="off")
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--fail-at-step", type=int, default=-1,
                    help="simulate a crash at this step (fault-tolerance drill)")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--model-parallel", type=int, default=1)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    if args.quantize != "off":
        cfg = cfg.replace(quantize=args.quantize)

    mesh = make_smoke_mesh(args.model_parallel)
    shape = ShapeCfg("train_cli", args.seq, args.batch, "train")

    with shd.use_mesh(mesh):
        params = init_model(cfg, jax.random.PRNGKey(0))
        opt_state = adamw_init(params)
        ds = make_dataset(cfg, shape)
        start = 0

        ckpt = AsyncCheckpointer(args.ckpt_dir) if args.ckpt_dir else None
        if ckpt is not None:
            s = latest_step(args.ckpt_dir)
            if s is not None:
                state = load_checkpoint(args.ckpt_dir, s,
                                        {"params": params, "opt": opt_state,
                                         "data": ds.state()})
                params, opt_state = state["params"], state["opt"]
                ds.restore(jax.tree.map(lambda x: np.asarray(x), state["data"]))
                start = s
                print(f"[train] resumed from checkpoint step {s}", flush=True)
                if start >= args.steps:
                    print(f"[train] checkpoint already at/past --steps "
                          f"{args.steps}; nothing to do", flush=True)
                    return 0

        train_step = jax.jit(ST.make_train_step(cfg), donate_argnums=(0, 1))

        t0 = time.time()
        tokens_done = 0
        for step in range(start, args.steps):
            if step == args.fail_at_step:
                print(f"[train] SIMULATED FAILURE at step {step}", flush=True)
                if ckpt:
                    ckpt.wait()
                return 42  # crash exit code — restart resumes from checkpoint

            loss_acc = 0.0
            for _ in range(args.grad_accum):
                batch = next(ds)
                batch = {k: jnp.asarray(v) for k, v in batch.items()}
                params, opt_state, metrics = train_step(
                    params, opt_state, batch, jnp.asarray(step, jnp.int32))
                loss_acc += float(metrics["loss"])
            tokens_done += args.batch * args.seq * args.grad_accum

            if step % args.log_every == 0 or step == args.steps - 1:
                dt = time.time() - t0
                print(f"[train] step {step:5d} loss {loss_acc / args.grad_accum:.4f} "
                      f"gnorm {float(metrics['gnorm']):.3f} lr {float(metrics['lr']):.2e} "
                      f"tok/s {tokens_done / max(dt, 1e-9):.0f}", flush=True)

            if ckpt is not None and step > start and step % args.ckpt_every == 0:
                ckpt.save(step, {"params": params, "opt": opt_state, "data": ds.state()})

        if ckpt is not None:
            ckpt.save(args.steps, {"params": params, "opt": opt_state, "data": ds.state()})
            ckpt.wait()
        print(f"[train] done: {args.steps} steps, final loss "
              f"{loss_acc / args.grad_accum:.4f}", flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
