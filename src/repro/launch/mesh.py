"""Production meshes.

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state). The dry-run launcher sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import so the host platform exposes enough placeholder devices.

Mesh geometry (TPU v5e pods, 256 chips each):
  single-pod  (16, 16)        ("data", "model")
  multi-pod   (2, 16, 16)     ("pod", "data", "model")   2 pods = 512 chips

The "pod" axis composes with "data" for batch sharding: gradient reduction is
hierarchical (reduce-scatter over ICI within the pod, all-reduce over DCI
between pods) — GSPMD derives the two-level schedule from the sharding.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_smoke_mesh(n_model: int = 1):
    """CPU test mesh: (n_devices/n_model, n_model)."""
    n = len(jax.devices())
    if n_model > 1 and n % n_model == 0:
        return jax.make_mesh((n // n_model, n_model), ("data", "model"))
    return jax.make_mesh((n,), ("data",))
