import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: jax locks the device count at first init.
#   This is set ONLY here (never in conftest/pyproject) so tests and benches
#   see the real single CPU device.

"""Multi-pod dry-run (deliverable e).

For every (architecture x input-shape x mesh) cell:
  jit(step).lower(**input_specs).compile()
on the single-pod (16,16) and multi-pod (2,16,16) production meshes, printing
``memory_analysis()`` (fits-in-HBM proof) and ``cost_analysis()`` (FLOPs /
bytes for §Roofline), plus collective bytes parsed from the post-SPMD HLO.

Results are appended as JSON under reports/dryrun/ — benchmarks/roofline.py
derives the three roofline terms from them.

Usage:
  python -m repro.launch.dryrun --arch tinyllama-1.1b --shape train_4k
  python -m repro.launch.dryrun --arch all --shape all [--multi-pod]
  python -m repro.launch.dryrun ... --quantize serve   (W8A8 Tensorizer path)
"""

import argparse
import json
import re
import sys
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config, shape_by_name, SHAPES
from repro.distributed import sharding as shd
from repro.launch.mesh import make_production_mesh
from repro.models import steps as ST

REPORT_DIR = Path(__file__).resolve().parents[3] / "reports" / "dryrun"

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """Bytes of an HLO shape string (handles tuples)."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum output bytes of every collective op in the (per-device) HLO.

    CPU-backend caveat (measured, §Perf cell A): XLA's float-normalization
    pass promotes bf16 collectives to f32 on CPU ("..._promoted" reduction
    computations with a convert fused in front). On the TPU *target* those
    collectives run at bf16, so promoted ops are counted at half — the true
    wire payload of the lowered program on v5e.
    """
    out = {k: 0 for k in _COLLECTIVES}
    count = {k: 0 for k in _COLLECTIVES}
    promoted_bytes = 0
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s+([a-z\-]+)", line)
        if not m:
            continue
        shape_str, op = m.group(1), m.group(2)
        base = op.replace("-start", "")
        if base in _COLLECTIVES and not op.endswith("-done"):
            b = _shape_bytes(shape_str)
            if "_promoted" in line:          # CPU bf16->f32 promotion artifact
                promoted_bytes += b // 2
                b //= 2
            out[base] += b
            count[base] += 1
    return {"bytes": out, "counts": count, "total_bytes": sum(out.values()),
            "cpu_promotion_discount_bytes": promoted_bytes}


def build_cell(cfg, shape):
    """Returns (step_fn, example_args_sds, donate) for a cell."""
    params = ST.param_sds(cfg)
    if shape.kind == "train":
        opt = ST.opt_sds(cfg, params)
        batch = ST.batch_specs(cfg, shape)
        step = jax.ShapeDtypeStruct((), jnp.int32)
        fn = ST.make_train_step(cfg)
        return fn, (params, opt, batch, step), (0, 1)
    if shape.kind == "prefill":
        batch = ST.batch_specs(cfg, shape)
        return ST.make_prefill_step(cfg), (params, batch), ()
    # decode
    cache = ST.cache_specs(cfg, shape)
    batch = ST.batch_specs(cfg, shape)
    return ST.make_decode_step(cfg), (params, cache, batch), (1,)


def reduced_depths(cfg):
    """(cfg_hi, cfg_lo, units_hi, units_lo, units_full) for the exact-cost
    extrapolation: cost(full) = cost(lo) + (U_full - U_lo) * marginal, with
    marginal = (cost(hi) - cost(lo)) / (U_hi - U_lo) from UNROLLED compiles.
    Family-aware so every depth unit is a true repeated block."""
    if cfg.family == "encdec":
        # enc and dec layer counts move together (both 12 in the config)
        hi = cfg.replace(n_layers=3, n_enc_layers=3, scan_unroll=True)
        lo = cfg.replace(n_layers=2, n_enc_layers=2, scan_unroll=True)
        return hi, lo, 3, 2, cfg.n_layers
    if cfg.family == "hybrid":
        tail = cfg.n_layers % cfg.attn_every
        g_full = cfg.n_layers // cfg.attn_every
        hi = cfg.replace(n_layers=2 * cfg.attn_every + tail, scan_unroll=True)
        lo = cfg.replace(n_layers=1 * cfg.attn_every + tail, scan_unroll=True)
        return hi, lo, 2, 1, g_full          # units = shared-block groups
    if cfg.family == "ssm":
        hi = cfg.replace(n_layers=4, scan_unroll=True)   # 2 pairs
        lo = cfg.replace(n_layers=2, scan_unroll=True)   # 1 pair
        return hi, lo, 2, 1, cfg.n_layers // 2           # units = pairs
    hi = cfg.replace(n_layers=3, scan_unroll=True)
    lo = cfg.replace(n_layers=2, scan_unroll=True)
    return hi, lo, 3, 2, cfg.n_layers


def _compile_once(cfg, shape, donate_ok=True):
    fn, args, donate = build_cell(cfg, shape)
    lowered = jax.jit(fn, donate_argnums=donate if donate_ok else ()).lower(*args)
    compiled = lowered.compile()
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):      # jax 0.4.x wraps it per-device
        cost = cost[0] if cost else {}
    hlo = compiled.as_text()
    return compiled, cost, collective_bytes(hlo), len(hlo)


def run_cell(arch: str, shape_name: str, multi_pod: bool, quantize: str = "off",
             overrides: dict | None = None, tag: str = "") -> dict:
    cfg = get_config(arch)
    if quantize != "off":
        cfg = cfg.replace(quantize=quantize)
    if overrides:
        cfg = cfg.replace(**overrides)
    shape = shape_by_name(shape_name)
    mesh_name = "multipod_2x16x16" if multi_pod else "pod_16x16"
    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name, "kind": shape.kind,
        "quantize": quantize, "tag": tag, "status": "skipped",
    }
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        rec["reason"] = "full-attention arch: long_500k requires sub-quadratic decode (DESIGN.md)"
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    with shd.use_mesh(mesh):
        # ---- pass 1: full depth, scan mode — the memory / sharding proof ----
        compiled, cost_scan, coll_scan, hlo_bytes = _compile_once(cfg, shape)
        t_full = time.time() - t0
        try:
            mem = compiled.memory_analysis()
            mem_d = {
                "argument_size_in_bytes": getattr(mem, "argument_size_in_bytes", None),
                "output_size_in_bytes": getattr(mem, "output_size_in_bytes", None),
                "temp_size_in_bytes": getattr(mem, "temp_size_in_bytes", None),
                "generated_code_size_in_bytes": getattr(mem, "generated_code_size_in_bytes", None),
            }
        except Exception as e:  # CPU backend may not implement it
            mem_d = {"error": str(e)}

        # ---- passes 2+3: reduced depth, UNROLLED — exact per-layer costs ----
        t1 = time.time()
        cfg_hi, cfg_lo, u_hi, u_lo, u_full = reduced_depths(cfg)
        _, cost_hi, coll_hi, _ = _compile_once(cfg_hi, shape, donate_ok=False)
        _, cost_lo, coll_lo, _ = _compile_once(cfg_lo, shape, donate_ok=False)
        t_cost = time.time() - t1

        def extrap(hi: float, lo: float) -> float:
            marginal = (hi - lo) / (u_hi - u_lo)
            return lo + (u_full - u_lo) * marginal

        flops = extrap(cost_hi.get("flops", 0.0), cost_lo.get("flops", 0.0))
        bytes_acc = extrap(cost_hi.get("bytes accessed", 0.0),
                           cost_lo.get("bytes accessed", 0.0))
        coll_total = extrap(coll_hi["total_bytes"], coll_lo["total_bytes"])
        coll_by_op = {
            k: extrap(coll_hi["bytes"][k], coll_lo["bytes"][k]) for k in coll_hi["bytes"]
        }

        rec.update(
            status="ok",
            n_devices=int(mesh.devices.size),
            compile_full_s=round(t_full, 2),
            compile_cost_s=round(t_cost, 2),
            flops=flops,
            bytes_accessed=bytes_acc,
            collective_bytes=coll_total,
            collective_bytes_by_op=coll_by_op,
            collective_counts_hi=coll_hi["counts"],
            flops_scan_mode_raw=cost_scan.get("flops"),
            collectives_scan_mode_raw=coll_scan,
            extrapolation={"units_full": u_full, "units_hi": u_hi, "units_lo": u_lo,
                           "flops_hi": cost_hi.get("flops"), "flops_lo": cost_lo.get("flops")},
            memory=mem_d,
            model_params=cfg.param_count(),
            model_params_active=cfg.active_param_count(),
            tokens=shape.global_batch * (1 if shape.kind == "decode" else shape.seq_len),
            hlo_bytes=hlo_bytes,
        )
    return rec


def save(rec: dict) -> Path:
    REPORT_DIR.mkdir(parents=True, exist_ok=True)
    tag = f"_{rec['tag']}" if rec.get("tag") else ""
    q = f"_q{rec['quantize']}" if rec.get("quantize", "off") != "off" else ""
    p = REPORT_DIR / f"{rec['arch']}_{rec['shape']}_{rec['mesh']}{q}{tag}.json"
    p.write_text(json.dumps(rec, indent=1))
    return p


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--quantize", default="off")
    ap.add_argument("--tag", default="")
    ap.add_argument("--override", default="", help="k=v,k=v config overrides")
    args = ap.parse_args()

    archs = ARCH_IDS if args.arch == "all" else [args.arch.replace("-", "_").replace(".", "_")]
    shapes = [s.name for s in SHAPES] if args.shape == "all" else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    overrides = {}
    for kv in filter(None, args.override.split(",")):
        k, v = kv.split("=")
        overrides[k] = {"true": True, "false": False}.get(v.lower(), v)
        if isinstance(overrides[k], str):
            for caster in (int, float):
                try:
                    overrides[k] = caster(v)
                    break
                except ValueError:
                    pass

    failures = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                label = f"{arch} x {shape} x {'2x16x16' if mp else '16x16'}"
                try:
                    rec = run_cell(arch, shape, mp, args.quantize, overrides, args.tag)
                    p = save(rec)
                    if rec["status"] == "ok":
                        print(f"[dryrun] OK   {label}: compile={rec['compile_full_s']}+{rec['compile_cost_s']}s "
                              f"flops={rec['flops']:.3e} coll={rec['collective_bytes']:.3e}B -> {p.name}",
                              flush=True)
                    else:
                        print(f"[dryrun] SKIP {label}: {rec.get('reason','')}", flush=True)
                except Exception as e:
                    failures += 1
                    print(f"[dryrun] FAIL {label}: {type(e).__name__}: {e}", flush=True)
                    traceback.print_exc()
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
