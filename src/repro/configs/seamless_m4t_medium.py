"""seamless-m4t-medium — encoder-decoder multimodal backbone (arXiv:2308.11596).

12L enc + 12L dec, d_model=1024, 16H (kv=16), d_ff=4096, vocab=256206.
Audio frontend is a STUB per assignment: input_specs provides precomputed
frame embeddings (B, S/4, D); the decoder consumes text tokens.
long_500k: SKIPPED (full attention; see DESIGN.md §Arch-applicability).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-medium",
    family="encdec",
    n_layers=12, n_enc_layers=12, d_model=1024, n_heads=16, n_kv=16,
    d_ff=4096, vocab=256206,
    act="gelu", norm="layernorm", rope_kind="rope",
    input_mode="embeds", enc_len_ratio=4,
)
