"""moonshot-v1-16b-a3b — Moonlight-style MoE (hf:moonshotai/Moonlight-16B-A3B).

48L d_model=2048 16H (GQA kv=16 = MHA) d_ff=1408/expert vocab=163840,
64 routed experts top-6 + 2 shared (deepseek-v3 lineage).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48, d_model=2048, n_heads=16, n_kv=16, d_ff=1408, vocab=163840,
    n_experts=64, n_shared_experts=2, topk=6,
    act="swiglu", rope_kind="rope",
)
