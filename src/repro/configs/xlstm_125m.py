"""xlstm-125m — sLSTM + mLSTM blocks (arXiv:2405.04517).

12L (6 alternating mLSTM/sLSTM pairs) d_model=768 4H d_ff=0 vocab=50304.
mLSTM trains in chunked (SSD-equivalent) form; sLSTM is a sequential scan
(inherently recurrent — see DESIGN.md). O(1) decode state =>
long_500k RUNS for this arch. 4 heads: shard_heads=False (TP on projections).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-125m",
    family="ssm",
    n_layers=12, d_model=768, n_heads=4, n_kv=4, d_ff=0, vocab=50304,
    ssm_chunk=256, act="gelu", rope_kind="none", shard_heads=False,
    sub_quadratic=True,
)
