"""Config registry: one module per assigned architecture (+ paper apps)."""

from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import ArchConfig, SHAPES, ShapeCfg, shape_by_name  # noqa: F401

ARCH_IDS: List[str] = [
    "moonshot_v1_16b_a3b",
    "deepseek_moe_16b",
    "seamless_m4t_medium",
    "qwen2_vl_2b",
    "granite_20b",
    "qwen3_14b",
    "starcoder2_15b",
    "tinyllama_1_1b",
    "zamba2_7b",
    "xlstm_125m",
]

_ALIAS = {i.replace("_", "-"): i for i in ARCH_IDS}


def get_config(arch: str) -> ArchConfig:
    arch = arch.replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.CONFIG


def all_configs() -> Dict[str, ArchConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
