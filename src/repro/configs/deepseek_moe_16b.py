"""deepseek-moe-16b — fine-grained MoE (arXiv:2401.06066).

28L d_model=2048 16H (kv=16) d_ff=1408/expert vocab=102400,
2 shared + 64 routed experts, top-6.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28, d_model=2048, n_heads=16, n_kv=16, d_ff=1408, vocab=102400,
    n_experts=64, n_shared_experts=2, topk=6,
    act="swiglu", rope_kind="rope",
)
