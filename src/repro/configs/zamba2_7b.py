"""zamba2-7b — hybrid Mamba2 + shared attention blocks (arXiv:2411.15242).

81L d_model=3584 32H (kv=32) d_ff=14336 ssm_state=64. Shared transformer
block (attn+MLP, single weight set) applied every 6 mamba layers with
per-application LoRA adapters on W_q (13 applications + 3 tail mamba layers).
sub-quadratic state => long_500k RUNS for this arch.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81, d_model=3584, n_heads=32, n_kv=32, d_ff=14336, vocab=32000,
    ssm_state=64, ssm_headdim=64, ssm_expand=2, ssm_chunk=256, attn_every=6,
    act="swiglu", rope_kind="rope", sub_quadratic=True,
)
