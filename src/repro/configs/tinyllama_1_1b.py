"""tinyllama-1.1b — llama2-arch small (arXiv:2401.02385).

22L d_model=2048 32H (GQA kv=4) d_ff=5632 vocab=32000. The end-to-end
training example (examples/train_tinyllama.py) uses this arch.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="tinyllama-1.1b",
    family="dense",
    n_layers=22, d_model=2048, n_heads=32, n_kv=4, d_ff=5632, vocab=32000,
    act="swiglu", rope_kind="rope",
)
