"""starcoder2-15b — dense GQA code model (arXiv:2402.19173).

40L d_model=6144 48H (GQA kv=4) d_ff=24576 vocab=49152, GeLU MLP,
layernorm (gpt-style), RoPE.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2-15b",
    family="dense",
    n_layers=40, d_model=6144, n_heads=48, n_kv=4, d_ff=24576, vocab=49152,
    act="gelu", norm="layernorm", rope_kind="rope",
)
