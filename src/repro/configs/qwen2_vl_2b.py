"""qwen2-vl-2b — VLM transformer backbone with M-RoPE (arXiv:2409.12191).

28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936. Dynamic-resolution
vision frontend is a STUB: input_specs provides precomputed patch embeddings
+ 3D (t,h,w) position ids. mrope_section=(16,24,24) on head_dim=128.
long_500k: SKIPPED (full attention). 12 heads are NOT divisible by the 16-way
model axis — heads stay replicated, TP shards d_ff (shard_heads=False;
revisited in §Perf).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-2b",
    family="vlm",
    n_layers=28, d_model=1536, n_heads=12, n_kv=2, d_ff=8960, vocab=151936,
    head_dim=128, rope_kind="mrope", mrope_sections=(16, 24, 24),
    act="swiglu", input_mode="embeds", shard_heads=False,
)
