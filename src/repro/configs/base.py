"""Architecture / run configuration.

One ``ArchConfig`` per assigned architecture lives in ``configs/<id>.py`` with
the exact published hyper-parameters; ``smoke()`` derives the reduced-family
config used by CPU tests. ``SHAPES`` defines the assigned input-shape set.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ShapeCfg:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # "train" | "prefill" | "decode"


# The assigned LM shape set (identical across the 10 archs).
SHAPES: Tuple[ShapeCfg, ...] = (
    ShapeCfg("train_4k", 4096, 256, "train"),
    ShapeCfg("prefill_32k", 32768, 32, "prefill"),
    ShapeCfg("decode_32k", 32768, 128, "decode"),
    ShapeCfg("long_500k", 524288, 1, "decode"),
)


def shape_by_name(name: str) -> ShapeCfg:
    for s in SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | hybrid | ssm | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    # ---- MoE ----
    n_experts: int = 0
    n_shared_experts: int = 0
    topk: int = 0
    capacity_factor: float = 1.25
    # ---- SSM / hybrid ----
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    attn_every: int = 0            # zamba2: shared attn block period (0 = off)
    # ---- features ----
    head_dim: Optional[int] = None
    qk_norm: bool = False
    rope_kind: str = "rope"        # rope | mrope | none
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)
    act: str = "swiglu"            # swiglu | gelu
    norm: str = "rmsnorm"          # rmsnorm | layernorm
    tie_embeddings: bool = False
    rope_theta: float = 10000.0
    # ---- enc-dec ----
    n_enc_layers: int = 0          # >0 => encoder-decoder
    enc_len_ratio: int = 4         # enc frames = seq_len // ratio (audio stub)
    # ---- frontends (stubs per assignment) ----
    input_mode: str = "tokens"     # tokens | embeds (vlm/audio backbones)
    # ---- runtime / training ----
    dtype: str = "bfloat16"
    remat: bool = True
    attn_chunk: int = 1024         # switch to online-softmax above this seq len
    quantize: str = "off"          # off | serve  (Tensorizer W8A8 serving path)
    param_dtype: str = "float32"   # float32 (train master) | bfloat16 (serving)
    kv_cache_dtype: str = "bfloat16"  # bfloat16 | int8 (Tensorizer per-token KV quant)
    sub_quadratic: bool = False    # True => long_500k decode is runnable
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    # ---- dry-run cost accounting ----
    # XLA's HloCostAnalysis counts a while-loop body ONCE regardless of trip
    # count, so scan-over-layers undercounts FLOPs by ~L x. The dry-run
    # compiles reduced-depth UNROLLED variants (scan_unroll=True) to measure
    # the exact per-layer marginal cost and extrapolates (launch/dryrun.py).
    scan_unroll: bool = False
    # ---- distribution knobs (hillclimbed in §Perf) ----
    shard_heads: bool = True       # TP over heads (False => replicate attn, TP only FFN)
    attn_impl: str = "f32"         # f32 | bf16acc (flash internals in bf16, f32 stats)
    norm_dtype: str = "float32"    # float32 | bfloat16 — norm math dtype; bf16 keeps
                                   # the backward activation all-reduces in bf16 (§Perf A4)
    attn_sp: bool = False          # shard prefill queries over 'model' (SP attention
                                   # for archs whose head count doesn't divide the axis)
    zero1: bool = False            # shard optimizer state over data axis
    grad_allreduce_dtype: str = "bfloat16"

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def vocab_padded(self) -> int:
        """Embedding-table rows, padded to a 16-multiple so the vocab dim
        shards evenly on the model axis (seamless's 256206 -> 256208).
        Padded logit columns are masked to -inf in the head."""
        return ((self.vocab + 15) // 16) * 16

    @property
    def is_encdec(self) -> bool:
        return self.n_enc_layers > 0

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    def smoke(self) -> "ArchConfig":
        """Reduced same-family config for CPU smoke tests: small widths, few
        layers/experts, tiny vocab — same code paths."""
        return self.replace(
            n_layers=min(self.n_layers, 4 if self.attn_every else 2),
            n_enc_layers=min(self.n_enc_layers, 2),
            d_model=64,
            n_heads=4,
            n_kv=min(self.n_kv, 2) if self.n_kv < self.n_heads else 4,
            d_ff=128 if self.d_ff else 0,
            vocab=256,
            head_dim=16,
            n_experts=min(self.n_experts, 4),
            topk=min(self.topk, 2),
            ssm_headdim=16 if self.ssm_state else self.ssm_headdim,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_chunk=8,
            attn_every=2 if self.attn_every else 0,
            attn_chunk=16,
            mrope_sections=(2, 3, 3),   # sums to head_dim/2 = 8
        )

    # ------------------------------------------------------------------
    # analytics used by the roofline report
    # ------------------------------------------------------------------

    def param_count(self) -> int:
        """Approximate parameter count (embedding + stacked blocks)."""
        D, H, KV, hd, F, V, L = (self.d_model, self.n_heads, self.n_kv,
                                 self.hd, self.d_ff, self.vocab, self.n_layers)
        attn = D * H * hd + 2 * D * KV * hd + H * hd * D
        if self.act == "swiglu":
            mlp = 3 * D * F
        else:
            mlp = 2 * D * F
        if self.family == "moe":
            mlp = (self.n_experts + self.n_shared_experts) * mlp + D * self.n_experts
        if self.family in ("ssm",):
            di = self.ssm_expand * D
            blk = 2 * (D * di) + di * (D)  # rough: in/out projections
            per_layer = blk
        elif self.family == "hybrid":
            di = self.ssm_expand * D
            nh = di // self.ssm_headdim
            per_layer = D * (2 * di + 2 * self.ssm_state + nh) + di * D
        else:
            per_layer = attn + mlp
        total = L * per_layer + V * D * (1 if self.tie_embeddings else 2)
        if self.is_encdec:
            total += self.n_enc_layers * (attn + mlp)
        if self.attn_every:
            total += attn + 3 * D * self.d_ff  # one shared attn+mlp block
        return int(total)

    def active_param_count(self) -> int:
        """Active (per-token) params — MoE uses topk+shared instead of all."""
        if self.family != "moe":
            return self.param_count()
        D, F, L = self.d_model, self.d_ff, self.n_layers
        mlp_all = (self.n_experts + self.n_shared_experts) * 3 * D * F
        mlp_act = (self.topk + self.n_shared_experts) * 3 * D * F
        return int(self.param_count() - L * (mlp_all - mlp_act))
