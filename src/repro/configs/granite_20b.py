"""granite-20b — dense MQA code model, llama-arch per assignment (arXiv:2405.04324).

52L d_model=6144 48H (MQA kv=1) d_ff=24576 vocab=49152.
kv=1: the KV cache is tiny (MQA) but replicated over the model axis;
decode is the most memory-bound cell (hillclimb candidate).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="granite-20b",
    family="dense",
    n_layers=52, d_model=6144, n_heads=48, n_kv=1, d_ff=24576, vocab=49152,
    act="swiglu", rope_kind="rope",
)
