"""qwen3-14b — dense GQA with qk_norm (hf:Qwen/Qwen3 family).

40L d_model=5120 40H (GQA kv=8) d_ff=17408 vocab=151936, head_dim=128.
40 heads are NOT divisible by the 16-way model axis — GSPMD pads; §Perf
hillclimbs this cell to head_dim-sharded attention.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-14b",
    family="dense",
    n_layers=40, d_model=5120, n_heads=40, n_kv=8, d_ff=17408, vocab=151936,
    head_dim=128, qk_norm=True, act="swiglu", rope_kind="rope",
)
