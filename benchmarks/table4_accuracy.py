"""Paper Table 4: MAPE / RMSE per application across input value ranges
(default + [-2^7, 2^7), [-2^15, 2^15), [-2^31, 2^31) synthetic ranges).
The range sweep exercises the Tensorizer's range-calibrated scaling: error
must stay ~constant as magnitudes grow (the anti-FBGEMM property)."""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.apps.common import mape, rmse_pct
from repro.core import tensorizer as tz
from benchmarks.common import emit

RANGES = {"default": 8.0, "2^7": 2.0**7, "2^15": 2.0**15, "2^31": 2.0**31}


def run() -> None:
    rng = np.random.default_rng(0)
    n = 256
    for rname, r in RANGES.items():
        a = rng.uniform(0, r, (n, n)).astype(np.float32)
        b = rng.uniform(0, r, (n, n)).astype(np.float32)
        out = np.asarray(tz.qdot_paper(jnp.asarray(a), jnp.asarray(b)), np.float64)
        ref = a.astype(np.float64) @ b.astype(np.float64)
        emit(f"table4/gemm_range_{rname}", 0.0,
             f"mape_pct={mape(out, ref):.3f};rmse_pct={rmse_pct(out, ref):.3f}")


if __name__ == "__main__":
    run()
