# One function per paper table. Prints ``name,us_per_call,derived`` CSV.
"""Benchmark harness entry: one module per paper table/figure + the roofline.

    PYTHONPATH=src python -m benchmarks.run [--only table1,fig5,...]
"""

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="",
                    help="comma-separated subset: table1,fig5,fig6,table4,fig7,fig8,roofline")
    args = ap.parse_args()
    from benchmarks import fig5_gemm, fig6_apps, fig7_overflow, fig8_scaling
    from benchmarks import fig8_podscale, roofline, table1_ops, table4_accuracy

    suites = {
        "table1": table1_ops.run,
        "fig5": fig5_gemm.run,
        "fig6": fig6_apps.run,
        "table4": table4_accuracy.run,
        "fig7": fig7_overflow.run,
        "fig8": fig8_scaling.run,
        "fig8pod": fig8_podscale.run,
        "roofline": roofline.run,
    }
    sel = [s for s in args.only.split(",") if s] or list(suites)
    print("name,us_per_call,derived")
    failed = []
    for name in sel:
        try:
            suites[name]()
        except Exception:
            failed.append(name)
            traceback.print_exc()
    if failed:
        print(f"# FAILED suites: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == '__main__':
    main()
