"""Paper Fig. 6: the seven applications — quantized GPETPU pipeline vs fp
reference. Wall-clock on this CPU container is NOT the paper's CPU-vs-EdgeTPU
comparison; the derived column therefore reports the v5e roofline advantage of
the int8 path (2x MXU throughput + 2x fewer HBM bytes on the weight stream),
which is what the dry-run measures structurally."""

from __future__ import annotations

from repro.apps import ALL, run_app
from benchmarks.common import emit, PEAK_BF16_FLOPS, PEAK_INT8_OPS


def run() -> None:
    for name in sorted(ALL):
        r = run_app(name, n=96, quantized=True)
        v5e_gain = PEAK_INT8_OPS / PEAK_BF16_FLOPS   # compute-bound bound: 2x
        emit(f"fig6/{name}", r.t_gptpu_s * 1e6,
             f"mape_pct={r.mape_pct:.3f};rmse_pct={r.rmse_pct:.3f};"
             f"v5e_int8_compute_gain={v5e_gain:.1f}x")


if __name__ == "__main__":
    run()
