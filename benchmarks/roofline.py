"""Roofline analysis (deliverable g): derive the three terms per
(arch x shape) cell from the dry-run JSONs (single-pod mesh, per assignment).

Methodology
-----------
``compiled.cost_analysis()`` analyzes the post-SPMD per-device module, so
flops / bytes are *per chip*; terms divide by per-chip peaks directly:

    compute    = flops_dev / 197e12        (bf16 MXU; int8 path: 394e12)
    memory     = bytes_dev / 819e9
    collective = collective_bytes_dev / 50e9

FLOPs/bytes/collectives come from the dry-run's exact-cost extrapolation
(unrolled reduced-depth marginal cost x depth — XLA counts while bodies once;
see launch/dryrun.py). MODEL_FLOPS = 6·N·D (train) / 2·N·D (prefill/decode),
active params for MoE. The xlstm sLSTM recurrence runs inside a time-step scan
and is corrected analytically (+T·B·4·H·hd^2·2·3 flops for fwd+bwd).

Emits CSV and writes reports/roofline_table.md (the §Roofline table).
"""

from __future__ import annotations

import json
from pathlib import Path

from repro.configs import ARCH_IDS, get_config, shape_by_name
from benchmarks.common import emit, HBM_BW, ICI_BW, PEAK_BF16_FLOPS

REPORTS = Path(__file__).resolve().parents[1] / "reports"
DRYRUN = REPORTS / "dryrun"


def _model_flops_per_dev(cfg, shape, n_dev: int) -> float:
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        total = 6.0 * n_active * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        total = 2.0 * n_active * tokens
    else:  # decode: one token per request
        total = 2.0 * n_active * shape.global_batch
    return total / n_dev


def _slstm_correction(cfg, shape, n_dev: int) -> float:
    """Analytic flops for the sLSTM recurrent-R matmuls (inside the time scan,
    invisible to HLO cost analysis). fwd 2x + bwd ~4x multiplier."""
    if cfg.family != "ssm":
        return 0.0
    H = cfg.n_heads
    hd = cfg.d_model // H
    tokens = (shape.global_batch * shape.seq_len
              if shape.kind != "decode" else shape.global_batch)
    per_tok = 4 * H * hd * hd * 2            # 4 gates, 2 flops/MAC
    mult = 3.0 if shape.kind == "train" else 1.0
    n_pairs = cfg.n_layers // 2
    return tokens * per_tok * n_pairs * mult / n_dev


def analyze(rec: dict) -> dict:
    cfg = get_config(rec["arch"])
    shape = shape_by_name(rec["shape"])
    n_dev = rec["n_devices"]
    flops = rec["flops"] + _slstm_correction(cfg, shape, n_dev)
    t_comp = flops / PEAK_BF16_FLOPS
    t_mem = rec["bytes_accessed"] / HBM_BW
    t_coll = rec["collective_bytes"] / ICI_BW
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = _model_flops_per_dev(cfg, shape, n_dev)
    useful = mf / max(flops, 1e-9)
    # roofline fraction: useful work at the dominant term's pace
    t_total = max(terms.values())
    frac = (mf / PEAK_BF16_FLOPS) / max(t_total, 1e-12)
    suggestions = {
        "compute": "cut remat recompute / pad waste; route matmuls to int8 MXU (2x)",
        "memory": "int8 weights (2x fewer bytes), larger per-step batch, fuse elementwise chains",
        "collective": "reshard to cut all-gathers (head->d_ff TP), bf16/int8 collectives, overlap with compute",
    }
    return {
        "arch": rec["arch"], "shape": rec["shape"], "kind": rec["kind"],
        "quantize": rec.get("quantize", "off"), "tag": rec.get("tag", ""),
        "t_compute_s": t_comp, "t_memory_s": t_mem, "t_collective_s": t_coll,
        "dominant": dominant, "model_flops_dev": mf, "hlo_flops_dev": flops,
        "useful_ratio": useful, "roofline_fraction": frac,
        "suggestion": suggestions[dominant],
    }


def run(write_md: bool = True) -> list:
    rows = []
    for arch in ARCH_IDS:
        for shape in ("train_4k", "prefill_32k", "decode_32k", "long_500k"):
            p = DRYRUN / f"{arch}_{shape}_pod_16x16.json"
            if not p.exists():
                continue
            rec = json.loads(p.read_text())
            if rec["status"] != "ok":
                rows.append({"arch": arch, "shape": shape, "skipped": rec.get("reason", "")})
                continue
            r = analyze(rec)
            rows.append(r)
            emit(f"roofline/{arch}_{shape}",
                 max(r["t_compute_s"], r["t_memory_s"], r["t_collective_s"]) * 1e6,
                 f"dominant={r['dominant']};useful={r['useful_ratio']:.2f};"
                 f"frac={r['roofline_fraction']:.3f}")
    if write_md:
        _write_md(rows)
    return rows


def _write_md(rows) -> None:
    lines = [
        "| arch | shape | compute (s) | memory (s) | collective (s) | dominant | MODEL/HLO | roofline frac |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if "skipped" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | SKIP | — | — |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.3e} | "
            f"{r['t_memory_s']:.3e} | {r['t_collective_s']:.3e} | "
            f"**{r['dominant']}** | {r['useful_ratio']:.2f} | "
            f"{r['roofline_fraction']:.3f} |")
    REPORTS.mkdir(exist_ok=True)
    (REPORTS / "roofline_table.md").write_text("\n".join(lines) + "\n")


if __name__ == "__main__":
    run()
