"""Serving throughput sweep: offered load vs sustained tok/s through the
continuous-batching engine (Jouppi et al.'s framing: a serving accelerator is
judged at its latency-bounded throughput, not peak batch FLOPs).

    PYTHONPATH=src python benchmarks/serve_throughput.py [--quantize serve]

Sweeps the arrival stagger (engine steps between request arrivals — smaller
stagger = higher offered load) and the slot count, and emits the CSV contract
of benchmarks/common.py: name,us_per_call,derived. ``us_per_call`` is the
microseconds per generated token (1e6 / sustained tok/s); ``derived`` carries
sustained tok/s, mean TTFT, and mean slot occupancy.
"""

from __future__ import annotations

import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent))

import jax
import numpy as np

from repro.configs import get_config
from repro.core import tensorizer as tz
from repro.distributed import sharding as shd
from repro.launch.mesh import make_smoke_mesh
from repro.launch.serve import _quant_predicate
from repro.models import init_model
from repro.serving.engine import Engine, EngineConfig

from common import emit


def run_cell(cfg, params, *, slots: int, stagger: int, n_requests: int,
             prompt_len: int, gen: int):
    engine = Engine(cfg, params, EngineConfig(
        max_slots=slots, max_queue=n_requests,
        max_seq_len=prompt_len + gen))
    rng = np.random.default_rng(0)
    reqs = []
    for _ in range(n_requests):
        reqs.append(engine.submit(
            rng.integers(0, cfg.vocab, (prompt_len,), dtype=np.int32), gen,
            strict=True))
        for _ in range(stagger):
            engine.step()
    engine.run_until_complete()
    s = engine.stats()
    ttft_ms = 1e3 * float(np.mean([r.metrics.ttft_s for r in reqs]))
    engine.close()
    return s, ttft_ms


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--quantize", default="off", choices=["off", "serve"])
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch).smoke().replace(quantize=args.quantize)
    mesh = make_smoke_mesh(1)
    with shd.use_mesh(mesh):
        params = init_model(cfg, jax.random.PRNGKey(0))
        if args.quantize == "serve":
            params = tz.quantize_params(params, predicate=_quant_predicate)

        for slots in (1, 2, 4, 8):
            # warmup compiles this slot count's executables with the sweep's
            # own shapes — same prompt_len+gen (cache/max_seq_len), the
            # all-at-once admission width (B = min(slots, requests) prefill)
            # AND the B=1 staggered-admission prefill — so the sweep cells
            # measure steady-state serving, not XLA
            run_cell(cfg, params, slots=slots, stagger=0,
                     n_requests=args.requests, prompt_len=args.prompt_len,
                     gen=args.gen)
            run_cell(cfg, params, slots=slots, stagger=1, n_requests=2,
                     prompt_len=args.prompt_len, gen=args.gen)
            for stagger in (0, 1, 4):          # all-at-once .. trickle
                s, ttft_ms = run_cell(
                    cfg, params, slots=slots, stagger=stagger,
                    n_requests=args.requests, prompt_len=args.prompt_len,
                    gen=args.gen)
                tps = s["sustained_tok_s"]
                emit(f"serve_s{slots}_g{stagger}",
                     1e6 / max(tps, 1e-9),
                     f"sustained={tps:.1f}tok/s ttft={ttft_ms:.0f}ms "
                     f"occ={s['mean_occupancy']:.2f}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
