"""Serving throughput sweep: offered load vs sustained tok/s through the
continuous-batching engine (Jouppi et al.'s framing: a serving accelerator is
judged at its latency-bounded throughput, not peak batch FLOPs).

    PYTHONPATH=src python benchmarks/serve_throughput.py [--quantize serve] \
        [--cache-backend contiguous|paged] \
        [--paged-report reports/BENCH_paged.json]

Sweeps the arrival stagger (engine steps between request arrivals — smaller
stagger = higher offered load) and the slot count, and emits the CSV contract
of benchmarks/common.py: name,us_per_call,derived. ``us_per_call`` is the
microseconds per generated token (1e6 / sustained tok/s); ``derived`` carries
sustained tok/s, mean TTFT, and mean slot occupancy. ``--cache-backend``
selects the SlotStore backend the sweep runs through (serving/store.py).

``--paged-report PATH`` skips the sweep and runs the paged-vs-contiguous
memory cell instead: the same short-prompt mix served by both backends
(tokens asserted bit-identical), with the paged block pool sized BELOW the
contiguous footprint — the JSON records cache bytes per admitted concurrent
request for each backend and the admission-backpressure counters, the
regression record for reports/BENCH_paged.json and the CI artifact.

``--router-report PATH`` runs the multi-host cell instead: the same request
mix served through the Router at 1/2/4 hosts (sessions cycling so the
second lap of arrivals pins by cache affinity), with a mid-run drain of
host 0 on every multi-host cell — tokens asserted bit-identical to the
single-engine run across the drain/handoff — recording wall-clock fleet
throughput, affinity hits, spills, and handoff counts per host count: the
regression record for reports/BENCH_router.json and the CI artifact.

``--prefix-report PATH`` runs the shared-prefix radix-cache cell instead:
the same request count served at 0% / 50% / 90% shared-prefix traffic
through a prefix-cache engine (tokens asserted bit-identical to a
prefix-cache-OFF paged engine at every share), recording per-cell TTFT and
the prefill work actually dispatched (block-size chunk units — cached
chunks are leased by refcount and skipped). Prefill dispatches are asserted
strictly decreasing as the share rises: the regression record for
reports/BENCH_prefix.json and the CI artifact.

``--spec-report PATH`` runs the speculative-decoding cell instead: the same
request mix served plain and with draft-verify decode at each ``--spec-k``
(draft == target, the full-acceptance ceiling), hard-asserting the token
streams bit-identical to plain greedy, mean accepted length > 1, and target
decode-path dispatches per emitted token strictly < 1.0 — recording tok/s
vs plain and the accepted-length histogram: the regression record for
reports/BENCH_spec.json and the CI artifact.

``--transport-report PATH`` runs the transport cell instead: the raw RPC
round-trip and per-decode-step overhead of the subprocess backend (framed
RPC over an AF_UNIX socket, workers rebuilding bit-identical weights from
the model spec) against the in-process backend serving the same mix —
tokens asserted bit-identical across the process boundary — plus fleet
throughput at 1/2/4 worker processes and the recovery timeline after a
hard SIGKILL of one worker mid-decode (loss detection, first re-placed
token, full drain): the regression record for reports/BENCH_transport.json
and the CI artifact.

``--disagg-report PATH`` runs the prefill/decode disaggregation cell
instead: a bimodal mix (short interactive prompts decoding while long
batch prompts keep arriving) served by a two-worker-process fleet without
roles and again split ``prefill:1,decode:1`` — streams ship their exact
KV blocks to the decode host once past the handoff threshold — recording
the interactive streams' p50/p99 inter-token gap in each mode, with
tokens hard-asserted bit-identical to a single engine in both modes (and
again in-process for int8-KV, whose dequant scales travel inside the
shipped payloads) and ZERO prefill instructions dispatched on the decode
host (OPQ flag audit): the regression record for
reports/BENCH_disagg.json and the CI artifact.

``--sampling-report PATH`` runs the sampling-engine cell instead: the same
request mix served all-greedy and all-sampled (temperature/top-k/top-p,
per-request seeds) through the ONE shared executable, recording the
per-decode-step sampler overhead; a seeded request's stream is hard-asserted
bit-identical alone vs inside mixed traffic vs on the paged backend (the
batch-invariance claim), and streaming TTFT is measured from HTTP POST to
the first SSE token event through serving/api.py next to the engine-loop
TTFT: the regression record for reports/BENCH_sampling.json and the CI
artifact.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent))

import jax
import numpy as np

from repro.configs import get_config
from repro.core import tensorizer as tz
from repro.distributed import sharding as shd
from repro.launch.mesh import make_smoke_mesh
from repro.launch.serve import _quant_predicate
from repro.models import init_model
from repro.serving.engine import Engine, EngineConfig
from repro.serving.router import Router, RouterConfig

from common import emit


def run_cell(cfg, params, *, slots: int, stagger: int, n_requests: int,
             prompt_len: int, gen: int, backend: str = "auto",
             block_size: int = 16, n_blocks=None, max_seq_len=None,
             paged_native=False, prefill_chunk=None, buckets=None):
    engine = Engine(cfg, params, EngineConfig(
        max_slots=slots, max_queue=n_requests,
        max_seq_len=max_seq_len or (prompt_len + gen), cache_backend=backend,
        block_size=block_size, n_blocks=n_blocks, paged_native=paged_native,
        prefill_chunk=prefill_chunk, buckets=buckets))
    rng = np.random.default_rng(0)
    reqs = []
    for _ in range(n_requests):
        reqs.append(engine.submit(
            rng.integers(0, cfg.vocab, (prompt_len,), dtype=np.int32), gen,
            strict=True))
        for _ in range(stagger):
            engine.step()
    engine.run_until_complete()
    s = engine.stats()
    ttft_ms = 1e3 * float(np.mean([r.metrics.ttft_s for r in reqs]))
    toks = [list(r.tokens) for r in reqs]
    engine.close()
    return s, ttft_ms, toks


def paged_memory_report(cfg, params, *, slots: int, prompt_len: int, gen: int,
                        block_size: int, out_path: str) -> dict:
    """The paged-KV memory claim, measured: serve one short-prompt mix through
    both backends under the same per-slot sequence BUDGET (``max_seq``, 4x the
    requests' true length — the headroom a production engine must offer), with
    the paged pool sized to the mix's true footprint. The contiguous backend
    reserves full max_seq rows per slot — a footprint that exceeds the whole
    paged pool — while paged leases only ceil((prompt+gen)/block) blocks per
    request, so it serves strictly more concurrent short requests per byte.
    Token streams are asserted bit-identical, so the bytes saved cost zero
    output fidelity."""
    req_len = prompt_len + gen
    max_seq = 4 * req_len                  # the budget slots must offer
    n_requests = 2 * slots
    blocks_per_req = -(-req_len // block_size)
    # pool: exactly the blocks the admitted short-request concurrency needs
    # (+ the reserved null block) — well under slots x max_seq rows
    n_blocks = slots * blocks_per_req + 1

    s_c, ttft_c, toks_c = run_cell(
        cfg, params, slots=slots, stagger=0, n_requests=n_requests,
        prompt_len=prompt_len, gen=gen, backend="contiguous",
        max_seq_len=max_seq)
    s_p, ttft_p, toks_p = run_cell(
        cfg, params, slots=slots, stagger=0, n_requests=n_requests,
        prompt_len=prompt_len, gen=gen, backend="paged",
        block_size=block_size, n_blocks=n_blocks, max_seq_len=max_seq)
    assert toks_c == toks_p, "paged decode diverged from contiguous"

    bytes_c = s_c["cache"]["bytes"]
    bytes_p = s_p["cache"]["bytes"]
    report = {
        "benchmark": "paged_kv_memory",
        "arch": cfg.name,
        "kv_cache_dtype": cfg.kv_cache_dtype,
        "slots": slots,
        "prompt_len": prompt_len,
        "gen": gen,
        "max_seq_len": max_seq,
        "block_size": block_size,
        "n_blocks": n_blocks,
        "requests": n_requests,
        "bit_identical_tokens": True,
        "contiguous": {
            "cache_bytes": bytes_c,
            "bytes_per_admitted_request": bytes_c // slots,
            "ttft_ms": ttft_c,
            "sustained_tok_s": s_c["sustained_tok_s"],
        },
        "paged": {
            "cache_bytes": bytes_p,
            "bytes_per_admitted_request": bytes_p // slots,
            # per-step transient contiguous view (the bit-identity gather
            # bridge) — the peak decode working set is cache + view, so the
            # byte saving is in the RESIDENT allocation, not the step peak
            "decode_view_bytes": s_p["cache"]["decode_view_bytes"],
            "ttft_ms": ttft_p,
            "sustained_tok_s": s_p["sustained_tok_s"],
            "admissions_deferred": s_p["admissions_deferred"],
            "blocks_total": s_p["cache"]["blocks_total"],
        },
        "paged_over_contiguous_bytes": bytes_p / bytes_c,
        # the headline: concurrent admitted requests a byte of cache buys
        "requests_per_mib_contiguous": slots / (bytes_c / 2**20),
        "requests_per_mib_paged": slots / (bytes_p / 2**20),
    }
    emit("paged_kv_bytes_per_req", report["paged"]["bytes_per_admitted_request"],
         f"contiguous={report['contiguous']['bytes_per_admitted_request']}B "
         f"ratio={report['paged_over_contiguous_bytes']:.2f}")
    Path(out_path).parent.mkdir(parents=True, exist_ok=True)
    Path(out_path).write_text(json.dumps(report, indent=2) + "\n")
    print(f"# paged {bytes_p}B vs contiguous {bytes_c}B "
          f"({report['paged_over_contiguous_bytes']:.2f}x) for the same "
          f"admitted concurrency, tokens bit-identical")
    print(f"# wrote {out_path}")
    return report


def paged_native_report(cfg, params, *, slots: int, prompt_len: int, gen: int,
                        block_size: int, chunk: int, long_prompt: int,
                        out_path: str) -> dict:
    """The block-native claim, measured: (1) the same short-prompt mix served
    through the paged gather-bridge and the block-native decode — tokens
    asserted bit-identical — recording each mode's PEAK decode working set.
    The bridge's peak is pool + the all-layer gather view; native mode's
    store-level view is gone (decode_view_bytes == 0), but the jnp
    block-native path still gathers ONE layer's rows transiently inside the
    layer scan (view_bytes / n_layers), so its honest peak is pool +
    per-layer gather; only the Pallas kernel path (paged_kernel=True) works
    from block-sized VMEM tiles alone, reported as kernel_peak_decode_bytes.
    (2) A long prompt (wider than every fused bucket) admitted via the
    chunked prefill, recording its TTFT against single-shot fused admission
    of the same prompt (tokens asserted bit-identical) and the peak prefill
    score-matrix bytes each mode materializes (B*H*S*S f32 single-shot vs
    B*H*chunk*S chunked — the quadratic term that caps admissible prompt
    length)."""
    req_len = prompt_len + gen
    n_requests = 2 * slots

    s_b, ttft_b, toks_b = run_cell(
        cfg, params, slots=slots, stagger=0, n_requests=n_requests,
        prompt_len=prompt_len, gen=gen, backend="paged",
        block_size=block_size)
    s_n, ttft_n, toks_n = run_cell(
        cfg, params, slots=slots, stagger=0, n_requests=n_requests,
        prompt_len=prompt_len, gen=gen, backend="paged",
        block_size=block_size, paged_native=True)
    assert toks_b == toks_n, "block-native decode diverged from gather bridge"
    assert s_n["cache"]["decode_view_bytes"] == 0

    # long-prompt admission: fused buckets capped at `chunk`, so the long
    # prompt can only enter through the chunked path
    long_seq = long_prompt + gen
    s_lc, ttft_lc, toks_lc = run_cell(
        cfg, params, slots=1, stagger=0, n_requests=1,
        prompt_len=long_prompt, gen=gen, max_seq_len=long_seq,
        buckets=(chunk,), prefill_chunk=chunk)
    s_lf, ttft_lf, toks_lf = run_cell(
        cfg, params, slots=1, stagger=0, n_requests=1,
        prompt_len=long_prompt, gen=gen, max_seq_len=long_seq)
    assert toks_lc == toks_lf, "chunked prefill diverged from single-shot"

    import math
    from repro.serving import bucket_for, default_buckets
    bucket = math.ceil(long_prompt / chunk) * chunk          # chunked engine
    fused_bucket = bucket_for(long_prompt, default_buckets(long_seq))
    score_fused = 4 * cfg.n_heads * fused_bucket * fused_bucket  # B=1, f32
    score_chunked = 4 * cfg.n_heads * chunk * bucket
    report = {
        "benchmark": "paged_native",
        "arch": cfg.name,
        "kv_cache_dtype": cfg.kv_cache_dtype,
        "slots": slots,
        "prompt_len": prompt_len,
        "gen": gen,
        "block_size": block_size,
        "requests": n_requests,
        "bit_identical_tokens": True,
        "decode": {
            "bridge": {
                "pool_bytes": s_b["cache"]["bytes"],
                "decode_view_bytes": s_b["cache"]["decode_view_bytes"],
                "peak_decode_bytes": (s_b["cache"]["bytes"]
                                      + s_b["cache"]["decode_view_bytes"]),
                "ttft_ms": ttft_b,
                "sustained_tok_s": s_b["sustained_tok_s"],
            },
            "native": {
                "pool_bytes": s_n["cache"]["bytes"],
                "decode_view_bytes": 0,
                # the jnp block-native path gathers one layer's rows
                # transiently inside the layer scan
                "per_layer_gather_bytes":
                    s_b["cache"]["decode_view_bytes"] // cfg.n_layers,
                "peak_decode_bytes": (
                    s_n["cache"]["bytes"]
                    + s_b["cache"]["decode_view_bytes"] // cfg.n_layers),
                # the Pallas kernel path holds only block-sized VMEM tiles
                "kernel_peak_decode_bytes": s_n["cache"]["bytes"],
                "ttft_ms": ttft_n,
                "sustained_tok_s": s_n["sustained_tok_s"],
                "table_uploads": s_n["cache"]["table_uploads"],
            },
            "native_over_bridge_peak_bytes": (
                (s_n["cache"]["bytes"]
                 + s_b["cache"]["decode_view_bytes"] // cfg.n_layers)
                / (s_b["cache"]["bytes"] + s_b["cache"]["decode_view_bytes"])),
        },
        "long_prompt": {
            "prompt_len": long_prompt,
            "prefill_chunk": chunk,
            "bucket": bucket,
            "fused": {"ttft_ms": ttft_lf,
                      "bucket": fused_bucket,
                      "score_matrix_bytes": score_fused},
            "chunked": {"ttft_ms": ttft_lc,
                        "score_matrix_bytes": score_chunked},
            "score_bytes_ratio": score_chunked / score_fused,
        },
    }
    emit("paged_native_peak_decode_bytes",
         report["decode"]["native"]["peak_decode_bytes"],
         f"bridge_peak={report['decode']['bridge']['peak_decode_bytes']}B "
         f"ratio={report['decode']['native_over_bridge_peak_bytes']:.2f}")
    emit("chunked_prefill_score_bytes",
         score_chunked,
         f"fused={score_fused}B ratio={report['long_prompt']['score_bytes_ratio']:.3f} "
         f"ttft_chunked={ttft_lc:.0f}ms ttft_fused={ttft_lf:.0f}ms")
    Path(out_path).parent.mkdir(parents=True, exist_ok=True)
    Path(out_path).write_text(json.dumps(report, indent=2) + "\n")
    print(f"# native peak decode {report['decode']['native']['peak_decode_bytes']}B "
          f"vs bridge {report['decode']['bridge']['peak_decode_bytes']}B "
          f"({report['decode']['native_over_bridge_peak_bytes']:.2f}x), "
          f"tokens bit-identical; long-prompt score matrix "
          f"{score_chunked}B vs {score_fused}B")
    print(f"# wrote {out_path}")
    return report


def router_report(cfg, params, *, hosts_swept=(1, 2, 4), slots: int,
                  prompt_len: int, gen: int, requests: int, drain_at: int,
                  out_path: str) -> dict:
    """The multi-host claim, measured: one request mix served through the
    Router at increasing host counts, sessions cycling over the host count
    so the second lap of arrivals pins to the host already holding that
    session's blocks. Every multi-host cell drains host 0 mid-run — its
    queued work re-places and its long in-flight generations hand off — and
    each cell's stitched token streams are asserted bit-identical to the
    1-host run, so scale-out and elastic restarts cost zero output
    fidelity. Records wall-clock fleet tok/s and the placement ledger
    (affinity hits / spills / handoffs) per host count."""
    import time

    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, (prompt_len,), dtype=np.int32)
               for _ in range(requests)]

    # warmup: compile the prefill/decode executables (shared across every
    # cell's engines via the engine step cache) so cells measure serving,
    # not XLA
    warm = Router(cfg, params, EngineConfig(
        max_slots=slots, max_queue=requests,
        max_seq_len=prompt_len + gen), RouterConfig(n_hosts=1))
    for p in prompts[:2]:
        warm.submit(p, gen, strict=True)
        warm.step()
    warm.run_until_complete()
    warm.close()

    cells = []
    baseline_tokens = None
    for n_hosts in hosts_swept:
        router = Router(cfg, params, EngineConfig(
            max_slots=slots, max_queue=requests,
            max_seq_len=prompt_len + gen),
            RouterConfig(n_hosts=n_hosts, handoff_threshold=0))
        fleet_steps = 0
        t0 = time.perf_counter()
        reqs = []
        for i, p in enumerate(prompts):
            reqs.append(router.submit(p, gen, session=str(i % n_hosts),
                                      strict=True))
            router.step()
            fleet_steps += 1
            if n_hosts > 1 and fleet_steps == drain_at:
                router.drain(0)
        while router.has_work():
            router.step()
            fleet_steps += 1
            if n_hosts > 1 and fleet_steps == drain_at:
                router.drain(0)
        wall_s = time.perf_counter() - t0
        toks = [list(r.tokens) for r in reqs]
        if baseline_tokens is None:
            baseline_tokens = toks
        else:
            assert toks == baseline_tokens, (
                f"{n_hosts}-host tokens diverged from single-host "
                f"(drain at step {drain_at})")
        s = router.stats()
        r = s["router"]
        cells.append({
            "hosts": n_hosts,
            "drained_host": 0 if n_hosts > 1 else None,
            "drain_at_step": drain_at if n_hosts > 1 else None,
            "wall_s": wall_s,
            "fleet_tok_s": requests * gen / wall_s,
            "placed": r["placed"],
            "affinity_hits": r["affinity_hits"],
            "spills": r["spills"],
            "handoffs": r["handoffs"],
            "requeued": r["requeued"],
            "completed_per_host": [h["completed"] for h in s["per_host"]],
            "preempted_per_host": [h["preempted"] for h in s["per_host"]],
        })
        router.close()

    report = {
        "benchmark": "router_multi_host",
        "arch": cfg.name,
        "kv_cache_dtype": cfg.kv_cache_dtype,
        "slots_per_host": slots,
        "prompt_len": prompt_len,
        "gen": gen,
        "requests": requests,
        "bit_identical_tokens": True,
        "cells": cells,
    }
    base = cells[0]["fleet_tok_s"]
    for c in cells:
        emit(f"router_h{c['hosts']}", 1e6 / max(c["fleet_tok_s"], 1e-9),
             f"fleet={c['fleet_tok_s']:.1f}tok/s "
             f"speedup={c['fleet_tok_s'] / base:.2f}x "
             f"affinity={c['affinity_hits']} spills={c['spills']} "
             f"handoffs={c['handoffs']}")
    Path(out_path).parent.mkdir(parents=True, exist_ok=True)
    Path(out_path).write_text(json.dumps(report, indent=2) + "\n")
    print(f"# router: {len(cells)} host-count cells, tokens bit-identical "
          f"across scale-out AND a mid-run drain/handoff on every "
          f"multi-host cell")
    print(f"# wrote {out_path}")
    return report


def prefix_report(cfg, params, *, prompt_len: int, gen: int, block_size: int,
                  requests: int, out_path: str) -> dict:
    """The shared-prefix claim, measured: the same request count served at
    0% / 50% / 90% shared-prefix traffic through a prefix-cache engine. At
    each share the first request is cold (it populates the radix trie); the
    rest lease the cached preamble blocks by refcount and run chunked
    prefill only over the suffix, so the prefill work actually dispatched —
    counted in block-size chunk units — drops as the share rises, and TTFT
    drops with it. Every cell's token streams are asserted bit-identical to
    a prefix-cache-OFF paged engine serving the same prompts: the reused
    cache bits cost zero output fidelity. Chunk units are hard-asserted
    strictly decreasing across shares; TTFT is recorded but not asserted
    (wall-clock on shared CI is noisy)."""
    import time

    rng = np.random.default_rng(0)
    max_seq = prompt_len + gen
    bps = max_seq // block_size

    def make_prompts(share_pct):
        shared = int(round(prompt_len * share_pct / 100.0))
        preamble = rng.integers(0, cfg.vocab, (shared,), dtype=np.int32)
        return [np.concatenate([
            preamble,
            rng.integers(0, cfg.vocab, (prompt_len - shared,),
                         dtype=np.int32)]) for _ in range(requests)]

    def make_engine(prefix):
        return Engine(cfg, params, EngineConfig(
            max_slots=2, max_queue=requests, max_seq_len=max_seq,
            cache_backend="paged", block_size=block_size,
            n_blocks=3 * bps + 1, prefix_cache=prefix))

    # warmup: compile the fused-prefill, suffix-prefill and decode
    # executables (shared across every cell's engines via the engine step
    # cache) so cells measure serving, not XLA
    warm = make_engine(True)
    for p in make_prompts(90)[:2]:
        warm.submit(p, gen, strict=True)
        warm.run_until_complete()
    warm.close()

    cells = []
    prev_dispatch = None
    for share in (0, 50, 90):
        prompts = make_prompts(share)
        hot = make_engine(True)
        cold = make_engine(False)
        toks_hot, toks_cold, ttfts = [], [], []
        chunks_after_first = 0
        for i, p in enumerate(prompts):
            rh = hot.submit(p, gen, strict=True)
            hot.run_until_complete()
            rc = cold.submit(p, gen, strict=True)
            cold.run_until_complete()
            toks_hot.append(list(rh.tokens))
            toks_cold.append(list(rc.tokens))
            ttfts.append(rh.metrics.ttft_s)
            if i == 0:
                chunks_after_first = hot.metrics.prefill_chunks
        assert toks_hot == toks_cold, (
            f"prefix-hit tokens diverged from prefix-cache-off serving at "
            f"{share}% shared-prefix traffic")
        s = hot.stats()
        # prefill work per WARM request (requests 2..N — request 1 always
        # pays the cold full-prompt prefill that populates the trie)
        dispatch = ((s["prefill_chunks"] - chunks_after_first)
                    / (requests - 1))
        if prev_dispatch is not None:
            assert dispatch < prev_dispatch, (
                f"prefill dispatches did not drop as shared-prefix share "
                f"rose to {share}%: {dispatch} >= {prev_dispatch}")
        prev_dispatch = dispatch
        cells.append({
            "share_pct": share,
            "shared_prefix_tokens": int(round(prompt_len * share / 100.0)),
            "prefill_chunk_units_per_warm_request": dispatch,
            "prefill_chunk_units_total": s["prefill_chunks"],
            "prefix_hits": s["prefix_hits"],
            "prefix_blocks_reused": s["prefix_blocks_reused"],
            "prefix_tokens_reused": s["prefix_tokens_reused"],
            "cow_forks": s["cache"]["cow_forks"],
            "prefix_evictions": s["cache"]["prefix_evictions"],
            "cold_ttft_ms": 1e3 * ttfts[0],
            "warm_ttft_ms": 1e3 * float(np.mean(ttfts[1:])),
        })
        hot.close()
        cold.close()

    report = {
        "benchmark": "prefix_cache",
        "arch": cfg.name,
        "kv_cache_dtype": cfg.kv_cache_dtype,
        "block_size": block_size,
        "prompt_len": prompt_len,
        "gen": gen,
        "requests": requests,
        "bit_identical_tokens": True,
        "cells": cells,
    }
    for c in cells:
        emit(f"prefix_s{c['share_pct']}", 1e3 * c["warm_ttft_ms"],
             f"chunks/warm-req={c['prefill_chunk_units_per_warm_request']:.1f} "
             f"hits={c['prefix_hits']} reused={c['prefix_blocks_reused']} "
             f"ttft={c['warm_ttft_ms']:.1f}ms")
    Path(out_path).parent.mkdir(parents=True, exist_ok=True)
    Path(out_path).write_text(json.dumps(report, indent=2) + "\n")
    trend = " -> ".join(
        f"{c['prefill_chunk_units_per_warm_request']:.1f}" for c in cells)
    print(f"# prefix: chunk units per warm request {trend} across shares "
          f"0/50/90%, tokens bit-identical to prefix-cache-off")
    print(f"# wrote {out_path}")
    return report


def spec_report(cfg, params, *, slots: int, prompt_len: int, gen: int,
                requests: int, spec_ks=(2, 4), out_path: str) -> dict:
    """The speculative-decoding claim, measured: the same request mix served
    plain and with draft-verify decode at each ``spec_k``. The draft is the
    TARGET model itself (same config, same weights): the full-acceptance
    ceiling, which makes the mechanism measurable without a second trained
    checkpoint — every verify round advances each slot by the whole window,
    so target decode-path dispatches per emitted token land at their floor
    ~1/(k+1). Token streams are hard-asserted bit-identical to plain greedy
    decode, mean accepted length is hard-asserted > 1, and dispatched target
    steps per decode token hard-asserted strictly < 1.0. Wall-clock tok/s is
    recorded vs plain but NOT asserted: with a draft as large as the target
    the (k+1) narrow draft forwards cost what they save — a deployment's
    draft is far smaller, and the dispatch-count reduction is the claim."""
    import time

    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, (prompt_len,), dtype=np.int32)
               for _ in range(requests)]
    base = dict(max_slots=slots, max_queue=requests,
                max_seq_len=prompt_len + gen)

    def serve(ecfg, dparams=None):
        eng = Engine(cfg, params, ecfg, draft_params=dparams)
        t0 = time.perf_counter()
        reqs = [eng.submit(p, gen, strict=True) for p in prompts]
        eng.run_until_complete()
        wall_s = time.perf_counter() - t0
        s = eng.stats()
        toks = [list(r.tokens) for r in reqs]
        eng.close()
        return s, wall_s, toks

    # warmup: compile the prefill/decode/draft/verify executables for every
    # spec_k (distinct window widths), so cells measure serving, not XLA
    serve(EngineConfig(**base))
    for k in spec_ks:
        serve(EngineConfig(**base, speculative=True, spec_k=k, draft=cfg),
              dparams=params)

    s_p, wall_p, toks_p = serve(EngineConfig(**base))
    decoded_p = s_p["tokens_generated"] - s_p["completed"]
    cells = []
    for k in spec_ks:
        s, wall_s, toks = serve(
            EngineConfig(**base, speculative=True, spec_k=k, draft=cfg),
            dparams=params)
        assert toks == toks_p, (
            f"speculative decode (spec_k={k}) diverged from plain greedy")
        decoded = s["tokens_generated"] - s["completed"]
        slot_rounds = sum(s["accept_hist"].values())
        mean_acc = decoded / slot_rounds
        spt = s["decode_steps"] / decoded
        assert mean_acc > 1.0, (
            f"mean accepted length {mean_acc:.2f} <= 1 at spec_k={k}: "
            f"speculation bought nothing")
        assert spt < 1.0, (
            f"target decode steps per emitted token {spt:.2f} >= 1 at "
            f"spec_k={k}: more dispatches than plain decode")
        # batching already puts plain below 1 step/token, so also pin the
        # stronger claim: strictly fewer target dispatches than plain made
        # for the very same streams
        assert s["decode_steps"] < s_p["decode_steps"], (
            f"spec_k={k} dispatched {s['decode_steps']} target decode "
            f"steps, plain needed only {s_p['decode_steps']}")
        cells.append({
            "spec_k": k,
            "wall_s": wall_s,
            "sustained_tok_s": s["sustained_tok_s"],
            "tok_s_vs_plain": s["sustained_tok_s"]
                              / max(s_p["sustained_tok_s"], 1e-9),
            "decode_steps": s["decode_steps"],
            "spec_rounds": s["spec_rounds"],
            "draft_steps": s["draft_steps"],
            "proposed_tokens": s["proposed_tokens"],
            "accepted_tokens": s["accepted_tokens"],
            "acceptance_rate": s["acceptance_rate"],
            "mean_accepted_len": mean_acc,
            "steps_per_decode_token": spt,
            "accept_hist": {str(length): count
                            for length, count in s["accept_hist"].items()},
        })

    report = {
        "benchmark": "speculative_decode",
        "arch": cfg.name,
        "kv_cache_dtype": cfg.kv_cache_dtype,
        "slots": slots,
        "prompt_len": prompt_len,
        "gen": gen,
        "requests": requests,
        "draft": {"arch": cfg.name,
                  "note": "draft == target (full-acceptance ceiling)"},
        "bit_identical_tokens": True,
        "plain": {
            "wall_s": wall_p,
            "sustained_tok_s": s_p["sustained_tok_s"],
            "decode_steps": s_p["decode_steps"],
            "tokens_generated": s_p["tokens_generated"],
            "steps_per_decode_token": s_p["decode_steps"] / decoded_p,
        },
        "cells": cells,
    }
    for c in cells:
        emit(f"spec_k{c['spec_k']}",
             1e6 / max(c["sustained_tok_s"], 1e-9),
             f"steps/tok={c['steps_per_decode_token']:.2f} "
             f"mean_acc={c['mean_accepted_len']:.2f} "
             f"accept={c['acceptance_rate']:.2f} "
             f"vs_plain={c['tok_s_vs_plain']:.2f}x")
    Path(out_path).parent.mkdir(parents=True, exist_ok=True)
    Path(out_path).write_text(json.dumps(report, indent=2) + "\n")
    trend = " ".join(f"k={c['spec_k']}:{c['steps_per_decode_token']:.2f}"
                     for c in cells)
    print(f"# speculative: target steps per decode token {trend} "
          f"(plain {report['plain']['steps_per_decode_token']:.2f}), "
          f"tokens bit-identical to plain greedy")
    print(f"# wrote {out_path}")
    return report


def sampling_report(cfg, params, *, slots: int, prompt_len: int, gen: int,
                    requests: int, out_path: str) -> dict:
    """The sampling-engine claims, measured: (1) per-decode-step overhead of
    the batched sampler vs pure greedy traffic — both mixes run the SAME
    executable (masked param application), so the cost is the sampler math,
    not a second program; (2) batch invariance, hard-asserted on tokens: one
    seeded request decodes alone, inside mixed traffic, and on the paged
    backend — three bit-identical streams or the report dies; (3) streaming
    TTFT — wall time from HTTP POST to the first SSE token event through
    serving/api.py, next to the engine-loop TTFT the CLI path records."""
    import http.client
    import time

    from repro.serving.api import serve_api
    from repro.serving.sampling import SamplingParams

    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, (prompt_len,), dtype=np.int32)
               for _ in range(requests)]
    base = dict(max_slots=slots, max_queue=requests,
                max_seq_len=prompt_len + gen)
    sampled_sp = [SamplingParams(temperature=0.8, top_k=20, top_p=0.95,
                                 seed=1000 + i) for i in range(requests)]

    def serve(sampling_for, ecfg_kw=None):
        eng = Engine(cfg, params, EngineConfig(**base, **(ecfg_kw or {})))
        t0 = time.perf_counter()
        reqs = [eng.submit(p, gen, sampling=sampling_for(i), strict=True)
                for i, p in enumerate(prompts)]
        eng.run_until_complete()
        wall_s = time.perf_counter() - t0
        s = eng.stats()
        toks = [list(r.tokens) for r in reqs]
        eng.close()
        return s, wall_s, toks

    # warmup compiles the shared executable and both prefill buckets; the
    # sampled warmup also pays the one-off sampler trace
    serve(lambda i: None)
    serve(lambda i: sampled_sp[i])
    serve(lambda i: sampled_sp[i], dict(cache_backend="paged", block_size=8))

    def decode_us(s, wall_s):
        # decode-path wall only: prefill forwards and cache-seed writes are
        # admission cost, identical across the two mixes
        decode_s = wall_s - s["prefill_wait_s"] - s["seed_write_s"]
        return 1e6 * decode_s / max(s["decode_steps"], 1)

    s_g, wall_g, _ = serve(lambda i: None)
    s_s, wall_s_, toks_mixed_base = serve(lambda i: sampled_sp[i])
    us_greedy = decode_us(s_g, wall_g)
    us_sampled = decode_us(s_s, wall_s_)

    # --- batch invariance, asserted on tokens --------------------------
    def solo(ecfg_kw=None):
        eng = Engine(cfg, params, EngineConfig(**base, **(ecfg_kw or {})))
        req = eng.submit(prompts[0], gen, sampling=sampled_sp[0], strict=True)
        eng.run_until_complete()
        out = list(req.tokens)
        eng.close()
        return out

    alone = solo()
    assert toks_mixed_base[0] == alone, (
        "seeded stream changed with batchmates: sampling is not "
        "batch-invariant")
    assert solo(dict(cache_backend="paged", block_size=8)) == alone, (
        "seeded stream changed across cache backends")
    _, _, toks_paged = serve(lambda i: sampled_sp[i],
                             dict(cache_backend="paged", block_size=8))
    assert toks_paged == toks_mixed_base, (
        "sampled batch diverged between contiguous and paged backends")

    # --- streaming TTFT over HTTP vs the engine-loop TTFT ---------------
    eng = Engine(cfg, params, EngineConfig(**base))
    req = eng.submit(prompts[0], gen, strict=True)
    eng.run_until_complete()
    cli_ttft_ms = 1e3 * req.metrics.ttft_s
    eng.close()

    eng = Engine(cfg, params, EngineConfig(**base))
    srv = serve_api(eng, port=0, mesh=shd.current_mesh())
    try:
        conn = http.client.HTTPConnection("127.0.0.1", srv.port, timeout=60)
        t0 = time.perf_counter()
        conn.request("POST", "/v1/completions",
                     body=json.dumps({
                         "prompt": [int(t) for t in prompts[0]],
                         "max_new_tokens": gen, "stream": True}).encode(),
                     headers={"Content-Type": "application/json"})
        resp = conn.getresponse()
        http_ttft_ms = None
        for raw in resp.fp:
            line = raw.decode().strip()
            if line.startswith("data: ") and "token" in line:
                http_ttft_ms = 1e3 * (time.perf_counter() - t0)
                break
        conn.close()
        assert http_ttft_ms is not None, "no SSE token event arrived"
    finally:
        srv.close()
        eng.close()

    report = {
        "benchmark": "sampling",
        "arch": cfg.name,
        "slots": slots,
        "prompt_len": prompt_len,
        "gen": gen,
        "requests": requests,
        "params": {"temperature": 0.8, "top_k": 20, "top_p": 0.95},
        "batch_invariant": True,        # hard-asserted above, or we died
        "greedy": {
            "wall_s": wall_g,
            "decode_us_per_step": us_greedy,
            "sustained_tok_s": s_g["sustained_tok_s"],
        },
        "sampled": {
            "wall_s": wall_s_,
            "decode_us_per_step": us_sampled,
            "sustained_tok_s": s_s["sustained_tok_s"],
            "sampled_tokens": s_s["sampled_tokens"],
        },
        "sampling_overhead_pct": 100.0 * (us_sampled - us_greedy)
                                 / max(us_greedy, 1e-9),
        "streaming": {
            "http_ttft_ms": http_ttft_ms,
            "cli_ttft_ms": cli_ttft_ms,
        },
    }
    emit("sample_greedy", us_greedy,
         f"tok/s={s_g['sustained_tok_s']:.1f}")
    emit("sample_full", us_sampled,
         f"tok/s={s_s['sustained_tok_s']:.1f} "
         f"overhead={report['sampling_overhead_pct']:.1f}%")
    emit("stream_ttft", 1e3 * http_ttft_ms,
         f"http={http_ttft_ms:.1f}ms cli={cli_ttft_ms:.1f}ms")
    Path(out_path).parent.mkdir(parents=True, exist_ok=True)
    Path(out_path).write_text(json.dumps(report, indent=2) + "\n")
    print(f"# sampling: {us_sampled:.0f}us vs {us_greedy:.0f}us greedy per "
          f"decode step ({report['sampling_overhead_pct']:+.1f}%), seeded "
          f"streams bit-identical across batchmates and backends, "
          f"HTTP TTFT {http_ttft_ms:.1f}ms vs CLI {cli_ttft_ms:.1f}ms")
    print(f"# wrote {out_path}")
    return report


def transport_report(cfg, params, *, arch: str, prompt_len: int, gen: int,
                     requests: int, hosts_swept=(1, 2, 4),
                     out_path: str) -> dict:
    """The transport claim, measured: (1) raw RPC round-trip — the same
    ``load`` call timed over the in-process backend (a method call) and the
    subprocess backend (a framed request over an AF_UNIX socket); (2) the
    same request mix served through one in-process host and one subprocess
    host — tokens asserted bit-identical, per-RPC and per-token overhead
    recorded from the TransportMetrics both backends share; (3) fleet
    throughput at 1/2/4 worker processes; (4) recovery after a hard SIGKILL
    of one worker mid-decode — time from the kill to the router marking the
    host LOST, to the first token of a re-placed continuation, and to the
    full mix completing. Workers rebuild bit-identical weights from the
    model spec, so no params cross the wire."""
    import os
    import signal
    import time

    from repro.serving.transport import (
        SubprocessTransport, build_inproc_fleet, build_model_spec,
        default_codec,
    )

    max_seq = prompt_len + gen
    ecfg = EngineConfig(max_slots=2, max_queue=max(requests, 8),
                        max_seq_len=max_seq)
    spec = build_model_spec(arch, smoke=True, seed=0)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, (prompt_len,), dtype=np.int32)
               for _ in range(requests)]

    def spawn(n, cfg_override=None):
        fleet = []
        try:
            for _ in range(n):
                fleet.append(SubprocessTransport(spec, cfg_override or ecfg))
        except Exception:
            for t in fleet:
                t.close()
            raise
        return fleet

    def warm(fleet):
        # every worker compiles its prefill/decode executables up front so
        # the cells measure serving + transport, not XLA
        for t in fleet:
            eid = t.submit(prompts[0][:4], 2)
            deadline = time.monotonic() + 300
            while not t.poll({eid: 0}).get(eid, {}).get("done"):
                assert time.monotonic() < deadline, "warmup never finished"
                if t.kind == "in-process":
                    t.pump()               # no worker process: we step
                else:
                    time.sleep(0.005)      # the worker free-runs
            t.poll({}, drop=[eid])

    def rpc_micro(t, n=300):
        for _ in range(20):
            t.load()                           # steady-state the path
        t0 = time.perf_counter()
        for _ in range(n):
            t.load()
        return 1e6 * (time.perf_counter() - t0) / n

    def serve(transports):
        router = Router(transports=transports)
        t0 = time.perf_counter()
        reqs = []
        for i, p in enumerate(prompts):
            reqs.append(router.submit(p, gen, session=str(i % len(transports)),
                                      strict=True))
            router.step()
        router.run_until_complete()
        wall_s = time.perf_counter() - t0
        toks = [list(r.tokens) for r in reqs]
        rows = router.stats()["router"]["transport"]
        router.close()
        return wall_s, toks, rows

    # --- (1)+(2): one in-process host vs one subprocess host ------------
    inproc = build_inproc_fleet(cfg, params, ecfg, n_hosts=1)
    warm(inproc)
    us_rpc_inproc = rpc_micro(inproc[0])
    wall_i, toks_i, rows_i = serve(inproc)

    sub = spawn(1)
    warm(sub)
    us_rpc_sub = rpc_micro(sub[0])
    wall_s_, toks_s, rows_s = serve(sub)
    assert toks_s == toks_i, (
        "subprocess host diverged from the in-process engine")

    n_toks = requests * gen

    def backend_cell(wall, rows, us_rpc):
        rpcs = sum(r["rpcs"] for r in rows)
        return {
            "wall_s": wall,
            "tok_s": n_toks / wall,
            "rpc_round_trip_us": us_rpc,
            "rpcs": rpcs,
            "rpcs_per_token": rpcs / n_toks,
            "rpc_wait_s": sum(r["rpc_wait_s"] for r in rows),
            "retries": sum(r["retries"] for r in rows),
            "errors": sum(r["errors"] for r in rows),
        }

    overhead = {
        "in_process": backend_cell(wall_i, rows_i, us_rpc_inproc),
        "subprocess": backend_cell(wall_s_, rows_s, us_rpc_sub),
        "bit_identical_tokens": True,
        "rpc_overhead_us": us_rpc_sub - us_rpc_inproc,
        "overhead_us_per_token": 1e6 * (wall_s_ - wall_i) / n_toks,
    }

    # --- (3): fleet throughput at 1/2/4 worker processes ----------------
    fleet_cells = []
    for n_hosts in hosts_swept:
        fleet = spawn(n_hosts)
        warm(fleet)
        wall, _, rows = serve(fleet)
        fleet_cells.append({
            "hosts": n_hosts,
            "wall_s": wall,
            "fleet_tok_s": n_toks / wall,
            "rpcs": sum(r["rpcs"] for r in rows),
            "rpc_wait_s": sum(r["rpc_wait_s"] for r in rows),
        })

    # --- (4): recovery after SIGKILL of one worker mid-decode -----------
    kill_gen = max(8 * gen, 128)
    kill_ecfg = EngineConfig(max_slots=2, max_queue=16,
                             max_seq_len=prompt_len + kill_gen)
    fleet = spawn(2, kill_ecfg)
    warm(fleet)
    router = Router(transports=fleet,
                    router_cfg=RouterConfig(handoff_threshold=0))
    reqs = [router.submit(prompts[i % requests], kill_gen,
                          session=str(i % 2), strict=True)
            for i in range(6)]
    victim = reqs[0].hosts[0]
    victim_reqs = [r for r in reqs if r.hosts[0] == victim]
    deadline = time.monotonic() + 120
    while not any(0 < len(r.tokens) < r.max_new_tokens for r in victim_reqs):
        router.step()
        assert time.monotonic() < deadline, "victim never got mid-decode"
    snap = [len(r.tokens) for r in victim_reqs]
    t_kill = time.perf_counter()
    os.kill(fleet[victim].pid, signal.SIGKILL)
    t_lost = t_first = None
    while router.has_work() and time.monotonic() < deadline:
        router.step()
        if t_lost is None and router.stats()["router"]["hosts_lost"]:
            t_lost = time.perf_counter() - t_kill
        if t_first is None and any(
                len(r.tokens) > s for r, s in zip(victim_reqs, snap)):
            t_first = time.perf_counter() - t_kill
            break
    router.run_until_complete()
    t_all = time.perf_counter() - t_kill
    r_stats = router.stats()["router"]
    recovery = {
        "kill_gen": kill_gen,
        "requests": len(reqs),
        "victim_streams": len(victim_reqs),
        "tokens_harvested_at_kill": sum(snap),
        "detect_lost_s": t_lost,
        "first_recovered_token_s": t_first,
        "drain_all_after_kill_s": t_all,
        "hosts_lost": r_stats["hosts_lost"],
        "recovered": r_stats["recovered"],
    }
    router.close()

    report = {
        "benchmark": "transport",
        "arch": cfg.name,
        "codec": default_codec(),
        "prompt_len": prompt_len,
        "gen": gen,
        "requests": requests,
        "slots_per_host": ecfg.max_slots,
        "overhead": overhead,
        "fleet": fleet_cells,
        "recovery_after_sigkill": recovery,
    }
    emit("transport_rpc_us", us_rpc_sub,
         f"inproc={us_rpc_inproc:.1f}us overhead="
         f"{overhead['rpc_overhead_us']:.1f}us codec={default_codec()}")
    for c in fleet_cells:
        emit(f"transport_h{c['hosts']}", 1e6 / max(c["fleet_tok_s"], 1e-9),
             f"fleet={c['fleet_tok_s']:.1f}tok/s rpcs={c['rpcs']}")
    emit("transport_recover_ms",
         1e3 * (t_first if t_first is not None else t_all),
         f"lost_detect={t_lost if t_lost is None else round(t_lost, 4)}s "
         f"drain_all={t_all:.2f}s")
    Path(out_path).parent.mkdir(parents=True, exist_ok=True)
    Path(out_path).write_text(json.dumps(report, indent=2) + "\n")
    print(f"# transport: RPC {us_rpc_sub:.0f}us subprocess vs "
          f"{us_rpc_inproc:.0f}us in-process; SIGKILL recovery "
          f"first-token {t_first if t_first is None else round(t_first, 3)}s, "
          f"tokens bit-identical across the process boundary")
    print(f"# wrote {out_path}")
    return report


def disagg_report(cfg, params, *, arch: str, prompt_len: int, gen: int,
                  requests: int, out_path: str, smoke: bool = True,
                  block_size: int = 8) -> dict:
    """The disaggregation claim, measured: a bimodal mix — short
    "interactive" prompts decoding while long "batch" prompts keep
    arriving — served by a two-worker-process fleet twice, without roles
    (both hosts prefill AND decode, so every batch arrival stalls
    whichever decode batch shares its host) and with the
    ``prefill:1,decode:1`` role split (admissions land on the prefill
    host; once a stream clears the handoff threshold its exact KV blocks
    ship to the decode host and decode continues there, prefill-free).
    Records the p50/p99 inter-token gap of the interactive streams in
    each mode. A shipped stream's single largest gap is the handoff
    boundary itself (the synchronous export->wire->import leg, plus the
    decode host's one-time import-scatter compile on the first ship); it
    is excluded from the gap series and reported separately as
    ``handoff_stall_ms`` — the steady-state series is what the role
    split is supposed to smooth, the one-time stall is what it costs.
    Hard asserts: tokens bit-identical to a single in-process engine in
    BOTH modes, at least one stream actually shipped, and zero prefill
    instructions dispatched on the decode host after warmup (OPQ flag
    audit). The same bit-identity + audit runs again in-process for
    int8-KV (``quantize="serve"``) over the full mix — serving
    quantization is batch-invariant (per-row activation calibration,
    models/layers.pdot), so the staggered disaggregated mix must match
    the all-at-once single engine exactly. The p99 ordering is recorded,
    not asserted — CPU wall-clock is too noisy for a hard latency gate."""
    import time

    from repro.serving.router import parse_disaggregate
    from repro.serving.transport import SubprocessTransport, build_model_spec

    # interactive prompts stay genuinely short (a chat turn), batch prompts
    # take the full --prompt-len (a document): the short side bounds the
    # ship payload (import cost on the decode host), the long side sets the
    # prefill interference the role split removes
    short_prompt = max(block_size, min(prompt_len // 4, 32))
    long_prompt = prompt_len
    n_inter = max(requests // 2, 2)
    n_batch = max(requests - n_inter, 2)
    # the canonical bimodal shape: interactive = short prompt + LONG decode,
    # batch = long prompt + SHORT decode (summarization-style). The handoff
    # threshold sits exactly at the batch budget, so interactive streams
    # (remaining >> threshold) ship to the decode host while batch streams
    # (remaining <= threshold from their first token) finish where they
    # prefilled — batch imports never stall the decode host's batch
    batch_gen = max(2, min(8, gen // 4))
    threshold = batch_gen
    # slots >= the interactive set, so a disaggregated decode host can hold
    # EVERY interactive stream at once — otherwise late ships sit decoding
    # on the prefill host, stalled by the very burst the split avoids
    ecfg = EngineConfig(max_slots=max(n_inter, 2),
                        max_queue=n_inter + n_batch + 2,
                        max_seq_len=long_prompt + gen,
                        cache_backend="paged", block_size=block_size,
                        paged_native=True)
    roles = parse_disaggregate("prefill:1,decode:1", 2)

    rng = np.random.default_rng(0)
    mix = ([("interactive",
             rng.integers(0, cfg.vocab, (short_prompt,), dtype=np.int32),
             gen) for _ in range(n_inter)]
           + [("batch",
               rng.integers(0, cfg.vocab, (long_prompt,), dtype=np.int32),
               batch_gen) for _ in range(n_batch)])

    def reference(rcfg, rparams):
        engine = Engine(rcfg, rparams, ecfg)
        reqs = [engine.submit(p, g, strict=True) for _, p, g in mix]
        engine.run_until_complete()
        toks = [list(r.tokens) for r in reqs]
        engine.close()
        return toks

    def prefill_issued(flags):
        return sum(n for f, n in flags.items()
                   if f.startswith(("prefill", "draft_prefill")))

    def serve_mix(router):
        """Interactive streams submit up front; once every one of them is
        established mid-decode (>= 2 tokens harvested — by which point a
        disaggregated fleet has shipped them to the decode host), the batch
        prompts trickle in one per fleet step, so the batch prefill burst
        lands while the interactive streams are decoding. Gaps come from
        the tokens' ENGINE-SIDE emission timestamps (RouterRequest
        .token_ts, stamped where the worker appends): a free-running
        worker's tokens reach the router in bursts, so harvest-time diffs
        would measure the router's poll cadence, not the decode host's."""
        inter = [(p, g) for k, p, g in mix if k == "interactive"]
        batch = [(p, g) for k, p, g in mix if k == "batch"]
        reqs = []
        for i, (p, g) in enumerate(inter):
            reqs.append(router.submit(p, g, session=str(i % 2),
                                      strict=True))
        bi = 0
        deadline = time.monotonic() + 600
        t0 = time.perf_counter()
        while router.has_work() or bi < len(batch):
            if bi < len(batch) and all(len(r.tokens) >= 2
                                       for r in reqs[:n_inter]):
                bp, bg = batch[bi]
                reqs.append(router.submit(bp, bg,
                                          session=str(bi % 2), strict=True))
                bi += 1
            router.step()
            assert time.monotonic() < deadline, "disagg mix never drained"
        wall = time.perf_counter() - t0
        seen = [list(r.token_ts) for r in reqs]
        shipped = [len(r.hosts) > 1 for r in reqs]
        return [list(r.tokens) for r in reqs], wall, seen, shipped

    def gap_stats(seen, shipped):
        """Interactive inter-token gaps, with each SHIPPED stream's single
        largest gap pulled out as its handoff stall (see docstring)."""
        gaps, stalls = [], []
        for ts, sh in zip(seen[:n_inter], shipped[:n_inter]):
            g = sorted(np.diff(ts))
            if sh and g:
                stalls.append(g.pop())
            gaps.extend(g)
        return gaps, stalls

    def run_fleet(with_roles):
        spec = build_model_spec(arch, smoke=smoke, seed=0)
        fleet = []
        try:
            for _ in range(2):
                fleet.append(SubprocessTransport(spec, ecfg))
            for t in fleet:
                # warm with the mix's own shapes so the cells measure
                # steady-state serving, not XLA — and so the decode host's
                # prefill-flag BASELINE includes exactly the warmup prefills.
                # The batch prompts trickle in while interactive streams
                # decode, so the width-2 fused long-prompt prefill is a
                # MID-STREAM shape in both modes: warm it too, or its
                # one-time compile lands as a fake inter-token gap
                for plens in ((short_prompt,), (short_prompt, short_prompt),
                              (long_prompt,), (long_prompt, long_prompt)):
                    eids = [t.submit(rng.integers(0, cfg.vocab, (plen,),
                                                  dtype=np.int32), 2)
                            for plen in plens]
                    warm_deadline = time.monotonic() + 300
                    for eid in eids:
                        while not t.poll({eid: 0}).get(eid, {}).get("done"):
                            assert time.monotonic() < warm_deadline, \
                                "warmup never finished"
                            time.sleep(0.005)
                    t.poll({}, drop=eids)
            # warm the ship path too: the export gather and import scatter
            # compile once per pool geometry — keep that off the clock
            wp = rng.integers(0, cfg.vocab, (short_prompt,), dtype=np.int32)
            eid = fleet[0].submit(wp, gen)
            warm_deadline = time.monotonic() + 300
            while not (fleet[0].poll({eid: 0}).get(eid) or {}).get("t"):
                assert time.monotonic() < warm_deadline, "warm ship stalled"
                time.sleep(0.002)
            entry = fleet[0].ship_blocks(eid)
            if entry is not None:           # a too-fast worker already retired
                nid = fleet[1].recv_blocks(entry)
                fleet[0].ack_ship(entry["payload_id"])
                while not fleet[1].poll({nid: 0}).get(nid, {}).get("done"):
                    assert time.monotonic() < warm_deadline, \
                        "warm ship stalled"
                    time.sleep(0.005)
                fleet[1].poll({}, drop=[nid])
            else:
                fleet[0].poll({}, drop=[eid])
            base = [prefill_issued(t.stats()["opq"]["flags"]) for t in fleet]
        except Exception:
            for t in fleet:
                t.close()
            raise
        router = Router(transports=fleet,
                        router_cfg=RouterConfig(
                            n_hosts=2, handoff_threshold=threshold,
                            roles=roles if with_roles else None))
        toks, wall, seen, shipped = serve_mix(router)
        s = router.stats()
        after = [prefill_issued(h["opq"]["flags"]) for h in s["per_host"]]
        router.close()                      # closes the worker transports
        gaps, stalls = gap_stats(seen, shipped)
        return toks, wall, gaps, stalls, s, base, after

    ref_dense = reference(cfg, params)

    def cell(wall, gaps, stalls, s):
        g = 1e3 * np.asarray(gaps)
        return {
            "wall_s": wall,
            "interactive_streams": n_inter,
            "itl_p50_ms": float(np.percentile(g, 50)),
            "itl_p99_ms": float(np.percentile(g, 99)),
            "itl_max_ms": float(g.max()),
            "handoff_stall_ms": (1e3 * float(max(stalls))
                                 if stalls else None),
            "ships": s["router"]["ships"],
            "shipped_blocks": s["router"]["shipped_blocks"],
            "ship_fallbacks": s["router"]["ship_fallbacks"],
        }

    toks_off, wall_off, gaps_off, stalls_off, s_off, _, _ = run_fleet(False)
    assert toks_off == ref_dense, (
        "role-less fleet diverged from the single engine")
    (toks_on, wall_on, gaps_on, stalls_on, s_on,
     base_on, after_on) = run_fleet(True)
    assert toks_on == ref_dense, (
        "disaggregated fleet diverged from the single engine")
    assert s_on["router"]["ships"] >= 1, "no stream ever shipped"
    decode_host = roles.index("decode")
    assert after_on[decode_host] == base_on[decode_host], (
        f"decode host dispatched "
        f"{after_on[decode_host] - base_on[decode_host]} prefill "
        "instructions during disaggregated serving")

    off_cell = cell(wall_off, gaps_off, stalls_off, s_off)
    on_cell = cell(wall_on, gaps_on, stalls_on, s_on)

    # --- int8: same split, full mix, in-process. Serving quantization is
    # batch-invariant (per-row activation calibration in models/layers.pdot:
    # a row's scale depends only on that row), so the whole staggered mix
    # must match the all-at-once single engine bit-for-bit — any divergence
    # here is the ship itself: the quantized weights' int8 path decoding
    # over shipped blocks that did not land bit-exact.
    cfg_q = cfg.replace(quantize="serve")
    params_q = tz.quantize_params(params, predicate=_quant_predicate)
    ref_q = reference(cfg_q, params_q)
    router = Router(cfg_q, params_q, ecfg,
                    RouterConfig(n_hosts=2, handoff_threshold=threshold,
                                 roles=roles))
    toks_q, _, _, _ = serve_mix(router)
    s_q = router.stats()
    q_flags = dict(s_q["per_host"][decode_host]["opq"]["flags"])
    router.close()
    assert toks_q == ref_q, (
        "int8-KV disagg diverged from the single engine")
    q_ships = s_q["router"]["ships"]
    assert q_ships >= 1, "int8-KV cell never shipped"
    assert prefill_issued(q_flags) == 0, q_flags

    report = {
        "benchmark": "disagg",
        "arch": cfg.name,
        "block_size": block_size,
        "gen": gen,
        "handoff_threshold": threshold,
        "mix": {"interactive": n_inter, "interactive_prompt": short_prompt,
                "interactive_gen": gen, "batch": n_batch,
                "batch_prompt": long_prompt, "batch_gen": batch_gen},
        "modes": {"off": off_cell, "on": on_cell},
        "itl_p99_improvement_ms": off_cell["itl_p99_ms"] - on_cell["itl_p99_ms"],
        "bit_identical": {"dense": True, "int8_kv": True},
        "decode_host_prefill_instructions": 0,
        "int8_kv": {"streams": n_inter + n_batch, "ships": q_ships,
                    "decode_host_flags": q_flags},
    }
    emit("disagg_itl_p99_off", 1e3 * off_cell["itl_p99_ms"],
         f"p50={off_cell['itl_p50_ms']:.2f}ms "
         f"max={off_cell['itl_max_ms']:.2f}ms ships=0")
    emit("disagg_itl_p99_on", 1e3 * on_cell["itl_p99_ms"],
         f"p50={on_cell['itl_p50_ms']:.2f}ms "
         f"max={on_cell['itl_max_ms']:.2f}ms ships={on_cell['ships']} "
         f"blocks={on_cell['shipped_blocks']}")
    Path(out_path).parent.mkdir(parents=True, exist_ok=True)
    Path(out_path).write_text(json.dumps(report, indent=2) + "\n")
    print(f"# disagg: interactive p99 gap {off_cell['itl_p99_ms']:.2f}ms "
          f"role-less vs {on_cell['itl_p99_ms']:.2f}ms disaggregated "
          f"({on_cell['ships']} ships, {on_cell['shipped_blocks']} blocks); "
          "tokens bit-identical (dense + int8-KV), decode host prefill-free")
    print(f"# wrote {out_path}")
    return report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--quantize", default="off", choices=["off", "serve"])
    ap.add_argument("--cache-backend", default="auto",
                    choices=["auto", "contiguous", "paged"])
    ap.add_argument("--block-size", type=int, default=8)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--paged-report", default="",
                    help="write the paged-vs-contiguous memory JSON here "
                         "and skip the throughput sweep")
    ap.add_argument("--paged-native-report", default="",
                    help="write the block-native-vs-bridge decode working "
                         "set + chunked long-prompt TTFT JSON here and skip "
                         "the throughput sweep")
    ap.add_argument("--prefill-chunk", type=int, default=16,
                    help="chunk width for the long-prompt cell of "
                         "--paged-native-report")
    ap.add_argument("--long-prompt", type=int, default=48,
                    help="long-prompt length for --paged-native-report "
                         "(must exceed --prefill-chunk)")
    ap.add_argument("--router-report", default="",
                    help="write the multi-host router JSON (scale-out sweep "
                         "+ mid-run drain/handoff, tokens asserted "
                         "bit-identical) here and skip the throughput sweep")
    ap.add_argument("--drain-at", type=int, default=3,
                    help="fleet step at which --router-report drains host 0 "
                         "in every multi-host cell")
    ap.add_argument("--prefix-report", default="",
                    help="write the shared-prefix radix-cache JSON (TTFT + "
                         "prefill chunk units dispatched at 0/50/90%% shared "
                         "traffic, tokens asserted bit-identical to "
                         "prefix-cache-off) here and skip the throughput "
                         "sweep")
    ap.add_argument("--spec-report", default="",
                    help="write the speculative-decoding JSON (tok/s + "
                         "accepted-length histogram at each --spec-k, tokens "
                         "hard-asserted bit-identical to plain greedy and "
                         "target steps per decode token < 1) here and skip "
                         "the throughput sweep")
    ap.add_argument("--spec-k", type=int, nargs="+", default=[2, 4],
                    help="spec_k values --spec-report sweeps")
    ap.add_argument("--transport-report", default="",
                    help="write the transport JSON (RPC round-trip + "
                         "per-decode-step overhead subprocess vs in-process "
                         "with tokens asserted bit-identical, fleet "
                         "throughput at 1/2/4 worker processes, recovery "
                         "time after SIGKILL of one worker mid-decode) here "
                         "and skip the throughput sweep")
    ap.add_argument("--disagg-report", default="",
                    help="write the prefill/decode disaggregation JSON "
                         "(interactive-stream p99 inter-token gap for a "
                         "bimodal mix with and without the prefill:1,"
                         "decode:1 role split over two worker processes, "
                         "tokens hard-asserted bit-identical to a single "
                         "engine for dense AND int8-KV, zero prefill "
                         "instructions on the decode host) here and skip "
                         "the throughput sweep; requires --quantize off "
                         "(the cell quantizes its own int8 copy)")
    ap.add_argument("--sampling-report", default="",
                    help="write the sampling-engine JSON (per-decode-step "
                         "sampler overhead vs greedy, seeded streams "
                         "hard-asserted bit-identical across batchmates and "
                         "backends, HTTP streaming TTFT vs the CLI loop) "
                         "here and skip the throughput sweep")
    ap.add_argument("--prefix-prompt-len", type=int, default=40,
                    help="prompt length for --prefix-report (its own flag: "
                         "the shares 0/50/90%% must land on distinct "
                         "full-block prefix lengths)")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch).smoke().replace(quantize=args.quantize)
    mesh = make_smoke_mesh(1)
    with shd.use_mesh(mesh):
        params = init_model(cfg, jax.random.PRNGKey(0))
        if args.quantize == "serve":
            params = tz.quantize_params(params, predicate=_quant_predicate)

        if args.prefix_report:
            if args.prefix_prompt_len % args.block_size:
                ap.error(f"--prefix-prompt-len {args.prefix_prompt_len} must "
                         f"be a multiple of --block-size {args.block_size} "
                         "so the 0/50/90% shares land on distinct full-block "
                         "prefix lengths")
            prefix_report(
                cfg, params, prompt_len=args.prefix_prompt_len, gen=8,
                block_size=args.block_size, requests=max(args.requests, 4),
                out_path=args.prefix_report)
            return 0

        if args.disagg_report:
            if args.quantize != "off":
                ap.error("--disagg-report runs the dense AND int8-KV cells "
                         "itself; leave --quantize off")
            disagg_report(
                cfg, params, arch=args.arch, prompt_len=args.prompt_len,
                gen=args.gen, requests=args.requests,
                block_size=args.block_size, out_path=args.disagg_report)
            return 0

        if args.transport_report:
            transport_report(
                cfg, params, arch=args.arch, prompt_len=args.prompt_len,
                gen=args.gen, requests=args.requests,
                out_path=args.transport_report)
            return 0

        if args.sampling_report:
            sampling_report(
                cfg, params, slots=2, prompt_len=args.prompt_len,
                gen=args.gen, requests=args.requests,
                out_path=args.sampling_report)
            return 0

        if args.spec_report:
            spec_report(
                cfg, params, slots=2, prompt_len=args.prompt_len,
                gen=args.gen, requests=args.requests,
                spec_ks=tuple(args.spec_k), out_path=args.spec_report)
            return 0

        if args.router_report:
            router_report(
                cfg, params, slots=2, prompt_len=args.prompt_len,
                gen=args.gen, requests=args.requests,
                drain_at=args.drain_at, out_path=args.router_report)
            return 0

        if args.paged_report:
            paged_memory_report(
                cfg, params, slots=4, prompt_len=args.prompt_len,
                gen=args.gen, block_size=args.block_size,
                out_path=args.paged_report)
            return 0

        if args.paged_native_report:
            if args.long_prompt <= args.prefill_chunk:
                ap.error(f"--long-prompt {args.long_prompt} must exceed "
                         f"--prefill-chunk {args.prefill_chunk}, or the "
                         "'chunked' cell would measure the fused path")
            long_seq = args.long_prompt + args.gen
            if (long_seq // args.prefill_chunk) * args.prefill_chunk < args.long_prompt:
                ap.error(f"--long-prompt {args.long_prompt} does not fit a "
                         f"chunk-multiple bucket within prompt+gen "
                         f"{long_seq} (chunk {args.prefill_chunk}); raise "
                         "--gen or align the prompt to the chunk width")
            paged_native_report(
                cfg, params, slots=4, prompt_len=args.prompt_len,
                gen=args.gen, block_size=args.block_size,
                chunk=args.prefill_chunk, long_prompt=args.long_prompt,
                out_path=args.paged_native_report)
            return 0

        for slots in (1, 2, 4, 8):
            # warmup compiles this slot count's executables with the sweep's
            # own shapes — same prompt_len+gen (cache/max_seq_len), the
            # all-at-once admission width (B = min(slots, requests) prefill)
            # AND the B=1 staggered-admission prefill — so the sweep cells
            # measure steady-state serving, not XLA
            run_cell(cfg, params, slots=slots, stagger=0,
                     n_requests=args.requests, prompt_len=args.prompt_len,
                     gen=args.gen, backend=args.cache_backend,
                     block_size=args.block_size)
            run_cell(cfg, params, slots=slots, stagger=1, n_requests=2,
                     prompt_len=args.prompt_len, gen=args.gen,
                     backend=args.cache_backend, block_size=args.block_size)
            for stagger in (0, 1, 4):          # all-at-once .. trickle
                s, ttft_ms, _ = run_cell(
                    cfg, params, slots=slots, stagger=stagger,
                    n_requests=args.requests, prompt_len=args.prompt_len,
                    gen=args.gen, backend=args.cache_backend,
                    block_size=args.block_size)
                tps = s["sustained_tok_s"]
                emit(f"serve_s{slots}_g{stagger}",
                     1e6 / max(tps, 1e-9),
                     f"sustained={tps:.1f}tok/s ttft={ttft_ms:.0f}ms "
                     f"occ={s['mean_occupancy']:.2f} "
                     f"backend={s['cache']['backend']}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
