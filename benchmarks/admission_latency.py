"""Admission (seeding) latency: fused prefill-with-cache vs B=1 prompt replay.

    PYTHONPATH=src python benchmarks/admission_latency.py [--smoke] \
        [--out reports/BENCH_admission.json]

Sweeps prompt length x admission batch and times how long it takes to seed a
leased slot's KV cache, two ways:

  * fused  — the engine's admission path: ONE bucketed prefill forward
    returning first-token + per-layer K/V, ONE batched donated scatter into
    the slot rows (models/serve.py prefill_with_cache + serving/kv.py
    write_slots). One dispatch per bucket, flat in prompt length.
  * replay — the PR-1 baseline, reconstructed here (it no longer exists in
    src/): replay the prompt token-by-token through the B=1 decode step and
    copy the region into the slot row. L dispatches, linear in prompt length.

Emits the CSV contract of benchmarks/common.py (name,us_per_call,derived) with
per-request seeding microseconds, and writes a JSON artifact (--out) carrying
the full sweep — the per-PR regression record for reports/BENCH_admission.json
and the CI artifact upload.
"""

from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.distributed import sharding as shd
from repro.launch.mesh import make_smoke_mesh
from repro.models import init_model
from repro.models import serve as SV
from repro.models import steps as ST
from repro.serving.engine import Engine, EngineConfig
from repro.serving.store import ContiguousKVStore

from common import emit


def _time(fn, iters: int) -> float:
    """Best-of-iters wall seconds per call (fn must block on device results).
    min, not median: seeding cost is deterministic work, so the floor is the
    signal and everything above it is scheduler noise on a shared CPU host."""
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    return min(times)


def fused_seed_cell(cfg, params, *, prompt_len: int, batch: int, max_seq: int,
                    iters: int):
    """Seed ``batch`` same-bucket requests through the engine's fused
    admission; returns (seconds per request, dispatched forwards)."""
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, (prompt_len,), dtype=np.int32)
               for _ in range(batch)]
    eng = Engine(cfg, params, EngineConfig(max_slots=batch, max_seq_len=max_seq))

    def admit_once():
        for p in prompts:
            eng.submit(p, max_new_tokens=2, strict=True)
        eng._admit()                      # one prefill + one batched write
        jax.block_until_ready(eng.kv.cache["k"])
        for slot in list(eng.scheduler.active):
            eng._retire(slot)

    admit_once()                          # warmup 1: compile this bucket shape
    admit_once()                          # warmup 2: first post-compile call
    sec = _time(admit_once, iters)        # still pays one-time warmup costs
    forwards = eng.stats()["prefill_batches"] / (iters + 2)
    eng.close()
    return sec / batch, forwards


def replay_seed_cell(cfg, params, *, prompt_len: int, batch: int, max_seq: int,
                     iters: int):
    """The deleted PR-1 seeding, reconstructed: per-request B=1 replay decode
    chain + per-slot write. Returns (seconds per request, decode dispatches
    per request)."""
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, (prompt_len,), dtype=np.int32)
               for _ in range(batch)]
    replay = jax.jit(ST.make_decode_step(cfg))
    template = SV.init_cache(cfg, 1, max_seq)
    mgr = ContiguousKVStore(cfg, n_slots=batch, max_seq_len=max_seq)

    def seed_all():
        for slot, p in enumerate(prompts):
            rc = template
            for t in p:
                _, rc = replay(params, rc,
                               {"tokens": jnp.asarray([[int(t)]], jnp.int32)})
            mgr.write_slot(slot, rc, n_valid=len(p))
        jax.block_until_ready(mgr.cache["k"])

    seed_all()                            # warmup (the B=1 decode step shape)
    seed_all()
    sec = _time(seed_all, iters)
    return sec / batch, float(prompt_len)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--kv-dtype", default="bfloat16", choices=["bfloat16", "int8"])
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sweep for CI (seconds, not minutes)")
    ap.add_argument("--iters", type=int, default=0,
                    help="timing iterations per cell (0 = auto)")
    ap.add_argument("--out", default="",
                    help="write the sweep as a JSON artifact to this path")
    args = ap.parse_args(argv)

    lengths = (8, 16) if args.smoke else (8, 16, 32, 64)
    batches = (1, 2) if args.smoke else (1, 4)
    iters = args.iters or (3 if args.smoke else 7)
    max_seq = max(lengths) + 8

    cfg = get_config(args.arch).smoke().replace(kv_cache_dtype=args.kv_dtype)
    mesh = make_smoke_mesh(1)
    cells = []
    with shd.use_mesh(mesh):
        params = init_model(cfg, jax.random.PRNGKey(0))
        for batch in batches:
            for L in lengths:
                fused_s, forwards = fused_seed_cell(
                    cfg, params, prompt_len=L, batch=batch, max_seq=max_seq,
                    iters=iters)
                replay_s, decodes = replay_seed_cell(
                    cfg, params, prompt_len=L, batch=batch, max_seq=max_seq,
                    iters=iters)
                cell = {
                    "prompt_len": L,
                    "batch": batch,
                    "fused_seed_us_per_req": 1e6 * fused_s,
                    "replay_seed_us_per_req": 1e6 * replay_s,
                    "fused_forwards_per_admission": forwards,
                    "replay_decodes_per_req": decodes,
                    "speedup": replay_s / max(fused_s, 1e-12),
                }
                cells.append(cell)
                emit(f"admission_L{L}_b{batch}_fused", 1e6 * fused_s,
                     f"1 forward/bucket speedup={cell['speedup']:.1f}x")
                emit(f"admission_L{L}_b{batch}_replay", 1e6 * replay_s,
                     f"{L} B=1 decodes/req (deleted baseline)")

    # the headline claim, checked numerically: fused per-request seeding is
    # ~flat in L while replay grows ~linearly
    by_batch = {b: [c for c in cells if c["batch"] == b] for b in batches}
    for b, cs in by_batch.items():
        lo, hi = cs[0], cs[-1]
        growth_f = hi["fused_seed_us_per_req"] / lo["fused_seed_us_per_req"]
        growth_r = hi["replay_seed_us_per_req"] / lo["replay_seed_us_per_req"]
        print(f"# batch={b}: L {lo['prompt_len']}->{hi['prompt_len']}: "
              f"fused grew {growth_f:.2f}x, replay grew {growth_r:.2f}x")

    if args.out:
        out = {
            "benchmark": "admission_latency",
            "arch": args.arch,
            "kv_cache_dtype": args.kv_dtype,
            "smoke": bool(args.smoke),
            "iters": iters,
            "max_seq_len": max_seq,
            "cells": cells,
        }
        Path(args.out).parent.mkdir(parents=True, exist_ok=True)
        Path(args.out).write_text(json.dumps(out, indent=2) + "\n")
        print(f"# wrote {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
