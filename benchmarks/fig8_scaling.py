"""Paper Fig. 8: task-queue scaling over 1..8 accelerators. The OPQ runtime
distributes independent GEMM tasks over N devices; scaling is measured in a
subprocess with N forced host devices (this process keeps its single real
device — the dry-run rule)."""

from __future__ import annotations

import json
import os
import subprocess
import sys
from pathlib import Path

from benchmarks.common import emit

_WORKER = r"""
import os, sys, json, time
n = int(sys.argv[1])
os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
import numpy as np
import jax
from repro.core import instr as I
from repro.core.opq import OPQ, Buffer

rng = np.random.default_rng(0)
TASKS, SIZE = 16, 192
bufs = [(Buffer(rng.uniform(0, 8, (SIZE, SIZE)).astype(np.float32)),
         Buffer(rng.uniform(0, 8, (SIZE, SIZE)).astype(np.float32)))
        for _ in range(TASKS)]
q = OPQ()
# warm the compile cache once per device
for a, b in bufs[:1]:
    q.invoke_operator(I.fully_connected_quant, a, b)
q.sync()
t0 = time.perf_counter()
for a, b in bufs:
    q.invoke_operator(I.fully_connected_quant, a, b)
q.sync()
dt = time.perf_counter() - t0
q.shutdown()
print(json.dumps({"n": n, "seconds": dt, "lanes": len(q.lanes)}))
"""


def run() -> None:
    root = Path(__file__).resolve().parents[1]
    env = dict(os.environ, PYTHONPATH=str(root / "src"))
    base = None
    for n in (1, 2, 4, 8):
        r = subprocess.run([sys.executable, "-c", _WORKER, str(n)],
                           capture_output=True, text=True, env=env, timeout=480)
        if r.returncode != 0:
            emit(f"fig8/devices_{n}", 0.0, f"error={r.stderr.strip()[-120:]}")
            continue
        row = json.loads(r.stdout.strip().splitlines()[-1])
        if base is None:
            base = row["seconds"]
        emit(f"fig8/devices_{n}", row["seconds"] * 1e6,
             f"speedup_vs_1dev={base / row['seconds']:.2f};lanes={row['lanes']}")


if __name__ == "__main__":
    run()
