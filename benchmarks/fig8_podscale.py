"""Fig. 8 extended: the paper's multi-accelerator GEMM scaling, re-expressed
at pod scale (256 chips) as a 2D-sharded GSPMD GEMM with the Tensorizer W8A8
path per shard. Runs in a subprocess (needs 512 forced host devices); reports
per-chip roofline terms and the compute-efficiency vs the ideal 2MNK/P split."""

from __future__ import annotations

import json
import os
import subprocess
import sys
from pathlib import Path

from benchmarks.common import emit

_WORKER = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import json, jax
from repro.distributed import sharding as shd
from repro.launch.mesh import make_production_mesh
from repro.core.distributed_gemm import dryrun_distributed_gemm

mesh = make_production_mesh()
with shd.use_mesh(mesh):
    for quantized in (False, True):
        r = dryrun_distributed_gemm(16384, 16384, 16384, quantized=quantized)
        r["quantized"] = quantized
        print(json.dumps(r))
"""


def run() -> None:
    root = Path(__file__).resolve().parents[1]
    env = dict(os.environ, PYTHONPATH=str(root / "src"))
    r = subprocess.run([sys.executable, "-c", _WORKER], capture_output=True,
                       text=True, env=env, timeout=560)
    if r.returncode != 0:
        emit("fig8pod/error", 0.0, f"err={r.stderr.strip()[-140:]}")
        return
    for line in r.stdout.strip().splitlines():
        row = json.loads(line)
        peak = 394e12 if row["quantized"] else 197e12
        t_comp = row["flops_dev"] / peak
        t_mem = row["bytes_dev"] / 819e9
        t_coll = row["collective_bytes_dev"] / 50e9
        eff = row["ideal_flops_dev"] / max(row["flops_dev"], 1e-9)
        tag = "int8" if row["quantized"] else "fp32"
        emit(f"fig8pod/gemm16k_{tag}_256chips",
             max(t_comp, t_mem, t_coll) * 1e6,
             f"t_comp={t_comp:.4f};t_mem={t_mem:.4f};t_coll={t_coll:.4f};"
             f"useful={eff:.2f}")


if __name__ == "__main__":
    run()
