"""Paper Fig. 5: GEMM lowering comparison — FullyConnected-blocked vs
conv2D-strided vs fp32 reference, across sizes. On the Edge TPU conv2D won
25x; on TPU/XLA the matmul path wins (DESIGN.md §2 inversion) — the benchmark
demonstrates the measurement that drives the selector either way."""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import gemm
from benchmarks.common import emit, time_fn

SIZES = (256, 512, 1024)


def run() -> None:
    rng = np.random.default_rng(0)
    for n in SIZES:
        a = jnp.asarray(rng.uniform(0, 8, (n, n)).astype(np.float32))
        b = jnp.asarray(rng.uniform(0, 8, (n, n)).astype(np.float32))
        exact = np.asarray(a, np.float64) @ np.asarray(b, np.float64)

        t_fp = time_fn(lambda: a @ b, iters=5)
        t_fc = time_fn(lambda: gemm.gemm_fully_connected(a, b), iters=5)
        t_cv = time_fn(lambda: gemm.gemm_conv2d(a, b), iters=5)

        for name, t, out in (
            ("fp32", t_fp, np.asarray(a @ b)),
            ("fully_connected", t_fc, np.asarray(gemm.gemm_fully_connected(a, b))),
            ("conv2d", t_cv, np.asarray(gemm.gemm_conv2d(a, b))),
        ):
            rmse = float(np.sqrt(np.mean((out - exact) ** 2))
                         / (exact.max() - exact.min()) * 100)
            emit(f"fig5/gemm_{n}_{name}", t * 1e6,
                 f"speedup_vs_fp32={t_fp / t:.3f};rmse_pct={rmse:.3f}")


if __name__ == "__main__":
    run()
