"""Paper Table 1: OPS / RPS per GPETPU instruction, re-measured on this
backend (the measure-then-rewrite methodology made live — instr_select
consumes the cached table)."""

from __future__ import annotations

from repro.core import instr_select
from benchmarks.common import emit


def run() -> None:
    table = instr_select.get_table(refresh=True)
    for name, row in sorted(table.items()):
        emit(f"table1/{name}",
             1e6 / max(row["ops_per_s"], 1e-9),
             f"rps={row['results_per_s']:.3e}")
    best = instr_select.best_gemm_lowering()
    emit("table1/best_gemm_lowering", 0.0, f"choice={best}")


if __name__ == "__main__":
    run()
