"""Paper Fig. 7: Tensorizer-calibrated GEMM vs dtype-naive int8 (the FBGEMM
strawman) as the max input value grows 2..128. The naive path saturates (RMSE
-> ~100%); the output-range-aware path stays <1% at every magnitude."""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.apps.common import rmse_pct
from repro.core import tensorizer as tz
from benchmarks.common import emit, time_fn


def run() -> None:
    rng = np.random.default_rng(0)
    n = 256
    for vmax in (2, 4, 8, 16, 32, 64, 128):
        a = rng.integers(0, vmax + 1, (n, n)).astype(np.float32)
        b = rng.integers(0, vmax + 1, (n, n)).astype(np.float32)
        ref = a.astype(np.float64) @ b.astype(np.float64)
        aj, bj = jnp.asarray(a), jnp.asarray(b)

        gptpu = np.asarray(tz.qdot_paper(aj, bj), np.float64)
        naive = np.asarray(tz.qdot_naive_int8(aj, bj), np.float64)
        t = time_fn(lambda: tz.qdot_paper(aj, bj), iters=5)
        emit(f"fig7/max_{vmax}", t * 1e6,
             f"gptpu_rmse_pct={rmse_pct(gptpu, ref):.3f};"
             f"naive_int8_rmse_pct={rmse_pct(naive, ref):.3f}")


if __name__ == "__main__":
    run()
