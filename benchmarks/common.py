"""Benchmark harness utilities: timing, CSV emission, v5e roofline constants."""

from __future__ import annotations

import time
from typing import Callable

import jax

# TPU v5e hardware constants (assignment §Roofline)
PEAK_BF16_FLOPS = 197e12          # per chip
PEAK_INT8_OPS = 394e12            # 2x bf16 (the Tensorizer fast path)
HBM_BW = 819e9                    # bytes/s per chip
ICI_BW = 50e9                     # bytes/s per link (~per direction)
CHIPS_PER_POD = 256


def time_fn(fn: Callable, *args, iters: int = 10, warmup: int = 2) -> float:
    """Median-of-iters wall time per call in seconds (host, CPU backend)."""
    for _ in range(warmup):
        out = fn(*args)
    jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def emit(name: str, us_per_call: float, derived: str) -> None:
    """The assignment's CSV contract: name,us_per_call,derived."""
    print(f"{name},{us_per_call:.2f},{derived}")
