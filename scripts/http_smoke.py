"""CI smoke for the HTTP serve API (serving/api.py): boot the server
in-process over a smoke-scale engine, then assert the three things a doc
example can't prove:

  * SSE tokens arrive INCREMENTALLY — the first streamed event lands while
    the engine is still mid-generation (checked against /v1/stats on a
    second connection), not in one burst after the request finishes
  * the streamed tokens are bit-identical to a direct Engine.submit
  * /v1/embeddings answers with the d_model-dim hidden state and a seeded
    sampled completion replays exactly

Runs on port 0 (OS-assigned), no subprocesses, exits non-zero on any
failed assertion. Usage: ``PYTHONPATH=src python scripts/http_smoke.py``.
"""

import http.client
import json
import sys

import jax
import numpy as np

from repro.configs import get_config
from repro.distributed import sharding as shd
from repro.launch.mesh import make_smoke_mesh
from repro.models import init_model
from repro.serving import Engine, EngineConfig, serve_api

GEN = 24                      # long enough that streaming visibly overlaps
                              # generation on a fast smoke model


def _request(port, method, path, body=None):
    conn = http.client.HTTPConnection("127.0.0.1", port, timeout=60)
    payload = json.dumps(body).encode() if body is not None else None
    conn.request(method, path, body=payload,
                 headers={"Content-Type": "application/json"} if payload else {})
    resp = conn.getresponse()
    data = json.loads(resp.read().decode())
    conn.close()
    return resp.status, data


def main() -> int:
    cfg = get_config("tinyllama-1.1b").smoke()
    mesh = make_smoke_mesh(1)
    with shd.use_mesh(mesh):
        params = init_model(cfg, jax.random.PRNGKey(0))
        rng = np.random.default_rng(5)
        prompt = [int(t) for t in rng.integers(0, cfg.vocab, (8,))]

        # direct-engine reference stream, computed before the server exists
        ref_eng = Engine(cfg, params,
                         EngineConfig(max_slots=2, max_seq_len=64))
        ref = ref_eng.submit(prompt, GEN, strict=True)
        ref_eng.run_until_complete()
        expected = list(ref.tokens)
        ref_eng.close()

        eng = Engine(cfg, params, EngineConfig(max_slots=2, max_seq_len=64))
        srv = serve_api(eng, port=0, mesh=mesh)
        try:
            status, body = _request(srv.port, "GET", "/healthz")
            assert status == 200 and body == {"ok": True}, body
            print(f"# serve API up on {srv.url}")

            # --- SSE stream: incremental delivery + bit-identity ---------
            conn = http.client.HTTPConnection("127.0.0.1", srv.port,
                                              timeout=60)
            conn.request("POST", "/v1/completions",
                         body=json.dumps({"prompt": prompt,
                                          "max_new_tokens": GEN,
                                          "stream": True}).encode(),
                         headers={"Content-Type": "application/json"})
            resp = conn.getresponse()
            assert resp.status == 200, resp.status
            assert resp.getheader("Content-Type") == "text/event-stream"
            toks, mid_generation = [], None
            for raw in resp.fp:
                line = raw.decode().strip()
                if not line.startswith("data: "):
                    continue
                data = line[len("data: "):]
                if data == "[DONE]":
                    break
                event = json.loads(data)
                if "token" in event:
                    toks.append(event["token"])
                    if mid_generation is None:
                        # first event just landed: is the engine still
                        # decoding? (the incremental-delivery proof)
                        _, stats = _request(srv.port, "GET", "/v1/stats")
                        mid_generation = stats["tokens_generated"] < GEN
                else:
                    assert event.get("done") and event["n_tokens"] == GEN, \
                        event
            conn.close()
            assert toks == expected, "SSE stream != direct engine stream"
            assert mid_generation, \
                "first SSE event arrived only after generation finished"
            print(f"# PASS stream: {GEN} tokens, incremental, bit-identical "
                  f"to direct submit")

            # --- seeded sampled completion replays exactly ---------------
            req = {"prompt": prompt, "max_new_tokens": 8,
                   "temperature": 0.8, "top_k": 20, "top_p": 0.95,
                   "seed": 1234}
            _, first = _request(srv.port, "POST", "/v1/completions", req)
            _, again = _request(srv.port, "POST", "/v1/completions", req)
            assert first["tokens"] == again["tokens"], (first, again)
            print(f"# PASS sampling: seeded stream replayed exactly "
                  f"({first['tokens'][:4]}...)")

            # --- embeddings endpoint -------------------------------------
            status, body = _request(srv.port, "POST", "/v1/embeddings",
                                    {"prompt": prompt})
            assert status == 200 and body["dim"] == cfg.d_model, body
            print(f"# PASS embeddings: dim={body['dim']}")
            print("# http_smoke: ALL PASS")
            return 0
        finally:
            srv.close()
            eng.close()


if __name__ == "__main__":
    sys.exit(main())
