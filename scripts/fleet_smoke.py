"""CI smoke for the multi-process fleet (serving/transport.py +
serving/host_main.py + serving/api.py): boot TWO real worker processes
behind a Router behind the HTTP serve API, drive mixed concurrent traffic,
SIGKILL one worker mid-run, and assert the fleet recovers:

  * every HTTP completion still finishes with its full token count and
    ``finish_reason: length`` — the router re-placed the dead host's
    streams as continuations from the harvested tokens
  * the router ledger records exactly one LOST host and at least one
    re-admitted continuation
  * a replay of one of the served prompts returns the identical stream —
    determinism survives the crash and the re-placement
  * both worker processes are reaped on shutdown (the SIGKILLed one too)

The full fleet stats tree is dumped as a JSON artifact (``--out``) for CI
upload. Exits non-zero on any failed assertion.

With ``--disaggregate 1:1`` the fleet runs role-split (prefill host
admits, decode host continues shipped streams) and the SIGKILL victim is
the DECODE host once at least one stream has shipped to it: the router
must recover every shipped stream by re-prefill continuation on the
surviving prefill host — same full token counts, same replayed stream —
proving the fallback path end to end under a real process death.

Usage: ``PYTHONPATH=src python scripts/fleet_smoke.py
[--disaggregate 1:1] [--out reports/fleet_smoke_stats.json]``.
"""

import argparse
import http.client
import json
import os
import signal
import sys
import threading
import time
from pathlib import Path

import numpy as np

from repro.configs import get_config
from repro.launch.mesh import make_smoke_mesh
from repro.serving import Router, RouterConfig, serve_api
from repro.serving.engine import EngineConfig
from repro.serving.router import parse_disaggregate
from repro.serving.transport import SubprocessTransport, build_model_spec

REQUESTS = 8
GEN = 128
PROMPT_LEN = 8


def _request(port, method, path, body=None, timeout=300):
    conn = http.client.HTTPConnection("127.0.0.1", port, timeout=timeout)
    payload = json.dumps(body).encode() if body is not None else None
    conn.request(method, path, body=payload,
                 headers={"Content-Type": "application/json"} if payload
                 else {})
    resp = conn.getresponse()
    data = json.loads(resp.read().decode())
    conn.close()
    return resp.status, data


def _warm(fleet):
    """One tiny request per worker so every process compiles its
    executables before traffic starts (batch invariance: warmups change no
    other stream)."""
    for t in fleet:
        eid = t.submit(np.arange(4, dtype=np.int32), 2)
        deadline = time.monotonic() + 300
        while not t.poll({eid: 0}).get(eid, {}).get("done"):
            assert time.monotonic() < deadline, "worker warmup never finished"
            time.sleep(0.01)
        t.poll({}, drop=[eid])


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="reports/fleet_smoke_stats.json",
                    help="where to dump the fleet stats JSON artifact")
    ap.add_argument("--disaggregate", default="",
                    help="role split spec (e.g. '1:1'): run prefill/decode "
                         "disaggregated and SIGKILL the DECODE host after "
                         "streams have shipped to it")
    args = ap.parse_args()
    roles = (parse_disaggregate(args.disaggregate, 2)
             if args.disaggregate else None)

    cfg = get_config("tinyllama-1.1b").smoke()
    spec = build_model_spec("tinyllama-1.1b", smoke=True, seed=0)
    # block shipping exports pool blocks, so disaggregation needs the
    # paged-native backend (same constraint serve.py enforces for
    # --disaggregate)
    paged = (dict(cache_backend="paged", paged_native=True, block_size=8)
             if roles else {})
    ecfg = EngineConfig(max_slots=2, max_queue=2 * REQUESTS,
                        max_seq_len=PROMPT_LEN + GEN, **paged)
    rng = np.random.default_rng(17)
    prompts = [[int(t) for t in rng.integers(0, cfg.vocab, (PROMPT_LEN,))]
               for _ in range(REQUESTS)]

    fleet = [SubprocessTransport(spec, ecfg) for _ in range(2)]
    victim = roles.index("decode") if roles else 0
    victim_pid = fleet[victim].pid
    print(f"# fleet up: worker pids {[t.pid for t in fleet]}"
          + (f", roles {roles}" if roles else ""))
    _warm(fleet)
    print("# workers warm (prefill/decode compiled)")

    router = Router(transports=fleet,
                    router_cfg=RouterConfig(
                        handoff_threshold=2 if roles else 0, roles=roles))
    srv = serve_api(router, port=0, mesh=make_smoke_mesh(1))
    results = [None] * REQUESTS

    def post(i):
        results[i] = _request(srv.port, "POST", "/v1/completions",
                              {"prompt": prompts[i], "max_new_tokens": GEN})

    try:
        threads = [threading.Thread(target=post, args=(i,))
                   for i in range(REQUESTS)]
        for th in threads:
            th.start()

        # kill the victim once the fleet is verifiably mid-run: some
        # tokens out, nowhere near done — and, disaggregated, only after
        # at least one stream has SHIPPED to the decode host, so the kill
        # provably lands on adopted streams
        total = REQUESTS * GEN
        deadline = time.monotonic() + 120
        while True:
            _, stats = _request(srv.port, "GET", "/v1/stats")
            done = stats["fleet"]["tokens_generated"]
            shipped = stats["router"].get("ships", 0)
            if 0 < done < total // 2 and (not roles or shipped >= 1):
                break
            assert done < total, "fleet finished before the kill landed"
            assert time.monotonic() < deadline, "fleet never got mid-run"
            time.sleep(0.005)
        os.kill(victim_pid, signal.SIGKILL)
        print(f"# SIGKILLed worker {victim_pid} at "
              f"{done}/{total} tokens generated"
              + (f", {shipped} streams shipped" if roles else ""))

        for th in threads:
            th.join(timeout=300)
        assert not any(th.is_alive() for th in threads), "HTTP requests hung"

        for i, (status, body) in enumerate(results):
            assert status == 200, f"request {i} failed: {body}"
            assert len(body["tokens"]) == GEN, (
                f"request {i}: {len(body['tokens'])} tokens != {GEN}")
            assert body["finish_reason"] == "length", body["finish_reason"]
        print(f"# PASS traffic: {REQUESTS} completions x {GEN} tokens, all "
              f"finished through the crash")

        # the serve-loop thread owns the router (api.py threading model) —
        # all stats reads go over HTTP, never router.stats() from here
        status, stats = _request(srv.port, "GET", "/v1/stats")
        assert status == 200, stats
        r = stats["router"]
        assert r["hosts_lost"] == 1, f"hosts_lost={r['hosts_lost']}"
        assert r["lost"] == [victim], f"lost={r['lost']}"
        assert r["recovered"] >= 1, f"recovered={r['recovered']}"
        if roles:
            # the decode host died holding shipped streams: they came back
            # by RE-PREFILL continuation on the surviving prefill host
            assert r["ships"] >= 1, f"ships={r['ships']}"
            print(f"# PASS disagg recovery: decode host {victim} LOST with "
                  f"{r['ships']} shipped streams, {r['recovered']} "
                  f"re-admitted by re-prefill on the prefill host")
        else:
            print(f"# PASS recovery: host {victim} LOST, {r['recovered']} "
                  f"streams re-admitted as continuations")

        # determinism survives the crash: a replay on the surviving fleet
        # returns the identical stream
        ref = results[0][1]["tokens"]
        status, replay = _request(srv.port, "POST", "/v1/completions",
                                  {"prompt": prompts[0],
                                   "max_new_tokens": GEN})
        assert status == 200, f"replay failed: {replay}"
        assert replay["tokens"] == ref, "replayed stream diverged"
        print("# PASS determinism: post-crash replay bit-identical")

        _, stats = _request(srv.port, "GET", "/v1/stats")   # final ledger
        stats["smoke"] = {
            "requests": REQUESTS, "gen": GEN,
            "disaggregate": args.disaggregate or None,
            "killed_host": victim,
            "killed_pid": victim_pid,
            "killed_at_tokens": done,
            "completions_ok": REQUESTS,
        }
        out = Path(args.out)
        out.parent.mkdir(parents=True, exist_ok=True)
        out.write_text(json.dumps(stats, indent=2, default=str) + "\n")
        print(f"# wrote {out}")
    finally:
        srv.close()
        router.close()
    assert all(t.proc.poll() is not None for t in fleet), "orphan workers"
    print("# PASS shutdown: both workers reaped")
    print("# fleet_smoke: ALL PASS")
    return 0


if __name__ == "__main__":
    sys.exit(main())
