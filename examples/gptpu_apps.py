"""Run the paper's seven applications (GPETPU §7) and print the Table-4-style
accuracy report (MAPE / RMSE, quantized GPETPU pipeline vs fp reference).

    PYTHONPATH=src python examples/gptpu_apps.py [--n 128]
"""

import argparse

from repro.apps import ALL, run_app


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=96)
    args = ap.parse_args()

    print(f"{'benchmark':<14s} {'MAPE':>8s} {'RMSE':>8s}   (paper Table 4: avg 0.33% / 0.41%)")
    mapes, rmses = [], []
    for name in sorted(ALL):
        r = run_app(name, n=args.n, quantized=True)
        mapes.append(r.mape_pct)
        rmses.append(r.rmse_pct)
        print(f"{name:<14s} {r.mape_pct:7.3f}% {r.rmse_pct:7.3f}%")
    print(f"{'average':<14s} {sum(mapes)/len(mapes):7.3f}% {sum(rmses)/len(rmses):7.3f}%")


if __name__ == "__main__":
    main()
