"""Quantized serving example: the paper's technique as the LM serving fast
path — Tensorizer W8A8 weights (half the decode-bandwidth) flowing through the
continuous-batching engine (serving/engine.py): requests arrive staggered,
join the in-flight decode batch mid-stream, and retire independently while
the OPQ runtime keeps the quantized weights device-resident (affinity).

    PYTHONPATH=src python examples/serve_quantized.py
"""

import sys

from repro.launch.serve import main as serve_main


if __name__ == "__main__":
    sys.exit(serve_main([
        "--arch", "qwen3-14b", "--smoke",
        "--quantize", "serve",
        "--requests", "4", "--prompt-len", "16", "--gen", "12",
        "--slots", "2", "--stagger-steps", "3",   # arrivals join mid-flight
    ]))
