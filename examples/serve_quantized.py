"""Quantized serving example: the paper's technique as the LM serving fast
path — Tensorizer W8A8 weights (half the decode-bandwidth), batched decode.

    PYTHONPATH=src python examples/serve_quantized.py
"""

import sys

from repro.launch.serve import main as serve_main


if __name__ == "__main__":
    sys.exit(serve_main([
        "--arch", "qwen3-14b", "--smoke",
        "--quantize", "serve",
        "--requests", "4", "--prompt-len", "16", "--gen", "12",
    ]))
