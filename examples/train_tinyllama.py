"""End-to-end training driver example: train a ~100M-class config for a few
hundred steps with checkpoint/resume (the deliverable-(b) end-to-end driver).

    PYTHONPATH=src python examples/train_tinyllama.py [--steps 300]

Uses the tinyllama-1.1b family at reduced width (CPU container); on a TPU pod
drop --smoke and raise --batch/--seq — the same driver, mesh, and sharding
rules apply (launch/train.py).
"""

import argparse
import sys

from repro.launch.train import main as train_main


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    args = ap.parse_args()
    sys.exit(train_main([
        "--arch", "tinyllama-1.1b", "--smoke",
        "--steps", str(args.steps),
        "--batch", "8", "--seq", "64",
        "--ckpt-dir", "/tmp/repro_tinyllama_ckpt",
        "--ckpt-every", "100",
        "--log-every", "20",
    ]))
