"""Quickstart: the GPETPU programming model on JAX — OpenCtpu-style task
queue, Tensorizer-quantized operators, and the tpuGemm library call.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np
import jax.numpy as jnp

from repro.core import OPQ, Buffer, tpu_gemm
from repro.core import instr as I
from repro.core import tensorizer as tz


def main():
    rng = np.random.default_rng(0)

    # ---- 1. the OpenCtpu-style task queue (paper Fig. 2) -------------------
    q = OPQ()
    a = Buffer(rng.uniform(0, 8, (256, 256)).astype(np.float32), name="a")
    b = Buffer(rng.uniform(0, 8, (256, 256)).astype(np.float32), name="b")

    def kernel(invoke, a, b):           # a TPU kernel function
        invoke(I.conv2d_quant, a, b)    # -> openctpu_invoke_operator(conv2D,...)

    def kernel2(invoke, a, b):
        invoke(I.add_quant, a, b)

    t1 = q.enqueue(kernel, a, Buffer(rng.normal(size=(3, 3)).astype(np.float32)))
    t2 = q.enqueue(kernel2, a, b)
    results = q.sync()                  # openctpu_sync()
    print(f"tasks completed: {sorted(results)}  scheduler stats: {q.stats}")
    q.shutdown()

    # ---- 2. Tensorizer: range-calibrated int8 with exact accounting -------
    x = rng.uniform(0, 8, (128, 384)).astype(np.float32)
    w = rng.uniform(-1, 1, (384, 64)).astype(np.float32)
    out_q = tz.qdot(jnp.asarray(x), jnp.asarray(w))       # W8A8, int32 accum
    out_f = x @ w
    rel = np.abs(np.asarray(out_q) - out_f).max() / np.abs(out_f).max()
    print(f"qdot W8A8 vs fp32: max rel err {rel:.4%}")

    # ---- 3. tpuGemm with lowering auto-selection (paper §7.1) --------------
    c = tpu_gemm(jnp.asarray(x), jnp.asarray(w))          # consults instr table
    rel = np.abs(np.asarray(c) - out_f).max() / np.abs(out_f).max()
    print(f"tpuGemm (auto-lowered): max rel err {rel:.4%}")


if __name__ == "__main__":
    main()
