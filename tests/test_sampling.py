"""Batch-invariant sampling invariants (serving/sampling.py + the sampled
decode/prefill paths in serving/engine.py and models/steps.py):

  * unit        — top_k=1 sampling IS greedy; temperature<=0 rows take the
                  bit-exact historical argmax; repetition penalty flips a
                  near-tie onto the unseen token; nucleus/top-k masks never
                  empty; stop_match is a pure suffix matcher
  * invariance  — the keystone: a seeded request's token stream is a pure
                  function of (seed, position) — IDENTICAL whether it decodes
                  alone, next to greedy batchmates, next to other sampled
                  requests, in a different slot, on the contiguous / paged /
                  paged-native backends, or across a mid-run router drain
                  that stitches the stream over a host handoff (asserted on
                  tokens, not distributions)
  * stops       — a 2-token stop spanning a decode-step boundary truncates
                  the stream at the match and records finish_reason="stop";
                  stops fire the same inside a prefix-cache warm hit
  * speculative — non-greedy params on a speculative engine are rejected at
                  submit with a ValueError (greedy acceptance is what keeps
                  draft-verify exact), never silently decoded greedy
  * property    — (hypothesis-or-fallback) over random seeds / temps / k / p
                  mixes: batch composition never changes a sampled row
"""

import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

sys.path.insert(0, str(Path(__file__).parent))
try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_fallback import given, settings, st

from repro.configs import get_config
from repro.models import init_model
from repro.serving import Engine, EngineConfig, SamplingParams
from repro.serving.router import Router, RouterConfig
from repro.serving.sampling import GREEDY, sample_tokens, stack_params, stop_match

CFG = get_config("tinyllama-1.1b").smoke()
RNG = np.random.default_rng(11)


@pytest.fixture(scope="module")
def params():
    return init_model(CFG, jax.random.PRNGKey(0))


def _prompts(lens, cfg=CFG):
    return [RNG.integers(0, cfg.vocab, (n,), dtype=np.int32) for n in lens]


def _stack(sps, vocab, presence_rows=()):
    presence = np.zeros((len(sps), vocab), bool)
    for i, toks in presence_rows:
        presence[i, list(toks)] = True
    return stack_params(sps, presence)


# ===========================================================================
# unit: the sampler collapses to greedy exactly where it must
# ===========================================================================

def test_top_k_one_is_greedy():
    """k=1 leaves exactly the argmax in the candidate set: the sampled token
    equals the greedy token bit for bit, for every row and any seed."""
    logits = jnp.asarray(RNG.standard_normal((4, 64)), jnp.float32)
    sp = _stack([SamplingParams(temperature=0.9, top_k=1, seed=s)
                 for s in (0, 1, 7, 123)], 64)
    toks = sample_tokens(logits, sp, jnp.arange(4, dtype=jnp.int32))
    np.testing.assert_array_equal(
        np.asarray(toks), np.asarray(jnp.argmax(logits, axis=-1)))


def test_tiny_top_p_is_greedy():
    """A nucleus too small for even one token still keeps the top token
    (the mask is clamped non-empty), so top_p -> 0 degenerates to greedy."""
    logits = jnp.asarray(RNG.standard_normal((3, 32)), jnp.float32)
    sp = _stack([SamplingParams(temperature=1.3, top_p=1e-6, seed=s)
                 for s in (3, 5, 9)], 32)
    toks = sample_tokens(logits, sp, jnp.zeros(3, jnp.int32))
    np.testing.assert_array_equal(
        np.asarray(toks), np.asarray(jnp.argmax(logits, axis=-1)))


def test_greedy_rows_bit_exact_in_mixed_batch():
    """temperature<=0 rows in a mixed batch take the plain argmax on the raw
    logits — the historical greedy path — regardless of their neighbours'
    params or their own (ignored) seed/top_k settings."""
    logits = jnp.asarray(RNG.standard_normal((4, 48)), jnp.float32)
    sp = _stack([GREEDY,
                 SamplingParams(temperature=1.0, seed=4),
                 SamplingParams(temperature=0.0, top_k=5, seed=9),
                 SamplingParams(temperature=0.7, top_p=0.8, seed=2)], 48)
    toks = np.asarray(sample_tokens(logits, sp, jnp.arange(4, dtype=jnp.int32)))
    ref = np.asarray(jnp.argmax(logits, axis=-1))
    assert toks[0] == ref[0] and toks[2] == ref[2]


def test_repetition_penalty_flips_near_tie():
    """Row 0 has seen the (slightly) top token; a strong penalty must move
    probability onto the runner-up. Row 1 has identical logits but an empty
    presence set, so it keeps the argmax. Near-greedy temperature makes both
    outcomes deterministic."""
    row = np.full(16, -5.0, np.float32)
    row[3], row[7] = 2.0, 1.9                      # 3 barely beats 7
    logits = jnp.asarray(np.stack([row, row]))
    sp = _stack([SamplingParams(temperature=0.01, repetition_penalty=5.0,
                                seed=0),
                 SamplingParams(temperature=0.01, repetition_penalty=5.0,
                                seed=0)],
                16, presence_rows=[(0, [3])])
    toks = np.asarray(sample_tokens(logits, sp, jnp.zeros(2, jnp.int32)))
    assert toks[0] == 7 and toks[1] == 3


def test_sampled_token_respects_topk_mask():
    """Whatever the gumbel draw, the emitted token must sit inside the top-k
    candidate set — over many seeds, never outside it."""
    logits = jnp.asarray(RNG.standard_normal((8, 40)), jnp.float32)
    order = np.argsort(-np.asarray(logits), axis=-1)
    sp = _stack([SamplingParams(temperature=1.5, top_k=4, seed=s)
                 for s in range(8)], 40)
    for pos in range(6):
        toks = np.asarray(sample_tokens(
            logits, sp, jnp.full(8, pos, jnp.int32)))
        for b in range(8):
            assert toks[b] in order[b, :4]


def test_stop_match_is_suffix_only():
    assert stop_match([1, 2, 3], ((2, 3),)) == (2, 3)
    assert stop_match([1, 2, 3], ((1, 2),)) is None      # not a suffix
    assert stop_match([1, 2, 3], ((9,), (3,))) == (3,)
    assert stop_match([1, 2], ((1, 2, 3),)) is None      # longer than stream
    assert stop_match([1, 2], ()) is None


def test_sampling_params_validation():
    with pytest.raises(ValueError):
        SamplingParams(temperature=-0.1)
    with pytest.raises(ValueError):
        SamplingParams(top_k=-1)
    with pytest.raises(ValueError):
        SamplingParams(top_p=0.0)
    with pytest.raises(ValueError):
        SamplingParams(top_p=1.5)
    with pytest.raises(ValueError):
        SamplingParams(repetition_penalty=0.0)
    with pytest.raises(ValueError):
        SamplingParams(stop=[[]])
    # normalization: a bare int / list-of-int stop becomes tuple-of-tuples
    assert SamplingParams(stop=5).stop == ((5,),)
    assert SamplingParams(stop=[[1, 2], 3]).stop == ((1, 2), (3,))


# ===========================================================================
# unit-level batch invariance: pure function of (seed, position)
# ===========================================================================

def test_sampled_row_ignores_batchmates_slot_and_padding():
    """The same (logits row, params, position) emits the same token whether
    the row sits alone, in slot 0 of a big batch, or in the last slot next
    to arbitrary other traffic."""
    row = jnp.asarray(RNG.standard_normal((1, 64)), jnp.float32)
    me = SamplingParams(temperature=0.9, top_k=12, top_p=0.9, seed=42)
    others = [SamplingParams(temperature=t, top_k=k, seed=s)
              for t, k, s in ((0.0, 0, 0), (1.7, 3, 9), (0.4, 0, 5))]
    for pos in (0, 3, 17):
        p = jnp.asarray([pos], jnp.int32)
        alone = int(sample_tokens(row, _stack([me], 64), p)[0])
        noise = jnp.asarray(RNG.standard_normal((3, 64)), jnp.float32)

        first = sample_tokens(jnp.concatenate([row, noise]),
                              _stack([me] + others, 64),
                              jnp.asarray([pos, 1, 2, 3], jnp.int32))
        last = sample_tokens(jnp.concatenate([noise, row]),
                             _stack(others + [me], 64),
                             jnp.asarray([5, 6, 7, pos], jnp.int32))
        assert int(first[0]) == alone == int(last[3])


@settings(max_examples=25, deadline=None)
@given(st.integers(min_value=0, max_value=2**31 - 1),
       st.floats(min_value=0.05, max_value=2.0, allow_nan=False),
       st.integers(min_value=0, max_value=48),
       st.floats(min_value=0.05, max_value=1.0, allow_nan=False),
       st.integers(min_value=0, max_value=1000))
def test_property_batch_composition_never_changes_a_row(seed, temp, k, p, pos):
    """Property sweep over the whole parameter surface: for random
    (seed, temperature, top_k, top_p, position), the sampled token is
    unchanged by batch composition and slot placement."""
    rng = np.random.default_rng(seed ^ 0x5EED)
    row = jnp.asarray(rng.standard_normal((1, 48)), jnp.float32)
    me = SamplingParams(temperature=float(temp), top_k=int(k),
                        top_p=float(p), seed=int(seed))
    pv = jnp.asarray([pos], jnp.int32)
    alone = int(sample_tokens(row, _stack([me], 48), pv)[0])
    mates = jnp.asarray(rng.standard_normal((2, 48)), jnp.float32)
    batch = sample_tokens(
        jnp.concatenate([mates[:1], row, mates[1:]]),
        _stack([GREEDY, me, SamplingParams(temperature=1.0, seed=seed + 1)],
               48),
        jnp.asarray([0, pos, 9], jnp.int32))
    assert int(batch[1]) == alone


# ===========================================================================
# engine-level invariance: across batchmates, backends, slots, drain
# ===========================================================================

SAMPLED = SamplingParams(temperature=0.8, top_k=20, top_p=0.95, seed=1234)


def _solo_stream(params, prompt, gen, **ecfg_kw):
    eng = Engine(CFG, params, EngineConfig(max_slots=2, max_seq_len=32,
                                           **ecfg_kw))
    req = eng.submit(prompt, gen, sampling=SAMPLED, strict=True)
    eng.run_until_complete()
    out = list(req.tokens)
    eng.close()
    return out


@pytest.mark.parametrize("backend_kw", [
    {},
    dict(cache_backend="paged", block_size=8),
    dict(cache_backend="paged", block_size=8, paged_native=True),
], ids=["contiguous", "paged", "paged-native"])
def test_seeded_stream_invariant_to_batchmates_and_backend(params, backend_kw):
    """The headline invariant, asserted on tokens: one seeded sampled request
    decodes alone, then again staggered next to greedy traffic, then next to
    other sampled traffic — the stream is bit-identical every time, on every
    cache backend. Batchmates, slots, and K/V layout are invisible to the
    randomness counter."""
    prompt = _prompts([6])[0]
    gen = 8
    solo = _solo_stream(params, prompt, gen, **backend_kw)
    assert solo == _solo_stream(params, prompt, gen)   # backend-invariant too

    for mate_sampling in (None, SamplingParams(temperature=1.3, seed=77)):
        eng = Engine(CFG, params,
                     EngineConfig(max_slots=2, max_seq_len=32, **backend_kw))
        mate = eng.submit(_prompts([9])[0], 10, sampling=mate_sampling,
                          strict=True)
        eng.step()                                     # mate decodes first ...
        req = eng.submit(prompt, gen, sampling=SAMPLED, strict=True)
        eng.run_until_complete()                       # ... then they share
        assert list(req.tokens) == solo
        assert len(mate.tokens) == 10
        eng.close()


def test_seeded_stream_survives_router_drain(params):
    """A sampled request preempted by drain(0) mid-generation finishes on
    host 1; the stitched stream must equal the undrained solo stream BIT FOR
    BIT — continuation prompts preserve absolute positions, so the handoff
    segment keeps drawing the same counter-derived noise."""
    prompt = _prompts([6])[0]
    gen = 10
    solo = _solo_stream(params, prompt, gen)

    router = Router(CFG, params, EngineConfig(max_slots=1, max_seq_len=32),
                    RouterConfig(n_hosts=2, handoff_threshold=0))
    rreq = router.submit(prompt, gen, session="a", sampling=SAMPLED,
                         strict=True)
    for _ in range(4):                                 # decode a few tokens
        router.step()
    live = router.progress(rreq)                       # mid-segment stream view
    assert 0 < len(live) < gen                         # genuinely mid-stream
    assert live == solo[:len(live)]                    # streaming == final prefix
    router.drain(0)
    assert router.stats()["router"]["handoffs"] == 1
    router.run_until_complete()
    assert list(rreq.tokens) == solo
    assert rreq.hosts == [0, 1]
    assert rreq.finish_reason == "length"
    router.close()


# ===========================================================================
# stop sequences: step-boundary span, prefix-cache interplay, finish_reason
# ===========================================================================

def _observe_greedy(params, prompt, gen, **ecfg_kw):
    eng = Engine(CFG, params, EngineConfig(max_slots=2, max_seq_len=32,
                                           **ecfg_kw))
    req = eng.submit(prompt, gen, strict=True)
    eng.run_until_complete()
    out = list(req.tokens)
    eng.close()
    return out


def test_stop_spanning_step_boundary_truncates(params):
    """Decode emits one token per step, so a 2-token stop taken from the
    observed stream necessarily spans a step boundary: its first token lands
    in one harvest, its second in the next. The resubmitted request must cut
    exactly at the match, with finish_reason='stop' and the stop_hits
    counter ticking."""
    prompt = _prompts([5])[0]
    full = _observe_greedy(params, prompt, 10)
    assert len(full) == 10
    stop = tuple(full[3:5])                            # spans steps 4 and 5

    eng = Engine(CFG, params, EngineConfig(max_slots=2, max_seq_len=32))
    req = eng.submit(prompt, 10, sampling=SamplingParams(stop=[stop]),
                     strict=True)
    eng.run_until_complete()
    assert list(req.tokens) == full[:5]                # truncated at the match
    assert req.finish_reason == "stop"
    assert eng.metrics.stop_hits == 1
    eng.close()


def test_stop_fires_inside_prefix_cache_hit(params):
    """Warm-hit admissions skip prefill work but must not skip stop
    semantics: the second request rides cached prefix blocks (prefix_hits
    ticks) and still truncates at its stop."""
    ecfg_kw = dict(cache_backend="paged", block_size=8, prefix_cache=True)
    prompt = _prompts([16])[0]                         # two full cached blocks
    full = _observe_greedy(params, prompt, 8, **ecfg_kw)

    eng = Engine(CFG, params, EngineConfig(max_slots=2, max_seq_len=32,
                                           **ecfg_kw))
    warm = eng.submit(prompt, 8, strict=True)          # populate the radix trie
    eng.run_until_complete()
    assert list(warm.tokens) == full
    stop = tuple(full[2:4])
    req = eng.submit(prompt, 8, sampling=SamplingParams(stop=[stop]),
                     strict=True)
    eng.run_until_complete()
    assert eng.metrics.prefix_hits >= 1                # the hit really happened
    assert list(req.tokens) == full[:4]
    assert req.finish_reason == "stop"
    eng.close()


def test_finish_reason_eos_and_length(params):
    """The non-stop finish reasons are recorded too: a hit on eos_id retires
    as 'eos', running the budget out retires as 'length'."""
    prompt = _prompts([5])[0]
    full = _observe_greedy(params, prompt, 6)
    eos = int(full[2])
    eng = Engine(CFG, params, EngineConfig(max_slots=2, max_seq_len=32,
                                           eos_id=eos))
    req = eng.submit(prompt, 6, strict=True)
    eng.run_until_complete()
    assert req.finish_reason == "eos"
    assert list(req.tokens) == full[:full.index(eos) + 1]   # first occurrence
    req2 = eng.submit(_prompts([4])[0], 4, strict=True)
    eng.run_until_complete()
    assert (req2.finish_reason == "length" if len(req2.tokens) == 4
            else req2.finish_reason == "eos")
    eng.close()


# ===========================================================================
# speculative: non-greedy is a diagnosed configuration error
# ===========================================================================

def test_speculative_rejects_non_greedy(params):
    """Draft-verify acceptance is exact only under greedy; sampled params on
    a speculative engine must raise at submit — loudly, not decode greedy."""
    eng = Engine(CFG, params,
                 EngineConfig(max_slots=2, max_seq_len=32, speculative=True,
                              spec_k=2, draft=CFG),
                 draft_params=params)
    with pytest.raises(ValueError, match="greedy-only"):
        eng.submit(_prompts([5])[0], 4, sampling=SAMPLED)
    # greedy params (and stops) remain fine on the same engine
    req = eng.submit(_prompts([5])[0], 4,
                     sampling=SamplingParams(stop=[(99999,)]), strict=True)
    eng.run_until_complete()
    assert len(req.tokens) == 4 and req.finish_reason == "length"
    eng.close()
