"""End-to-end system tests: train -> crash -> resume; quantized serving;
multi-device sharding consistency (subprocess with forced host devices);
dry-run machinery on a small arch."""

import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

pytestmark = pytest.mark.slow          # subprocess end-to-end runs (minutes)

ROOT = Path(__file__).resolve().parents[1]
ENV = dict(os.environ, PYTHONPATH=str(ROOT / "src"))


def _run(args, env=ENV, timeout=480):
    return subprocess.run([sys.executable] + args, capture_output=True,
                          text=True, env=env, timeout=timeout, cwd=ROOT)


def test_train_crash_resume(tmp_path):
    base = ["-m", "repro.launch.train", "--arch", "tinyllama-1.1b", "--smoke",
            "--steps", "8", "--batch", "2", "--seq", "16",
            "--ckpt-dir", str(tmp_path), "--ckpt-every", "3", "--log-every", "2"]
    r1 = _run(base + ["--fail-at-step", "7"])
    assert r1.returncode == 42, r1.stderr[-2000:]
    assert "SIMULATED FAILURE" in r1.stdout
    r2 = _run(base)
    assert r2.returncode == 0, r2.stderr[-2000:]
    assert "resumed from checkpoint step 6" in r2.stdout
    assert "done: 8 steps" in r2.stdout


def test_serve_quantized_end_to_end():
    r = _run(["-m", "repro.launch.serve", "--arch", "tinyllama-1.1b", "--smoke",
              "--quantize", "serve", "--requests", "2",
              "--prompt-len", "4", "--gen", "4"])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "Tensorizer W8A8" in r.stdout
    assert "decode steps" in r.stdout


def test_multi_device_sharded_training_consistent():
    """Forward/train on a (2,4) mesh must produce the same loss as 1 device —
    run in a subprocess with 8 forced host devices."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.distributed import sharding as shd
from repro.models import init_model, steps
from repro.optim import adamw_init

cfg = get_config("deepseek_moe_16b").smoke().replace(n_experts=4, topk=2)
batch = {"tokens": jnp.arange(8*16, dtype=jnp.int32).reshape(8,16) % cfg.vocab,
         "labels": (jnp.arange(8*16, dtype=jnp.int32).reshape(8,16)+1) % cfg.vocab}
losses = []
for shape, names in [((1,), ("data",)), ((2, 4), ("data", "model"))]:
    mesh = jax.make_mesh(shape, names)
    with shd.use_mesh(mesh):
        params = init_model(cfg, jax.random.PRNGKey(0))
        opt = adamw_init(params)
        ts = jax.jit(steps.make_train_step(cfg))
        _, _, m = ts(params, opt, batch, jnp.zeros((), jnp.int32))
        losses.append(float(m["loss"]))
print("LOSSES", losses)
assert abs(losses[0] - losses[1]) < 0.05, losses
print("SHARDING_CONSISTENT")
"""
    r = _run(["-c", code])
    assert r.returncode == 0, (r.stdout[-1000:], r.stderr[-3000:])
    assert "SHARDING_CONSISTENT" in r.stdout


def test_dryrun_cell_small_arch():
    """The dry-run machinery end-to-end on the smallest cell (subprocess —
    it forces 512 devices). Proves lower+compile+cost+collectives pipeline."""
    r = _run(["-m", "repro.launch.dryrun", "--arch", "xlstm-125m",
              "--shape", "decode_32k"], timeout=560)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "[dryrun] OK" in r.stdout
    rec = json.loads((ROOT / "reports" / "dryrun" /
                      "xlstm_125m_decode_32k_pod_16x16.json").read_text())
    assert rec["status"] == "ok"
    assert rec["n_devices"] == 256
    assert rec["flops"] > 0
