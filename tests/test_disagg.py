"""Prefill/decode disaggregation invariants (serving/store.py export/import,
serving/transport.py ship RPCs, serving/router.py role split) plus the
preemption-seed verification harness (tests/_seed_verify.py):

  * seed harness — every continuation point W of the pinned reference
    streams is clean (re-admitting ``prompt + tokens[:W]`` regenerates the
    exact remaining stream), so the fallback tests below cannot pass by
    luck of the cut point; a tamper self-test proves the sweep has teeth
  * bit-identity — a ``prefill:1,decode:1`` fleet serving a staggered mix
    emits streams bit-identical to a single engine serving the requests
    one at a time, for dense AND int8 serving (``quantize="serve"``, whose
    per-row activation calibration in models/layers.pdot is what makes the
    shipped continuation admission-pattern invariant)
  * role purity — zero prefill instructions dispatch on the decode host
    (OPQ flag audit): ships land as imports, never as re-prefills
  * fault injection — a dropped ship_blocks reply retries and reuses the
    SAME cached export entry (no double export/import); a corrupted payload
    is refused by checksum and the stream falls back to re-prefill,
    bit-identical — never silently corrupt; a backpressured decode host
    parks the ship and the retry lands it
  * counters — preempting/exporting a stream takes back its host's
    prefix_hits contribution and eviction takes back admissions_deferred,
    so fleet-summed counters count each logical admission once
  * conservation — property test over a two-store ship lifecycle: on BOTH
    pools, free + referenced + cached-unreferenced partitions the blocks
    after every operation, with exported-but-unacked blocks held referenced
    by the export ledger (never freed, never re-leased) until the ack
"""

import sys
from pathlib import Path

import numpy as np
import pytest

import jax

sys.path.insert(0, str(Path(__file__).parent))
try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_fallback import given, settings, st

import _seed_verify as sv

from repro.configs import get_config
from repro.core import tensorizer as tz
from repro.launch.serve import _quant_predicate
from repro.models import init_model
from repro.serving import Engine, EngineConfig, PagedKVStore, Router, RouterConfig
from repro.serving.router import parse_disaggregate
from repro.serving.transport import build_inproc_fleet

CFG = get_config("tinyllama-1.1b").smoke()
RNG = np.random.default_rng(7)
ROLES = parse_disaggregate("prefill:1,decode:1", 2)


@pytest.fixture(scope="module")
def params():
    return init_model(CFG, jax.random.PRNGKey(0))


def _pecfg(**kw):
    base = dict(max_slots=4, max_queue=16, max_seq_len=64,
                cache_backend="paged", block_size=8, paged_native=True)
    base.update(kw)
    return EngineConfig(**base)


def _prompts(lens, rng=None):
    rng = RNG if rng is None else rng
    return [rng.integers(0, CFG.vocab, (l,), dtype=np.int32) for l in lens]


def _sequential(cfg, params, prompts, gens, ecfg):
    """Reference: one engine, one request at a time."""
    eng = Engine(cfg, params, ecfg)
    outs = []
    for p, g in zip(prompts, gens):
        req = eng.submit(p, g, strict=True)
        eng.run_until_complete()
        outs.append(list(req.tokens))
    eng.close()
    return outs


def _prefill_issued(flags):
    return sum(n for f, n in flags.items()
               if f.startswith(("prefill", "draft_prefill")))


# ================================================================ seed harness

def test_continuation_sweep_all_points_clean():
    """The harness's core guarantee at smoke scale: EVERY continuation
    point of a greedy stream is clean — cutting at W and re-admitting
    ``prompt + tokens[:W]`` regenerates the exact remaining stream. This is
    the property the router's re-prefill fallback (host loss, failed ship)
    silently relies on at arbitrary, load-dependent cut points."""
    params = init_model(CFG, jax.random.PRNGKey(0))
    prompt = _prompts([6], rng=np.random.default_rng(21))[0]
    report = sv.assert_clean_continuations(
        CFG, params, prompt, 10,
        ecfg_kw=dict(max_slots=2, max_seq_len=32))
    assert report.clean == list(range(1, 10))
    assert report.ranges() == [(1, 9)]


def test_continuation_sweep_has_teeth():
    """Self-test: a tampered continuation token at one cut point must be
    flagged at exactly that W with the right first-divergence index — a
    sweep that cannot fail would verify nothing."""
    params = init_model(CFG, jax.random.PRNGKey(0))
    prompt = _prompts([6], rng=np.random.default_rng(21))[0]
    base = sv.run_stream(CFG, params, prompt, 8,
                         ecfg_kw=dict(max_slots=2, max_seq_len=32))

    def tamper(w, cont):
        return ([(cont[0] + 1) % CFG.vocab] + cont[1:]) if w == 3 else cont

    report = sv.sweep_continuations(
        CFG, params, prompt, 8, baseline=base,
        ecfg_kw=dict(max_slots=2, max_seq_len=32),
        cut_points=(2, 3, 4), _tamper=tamper)
    assert report.divergent == [(3, 3)]
    assert report.clean == [2, 4]
    assert not report.all_clean
    with pytest.raises(AssertionError, match="divergent cut points"):
        sv.assert_clean_continuations(
            CFG, params, prompt, 8, baseline=base,
            ecfg_kw=dict(max_slots=2, max_seq_len=32),
            cut_points=(3,), _tamper=tamper)


@pytest.mark.slow
def test_pinned_transport_seeds_verified():
    """The seeds tests/test_transport.py pins (21/22/13) were historically
    hand-picked so their preemption tests' particular cut points happened
    to stitch cleanly. Verify the greedy streams of those (config, seed)
    pairs through the harness at a spread of cut points — replacing the
    folklore with a sweep any future re-pin must pass."""
    big = CFG.replace(n_layers=4, d_model=256, n_heads=8, n_kv=4,
                      d_ff=1024, vocab=512, head_dim=32)
    bparams = init_model(big, jax.random.PRNGKey(0))
    for seed, plen, gen in ((21, 7, 96), (22, 6, 96), (13, 6, 96)):
        prompt = np.random.default_rng(seed).integers(
            0, big.vocab, (plen,), dtype=np.int32)
        sv.assert_clean_continuations(
            big, bparams, prompt, gen,
            ecfg_kw=dict(max_slots=2, max_seq_len=128),
            cut_points=(1, 2, 3, gen // 2, gen - 2))


# ============================================================== bit-identity

def _serve_disagg(cfg, params, prompts, gens, ecfg, *, stagger=3,
                  wrap_src=None, wrap_dst=None):
    """Serve a staggered mix on an in-process prefill:1,decode:1 fleet.
    ``wrap_src``/``wrap_dst`` optionally wrap the prefill/decode host
    transports (fault injection). Returns (tokens, router_stats,
    decode_host_flags)."""
    fleet = build_inproc_fleet(cfg, params, ecfg, 2)
    if wrap_src:
        wrap_src(fleet[ROLES.index("prefill")])
    if wrap_dst:
        wrap_dst(fleet[ROLES.index("decode")])
    router = Router(transports=fleet,
                    router_cfg=RouterConfig(handoff_threshold=2,
                                            roles=ROLES))
    reqs = []
    for i, (p, g) in enumerate(zip(prompts, gens)):
        reqs.append(router.submit(p, g, session=str(i), strict=True))
        for _ in range(stagger):
            router.step()
    router.run_until_complete()
    s = router.stats()
    flags = dict(s["per_host"][ROLES.index("decode")]["opq"]["flags"])
    router.close()
    return [list(r.tokens) for r in reqs], s["router"], flags


def test_disagg_dense_bit_identical_and_prefill_free(params):
    """Role-split serving is unobservable in the tokens: the staggered
    disaggregated mix equals one-at-a-time single-engine serving exactly,
    with every long stream shipped and ZERO prefill instructions on the
    decode host — continuation by block import, never by re-prefill."""
    prompts = _prompts([12, 24, 9, 17])
    gens = [24, 16, 24, 16]
    ecfg = _pecfg()
    want = _sequential(CFG, params, prompts, gens, ecfg)
    toks, rstats, flags = _serve_disagg(CFG, params, prompts, gens, ecfg)
    assert toks == want
    assert rstats["ships"] >= 1 and rstats["ship_fallbacks"] == 0
    assert _prefill_issued(flags) == 0, flags


def test_disagg_int8_bit_identical(params):
    """The same mix under serving quantization: regression for the per-row
    activation calibration in models/layers.pdot — with per-TENSOR scales a
    slot's numerics shifted with its decode batchmates, so the disaggregated
    (differently-batched) continuation diverged from the single engine.
    Per-row scales make the whole staggered, shipped mix bit-identical."""
    cfg_q = CFG.replace(quantize="serve")
    params_q = tz.quantize_params(params, predicate=_quant_predicate)
    prompts = _prompts([12, 24, 9])
    gens = [20, 14, 20]
    ecfg = _pecfg()
    want = _sequential(cfg_q, params_q, prompts, gens, ecfg)
    toks, rstats, flags = _serve_disagg(cfg_q, params_q, prompts, gens, ecfg)
    assert toks == want
    assert rstats["ships"] >= 1
    assert _prefill_issued(flags) == 0, flags


# ============================================================ fault injection

def test_ship_rpc_idempotent_no_double_import(params):
    """Transport-level ship semantics under retry: a re-called ship_blocks
    returns the SAME cached entry; a re-delivered recv_blocks of that entry
    dedups on the payload id and returns the SAME local request id (one
    import, not two); a re-sent ack_ship is a no-op. This is what makes the
    whole trio safe for the channel's idempotent-retry policy."""
    ecfg = _pecfg(max_slots=2, max_seq_len=32)
    fleet = build_inproc_fleet(CFG, params, ecfg, 2)
    src, dst = fleet
    eid = src.submit(_prompts([10])[0], 12)
    while not (src.poll({eid: 0}).get(eid) or {}).get("t"):
        src.pump()
    entry = src.ship_blocks(eid)
    assert entry is not None
    again = src.ship_blocks(eid)
    assert again is entry                       # cached, not re-exported
    nid = dst.recv_blocks(entry)
    assert nid is not None
    assert dst.recv_blocks(entry) == nid        # dedup on payload id
    assert dst.engine.metrics.imported_slots == 1
    assert src.ack_ship(entry["payload_id"]) is True
    assert src.ack_ship(entry["payload_id"]) is False     # idempotent
    while dst.has_work():
        dst.pump()
    assert dst.poll({nid: 0})[nid].get("done")
    for t in fleet:
        t.close()


def test_corrupt_ship_payload_falls_back_bit_identically(params):
    """Bit-flip every shipped payload in flight: the importer's checksum
    refuses it (ValueError, slot unwound) and the router falls back to
    re-prefill continuation on the prefill host. The streams still finish
    bit-identical to the single engine — a broken wire can cost latency,
    never correctness, and corruption is never silent."""
    prompts = _prompts([12, 9])
    gens = [20, 20]
    ecfg = _pecfg()
    want = _sequential(CFG, params, prompts, gens, ecfg)

    def corrupt(t):
        orig = t.ship_blocks

        def bad_ship(req_id):
            entry = orig(req_id)
            if entry is not None:
                name = sorted(entry["payload"]["leaves"])[0]
                leaf = np.array(entry["payload"]["leaves"][name], copy=True)
                flat = leaf.reshape(-1).view(np.uint8)
                flat[0] ^= 0xFF
                entry["payload"]["leaves"][name] = leaf
            return entry

        t.ship_blocks = bad_ship

    toks, rstats, flags = _serve_disagg(CFG, params, prompts, gens, ecfg,
                                        wrap_src=corrupt)
    assert toks == want
    assert rstats["ship_fallbacks"] >= 1 and rstats["ships"] == 0
    # the fallback re-prefills on the PREFILL host: the decode host stays
    # prefill-free even on the failure path
    assert _prefill_issued(flags) == 0, flags


def test_backpressured_ship_parks_and_retries(params):
    """A decode host that transiently refuses imports (slot/lease race —
    recv_blocks returns None) parks the ship; the router retries it and the
    stream lands by import, not fallback, still bit-identical."""
    prompts = _prompts([12, 9])
    gens = [20, 20]
    ecfg = _pecfg()
    want = _sequential(CFG, params, prompts, gens, ecfg)

    def flaky(t):
        orig = t.recv_blocks
        state = {"refusals": 3}

        def refusing(entry):
            if state["refusals"] > 0:
                state["refusals"] -= 1
                return None
            return orig(entry)

        t.recv_blocks = refusing

    toks, rstats, flags = _serve_disagg(CFG, params, prompts, gens, ecfg,
                                        wrap_dst=flaky)
    assert toks == want
    assert rstats["ships"] >= 1 and rstats["ship_fallbacks"] == 0
    assert _prefill_issued(flags) == 0, flags


# ================================================================== counters

def test_preempt_and_evict_reconcile_admission_counters(params):
    """Regression for the double-count: a preempted (or exported) stream's
    prefix_hits contribution leaves with it, and an evicted queued request
    takes its admissions_deferred mark along — whichever host re-admits
    counts afresh, so fleet sums count one logical admission once. A stream
    that COMPLETES keeps its host's counts."""
    # 6 usable blocks: one 16+16-token stream leases 4, so a second one's
    # admission must defer on the lease even with a slot free
    ecfg = _pecfg(max_slots=2, max_seq_len=32, n_blocks=7,
                  prefix_cache=True)
    eng = Engine(CFG, params, ecfg)
    prompt = _prompts([16])[0]
    # cold run commits the prefix; the rerun's lease walks the trie
    r0 = eng.submit(prompt, 4, strict=True)
    eng.run_until_complete()
    assert r0.done and eng.metrics.prefix_hits == 0
    r1 = eng.submit(prompt, 8, strict=True)
    eng.step()
    assert eng.metrics.prefix_hits == 1
    eng.preempt(r1.id)
    assert eng.metrics.prefix_hits == 0          # contribution unwound
    # same via the export path
    r2 = eng.submit(prompt, 8, strict=True)
    eng.step()
    assert eng.metrics.prefix_hits == 1
    _, payload = eng.extract_seeded(r2.id)
    assert eng.metrics.prefix_hits == 0
    eng.release_exported(payload["payload_id"])

    # deferral reconciliation: exhaust the pool so admission defers, then
    # evict the queued request — the deferral leaves with it
    big = _prompts([16])[0]
    ra = eng.submit(big, 16, strict=True)
    rb = eng.submit(big[::-1].copy(), 16, strict=True)
    deadline = 200
    while eng.metrics.admissions_deferred == 0 and deadline:
        eng.step()
        deadline -= 1
    assert eng.metrics.admissions_deferred == 1
    evicted = eng.evict_queued()
    assert [r.id for r in evicted] == [rb.id]
    assert eng.metrics.admissions_deferred == 0  # mark left with the request
    eng.run_until_complete()
    assert ra.done
    eng.close()


# =============================================================== conservation

def _census_ok(store: PagedKVStore):
    """Free / referenced / cached-unreferenced partition the pool, and the
    refcounts reconcile with slot leases PLUS the export ledger — an
    exported-but-unacked block is referenced (unfreed, unreusable)."""
    from collections import Counter
    c = store.debug_block_census()
    everything = c["free"] + c["referenced"] + c["cached_unreferenced"]
    assert len(everything) == len(set(everything)), c
    assert sorted(everything) == list(range(1, store.n_blocks)), c
    holds = Counter(b for bs in store._leased.values() for b in bs)
    holds.update(b for bs in store._exported.values() for b in bs)
    assert sorted(holds) == c["referenced"]
    for b, n in holds.items():
        assert store._ref[b] == n, (b, n, store._ref[b])


@settings(deadline=None, max_examples=10)
@given(st.integers(min_value=0, max_value=1 << 30))
def test_block_conservation_across_ship_lifecycle(seed):
    """Random lease/commit/export/import/ack/fallback/retire traffic over
    TWO small pools (the shipping pair): after EVERY operation both pools
    partition exactly into free / referenced / cached-unreferenced, blocks
    on the export ledger stay referenced until the ack (in-flight ships are
    never freed or re-leased — the store's fresh-lease assert arms that),
    and a released payload frees exactly the blocks nothing else holds."""
    rng = np.random.default_rng(seed)
    cfg = get_config("tinyllama-1.1b").smoke()
    mk = lambda: PagedKVStore(cfg, n_slots=3, max_seq_len=16, block_size=4,
                              n_blocks=12, prefix_cache=True)
    A, B = mk(), mk()
    in_flight = []                 # exported from A, not yet imported/acked
    imported = []                  # payload ids imported into B, unacked
    pid_counter = [0]
    for _ in range(80):
        op = int(rng.integers(0, 6))
        if op == 0:                              # lease on A (maybe commit)
            slot = int(rng.integers(0, 3))
            if slot not in A._leased:
                plen = int(rng.integers(1, 13))
                gen = int(rng.integers(1, 17 - plen))
                tokens = rng.integers(0, 3, (plen,), dtype=np.int32)
                if A.lease(slot, plen, gen, tokens=tokens) and \
                        int(rng.integers(0, 2)):
                    A.commit_prefix(slot)
        elif op == 1:                            # export a leased A slot
            leased = sorted(set(A._leased))
            if leased:
                slot = int(rng.choice(leased))
                # stamp a valid length so the payload carries real blocks
                # (bounded by the lease, as any real decode position is)
                cap = len(A._leased[slot]) * 4
                n_valid = int(rng.integers(0, cap + 1))
                A.cache = dict(A.cache,
                               index=A.cache["index"].at[slot].set(n_valid))
                pid_counter[0] += 1
                pid = f"p{pid_counter[0]}"
                in_flight.append((pid, A.export_blocks(slot,
                                                       payload_id=pid)))
        elif op == 2 and in_flight:              # import into B
            pid, payload = in_flight.pop(int(rng.integers(len(in_flight))))
            free = [s for s in range(3) if s not in B._leased]
            if free and B.lease(free[0], 8, 8):
                try:
                    B.import_blocks(free[0], payload)
                    imported.append(pid)
                except ValueError:
                    B.reset(free[0])
                    A.release_exported(pid)      # corrupt: fall back
            else:
                in_flight.append((pid, payload))  # refused: park
        elif op == 3:                            # ack an imported ship
            if imported:
                assert A.release_exported(imported.pop()) is True
        elif op == 4 and in_flight:              # fallback without import
            pid, _ = in_flight.pop(int(rng.integers(len(in_flight))))
            assert A.release_exported(pid) is True
        else:                                    # retire someone somewhere
            store = A if int(rng.integers(0, 2)) else B
            leased = sorted(set(store._leased))
            if leased:
                store.reset(int(rng.choice(leased)))
        _census_ok(A)
        _census_ok(B)
        # double-ack is always a no-op
        assert A.release_exported("nonexistent") is False
    # settle everything: acks for all in-flight ships, resets everywhere
    for pid, _ in in_flight:
        assert A.release_exported(pid) is True
    for pid in imported:
        A.release_exported(pid)
    for store in (A, B):
        for slot in sorted(set(store._leased)):
            store.reset(slot)
        _census_ok(store)
        c = store.debug_block_census()
        assert c["referenced"] == []             # nothing leaks at the end
