"""Multi-host router invariants (serving/router.py):

  * bit-identity  — staggered multi-host serving (affinity placement, spills,
                    AND a mid-run drain/handoff) produces exactly the tokens
                    of single-engine sequential serving, for dense, int8-KV,
                    and MoE cache formats
  * drain/handoff — drain() re-places queued requests, hands off long
                    in-flight generations through the continuation path
                    (prompt + tokens so far, the fused prefill-with-cache
                    seeding), finishes short tails in place, and the host
                    reports is_drained once empty; undrain() restores it
  * affinity      — same-session requests pin to the host holding their
                    blocks, counted the way OPQ counts per-lane affinity
                    (placed/affinity_hits); first-seen keys go least-loaded
  * spill         — a pinned host with a dry paged pool sheds the request to
                    the least-loaded host (counted) instead of queueing the
                    fleet behind the backpressure
  * drain hooks   — Engine.evict_queued / preempt / would_accept /
                    lease_headroom operate at step boundaries and never
                    touch in-flight slots they shouldn't
  * stats         — the three-level stats() surface (router ledger, fleet
                    sums, per-host engine stats incl. per-lane OPQ counters)
"""

import numpy as np
import pytest

import jax

from repro.configs import get_config
from repro.models import init_model
from repro.serving import (
    Engine, EngineConfig, QueueFull, RequestState, Router, RouterConfig,
    format_router_stats,
)

CFG = get_config("tinyllama-1.1b").smoke()
MOE_CFG = get_config("moonshot-v1-16b-a3b").smoke()
RNG = np.random.default_rng(11)


@pytest.fixture(scope="module")
def params():
    return init_model(CFG, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def moe_params():
    return init_model(MOE_CFG, jax.random.PRNGKey(1))


def _prompts(lens, cfg=CFG):
    return [RNG.integers(0, cfg.vocab, (l,), dtype=np.int32) for l in lens]


def _sequential(params, prompts, gens, cfg=CFG, **ecfg_kw):
    """Reference: one engine, one request at a time, drained in between."""
    kw = dict(max_slots=2, max_seq_len=32)
    kw.update(ecfg_kw)
    eng = Engine(cfg, params, EngineConfig(**kw))
    outs = []
    for p, g in zip(prompts, gens):
        req = eng.submit(p, g)
        eng.run_until_complete()
        outs.append(list(req.tokens))
    eng.close()
    return outs


def _fleet_staggered(params, prompts, gens, cfg=CFG, *, n_hosts=2,
                     drain_at=None, handoff_threshold=0, sessions=None,
                     **ecfg_kw):
    """Mixed multi-host traffic: staggered arrivals, optional mid-run drain
    of host 0. Returns (token streams, router stats, request objects)."""
    router = Router(cfg, params,
                    EngineConfig(max_slots=2, max_seq_len=32, **ecfg_kw),
                    RouterConfig(n_hosts=n_hosts,
                                 handoff_threshold=handoff_threshold))
    reqs = []
    step = 0
    for i, (p, g) in enumerate(zip(prompts, gens)):
        sess = sessions[i] if sessions else str(i % n_hosts)
        reqs.append(router.submit(p, g, session=sess, strict=True))
        router.step()
        step += 1
        if drain_at is not None and step == drain_at:
            router.drain(0)
    router.run_until_complete()
    outs = [list(r.tokens) for r in reqs]
    stats = router.stats()
    router.close()
    return outs, stats, reqs


@pytest.mark.parametrize("family,kv_dtype", [
    ("dense", "bfloat16"), ("dense", "int8"), ("moe", "bfloat16"),
])
def test_multi_host_bit_identical_to_sequential(params, moe_params, family,
                                                kv_dtype):
    """The headline router invariant: requests spread across hosts by
    affinity/load — including a mid-run drain() that hands host 0's
    in-flight generations off to host 1 — produce exactly the tokens each
    request would produce alone on a single engine, for the float, int8-KV,
    and MoE cache formats."""
    base, p = (CFG, params) if family == "dense" else (MOE_CFG, moe_params)
    cfg = base.replace(kv_cache_dtype=kv_dtype)
    prompts = _prompts([5, 9, 4, 7], cfg=cfg)
    gens = [8, 6, 8, 5]
    sequential = _sequential(p, prompts, gens, cfg=cfg)

    plain, s_plain, _ = _fleet_staggered(p, prompts, gens, cfg=cfg)
    assert plain == sequential                    # bit-identical, not allclose

    drained, s_drain, reqs = _fleet_staggered(p, prompts, gens, cfg=cfg,
                                              drain_at=2)
    assert drained == sequential                  # ... across drain/handoff
    assert s_drain["router"]["handoffs"] >= 1     # the drain really handed off
    assert any(len(r.hosts) > 1 for r in reqs)


def test_drain_handoff_stitches_streams_and_empties_host(params):
    """drain() mechanics, step by step: host 0's in-flight request hands off
    mid-generation (slot retired, preempted counted), its queued request
    re-places, the stitched stream is exactly the undrained one, and the
    host reports is_drained once empty — then undrain() returns it to the
    placement pool."""
    prompts = _prompts([6, 5, 4])
    gens = [10, 8, 6]
    sequential = _sequential(params, prompts, gens)

    router = Router(CFG, params, EngineConfig(max_slots=1, max_seq_len=32),
                    RouterConfig(n_hosts=2, handoff_threshold=0))
    # host 0: one decoding + one queued behind the single slot
    r0 = router.submit(prompts[0], gens[0], session="a")
    router.step()
    router.step()
    r1 = router.submit(prompts[1], gens[1], session="a")   # affinity: host 0
    r2 = router.submit(prompts[2], gens[2], session="b")   # least-loaded: 1
    assert [r.hosts[0] for r in (r0, r1, r2)] == [0, 0, 1]
    eng0 = router.engines[0]
    assert eng0.scheduler.n_active == 1 and eng0.scheduler.queue_depth == 1

    router.drain(0)
    # the in-flight request was preempted with >= 1 token standing, the
    # queued one was evicted and re-placed — host 0 holds nothing
    assert eng0.metrics.preempted == 1 and eng0.metrics.evicted == 1
    assert not eng0.has_work() and router.is_drained(0)
    s = router.stats()["router"]
    assert s["handoffs"] == 1 and s["requeued"] == 1 and s["drains"] == 1
    assert len(r0.tokens) >= 1 and not r0.done    # segment 1 stands, not done

    router.run_until_complete()
    assert [list(r.tokens) for r in (r0, r1, r2)] == sequential
    assert r0.hosts == [0, 1]                     # the handoff trail
    assert r1.hosts == [0, 1]                     # evicted -> re-placed

    # elastic restart: undrain returns the host to the placement pool
    router.undrain(0)
    r3 = router.submit(_prompts([4])[0], 4)
    assert r3.hosts == [0]                        # least-loaded again
    router.run_until_complete()
    assert len(r3.tokens) == 4
    router.close()


def test_drain_short_tail_finishes_in_place(params):
    """handoff_threshold: a request with at most that many tokens left rides
    out the drain on the draining engine (a continuation prefill isn't worth
    a few tail tokens) — and still finishes bit-identically."""
    prompts = _prompts([6])
    gens = [4]
    sequential = _sequential(params, prompts, gens)
    router = Router(CFG, params, EngineConfig(max_slots=2, max_seq_len=32),
                    RouterConfig(n_hosts=2, handoff_threshold=8))
    r = router.submit(prompts[0], gens[0])
    router.step()                                 # 1 token in, 3 < 8 remain
    router.drain(0)
    assert router.stats()["router"]["handoffs"] == 0
    assert router.engines[0].has_work()           # finishing in place
    router.run_until_complete()
    assert [list(r.tokens)] == sequential
    assert r.hosts == [0]
    assert router.is_drained(0)
    router.close()


def test_affinity_pins_sessions_and_counts_like_opq(params):
    """Same-session requests pin to the host that served the session last;
    hits are ledgered the way OPQ ledgers lane affinity (placed /
    affinity_hits). Distinct fresh sessions spread by load."""
    prompts = _prompts([4, 4, 4, 4])
    router = Router(CFG, params, EngineConfig(max_slots=4, max_seq_len=32),
                    RouterConfig(n_hosts=2))
    ra = router.submit(prompts[0], 4, session="a")     # fresh: least-loaded
    rb = router.submit(prompts[1], 4, session="b")     # fresh: the other host
    ra2 = router.submit(prompts[2], 4, session="a")    # pin: a's host
    rb2 = router.submit(prompts[3], 4, session="b")    # pin: b's host
    assert ra.hosts != rb.hosts                        # load spread the fleet
    assert ra2.hosts == ra.hosts and rb2.hosts == rb.hosts
    s = router.stats()["router"]
    assert s["placed"] == 4 and s["affinity_hits"] == 2 and s["spills"] == 0
    # no session: identical prompts hash to the same affinity key (rh1's
    # key is fresh — only rh2's placement is a hit)
    rh1 = router.submit(prompts[0], 4)
    rh2 = router.submit(prompts[0], 4)
    assert rh1.hosts == rh2.hosts
    assert router.stats()["router"]["affinity_hits"] == 3
    router.run_until_complete()
    router.close()


def test_spill_on_dry_pinned_pool(params):
    """Load-aware spill: the pinned host's paged pool is fully leased, so the
    next same-session request sheds to the least-loaded host (spill counted,
    pin moves with the blocks) instead of queueing behind the dry pool —
    and the fleet decodes both concurrently."""
    # pool: 2 usable blocks of 8 = exactly one 8+8 request per host
    ecfg = EngineConfig(max_slots=2, max_seq_len=16, cache_backend="paged",
                        block_size=8, n_blocks=3)
    router = Router(CFG, params, ecfg, RouterConfig(n_hosts=2))
    p = _prompts([8, 8])
    r0 = router.submit(p[0], 8, session="a")
    router.step()                                  # host 0's pool: dry
    assert not router.engines[r0.hosts[0]].lease_headroom(8, 8)
    r1 = router.submit(p[1], 8, session="a")       # pinned to a dry host
    s = router.stats()["router"]
    assert r1.hosts[0] != r0.hosts[0]              # spilled off the pin
    assert s["spills"] == 1 and s["affinity_hits"] == 0
    router.step()
    # both decode concurrently — nobody waited for host 0's retire
    assert all(e.scheduler.n_active == 1 for e in router.engines)
    router.run_until_complete()
    assert [list(r0.tokens), list(r1.tokens)] == _sequential(
        params, p, [8, 8], cache_backend="paged", block_size=8, n_blocks=3,
        max_seq_len=16, max_slots=2)
    router.close()


def test_router_rejects_when_no_host_accepts(params):
    """The fleet door: a request no host can serve bounces (None, QueueFull
    when strict), counted on the router ledger, and draining every host
    closes the door entirely."""
    router = Router(CFG, params, EngineConfig(max_slots=2, max_seq_len=16),
                    RouterConfig(n_hosts=2))
    assert router.submit(_prompts([8])[0], 20) is None     # over every budget
    with pytest.raises(QueueFull):
        router.submit(_prompts([8])[0], 20, strict=True)
    ok = router.submit(_prompts([8])[0], 4)
    assert ok is not None
    router.drain(0)
    router.drain(1)                                # whole fleet draining
    assert router.submit(_prompts([4])[0], 4) is None
    assert router.stats()["router"]["rejected"] == 3
    router.run_until_complete()
    assert len(ok.tokens) == 4
    router.close()


def test_drain_tolerates_direct_engine_submits(params):
    """Requests submitted to an engine directly (bypassing the router) are
    not router-placed; drain() must not crash on them — queued ones go back
    to that engine's own queue (same Request object, so the caller's handle
    completes) and in-flight ones finish in place."""
    router = Router(CFG, params, EngineConfig(max_slots=1, max_seq_len=32),
                    RouterConfig(n_hosts=2, handoff_threshold=0))
    eng0 = router.engines[0]
    d_active = eng0.submit(_prompts([5])[0], 6)    # direct: will hold the slot
    router.step()
    d_queued = eng0.submit(_prompts([4])[0], 4)    # direct: waits behind it
    router.drain(0)                                # must not raise
    assert router.stats()["router"]["handoffs"] == 0
    assert eng0.scheduler.queue_depth == 1         # re-enqueued, not dropped
    router.run_until_complete()
    assert d_active.done and d_queued.done
    assert len(d_active.tokens) == 6 and len(d_queued.tokens) == 4
    router.close()


def test_router_config_validation(params):
    with pytest.raises(ValueError, match="n_hosts"):
        Router(CFG, params, router_cfg=RouterConfig(n_hosts=0))
    with pytest.raises(ValueError, match="handoff_threshold"):
        Router(CFG, params,
               router_cfg=RouterConfig(n_hosts=1, handoff_threshold=-1))
    router = Router(CFG, params, EngineConfig(max_slots=1, max_seq_len=16),
                    RouterConfig(n_hosts=1))
    with pytest.raises(ValueError, match="no host"):
        router.drain(5)
    router.close()


def test_engine_drain_hooks(params):
    """The Engine-level hooks the router composes: would_accept mirrors
    submit's door without side effects, evict_queued empties only the FIFO,
    preempt retires only the named request's slot and scrubs its rows."""
    eng = Engine(CFG, params, EngineConfig(max_slots=1, max_seq_len=32))
    assert eng.would_accept(4, 4)
    assert not eng.would_accept(4, 40)            # over the seq budget
    assert not eng.would_accept(0, 4)
    assert eng.lease_headroom(4, 4)               # contiguous: always now

    r_active = eng.submit(_prompts([5])[0], 8)
    eng.step()                                    # r_active holds the slot
    r_q1 = eng.submit(_prompts([4])[0], 4)
    r_q2 = eng.submit(_prompts([6])[0], 4)
    evicted = eng.evict_queued()
    assert evicted == [r_q1, r_q2]                # FIFO order preserved
    assert all(r.state == RequestState.PREEMPTED for r in evicted)
    assert eng.scheduler.queue_depth == 0
    assert eng.scheduler.n_active == 1            # in-flight untouched

    tokens_before = list(r_active.tokens)
    preempted = eng.preempt(r_active.id)
    assert preempted is r_active
    assert preempted.tokens == tokens_before      # tokens stand
    assert eng.scheduler.n_active == 0
    assert eng.store.slot_index(0) == 0           # slot scrubbed
    assert eng.metrics.preempted == 1 and eng.metrics.evicted == 2
    with pytest.raises(KeyError):
        eng.preempt(r_active.id)                  # no longer in flight
    eng.close()


def test_paged_available_now_tracks_occupancy(params):
    """available_now (the spill signal) is occupancy-aware where fits is
    total-capacity-aware: a fully-leased pool still fits the request class
    but cannot lease it now; a retire flips it back."""
    from repro.serving import make_store
    store = make_store(CFG, 2, 16, backend="paged", block_size=8, n_blocks=3)
    assert store.fits(8, 8) and store.available_now(8, 8)
    assert store.lease(0, 8, 8)
    assert store.fits(8, 8)                       # still servable in principle
    assert not store.available_now(8, 8)          # but not right now
    store.reset(0)
    assert store.available_now(8, 8)


def test_router_stats_three_levels(params):
    """stats() carries the placement ledger (OPQ-shaped), fleet sums that
    reconcile with per-host engine counters, and each host's own stats
    (per-lane OPQ affinity included); format_router_stats renders it."""
    prompts = _prompts([5, 9, 4, 7])
    gens = [6, 5, 8, 3]
    _, s, _ = _fleet_staggered(params, prompts, gens, drain_at=2)
    assert s["router"]["hosts"] == 2 and s["router"]["draining"] == [0]
    assert s["router"]["placed"] == 4
    assert s["router"]["completed"] == 4
    assert len(s["per_host"]) == 2
    for key in ("completed", "tokens_generated", "decode_steps",
                "preempted", "evicted"):
        assert s["fleet"][key] == sum(h[key] for h in s["per_host"])
    assert s["fleet"]["tokens_generated"] == sum(gens)
    # every host dispatched through its own OPQ runtime
    assert all(h["opq"]["issued"] > 0 for h in s["per_host"]
               if h["decode_steps"] > 0)
    line = format_router_stats(s)
    assert "2 hosts" in line and "affinity" in line and "handoffs" in line
