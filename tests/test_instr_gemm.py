"""GPETPU instruction set semantics + GEMM lowerings (paper §5, §7.1)."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import gemm, instr as I
from repro.core import tensorizer as tz

RNG = np.random.default_rng(7)


class TestInstructions:
    def test_fp_semantics(self):
        a = RNG.normal(size=(16, 16)).astype(np.float32)
        b = RNG.normal(size=(16, 16)).astype(np.float32)
        np.testing.assert_allclose(I.invoke(I.Instr.ADD, a, b, quantized=False), a + b, rtol=1e-6)
        np.testing.assert_allclose(I.invoke(I.Instr.SUB, a, b, quantized=False), a - b, rtol=1e-6)
        np.testing.assert_allclose(I.invoke(I.Instr.MUL, a, b, quantized=False), a * b, rtol=1e-6)
        np.testing.assert_allclose(I.invoke(I.Instr.MEAN, a, quantized=False), a.mean(), rtol=1e-5)
        np.testing.assert_allclose(I.invoke(I.Instr.MAX, a, quantized=False), a.max(), rtol=1e-6)

    def test_quant_close_to_fp(self):
        a = RNG.uniform(0, 8, (32, 32)).astype(np.float32)
        b = RNG.uniform(0, 8, (32, 32)).astype(np.float32)
        for op, ref in [(I.Instr.ADD, a + b), (I.Instr.SUB, a - b), (I.Instr.MUL, a * b)]:
            out = np.asarray(I.invoke(op, a, b, quantized=True))
            scale = np.abs(ref).max() + 1e-9
            assert np.abs(out - ref).max() / scale < 0.03, op

    def test_matrixwise_quant(self):
        a = RNG.uniform(-2, 2, (100, 70)).astype(np.float32)
        assert abs(float(I.mean_quant(jnp.asarray(a))) - a.mean()) < 0.05
        assert abs(float(I.max_quant(jnp.asarray(a))) - a.max()) < 0.05

    def test_conv2d_quant(self):
        x = RNG.uniform(-2, 2, (64, 64)).astype(np.float32)
        k = RNG.normal(size=(3, 3)).astype(np.float32)
        out = np.asarray(I.conv2d_quant(jnp.asarray(x), jnp.asarray(k)))
        ref = np.asarray(I.conv2d_fp(jnp.asarray(x), jnp.asarray(k)))
        assert np.abs(out - ref).max() / (np.abs(ref).max() + 1e-9) < 0.05

    def test_crop_ext(self):
        x = RNG.normal(size=(10, 13)).astype(np.float32)
        padded = I.invoke(I.Instr.EXT, x, quantized=False)
        assert padded.shape == (128, 128)
        back = I.invoke(I.Instr.CROP, padded, 10, 13, quantized=False)
        np.testing.assert_array_equal(np.asarray(back), x)


class TestGemmLowerings:
    @pytest.mark.parametrize("M,K,N", [(64, 64, 64), (100, 70, 90), (129, 257, 65)])
    def test_conv2d_lowering_fp_exact(self, M, K, N):
        """The conv2D-strided GEMM (paper §7.1.2) is EXACTLY GEMM in fp."""
        a = RNG.normal(size=(M, K)).astype(np.float32)
        b = RNG.normal(size=(K, N)).astype(np.float32)
        out = np.asarray(gemm.gemm_conv2d(jnp.asarray(a), jnp.asarray(b), quantized=False))
        np.testing.assert_allclose(out, a @ b, rtol=1e-4, atol=1e-4)

    @pytest.mark.parametrize("M,K,N", [(64, 64, 64), (100, 70, 90)])
    def test_lowerings_agree(self, M, K, N):
        a = RNG.uniform(0, 4, (M, K)).astype(np.float32)
        b = RNG.uniform(0, 4, (K, N)).astype(np.float32)
        exact = a.astype(np.float64) @ b.astype(np.float64)
        rel = lambda o: np.abs(o - exact).max() / np.abs(exact).max()
        fc = np.asarray(gemm.gemm_fully_connected(jnp.asarray(a), jnp.asarray(b)))
        cv = np.asarray(gemm.gemm_conv2d(jnp.asarray(a), jnp.asarray(b)))
        assert rel(fc) < 0.02 and rel(cv) < 0.02

    def test_kernel_path_matches_einsum_path(self):
        a = RNG.uniform(-2, 2, (100, 70)).astype(np.float32)
        b = RNG.uniform(-2, 2, (70, 90)).astype(np.float32)
        k = np.asarray(gemm.gemm_fully_connected(jnp.asarray(a), jnp.asarray(b), use_kernel=True))
        e = np.asarray(gemm.gemm_fully_connected(jnp.asarray(a), jnp.asarray(b), use_kernel=False))
        np.testing.assert_allclose(k, e, rtol=2e-3, atol=2e-3)

    def test_tpu_gemm_auto_lowering(self):
        a = RNG.uniform(0, 4, (64, 64)).astype(np.float32)
        b = RNG.uniform(0, 4, (64, 64)).astype(np.float32)
        out = np.asarray(gemm.tpu_gemm(jnp.asarray(a), jnp.asarray(b)))
        exact = a @ b
        assert np.abs(out - exact).max() / np.abs(exact).max() < 0.02
