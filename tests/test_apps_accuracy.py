"""Paper Table 4 reproduction: all seven applications under the quantized
GPETPU pipeline keep MAPE at the ~1% level (paper: avg 0.33%, max 0.89%)."""

import pytest

from repro.apps import ALL, run_app

# per-app MAPE ceilings (%): paper Table 4 + small slack for our data choices
LIMITS = {
    "gemm": 1.0,
    "pagerank": 1.0,
    "hotspot3d": 1.0,
    "lud": 0.5,
    "gaussian": 0.01,        # exact (integer-snap path, paper: 0.00%)
    "backprop": 0.5,
    "blackscholes": 2.0,     # deep-OTM tail; RMSE limit below is the tight one
}

RMSE_LIMITS = {name: 1.0 for name in LIMITS}


@pytest.mark.parametrize("name", sorted(LIMITS))
def test_app_accuracy(name):
    r = run_app(name, n=64, quantized=True)
    assert r.mape_pct <= LIMITS[name], f"{name} MAPE {r.mape_pct:.3f}%"
    assert r.rmse_pct <= RMSE_LIMITS[name], f"{name} RMSE {r.rmse_pct:.3f}%"


def test_fp_paths_are_exact():
    for name in ("gemm", "pagerank", "gaussian"):
        r = run_app(name, n=48, quantized=False)
        assert r.mape_pct < 0.05, f"{name} fp path MAPE {r.mape_pct:.3f}%"
