"""Doc-drift guards: documentation that CI keeps true.

  * flag drift  — every argparse flag on the serve CLI
                  (launch/serve.py build_parser) has a row in the
                  docs/serving.md flag-reference table, and every row there
                  names a real flag — a flag added without docs (or docs for
                  a deleted flag) fails, so the operator guide cannot
                  silently rot
  * link rot    — every relative markdown link in README.md, ROADMAP.md,
                  and docs/*.md resolves to a real file in the repo
  * docs exist  — the tree the README points operators at is actually there
"""

import re
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
DOCS = REPO / "docs"
SERVING_MD = DOCS / "serving.md"


def _parser_flags():
    from repro.launch.serve import build_parser
    flags = set()
    for action in build_parser()._actions:
        for opt in action.option_strings:
            if opt.startswith("--") and opt != "--help":
                flags.add(opt)
    return flags


def _documented_flags():
    """Flags named in the serving.md flag-reference table — rows shaped
    ``| `--flag` | default | ... |``. Prose mentions elsewhere (e.g. of
    benchmark-script flags) are deliberately not rows."""
    flags = set()
    for line in SERVING_MD.read_text().splitlines():
        m = re.match(r"\|\s*`(--[a-z][a-z0-9-]*)`\s*\|", line)
        if m:
            flags.add(m.group(1))
    return flags


def test_docs_tree_exists():
    for name in ("architecture.md", "serving.md", "benchmarks.md"):
        assert (DOCS / name).is_file(), f"docs/{name} is missing"


def test_every_serve_flag_is_documented():
    missing = _parser_flags() - _documented_flags()
    assert not missing, (
        f"serve CLI flags without a docs/serving.md flag-reference row: "
        f"{sorted(missing)} — add a table row for each")


def test_every_documented_flag_exists():
    stale = _documented_flags() - _parser_flags()
    assert not stale, (
        f"docs/serving.md documents flags the serve CLI no longer has: "
        f"{sorted(stale)} — drop the rows or restore the flags")


def test_flag_table_parses_nonempty():
    """Teeth for the extractor itself: an empty parse would make both drift
    checks vacuously green."""
    assert len(_documented_flags()) >= 10


_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def _md_files():
    files = [REPO / "README.md", REPO / "ROADMAP.md"]
    files += sorted(DOCS.glob("*.md"))
    return files


def test_relative_markdown_links_resolve():
    broken = []
    for md in _md_files():
        for target in _LINK.findall(md.read_text()):
            if re.match(r"[a-z]+://", target) or target.startswith(
                    ("#", "mailto:")):
                continue
            path = (md.parent / target.split("#")[0]).resolve()
            if not path.exists():
                broken.append(f"{md.relative_to(REPO)} -> {target}")
    assert not broken, f"broken relative links: {broken}"
