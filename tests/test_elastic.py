"""Elastic restart end-to-end: checkpoint on one mesh, reload + resume on a
DIFFERENT device count (the node-failure recovery path, DESIGN.md §7)."""

import os
import subprocess
import sys
from pathlib import Path

import pytest

pytestmark = pytest.mark.slow          # 8-device subprocess restart (minutes)

ROOT = Path(__file__).resolve().parents[1]
ENV = dict(os.environ, PYTHONPATH=str(ROOT / "src"))

_WORKER = r"""
import os, sys
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint import load_checkpoint, save_checkpoint, latest_step
from repro.configs import get_config
from repro.distributed import sharding as shd
from repro.ft import plan_elastic_mesh
from repro.models import init_model, steps, param_specs
from repro.optim import adamw_init

ckpt = sys.argv[1]
cfg = get_config("tinyllama_1_1b").smoke()
batch = {"tokens": jnp.arange(8*16, dtype=jnp.int32).reshape(8,16) % cfg.vocab,
         "labels": (jnp.arange(8*16, dtype=jnp.int32).reshape(8,16)+1) % cfg.vocab}

# ---- phase 1: train 2 steps on a (4, 2) mesh, checkpoint ----
mesh1 = jax.make_mesh((4, 2), ("data", "model"))
with shd.use_mesh(mesh1):
    params = init_model(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    ts = jax.jit(steps.make_train_step(cfg))
    for s in range(2):
        params, opt, m = ts(params, opt, batch, jnp.asarray(s + 5, jnp.int32))
    save_checkpoint(ckpt, 2, {"params": params, "opt": opt})
    loss_before = float(m["loss"])

# ---- phase 2: "2 hosts died" -> elastic plan -> resume on (2, 2) mesh ----
plan = plan_elastic_mesh(n_surviving_hosts=1, chips_per_host=4,
                         model_parallel=2, old_data_parallel=4, global_batch=8)
assert plan["mesh_shape"] == (2, 2), plan
mesh2 = jax.make_mesh(plan["mesh_shape"], plan["axis_names"])
with shd.use_mesh(mesh2):
    like_p = jax.eval_shape(lambda k: init_model(cfg, k), jax.random.PRNGKey(0))
    like_o = jax.eval_shape(adamw_init, like_p)
    state = load_checkpoint(ckpt, 2, {"params": like_p, "opt": like_o})
    params2, opt2 = state["params"], state["opt"]
    ts2 = jax.jit(steps.make_train_step(cfg))
    params2, opt2, m2 = ts2(params2, opt2, batch, jnp.asarray(7, jnp.int32))
    print("RESUMED_LOSS", float(m2["loss"]))
    assert np.isfinite(float(m2["loss"]))
print("ELASTIC_OK grad_accum=%d" % plan["grad_accum"])
"""


def test_elastic_restart_across_meshes(tmp_path):
    r = subprocess.run([sys.executable, "-c", _WORKER, str(tmp_path / "ck")],
                       capture_output=True, text=True, env=ENV, timeout=480,
                       cwd=ROOT)
    assert r.returncode == 0, (r.stdout[-800:], r.stderr[-2500:])
    assert "ELASTIC_OK" in r.stdout
