"""Preemption-seed verification harness.

The transport/router test suite pins its reference streams to specific RNG
seeds (historically 21/22/13 in tests/test_transport.py), hand-picked so
that a re-prefill CONTINUATION — re-admitting ``prompt + harvested_tokens``
with the remaining budget, exactly what the router does after a drain, a
host loss, or a failed block ship — happens to be bit-identical to the
uninterrupted stream. That identity is NOT guaranteed in general: prefilling
the first W generated tokens computes their cache entries through the fused
prefill path, whose reduction shapes differ from decode's, so a stream can
diverge at SOME continuation points W and not others. A seed that survives
the particular W a test happens to cut at proves nothing about the next W.

This module replaces the hand-pinned convention with an exhaustive check:
``sweep_continuations`` cuts one stream at EVERY continuation point and
reports the clean/divergent W ranges, and ``assert_clean_continuations``
turns that into a test-time guarantee. ROADMAP requires any new preemption
mechanism to re-verify its seeds through this harness — the disaggregation
fallback tests (tests/test_disagg.py) consume it, and the pinned seeds in
tests/test_transport.py are documented against its output.

Run standalone for a report:

    PYTHONPATH=src python tests/_seed_verify.py --seed 21 --prompt-len 6 --gen 48
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass
class SweepReport:
    """Outcome of sweeping every continuation point of one stream."""

    baseline: List[int]                 # the uninterrupted stream's tokens
    clean: List[int]                    # W values whose stitch is bit-equal
    divergent: List[Tuple[int, int]]    # (W, first differing token index)

    @property
    def all_clean(self) -> bool:
        return not self.divergent

    def ranges(self) -> List[Tuple[int, int]]:
        """Clean W values compressed to inclusive (lo, hi) runs."""
        runs: List[Tuple[int, int]] = []
        for w in self.clean:
            if runs and w == runs[-1][1] + 1:
                runs[-1] = (runs[-1][0], w)
            else:
                runs.append((w, w))
        return runs

    def summary(self) -> str:
        runs = ", ".join(f"{lo}-{hi}" if lo != hi else str(lo)
                         for lo, hi in self.ranges())
        bad = ", ".join(f"W={w}@tok{i}" for w, i in self.divergent[:8])
        more = f" (+{len(self.divergent) - 8} more)" \
            if len(self.divergent) > 8 else ""
        return (f"clean W: [{runs or 'none'}]"
                + (f"; divergent: {bad}{more}" if self.divergent else ""))


def _default_engine_factory(cfg, params, ecfg_kw):
    from repro.serving import Engine, EngineConfig
    return Engine(cfg, params, EngineConfig(**ecfg_kw))


def run_stream(cfg, params, prompt, gen, *, sampling=None,
               ecfg_kw=None, engine_factory=None) -> List[int]:
    """One uninterrupted stream on a fresh engine — the baseline."""
    factory = engine_factory or _default_engine_factory
    eng = factory(cfg, params, dict(ecfg_kw or {}))
    req = eng.submit(np.asarray(prompt, np.int32), gen, sampling=sampling,
                     strict=True)
    eng.run_until_complete()
    tokens = list(req.tokens)
    eng.close()
    return tokens


def sweep_continuations(
    cfg, params, prompt, gen, *,
    sampling=None,
    ecfg_kw: Optional[dict] = None,
    cut_points: Optional[Sequence[int]] = None,
    engine_factory: Optional[Callable] = None,
    baseline: Optional[Sequence[int]] = None,
    _tamper: Optional[Callable[[int, List[int]], List[int]]] = None,
) -> SweepReport:
    """Cut one greedy stream at every continuation point W and re-admit it
    as ``prompt + baseline[:W]`` with budget ``gen - W`` on a FRESH engine —
    the router's re-prefill continuation, reproduced at engine level. A cut
    is *clean* when the stitched stream equals the baseline bit-for-bit.

    ``cut_points`` restricts the sweep (default: every W in 1..gen-1).
    ``_tamper(W, continuation_tokens)`` is the harness's own self-test hook
    (tests/test_disagg.py uses it to prove the sweep has teeth); real
    callers never pass it.
    """
    prompt = np.asarray(prompt, np.int32).reshape(-1)
    base = (list(baseline) if baseline is not None
            else run_stream(cfg, params, prompt, gen, sampling=sampling,
                            ecfg_kw=ecfg_kw, engine_factory=engine_factory))
    if len(base) != gen:
        raise ValueError(
            f"baseline stopped early ({len(base)} of {gen} tokens) — "
            "sweep continuation points would be ill-defined; raise "
            "max_seq_len or drop stop conditions")
    factory = engine_factory or _default_engine_factory
    ws = list(cut_points) if cut_points is not None else range(1, gen)
    clean: List[int] = []
    divergent: List[Tuple[int, int]] = []
    for w in ws:
        if not 1 <= w < gen:
            raise ValueError(f"cut point W={w} outside 1..{gen - 1}")
        eng = factory(cfg, params, dict(ecfg_kw or {}))
        cont_prompt = np.concatenate([prompt, np.asarray(base[:w], np.int32)])
        req = eng.submit(cont_prompt, gen - w, sampling=sampling, strict=True)
        eng.run_until_complete()
        cont = list(req.tokens)
        eng.close()
        if _tamper is not None:
            cont = _tamper(w, cont)
        stitched = base[:w] + cont
        if stitched == base:
            clean.append(w)
        else:
            first_bad = next(i for i, (x, y) in enumerate(zip(stitched, base))
                             if x != y)
            divergent.append((w, first_bad))
    return SweepReport(baseline=base, clean=clean, divergent=divergent)


def assert_clean_continuations(cfg, params, prompt, gen, **kw) -> SweepReport:
    """Assert every swept continuation point is clean; returns the report."""
    report = sweep_continuations(cfg, params, prompt, gen, **kw)
    assert report.all_clean, (
        f"continuation-seed sweep found divergent cut points: "
        f"{report.summary()} — this (config, seed, prompt) pair is not safe "
        "to pin as a preemption/fallback reference")
    return report


def main(argv=None) -> int:
    import argparse

    import jax

    from repro.configs import get_config
    from repro.distributed import sharding as shd
    from repro.models import init_model

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--seed", type=int, default=21,
                    help="prompt RNG seed to verify (the pinned value)")
    ap.add_argument("--prompt-len", type=int, default=6)
    ap.add_argument("--gen", type=int, default=48)
    ap.add_argument("--max-seq-len", type=int, default=128)
    ap.add_argument("--big", action="store_true",
                    help="use the transport tests' BIG geometry "
                         "(4 layers, d_model 256) instead of plain smoke")
    args = ap.parse_args(argv)

    shd.set_mesh(jax.make_mesh((1,), ("data",)))
    cfg = get_config(args.arch).smoke()
    if args.big:
        cfg = cfg.replace(n_layers=4, d_model=256, n_heads=8, n_kv=4,
                          d_ff=1024, vocab=512, head_dim=32)
    params = init_model(cfg, jax.random.PRNGKey(0))
    prompt = np.random.default_rng(args.seed).integers(
        0, cfg.vocab, (args.prompt_len,), dtype=np.int32)
    report = sweep_continuations(
        cfg, params, prompt, args.gen,
        ecfg_kw=dict(max_slots=2, max_seq_len=args.max_seq_len))
    print(f"seed={args.seed} prompt_len={args.prompt_len} gen={args.gen}: "
          f"{report.summary()}")
    return 0 if report.all_clean else 1


if __name__ == "__main__":
    raise SystemExit(main())
