"""Shared-prefix radix cache invariants (serving/store.py PagedKVStore with
``prefix_cache=True`` + the engine's suffix-only admission):

  * bit-identity — a prefix-HIT admission produces tokens AND cache bits
                   bit-identical to a cold admission, for dense, int8-KV,
                   and MoE configs (the repo's signature guarantee extended:
                   skipping a cached prefix's prefill must be unobservable)
  * COW          — a prompt diverging MID-block gets a copy-on-write fork of
                   the divergence block; decode writes land in the fork and
                   the cached original re-serves later hits bit-intact
  * teeth        — the refcount-aware scrub is load-bearing: replaying the
                   pre-fix retire (scrub EVERY leased block) detectably
                   corrupts a block another slot still references, and the
                   bit-identity assertion catches it
  * conservation — property test (hypothesis, or the numpy fallback shim)
                   driving random lease/commit/retire/drain sequences:
                   free + referenced + cached-unreferenced partitions the
                   pool at every step — no leak, no double-own, no
                   double-free, no fresh lease of an owned block
  * router       — a drain() handoff of a prefix-sharing session across
                   prefix-cache engines stitches the exact token stream
"""

import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

sys.path.insert(0, str(Path(__file__).parent))
try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_fallback import given, settings, st

from repro.configs import get_config
from repro.models import init_model
from repro.serving import Engine, EngineConfig, PagedKVStore
from repro.serving import store as store_mod
from repro.serving.router import Router, RouterConfig

CFG = get_config("tinyllama-1.1b").smoke()
MOE_CFG = get_config("moonshot-v1-16b-a3b").smoke()
RNG = np.random.default_rng(11)


@pytest.fixture(scope="module")
def params():
    return init_model(CFG, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def moe_params():
    return init_model(MOE_CFG, jax.random.PRNGKey(1))


def _ecfg(prefix: bool, **kw):
    base = dict(max_slots=2, max_seq_len=32, cache_backend="paged",
                block_size=8, prefix_cache=prefix)
    base.update(kw)
    return EngineConfig(**base)


def _serve_snapshot(eng, prompt, gen):
    """Submit one request, run a single engine step (admit + one decode),
    snapshot its slot's contiguous cache view, then drain. Returns
    (tokens, {leaf: row bits})."""
    req = eng.submit(prompt, gen, strict=True)
    eng.step()
    slot = next(s for s, r in eng.scheduler.active.items() if r.id == req.id)
    view = eng.store.gather_view()
    row = {n: np.asarray(leaf[slot] if n == "index" else leaf[:, slot])
           for n, leaf in view.items()}
    eng.run_until_complete()
    return list(req.tokens), row


@pytest.mark.parametrize("family,kv_dtype", [
    ("dense", "bfloat16"), ("dense", "int8"), ("moe", "bfloat16"),
])
def test_prefix_hit_bit_identical_to_cold(family, kv_dtype, params,
                                          moe_params):
    """The load-bearing invariant: admissions that lease cached prefix
    blocks (skipping their prefill) emit the same first token, the same
    decode stream, AND the same cache bits as a cold admission of the same
    prompt — for float-KV, int8-per-token-scale, and MoE cache formats."""
    base = MOE_CFG if family == "moe" else CFG
    cfg = base.replace(kv_cache_dtype=kv_dtype)
    p = moe_params if family == "moe" else params
    preamble = RNG.integers(0, cfg.vocab, (16,), dtype=np.int32)
    prompts = [
        np.concatenate([preamble,
                        RNG.integers(0, cfg.vocab, (8,), dtype=np.int32)])
        for _ in range(3)]

    hot = Engine(cfg, p, _ecfg(True))
    cold = Engine(cfg, p, _ecfg(False))
    for i, prompt in enumerate(prompts):
        toks_h, row_h = _serve_snapshot(hot, prompt, 5)
        toks_c, row_c = _serve_snapshot(cold, prompt, 5)
        assert toks_h == toks_c                   # bit-identical, not allclose
        for name in row_c:
            np.testing.assert_array_equal(row_h[name], row_c[name])
    s = hot.stats()
    # request 0 walked an empty trie; 1 and 2 leased its cached preamble
    assert s["prefix_hits"] == 2
    assert s["prefix_blocks_reused"] == 4         # 2 hits x 2 preamble blocks
    assert s["cache"]["prefix_hits"] == 2
    hot.close()
    cold.close()


def test_cow_fork_mid_block_preserves_cached_original(params):
    """A prompt that diverges MID-block forks the divergence block before
    its slot writes into it (decode lands at position 20 inside the fork):
    the forked request's stream is bit-identical to cold, and the cached
    original block still serves a later full-match hit bit-intact."""
    A = RNG.integers(0, CFG.vocab, (24,), dtype=np.int32)     # 3 full blocks
    B = A[:20].copy()                 # 2 full blocks + 4-token tail of block 2

    hot = Engine(CFG, params, _ecfg(True))
    cold = Engine(CFG, params, _ecfg(False))
    for prompt in (A, B, A):          # cold fill, mid-block fork, re-hit
        toks_h, row_h = _serve_snapshot(hot, prompt, 5)
        toks_c, row_c = _serve_snapshot(cold, prompt, 5)
        assert toks_h == toks_c
        for name in row_c:
            np.testing.assert_array_equal(row_h[name], row_c[name])
    st_ = hot.stats()["cache"]
    assert st_["cow_forks"] == 1                  # B forked A's block 2
    assert st_["prefix_hits"] == 2                # B (fork) + A's re-serve
    # the re-served A matched all 3 of its full blocks — the fork never
    # contaminated the cached original
    assert st_["prefix_blocks_reused"] == 2 + 3
    hot.close()
    cold.close()


def test_buggy_scrub_of_shared_block_is_caught(params):
    """Teeth for the refcount-aware scrub: with requests A and B in flight
    sharing cached prefix blocks, retiring A the PRE-FIX way (scrub every
    block on A's lease list) detectably corrupts B's view — proving the
    bit-identity assertions would catch that bug — while the real
    refcount-aware reset leaves B's bits untouched."""
    preamble = RNG.integers(0, CFG.vocab, (16,), dtype=np.int32)
    pa = np.concatenate([preamble,
                         RNG.integers(0, CFG.vocab, (8,), dtype=np.int32)])
    pb = np.concatenate([preamble,
                         RNG.integers(0, CFG.vocab, (8,), dtype=np.int32)])

    def spin_up():
        eng = Engine(CFG, params, _ecfg(True))
        ra = eng.submit(pa, 8, strict=True)
        eng.step()                    # A admitted cold; its blocks cached
        rb = eng.submit(pb, 8, strict=True)
        eng.step()                    # B admitted as a hit: preamble shared
        slot_b = next(s for s, r in eng.scheduler.active.items()
                      if r.id == rb.id)
        row_b = {n: np.asarray(leaf[:, slot_b])
                 for n, leaf in eng.store.gather_view().items()
                 if n != "index"}
        return eng, ra, rb, slot_b, row_b

    # the CORRECT retire: A's shared blocks survive (refcount held by B)
    eng, ra, rb, slot_b, before = spin_up()
    assert eng.stats()["prefix_hits"] == 1
    eng.preempt(ra.id)                # retire A -> store.reset(slot_a)
    after = {n: np.asarray(leaf[:, slot_b])
             for n, leaf in eng.store.gather_view().items() if n != "index"}
    for name in before:
        np.testing.assert_array_equal(before[name], after[name])
    eng.close()

    # the BUGGY retire (pre-fix behavior): scrub EVERY block on A's lease
    # list, shared or not — B's shared prefix positions turn pristine, and
    # the exact assertion the suite leans on flags it
    eng, ra, rb, slot_b, before = spin_up()
    slot_a = next(s for s, r in eng.scheduler.active.items()
                  if r.id == ra.id)
    blocks_a = list(eng.store._leased[slot_a])
    padded = blocks_a + [0] * (eng.store.blocks_per_slot - len(blocks_a))
    eng.store.cache = store_mod._paged_reset(
        eng.store.cache, jnp.asarray(padded, jnp.int32), jnp.int32(slot_a))
    after = {n: np.asarray(leaf[:, slot_b])
             for n, leaf in eng.store.gather_view().items() if n != "index"}
    with pytest.raises(AssertionError):
        for name in before:
            np.testing.assert_array_equal(before[name], after[name])
    eng.close()


def test_prefix_sharing_session_survives_router_drain(params):
    """Drain handoff across prefix-cache engines: a session whose prompts
    share a hot prefix is preempted mid-generation by drain(0) and finishes
    on another host — the stitched stream must equal an undrained serve."""
    ecfg = EngineConfig(max_slots=1, max_seq_len=32, cache_backend="paged",
                        block_size=8, prefix_cache=True)
    preamble = RNG.integers(0, CFG.vocab, (16,), dtype=np.int32)
    prompt = np.concatenate([preamble,
                             RNG.integers(0, CFG.vocab, (4,), dtype=np.int32)])

    ref = Engine(CFG, params, ecfg)
    warm = ref.submit(preamble, 4, strict=True)   # seeds the trie
    ref.run_until_complete()
    r0 = ref.submit(prompt, 10, strict=True)
    ref.run_until_complete()
    assert ref.stats()["prefix_hits"] >= 1
    ref.close()

    router = Router(CFG, params, ecfg, RouterConfig(n_hosts=2,
                                                    handoff_threshold=0))
    router.submit(preamble, 4, session="a", strict=True)
    while router.has_work():
        router.step()
    r = router.submit(prompt, 10, session="a", strict=True)
    for _ in range(3):
        router.step()
    router.drain(r.hosts[0])                      # preempt mid-generation
    while router.has_work():
        router.step()
    assert router.stats()["router"]["handoffs"] >= 1
    assert len(r.hosts) > 1
    assert r.tokens == list(r0.tokens)            # bit-identical stitched
    router.close()


# ===========================================================================
# block-conservation property test
# ===========================================================================

def _census_ok(store: PagedKVStore):
    c = store.debug_block_census()
    everything = c["free"] + c["referenced"] + c["cached_unreferenced"]
    # partition: disjoint (no block owned twice) and complete (no leak)
    assert len(everything) == len(set(everything)), c
    assert sorted(everything) == list(range(1, store.n_blocks)), c
    # referenced counts must reconcile with the lease lists
    from collections import Counter
    leases = Counter(b for bs in store._leased.values() for b in bs)
    assert sorted(leases) == c["referenced"]
    for b, n in leases.items():
        assert store._ref[b] == n, (b, n, store._ref[b])


@settings(deadline=None, max_examples=10)
@given(st.integers(min_value=0, max_value=1 << 30))
def test_block_conservation_under_random_lifecycle(seed):
    """Random admit/commit/retire/drain traffic over a small pool with a
    tiny token alphabet (collisions, partial tails, forks, evictions all
    fire): after EVERY operation the pool partitions exactly into
    free / referenced / cached-unreferenced. The store's internal asserts
    (no double-free, no fresh lease of an owned block) arm the rest."""
    rng = np.random.default_rng(seed)
    cfg = get_config("tinyllama-1.1b").smoke()
    store = PagedKVStore(cfg, n_slots=3, max_seq_len=16, block_size=4,
                         n_blocks=10, prefix_cache=True)
    _census_ok(store)
    for _ in range(60):
        op = int(rng.integers(0, 4))
        if op == 0 or op == 3:                    # lease (+ maybe commit)
            slot = int(rng.integers(0, 3))
            if slot in store._leased:
                continue
            plen = int(rng.integers(1, 13))
            gen = int(rng.integers(1, 17 - plen))
            tokens = rng.integers(0, 3, (plen,), dtype=np.int32)
            if store.lease(slot, plen, gen, tokens=tokens) and op == 0:
                store.commit_prefix(slot)
        elif op == 1:                             # retire one leased slot
            leased = sorted(store._leased)
            if leased:
                store.reset(int(rng.choice(leased)))
        else:                                     # drain: retire everything
            for slot in sorted(store._leased):
                store.reset(slot)
        _census_ok(store)
    for slot in sorted(store._leased):            # final drain must balance
        store.reset(slot)
    _census_ok(store)
