import os

import jax
import pytest

from repro.distributed import sharding as shd

# NOTE: no XLA_FLAGS here on purpose — tests run on the real single CPU
# device; only launch/dryrun.py forces 512 host devices (assignment step 0).

# Persistent XLA compilation cache: the fast tier is compile-bound on CPU, so
# repeated runs (local dev, the tier-1 gate) skip recompilation entirely.
jax.config.update("jax_compilation_cache_dir",
                  os.path.join(os.path.dirname(__file__), os.pardir, ".jax_cache"))
jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.5)


@pytest.fixture(scope="session")
def mesh():
    m = jax.make_mesh((1,), ("data",))
    shd.set_mesh(m)
    with m:
        yield m


@pytest.fixture(autouse=True)
def _mesh_ctx(mesh):
    # every test runs inside the 1-device mesh context
    shd.set_mesh(mesh)
    yield
