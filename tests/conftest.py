import jax
import pytest

from repro.distributed import sharding as shd

# NOTE: no XLA_FLAGS here on purpose — tests run on the real single CPU
# device; only launch/dryrun.py forces 512 host devices (assignment step 0).


@pytest.fixture(scope="session")
def mesh():
    m = jax.make_mesh((1,), ("data",))
    shd.set_mesh(m)
    with m:
        yield m


@pytest.fixture(autouse=True)
def _mesh_ctx(mesh):
    # every test runs inside the 1-device mesh context
    shd.set_mesh(mesh)
    yield
