"""Attention execution-path variants: chunked vs plain, bf16acc internals,
SP (query-sharded) attention, int8 KV cache — the §Perf knobs must preserve
semantics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import forward, init_model, serve
from repro.models.attention import _chunked_attention, _plain_attention


def _setup(arch="tinyllama_1_1b", **overrides):
    cfg = get_config(arch).smoke().replace(remat=False, **overrides)
    params = init_model(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 32), 0, cfg.vocab)
    return cfg, params, toks


def test_bf16acc_close_to_f32():
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(2, 64, 4, 16)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(2, 64, 4, 16)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(2, 64, 4, 16)).astype(np.float32))
    f32 = _chunked_attention(q, k, v, causal=True, chunk=16, impl="f32")
    b16 = _chunked_attention(q, k, v, causal=True, chunk=16, impl="bf16acc")
    np.testing.assert_allclose(np.asarray(b16), np.asarray(f32), rtol=0.05, atol=0.05)


def test_forward_same_across_attn_impls():
    """Model logits must agree between f32 and bf16acc chunked paths (S=32 >
    smoke attn_chunk=16 -> chunked path exercised)."""
    cfg, params, toks = _setup()
    l_f32, _ = forward(params, cfg, {"tokens": toks})
    cfg2 = cfg.replace(attn_impl="bf16acc")
    l_b16, _ = forward(params, cfg2, {"tokens": toks})
    np.testing.assert_allclose(
        np.asarray(l_b16, np.float32), np.asarray(l_f32, np.float32),
        rtol=0.2, atol=0.2)


def test_forward_same_with_attn_sp():
    """SP attention (query sharding) is a pure re-layout on 1 device."""
    cfg, params, toks = _setup()
    l_base, _ = forward(params, cfg, {"tokens": toks})
    l_sp, _ = forward(params, cfg.replace(attn_sp=True), {"tokens": toks})
    np.testing.assert_allclose(
        np.asarray(l_sp, np.float32), np.asarray(l_base, np.float32),
        rtol=1e-2, atol=1e-2)


def test_mrope_chunked_path():
    cfg = get_config("qwen2_vl_2b").smoke().replace(remat=False)
    params = init_model(cfg, jax.random.PRNGKey(0))
    B, S = 2, 32
    batch = {"embeds": jnp.ones((B, S, cfg.d_model), jnp.bfloat16),
             "positions3": jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (3, B, S))}
    logits, _ = forward(params, cfg, batch)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


@pytest.mark.slow
def test_int8_kv_cache_decode_accuracy():
    cfg, params, toks = _setup()
    full_logits, _ = forward(params, cfg, {"tokens": toks})
    cfg8 = cfg.replace(kv_cache_dtype="int8")
    cache = serve.init_cache(cfg8, 2, 32)
    assert cache["k"].dtype == jnp.int8
    for t in range(32):
        dl, cache = serve.decode(params, cfg8, cache, {"tokens": toks[:, t:t + 1]})
    err = np.abs(np.asarray(dl[:, 0], np.float32)
                 - np.asarray(full_logits[:, -1], np.float32)).max()
    assert err < 0.5, err


def test_long_context_decode_ssm_constant_state():
    """SSM decode state size is independent of context length (the
    sub-quadratic property that qualifies xlstm/zamba2 for long_500k)."""
    cfg = get_config("xlstm_125m").smoke()
    c_small = serve.init_cache(cfg, 2, 128)
    c_large = serve.init_cache(cfg, 2, 4096)
    for k in ("mlstm_C", "slstm_c"):
        assert c_small[k].shape == c_large[k].shape  # no seq dimension
