"""Per-kernel correctness: Pallas (interpret=True) vs pure-jnp oracle across
shape sweeps (the assignment's kernel contract)."""

import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(42)


@pytest.mark.parametrize("M,K,N,bk", [
    (128, 512, 128, 512),
    (256, 512, 256, 256),
    (128, 1024, 384, 512),
])
def test_qgemm_matches_ref(M, K, N, bk):
    aq = RNG.integers(-127, 128, (M, K)).astype(np.int8)
    bq = RNG.integers(-127, 128, (K, N)).astype(np.int8)
    sb = RNG.uniform(1e-3, 1e-2, (N,)).astype(np.float32)
    out = np.asarray(ops.qgemm_f32(aq, bq, sb, bk=bk))
    expect = np.asarray(ref.qgemm_ref(aq, bq, sb))
    np.testing.assert_allclose(out, expect, rtol=1e-6, atol=1e-6)


def test_qgemm_int32_exact():
    """int8 x int8 -> int32 accumulation must be bit-exact (no fp rounding)."""
    aq = RNG.integers(-127, 128, (128, 512)).astype(np.int8)
    bq = RNG.integers(-127, 128, (512, 128)).astype(np.int8)
    ones = np.ones((128,), np.float32)
    out = np.asarray(ops.qgemm_f32(aq, bq, ones))
    expect = aq.astype(np.int64) @ bq.astype(np.int64)
    assert np.array_equal(out.astype(np.int64), expect)


@pytest.mark.parametrize("Mb,Kb,Nb", [(1, 2, 1), (2, 4, 2)])
def test_qgemm_tile_scales(Mb, Kb, Nb):
    t = 128
    M, K, N = Mb * t, Kb * t, Nb * t
    aq = RNG.integers(-127, 128, (M, K)).astype(np.int8)
    bq = RNG.integers(-127, 128, (K, N)).astype(np.int8)
    sa = RNG.uniform(1e-3, 1e-2, (Mb, Kb)).astype(np.float32)
    sb = RNG.uniform(1e-3, 1e-2, (Kb, Nb)).astype(np.float32)
    out = np.asarray(ops.qgemm_tiles(
        aq.reshape(Mb, t, Kb, t).swapaxes(1, 2), sa,
        bq.reshape(Kb, t, Nb, t).swapaxes(1, 2), sb))
    expect = np.asarray(ref.qgemm_tile_scales_ref(aq, bq, sa, sb))
    expect = expect.reshape(Mb, t, Nb, t).swapaxes(1, 2)
    np.testing.assert_allclose(out, expect, rtol=2e-3, atol=1e-4)


@pytest.mark.parametrize("H,W,bm", [(64, 128, 64), (100, 300, 64), (257, 129, 128)])
def test_stencil_matches_ref(H, W, bm):
    x = RNG.normal(size=(H, W)).astype(np.float32)
    w = RNG.normal(size=(3, 3)).astype(np.float32)
    out = np.asarray(ops.stencil(x, w, bm=bm))
    expect = np.asarray(ref.stencil3x3_ref(x, w))
    np.testing.assert_allclose(out, expect, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("B,K,N", [(1, 256, 256), (8, 384, 512)])
def test_qgemv_matches_ref(B, K, N):
    x = RNG.normal(size=(B, K)).astype(np.float32)
    wq = RNG.integers(-127, 128, (K, N)).astype(np.int8)
    s = RNG.uniform(1e-3, 1e-2, (N,)).astype(np.float32)
    out = np.asarray(ops.qgemv(x, wq, s))
    expect = np.asarray(ref.qgemv_ref(x, wq, s))
    np.testing.assert_allclose(out, expect, rtol=2e-4, atol=1e-4)


# ===========================================================================
# block-native paged decode attention (kernels/paged_attention.py)
# ===========================================================================

def _paged_ref(q, k_pool, v_pool, tables, index):
    """Gather-view oracle: concatenate each slot's table-addressed blocks and
    run plain masked single-query attention over the contiguous rows."""
    import jax.numpy as jnp
    import jax
    B, H, hd = q.shape
    _, bs, KV, _ = k_pool.shape
    MB = tables.shape[1]
    S = MB * bs
    flat = tables.reshape(-1)
    k = jnp.take(jnp.asarray(k_pool), flat, axis=0).reshape(B, S, KV, hd)
    v = jnp.take(jnp.asarray(v_pool), flat, axis=0).reshape(B, S, KV, hd)
    rep = H // KV
    k = jnp.repeat(k, rep, axis=2)
    v = jnp.repeat(v, rep, axis=2)
    s = jnp.einsum("bhd,bkhd->bhk", jnp.asarray(q, jnp.float32),
                   k.astype(jnp.float32)) * hd ** -0.5
    valid = jnp.arange(S)[None, None, :] <= jnp.asarray(index)[:, None, None]
    s = jnp.where(valid, s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    return np.asarray(jnp.einsum("bhk,bkhd->bhd", w, v.astype(jnp.float32)))


def _paged_case(B, H, KV, hd, bs, MB, seed=0):
    rng = np.random.default_rng(seed)
    NB = B * MB + 1                                    # + null block
    q = rng.normal(size=(B, H, hd)).astype(np.float32)
    k_pool = rng.normal(size=(NB, bs, KV, hd)).astype(np.float32)
    v_pool = rng.normal(size=(NB, bs, KV, hd)).astype(np.float32)
    tables = np.zeros((B, MB), np.int32)
    free = list(range(1, NB))
    index = np.zeros((B,), np.int32)
    for b in range(B):
        n_lease = int(rng.integers(1, MB + 1))         # partial leases incl. full
        for j in range(n_lease):
            tables[b, j] = free.pop()
        index[b] = int(rng.integers(0, n_lease * bs))  # horizon inside lease
    return q, k_pool, v_pool, tables, index


@pytest.mark.parametrize("B,H,KV,hd,bs,MB", [
    (2, 4, 4, 8, 4, 2),       # MHA
    (3, 4, 2, 8, 4, 3),       # GQA rep=2
    (2, 8, 1, 16, 8, 2),      # MQA
])
def test_paged_attention_kernel_matches_gather_ref(B, H, KV, hd, bs, MB):
    """The Pallas block-native decode kernel (interpret mode — the CPU CI
    path) against the gather-view oracle across MHA/GQA/MQA head layouts and
    partial leases."""
    from repro.kernels.paged_attention import paged_decode_attention
    q, k_pool, v_pool, tables, index = _paged_case(B, H, KV, hd, bs, MB)
    out = np.asarray(paged_decode_attention(
        q, k_pool, v_pool, tables, index, interpret=True))
    expect = _paged_ref(q, k_pool, v_pool, tables, index)
    np.testing.assert_allclose(out, expect, rtol=1e-5, atol=1e-5)


def test_paged_attention_kernel_masks_beyond_horizon():
    """Block-table addressing has teeth: poisoning the null block and every
    pool cell past each slot's causal horizon must not move the output —
    those positions get softmax weight exactly 0."""
    from repro.kernels.paged_attention import paged_decode_attention
    q, k_pool, v_pool, tables, index = _paged_case(2, 4, 2, 8, 4, 3, seed=1)
    clean = np.asarray(paged_decode_attention(
        q, k_pool, v_pool, tables, index, interpret=True))
    kp, vp = k_pool.copy(), v_pool.copy()
    kp[0] = 1e6                                        # null block
    vp[0] = 1e6
    for b in range(tables.shape[0]):                   # cells past the horizon
        for j in range(tables.shape[1]):
            blk = tables[b, j]
            if blk == 0:
                continue
            for t in range(k_pool.shape[1]):
                if j * k_pool.shape[1] + t > index[b]:
                    kp[blk, t] = -1e6
                    vp[blk, t] = -1e6
    poisoned = np.asarray(paged_decode_attention(
        q, kp, vp, tables, index, interpret=True))
    np.testing.assert_allclose(poisoned, clean, rtol=1e-5, atol=1e-5)
