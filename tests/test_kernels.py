"""Per-kernel correctness: Pallas (interpret=True) vs pure-jnp oracle across
shape sweeps (the assignment's kernel contract)."""

import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(42)


@pytest.mark.parametrize("M,K,N,bk", [
    (128, 512, 128, 512),
    (256, 512, 256, 256),
    (128, 1024, 384, 512),
])
def test_qgemm_matches_ref(M, K, N, bk):
    aq = RNG.integers(-127, 128, (M, K)).astype(np.int8)
    bq = RNG.integers(-127, 128, (K, N)).astype(np.int8)
    sb = RNG.uniform(1e-3, 1e-2, (N,)).astype(np.float32)
    out = np.asarray(ops.qgemm_f32(aq, bq, sb, bk=bk))
    expect = np.asarray(ref.qgemm_ref(aq, bq, sb))
    np.testing.assert_allclose(out, expect, rtol=1e-6, atol=1e-6)


def test_qgemm_int32_exact():
    """int8 x int8 -> int32 accumulation must be bit-exact (no fp rounding)."""
    aq = RNG.integers(-127, 128, (128, 512)).astype(np.int8)
    bq = RNG.integers(-127, 128, (512, 128)).astype(np.int8)
    ones = np.ones((128,), np.float32)
    out = np.asarray(ops.qgemm_f32(aq, bq, ones))
    expect = aq.astype(np.int64) @ bq.astype(np.int64)
    assert np.array_equal(out.astype(np.int64), expect)


@pytest.mark.parametrize("Mb,Kb,Nb", [(1, 2, 1), (2, 4, 2)])
def test_qgemm_tile_scales(Mb, Kb, Nb):
    t = 128
    M, K, N = Mb * t, Kb * t, Nb * t
    aq = RNG.integers(-127, 128, (M, K)).astype(np.int8)
    bq = RNG.integers(-127, 128, (K, N)).astype(np.int8)
    sa = RNG.uniform(1e-3, 1e-2, (Mb, Kb)).astype(np.float32)
    sb = RNG.uniform(1e-3, 1e-2, (Kb, Nb)).astype(np.float32)
    out = np.asarray(ops.qgemm_tiles(
        aq.reshape(Mb, t, Kb, t).swapaxes(1, 2), sa,
        bq.reshape(Kb, t, Nb, t).swapaxes(1, 2), sb))
    expect = np.asarray(ref.qgemm_tile_scales_ref(aq, bq, sa, sb))
    expect = expect.reshape(Mb, t, Nb, t).swapaxes(1, 2)
    np.testing.assert_allclose(out, expect, rtol=2e-3, atol=1e-4)


@pytest.mark.parametrize("H,W,bm", [(64, 128, 64), (100, 300, 64), (257, 129, 128)])
def test_stencil_matches_ref(H, W, bm):
    x = RNG.normal(size=(H, W)).astype(np.float32)
    w = RNG.normal(size=(3, 3)).astype(np.float32)
    out = np.asarray(ops.stencil(x, w, bm=bm))
    expect = np.asarray(ref.stencil3x3_ref(x, w))
    np.testing.assert_allclose(out, expect, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("B,K,N", [(1, 256, 256), (8, 384, 512)])
def test_qgemv_matches_ref(B, K, N):
    x = RNG.normal(size=(B, K)).astype(np.float32)
    wq = RNG.integers(-127, 128, (K, N)).astype(np.int8)
    s = RNG.uniform(1e-3, 1e-2, (N,)).astype(np.float32)
    out = np.asarray(ops.qgemv(x, wq, s))
    expect = np.asarray(ref.qgemv_ref(x, wq, s))
    np.testing.assert_allclose(out, expect, rtol=2e-4, atol=1e-4)
