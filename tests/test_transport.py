"""HostTransport invariants (serving/transport.py, serving/host_main.py):

  * protocol isolation — the Router speaks only HostTransport: no direct
    engine attribute access anywhere in router.py (grep-asserted), and the
    default in-process fleet behaves exactly as before (test_router.py runs
    unchanged)
  * codec        — msgpack and JSON frames round-trip the full wire surface
    (ndarrays, nested dicts, int keys normalized across JSON stringification)
  * bit-identity — a seeded, staggered, mid-run-drained fleet over
    SubprocessTransport (real OS processes, free-running workers) emits
    streams bit-identical to a single in-process engine serving the same
    requests one at a time — dense and int8-KV cache formats
  * crash safety — SIGKILL of one worker mid-decode: the router marks the
    host LOST, re-admits its streams as continuations from the harvested
    tokens, and the final streams are STILL bit-identical (determinism
    regenerates exactly the tokens that died un-polled; nothing
    double-emits)
  * fault injection — dropped/duplicated/timed-out frames through a flaky
    channel: idempotent calls retry with fresh seqs and discard stale
    replies; non-idempotent calls surface TransportError instead of
    retrying
  * TOCTOU       — a host whose door closes between would_accept and submit
    is skipped and the next candidate takes the request (no spurious
    fleet-level rejection)
"""

import os
import pathlib
import signal
import time

import numpy as np
import pytest

import jax

from repro.configs import get_config
from repro.models import init_model
from repro.serving import (
    Engine, EngineConfig, Router, RouterConfig, SamplingParams,
)
from repro.serving import transport as tp
from repro.serving.transport import (
    Channel, EngineHost, InProcessTransport, SubprocessTransport,
    TransportError, build_inproc_fleet, build_model_spec, decode_frame,
    encode_frame, engine_cfg_from_wire, engine_cfg_to_wire,
)

CFG = get_config("tinyllama-1.1b").smoke()
RNG = np.random.default_rng(7)

pytestmark = pytest.mark.filterwarnings("ignore::DeprecationWarning")


@pytest.fixture(scope="module")
def params():
    return init_model(CFG, jax.random.PRNGKey(0))


def _prompts(lens, cfg=CFG, rng=None):
    rng = RNG if rng is None else rng
    return [rng.integers(0, cfg.vocab, (l,), dtype=np.int32) for l in lens]


def _sampling(i):
    """Mixed traffic: even requests sample (per-request seed), odd greedy."""
    if i % 2 == 0:
        return SamplingParams(temperature=0.8, top_k=40, seed=100 + i)
    return None


def _sequential(params, prompts, gens, samplings, cfg=CFG, **ecfg_kw):
    """Reference: one in-process engine, one request at a time."""
    kw = dict(max_slots=2, max_seq_len=48)
    kw.update(ecfg_kw)
    eng = Engine(cfg, params, EngineConfig(**kw))
    outs = []
    for p, g, sp in zip(prompts, gens, samplings):
        req = eng.submit(p, g, sampling=sp)
        eng.run_until_complete()
        outs.append(list(req.tokens))
    eng.close()
    return outs


# --------------------------------------------------------------------- codec

@pytest.mark.parametrize("codec", ["json"] + (["msgpack"] if tp.msgpack else []))
def test_codec_round_trip(codec):
    obj = {
        "ints": [1, 2, 3], "f": 1.5, "none": None, "flag": True,
        "nested": {"deep": {"arr": np.arange(6, dtype=np.int32).reshape(2, 3)}},
        "f32": np.float32(2.5), "i64": np.int64(9),
        "emb": np.linspace(0, 1, 5, dtype=np.float32),
    }
    out = decode_frame(encode_frame(obj, codec))
    assert out["ints"] == [1, 2, 3] and out["f"] == 1.5
    assert out["none"] is None and out["flag"] is True
    nd = out["nested"]["deep"]["arr"]
    assert isinstance(nd, np.ndarray) and nd.dtype == np.int32
    np.testing.assert_array_equal(nd, np.arange(6).reshape(2, 3))
    np.testing.assert_allclose(out["emb"], np.linspace(0, 1, 5), rtol=0)
    # the codec byte dispatches per frame, so mixed peers interoperate
    assert encode_frame({}, "json")[:1] == b"J"


def test_engine_cfg_wire_round_trip():
    ecfg = EngineConfig(max_slots=3, max_seq_len=32, buckets=(16, 32),
                        cache_backend="paged", block_size=8, n_blocks=9,
                        prefix_cache=True)
    back = engine_cfg_from_wire(engine_cfg_to_wire(ecfg))
    assert back == ecfg
    # the draft ArchConfig never crosses the wire: workers rebuild it from
    # the model spec's registry name
    spec_ecfg = EngineConfig(speculative=True, spec_k=3, draft=CFG)
    wire = engine_cfg_to_wire(spec_ecfg)
    assert "draft" not in wire
    rebuilt = engine_cfg_from_wire(wire, draft_cfg=CFG)
    assert rebuilt.draft == CFG and rebuilt.spec_k == 3


def test_request_wire_form(params):
    eng = Engine(CFG, params, EngineConfig(max_slots=1, max_seq_len=32))
    req = eng.submit(_prompts([5])[0], 4,
                     sampling=SamplingParams(temperature=0.5, seed=3,
                                             stop=((7, 8),)),
                     want_logprobs=2)
    eng.run_until_complete()
    wire = decode_frame(encode_frame(req.to_wire()))     # through the codec
    assert wire["tokens"] == list(req.tokens)
    assert wire["sampling"]["seed"] == 3
    assert wire["sampling"]["stop"] == [[7, 8]]
    assert len(wire["logprobs"]) == len(req.tokens)
    assert all(len(row) >= 2 for row in wire["top_logprobs"])
    eng.close()


# -------------------------------------------------------- protocol isolation

def test_router_speaks_only_the_transport_protocol():
    """The refactor's structural guarantee: router.py contains no direct
    engine attribute access — every host interaction goes through
    HostTransport, so swapping in-process for subprocess hosts cannot change
    router behavior."""
    src = (pathlib.Path(__file__).parent.parent
           / "src/repro/serving/router.py").read_text()
    for forbidden in ("repro.serving.engine", "Engine(", ".scheduler",
                      ".opq", ".store", ".completed[", "run_engine"):
        assert forbidden not in src, (
            f"router.py reaches around the transport protocol: {forbidden!r}")


def test_default_fleet_is_in_process(params):
    router = Router(CFG, params, EngineConfig(max_slots=2, max_seq_len=32),
                    RouterConfig(n_hosts=2))
    assert [t.kind for t in router.transports] == ["in-process"] * 2
    assert len(router.engines) == 2                  # debug surface intact
    r = router.submit(_prompts([5])[0], 4)
    router.run_until_complete()
    assert len(r.tokens) == 4 and r.done
    s = router.stats()["router"]
    assert [t["kind"] for t in s["transport"]] == ["in-process"] * 2
    assert all(t["rpcs"] > 0 for t in s["transport"])
    router.close()


def test_engine_host_poll_is_cursor_idempotent(params):
    """poll never re-emits: identical cursors return identical deltas, and
    advancing the cursor excludes exactly the harvested prefix. done rides
    the same delta as the final tokens."""
    host = EngineHost(Engine(CFG, params,
                             EngineConfig(max_slots=1, max_seq_len=32)))
    eid = host.submit(_prompts([5])[0], 4)
    while host.has_work():
        host.pump()
    d1 = host.poll({eid: 0})
    d2 = host.poll({eid: 0})                        # duplicated poll
    assert d1 == d2 and len(d1[eid]["t"]) == 4      # same answer, no re-emit
    assert d1[eid]["done"] and d1[eid]["reason"] == "length"
    tail = host.poll({eid: 3})
    assert tail[eid]["t"] == d1[eid]["t"][3:]       # cursor slices the tail
    host.poll({}, drop=[eid])
    assert host.poll({eid: 0}) == {}                # forgotten after drop
    host.close()


# --------------------------------------------------------------- TOCTOU door

class _FlakyDoor:
    """Transport wrapper whose door lies once: would_accept says yes but the
    next submit returns None (the race where capacity vanishes between the
    probe and the submit)."""

    def __init__(self, inner):
        self.inner = inner
        self.deny_submits = 0
        self.denied = 0

    def __getattr__(self, name):
        return getattr(self.inner, name)

    def submit(self, *args, **kwargs):
        if self.deny_submits > 0:
            self.deny_submits -= 1
            self.denied += 1
            return None
        return self.inner.submit(*args, **kwargs)


def test_submit_revalidates_and_falls_through(params):
    fleet = build_inproc_fleet(CFG, params,
                               EngineConfig(max_slots=2, max_seq_len=32),
                               n_hosts=2)
    flaky = _FlakyDoor(fleet[0])
    router = Router(transports=[flaky, fleet[1]])
    flaky.deny_submits = 1
    r = router.submit(_prompts([5])[0], 4, session="x")
    assert r is not None and flaky.denied == 1
    assert r.hosts == [1]                            # fell through to host 1
    s = router.stats()["router"]
    assert s["placed"] == 1 and s["rejected"] == 0
    # when EVERY candidate's door closes, the fleet-level contract holds
    flaky.deny_submits = 10
    router2 = Router(transports=[flaky])
    assert router2.submit(_prompts([5])[0], 4) is None
    assert router2.stats()["router"]["rejected"] == 1
    router.close()


# ----------------------------------------------------- loss recovery (fast)

class _Breakable:
    """Transport wrapper that starts raising TransportError on command —
    the in-process stand-in for a dead worker, driving the router's LOST
    path without subprocess cost."""

    def __init__(self, inner):
        self.inner = inner
        self.broken = False

    def __getattr__(self, name):
        attr = getattr(self.inner, name)
        if not callable(attr) or name == "close":
            return attr
        def wrapped(*args, **kwargs):
            if self.broken:
                raise TransportError("injected host failure")
            return attr(*args, **kwargs)
        return wrapped


def test_lost_host_streams_recover_bit_identically(params):
    prompts = _prompts([6, 5, 7, 4])
    gens = [10, 9, 8, 10]
    samplings = [_sampling(i) for i in range(4)]
    sequential = _sequential(params, prompts, gens, samplings)

    fleet = build_inproc_fleet(CFG, params,
                               EngineConfig(max_slots=2, max_seq_len=48),
                               n_hosts=2)
    breakable = _Breakable(fleet[0])
    router = Router(transports=[breakable, fleet[1]],
                    router_cfg=RouterConfig(handoff_threshold=0))
    reqs = []
    for i in range(4):
        reqs.append(router.submit(prompts[i], gens[i], session=str(i % 2),
                                  sampling=samplings[i], strict=True))
        router.step()
    assert {r.hosts[0] for r in reqs} == {0, 1}     # both hosts held work
    host0_reqs = [r for r in reqs if r.hosts[0] == 0]
    assert any(len(r.tokens) > 0 for r in host0_reqs)   # mid-decode...
    breakable.broken = True                             # ...and now it dies
    router.run_until_complete()

    assert [list(r.tokens) for r in reqs] == sequential   # bit-identical
    s = router.stats()["router"]
    assert s["lost"] == [0] and s["hosts_lost"] == 1
    assert s["recovered"] >= len(host0_reqs)
    assert all(r.hosts[-1] == 1 for r in host0_reqs)      # re-admitted on 1
    router.close()


# ---------------------------------------------------- subprocess: real fleet
#
# These tests use a scaled-up smoke model (~4 ms/decode-step): a 96-token
# generation is a ~0.4 s window on a free-running worker, so a drain or a
# SIGKILL issued right after submit reliably lands mid-decode. Sequence
# positions stay <= 128 — the envelope where the engine's
# prefill-with-cache == decode-replay bit invariant is proven (longer
# continuations can round differently under XLA; see ROADMAP).
#
# Streams that get preempted mid-decode (drained or killed, i.e. re-prefilled
# as continuations at a timing-dependent point) are GREEDY here: sampled
# continuations re-roll a Gumbel-perturbed argmax on each step, which can
# flip on the tiny prefill-vs-decode logit epsilon at these shapes even
# inside the envelope — the same pre-existing engine hole tracked in
# ROADMAP, amplified. Sampled streams still run in every fleet, pinned to
# the surviving host, and must match the sequential reference exactly.
# Each test draws prompts from its own fixed rng (seeds 21/22/13 below) so
# the token streams are identical regardless of which other tests ran
# first. Those seeds are NOT folklore: tests/_seed_verify.py sweeps the
# greedy continuation space of each pinned (config, seed) pair — cutting
# the stream at continuation points and re-admitting prompt + prefix, the
# exact re-prefill these tests exercise at timing-dependent W — and
# tests/test_disagg.py::test_pinned_transport_seeds_verified keeps that
# sweep in the suite. Re-pin a seed only if it passes the harness:
#   PYTHONPATH=src python tests/_seed_verify.py --big --seed <n> --gen 96

BIG = dict(n_layers=4, d_model=256, n_heads=8, n_kv=4, d_ff=1024,
           vocab=512, head_dim=32)
BIG_CFG = CFG.replace(**BIG)


def _spawn_fleet(n, ecfg, overrides=None):
    spec = build_model_spec("tinyllama-1.1b", smoke=True, seed=0,
                            overrides=dict(BIG, **(overrides or {})))
    fleet = []
    try:
        for _ in range(n):
            fleet.append(SubprocessTransport(spec, ecfg))
    except Exception:
        for t in fleet:
            t.close()
        raise
    return fleet


def _warm(fleet):
    """Run one tiny greedy request on each worker so every process compiles
    its prefill + decode executables up front. Without this, an RPC to a
    still-compiling host can stall the parent long enough for a
    free-running victim to finish its whole generation before a drain or a
    kill lands — batch invariance means the warmup requests change no other
    stream."""
    rng = np.random.default_rng(99)
    for t in fleet:
        eid = t.submit(_prompts([4], rng=rng)[0], 2)
        deadline = time.monotonic() + 300
        while not t.poll({eid: 0}).get(eid, {}).get("done"):
            assert time.monotonic() < deadline, "warmup never finished"
            time.sleep(0.01)
        t.poll({}, drop=[eid])


@pytest.mark.parametrize("kv_dtype", ["bfloat16", "int8"])
def test_subprocess_fleet_bit_identical_to_sequential(kv_dtype):
    """THE transport invariant: a seeded, staggered, mid-run-drained fleet
    of real OS processes — workers free-running their engines, tokens
    arriving through framed RPC polls — emits streams bit-identical to a
    single in-process engine serving the same requests sequentially."""
    cfg = BIG_CFG.replace(kv_cache_dtype=kv_dtype)
    params = init_model(cfg, jax.random.PRNGKey(0))   # same seed as workers
    prompts = _prompts([6, 9, 4, 7], cfg=cfg, rng=np.random.default_rng(21))
    # long second generation: the drain must land while it is mid-decode on
    # a free-running worker, so the handoff really crosses the wire
    gens = [48, 96, 8, 6]
    samplings = [_sampling(i) for i in range(4)]
    sequential = _sequential(params, prompts, gens, samplings, cfg=cfg,
                             max_seq_len=128)

    ecfg = EngineConfig(max_slots=2, max_seq_len=128)
    fleet = _spawn_fleet(2, ecfg, overrides={"kv_cache_dtype": kv_dtype})
    _warm(fleet)
    router = Router(transports=fleet,
                    router_cfg=RouterConfig(handoff_threshold=0))
    reqs = []
    for i in range(4):
        reqs.append(router.submit(prompts[i], gens[i], session=str(i % 2),
                                  sampling=samplings[i],
                                  want_logprobs=2 if i == 0 else None,
                                  strict=True))
        router.step()
    # drain the host holding session "1" (the greedy streams) once req 1 is
    # verifiably mid-decode there — the handoff crosses the wire for real
    victim = reqs[1].hosts[0]
    assert reqs[0].hosts[0] != victim       # sampled streams live elsewhere
    deadline = time.monotonic() + 120
    while not 0 < len(reqs[1].tokens) < reqs[1].max_new_tokens:
        router.step()
        assert time.monotonic() < deadline, "req 1 never got mid-decode"
    router.drain(victim)                    # mid-run drain: handoff on wire
    router.run_until_complete()

    assert [list(r.tokens) for r in reqs] == sequential
    assert len(reqs[1].hosts) > 1                     # the handoff happened
    # logprobs survive the transport (and any handoff) aligned with tokens
    assert len(reqs[0].logprobs) == len(reqs[0].tokens)
    # rows carry the engine's fixed top-K; the API layer truncates to `want`
    assert all(len(row) >= 2 for row in reqs[0].top_logprobs)
    s = router.stats()
    assert s["router"]["drains"] == 1 and s["router"]["handoffs"] >= 1
    assert s["router"]["hosts_lost"] == 0
    assert [t["kind"] for t in s["router"]["transport"]] == ["subprocess"] * 2
    assert s["fleet"]["tokens_generated"] >= sum(gens)    # fleet really ran
    router.close()
    assert all(t.proc.poll() is not None for t in fleet)  # no orphans


def test_sigkill_mid_decode_recovers_bit_identically():
    """Hard host death: SIGKILL one worker while it decodes. The router
    detects the loss on the next RPC, re-places the dead host's streams as
    continuations from the harvested tokens, and the final streams match
    the sequential reference exactly — the un-harvested tokens died with
    the process and were regenerated, never double-emitted."""
    params = init_model(BIG_CFG, jax.random.PRNGKey(0))
    prompts = _prompts([6, 5, 7, 4], rng=np.random.default_rng(22))
    # long generations so the SIGKILL lands while the victim is mid-decode
    gens = [96, 80, 96, 80]
    samplings = [_sampling(i) for i in range(4)]
    sequential = _sequential(params, prompts, gens, samplings, cfg=BIG_CFG,
                             max_seq_len=128)

    fleet = _spawn_fleet(2, EngineConfig(max_slots=2, max_seq_len=128))
    _warm(fleet)
    router = Router(transports=fleet,
                    router_cfg=RouterConfig(handoff_threshold=0))
    reqs = []
    for i in range(4):
        reqs.append(router.submit(prompts[i], gens[i], session=str(i % 2),
                                  sampling=samplings[i], strict=True))
    # kill the host holding session "1" — the greedy streams (see the
    # section comment: preempted streams stay greedy)
    victim = reqs[1].hosts[0]
    victim_reqs = [r for r in reqs if r.hosts[0] == victim]
    survivor = next(h for h in (0, 1) if h != victim)
    assert victim_reqs and len(victim_reqs) < 4       # both hosts hold work
    victim_pid = fleet[victim].pid
    deadline = time.monotonic() + 120
    while not any(0 < len(r.tokens) < r.max_new_tokens for r in victim_reqs):
        router.step()                # harvest until the victim is mid-decode
        assert time.monotonic() < deadline, "victim never got mid-decode"
    os.kill(victim_pid, signal.SIGKILL)
    router.run_until_complete()

    assert [list(r.tokens) for r in reqs] == sequential   # bit-identical
    s = router.stats()["router"]
    assert s["lost"] == [victim] and s["hosts_lost"] == 1
    assert s["recovered"] >= 1
    assert all(r.hosts[-1] == survivor for r in victim_reqs)
    router.close()
    assert all(t.proc.poll() is not None for t in fleet)  # victim reaped too


def test_flaky_frames_retry_and_error_semantics():
    """Frame-level fault injection on a live worker channel: a dropped
    reply retries an idempotent call (fresh seq, counted); a duplicated /
    stale-seq frame is discarded, not misdelivered; a dropped reply on a
    NON-idempotent call raises TransportError instead of retrying."""
    fleet = _spawn_fleet(1, EngineConfig(max_slots=2, max_seq_len=32))
    t = fleet[0]
    chan = t.chan
    real_recv = chan.recv

    # 1) dropped reply -> idempotent retry succeeds
    state = {"drops": 1}
    def dropping_recv(timeout=None):
        if state["drops"] > 0:
            state["drops"] -= 1
            raise TransportError("injected drop")
        return real_recv(timeout)
    chan.recv = dropping_recv
    assert t.load() == 0                       # retried transparently
    assert t.metrics.retries == 1 and t.metrics.errors == 1
    chan.recv = real_recv

    # 2) duplicated/stale frame -> seq filter discards it
    state2 = {"extra": 1}
    def duplicating_recv(timeout=None):
        if state2["extra"] > 0:
            state2["extra"] -= 1
            return {"seq": -12345, "ok": True, "val": 987654}   # stale junk
        return real_recv(timeout)
    chan.recv = duplicating_recv
    assert t.would_accept(4, 4) is True        # not 987654
    chan.recv = real_recv

    # 3) dropped reply on submit (non-idempotent) -> TransportError, and the
    # transport records the error without inventing a retry
    errors_before = t.metrics.errors
    def always_drop(timeout=None):
        raise TransportError("injected drop")
    chan.recv = always_drop
    with pytest.raises(TransportError):
        t.submit(_prompts([4])[0], 4)
    assert t.metrics.errors == errors_before + 1
    chan.recv = real_recv
    # the worker itself is fine — the dropped reply was consumed by the seq
    # filter of the next call, and service continues
    assert t.probe() is True
    t.close()


def test_lost_host_never_double_emits_over_flaky_transport():
    """Router + flaky subprocess: break the channel under a live stream;
    the host goes LOST and the stream re-admits elsewhere. The recovered
    stream must equal the sequential reference exactly — in particular no
    token appears twice even though the dead host had generated (and we had
    harvested) a prefix of it."""
    params = init_model(BIG_CFG, jax.random.PRNGKey(0))
    prompts = _prompts([6, 5], rng=np.random.default_rng(13))
    gens = [96, 24]
    # both greedy: req 0 is the preempted stream, and req 1 could land on
    # the same host as req 0 under load ties, so it must survive a re-prefill
    samplings = [None, None]
    sequential = _sequential(params, prompts, gens, samplings, cfg=BIG_CFG,
                             max_seq_len=128)

    fleet = _spawn_fleet(2, EngineConfig(max_slots=2, max_seq_len=128))
    _warm(fleet)
    router = Router(transports=fleet,
                    router_cfg=RouterConfig(handoff_threshold=0))
    reqs = [router.submit(prompts[i], gens[i], session=str(i),
                          sampling=samplings[i], strict=True)
            for i in range(2)]
    victim = reqs[0].hosts[0]
    deadline = time.monotonic() + 120
    while not 0 < len(reqs[0].tokens) < reqs[0].max_new_tokens:
        router.step()                          # harvest a real prefix first
        assert time.monotonic() < deadline
    harvested_prefix = list(reqs[0].tokens)
    fleet[victim].chan.sock.close()            # frames now fail, proc lives
    router.run_until_complete()

    assert [list(r.tokens) for r in reqs] == sequential
    assert reqs[0].tokens[:len(harvested_prefix)] == harvested_prefix
    s = router.stats()["router"]
    assert s["hosts_lost"] == 1 and s["recovered"] >= 1
    router.close()
    assert all(t.proc.poll() is not None for t in fleet)
