"""Property-based tests (hypothesis) on the Tensorizer's invariants:
quantization error bounds, overflow-proof scaling (Eqs. 4-8), tiling
round-trips, integer-snap exactness.

``hypothesis`` is optional: on containers without it, a numpy.random shim
(tests/_hypothesis_fallback.py) generates equivalent random cases so the
suite still collects and the invariants still get exercised."""

import numpy as np
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
    from hypothesis.extra import numpy as hnp
except ImportError:                                    # clean container
    from _hypothesis_fallback import given, settings, st, hnp

from repro.core import tensorizer as tz

settings.register_profile("repro", max_examples=25, deadline=None)
settings.load_profile("repro")

floats = st.floats(min_value=-1e4, max_value=1e4, allow_nan=False, width=32)


def arrays(min_side=1, max_side=24, dims=2):
    return hnp.arrays(np.float32,
                      hnp.array_shapes(min_dims=dims, max_dims=dims,
                                       min_side=min_side, max_side=max_side),
                      elements=floats)


@given(arrays())
def test_quantize_error_bound(x):
    """|dequant(quant(x)) - x| <= scale/2 element-wise (symmetric rounding)."""
    qt = tz.quantize(jnp.asarray(x))
    err = np.abs(np.asarray(qt.dequantize()) - x)
    bound = float(qt.scale) / 2 + 1e-6
    assert err.max() <= bound


@given(arrays())
def test_paper_scales_prevent_overflow(x):
    """Eqs. 5-8: |output| * S <= 1 for the worst-case output of each class."""
    lo, hi = float(x.min()), float(x.max())
    r = abs(hi - lo)
    n = x.shape[-1]
    for kind, worst in [
        (tz.OpKind.MATMUL, r * r * n),      # n products of magnitude <= r^2
        (tz.OpKind.ADD_SUB, 2 * r),
        (tz.OpKind.MUL, r * r),
        (tz.OpKind.ELEMENTWISE, r),
    ]:
        S = float(tz.paper_scale_for(kind, jnp.float32(lo), jnp.float32(hi),
                                     n=n if kind == tz.OpKind.MATMUL else None))
        assert worst * S <= 1.0 + 1e-5


@given(arrays(min_side=2))
def test_partition_reassemble_roundtrip(x):
    tiles = tz.partition(jnp.asarray(x), tile=8)
    back = np.asarray(tz.reassemble(tiles, x.shape[0], x.shape[1]))
    np.testing.assert_array_equal(back, x)


@given(arrays(min_side=2))
def test_ext_crop_roundtrip(x):
    padded = tz.ext(jnp.asarray(x), 16, 16)
    assert padded.shape[0] % 16 == 0 and padded.shape[1] % 16 == 0
    back = np.asarray(tz.crop(padded, x.shape[0], x.shape[1]))
    np.testing.assert_array_equal(back, x)


@given(st.integers(min_value=1, max_value=30), st.integers(min_value=1, max_value=30))
def test_round_up(a, m):
    r = tz.round_up(a, m)
    assert r >= a and r % m == 0 and r - a < m


@given(hnp.arrays(np.int32,
                  hnp.array_shapes(min_dims=2, max_dims=2, min_side=2, max_side=16),
                  elements=st.integers(min_value=-127, max_value=127)))
def test_integer_snap_is_exact(xi):
    """Integer data within +-127 quantizes EXACTLY with snap_integer (the
    mechanism behind the paper's 0.00% Gaussian/LUD rows)."""
    x = xi.astype(np.float32)
    out = np.asarray(tz.fake_quantize(jnp.asarray(x), snap_integer=True))
    np.testing.assert_array_equal(out, x)


@given(st.integers(min_value=1, max_value=8),
       st.integers(min_value=1, max_value=8),
       st.integers(min_value=1, max_value=8))
def test_qdot_error_bound(m, k, n):
    """W8A8 relative error stays within the analytic bound:
    err <= (amax_a/254) * sum|b| + (amax_b/254) * sum|a| per output elem."""
    rng = np.random.default_rng(m * 64 + k * 8 + n)
    a = rng.uniform(-4, 4, (m * 8, k * 8)).astype(np.float32)
    b = rng.uniform(-4, 4, (k * 8, n * 8)).astype(np.float32)
    out = np.asarray(tz.qdot(jnp.asarray(a), jnp.asarray(b)))
    exact = a.astype(np.float64) @ b.astype(np.float64)
    da = np.abs(a).max() / 254.0
    db = np.abs(b).max(axis=0) / 254.0   # per-channel weight scales
    bound = (da * np.abs(b).sum(axis=0)[None, :]
             + np.abs(a).sum(axis=1)[:, None] * db[None, :]
             + da * db * a.shape[1] + 1e-4)
    assert (np.abs(out - exact) <= bound).all()


def test_qdot_paper_no_overflow_large_values():
    """The FBGEMM failure mode (paper Fig. 7): large-magnitude inputs must not
    saturate — output-range-aware scaling keeps relative error ~1%."""
    rng = np.random.default_rng(0)
    for vmax in (2, 32, 128, 1024):
        a = rng.uniform(0, vmax, (64, 64)).astype(np.float32)
        b = rng.uniform(0, vmax, (64, 64)).astype(np.float32)
        out = np.asarray(tz.qdot_paper(jnp.asarray(a), jnp.asarray(b)))
        exact = a.astype(np.float64) @ b.astype(np.float64)
        rmse = np.sqrt(np.mean((out - exact) ** 2)) / (exact.max() - exact.min())
        assert rmse < 0.01, (vmax, rmse)


def test_quantize_params_scan_compatible():
    """Stacked-layer weights keep their leading axis in the scale (so lax.scan
    over quantized params still slices layer-by-layer)."""
    p = {"w": jnp.ones((4, 8, 16)), "norm": jnp.ones((4, 8))}
    q = tz.quantize_params(p, predicate=lambda path, leaf: leaf.ndim == 3)
    assert isinstance(q["w"], tz.QTensor)
    assert q["w"].q.shape == (4, 8, 16) and q["w"].scale.shape == (4, 1, 16)
    assert not isinstance(q["norm"], tz.QTensor)
