"""Speculative draft-verify decode invariants (serving/engine.py
``_spec_decode_once`` + the window verify path in models/serve.py and the
store rollback machinery in serving/store.py):

  * bit-identity — greedy acceptance makes the speculative stream equal to
                   plain decode TOKEN FOR TOKEN and, at retire time, CACHE
                   BIT FOR CACHE BIT, across dense / int8-KV / MoE targets
                   and contiguous / paged-bridge / paged-native backends —
                   a bad draft costs speed, never correctness
  * stops        — EOS and length stops landing MID-WINDOW retire the slot
                   at the stop position: nothing past the stop is emitted
                   or left in the cache, and the overshoot scrub has teeth
                   (forgetting it is detected by the cache-bit check)
  * interplay    — speculative x prefix-cache warm hit, x router drain /
                   handoff, and with a RECURRENT draft (snapshot-selection
                   rollback) all stay bit-identical to plain decode
  * lockstep     — the draft store tracks the target store's per-slot write
                   position through admission, variable advancement,
                   preemption, and retire (token bit-identity alone cannot
                   see draft drift: greedy acceptance is draft-agnostic)
  * conservation — the paged block census survives random accept/reject/
                   retire lifecycles under variable per-slot advancement
"""

import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

sys.path.insert(0, str(Path(__file__).parent))
try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_fallback import given, settings, st

from repro.configs import get_config
from repro.models import init_model
from repro.serving import Engine, EngineConfig, PagedKVStore
from repro.serving.router import Router, RouterConfig
from repro.serving.store import RecurrentStateStore, pristine_value

CFG = get_config("tinyllama-1.1b").smoke()
MOE_CFG = get_config("moonshot-v1-16b-a3b").smoke()
XLSTM_CFG = get_config("xlstm-125m").smoke()
RNG = np.random.default_rng(23)


@pytest.fixture(scope="module")
def params():
    return init_model(CFG, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def moe_params():
    return init_model(MOE_CFG, jax.random.PRNGKey(1))


@pytest.fixture(scope="module")
def bad_draft_params():
    """A draft that disagrees with the target almost everywhere — the
    zero-acceptance worst case (every round advances each slot by 1)."""
    return init_model(CFG, jax.random.PRNGKey(7))


def _spec_kw(draft_cfg=None, k=3):
    return dict(speculative=True, spec_k=k, draft=draft_cfg or CFG)


class SnapshotEngine(Engine):
    """Engine that captures each request's cache row AT RETIRE, before the
    slot reset scrubs it — the cache-bit half of the spec==plain invariant.
    Rows are masked to the slot's leased extent (prompt + max_new): cells
    past the lease read through the shared null block (paged) or untouched
    free-row space, which two runs are free to differ on because no request
    can ever observe them."""

    def __init__(self, *args, **kw):
        super().__init__(*args, **kw)
        self.rows = {}

    def _retire(self, slot):
        req = self.scheduler.active[slot]
        ext = len(req.prompt) + req.max_new_tokens
        view = self.store.gather_view()
        self.rows[req.id] = {
            n: np.asarray(leaf[slot] if n == "index" else leaf[:, slot, :ext])
            for n, leaf in view.items()}
        super()._retire(slot)


def _assert_rows_equal(ra, rb):
    assert set(ra) == set(rb)
    for name in ra:
        np.testing.assert_array_equal(ra[name], rb[name], err_msg=name)


def _serve(eng, prompts, gens, stagger=0):
    """Submit every prompt (optionally stepping between submissions so
    arrivals join a mid-flight batch), run to completion, return streams."""
    reqs = []
    for p, g in zip(prompts, gens):
        reqs.append(eng.submit(p, g, strict=True))
        for _ in range(stagger):
            eng.step()
    eng.run_until_complete()
    return reqs, [list(r.tokens) for r in reqs]


def _traffic(vocab, lens=(6, 12, 9), gens=(6, 4, 5)):
    return ([RNG.integers(0, vocab, (n,), dtype=np.int32) for n in lens],
            list(gens))


# ===========================================================================
# bit-identity: tokens AND retire-time cache bits, across formats/backends
# ===========================================================================

@pytest.mark.parametrize("family,kv_dtype,backend_kw,draft", [
    ("dense", "bfloat16", {}, "good"),
    ("dense", "bfloat16", {}, "bad"),
    ("dense", "int8", {}, "good"),
    ("moe", "bfloat16", {}, "good"),
    ("dense", "bfloat16",
     dict(cache_backend="paged", block_size=8), "good"),
    ("dense", "bfloat16",
     dict(cache_backend="paged", block_size=8, paged_native=True), "bad"),
], ids=["contig-good", "contig-bad", "int8-good", "moe-good",
        "paged-bridge-good", "paged-native-bad"])
def test_spec_matches_plain_tokens_and_cache_bits(
        family, kv_dtype, backend_kw, draft, params, moe_params,
        bad_draft_params):
    """The load-bearing invariant: a speculative engine serving staggered
    traffic emits the same streams AND leaves the same retire-time cache
    bits as plain decode — for float-KV, int8-per-token-scale, and MoE
    targets over every store backend, with both a perfect draft (full
    acceptance) and a disagreeing one (every window rejected)."""
    base = MOE_CFG if family == "moe" else CFG
    cfg = base.replace(kv_cache_dtype=kv_dtype)
    tgt_params = moe_params if family == "moe" else params
    # the "good" draft is the target model itself (full acceptance); the
    # "bad" one shares the architecture but not the weights (full rejection)
    dparams = tgt_params if draft == "good" else bad_draft_params
    kw = dict(max_slots=2, max_seq_len=32, **backend_kw)
    prompts, gens = _traffic(cfg.vocab)

    plain = SnapshotEngine(cfg, tgt_params, EngineConfig(**kw))
    preqs, ptoks = _serve(plain, prompts, gens, stagger=1)
    plain.close()

    spec = SnapshotEngine(cfg, tgt_params,
                          EngineConfig(**kw, **_spec_kw(base)),
                          draft_params=dparams)
    sreqs, stoks = _serve(spec, prompts, gens, stagger=1)
    spec.close()

    assert stoks == ptoks
    for pr, sr in zip(preqs, sreqs):
        _assert_rows_equal(plain.rows[pr.id], spec.rows[sr.id])
    # speculation actually speculated: a perfect draft buys multi-token
    # rounds (steps/decode-token < 1), a hostile one degrades to 1/round
    decoded = sum(gens) - len(gens)
    if draft == "good":
        assert spec.metrics.decode_steps < decoded
        assert spec.metrics.accepted_tokens > 0
    else:
        assert spec.metrics.accepted_tokens == 0
        assert all(length == 1 for length in spec.metrics.accept_hist)


def test_spec_staggered_equals_sequential(params):
    """Batch-join invariance survives variable per-slot advancement: slots
    at different depths sharing a verify window emit exactly what each
    request gets when served alone."""
    prompts, gens = _traffic(CFG.vocab, lens=(6, 12, 9), gens=(7, 4, 6))
    kw = dict(max_slots=2, max_seq_len=32, **_spec_kw())
    seq = Engine(CFG, params, EngineConfig(**kw), draft_params=params)
    solo = []
    for p, g in zip(prompts, gens):
        r = seq.submit(p, g, strict=True)
        seq.run_until_complete()
        solo.append(list(r.tokens))
    seq.close()

    stag = Engine(CFG, params, EngineConfig(**kw), draft_params=params)
    _, stoks = _serve(stag, prompts, gens, stagger=1)
    stag.close()
    assert stoks == solo


def test_spec_prefix_cache_warm_hit_bit_identical(params):
    """Speculative decode over a WARM prefix-cache hit: the suffix-only
    admission seeds both caches, then draft-verify rounds advance through
    COW-forked blocks — tokens and retire bits equal plain decode's."""
    preamble = RNG.integers(0, CFG.vocab, (16,), dtype=np.int32)
    prompt = np.concatenate(
        [preamble, RNG.integers(0, CFG.vocab, (4,), dtype=np.int32)])
    kw = dict(max_slots=2, max_seq_len=32, cache_backend="paged",
              block_size=8, prefix_cache=True)

    def serve_hit(ecfg, dparams=None):
        eng = SnapshotEngine(CFG, params, ecfg, draft_params=dparams)
        eng.submit(preamble, 4, strict=True)          # seeds the trie
        eng.run_until_complete()
        req = eng.submit(prompt, 8, strict=True)
        eng.run_until_complete()
        assert eng.stats()["prefix_hits"] >= 1        # the hit actually hit
        row = eng.rows[req.id]
        eng.close()
        return list(req.tokens), row

    ptoks, prow = serve_hit(EngineConfig(**kw))
    stoks, srow = serve_hit(EngineConfig(**kw, **_spec_kw()), params)
    assert stoks == ptoks
    _assert_rows_equal(prow, srow)


# ===========================================================================
# EOS / length stops landing mid-window
# ===========================================================================

def _pick_mid_window_eos(full):
    """A token whose FIRST occurrence in the stream sits strictly inside an
    accepted window (stream index not a multiple of W=4): stopping there
    forces a truncated round, not a window-boundary retire."""
    return next((i, int(t)) for i, t in enumerate(full)
                if 0 < i < len(full) - 1 and i % 4 != 0
                and full.index(t) == i)


@pytest.mark.parametrize("backend_kw", [
    {}, dict(cache_backend="paged", block_size=8, paged_native=True),
], ids=["contig", "paged-native"])
def test_eos_mid_window_retires_at_stop(backend_kw, params):
    """An EOS inside the accepted window retires the slot AT the stop: no
    token past EOS is emitted, nothing past it survives in the cache (the
    retire row equals plain-with-EOS bit for bit), and for the paged store
    every freed generation block comes back scrubbed."""
    prompt = RNG.integers(0, CFG.vocab, (8,), dtype=np.int32)
    kw = dict(max_slots=2, max_seq_len=32, **backend_kw)

    probe = Engine(CFG, params, EngineConfig(**kw))
    r = probe.submit(prompt, 10, strict=True)
    probe.run_until_complete()
    full = list(r.tokens)
    probe.close()
    stop, eos = _pick_mid_window_eos(full)

    plain = SnapshotEngine(CFG, params, EngineConfig(**kw, eos_id=eos))
    rp = plain.submit(prompt, 10, strict=True)
    plain.run_until_complete()
    plain.close()

    spec = SnapshotEngine(CFG, params, EngineConfig(**kw, eos_id=eos,
                                                    **_spec_kw()),
                          draft_params=params)
    rs = spec.submit(prompt, 10, strict=True)
    spec.run_until_complete()

    assert list(rs.tokens) == list(rp.tokens) == full[:stop + 1]
    assert rs.tokens[-1] == eos and eos not in rs.tokens[:-1]
    # the stop round truncated mid-window (emitted stop % 4 < W tokens)
    assert spec.metrics.accept_hist.get(stop % 4, 0) >= 1
    _assert_rows_equal(plain.rows[rp.id], spec.rows[rs.id])
    if backend_kw:
        # with every slot retired, all blocks but the shared null block (0,
        # the write sink for out-of-lease redirects) must be back to the
        # pristine fill — freed mid-window blocks included
        store = spec.store
        assert not store._leased
        for name, leaf in store.cache.items():
            if name in ("index", "tables"):
                continue
            assert np.all(np.asarray(leaf[:, 1:]) == pristine_value(name)), \
                name
    spec.close()


@pytest.mark.parametrize("backend_kw", [
    {}, dict(cache_backend="paged", block_size=8, paged_native=True),
], ids=["contig", "paged-native"])
def test_eos_overshoot_scrub_has_teeth(backend_kw, params):
    """The rejected-position scrub is load-bearing for the cache-bit
    invariant: replay the would-be bug (rollback updates indices but
    FORGETS to scrub past the stop) and the retire-row comparison must
    catch the leaked draft K/V — proof an overshoot would be detected."""
    prompt = RNG.integers(0, CFG.vocab, (8,), dtype=np.int32)
    kw = dict(max_slots=2, max_seq_len=32, **backend_kw)

    probe = Engine(CFG, params, EngineConfig(**kw))
    r = probe.submit(prompt, 10, strict=True)
    probe.run_until_complete()
    stop, eos = _pick_mid_window_eos(list(r.tokens))
    probe.close()

    plain = SnapshotEngine(CFG, params, EngineConfig(**kw, eos_id=eos))
    rp = plain.submit(prompt, 10, strict=True)
    plain.run_until_complete()
    plain.close()

    spec = SnapshotEngine(CFG, params, EngineConfig(**kw, eos_id=eos,
                                                    **_spec_kw()),
                          draft_params=params)
    forgot = spec.store.rollback

    def no_scrub(slots, new_index, positions):
        # indices advance correctly, but every scrub position is replaced
        # by the out-of-range pad — nothing gets cleaned
        forgot(slots, new_index,
               np.full_like(np.asarray(positions), spec.ecfg.max_seq_len))

    spec.store.rollback = no_scrub
    rs = spec.submit(prompt, 10, strict=True)
    spec.run_until_complete()
    spec.close()
    with pytest.raises(AssertionError):
        _assert_rows_equal(plain.rows[rp.id], spec.rows[rs.id])


# ===========================================================================
# interplay: router drain/handoff, recurrent draft
# ===========================================================================

def test_spec_session_survives_router_drain(params):
    """Drain handoff between SPECULATIVE engines mid-generation: the
    preempted continuation re-admits (target + draft caches re-seeded from
    prompt + tokens-so-far) and the stitched stream equals an undrained
    speculative serve — which other tests pin to plain decode."""
    ecfg = EngineConfig(max_slots=1, max_seq_len=32, **_spec_kw())
    prompt = RNG.integers(0, CFG.vocab, (12,), dtype=np.int32)

    ref = Engine(CFG, params, ecfg, draft_params=params)
    r0 = ref.submit(prompt, 10, strict=True)
    ref.run_until_complete()
    ref.close()

    router = Router(CFG, params, ecfg,
                    RouterConfig(n_hosts=2, handoff_threshold=0),
                    draft_params=params)
    r = router.submit(prompt, 10, session="a", strict=True)
    for _ in range(2):
        router.step()
    router.drain(r.hosts[0])                      # preempt mid-generation
    while router.has_work():
        router.step()
    assert router.stats()["router"]["handoffs"] >= 1
    assert len(r.hosts) > 1
    assert r.tokens == list(r0.tokens)            # bit-identical stitched
    router.close()


def test_recurrent_draft_bit_identical_and_lockstep(params):
    """A RECURRENT draft (state snapshots instead of K/V rollback) drives
    the same stream as plain decode, and its per-slot write position stays
    in lockstep with the target store at every step — token bit-identity
    alone cannot see draft drift, so lockstep is asserted directly."""
    assert XLSTM_CFG.vocab == CFG.vocab
    dparams = init_model(XLSTM_CFG, jax.random.PRNGKey(3))
    prompts, gens = _traffic(CFG.vocab, lens=(6, 11), gens=(8, 5))
    kw = dict(max_slots=2, max_seq_len=32)

    plain = Engine(CFG, params, EngineConfig(**kw))
    _, ptoks = _serve(plain, prompts, gens, stagger=1)
    plain.close()

    spec = Engine(CFG, params,
                  EngineConfig(**kw, **_spec_kw(XLSTM_CFG, k=2)),
                  draft_params=dparams)
    reqs = [spec.submit(p, g, strict=True) for p, g in zip(prompts, gens)]
    while spec.scheduler.has_work():
        spec.step()
        for slot in spec.scheduler.active:
            assert (spec.draft_store.slot_index(slot)
                    == spec.store.slot_index(slot))
    spec.close()
    assert [list(r.tokens) for r in reqs] == ptoks


def test_adopt_selected_picks_per_slot_snapshot():
    """RecurrentStateStore.adopt_selected unit: with snapshots filled by
    their list position, each slot's row must come out equal to its sel
    index — the per-slot gather over the stacked snapshot axis that
    implements recurrent-draft rollback."""
    store = RecurrentStateStore(XLSTM_CFG, n_slots=3, max_seq_len=8)
    snaps = [jax.tree.map(lambda leaf, i=i: jnp.full_like(leaf, i),
                          store.cache) for i in range(4)]
    sel = [2, 0, 3]
    store.adopt_selected(snaps, sel)
    for name, leaf in store.cache.items():
        arr = np.asarray(leaf)
        for slot, s in enumerate(sel):
            row = arr[slot] if name == "index" else arr[:, slot]
            assert np.all(row == s), (name, slot, s)


# ===========================================================================
# lockstep + conservation under the full lifecycle
# ===========================================================================

def test_spec_lifecycle_lockstep_preempt_conservation(params):
    """Speculative engine over the paged prefix-cache backend with a
    mid-run preemption: after EVERY step the block census partitions the
    pool, the draft store tracks the target store per slot, and the
    device-side write position agrees with host arithmetic
    (prompt + generated - 1). Completed streams still match plain decode;
    the preempted stream is a prefix of its plain serve."""
    sys.path  # noqa: B018  (keep flake quiet about the shim import above)
    from test_prefix_cache import _census_ok

    ecfg = EngineConfig(max_slots=2, max_seq_len=32, cache_backend="paged",
                        block_size=8, prefix_cache=True, **_spec_kw())
    eng = Engine(CFG, params, ecfg, draft_params=params)
    prompts, gens = _traffic(CFG.vocab, lens=(6, 11, 8), gens=(8, 5, 7))
    reqs = [eng.submit(p, g, strict=True) for p, g in zip(prompts, gens)]
    preempted = None
    steps = 0
    while eng.scheduler.has_work():
        eng.step()
        steps += 1
        _census_ok(eng.store)
        for slot, req in eng.scheduler.active.items():
            assert (eng.draft_store.slot_index(slot)
                    == eng.store.slot_index(slot))
            assert (eng.store.slot_index(slot)
                    == len(req.prompt) + req.metrics.n_generated - 1)
        if steps == 2 and preempted is None and eng.scheduler.active:
            victim = next(iter(eng.scheduler.active.values()))
            preempted = eng.preempt(victim.id)
            _census_ok(eng.store)
    eng.close()

    plain = Engine(CFG, params, EngineConfig(max_slots=2, max_seq_len=32))
    for req, prompt, gen in zip(reqs, prompts, gens):
        ref = plain.submit(prompt, gen, strict=True)
        plain.run_until_complete()
        if preempted is not None and req.id == preempted.id:
            got = list(req.tokens)              # cut short mid-generation
            assert got == list(ref.tokens)[:len(got)]
        else:
            assert list(req.tokens) == list(ref.tokens)
    plain.close()


@settings(deadline=None, max_examples=10)
@given(st.integers(min_value=0, max_value=1 << 30))
def test_block_conservation_under_variable_advancement(seed):
    """Property test: random lease / speculative-rollback / retire / drain
    sequences — with rollback plans whose scrub windows overshoot both the
    lease and the sequence bound, exactly as variable per-slot advancement
    produces them — keep the free / referenced / cached-unreferenced pool
    partition exact after every operation."""
    from test_prefix_cache import _census_ok

    rng = np.random.default_rng(seed)
    cfg = get_config("tinyllama-1.1b").smoke()
    store = PagedKVStore(cfg, n_slots=3, max_seq_len=16, block_size=4,
                         n_blocks=10, prefix_cache=True)
    k = 3
    extents = {}
    _census_ok(store)
    for _ in range(60):
        op = int(rng.integers(0, 5))
        if op in (0, 3):                          # lease (+ maybe commit)
            slot = int(rng.integers(0, 3))
            if slot in store._leased:
                continue
            plen = int(rng.integers(1, 13))
            gen = int(rng.integers(1, 17 - plen))
            tokens = rng.integers(0, 3, (plen,), dtype=np.int32)
            if store.lease(slot, plen, gen, tokens=tokens):
                extents[slot] = (plen, plen + gen)
                if op == 0:
                    store.commit_prefix(slot)
        elif op == 1:                             # retire one leased slot
            leased = sorted(store._leased)
            if leased:
                s = int(rng.choice(leased))
                store.reset(s)
                extents.pop(s, None)
        elif op == 4:                             # speculative rollback
            leased = sorted(store._leased)
            if not leased:
                continue
            slots = np.full((3,), 3, np.int64)    # pad: dropped
            new_index = np.zeros((3,), np.int64)
            scrub = np.full((3, k), 16, np.int64)
            for s in leased:
                plen, ext = extents[s]
                p = int(rng.integers(plen - 1, ext))
                emit = int(rng.integers(1, k + 2))
                slots[s] = s
                new_index[s] = min(p + emit, ext)
                # deliberately overshoots the lease and max_seq_len: the
                # null-block redirect must absorb it
                scrub[s] = p + emit + np.arange(k)
            store.rollback(slots, new_index, scrub)
        else:                                     # drain
            for s in sorted(store._leased):
                store.reset(s)
            extents.clear()
        _census_ok(store)
    for s in sorted(store._leased):
        store.reset(s)
    _census_ok(store)


# ===========================================================================
# dispatch-shape audit + metrics reconciliation + config validation
# ===========================================================================

def test_spec_opq_flags_and_metrics_reconcile(params):
    """A speculative engine's OPQ flag set is exactly {prefill, draft
    prefill, draft decode, verify} — no plain decode sneaks in — with
    counts that reconcile against the metrics, and the token counters are
    accepted-token based: steps per decode token lands strictly below 1
    with a perfect draft."""
    kw = dict(max_slots=2, max_seq_len=32, **_spec_kw())
    eng = Engine(CFG, params, EngineConfig(**kw), draft_params=params)
    prompts, gens = _traffic(CFG.vocab, lens=(6, 12), gens=(7, 5))
    reqs, _ = _serve(eng, prompts, gens)
    s = eng.stats()
    eng.close()

    flags = s["opq"]["flags"]
    assert set(flags) == {"prefill/16", "draft_prefill/16",
                          "draft_decode", "verify"}
    assert flags["verify"] == s["decode_steps"] == s["spec_rounds"]
    assert flags["draft_decode"] == s["draft_steps"]
    assert s["draft_steps"] == (eng.ecfg.spec_k + 1) * s["spec_rounds"]

    # token accounting reconciles: every emitted token counted once
    assert s["tokens_generated"] == sum(r.metrics.n_generated for r in reqs)
    decoded = s["tokens_generated"] - s["completed"]     # minus first tokens
    slot_rounds = sum(s["accept_hist"].values())
    assert decoded == s["accepted_tokens"] + slot_rounds
    assert s["proposed_tokens"] == eng.ecfg.spec_k * slot_rounds
    assert s["acceptance_rate"] == pytest.approx(
        s["accepted_tokens"] / s["proposed_tokens"])
    assert s["decode_steps"] < decoded           # the whole point


def test_plain_engine_flag_set_unchanged(params):
    """Guard: a NON-speculative engine's dispatch shapes are untouched by
    the spec machinery — exactly one prefill flag per bucket plus plain
    decode, nothing draft- or verify-shaped."""
    eng = Engine(CFG, params, EngineConfig(max_slots=2, max_seq_len=32))
    prompts, gens = _traffic(CFG.vocab, lens=(6, 12), gens=(4, 4))
    _serve(eng, prompts, gens)
    flags = eng.stats()["opq"]["flags"]
    eng.close()
    assert set(flags) == {"prefill/16", "decode"}


def test_spec_config_validation(params):
    base = dict(max_slots=1, max_seq_len=32)
    with pytest.raises(ValueError, match="draft model"):
        Engine(CFG, params, EngineConfig(**base, speculative=True))
    with pytest.raises(ValueError, match="speculative=False"):
        Engine(CFG, params, EngineConfig(**base, draft=CFG))
    with pytest.raises(ValueError, match="spec_k"):
        Engine(CFG, params,
               EngineConfig(**base, **_spec_kw(k=0)), draft_params=params)
    with pytest.raises(ValueError, match="vocab"):
        Engine(CFG, params,
               EngineConfig(**base, speculative=True,
                            draft=CFG.replace(vocab=CFG.vocab * 2)),
               draft_params=params)
    with pytest.raises(ValueError, match="TARGET"):
        Engine(XLSTM_CFG, params,
               EngineConfig(**base, **_spec_kw()), draft_params=params)
    with pytest.raises(ValueError, match="paged_kernel|kernel"):
        Engine(CFG, params,
               EngineConfig(**base, cache_backend="paged", paged_native=True,
                            paged_kernel=True, **_spec_kw()),
               draft_params=params)


def test_spec_round_donation_gated_off_cpu():
    """Regression pin for the jax 0.4.37 XLA:CPU donation race: an executable
    deserialized from the persistent compilation cache can signal completion
    before its donated in-place writes land, so the rollback scrub dispatched
    right after a verify races the verify's own tail writes. The gate must
    disable donation on CPU (correctness) and keep it everywhere else (the
    no-copy verify round is the perf point). If a jax upgrade fixes the
    runtime, this test is the reminder to re-measure before re-enabling."""
    from repro.serving.engine import _spec_round_donate
    assert _spec_round_donate() == (jax.default_backend() != "cpu")
