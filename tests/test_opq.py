"""OPQ runtime: OpenCtpu semantics, affinity/FCFS scheduling, straggler
backup re-issue (paper §6.1)."""

import time

import numpy as np
import pytest

from repro.core import instr as I
from repro.core.opq import OPQ, Buffer, Instruction, _StragglerTimeout

RNG = np.random.default_rng(3)


def _mat(n=16):
    return Buffer(RNG.normal(size=(n, n)).astype(np.float32))


def test_enqueue_sync_wait():
    q = OPQ()
    a, b = _mat(), _mat()
    tid = q.enqueue(lambda invoke, x, y: invoke(I.add_fp, x, y), a, b)
    res = q.wait(tid)
    np.testing.assert_allclose(np.asarray(res[0]), a.data + b.data, rtol=1e-6)
    q.shutdown()


def test_tasks_run_out_of_order_but_serialize_within_task():
    """Operators within a task serialize; tasks are independent (paper §5)."""
    q = OPQ()
    order = []

    def kernel(invoke, x, y):
        invoke(lambda u, v: order.append("op1") or u + v, x, y)
        invoke(lambda u, v: order.append("op2") or u - v, x, y)

    tid = q.enqueue(kernel, _mat(), _mat())
    q.wait(tid)
    assert order == ["op1", "op2"]
    q.shutdown()


def test_affinity_scheduling():
    """Instructions sharing a resident buffer go to the same device."""
    q = OPQ()
    a, b = _mat(), _mat()
    q.invoke_operator(I.add_fp, a, b)
    q.sync()
    # second op on the same buffers must hit the affinity path
    q.invoke_operator(I.mul_fp, a, b)
    q.sync()
    assert q.stats["affinity_hits"] >= 1
    q.shutdown()


def test_multi_task_parallel_results():
    q = OPQ()
    bufs = [_mat() for _ in range(8)]
    tids = [q.enqueue(lambda invoke, x, y: invoke(I.sub_fp, x, y), bufs[i], bufs[i + 1])
            for i in range(0, 8, 2)]
    res = q.sync()
    assert sorted(res) == sorted(tids)
    for i, tid in enumerate(tids):
        np.testing.assert_allclose(
            np.asarray(res[tid][0]), bufs[2 * i].data - bufs[2 * i + 1].data, rtol=1e-6)
    q.shutdown()


def test_straggler_backup_reissue():
    """An injected straggling executor triggers the backup-task policy."""
    calls = {"n": 0}

    def flaky_executor(ins: Instruction, device):
        calls["n"] += 1
        if calls["n"] == 1:                       # first attempt straggles
            raise _StragglerTimeout()
        return OPQ._default_executor(ins, device)

    q = OPQ(executor=flaky_executor)
    a, b = _mat(), _mat()
    fut = q.invoke_operator(I.add_fp, a, b)
    out = fut.result()
    np.testing.assert_allclose(np.asarray(out), a.data + b.data, rtol=1e-6)
    assert q.stats["backups_issued"] == 1
    assert calls["n"] == 2                        # original + backup
    q.shutdown()


def test_fcfs_least_loaded():
    """Without affinity, picks the least-loaded lane (trivial with 1 device,
    but the policy function must still return a lane)."""
    q = OPQ()
    lane, aff = q._pick_lane(Instruction(0, I.add_fp, (_mat(), _mat())))
    assert lane in q.lanes and aff is False
    q.shutdown()
