"""OPQ runtime: OpenCtpu semantics, affinity/FCFS scheduling, straggler
backup re-issue (paper §6.1)."""

import time

import jax
import numpy as np
import pytest

from repro.core import instr as I
from repro.core.opq import OPQ, Buffer, Instruction, _StragglerTimeout

RNG = np.random.default_rng(3)


def _mat(n=16):
    return Buffer(RNG.normal(size=(n, n)).astype(np.float32))


def test_enqueue_sync_wait():
    q = OPQ()
    a, b = _mat(), _mat()
    tid = q.enqueue(lambda invoke, x, y: invoke(I.add_fp, x, y), a, b)
    res = q.wait(tid)
    np.testing.assert_allclose(np.asarray(res[0]), a.data + b.data, rtol=1e-6)
    q.shutdown()


def test_tasks_run_out_of_order_but_serialize_within_task():
    """Operators within a task serialize; tasks are independent (paper §5)."""
    q = OPQ()
    order = []

    def kernel(invoke, x, y):
        invoke(lambda u, v: order.append("op1") or u + v, x, y)
        invoke(lambda u, v: order.append("op2") or u - v, x, y)

    tid = q.enqueue(kernel, _mat(), _mat())
    q.wait(tid)
    assert order == ["op1", "op2"]
    q.shutdown()


def test_affinity_scheduling():
    """Instructions sharing a resident buffer go to the same device."""
    q = OPQ()
    a, b = _mat(), _mat()
    q.invoke_operator(I.add_fp, a, b)
    q.sync()
    # second op on the same buffers must hit the affinity path
    q.invoke_operator(I.mul_fp, a, b)
    q.sync()
    assert q.stats["affinity_hits"] >= 1
    q.shutdown()


def test_multi_task_parallel_results():
    q = OPQ()
    bufs = [_mat() for _ in range(8)]
    tids = [q.enqueue(lambda invoke, x, y: invoke(I.sub_fp, x, y), bufs[i], bufs[i + 1])
            for i in range(0, 8, 2)]
    res = q.sync()
    assert sorted(res) == sorted(tids)
    for i, tid in enumerate(tids):
        np.testing.assert_allclose(
            np.asarray(res[tid][0]), bufs[2 * i].data - bufs[2 * i + 1].data, rtol=1e-6)
    q.shutdown()


def test_straggler_backup_reissue():
    """An injected straggling executor triggers the backup-task policy."""
    calls = {"n": 0}

    def flaky_executor(ins: Instruction, device):
        calls["n"] += 1
        if calls["n"] == 1:                       # first attempt straggles
            raise _StragglerTimeout()
        return OPQ._default_executor(ins, device)

    q = OPQ(executor=flaky_executor)
    a, b = _mat(), _mat()
    fut = q.invoke_operator(I.add_fp, a, b)
    out = fut.result()
    np.testing.assert_allclose(np.asarray(out), a.data + b.data, rtol=1e-6)
    assert q.stats["backups_issued"] == 1
    assert calls["n"] == 2                        # original + backup
    q.shutdown()


def test_fcfs_least_loaded():
    """Without affinity, picks the least-loaded lane (trivial with 1 device,
    but the policy function must still return a lane)."""
    q = OPQ()
    lane, aff = q._pick_lane(Instruction(0, I.add_fp, (_mat(), _mat())))
    assert lane in q.lanes and aff is False
    q.shutdown()


def test_affinity_hit_accounting_exact():
    """issued/affinity_hits reconcile: first touch of a buffer pair is a miss,
    every follow-up instruction on the now-resident buffers is a hit."""
    q = OPQ()
    a, b = _mat(), _mat()
    q.invoke_operator(I.add_fp, a, b)
    q.sync()
    n_follow = 5
    for _ in range(n_follow):
        q.invoke_operator(I.mul_fp, a, b)
        q.sync()
    assert q.stats["issued"] == 1 + n_follow
    assert q.stats["affinity_hits"] == n_follow
    q.shutdown()


def test_wait_is_per_task_sync_is_global():
    """``wait(tid)`` blocks on exactly that task's instructions; ``sync``
    drains everything and groups results by task id — including tasks already
    waited on (idempotent re-read of their futures)."""
    q = OPQ()
    pairs = [(_mat(), _mat()) for _ in range(3)]
    tids = [q.enqueue(lambda invoke, x, y: invoke(I.add_fp, x, y), a, b)
            for a, b in pairs]
    # wait on the middle task only: its result is complete and correct even
    # though the other tasks may still be in flight
    res1 = q.wait(tids[1])
    np.testing.assert_allclose(np.asarray(res1[0]),
                               pairs[1][0].data + pairs[1][1].data, rtol=1e-6)
    out = q.sync()
    assert sorted(out) == sorted(tids)
    for tid, (a, b) in zip(tids, pairs):
        np.testing.assert_allclose(np.asarray(out[tid][0]), a.data + b.data,
                                   rtol=1e-6)
    # wait after sync is a no-op re-read, same values
    res_again = q.wait(tids[1])
    np.testing.assert_allclose(np.asarray(res_again[0]),
                               np.asarray(res1[0]), rtol=0)
    q.shutdown()


def test_wait_on_unknown_task_returns_empty():
    q = OPQ()
    assert q.wait(12345) == []
    q.shutdown()


def test_untracked_invoke_does_not_accumulate_futures():
    """track=False (the serving engine's mode) must not grow the task-futures
    registry — a long-running engine would otherwise leak every step result."""
    q = OPQ()
    a, b = _mat(), _mat()
    futs = [q.invoke_operator(I.add_fp, a, b, track=False) for _ in range(6)]
    for f in futs:
        np.testing.assert_allclose(np.asarray(f.result()), a.data + b.data,
                                   rtol=1e-6)
    assert len(q._task_futures) == 0
    assert q.sync() == {}
    assert q.stats["issued"] == 6          # still scheduled/accounted normally
    q.shutdown()


def test_straggler_detection_with_injected_slow_executor():
    """A wall-clock-slow executor (not an exception) on a multi-lane queue
    trips the post-hoc straggler detector: the lane's EMA service time is
    warmed up by fast instructions, then one instruction blows through
    ``straggler_factor`` x EMA and is recorded."""
    devices = [jax.devices()[0]] * 2               # two lanes, one CPU device
    calls = {"n": 0}

    def slow_once_executor(ins: Instruction, device):
        calls["n"] += 1
        if calls["n"] == 8:                        # straggle late, post-warmup
            time.sleep(0.25)
        return OPQ._default_executor(ins, device)

    q = OPQ(devices=devices, straggler_factor=2.0, executor=slow_once_executor)
    a, b = _mat(4), _mat(4)
    for _ in range(8):
        q.invoke_operator(I.add_fp, a, b)
        q.sync()                                   # serialize: stable EMA
    assert q.stats.get("stragglers_detected", 0) >= 1
    assert q.stats["issued"] == 8
    q.shutdown()


def test_backup_reissue_result_correct_under_repeated_straggling():
    """Every instruction straggles on first attempt; the backup path must
    still return correct results for all of them."""
    attempts = {}

    def flaky(ins: Instruction, device):
        # key by task id, not id(ins): object ids get reused after GC
        attempts[ins.task_id] = attempts.get(ins.task_id, 0) + 1
        if attempts[ins.task_id] == 1:
            raise _StragglerTimeout()
        return OPQ._default_executor(ins, device)

    q = OPQ(executor=flaky)
    bufs = [(_mat(), _mat()) for _ in range(4)]
    futs = [q.invoke_operator(I.sub_fp, a, b) for a, b in bufs]
    for fut, (a, b) in zip(futs, bufs):
        np.testing.assert_allclose(np.asarray(fut.result()), a.data - b.data,
                                   rtol=1e-6)
    assert q.stats["backups_issued"] == 4
    q.shutdown()
