"""MoE dispatch correctness vs a run-everything oracle + gradient compression
error-feedback properties."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import moe as MOE
from repro.optim.compress import compress_grads, decompress_grads, init_error_feedback


def _moe_oracle(p, x, cfg):
    """Reference: run EVERY expert on every token, combine with the same
    normalized top-k gates, no capacity limit."""
    B, S, D = x.shape
    xf = x.reshape(B * S, D).astype(jnp.float32)
    scores = xf @ p["router"]
    gate, ids = jax.lax.top_k(scores, cfg.topk)
    gate = jax.nn.softmax(gate, axis=-1)
    # (T, E) combine weights
    comb = jnp.zeros((B * S, cfg.n_experts))
    comb = comb.at[jnp.arange(B * S)[:, None], ids].add(gate)
    h = jnp.einsum("td,edf->tef", xf, p["wi"].astype(jnp.float32))
    h = jax.nn.silu(h) * jnp.einsum("td,edf->tef", xf, p["wg"].astype(jnp.float32))
    y_all = jnp.einsum("tef,efd->ted", h, p["wo"].astype(jnp.float32))
    y = jnp.einsum("ted,te->td", y_all, comb)
    return y.reshape(B, S, D)


@pytest.mark.slow
def test_moe_dispatch_matches_oracle():
    """With ample capacity, the sort-free cumsum dispatch must equal the
    run-every-expert oracle exactly (no drops, exact combine weights)."""
    cfg = get_config("deepseek_moe_16b").smoke().replace(
        n_experts=4, topk=2, capacity_factor=4.0, n_shared_experts=0,
        dtype="float32")
    key = jax.random.PRNGKey(0)
    p = MOE.init_moe(key, cfg, cfg.d_model)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model), jnp.float32)
    y, aux = MOE.apply_moe(p, x, cfg)
    y_ref = _moe_oracle(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=2e-3, atol=2e-3)
    assert float(aux) > 0.0


@pytest.mark.slow
def test_moe_capacity_drops_tokens():
    """With capacity 1 slot/expert, most tokens drop — outputs shrink but
    stay finite (the Switch-style bounded-capacity contract)."""
    cfg = get_config("deepseek_moe_16b").smoke().replace(
        n_experts=4, topk=2, capacity_factor=0.05, n_shared_experts=0,
        dtype="float32")
    p = MOE.init_moe(jax.random.PRNGKey(0), cfg, cfg.d_model)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model), jnp.float32)
    y, _ = MOE.apply_moe(p, x, cfg)
    y_ref = _moe_oracle(p, x, cfg)
    assert bool(jnp.all(jnp.isfinite(y)))
    assert float(jnp.linalg.norm(y)) < float(jnp.linalg.norm(y_ref))


class TestGradCompression:
    def test_roundtrip_error_bounded(self):
        g = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(64, 64)), jnp.float32)}
        q, ef = compress_grads(g)
        back = decompress_grads(q)
        err = float(jnp.abs(back["w"] - g["w"]).max())
        assert err <= float(q["w"].scale) / 2 + 1e-6
        # error feedback holds exactly the residual
        np.testing.assert_allclose(
            np.asarray(ef["w"]), np.asarray(g["w"] - back["w"]), rtol=1e-6, atol=1e-7)

    def test_error_feedback_unbiased_over_steps(self):
        """Accumulated (decompressed + carried) signal converges to the true
        sum of gradients — the EF property that makes int8 reduction safe."""
        rng = np.random.default_rng(1)
        g_const = {"w": jnp.asarray(rng.normal(size=(32,)), jnp.float32)}
        ef = init_error_feedback(g_const)
        total = jnp.zeros((32,))
        steps = 50
        for _ in range(steps):
            q, ef = compress_grads(g_const, ef)
            total = total + decompress_grads(q)["w"]
        # mean applied update ~= true gradient (residual bounded by one scale)
        mean_applied = total / steps
        err = float(jnp.abs(mean_applied - g_const["w"]).max())
        assert err < float(q["w"].scale) / steps * 2 + 1e-5

    def test_wire_bytes_4x_smaller(self):
        g = {"w": jnp.zeros((1024, 1024), jnp.float32)}
        q, _ = compress_grads(g)
        assert q["w"].q.dtype == jnp.int8
        assert q["w"].q.size * 1 == g["w"].size  # 1 byte/elem vs 4
