"""Data pipeline determinism/sharding/resume + optimizer behavior."""

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.configs.base import ShapeCfg
from repro.data import SyntheticLM, make_dataset
from repro.optim import adamw_init, adamw_update, clip_by_global_norm, cosine_schedule


class TestData:
    def test_deterministic(self):
        a = SyntheticLM(vocab=100, seq_len=8, global_batch=4)
        b = SyntheticLM(vocab=100, seq_len=8, global_batch=4)
        np.testing.assert_array_equal(next(a)["tokens"], next(b)["tokens"])

    def test_shards_disjoint_and_cover(self):
        full = SyntheticLM(vocab=100, seq_len=8, global_batch=4, num_shards=1)
        s0 = SyntheticLM(vocab=100, seq_len=8, global_batch=4, shard_id=0, num_shards=2)
        s1 = SyntheticLM(vocab=100, seq_len=8, global_batch=4, shard_id=1, num_shards=2)
        b0, b1 = next(s0), next(s1)
        assert b0["tokens"].shape == (2, 8) and b1["tokens"].shape == (2, 8)
        assert not np.array_equal(b0["tokens"], b1["tokens"])

    def test_resume_reproduces_stream(self):
        ds = SyntheticLM(vocab=100, seq_len=8, global_batch=4)
        next(ds); next(ds)
        state = ds.state()
        expected = next(ds)["tokens"]
        ds2 = SyntheticLM(vocab=100, seq_len=8, global_batch=4)
        ds2.restore(state)
        np.testing.assert_array_equal(next(ds2)["tokens"], expected)

    def test_memmap_dataset(self, tmp_path):
        toks = np.arange(1024, dtype=np.uint16) % 100
        p = tmp_path / "tokens.bin"
        toks.tofile(p)
        cfg = get_config("tinyllama-1.1b").smoke()
        ds = make_dataset(cfg, ShapeCfg("t", 16, 4, "train"), path=str(p))
        b = next(ds)
        assert b["tokens"].shape == (4, 16)
        assert (b["tokens"] < 100).all()


class TestOptim:
    def test_adamw_converges_on_quadratic(self):
        params = {"x": jnp.asarray([5.0, -3.0])}
        state = adamw_init(params)
        for _ in range(300):
            grads = {"x": 2 * params["x"]}
            params, state = adamw_update(params, grads, state, 0.1, weight_decay=0.0)
        assert float(jnp.abs(params["x"]).max()) < 0.05

    def test_clip_by_global_norm(self):
        g = {"a": jnp.full((10,), 10.0)}
        clipped, gn = clip_by_global_norm(g, 1.0)
        assert abs(float(gn) - np.sqrt(1000.0)) < 1e-3
        norm_after = float(jnp.linalg.norm(clipped["a"]))
        assert abs(norm_after - 1.0) < 1e-4

    def test_weight_decay_skips_1d(self):
        params = {"w": jnp.ones((4, 4)), "b": jnp.ones((4,))}
        state = adamw_init(params)
        zero_grads = jax.tree.map(jnp.zeros_like, params)
        new, _ = adamw_update(params, zero_grads, state, 0.1, weight_decay=0.5)
        assert float(new["w"].max()) < 1.0          # decayed
        assert float(new["b"].max()) == 1.0         # not decayed

    def test_cosine_schedule(self):
        assert float(cosine_schedule(jnp.asarray(0), peak=1.0, warmup=10)) == 0.0
        assert abs(float(cosine_schedule(jnp.asarray(10), peak=1.0, warmup=10)) - 1.0) < 1e-5
        late = float(cosine_schedule(jnp.asarray(10000), peak=1.0, warmup=10, total=10000))
        assert late <= 0.11
