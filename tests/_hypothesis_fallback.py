"""Minimal numpy.random stand-in for the slice of the hypothesis API that
tests/test_tensorizer.py uses, so the property tests still run (with random
rather than adversarially-shrunk cases) on containers without the package.

Drop-in for: ``given``, ``settings``, ``strategies.floats/integers``,
``hypothesis.extra.numpy.arrays/array_shapes``. Each ``@given`` test runs
``N_EXAMPLES`` times on a per-test deterministic seed.
"""

from __future__ import annotations

import zlib

import numpy as np

N_EXAMPLES = 10      # enough cases to exercise the invariants without
                     # paying a fresh XLA compile for 25 distinct shapes


class _Strategy:
    def __init__(self, sample_fn):
        self._sample = sample_fn

    def example(self, rng: np.random.Generator):
        return self._sample(rng)


class settings:                                          # noqa: N801
    """API-compatible no-op (profiles only tune example counts/deadlines)."""

    def __init__(self, *a, **kw):
        pass

    @staticmethod
    def register_profile(name, *a, **kw):
        pass

    @staticmethod
    def load_profile(name):
        pass

    def __call__(self, fn):
        return fn


def given(*strategies):
    def deco(fn):
        # zero-arg wrapper (no functools.wraps: pytest must NOT see the
        # wrapped signature, or it would treat strategy args as fixtures)
        def wrapper():
            # stable per-test seed: same cases every run, distinct per test
            rng = np.random.default_rng(zlib.crc32(fn.__name__.encode()))
            for _ in range(N_EXAMPLES):
                fn(*(s.example(rng) for s in strategies))
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        return wrapper
    return deco


class _St:
    @staticmethod
    def floats(min_value=-1e9, max_value=1e9, allow_nan=False, width=64, **kw):
        dt = np.float32 if width == 32 else np.float64
        return _Strategy(lambda rng: dt(rng.uniform(min_value, max_value)))

    @staticmethod
    def integers(min_value=0, max_value=1 << 30):
        return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))


class _Hnp:
    @staticmethod
    def array_shapes(min_dims=1, max_dims=3, min_side=1, max_side=10):
        def sample(rng):
            nd = int(rng.integers(min_dims, max_dims + 1))
            return tuple(int(rng.integers(min_side, max_side + 1))
                         for _ in range(nd))
        return _Strategy(sample)

    @staticmethod
    def arrays(dtype, shape, elements=None):
        def sample(rng):
            shp = shape.example(rng) if isinstance(shape, _Strategy) else shape
            if elements is None:
                return rng.standard_normal(shp).astype(dtype)
            flat = [elements.example(rng) for _ in range(int(np.prod(shp)))]
            return np.asarray(flat, dtype=dtype).reshape(shp)
        return _Strategy(sample)


st = _St()
hnp = _Hnp()
