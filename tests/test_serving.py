"""Continuous-batching engine invariants (serving/engine.py):

  * isolation   — a request's tokens never leak into another slot: staggered
                  mixed-traffic outputs are BIT-IDENTICAL to one-at-a-time
                  sequential decoding of the same requests
  * slot reuse  — retired slots are re-leased without reallocating the cache
  * metrics     — engine counters reconcile with per-request token counts
  * admission   — the bounded queue and the per-slot sequence budget reject
  * int8 KV     — the slot manager carries the Tensorizer int8 cache scales
"""

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import init_model
from repro.serving import (
    Engine, EngineConfig, KVSlotManager, QueueFull, bucket_for, default_buckets,
)

CFG = get_config("tinyllama-1.1b").smoke()
RNG = np.random.default_rng(7)


@pytest.fixture(scope="module")
def params():
    return init_model(CFG, jax.random.PRNGKey(0))


def _prompts(lens):
    return [RNG.integers(0, CFG.vocab, (l,), dtype=np.int32) for l in lens]


def _sequential(params, prompts, gens, **ecfg_kw):
    """Reference: same engine, one request at a time, drained in between."""
    eng = Engine(CFG, params, EngineConfig(max_slots=2, max_seq_len=32, **ecfg_kw))
    outs = []
    for p, g in zip(prompts, gens):
        req = eng.submit(p, g)
        eng.run_until_complete()
        outs.append(list(req.tokens))
    eng.close()
    return outs


def test_staggered_arrivals_match_sequential_exactly(params):
    """The headline invariant: requests joining/leaving the in-flight batch
    mid-decode produce exactly the tokens they would produce alone."""
    prompts = _prompts([5, 9, 4, 7])
    gens = [6, 5, 8, 3]
    eng = Engine(CFG, params, EngineConfig(max_slots=2, max_seq_len=32))
    reqs = [eng.submit(prompts[0], gens[0])]
    eng.step()                                   # r0 decoding alone
    reqs.append(eng.submit(prompts[1], gens[1]))  # joins mid-flight
    eng.step()
    reqs.append(eng.submit(prompts[2], gens[2]))  # queues (slots full) then joins
    reqs.append(eng.submit(prompts[3], gens[3]))
    eng.run_until_complete()
    staggered = [list(r.tokens) for r in reqs]

    sequential = _sequential(params, prompts, gens)
    assert staggered == sequential               # bit-identical, not allclose
    eng.close()


def test_no_cross_slot_leakage_same_prompt(params):
    """Two identical prompts decoding simultaneously in different slots must
    produce identical streams (any cross-slot read would desync them)."""
    p = _prompts([6])[0]
    eng = Engine(CFG, params, EngineConfig(max_slots=2, max_seq_len=32))
    r1 = eng.submit(p, 8)
    r2 = eng.submit(p, 8)
    eng.run_until_complete()
    assert r1.tokens == r2.tokens
    assert r1.metrics.n_generated == 8
    eng.close()


def test_slot_reuse_without_reallocation(params):
    """More requests than slots: retired slots are re-leased, the cache pytree
    is allocated exactly once, and shapes never change."""
    prompts = _prompts([4, 5, 6, 4, 5])
    gens = [3, 4, 2, 5, 3]
    eng = Engine(CFG, params, EngineConfig(max_slots=2, max_seq_len=32))
    shape0 = jax.tree.map(lambda l: l.shape, eng.kv.cache)
    reqs = [eng.submit(p, g) for p, g in zip(prompts, gens)]
    eng.run_until_complete()
    assert eng.kv.alloc_count == 1
    assert jax.tree.map(lambda l: l.shape, eng.kv.cache) == shape0
    assert [r.tokens for r in reqs] == _sequential(params, prompts, gens)
    eng.close()


def test_retired_slot_is_scrubbed(params):
    eng = Engine(CFG, params, EngineConfig(max_slots=2, max_seq_len=32))
    eng.submit(_prompts([6])[0], 4)
    eng.run_until_complete()
    # slots free again, and the RETIRED slot's row is back to pristine zeros
    # (idle slots write their own rows during decode — that's fine, admission
    # overwrites the entire leased row — but a retired row must be scrubbed)
    assert eng.scheduler.n_active == 0 and len(eng.scheduler.free) == 2
    assert eng.kv.slot_index(0) == 0
    np.testing.assert_array_equal(np.asarray(eng.kv.cache["k"][:, 0]), 0)
    np.testing.assert_array_equal(np.asarray(eng.kv.cache["v"][:, 0]), 0)
    eng.close()


def test_metrics_reconcile(params):
    prompts = _prompts([4, 6, 5])
    gens = [3, 6, 4]
    eng = Engine(CFG, params, EngineConfig(max_slots=2, max_seq_len=32))
    reqs = [eng.submit(p, g) for p, g in zip(prompts, gens)]
    eng.run_until_complete()
    s = eng.stats()
    assert s["completed"] == s["submitted"] == 3
    assert s["tokens_generated"] == sum(r.metrics.n_generated for r in reqs)
    assert s["tokens_generated"] == sum(gens)
    assert s["prefill_tokens"] == sum(len(p) for p in prompts)
    assert all(len(r.tokens) == r.metrics.n_generated for r in reqs)
    assert all(r.metrics.ttft_s is not None and r.metrics.ttft_s >= 0 for r in reqs)
    assert all(r.metrics.finish_s >= r.metrics.first_token_s for r in reqs)
    # every generated token beyond each request's prefill token came from a
    # batched decode step
    assert s["decode_steps"] >= max(gens) - 1
    # the OPQ runtime saw the work: params stay resident -> affinity hits
    assert s["opq"]["issued"] > 0 and s["opq"]["affinity_hits"] > 0
    eng.close()


def test_admission_control(params):
    eng = Engine(CFG, params, EngineConfig(max_slots=2, max_queue=2,
                                           max_seq_len=32))
    assert eng.submit(_prompts([4])[0], 40) is None      # over seq budget
    assert eng.submit([], 4) is None                     # empty prompt
    ok1 = eng.submit(_prompts([4])[0], 4)
    ok2 = eng.submit(_prompts([4])[0], 4)
    assert ok1 is not None and ok2 is not None
    assert eng.submit(_prompts([4])[0], 4) is None       # queue full
    with pytest.raises(QueueFull):
        eng.submit(_prompts([4])[0], 4, strict=True)
    assert eng.stats()["rejected"] == 4
    eng.run_until_complete()
    assert eng.stats()["completed"] == 2
    # untracked OPQ dispatch: no step results retained across the run
    assert len(eng.opq._task_futures) == 0
    eng.close()


def test_single_slot_engine_reuses_cleanly(params):
    """n_slots=1 regression: the pristine-row snapshot must be a real copy —
    a full-extent slice aliases the cache buffer, which donation deletes."""
    prompts = _prompts([5, 7])
    gens = [4, 3]
    eng = Engine(CFG, params, EngineConfig(max_slots=1, max_seq_len=16))
    reqs = [eng.submit(p, g) for p, g in zip(prompts, gens)]
    eng.run_until_complete()
    assert [r.metrics.n_generated for r in reqs] == gens
    assert eng.kv.alloc_count == 1
    eng.close()


def test_admission_rejects_prompt_over_largest_bucket(params):
    """Custom buckets capping below max_seq_len must reject at submit(), not
    wedge the scheduler mid-admission after a slot was leased."""
    eng = Engine(CFG, params, EngineConfig(max_slots=2, max_seq_len=32,
                                           buckets=(8,)))
    assert eng.submit(_prompts([12])[0], 4) is None      # 12 > bucket cap 8
    ok = eng.submit(_prompts([6])[0], 4)
    assert ok is not None
    eng.run_until_complete()
    assert ok.metrics.n_generated == 4
    eng.close()


def test_int8_kv_slot_manager(params):
    """int8 KV cache config: the slot manager carries per-token scale planes
    and the engine still decodes staggered == sequential."""
    cfg8 = CFG.replace(kv_cache_dtype="int8")
    params8 = init_model(cfg8, jax.random.PRNGKey(0))
    mgr = KVSlotManager(cfg8, n_slots=2, max_seq_len=16)
    assert mgr.cache["k"].dtype == np.int8
    assert "k_scale" in mgr.cache and "v_scale" in mgr.cache

    prompts = _prompts([4, 6])
    gens = [4, 3]
    eng = Engine(cfg8, params8, EngineConfig(max_slots=2, max_seq_len=16))
    r0 = eng.submit(prompts[0], gens[0])
    eng.step()
    r1 = eng.submit(prompts[1], gens[1])          # staggered join
    eng.run_until_complete()
    staggered = [list(r0.tokens), list(r1.tokens)]
    eng.close()

    eng2 = Engine(cfg8, params8, EngineConfig(max_slots=2, max_seq_len=16))
    seq = []
    for p, g in zip(prompts, gens):
        r = eng2.submit(p, g)
        eng2.run_until_complete()
        seq.append(list(r.tokens))
    eng2.close()
    assert staggered == seq


def test_bucketing_bounds_prefill_shapes(params):
    """Prompts of many lengths compile at most len(buckets) prefill shapes,
    and same-step same-bucket arrivals share one prefill batch."""
    assert default_buckets(48) == (16, 32, 48)
    assert default_buckets(32) == (16, 32)
    assert bucket_for(5, (16, 32)) == 16 and bucket_for(17, (16, 32)) == 32
    with pytest.raises(ValueError):
        bucket_for(33, (16, 32))
    eng = Engine(CFG, params, EngineConfig(max_slots=2, max_seq_len=32))
    for l in (3, 9):                              # both land in the 16-bucket
        eng.submit(_prompts([l])[0], 2)
    eng.step()
    assert eng.stats()["prefill_batches"] == 1    # one shared prefill forward
    eng.run_until_complete()
    eng.close()