"""Continuous-batching engine invariants (serving/engine.py):

  * isolation   — a request's tokens never leak into another slot: staggered
                  mixed-traffic outputs are BIT-IDENTICAL to one-at-a-time
                  sequential decoding of the same requests (dense AND MoE)
  * fused admission — seeding is ONE prefill forward + ONE batched slot write
                  per bucket (asserted via OPQ instruction flags, zero replay
                  decodes), and the seeded cache + generated tokens are
                  bit-identical to the PR-1 B=1 prompt-replay seeding (the
                  reference replay seeder lives HERE, not in src/)
  * slot reuse  — retired slots are re-leased without reallocating the cache
  * metrics     — engine counters reconcile with per-request token counts
  * admission   — the bounded queue and the per-slot sequence budget reject
  * int8 KV     — the slot store carries the Tensorizer int8 cache scales
  * MoE         — routing is per-request isolated: idle slots are masked out
                  of the expert-capacity cumsum, prefill routes row-isolated
  * SlotStore   — the cache sits behind the pluggable store protocol
                  (serving/store.py): paged decode is bit-identical to
                  contiguous (dense + int8-KV + MoE), block-pool exhaustion
                  is admission backpressure (never corruption), and the
                  recurrent backend serves ssm/hybrid families with pristine
                  slot reset (no state leaks across leases)
"""

import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import init_model
from repro.models import serve as SV
from repro.models import steps as ST
from repro.serving import (
    ContiguousKVStore, Engine, EngineConfig, KVSlotManager, PagedKVStore,
    QueueFull, RecurrentStateStore, bucket_for, default_buckets,
    format_memory_stats, make_store,
)

CFG = get_config("tinyllama-1.1b").smoke()
MOE_CFG = get_config("moonshot-v1-16b-a3b").smoke()
XLSTM_CFG = get_config("xlstm-125m").smoke()
HYBRID_CFG = get_config("zamba2-7b").smoke()
RNG = np.random.default_rng(7)


@pytest.fixture(scope="module")
def params():
    return init_model(CFG, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def moe_params():
    return init_model(MOE_CFG, jax.random.PRNGKey(1))


@pytest.fixture(scope="module")
def xlstm_params():
    return init_model(XLSTM_CFG, jax.random.PRNGKey(2))


def _prompts(lens, cfg=CFG):
    return [RNG.integers(0, cfg.vocab, (l,), dtype=np.int32) for l in lens]


def _sequential(params, prompts, gens, cfg=CFG, **ecfg_kw):
    """Reference: same engine, one request at a time, drained in between."""
    eng = Engine(cfg, params, EngineConfig(max_slots=2, max_seq_len=32, **ecfg_kw))
    outs = []
    for p, g in zip(prompts, gens):
        req = eng.submit(p, g)
        eng.run_until_complete()
        outs.append(list(req.tokens))
    eng.close()
    return outs


def _staggered(params, prompts, gens, cfg=CFG, **ecfg_kw):
    """Mixed traffic: two joins mid-flight, the rest queued behind them."""
    eng = Engine(cfg, params, EngineConfig(max_slots=2, max_seq_len=32, **ecfg_kw))
    reqs = [eng.submit(prompts[0], gens[0])]
    eng.step()
    reqs.append(eng.submit(prompts[1], gens[1]))
    eng.step()
    for p, g in zip(prompts[2:], gens[2:]):
        reqs.append(eng.submit(p, g))
    eng.run_until_complete()
    outs = [list(r.tokens) for r in reqs]
    eng.close()
    return outs


class _ReplaySeededEngine(Engine):
    """The PR-1 admission reference: first token from the bucketed prefill,
    but slot caches seeded by replaying the prompt token-by-token through the
    B=1 decode step (O(prompt_len) forwards — the path fused admission
    deleted). Kept in tests only, as the bit-identity oracle."""

    def __init__(self, cfg, params, engine_cfg=None, **kw):
        super().__init__(cfg, params, engine_cfg, **kw)
        self._replay = jax.jit(ST.make_decode_step(cfg))
        self._replay_template = SV.init_cache(cfg, 1, self.ecfg.max_seq_len)

    def _seed_admitted(self, pairs, kv):
        del kv                               # fused prefill K/V ignored
        for slot, req in pairs:
            rc = self._replay_template
            for t in req.prompt:
                _, rc = self._replay(
                    self.params, rc, {"tokens": jnp.asarray([[int(t)]], jnp.int32)})
            self.kv.write_slot(slot, rc, n_valid=len(req.prompt))


def _pure_sequential_decode(cfg, params, prompt, gen, max_seq):
    """Single-request decoding with no engine at all: feed the prompt through
    the B=1 decode step, then greedy-decode ``gen`` tokens."""
    dec = jax.jit(ST.make_decode_step(cfg))
    cache = SV.init_cache(cfg, 1, max_seq)
    for t in prompt:
        tok, cache = dec(params, cache, {"tokens": jnp.asarray([[int(t)]], jnp.int32)})
    out = [int(tok[0])]
    while len(out) < gen:
        tok, cache = dec(params, cache,
                         {"tokens": jnp.asarray([[out[-1]]], jnp.int32)})
        out.append(int(tok[0]))
    return out


def test_staggered_arrivals_match_sequential_exactly(params):
    """The headline invariant: requests joining/leaving the in-flight batch
    mid-decode produce exactly the tokens they would produce alone."""
    prompts = _prompts([5, 9, 4, 7])
    gens = [6, 5, 8, 3]
    eng = Engine(CFG, params, EngineConfig(max_slots=2, max_seq_len=32))
    reqs = [eng.submit(prompts[0], gens[0])]
    eng.step()                                   # r0 decoding alone
    reqs.append(eng.submit(prompts[1], gens[1]))  # joins mid-flight
    eng.step()
    reqs.append(eng.submit(prompts[2], gens[2]))  # queues (slots full) then joins
    reqs.append(eng.submit(prompts[3], gens[3]))
    eng.run_until_complete()
    staggered = [list(r.tokens) for r in reqs]

    sequential = _sequential(params, prompts, gens)
    assert staggered == sequential               # bit-identical, not allclose
    eng.close()


def test_no_cross_slot_leakage_same_prompt(params):
    """Two identical prompts decoding simultaneously in different slots must
    produce identical streams (any cross-slot read would desync them)."""
    p = _prompts([6])[0]
    eng = Engine(CFG, params, EngineConfig(max_slots=2, max_seq_len=32))
    r1 = eng.submit(p, 8)
    r2 = eng.submit(p, 8)
    eng.run_until_complete()
    assert r1.tokens == r2.tokens
    assert r1.metrics.n_generated == 8
    eng.close()


def test_slot_reuse_without_reallocation(params):
    """More requests than slots: retired slots are re-leased, the cache pytree
    is allocated exactly once, and shapes never change."""
    prompts = _prompts([4, 5, 6, 4, 5])
    gens = [3, 4, 2, 5, 3]
    eng = Engine(CFG, params, EngineConfig(max_slots=2, max_seq_len=32))
    shape0 = jax.tree.map(lambda l: l.shape, eng.kv.cache)
    reqs = [eng.submit(p, g) for p, g in zip(prompts, gens)]
    eng.run_until_complete()
    assert eng.kv.alloc_count == 1
    assert jax.tree.map(lambda l: l.shape, eng.kv.cache) == shape0
    assert [r.tokens for r in reqs] == _sequential(params, prompts, gens)
    eng.close()


def test_retired_slot_is_scrubbed(params):
    eng = Engine(CFG, params, EngineConfig(max_slots=2, max_seq_len=32))
    eng.submit(_prompts([6])[0], 4)
    eng.run_until_complete()
    # slots free again, and the RETIRED slot's row is back to pristine zeros
    # (idle slots write their own rows during decode — that's fine, admission
    # overwrites the entire leased row — but a retired row must be scrubbed)
    assert eng.scheduler.n_active == 0 and len(eng.scheduler.free) == 2
    assert eng.kv.slot_index(0) == 0
    np.testing.assert_array_equal(np.asarray(eng.kv.cache["k"][:, 0]), 0)
    np.testing.assert_array_equal(np.asarray(eng.kv.cache["v"][:, 0]), 0)
    eng.close()


def test_metrics_reconcile(params):
    prompts = _prompts([4, 6, 5])
    gens = [3, 6, 4]
    eng = Engine(CFG, params, EngineConfig(max_slots=2, max_seq_len=32))
    reqs = [eng.submit(p, g) for p, g in zip(prompts, gens)]
    eng.run_until_complete()
    s = eng.stats()
    assert s["completed"] == s["submitted"] == 3
    assert s["tokens_generated"] == sum(r.metrics.n_generated for r in reqs)
    assert s["tokens_generated"] == sum(gens)
    assert s["prefill_tokens"] == sum(len(p) for p in prompts)
    assert all(len(r.tokens) == r.metrics.n_generated for r in reqs)
    assert all(r.metrics.ttft_s is not None and r.metrics.ttft_s >= 0 for r in reqs)
    assert all(r.metrics.finish_s >= r.metrics.first_token_s for r in reqs)
    # every generated token beyond each request's prefill token came from a
    # batched decode step
    assert s["decode_steps"] >= max(gens) - 1
    # the OPQ runtime saw the work: params stay resident -> affinity hits
    assert s["opq"]["issued"] > 0 and s["opq"]["affinity_hits"] > 0
    eng.close()


def test_admission_control(params):
    eng = Engine(CFG, params, EngineConfig(max_slots=2, max_queue=2,
                                           max_seq_len=32))
    assert eng.submit(_prompts([4])[0], 40) is None      # over seq budget
    assert eng.submit([], 4) is None                     # empty prompt
    ok1 = eng.submit(_prompts([4])[0], 4)
    ok2 = eng.submit(_prompts([4])[0], 4)
    assert ok1 is not None and ok2 is not None
    assert eng.submit(_prompts([4])[0], 4) is None       # queue full
    with pytest.raises(QueueFull):
        eng.submit(_prompts([4])[0], 4, strict=True)
    assert eng.stats()["rejected"] == 4
    eng.run_until_complete()
    assert eng.stats()["completed"] == 2
    # untracked OPQ dispatch: no step results retained across the run
    assert len(eng.opq._task_futures) == 0
    eng.close()


def test_single_slot_engine_reuses_cleanly(params):
    """n_slots=1 regression: the pristine-row snapshot must be a real copy —
    a full-extent slice aliases the cache buffer, which donation deletes."""
    prompts = _prompts([5, 7])
    gens = [4, 3]
    eng = Engine(CFG, params, EngineConfig(max_slots=1, max_seq_len=16))
    reqs = [eng.submit(p, g) for p, g in zip(prompts, gens)]
    eng.run_until_complete()
    assert [r.metrics.n_generated for r in reqs] == gens
    assert eng.kv.alloc_count == 1
    eng.close()


def test_engine_rejects_bucket_wider_than_slot_rows(params):
    """A bucket wider than max_seq_len could admit prompts whose fused K/V
    block can't be scattered into the slot rows — rejected at construction."""
    with pytest.raises(ValueError, match="exceeds"):
        Engine(CFG, params, EngineConfig(max_slots=2, max_seq_len=32,
                                         buckets=(64,)))


def test_admission_rejects_prompt_over_largest_bucket(params):
    """Custom buckets capping below max_seq_len must reject at submit(), not
    wedge the scheduler mid-admission after a slot was leased."""
    eng = Engine(CFG, params, EngineConfig(max_slots=2, max_seq_len=32,
                                           buckets=(8,)))
    assert eng.submit(_prompts([12])[0], 4) is None      # 12 > bucket cap 8
    ok = eng.submit(_prompts([6])[0], 4)
    assert ok is not None
    eng.run_until_complete()
    assert ok.metrics.n_generated == 4
    eng.close()


def test_int8_kv_slot_store(params):
    """int8 KV cache config: the slot store carries per-token scale planes
    and the engine still decodes staggered == sequential."""
    cfg8 = CFG.replace(kv_cache_dtype="int8")
    params8 = init_model(cfg8, jax.random.PRNGKey(0))
    mgr = make_store(cfg8, n_slots=2, max_seq_len=16, backend="contiguous")
    assert mgr.cache["k"].dtype == np.int8
    assert "k_scale" in mgr.cache and "v_scale" in mgr.cache

    prompts = _prompts([4, 6])
    gens = [4, 3]
    eng = Engine(cfg8, params8, EngineConfig(max_slots=2, max_seq_len=16))
    r0 = eng.submit(prompts[0], gens[0])
    eng.step()
    r1 = eng.submit(prompts[1], gens[1])          # staggered join
    eng.run_until_complete()
    staggered = [list(r0.tokens), list(r1.tokens)]
    eng.close()

    eng2 = Engine(cfg8, params8, EngineConfig(max_slots=2, max_seq_len=16))
    seq = []
    for p, g in zip(prompts, gens):
        r = eng2.submit(p, g)
        eng2.run_until_complete()
        seq.append(list(r.tokens))
    eng2.close()
    assert staggered == seq


@pytest.mark.parametrize("family,kv_dtype", [
    ("dense", "bfloat16"), ("dense", "int8"), ("moe", "bfloat16"),
])
def test_fused_seeding_bit_identical_to_replay(params, moe_params, family, kv_dtype):
    """The fused-admission guarantee: seeding a slot from the prefill's K/V
    block produces (a) the bit-identical cache state and (b) the bit-identical
    generated tokens of the PR-1 B=1 prompt-replay seeding — for the float and
    the int8-KV (per-token scales) cache formats, and for MoE (where dropless
    row-isolated prefill routing makes a batched prompt route exactly as the
    one-token-at-a-time replay did) — and both equal decoding the request with
    no engine at all."""
    base, params = (CFG, params) if family == "dense" else (MOE_CFG, moe_params)
    cfg = base.replace(kv_cache_dtype=kv_dtype)
    prompts = _prompts([5, 9, 4])
    gens = [6, 4, 7]
    ecfg = EngineConfig(max_slots=2, max_seq_len=32)
    eng_f = Engine(cfg, params, ecfg)
    eng_r = _ReplaySeededEngine(cfg, params, ecfg)
    reqs_f = [eng_f.submit(p, g) for p, g in zip(prompts, gens)]
    reqs_r = [eng_r.submit(p, g) for p, g in zip(prompts, gens)]
    eng_f._admit()
    eng_r._admit()
    # freshly admitted rows: the batched fused scatter leaves the cache
    # bit-equal to per-slot replay writes (pad tails scrubbed to pristine)
    for name in eng_f.kv.cache:
        np.testing.assert_array_equal(
            np.asarray(eng_f.kv.cache[name]), np.asarray(eng_r.kv.cache[name]),
            err_msg=f"cache leaf {name!r} diverged ({kv_dtype})")
    eng_f.run_until_complete()
    eng_r.run_until_complete()
    toks_f = [list(r.tokens) for r in reqs_f]
    assert toks_f == [list(r.tokens) for r in reqs_r]
    assert toks_f == [_pure_sequential_decode(cfg, params, p, g, 32)
                      for p, g in zip(prompts, gens)]
    eng_f.close()
    eng_r.close()


def test_admission_is_one_forward_per_bucket_no_replay(params):
    """Dispatch-shape audit via OPQ instruction flags: an admission round
    issues exactly ONE prefill instruction per bucket batch (same-bucket
    arrivals share it) and ZERO replay decodes — seeding is O(1) dispatches
    in prompt length."""
    eng = Engine(CFG, params, EngineConfig(max_slots=4, max_seq_len=32))
    for l, g in ((3, 4), (9, 3), (20, 5)):       # buckets: 16, 16, 32
        eng.submit(_prompts([l])[0], g)
    eng.step()
    flags = eng.stats()["opq"]["flags"]
    assert flags["prefill/16"] == 1              # two prompts, one forward
    assert flags["prefill/32"] == 1
    eng.run_until_complete()
    s = eng.stats()
    flags = s["opq"]["flags"]
    # the complete run's instruction ledger: per-bucket prefills and batched
    # decode steps, nothing else — the replay instruction class is extinct
    assert set(flags) == {"prefill/16", "prefill/32", "decode"}
    assert sum(c for f, c in flags.items()
               if f.startswith("prefill/")) == s["prefill_batches"] == 2
    assert flags["decode"] == s["decode_steps"]
    eng.close()


def test_moe_staggered_matches_sequential(moe_params):
    """MoE serving carries the dense bit-identity guarantee now: idle slots
    are masked out of the expert-capacity cumsum at decode and fused prefill
    routes row-isolated, so requests joining/leaving mid-flight decode exactly
    as if served one at a time."""
    prompts = _prompts([5, 9, 4, 7])
    gens = [6, 5, 8, 3]
    eng = Engine(MOE_CFG, moe_params, EngineConfig(max_slots=2, max_seq_len=32))
    reqs = [eng.submit(prompts[0], gens[0])]
    eng.step()                                    # r0 decoding alone
    reqs.append(eng.submit(prompts[1], gens[1]))  # joins mid-flight
    eng.step()
    reqs.append(eng.submit(prompts[2], gens[2]))
    reqs.append(eng.submit(prompts[3], gens[3]))
    eng.run_until_complete()
    staggered = [list(r.tokens) for r in reqs]
    assert staggered == _sequential(moe_params, prompts, gens, cfg=MOE_CFG)
    eng.close()


def test_moe_idle_mask_restores_isolation(moe_params):
    """Teeth for the capacity-masking fix, at the apply_moe level: four
    identical tokens all pick the same experts, so with shared capacity
    ceil(4*topk/E*cf) = 3 the last row's expert traffic is dropped on the
    floor. With its three batchmates masked idle, the survivor routes exactly
    as the first row does alone."""
    from repro.models import moe as MOE
    p = jax.tree.map(lambda l: l[0], moe_params["layers"])["moe"]
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 1, MOE_CFG.d_model), jnp.float32)
    x4 = jnp.broadcast_to(x, (4, 1, MOE_CFG.d_model))
    y_shared, _ = MOE.apply_moe(p, x4, MOE_CFG)
    y_masked, _ = MOE.apply_moe(p, x4, MOE_CFG,
                                active=jnp.asarray([False, False, False, True]))
    y_first, _ = MOE.apply_moe(p, x4, MOE_CFG,
                               active=jnp.asarray([True, False, False, False]))
    # the lone active row routes identically wherever it sits in the batch
    np.testing.assert_array_equal(np.asarray(y_masked[3]), np.asarray(y_first[0]))
    # and shared capacity really was the failure mode being fixed: without the
    # mask, row 3 lost its routed experts to its (identical) batchmates
    assert not np.array_equal(np.asarray(y_shared[3]), np.asarray(y_masked[3]))
    # serving decode is dropless: even with every batchmate ACTIVE and
    # colliding on the same experts (worst case for the old shared capacity
    # of 3), each token routes exactly as it does alone
    y_active, _ = MOE.apply_moe(p, x4, MOE_CFG, active=jnp.ones((4,), bool))
    np.testing.assert_array_equal(np.asarray(y_active[3]), np.asarray(y_first[0]))


def test_bucketing_bounds_prefill_shapes(params):
    """Prompts of many lengths compile at most len(buckets) prefill shapes,
    and same-step same-bucket arrivals share one prefill batch."""
    assert default_buckets(48) == (16, 32, 48)
    assert default_buckets(32) == (16, 32)
    assert bucket_for(5, (16, 32)) == 16 and bucket_for(17, (16, 32)) == 32
    with pytest.raises(ValueError):
        bucket_for(33, (16, 32))
    eng = Engine(CFG, params, EngineConfig(max_slots=2, max_seq_len=32))
    for l in (3, 9):                              # both land in the 16-bucket
        eng.submit(_prompts([l])[0], 2)
    eng.step()
    assert eng.stats()["prefill_batches"] == 1    # one shared prefill forward
    eng.run_until_complete()
    eng.close()


# ===========================================================================
# SlotStore protocol: paged KV + recurrent-state backends
# ===========================================================================

def _leaf_rows(cache, slot):
    """Flatten a (possibly nested) cache pytree to {path: slot-row array}."""
    flat, _ = jax.tree_util.tree_flatten_with_path(cache)
    out = {}
    for path, leaf in flat:
        name = jax.tree_util.keystr(path)
        out[name] = leaf[slot] if "index" in name else leaf[:, slot]
    return out


def test_make_store_backend_selection():
    assert isinstance(make_store(CFG, 2, 32), ContiguousKVStore)
    assert isinstance(make_store(CFG, 2, 32, backend="paged"), PagedKVStore)
    assert isinstance(make_store(XLSTM_CFG, 2, 32), RecurrentStateStore)
    with pytest.raises(ValueError, match="dense-family"):
        make_store(XLSTM_CFG, 2, 32, backend="paged")
    with pytest.raises(ValueError, match="ssm/hybrid"):
        make_store(CFG, 2, 32, backend="recurrent")
    with pytest.raises(ValueError, match="divide"):
        make_store(CFG, 2, 32, backend="paged", block_size=12)
    with pytest.raises(ValueError, match="unknown cache backend"):
        make_store(CFG, 2, 32, backend="mmap")


def test_kvslotmanager_shim_warns():
    """Direct KVSlotManager use is deprecated but still works (it IS the
    contiguous backend underneath)."""
    with pytest.warns(DeprecationWarning, match="make_store"):
        mgr = KVSlotManager(CFG, n_slots=2, max_seq_len=16)
    assert isinstance(mgr, ContiguousKVStore)
    with warnings.catch_warnings():
        warnings.simplefilter("error")            # the store itself is clean
        make_store(CFG, 2, 16, backend="contiguous")


@pytest.mark.parametrize("family,kv_dtype,block_size", [
    ("dense", "bfloat16", 8), ("dense", "int8", 8), ("moe", "bfloat16", 16),
])
def test_paged_decode_bit_identical_to_contiguous(
        params, moe_params, family, kv_dtype, block_size):
    """The paged-backend contract: the same staggered token stream served
    through block-paged KV produces bit-identical tokens to contiguous rows —
    for float, int8-per-token-scale, and MoE cache formats — and the seeded
    cache contents agree on every valid position."""
    base, p = (CFG, params) if family == "dense" else (MOE_CFG, moe_params)
    cfg = base.replace(kv_cache_dtype=kv_dtype)
    prompts = _prompts([5, 9, 4, 7])
    gens = [6, 5, 8, 3]

    eng_c = Engine(cfg, p, EngineConfig(max_slots=2, max_seq_len=32))
    eng_p = Engine(cfg, p, EngineConfig(max_slots=2, max_seq_len=32,
                                        cache_backend="paged",
                                        block_size=block_size))
    for e in (eng_c, eng_p):
        for pr, g in zip(prompts, gens):
            e.submit(pr, g)
        e._admit()
    # freshly admitted rows agree bit-for-bit on every valid position
    view_c = eng_c.store.gather_view()
    view_p = eng_p.store.gather_view()
    for slot, req in eng_c.scheduler.active.items():
        n = len(req.prompt)
        for name in ("k", "v", "k_scale", "v_scale"):
            if name not in view_c:
                continue
            np.testing.assert_array_equal(
                np.asarray(view_c[name][:, slot, :n]),
                np.asarray(view_p[name][:, slot, :n]),
                err_msg=f"seeded leaf {name!r} diverged (slot {slot})")

    toks_c = _staggered(p, prompts, gens, cfg=cfg)
    toks_p = _staggered(p, prompts, gens, cfg=cfg, cache_backend="paged",
                        block_size=block_size)
    assert toks_c == toks_p                       # bit-identical, not allclose
    eng_c.close()
    eng_p.close()


def test_paged_fused_seeding_bit_identical_to_replay(params):
    """The fused==replay guarantee holds per backend: a paged store seeded by
    the B=1 replay reference path (write_slot through the block tables)
    generates the same tokens as fused admission."""
    prompts = _prompts([5, 9, 4])
    gens = [6, 4, 7]
    ecfg = EngineConfig(max_slots=2, max_seq_len=32, cache_backend="paged",
                        block_size=8)
    eng_f = Engine(CFG, params, ecfg)
    eng_r = _ReplaySeededEngine(CFG, params, ecfg)
    reqs_f = [eng_f.submit(p, g) for p, g in zip(prompts, gens)]
    reqs_r = [eng_r.submit(p, g) for p, g in zip(prompts, gens)]
    eng_f.run_until_complete()
    eng_r.run_until_complete()
    assert [r.tokens for r in reqs_f] == [r.tokens for r in reqs_r]
    eng_f.close()
    eng_r.close()


def test_paged_pool_exhaustion_is_backpressure_not_corruption(params):
    """A block pool sized for 2 concurrent requests with 4 slots free: the
    scheduler defers the overflow at the queue head (FIFO intact) until
    retires free blocks — every request completes with tokens bit-identical
    to the contiguous backend, and the pool drains back to fully free."""
    prompts = _prompts([8, 8, 8, 8])
    # 2 blocks per request (8 prompt + 8 gen, block 8); pool holds 4 blocks
    eng = Engine(CFG, params, EngineConfig(max_slots=4, max_seq_len=16,
                                           cache_backend="paged",
                                           block_size=8, n_blocks=5))
    reqs = [eng.submit(p, 8) for p in prompts]
    eng.step()
    s = eng.stats()
    assert s["cache"]["blocks_used"] == 4 and s["cache"]["blocks_free"] == 0
    assert eng.scheduler.n_active == 2            # two admitted, two held back
    assert s["admissions_deferred"] >= 1
    eng.run_until_complete()
    s = eng.stats()
    assert s["completed"] == 4
    assert s["cache"]["blocks_free"] == s["cache"]["blocks_total"] == 4

    eng_c = Engine(CFG, params, EngineConfig(max_slots=4, max_seq_len=16))
    reqs_c = [eng_c.submit(p, 8) for p in prompts]
    eng_c.run_until_complete()
    assert [r.tokens for r in reqs] == [r.tokens for r in reqs_c]
    eng.close()
    eng_c.close()


def test_paged_pool_exhaustion_evicts_cached_prefixes_before_refusal(params):
    """Pool exhaustion while cached prefixes sit unreferenced: the lease
    must LRU-evict them to unblock admission instead of refusing. With the
    pool sized so a retired request's cached prompt block is the only spare
    capacity, a non-matching follow-up request admits only if eviction
    fires — without it, this exact traffic is the zero-active admission
    livelock the engine raises on."""
    pa, pb = _prompts([8, 8])
    # 2 usable blocks of 8; each request needs 2 (8 prompt + 8 gen). After A
    # retires, its prompt block stays CACHED -> only 1 block is free.
    eng = Engine(CFG, params, EngineConfig(max_slots=2, max_seq_len=16,
                                           cache_backend="paged",
                                           block_size=8, n_blocks=3,
                                           prefix_cache=True))
    ra = eng.submit(pa, 8, strict=True)
    eng.run_until_complete()
    ms = eng.store.memory_stats()
    assert ms["prefix_cached_blocks"] == 1 and ms["blocks_free"] == 1
    # B shares no prefix with A: it needs 2 fresh blocks RIGHT NOW, and the
    # router-facing signal must already count the evictable cached block
    assert eng.lease_headroom(8, 8)
    rb = eng.submit(pb, 8, strict=True)
    eng.run_until_complete()                      # no livelock, no deferral
    s = eng.stats()
    assert s["completed"] == 2
    assert s["admissions_deferred"] == 0
    assert eng.store.prefix_evictions == 1        # the cached block made room

    eng_c = Engine(CFG, params, EngineConfig(max_slots=2, max_seq_len=16))
    toks_c = []
    for p in (pa, pb):
        r = eng_c.submit(p, 8, strict=True)
        eng_c.run_until_complete()
        toks_c.append(r.tokens)
    assert [ra.tokens, rb.tokens] == toks_c       # eviction never skews bits
    eng.close()
    eng_c.close()


def test_paged_request_that_can_never_fit_is_rejected_not_livelocked(params):
    """A request needing more blocks than the whole pool holds must bounce at
    submit() — deferring it would park it at the queue head forever, spinning
    run_until_complete and starving everything behind it."""
    # pool: 2 usable blocks of 8 -> 16 tokens total; request needs 24
    eng = Engine(CFG, params, EngineConfig(max_slots=2, max_seq_len=32,
                                           cache_backend="paged",
                                           block_size=8, n_blocks=3))
    assert eng.submit(_prompts([16])[0], 8) is None
    with pytest.raises(QueueFull):
        eng.submit(_prompts([16])[0], 8, strict=True)
    assert eng.stats()["rejected"] == 2
    # a fitting request behind the rejection still serves normally
    ok = eng.submit(_prompts([8])[0], 8)
    eng.run_until_complete()
    assert ok.metrics.n_generated == 8
    eng.close()


def test_ssm_staggered_matches_sequential(xlstm_params):
    """The headline invariant, extended to the recurrent family: xlstm
    requests joining/leaving the in-flight batch mid-decode produce exactly
    the tokens they would produce served one at a time."""
    prompts = _prompts([5, 9, 4, 7], cfg=XLSTM_CFG)
    gens = [6, 5, 8, 3]
    staggered = _staggered(xlstm_params, prompts, gens, cfg=XLSTM_CFG)
    sequential = _sequential(xlstm_params, prompts, gens, cfg=XLSTM_CFG)
    assert staggered == sequential               # bit-identical, not allclose


def test_recurrent_slot_reset_has_teeth(xlstm_params):
    """A retired xlstm slot never leaks state into the next lease: the row is
    restored to the pristine pattern (incl. the non-zero mLSTM/sLSTM
    stabilizer sentinels) immediately at retire, and a request served through
    the reused slot decodes exactly as on a fresh engine."""
    prompts = _prompts([6, 9], cfg=XLSTM_CFG)
    eng = Engine(XLSTM_CFG, xlstm_params,
                 EngineConfig(max_slots=1, max_seq_len=32))
    r0 = eng.submit(prompts[0], 5)
    eng.run_until_complete()
    assert r0.metrics.n_generated == 5
    # slot 0's row is bit-equal to a never-used store's (M_INIT / 1e-6 /
    # -1e30 sentinels included — zeros would NOT be pristine here)
    fresh = make_store(XLSTM_CFG, 1, 32, backend="recurrent")
    got, want = _leaf_rows(eng.store.cache, 0), _leaf_rows(fresh.cache, 0)
    for name in want:
        np.testing.assert_array_equal(
            np.asarray(got[name]), np.asarray(want[name]),
            err_msg=f"retired slot leaf {name} not pristine")
    # the re-leased slot serves exactly like a fresh engine
    r1 = eng.submit(prompts[1], 5)
    eng.run_until_complete()
    eng2 = Engine(XLSTM_CFG, xlstm_params,
                  EngineConfig(max_slots=1, max_seq_len=32))
    r1_fresh = eng2.submit(prompts[1], 5)
    eng2.run_until_complete()
    assert r1.tokens == r1_fresh.tokens
    eng.close()
    eng2.close()


def test_hybrid_serves_end_to_end():
    """zamba2 (mamba conv/ssm state + shared-attention KV rows) serves through
    the same engine via the recurrent backend, staggered == sequential."""
    hp = init_model(HYBRID_CFG, jax.random.PRNGKey(3))
    prompts = _prompts([5, 9, 4], cfg=HYBRID_CFG)
    gens = [4, 3, 5]
    staggered = _staggered(hp, prompts, gens, cfg=HYBRID_CFG)
    sequential = _sequential(hp, prompts, gens, cfg=HYBRID_CFG)
    assert staggered == sequential


# ===========================================================================
# block-native paged decode + chunked long-prompt admission
# ===========================================================================

@pytest.mark.parametrize("family,kv_dtype", [
    ("dense", "bfloat16"), ("dense", "int8"), ("moe", "bfloat16"),
])
def test_paged_native_decode_bit_identical_to_bridge(
        params, moe_params, family, kv_dtype):
    """The block-native contract: decode attending over the pool through the
    block tables (no gather view) produces bit-identical tokens to the
    gather-bridge path — for float, int8-per-token-scale, and MoE cache
    formats — the bridge stays available as the reference oracle, and native
    mode's peak decode working set is the pool alone
    (memory_stats decode_view_bytes == 0)."""
    base, p = (CFG, params) if family == "dense" else (MOE_CFG, moe_params)
    cfg = base.replace(kv_cache_dtype=kv_dtype)
    prompts = _prompts([5, 9, 4, 7])
    gens = [6, 5, 8, 3]
    toks_b = _staggered(p, prompts, gens, cfg=cfg, cache_backend="paged",
                        block_size=8)
    toks_n = _staggered(p, prompts, gens, cfg=cfg, cache_backend="paged",
                        block_size=8, paged_native=True)
    assert toks_n == toks_b                       # bit-identical, not allclose

    # working-set accounting: bridge reports the transient view, native 0
    eng_b = Engine(cfg, p, EngineConfig(max_slots=2, max_seq_len=32,
                                        cache_backend="paged", block_size=8))
    eng_n = Engine(cfg, p, EngineConfig(max_slots=2, max_seq_len=32,
                                        cache_backend="paged", block_size=8,
                                        paged_native=True))
    for e in (eng_b, eng_n):
        e.submit(prompts[0], gens[0])
        e.step()
    ms_b, ms_n = eng_b.stats()["cache"], eng_n.stats()["cache"]
    assert ms_b["decode_view_bytes"] > 0
    assert ms_n["decode_view_bytes"] == 0
    assert ms_n["bytes"] == ms_b["bytes"]         # same resident pool
    # seeded + decoded cache contents agree on every valid position
    view_b, view_n = eng_b.store.gather_view(), eng_n.store.gather_view()
    for slot, req in eng_b.scheduler.active.items():
        n = eng_b.store.slot_index(slot)
        for name in ("k", "v", "k_scale", "v_scale"):
            if name not in view_b:
                continue
            np.testing.assert_array_equal(
                np.asarray(view_b[name][:, slot, :n]),
                np.asarray(view_n[name][:, slot, :n]),
                err_msg=f"native cache leaf {name!r} diverged ({kv_dtype})")
    eng_b.close()
    eng_n.close()


def test_paged_native_requires_paged_backend(params):
    with pytest.raises(ValueError, match="paged"):
        Engine(CFG, params, EngineConfig(max_slots=2, max_seq_len=32,
                                         paged_native=True))
    with pytest.raises(ValueError, match="paged_native"):
        Engine(CFG, params, EngineConfig(max_slots=2, max_seq_len=32,
                                         cache_backend="paged",
                                         paged_kernel=True))
    with pytest.raises(ValueError, match="paged"):
        make_store(CFG, 2, 32, backend="contiguous", native=True)


@pytest.mark.parametrize("family,kv_dtype", [
    ("dense", "bfloat16"), ("dense", "int8"), ("moe", "bfloat16"),
])
def test_chunked_prefill_bit_identical_to_fused(params, moe_params, family,
                                                kv_dtype):
    """The chunked-admission guarantee: prompts admitted through the chunked
    prefill scan (fixed-width chunks attending over everything already
    written) produce the bit-identical first token, seeded cache, and decode
    continuation of the single-shot fused prefill — for float and int8-KV
    cache formats, and for MoE (row-isolated dropless routing makes a
    token's expert assignment independent of which chunk carried it)."""
    base, p = (CFG, params) if family == "dense" else (MOE_CFG, moe_params)
    cfg = base.replace(kv_cache_dtype=kv_dtype)
    prompts = _prompts([5, 9, 4, 20])             # buckets 16, 16, 16, 32
    gens = [6, 5, 8, 3]
    ecfg_f = EngineConfig(max_slots=2, max_seq_len=32)
    ecfg_c = EngineConfig(max_slots=2, max_seq_len=32, prefill_chunk=8)
    eng_f = Engine(cfg, p, ecfg_f)
    eng_c = Engine(cfg, p, ecfg_c)
    reqs_f = [eng_f.submit(pr, g) for pr, g in zip(prompts, gens)]
    reqs_c = [eng_c.submit(pr, g) for pr, g in zip(prompts, gens)]
    eng_f._admit()
    eng_c._admit()
    # freshly admitted rows bit-equal on every leaf (pad tails included)
    for name in eng_f.kv.cache:
        np.testing.assert_array_equal(
            np.asarray(eng_f.kv.cache[name]), np.asarray(eng_c.kv.cache[name]),
            err_msg=f"chunk-seeded cache leaf {name!r} diverged ({kv_dtype})")
    eng_f.run_until_complete()
    eng_c.run_until_complete()
    assert ([list(r.tokens) for r in reqs_c]
            == [list(r.tokens) for r in reqs_f])  # bit-identical, not allclose
    # the audit trail shows chunked instructions carried the wide buckets
    flags = eng_c.stats()["opq"]["flags"]
    assert any(f.startswith("prefill_chunked/") for f in flags)
    eng_f.close()
    eng_c.close()


def test_long_prompt_admits_via_chunking(params):
    """The admission cap lifts: a prompt wider than every fused bucket is
    rejected by the single-shot engine but admits through chunk-multiple
    buckets when prefill_chunk is set — and decodes exactly the tokens of
    serving it alone through an unconstrained engine."""
    long_prompt = _prompts([20])[0]
    eng_nochunk = Engine(CFG, params, EngineConfig(max_slots=2, max_seq_len=32,
                                                   buckets=(8,)))
    assert eng_nochunk.submit(long_prompt, 5) is None    # 20 > max bucket 8
    eng_nochunk.close()

    eng = Engine(CFG, params, EngineConfig(max_slots=2, max_seq_len=32,
                                           buckets=(8,), prefill_chunk=8))
    r = eng.submit(long_prompt, 5)
    assert r is not None                                  # > max bucket: admits
    eng.run_until_complete()
    assert r.tokens == _pure_sequential_decode(CFG, params, long_prompt, 5, 32)
    eng.close()

    # chunked + paged-native compose: the long prompt seeds block layout
    eng_p = Engine(CFG, params, EngineConfig(max_slots=2, max_seq_len=32,
                                             buckets=(8,), prefill_chunk=8,
                                             cache_backend="paged",
                                             block_size=8, paged_native=True))
    rp = eng_p.submit(long_prompt, 5)
    eng_p.run_until_complete()
    assert rp.tokens == r.tokens
    assert eng_p.stats()["cache"]["decode_view_bytes"] == 0
    eng_p.close()


def test_chunked_prefill_rejects_bad_config(params):
    with pytest.raises(ValueError, match="prefill_chunk"):
        Engine(CFG, params, EngineConfig(max_slots=2, max_seq_len=32,
                                         prefill_chunk=64))   # > max_seq_len
    with pytest.raises(ValueError, match="recurrent"):
        xp = init_model(XLSTM_CFG, jax.random.PRNGKey(2))
        Engine(XLSTM_CFG, xp, EngineConfig(max_slots=2, max_seq_len=32,
                                           prefill_chunk=8))
    with pytest.raises(ValueError, match="mrope"):
        Engine(CFG.replace(rope_kind="mrope"), params,
               EngineConfig(max_slots=2, max_seq_len=32, prefill_chunk=8))


def test_paged_lease_batches_table_uploads(params):
    """Regression (store.py lease): leases mutate only the host table mirror;
    the device copy uploads ONCE per admission round when decode next needs
    it — not once per lease."""
    store = make_store(CFG, 4, 32, backend="paged", block_size=8)
    assert store.table_uploads == 0
    for slot in range(3):
        assert store.lease(slot, 8, 8)
    assert store.table_uploads == 0               # three leases, zero uploads
    store.decode_cache()
    assert store.table_uploads == 1               # one batched upload
    store.decode_cache()
    assert store.table_uploads == 1               # clean: no re-upload
    assert store.lease(3, 8, 8)
    store.gather_view()
    assert store.table_uploads == 2
    # the device copy the sync produced matches the host mirror
    np.testing.assert_array_equal(np.asarray(store.cache["tables"]),
                                  store._tables)

    # engine-level: a 3-request admission round costs one upload, and a
    # full serving run stays at one upload per admission round
    eng = Engine(CFG, params, EngineConfig(max_slots=4, max_seq_len=32,
                                           cache_backend="paged",
                                           block_size=8))
    for pr in _prompts([5, 9, 4]):
        eng.submit(pr, 4)
    eng.step()
    assert eng.store.table_uploads == 1
    eng.run_until_complete()
    assert eng.store.table_uploads == 1           # no further admission rounds
    eng.close()


def test_engine_zero_progress_raises_immediately(params):
    """Satellite regression (engine.py run_until_complete): a queue head
    deferred by the store lease while zero slots are active can never make
    progress — the engine must raise a diagnostic immediately instead of
    spinning max_steps no-op iterations."""
    eng = Engine(CFG, params, EngineConfig(max_slots=2, max_seq_len=32,
                                           cache_backend="paged",
                                           block_size=8))
    # simulate fits/lease drift: fits admits at submit, lease then refuses
    eng.store.lease = lambda *a, **kw: False
    req = eng.submit(_prompts([8])[0], 4)
    assert req is not None
    with pytest.raises(RuntimeError, match="livelock") as ei:
        eng.run_until_complete()
    # the diagnostic names the stuck request and the pool state
    assert f"request {req.id}" in str(ei.value)
    assert "blocks_free" in str(ei.value)
    eng.close()


def test_paged_fits_boundary_pool_smaller_than_slot_table(params):
    """fits() clamps against min(n_blocks - 1, blocks_per_slot): with a pool
    SMALLER than one slot's table, a request needing exactly the whole pool
    (n_blocks - 1 blocks) must admit, one block more must bounce at submit —
    the line that keeps submit-reject and lease-defer from drifting into the
    livelock fits() exists to prevent."""
    # blocks_per_slot = 32/8 = 4, pool = 3 usable blocks < 4
    store = make_store(CFG, 2, 32, backend="paged", block_size=8, n_blocks=4)
    assert store.fits(16, 8)                      # 3 blocks == n_blocks - 1
    assert store.lease(0, 16, 8)                  # and lease agrees
    store.reset(0)
    assert not store.fits(17, 8)                  # 4 blocks > pool: reject
    assert not store.fits(32, 0)                  # whole table, pool too small

    eng = Engine(CFG, params, EngineConfig(max_slots=2, max_seq_len=32,
                                           cache_backend="paged",
                                           block_size=8, n_blocks=4))
    assert eng.submit(_prompts([17])[0], 8) is None       # can never lease
    ok = eng.submit(_prompts([16])[0], 8)                 # exactly the pool
    assert ok is not None
    eng.run_until_complete()                      # completes, no livelock
    assert ok.metrics.n_generated == 8
    eng.close()


def test_paged_fits_boundary_table_caps_below_pool():
    """The other side of the clamp: a pool larger than one slot's table must
    still reject requests wider than the table (they could never be mapped),
    even with plenty of free blocks."""
    # blocks_per_slot = 2, pool = 8 usable blocks
    store = make_store(CFG, 2, 16, backend="paged", block_size=8, n_blocks=9)
    assert store.fits(8, 8)                       # 2 blocks == table width
    assert not store.fits(16, 8)                  # 3 blocks > table width
    assert store.lease(0, 8, 8)
    assert not store.lease(1, 16, 8)              # lease agrees with fits


try:
    from hypothesis import given, settings as hyp_settings, strategies as hyp_st
except ImportError:                                    # clean container
    from _hypothesis_fallback import (
        given, settings as hyp_settings, st as hyp_st)


@hyp_settings(max_examples=5, deadline=None)   # each example builds 14 stores
@given(hyp_st.integers(min_value=0, max_value=2**31 - 1))
def test_pristine_equals_init_cache_every_family_leaf(seed):
    """Property: ``pristine_value``/``_PRISTINE`` (store.py) is bit-equal to
    ``models/serve.py init_cache``'s empty fill for EVERY leaf of EVERY
    servable family's store — and a slot retired after arbitrary payload
    writes is restored to exactly that pattern, including the paged backend's
    block scrub. Guards the two definitions of "empty" against drift."""
    from repro.serving.store import pristine_value

    rng = np.random.default_rng(seed)
    cases = [
        (CFG, "contiguous"), (CFG.replace(kv_cache_dtype="int8"), "contiguous"),
        (MOE_CFG, "contiguous"), (CFG, "paged"),
        (CFG.replace(kv_cache_dtype="int8"), "paged"),
        (XLSTM_CFG, "recurrent"), (HYBRID_CFG, "recurrent"),
    ]
    for cfg, backend in cases:
        store = make_store(cfg, 2, 16, backend=backend, block_size=8)
        fresh = jax.tree_util.tree_flatten_with_path(store.cache)[0]
        # 1) a fresh alloc is the pristine pattern everywhere
        for path, leaf in fresh:
            name = _leaf_name_str(path)
            if name == "tables":
                continue
            np.testing.assert_array_equal(
                np.asarray(leaf),
                np.full(leaf.shape, pristine_value(name), leaf.dtype),
                err_msg=f"{cfg.family}/{backend} init leaf {name!r} is not "
                        f"the pristine_value fill")
        # 2) write a random payload into slot 0, retire, compare to fresh
        store.lease(0, 8, 8)

        def junk_row(path, l):
            name = _leaf_name_str(path)
            if name in ("index", "tables"):
                return jnp.zeros((1,), jnp.int32)      # ignored by write_slot
            return jnp.asarray(rng.integers(1, 5, (l.shape[0], 1) + l.shape[2:])
                               .astype(l.dtype))

        src = jax.tree_util.tree_map_with_path(junk_row, store.cache)
        store.write_slot(0, src, n_valid=8)
        store.reset(0)
        ref = make_store(cfg, 2, 16, backend=backend, block_size=8)
        got = jax.tree_util.tree_flatten_with_path(store.cache)[0]
        want = jax.tree_util.tree_flatten_with_path(ref.cache)[0]
        for (path, g), (_, w) in zip(got, want):
            name = _leaf_name_str(path)
            np.testing.assert_array_equal(
                np.asarray(g), np.asarray(w),
                err_msg=f"{cfg.family}/{backend} leaf {name!r} not pristine "
                        f"after retire (seed {seed})")


def _leaf_name_str(path) -> str:
    for p in reversed(path):
        key = getattr(p, "key", getattr(p, "name", ""))
        if key:
            return str(key)
    return ""


def test_memory_stats_surface(params):
    """memory_stats flows from the store through engine.stats() to the
    human-readable report line."""
    eng = Engine(CFG, params, EngineConfig(max_slots=2, max_seq_len=32,
                                           cache_backend="paged",
                                           block_size=8))
    eng.submit(_prompts([6])[0], 4)
    eng.step()
    ms = eng.stats()["cache"]
    assert ms["backend"] == "paged" and ms["blocks_used"] > 0
    assert ms["bytes"] == eng.store.nbytes() > 0
    line = format_memory_stats(ms)
    assert "paged" in line and "blocks" in line
    eng.run_until_complete()
    assert eng.stats()["cache"]["blocks_used"] == 0
    eng.close()
    contiguous = format_memory_stats(make_store(CFG, 2, 32).memory_stats())
    assert "contiguous" in contiguous